//! Distributed refinement demo: one OS thread per machine running the
//! paper's Fig.-2 trigger protocol (token ring, `ReceiveNodeTrigger`,
//! `RegularUpdateTrigger`) over a message bus, with the §4.5 overhead
//! accounting that shows synchronization cost is O(K) per transfer —
//! independent of the number of simulated LPs.
//!
//! Run: `cargo run --release --example distributed_refinement -- \
//!        [--nodes N] [--k K] [--seed S] [--latency-us U]`

use std::sync::Arc;
use std::time::{Duration, Instant};

use gtip::coordinator::{run_distributed, DistributedOptions};
use gtip::graph::generators::preferential_attachment;
use gtip::partition::initial::grow_partition;
use gtip::partition::{global_cost, MachineConfig};
use gtip::util::cli::Args;
use gtip::util::rng::Pcg32;

fn main() {
    let args = Args::from_env().expect("args");
    let k = args.opt_or::<usize>("k", 5).expect("k");
    let seed = args.opt_or::<u64>("seed", 2011).expect("seed");
    let latency_us = args.opt_or::<u64>("latency-us", 0).expect("latency-us");

    println!("== distributed refinement: Fig. 2 trigger protocol, {k} machine actors ==\n");
    println!("{:<8} {:>10} {:>10} {:>10} {:>12} {:>14} {:>10}",
        "N", "transfers", "msgs", "bytes", "bytes/xfer", "C0 drop", "wall ms");

    for nodes in [200usize, 400, 800, 1600] {
        let mut rng = Pcg32::new(seed);
        let graph = Arc::new(preferential_attachment(nodes, 2, &mut rng));
        let machines = MachineConfig::homogeneous(k);
        let initial = grow_partition(&graph, &machines, &mut rng);
        let c0_before = global_cost::c0(&graph, &machines, &initial, 8.0);

        let t0 = Instant::now();
        let report = run_distributed(
            Arc::clone(&graph),
            &machines,
            initial,
            &DistributedOptions {
                latency: Duration::from_micros(latency_us),
                ..Default::default()
            },
        );
        let wall = t0.elapsed();
        let c0_after = global_cost::c0(&graph, &machines, &report.partition, 8.0);

        println!(
            "{:<8} {:>10} {:>10} {:>10} {:>12.1} {:>13.1}% {:>10.1}",
            nodes,
            report.transfers,
            report.overhead.total_messages(),
            report.overhead.total_bytes(),
            report.overhead.bytes_per_transfer(report.transfers as u64),
            100.0 * (c0_before - c0_after) / c0_before,
            wall.as_secs_f64() * 1e3,
        );
    }

    println!("\nbytes/transfer is flat across N — the paper's §4.5 feasibility claim:");
    println!("machines exchange only O(K) aggregate state, never per-node state.");
}

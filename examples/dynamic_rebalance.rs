//! The paper's *title* scenario end to end: closed-loop dynamic load
//! balancing under every scripted drifting workload.
//!
//! For each scenario (hot-spot shift, flash crowd, diurnal ramp,
//! failure/rejoin) the same graph, workload, and initial partition run
//! twice: once with the initial partition frozen, once with the
//! `sim::dynamic` loop re-measuring loads every epoch, smoothing them
//! through an EWMA estimator, and re-refining warm-started from the
//! previous equilibrium. The headline number is the wall-tick speedup
//! of the rebalanced arm (cf. paper Figs. 7/8).
//!
//! Run: `cargo run --release --example dynamic_rebalance [-- --seed S]`

use gtip::graph::generators::preferential_attachment;
use gtip::partition::initial::grow_partition;
use gtip::partition::MachineConfig;
use gtip::sim::dynamic::{compare_frozen_vs_rebalanced, DynamicOptions, WeightEstimator};
use gtip::sim::engine::SimOptions;
use gtip::sim::scenario::{Scenario, ScenarioKind, ScenarioOptions};
use gtip::util::cli::Args;
use gtip::util::rng::Pcg32;

fn main() {
    let args = Args::from_env().expect("args");
    let seed = args.opt_or::<u64>("seed", 2011).expect("seed");
    let nodes = args.opt_or::<usize>("nodes", 150).expect("nodes");
    let threads = args.opt_or::<usize>("threads", 160).expect("threads");
    let epoch_ticks = args.opt_or::<u64>("epoch-ticks", 200).expect("epoch-ticks");

    println!("== closed-loop dynamic rebalancing across drifting workloads ==");
    println!(
        "   {nodes} LPs, 4 machines, {threads} floods per scenario, epoch = {epoch_ticks} ticks, EWMA estimator\n"
    );

    let machines = MachineConfig::homogeneous(4);
    let options = DynamicOptions {
        sim: SimOptions { max_ticks: 2_000_000, ..Default::default() },
        epoch_ticks,
        ..Default::default()
    };

    let mut wins = 0;
    for kind in ScenarioKind::ALL {
        let mut rng = Pcg32::new(seed);
        let graph = preferential_attachment(nodes, 2, &mut rng);
        let scenario = Scenario::build(
            kind,
            &graph,
            &ScenarioOptions { threads, ..Default::default() },
            &mut rng,
        );
        let initial = grow_partition(&graph, &machines, &mut rng);
        let report = compare_frozen_vs_rebalanced(
            &graph,
            &machines,
            &initial,
            &scenario.injections,
            WeightEstimator::ewma(0.5),
            &options,
        );
        if report.rebalanced.total_time() < report.frozen.total_time() {
            wins += 1;
        }
        println!(
            "{:<8} ({:<55}) frozen {:>7} ticks | rebalanced {:>7} ticks | {:>2} refinements, {:>4} transfers | speedup {:.2}x",
            kind.name(),
            kind.describe(),
            report.frozen.total_time(),
            report.rebalanced.total_time(),
            report.rebalanced.refinements(),
            report.rebalanced.transfers,
            report.speedup(),
        );
    }
    println!(
        "\nrebalancing beat the frozen partition on {wins}/{} scenarios",
        ScenarioKind::ALL.len()
    );

    // Zoom into the hot-spot scenario's epoch stream.
    let mut rng = Pcg32::new(seed);
    let graph = preferential_attachment(nodes, 2, &mut rng);
    let scenario = Scenario::build(
        ScenarioKind::HotspotShift,
        &graph,
        &ScenarioOptions { threads, ..Default::default() },
        &mut rng,
    );
    let initial = grow_partition(&graph, &machines, &mut rng);
    let report = compare_frozen_vs_rebalanced(
        &graph,
        &machines,
        &initial,
        &scenario.injections,
        WeightEstimator::ewma(0.5),
        &options,
    );
    println!("\n{}", report.rebalanced.epoch_table("hotspot — per-epoch closed loop").to_text());
}

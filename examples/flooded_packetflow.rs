//! END-TO-END driver (EXPERIMENTS.md §E2E): the full system on a real
//! small workload, proving all layers compose.
//!
//! A 230-LP preferential-attachment network model runs under the
//! optimistic (Time Warp) simulator archetype with a limited-scope
//! flooded packet-flow workload and moving traffic hot spots (§6.1).
//! Every 500 wall ticks the live node/edge weights are measured and the
//! game-theoretic refinement re-balances the LP-to-machine assignment.
//! The run reports the paper's headline metric — total simulation
//! execution time — against the no-refinement baseline, plus the load
//! traces and rollback counts.
//!
//! Run: `cargo run --release --example flooded_packetflow [-- --seed S]`

use gtip::game::cost::Framework;
use gtip::graph::generators::preferential_attachment;
use gtip::partition::MachineConfig;
use gtip::sim::driver::{run_dynamic, DriverOptions};
use gtip::sim::engine::SimOptions;
use gtip::sim::workload::{FloodWorkload, WorkloadOptions};
use gtip::util::cli::Args;
use gtip::util::rng::Pcg32;
use gtip::util::stats::ascii_chart;

fn main() {
    let args = Args::from_env().expect("args");
    let seed = args.opt_or::<u64>("seed", 2011).expect("seed");
    let nodes = args.opt_or::<usize>("nodes", 230).expect("nodes");
    let threads = args.opt_or::<usize>("threads", 150).expect("threads");

    println!("== end-to-end: optimistic PDES + dynamic game-theoretic refinement ==");
    println!("   {nodes} LPs, 5 machines, {threads} packet floods, hot spots moving every 500 ticks\n");

    let machines = MachineConfig::homogeneous(5);
    let wl = WorkloadOptions {
        threads,
        horizon_ticks: 4_000,
        hot_spot_period: 500,
        ..Default::default()
    };

    let mut results = Vec::new();
    for (label, refine_every, fw) in [
        ("no refinement     ", 0u64, Framework::A),
        ("framework A @ 500 ", 500, Framework::A),
        ("framework B @ 500 ", 500, Framework::B),
    ] {
        let mut rng = Pcg32::new(seed);
        let graph = preferential_attachment(nodes, 2, &mut rng);
        let workload = FloodWorkload::generate(&graph, &wl, &mut rng);
        let options = DriverOptions {
            sim: SimOptions { trace_every: 50, max_ticks: 1_000_000, ..Default::default() },
            refine_every,
            framework: fw,
            mu: 8.0,
            ticks_per_transfer: 0,
        };
        let report = run_dynamic(&graph, &machines, workload, &options, &mut rng);
        println!(
            "{label}: sim time {:>7} ticks | rollbacks {:>6} | cross-machine forwards {:>6} | refinements {:>3} | transfers {:>5}",
            report.total_time(),
            report.stats.rollbacks,
            report.stats.cross_machine_forwards,
            report.refinements,
            report.transfers,
        );
        results.push((label, report));
    }

    let baseline = results[0].1.total_time() as f64;
    let refined = results[1].1.total_time() as f64;
    println!(
        "\nspeedup from dynamic refinement (framework A): {:.2}x (paper Figs. 7/8: simulation time drops with refinement)",
        baseline / refined
    );

    println!("\nmachine-load traces of the refined run (cf. paper Fig. 10):");
    println!("{}", ascii_chart(&results[1].1.load_traces, 60, 10));
}

//! Framework comparison on a user-configurable setup — a Table-I-style
//! head-to-head between the C_i (eq. 1) and C̃_i (eq. 6) cost criteria,
//! including the §5.1 discrepancy statistics.
//!
//! Run: `cargo run --release --example framework_comparison -- \
//!        [--nodes N] [--trials T] [--mu MU] [--seed S]`

use gtip::experiments::common::{run_tracked, StudySetup};
use gtip::game::cost::Framework;
use gtip::partition::MachineConfig;
use gtip::util::cli::Args;
use gtip::util::rng::Pcg32;
use gtip::util::table::Table;

fn main() {
    let args = Args::from_env().expect("args");
    let nodes = args.opt_or::<usize>("nodes", 230).expect("nodes");
    let trials = args.opt_or::<usize>("trials", 5).expect("trials");
    let mu = args.opt_or::<f64>("mu", 8.0).expect("mu");
    let seed = args.opt_or::<u64>("seed", 1).expect("seed");

    let setup = StudySetup {
        nodes,
        machines: MachineConfig::from_speeds(&[0.1, 0.2, 0.3, 0.3, 0.1]),
        mu,
    };

    let mut table = Table::new(
        format!("Framework comparison (N={nodes}, mu={mu})"),
        &["trial", "A: C0", "A: C~0", "A: iters", "A: C~0-disc", "B: C0", "B: C~0", "B: iters", "B: C0-disc"],
    );
    let mut a_wins = 0;
    for trial in 1..=trials {
        let mut rng = Pcg32::new(seed.wrapping_add(trial as u64));
        let graph = setup.graph(&mut rng);
        let initial = setup.initial(&graph, &mut rng);
        let a = run_tracked(&graph, &setup.machines, initial.clone(), mu, Framework::A);
        let b = run_tracked(&graph, &setup.machines, initial, mu, Framework::B);
        if a.c0 <= b.c0 && a.c0_tilde <= b.c0_tilde {
            a_wins += 1;
        }
        table.row(&[
            trial.to_string(),
            format!("{:.0}", a.c0),
            format!("{:.0}", a.c0_tilde),
            a.iterations.to_string(),
            a.c0_tilde_discrepancies.to_string(),
            format!("{:.0}", b.c0),
            format!("{:.0}", b.c0_tilde),
            b.iterations.to_string(),
            b.c0_discrepancies.to_string(),
        ]);
    }
    println!("{}", table.to_text());
    println!("framework A best on both global costs in {a_wins}/{trials} trials");
    println!("(paper §5.1: A won both costs in 49 of 50 batch runs)");
}

//! Three-layer cross-check: evaluate a refinement step through the AOT
//! Pallas/JAX HLO artifact on PJRT and compare, number by number, with
//! the native Rust evaluator. Also demonstrates driving a *refinement
//! decision* from the PJRT outputs alone.
//!
//! Requires `make artifacts`.
//! Run: `cargo run --release --example hlo_cost_eval`

use gtip::experiments::common::StudySetup;
use gtip::game::cost::Framework;
use gtip::game::refine::{RefineEngine, RefineOptions};
use gtip::runtime::cost_eval::{max_rel_error_vs_native, PjrtCostEvaluator};
use gtip::util::rng::Pcg32;

fn main() {
    let mut eval = match PjrtCostEvaluator::from_default_dir() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("artifacts unavailable: {e}\nrun `make artifacts` first");
            std::process::exit(1);
        }
    };

    let setup = StudySetup::default();
    let mut rng = Pcg32::new(2011);
    let graph = setup.graph(&mut rng);
    let part = setup.initial(&graph, &mut rng);

    // 1. Execute the AOT artifact.
    let out = eval.evaluate(&graph, &setup.machines, &part, setup.mu).unwrap();
    println!("PJRT refine_step (N={} padded to artifact ladder):", out.n);
    println!("  C0 = {:.0}   C~0 = {:.0}", out.c0, out.c0_tilde);

    // 2. Cross-check against the native evaluator.
    let err = max_rel_error_vs_native(&graph, &setup.machines, &part, setup.mu, &out);
    println!("  max relative error vs native Rust evaluator: {err:.2e}");
    assert!(err < 1e-3);

    // 3. Use the PJRT outputs to drive a transfer: pick the most
    //    dissatisfied node and its argmin machine from the artifact's
    //    outputs, apply it natively, verify the potential drops by
    //    exactly 2*dissatisfaction (Thm 3.1).
    let (node, &dissat) = out
        .dissat_a
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap();
    let target = out.best_a[node] as usize;
    println!("\nmost dissatisfied LP (per PJRT): node {node}, J = {dissat:.1}, best machine {target}");

    let mut engine = RefineEngine::new(&graph, &setup.machines, part, setup.mu, Framework::A);
    let before = engine.potential();
    let delta = engine.apply_transfer(node, target);
    println!("applied transfer: C0 {before:.0} -> {:.0} (delta {delta:.1} = -2*J, Thm 3.1)", engine.potential());
    assert!((delta + 2.0 * dissat as f64).abs() < 1e-2 * (1.0 + delta.abs()));

    // 4. Finish refinement natively and re-verify through PJRT.
    let _ = engine.run(&RefineOptions::default());
    let after = eval.evaluate(&graph, &setup.machines, engine.partition(), setup.mu).unwrap();
    println!("\nafter native convergence: PJRT-reported C0 = {:.0} (was {:.0})", after.c0, out.c0);
    assert!(after.c0 < out.c0);
    println!("three-layer stack verified: Pallas kernel == jnp ref == native Rust == PJRT execution");
}

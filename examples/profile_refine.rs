//! Profiling harness for the refinement hot loop (used with
//! `perf record -g` during the EXPERIMENTS.md §Perf pass): 60 full
//! refinement runs at N=10k / K=8 back to back.

use gtip::game::cost::Framework;
use gtip::game::refine::{RefineEngine, RefineOptions};
use gtip::graph::generators::preferential_attachment;
use gtip::partition::{MachineConfig, Partition};
use gtip::util::rng::Pcg32;
fn main() {
    let n = 10_000;
    let mut rng = Pcg32::new(n as u64);
    let graph = preferential_attachment(n, 2, &mut rng);
    let machines = MachineConfig::homogeneous(8);
    let part = Partition::from_assignment(&graph, 8, (0..n).map(|_| rng.index(8)).collect());
    let mut total = 0usize;
    for _ in 0..60 {
        let mut engine = RefineEngine::new(&graph, &machines, part.clone(), 8.0, Framework::A);
        total += engine.run(&RefineOptions::default()).transfers;
    }
    println!("{total}");
}

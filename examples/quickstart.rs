//! Quickstart: generate a random LP graph, build the App.-A initial
//! partition, refine it with both cost frameworks, and print the global
//! costs — the 60-second tour of the public API.
//!
//! Run: `cargo run --release --example quickstart`

use gtip::game::cost::Framework;
use gtip::game::refine::{RefineEngine, RefineOptions};
use gtip::graph::generators::{table1_graph, WeightModel};
use gtip::partition::initial::grow_partition;
use gtip::partition::{global_cost, MachineConfig};
use gtip::util::rng::Pcg32;

fn main() {
    // The paper's §5.1 setup: 230 LPs, degree 3-6, weights of mean 5,
    // five machines with normalized speeds (.1,.2,.3,.3,.1), mu = 8.
    let mut rng = Pcg32::new(2011);
    let graph = table1_graph(230, 3, 6, WeightModel::default(), &mut rng);
    let machines = MachineConfig::from_speeds(&[0.1, 0.2, 0.3, 0.3, 0.1]);
    let mu = 8.0;

    println!("graph: {} nodes / {} edges", graph.node_count(), graph.edge_count());

    // Appendix-A initial partitioning: focal nodes + hop-by-hop growth.
    let initial = grow_partition(&graph, &machines, &mut rng);
    let (c0, c0t) = global_cost::both(&graph, &machines, &initial, mu);
    println!("initial:      C0 = {c0:>12.0}   C~0 = {c0t:>10.0}   counts = {:?}", initial.counts());

    // Iterative refinement under each framework, from the same start.
    for fw in [Framework::A, Framework::B] {
        let mut engine = RefineEngine::new(&graph, &machines, initial.clone(), mu, fw);
        let report = engine.run(&RefineOptions::default());
        let (c0, c0t) = global_cost::both(&graph, &machines, engine.partition(), mu);
        println!(
            "framework {fw}:  C0 = {c0:>12.0}   C~0 = {c0t:>10.0}   transfers = {:>4}   converged = {}",
            report.transfers, report.converged
        );
    }

    println!("\n(the equilibrium is a pure-strategy Nash equilibrium: no LP can lower");
    println!(" its own cost by unilaterally moving to another machine — Thm 3.1/5.1)");
}

"""AOT lowering: jax -> HLO text artifacts for the Rust PJRT runtime.

Emits one artifact per padded problem shape (N in the size ladder, fixed
K) plus `manifest.txt` describing them. HLO *text* is the interchange
format — the image's xla_extension 0.5.1 rejects serialized protos from
jax >= 0.5 (64-bit instruction ids); the text parser reassigns ids. See
/opt/xla-example/README.md.

Usage: python -m compile.aot --out-dir ../artifacts [--sizes 256,512,1024]
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import refine_step

DEFAULT_SIZES = (256, 512, 1024)
DEFAULT_K = 8


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_refine_step(n: int, k: int) -> str:
    """Lower refine_step for padded shape (n, k) and return HLO text."""
    f32 = jnp.float32
    spec = lambda *shape: jax.ShapeDtypeStruct(shape, f32)  # noqa: E731
    lowered = jax.jit(refine_step).lower(
        spec(n),        # b
        spec(k),        # w
        spec(k),        # wmask
        spec(n, n),     # adj
        spec(n, k),     # xt
        spec(),         # mu
    )
    return to_hlo_text(lowered)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--sizes", default=",".join(str(s) for s in DEFAULT_SIZES),
        help="comma-separated padded node counts",
    )
    parser.add_argument("--k", type=int, default=DEFAULT_K, help="padded machine count")
    args = parser.parse_args()

    sizes = [int(s) for s in args.sizes.split(",") if s]
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_lines = ["gtip-artifacts v1"]
    for n in sizes:
        name = f"refine_step_n{n}_k{args.k}"
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = lower_refine_step(n, args.k)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"artifact {name} n={n} k={args.k} file={name}.hlo.txt")
        print(f"wrote {path} ({len(text)} chars)")

    manifest = os.path.join(args.out_dir, "manifest.txt")
    with open(manifest, "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {manifest}")


if __name__ == "__main__":
    main()

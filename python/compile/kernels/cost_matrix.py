"""Layer-1 Pallas kernel: fused dense cost-matrix computation.

The hot spot of a refinement epoch is rebuilding the full N x K cost
tables (both frameworks) from the adjacency matrix: the dominant term is
`adjrow = adj @ xt` — an (N,N)x(N,K) matmul — followed by a cheap
element-wise epilogue. This kernel tiles the matmul over rows of `adj`
(grid = N / BM programs) and fuses the epilogue so the cost tables are
produced in one pass without materializing `adjrow` in HBM.

TPU mapping (DESIGN.md section "Hardware adaptation"): each program holds
one (BM, N) strip of `adj` plus the (N, K) one-hot in VMEM and drives the
MXU with a (BM,N)x(N,K) contraction; the rank-1 load terms are a VPU
epilogue on the (BM, K) accumulator. `interpret=True` everywhere in this
repo: the CPU PJRT plugin cannot execute Mosaic custom-calls, so the
kernel is lowered to plain HLO for both testing and the AOT artifacts —
numerics are identical, scheduling is XLA's.

Inputs are pre-broadcast into 2-D tiles because Pallas BlockSpecs address
array blocks, not scalars:
  adj    f32[N, N]
  xt     f32[N, K]    one-hot assignment (xt[i,k] = 1 iff r_i = k)
  b      f32[N, 1]    node weights
  params f32[3, K]    rows: loads L_k, speeds w_k, machine mask
  scal   f32[1, 2]    [mu, B]
Outputs:
  costs_a f32[N, K], costs_b f32[N, K]
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.ref import BIG

# Default row-block size. 128 matches the MXU systolic dimension; padded
# shapes in aot.py are multiples of it.
DEFAULT_BLOCK_ROWS = 128


def _cost_kernel(adj_ref, xt_ref, b_ref, params_ref, scal_ref, out_a_ref, out_b_ref):
    """One program: rows [i*BM, (i+1)*BM) of both cost matrices."""
    adj_blk = adj_ref[...]          # (BM, N)
    xt_all = xt_ref[...]            # (N, K)
    b_blk = b_ref[...]              # (BM, 1)
    loads = params_ref[0, :]        # (K,)
    w = params_ref[1, :]            # (K,)
    wmask = params_ref[2, :]        # (K,)
    mu = scal_ref[0, 0]
    b_total = scal_ref[0, 1]

    # MXU part: adjacency-to-machine mass for this row strip.
    adjrow = jnp.dot(adj_blk, xt_all, preferred_element_type=jnp.float32)  # (BM, K)

    # VPU epilogue.
    s = jnp.sum(adj_blk, axis=1, keepdims=True)          # (BM, 1)
    # One-hot rows of this strip: xt[i, :] for i in the strip. The strip of
    # xt is addressed through a second BlockSpec view (same array, row
    # block): Pallas lets us slice xt_all because BM rows of xt are at the
    # same row offset as adj rows — recovered via index arithmetic below.
    # Instead of a gather we pass the strip directly: see xt_strip_ref in
    # cost_matrices_pallas (merged into b_ref? no — see wrapper), so here
    # we recompute it from program_id.
    i = pl.program_id(0)
    bm = adj_blk.shape[0]
    xt_strip = jax.lax.dynamic_slice_in_dim(xt_all, i * bm, bm, axis=0)  # (BM, K)

    same_load = loads[None, :] - b_blk * xt_strip
    cut = 0.5 * mu * (s - adjrow)
    penalty = (1.0 - wmask)[None, :] * BIG

    out_a_ref[...] = b_blk / w[None, :] * same_load + cut + penalty
    w2 = w * w
    out_b_ref[...] = (
        b_blk * b_blk / w2[None, :]
        + 2.0 * b_blk / w2[None, :] * same_load
        - 2.0 * b_blk / w[None, :] * b_total
        + cut
        + penalty
    )


@functools.partial(jax.jit, static_argnames=("block_rows",))
def cost_matrices_pallas(b, w, wmask, adj, xt, mu, *, block_rows=DEFAULT_BLOCK_ROWS):
    """Pallas-kernel version of `ref.cost_matrices_ref` (same signature,
    plus the row-block size)."""
    n = adj.shape[0]
    k = xt.shape[1]
    bm = min(block_rows, n)
    assert n % bm == 0, f"N={n} must be a multiple of block_rows={bm}"

    loads = xt.T @ b.astype(jnp.float32)
    b_total = jnp.sum(b)
    params = jnp.stack([loads, w.astype(jnp.float32), wmask.astype(jnp.float32)])
    scal = jnp.array([[0.0, 0.0]], dtype=jnp.float32)
    scal = scal.at[0, 0].set(jnp.asarray(mu, dtype=jnp.float32))
    scal = scal.at[0, 1].set(b_total.astype(jnp.float32))

    grid = (n // bm,)
    out_shape = [
        jax.ShapeDtypeStruct((n, k), jnp.float32),
        jax.ShapeDtypeStruct((n, k), jnp.float32),
    ]
    costs_a, costs_b = pl.pallas_call(
        _cost_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),   # adj strip
            pl.BlockSpec((n, k), lambda i: (0, 0)),    # full one-hot
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),   # b strip
            pl.BlockSpec((3, k), lambda i: (0, 0)),    # loads/w/mask
            pl.BlockSpec((1, 2), lambda i: (0, 0)),    # [mu, B]
        ],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
        ],
        out_shape=out_shape,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(adj, xt, b.astype(jnp.float32)[:, None], params, scal)
    return costs_a, costs_b

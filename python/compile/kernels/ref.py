"""Pure-jnp reference (oracle) for the dense cost-matrix computation.

This mirrors, in straightforward jax.numpy, exactly what the Pallas
kernel (`cost_matrix.py`) and the Rust native evaluator
(`gtip::game::cost::dense_cost_matrices`) compute:

  Framework A (paper eq. 1):
      C[i,k]  = b_i / w_k * (L_k - b_i * X[k,i]) + (mu/2) * (S_i - A_ik)
  Framework B (paper eq. 6):
      C~[i,k] = b_i^2/w_k^2 + 2 b_i/w_k^2 * (L_k - b_i X[k,i])
                - 2 b_i / w_k * B + (mu/2) * (S_i - A_ik)

with A_ik = sum_j adj[i,j] X[k,j] (adjacency-to-machine mass), L = X b
(aggregate loads), S_i = sum_j adj[i,j], B = sum_i b_i. Padding machines
(wmask == 0) are pushed to +BIG so argmin/min never select them.

pytest compares the Pallas kernel against this module; the Rust
integration test compares the AOT HLO executable against the Rust native
evaluator, closing the loop across all three implementations.
"""

import jax.numpy as jnp

# Large additive penalty for masked (padding) machines. Kept finite so
# arithmetic stays NaN-free in f32.
BIG = 1.0e30


def cost_matrices_ref(b, w, wmask, adj, xt, mu):
    """Dense cost matrices for both frameworks.

    Args:
      b:     f32[N]   node weights (0 for padded nodes).
      w:     f32[K]   normalized machine speeds (1 for padded machines).
      wmask: f32[K]   1 for real machines, 0 for padding.
      adj:   f32[N,N] symmetric edge-weight matrix (0 diag, 0 padding).
      xt:    f32[N,K] one-hot assignment, xt[i,k] = 1 iff node i on k.
      mu:    f32[]    rollback-delay weight.

    Returns:
      (costs_a, costs_b): each f32[N,K].
    """
    b = b.astype(jnp.float32)
    loads = xt.T @ b                           # L_k, shape (K,)
    b_total = jnp.sum(b)                       # B
    adjrow = adj @ xt                          # A_ik, shape (N,K)
    s = jnp.sum(adj, axis=1, keepdims=True)    # S_i, shape (N,1)

    bcol = b[:, None]                          # (N,1)
    same_load = loads[None, :] - bcol * xt     # L_k - b_i X[k,i]
    cut = 0.5 * mu * (s - adjrow)              # (N,K)
    penalty = (1.0 - wmask)[None, :] * BIG

    costs_a = bcol / w[None, :] * same_load + cut + penalty
    w2 = w * w
    costs_b = (
        bcol * bcol / w2[None, :]
        + 2.0 * bcol / w2[None, :] * same_load
        - 2.0 * bcol / w[None, :] * b_total
        + cut
        + penalty
    )
    return costs_a, costs_b


def refine_step_ref(b, w, wmask, adj, xt, mu):
    """Full L2 reference: cost matrices + dissatisfaction + argmin + globals.

    Returns a tuple:
      costs_a  f32[N,K]
      costs_b  f32[N,K]
      dissat_a f32[N]   (eq. 4 under framework A)
      dissat_b f32[N]
      best_a   i32[N]   argmin_k C[i,k]
      best_b   i32[N]
      c0       f32[]    sum_i C_i(r_i)            (Thm 3.1 potential)
      c0t      f32[]    eq. 8 with (mu/2)*cut     (Thm 5.1 potential)
    """
    costs_a, costs_b = cost_matrices_ref(b, w, wmask, adj, xt, mu)

    cur_a = jnp.sum(costs_a * xt, axis=1)
    cur_b = jnp.sum(costs_b * xt, axis=1)
    min_a = jnp.min(costs_a, axis=1)
    min_b = jnp.min(costs_b, axis=1)
    dissat_a = jnp.maximum(cur_a - min_a, 0.0)
    dissat_b = jnp.maximum(cur_b - min_b, 0.0)
    best_a = jnp.argmin(costs_a, axis=1).astype(jnp.int32)
    best_b = jnp.argmin(costs_b, axis=1).astype(jnp.int32)

    # Global costs. Padded nodes sit on machine 0 (real) with b=0 and no
    # edges, so their current cost is exactly 0 and they do not perturb
    # the sums.
    c0 = jnp.sum(cur_a)
    b_total = jnp.sum(b)
    loads = xt.T @ b
    dev = wmask * (loads / w - b_total)
    s = jnp.sum(adj, axis=1)
    adj_cur = jnp.sum((adj @ xt) * xt, axis=1)
    cut_weight = 0.5 * jnp.sum(s - adj_cur)    # each undirected cut edge once
    c0t = jnp.sum(dev * dev) + 0.5 * mu * cut_weight
    return costs_a, costs_b, dissat_a, dissat_b, best_a, best_b, c0, c0t

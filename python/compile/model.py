"""Layer-2 JAX model: one full refinement-epoch evaluation step.

`refine_step` is the computation the Rust coordinator executes through
PJRT at every refinement-epoch start: dense cost tables for both
frameworks (via the L1 Pallas kernel), per-node dissatisfaction and
best-response machines (paper eq. 4), and both global potentials
(Thm 3.1 / eq. 8). Build-time only — `aot.py` lowers it to HLO text; no
Python at partitioning time.
"""

import jax.numpy as jnp

from compile.kernels.cost_matrix import cost_matrices_pallas


def refine_step(b, w, wmask, adj, xt, mu):
    """Full refinement-step evaluation (shapes as in kernels/ref.py).

    Returns an 8-tuple:
      costs_a f32[N,K], costs_b f32[N,K],
      dissat_a f32[N], dissat_b f32[N],
      best_a i32[N], best_b i32[N],
      c0 f32[], c0t f32[]
    """
    costs_a, costs_b = cost_matrices_pallas(b, w, wmask, adj, xt, mu)

    cur_a = jnp.sum(costs_a * xt, axis=1)
    cur_b = jnp.sum(costs_b * xt, axis=1)
    dissat_a = jnp.maximum(cur_a - jnp.min(costs_a, axis=1), 0.0)
    dissat_b = jnp.maximum(cur_b - jnp.min(costs_b, axis=1), 0.0)
    best_a = jnp.argmin(costs_a, axis=1).astype(jnp.int32)
    best_b = jnp.argmin(costs_b, axis=1).astype(jnp.int32)

    # Global potentials (cheap reductions; fused by XLA into the epilogue).
    c0 = jnp.sum(cur_a)
    b32 = b.astype(jnp.float32)
    loads = xt.T @ b32
    b_total = jnp.sum(b32)
    dev = wmask * (loads / w - b_total)
    # Cut term WITHOUT a second N x N matmul (PERF, EXPERIMENTS.md §Perf
    # change 3): each node's current framework-A cost decomposes as
    #   cur_a_i = b_i/w_{r_i} (L_{r_i} - b_i) + (mu/2)(S_i - A_{i,r_i})
    # so summing (cur_a_i - loadterm_i) yields (mu/2) * sum_i cut_i =
    # mu * cut_weight exactly, and C~0's cut term (mu/2)*cut_weight is
    # half of that. Algebraically identical to the ref oracle.
    w_cur = xt @ w                   # w_{r_i}
    l_cur = xt @ loads               # L_{r_i}
    loadterm = b32 / w_cur * (l_cur - b32)
    mu_cut = jnp.sum(cur_a - loadterm)   # = mu * cut_weight
    c0t = jnp.sum(dev * dev) + 0.5 * mu_cut

    return costs_a, costs_b, dissat_a, dissat_b, best_a, best_b, c0, c0t

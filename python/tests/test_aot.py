"""AOT lowering tests: the HLO-text pipeline used by `make artifacts`.

Uses small padded shapes so the test is fast; asserts the artifact text
is parseable-looking HLO with the right entry computation and that the
manifest writer round-trips through the CLI path.
"""

import os
import subprocess
import sys

from compile.aot import lower_refine_step


def test_lowering_produces_hlo_text():
    text = lower_refine_step(32, 8)
    assert "HloModule" in text
    assert "ENTRY" in text
    # All six parameters present.
    for i in range(6):
        assert f"parameter({i})" in text, f"missing parameter({i})"
    # The heavy op made it in.
    assert "dot(" in text or "dot " in text


def test_lowering_shapes_encode_padded_size():
    text = lower_refine_step(64, 8)
    assert "f32[64,64]" in text          # adjacency parameter
    assert "f32[64,8]" in text           # one-hot / cost matrices
    assert "s32[64]" in text             # argmin outputs


def test_cli_writes_manifest(tmp_path):
    out = tmp_path / "arts"
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--sizes", "32", "--k", "8"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    manifest = (out / "manifest.txt").read_text()
    assert manifest.startswith("gtip-artifacts v1")
    assert "refine_step_n32_k8" in manifest
    assert (out / "refine_step_n32_k8.hlo.txt").exists()


def test_different_sizes_differ_only_in_shapes():
    a = lower_refine_step(32, 8)
    b = lower_refine_step(64, 8)
    assert a != b
    assert a.count("ENTRY") == b.count("ENTRY") == 1

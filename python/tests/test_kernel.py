"""L1 correctness: the Pallas kernel against the pure-jnp oracle.

Hypothesis sweeps problem shapes, weights, assignments and mu; every
case asserts allclose between `cost_matrices_pallas` and
`cost_matrices_ref`. This is the core correctness signal for the kernel.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.cost_matrix import cost_matrices_pallas
from compile.kernels.ref import BIG, cost_matrices_ref


def make_problem(rng, n, k, n_real=None, k_real=None, weight_scale=10.0):
    """Random padded problem. Returns (b, w, wmask, adj, xt, mu)."""
    n_real = n if n_real is None else n_real
    k_real = k if k_real is None else k_real
    b = np.zeros(n, dtype=np.float32)
    b[:n_real] = rng.integers(1, int(weight_scale), size=n_real).astype(np.float32)
    raw_w = rng.random(k_real).astype(np.float32) + 0.1
    w = np.ones(k, dtype=np.float32)
    w[:k_real] = raw_w / raw_w.sum()
    wmask = np.zeros(k, dtype=np.float32)
    wmask[:k_real] = 1.0
    adj = np.zeros((n, n), dtype=np.float32)
    # sprinkle symmetric edges among real nodes
    m = max(1, 3 * n_real)
    us = rng.integers(0, n_real, size=m)
    vs = rng.integers(0, n_real, size=m)
    cs = rng.integers(1, int(weight_scale), size=m).astype(np.float32)
    for u, v, c in zip(us, vs, cs):
        if u != v:
            adj[u, v] += c
            adj[v, u] += c
    assign = rng.integers(0, k_real, size=n)
    assign[n_real:] = 0  # padded nodes sit on machine 0
    xt = np.zeros((n, k), dtype=np.float32)
    xt[np.arange(n), assign] = 1.0
    mu = np.float32(rng.random() * 16.0)
    return b, w, wmask, adj, xt, mu


def assert_matches_ref(b, w, wmask, adj, xt, mu, block_rows):
    got_a, got_b = cost_matrices_pallas(
        jnp.asarray(b), jnp.asarray(w), jnp.asarray(wmask),
        jnp.asarray(adj), jnp.asarray(xt), jnp.asarray(mu),
        block_rows=block_rows,
    )
    want_a, want_b = cost_matrices_ref(
        jnp.asarray(b), jnp.asarray(w), jnp.asarray(wmask),
        jnp.asarray(adj), jnp.asarray(xt), jnp.asarray(mu),
    )
    np.testing.assert_allclose(np.asarray(got_a), np.asarray(want_a), rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(np.asarray(got_b), np.asarray(want_b), rtol=1e-4, atol=1e-2)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_pow=st.integers(2, 5),           # N = 2^n_pow * 8  in [32, 256]
    k_real=st.integers(1, 8),
    block_pow=st.integers(0, 3),
)
def test_kernel_matches_ref_hypothesis(seed, n_pow, k_real, block_pow):
    n = (2 ** n_pow) * 8
    block_rows = min(n, 8 * (2 ** block_pow))
    if n % block_rows != 0:
        block_rows = n
    rng = np.random.default_rng(seed)
    n_real = int(rng.integers(1, n + 1))
    prob = make_problem(rng, n, 8, n_real=n_real, k_real=k_real)
    assert_matches_ref(*prob, block_rows=block_rows)


def test_kernel_matches_ref_paper_shape():
    """The paper's 230-node / 5-machine study padded to 256 x 8."""
    rng = np.random.default_rng(0)
    prob = make_problem(rng, 256, 8, n_real=230, k_real=5)
    assert_matches_ref(*prob, block_rows=128)


def test_padding_machines_are_never_attractive():
    rng = np.random.default_rng(1)
    b, w, wmask, adj, xt, mu = make_problem(rng, 64, 8, n_real=50, k_real=3)
    got_a, got_b = cost_matrices_pallas(
        jnp.asarray(b), jnp.asarray(w), jnp.asarray(wmask),
        jnp.asarray(adj), jnp.asarray(xt), jnp.asarray(mu),
        block_rows=64,
    )
    a = np.asarray(got_a)
    bb = np.asarray(got_b)
    # All padded-machine columns carry the BIG penalty.
    assert (a[:, 3:] >= BIG * 0.5).all()
    assert (bb[:, 3:] >= BIG * 0.5).all()


def test_padded_nodes_cost_zero_on_their_machine():
    rng = np.random.default_rng(2)
    b, w, wmask, adj, xt, mu = make_problem(rng, 64, 8, n_real=40, k_real=4)
    got_a, _ = cost_matrices_pallas(
        jnp.asarray(b), jnp.asarray(w), jnp.asarray(wmask),
        jnp.asarray(adj), jnp.asarray(xt), jnp.asarray(mu),
        block_rows=32,
    )
    a = np.asarray(got_a)
    # Padded nodes (b=0, no edges) on machine 0: current cost exactly 0.
    np.testing.assert_allclose(a[40:, 0], 0.0, atol=1e-6)


def test_block_size_invariance():
    rng = np.random.default_rng(3)
    prob = make_problem(rng, 128, 8, n_real=100, k_real=5)
    outs = []
    for br in (16, 32, 64, 128):
        got = cost_matrices_pallas(
            jnp.asarray(prob[0]), jnp.asarray(prob[1]), jnp.asarray(prob[2]),
            jnp.asarray(prob[3]), jnp.asarray(prob[4]), jnp.asarray(prob[5]),
            block_rows=br,
        )
        outs.append((np.asarray(got[0]), np.asarray(got[1])))
    for a, b in outs[1:]:
        np.testing.assert_allclose(a, outs[0][0], rtol=1e-6)
        np.testing.assert_allclose(b, outs[0][1], rtol=1e-6)


def test_rejects_non_divisible_block():
    rng = np.random.default_rng(4)
    prob = make_problem(rng, 48, 8)
    with pytest.raises(AssertionError):
        cost_matrices_pallas(
            jnp.asarray(prob[0]), jnp.asarray(prob[1]), jnp.asarray(prob[2]),
            jnp.asarray(prob[3]), jnp.asarray(prob[4]), jnp.asarray(prob[5]),
            block_rows=36,
        )

"""L2 correctness: refine_step (Pallas-backed) against the pure-jnp ref,
plus semantic checks of dissatisfaction/argmin/global costs."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import refine_step_ref
from compile.model import refine_step
from tests.test_kernel import make_problem


def run_both(prob):
    args = tuple(jnp.asarray(x) for x in prob)
    got = refine_step(*args)
    want = refine_step_ref(*args)
    return [np.asarray(g) for g in got], [np.asarray(w) for w in want]


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k_real=st.integers(1, 8))
def test_model_matches_ref(seed, k_real):
    rng = np.random.default_rng(seed)
    prob = make_problem(rng, 128, 8, n_real=int(rng.integers(2, 129)), k_real=k_real)
    got, want = run_both(prob)
    labels = ["costs_a", "costs_b", "dissat_a", "dissat_b", "best_a", "best_b", "c0", "c0t"]
    for g, w, label in zip(got, want, labels):
        if label.startswith("best"):
            # argmin ties may break differently between fused/unfused
            # paths; equal-cost targets are equally valid. Check cost
            # equality at chosen machines instead.
            idx = label[-1]
            costs = got[0] if idx == "a" else got[1]
            n = costs.shape[0]
            np.testing.assert_allclose(
                costs[np.arange(n), g], costs[np.arange(n), w], rtol=1e-4, atol=1e-2,
                err_msg=label,
            )
        else:
            np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-2, err_msg=label)


def test_dissatisfaction_nonnegative_and_zero_at_argmin():
    rng = np.random.default_rng(7)
    prob = make_problem(rng, 128, 8, n_real=100, k_real=5)
    got, _ = run_both(prob)
    costs_a, _, dissat_a, dissat_b, best_a, _, _, _ = got
    assert (dissat_a >= 0).all() and (dissat_b >= 0).all()
    # A node already on its argmin machine has zero dissatisfaction.
    xt = np.asarray(prob[4])
    cur = xt.argmax(axis=1)
    at_best = cur == best_a
    np.testing.assert_allclose(dissat_a[at_best], 0.0, atol=1e-4)


def test_c0_matches_manual_sum():
    rng = np.random.default_rng(8)
    prob = make_problem(rng, 64, 8, n_real=60, k_real=4)
    got, _ = run_both(prob)
    costs_a = got[0]
    xt = np.asarray(prob[4])
    manual = (costs_a * xt).sum()
    np.testing.assert_allclose(got[6], manual, rtol=1e-5)


def test_globals_scale_sanely_with_mu():
    """c0 and c0t are affine in mu with non-negative slope (cut >= 0)."""
    rng = np.random.default_rng(9)
    b, w, wmask, adj, xt, _ = make_problem(rng, 64, 8, n_real=64, k_real=5)
    outs = []
    for mu in (0.0, 4.0, 8.0):
        got = refine_step(
            jnp.asarray(b), jnp.asarray(w), jnp.asarray(wmask),
            jnp.asarray(adj), jnp.asarray(xt), jnp.asarray(np.float32(mu)),
        )
        outs.append((float(got[6]), float(got[7])))
    (c0_0, c0t_0), (c0_4, c0t_4), (c0_8, c0t_8) = outs
    assert c0_4 >= c0_0 - 1e-3 and c0_8 >= c0_4 - 1e-3
    np.testing.assert_allclose(c0_8 - c0_4, c0_4 - c0_0, rtol=1e-3, atol=1e-2)
    np.testing.assert_allclose(c0t_8 - c0t_4, c0t_4 - c0t_0, rtol=1e-3, atol=1e-2)

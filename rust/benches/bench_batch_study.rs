//! Bench + regeneration harness for the §5.1 batch study (50 graphs ×
//! 10 initial partitions in the paper; a reduced sweep here unless
//! GTIP_BENCH_FULL=1).

use gtip::experiments::batch;
use gtip::util::bench::{BenchConfig, Bencher};

fn main() {
    let full = std::env::var("GTIP_BENCH_FULL").ok().as_deref() == Some("1");
    let (realizations, initials) = if full { (50, 10) } else { (10, 3) };

    let report = batch::run(230, realizations, initials, 2011);
    println!("{}", report.to_table().to_text());

    let mut b = Bencher::new("batch_study").with_config(BenchConfig::coarse());
    b.bench("batch_10x3_n230", || batch::run(230, 10, 3, 99).runs);
    let _ = b.write_csv();
}

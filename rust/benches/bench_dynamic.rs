//! Closed-loop rebalancing benchmarks: wall-clock cost of the
//! measure→estimate→refine→migrate epoch machinery, and the headline
//! static-vs-rebalanced tick comparison per scenario.
//!
//! The tick counts printed alongside the timings are the *simulated*
//! wall ticks (the paper's metric); the bench timings are host time.

use gtip::sim::dynamic::{
    compare_frozen_vs_rebalanced, DynamicDriver, DynamicOptions, WeightEstimator,
};
use gtip::sim::engine::SimOptions;
use gtip::sim::scenario::ScenarioKind;
use gtip::util::bench::{black_box, BenchConfig, Bencher};
use gtip::util::rng::Pcg32;
use gtip::util::testkit::ScenarioFixture;

fn main() {
    let mut cfg = BenchConfig::coarse();
    cfg.samples = 3;
    cfg.max_iters = 3;
    let mut b = Bencher::new("dynamic").with_config(cfg);

    let options = DynamicOptions {
        sim: SimOptions { max_ticks: 2_000_000, ..Default::default() },
        epoch_ticks: 200,
        ..Default::default()
    };

    // Headline comparison: frozen vs closed-loop tick counts.
    println!("static-vs-rebalanced simulated wall ticks (seed 2011):");
    for kind in ScenarioKind::ALL {
        let fixture = ScenarioFixture::new(kind, 2011).build();
        let report = compare_frozen_vs_rebalanced(
            &fixture.graph,
            &fixture.machines,
            &fixture.initial,
            &fixture.scenario.injections,
            WeightEstimator::ewma(0.5),
            &options,
        );
        println!(
            "  {:<8} frozen {:>7} | rebalanced {:>7} | speedup {:.2}x",
            kind.name(),
            report.frozen.total_time(),
            report.rebalanced.total_time(),
            report.speedup(),
        );
    }

    // Host-time cost of one full closed loop per scenario.
    for kind in ScenarioKind::ALL {
        let fixture = ScenarioFixture::new(kind, 2011).build();
        b.bench(format!("closed_loop_{}", kind.name()), || {
            let driver = DynamicDriver::new(
                &fixture.graph,
                fixture.machines.clone(),
                fixture.initial.clone(),
                fixture.scenario.injections.clone(),
                WeightEstimator::ewma(0.5),
                options.clone(),
            );
            black_box(driver.run_owned().stats.ticks)
        });
    }

    // Frozen baseline engine cost for reference (same workload).
    {
        let fixture = ScenarioFixture::new(ScenarioKind::HotspotShift, 2011).build();
        let frozen = DynamicOptions { epoch_ticks: 0, ..options.clone() };
        b.bench("frozen_baseline_hotspot", || {
            let driver = DynamicDriver::new(
                &fixture.graph,
                fixture.machines.clone(),
                fixture.initial.clone(),
                fixture.scenario.injections.clone(),
                WeightEstimator::instantaneous(),
                frozen.clone(),
            );
            black_box(driver.run_owned().stats.ticks)
        });
    }

    // Epoch machinery in isolation: a warm-started refine pass on
    // re-measured weights, without the simulation in the loop.
    {
        let fixture = ScenarioFixture::new(ScenarioKind::DiurnalRamp, 7).build();
        let mut rng = Pcg32::new(99);
        let drift = fixture.drift_schedule(8, &mut rng);
        b.bench("reweight_and_refine_epoch", || {
            let mut graph = fixture.graph.clone();
            let mut part = fixture.initial.clone();
            let mut total_transfers = 0usize;
            for weights in &drift {
                graph.set_node_weights(weights);
                part.rebuild_aggregates(&graph);
                let mut engine = gtip::game::refine::RefineEngine::new(
                    &graph,
                    &fixture.machines,
                    part.clone(),
                    8.0,
                    gtip::game::cost::Framework::A,
                );
                let report = engine.run(&gtip::game::refine::RefineOptions::default());
                total_transfers += report.transfers;
                part = engine.into_partition();
            }
            black_box(total_transfers)
        });
    }

    let _ = b.write_csv();
}

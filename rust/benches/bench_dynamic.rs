//! Closed-loop rebalancing benchmarks: wall-clock cost of the
//! measure→estimate→refine→migrate epoch machinery, the headline
//! static-vs-rebalanced tick comparison per scenario, and the promoted
//! worst-case schedules from the committed fuzz corpus
//! (`results/fuzz_corpus/seed-*.json`).
//!
//! The tick counts printed alongside the timings are the *simulated*
//! wall ticks (the paper's metric); the bench timings are host time.

use gtip::sim::dynamic::{
    compare_frozen_vs_rebalanced, DynamicDriver, DynamicOptions, WeightEstimator,
};
use gtip::sim::engine::SimOptions;
use gtip::sim::fuzz::{self, EvalOptions};
use gtip::sim::scenario::ScenarioKind;
use gtip::util::bench::{black_box, write_json_group, BenchConfig, Bencher, JsonVal};
use gtip::util::rng::Pcg32;
use gtip::util::testkit::{committed_fuzz_corpus, ScenarioFixture};

fn main() {
    let smoke = std::env::var("GTIP_BENCH_SMOKE")
        .map_or(false, |v| !v.is_empty() && v != "0");
    let fixture_for = |kind: ScenarioKind| {
        let f = ScenarioFixture::new(kind, 2011);
        if smoke {
            // Shrunken fixtures for the CI smoke job.
            f.nodes(80).threads(60).horizon(800)
        } else {
            f
        }
        .build()
    };
    let mut cfg = BenchConfig::coarse();
    cfg.samples = 3;
    cfg.max_iters = 3;
    let mut b = Bencher::new("dynamic").with_config(cfg);
    let mut scenario_json: Vec<(String, JsonVal)> =
        vec![("smoke".into(), JsonVal::Bool(smoke))];

    let options = DynamicOptions {
        sim: SimOptions { max_ticks: 2_000_000, ..Default::default() },
        epoch_ticks: 200,
        ..Default::default()
    };

    // Headline comparison: frozen vs closed-loop tick counts.
    println!("static-vs-rebalanced simulated wall ticks (seed 2011):");
    for kind in ScenarioKind::ALL {
        let fixture = fixture_for(kind);
        let report = compare_frozen_vs_rebalanced(
            &fixture.graph,
            &fixture.machines,
            &fixture.initial,
            &fixture.scenario.injections,
            WeightEstimator::ewma(0.5),
            &options,
        );
        println!(
            "  {:<8} frozen {:>7} | rebalanced {:>7} | speedup {:.2}x",
            kind.name(),
            report.frozen.total_time(),
            report.rebalanced.total_time(),
            report.speedup(),
        );
        scenario_json.push((
            kind.name().to_string(),
            JsonVal::Obj(vec![
                ("frozen_ticks".into(), JsonVal::Int(report.frozen.total_time())),
                ("rebalanced_ticks".into(), JsonVal::Int(report.rebalanced.total_time())),
                ("tick_speedup".into(), JsonVal::Num(report.speedup())),
            ]),
        ));
    }

    // Host-time cost of one full closed loop per scenario. The +1 on
    // the json index skips the leading "smoke" entry.
    for (kind, json_idx) in ScenarioKind::ALL.into_iter().zip(1usize..) {
        let fixture = fixture_for(kind);
        let r = b.bench(format!("closed_loop_{}", kind.name()), || {
            let driver = DynamicDriver::new(
                &fixture.graph,
                fixture.machines.clone(),
                fixture.initial.clone(),
                fixture.scenario.injections.clone(),
                WeightEstimator::ewma(0.5),
                options.clone(),
            );
            black_box(driver.run_owned().stats.ticks)
        });
        let host = r.per_iter.mean;
        if let JsonVal::Obj(fields) = &mut scenario_json[json_idx].1 {
            fields.push(("closed_loop_host_seconds".into(), JsonVal::Num(host)));
        }
    }

    // Frozen baseline engine cost for reference (same workload).
    {
        let fixture = fixture_for(ScenarioKind::HotspotShift);
        let frozen = DynamicOptions { epoch_ticks: 0, ..options.clone() };
        b.bench("frozen_baseline_hotspot", || {
            let driver = DynamicDriver::new(
                &fixture.graph,
                fixture.machines.clone(),
                fixture.initial.clone(),
                fixture.scenario.injections.clone(),
                WeightEstimator::instantaneous(),
                frozen.clone(),
            );
            black_box(driver.run_owned().stats.ticks)
        });
    }

    // Epoch machinery in isolation: a warm-started refine pass on
    // re-measured weights, without the simulation in the loop.
    {
        let fixture = ScenarioFixture::new(ScenarioKind::DiurnalRamp, 7).build();
        let mut rng = Pcg32::new(99);
        let drift = fixture.drift_schedule(8, &mut rng);
        b.bench("reweight_and_refine_epoch", || {
            let mut graph = fixture.graph.clone();
            let mut part = fixture.initial.clone();
            let mut total_transfers = 0usize;
            for weights in &drift {
                graph.set_node_weights(weights);
                part.rebuild_aggregates(&graph);
                let mut engine = gtip::game::refine::RefineEngine::new(
                    &graph,
                    &fixture.machines,
                    part.clone(),
                    8.0,
                    gtip::game::cost::Framework::A,
                );
                let report = engine.run(&gtip::game::refine::RefineOptions::default());
                total_transfers += report.transfers;
                part = engine.into_partition();
            }
            black_box(total_transfers)
        });
    }

    // Promoted worst cases: replay the committed fuzz corpus and report
    // each schedule's frozen-vs-rebalanced gap next to the hand-written
    // scenarios (the adversarial bench suite).
    let mut fuzz_json: Vec<(String, JsonVal)> = vec![("smoke".into(), JsonVal::Bool(smoke))];
    let corpus = committed_fuzz_corpus();
    if corpus.is_empty() {
        println!("fuzz corpus: empty (run `gtip fuzz` to grow it)");
    } else {
        println!("fuzz-corpus worst-case schedules (committed seed-*.json):");
    }
    // Oracle equality is asserted by the test suites; the bench only
    // measures, so skip the reference run here.
    let eval = EvalOptions { oracle: false, ..Default::default() };
    for case in &corpus {
        let t0 = std::time::Instant::now();
        match fuzz::evaluate(&case.fixture, &case.schedule, &eval) {
            Ok(obj) => {
                let host = t0.elapsed().as_secs_f64();
                println!(
                    "  {:<32} frozen {:>7} | rebalanced {:>7} | gap {:.2}x | rollbacks {:>6}",
                    case.name, obj.frozen_ticks, obj.rebalanced_ticks, obj.gap, obj.rollbacks,
                );
                fuzz_json.push((
                    case.name.clone(),
                    JsonVal::Obj(vec![
                        ("frozen_ticks".into(), JsonVal::Int(obj.frozen_ticks)),
                        ("rebalanced_ticks".into(), JsonVal::Int(obj.rebalanced_ticks)),
                        ("tick_gap".into(), JsonVal::Num(obj.gap)),
                        ("rollbacks".into(), JsonVal::Int(obj.rollbacks)),
                        ("transfers".into(), JsonVal::Int(obj.transfers)),
                        ("host_seconds".into(), JsonVal::Num(host)),
                    ]),
                ));
            }
            Err(e) => eprintln!("  {}: evaluation failed: {e}", case.name),
        }
    }

    let _ = b.write_csv();
    match write_json_group(
        "results/BENCH_sim.json",
        "dynamic_closed_loop",
        &JsonVal::Obj(scenario_json),
    ) {
        Ok(path) => println!("(wrote {})", path.display()),
        Err(e) => eprintln!("(BENCH_sim.json write failed: {e})"),
    }
    match write_json_group("results/BENCH_sim.json", "fuzz_worst", &JsonVal::Obj(fuzz_json)) {
        Ok(path) => println!("(merged fuzz_worst into {})", path.display()),
        Err(e) => eprintln!("(BENCH_sim.json write failed: {e})"),
    }
}

//! Bench + regeneration harness for paper Fig. 7: simulation time vs
//! refinement frequency, preferential-attachment graph.

use gtip::experiments::figs78::{run, SweepOptions};
use gtip::graph::generators::GraphFamily;
use gtip::util::bench::{BenchConfig, Bencher};

fn main() {
    let full = std::env::var("GTIP_BENCH_FULL").ok().as_deref() == Some("1");
    let mut options = SweepOptions::paper_default(GraphFamily::PreferentialAttachment);
    if !full {
        options.seeds = 2;
    }
    let report = run(&options, 2011);
    println!("{}", report.to_table("Fig. 7 — preferential attachment").to_text());
    println!("refinement helps: {}\n", report.refinement_helps());

    let mut b = Bencher::new("fig7").with_config(BenchConfig::coarse());
    let mut quick = SweepOptions::paper_default(GraphFamily::PreferentialAttachment);
    quick.seeds = 1;
    quick.periods = vec![500];
    quick.nodes = 150;
    quick.workload.threads = 80;
    b.bench("fig7_single_point_n150", || run(&quick, 3).points.len());
    let _ = b.write_csv();
}

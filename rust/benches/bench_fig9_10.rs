//! Bench + regeneration harness for paper Figs. 9/10: machine-load
//! traces without refinement vs refinement every 500 ticks.

use gtip::experiments::fig9_10::run_arm;
use gtip::graph::generators::GraphFamily;
use gtip::util::bench::{BenchConfig, Bencher};
use gtip::util::stats::ascii_chart;

fn main() {
    let fig9 = run_arm(GraphFamily::PreferentialAttachment, 230, 5, 0, 2011, false);
    let fig10 = run_arm(GraphFamily::PreferentialAttachment, 230, 5, 500, 2011, false);
    println!(
        "### Fig. 9 — no refinement (sim time {} ticks, load CoV {:.3})",
        fig9.sim_time, fig9.mean_cov
    );
    println!("{}", ascii_chart(&fig9.traces, 56, 10));
    println!(
        "### Fig. 10 — refine every 500 ticks (sim time {} ticks, load CoV {:.3})",
        fig10.sim_time, fig10.mean_cov
    );
    println!("{}", ascii_chart(&fig10.traces, 56, 10));
    println!(
        "balance improvement: CoV {:.3} -> {:.3} (paper: 'load with regular refinements certainly looks more balanced')\n",
        fig9.mean_cov, fig10.mean_cov
    );

    let mut b = Bencher::new("fig9_10").with_config(BenchConfig::coarse());
    b.bench("fig10_arm_n150_traced", || {
        run_arm(GraphFamily::PreferentialAttachment, 150, 5, 500, 3, true).sim_time
    });
    let _ = b.write_csv();
}

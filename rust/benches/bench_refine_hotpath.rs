//! L3 hot-path micro-benchmarks: the refinement engine's inner loop
//! (most-dissatisfied search + transfer + incremental updates) across
//! problem sizes, plus the distributed protocol overhead. This is the
//! primary target of the EXPERIMENTS.md §Perf pass.

use std::sync::Arc;

use gtip::coordinator::{run_distributed, DistributedOptions};
use gtip::game::cost::{dense_cost_matrices, Framework};
use gtip::game::refine::{RefineEngine, RefineOptions};
use gtip::graph::generators::preferential_attachment;
use gtip::graph::Graph;
use gtip::partition::{MachineConfig, Partition};
use gtip::util::bench::{black_box, BenchConfig, Bencher};
use gtip::util::rng::Pcg32;

fn random_partition(g: &Graph, k: usize, seed: u64) -> Partition {
    let mut rng = Pcg32::new(seed);
    Partition::from_assignment(g, k, (0..g.node_count()).map(|_| rng.index(k)).collect())
}

fn main() {
    let mut b = Bencher::new("refine_hotpath");
    let k = 8;
    let machines = MachineConfig::homogeneous(k);

    for &n in &[230usize, 1_000, 10_000, 100_000] {
        let mut rng = Pcg32::new(n as u64);
        let graph = preferential_attachment(n, 2, &mut rng);
        let part = random_partition(&graph, k, 1);

        // Full refinement to convergence: transfers/second.
        let mut transfers_done = 0usize;
        let r = b.bench_elems(format!("refine_to_convergence_n{n}"), n as u64, || {
            let mut engine =
                RefineEngine::new(&graph, &machines, part.clone(), 8.0, Framework::A);
            let report = engine.run(&RefineOptions::default());
            transfers_done = report.transfers;
            report.transfers
        });
        let tps = transfers_done as f64 / r.per_iter.mean;
        println!("    -> {transfers_done} transfers, {tps:.0} transfers/sec");

        // One machine turn (scan + transfer) on a fresh engine.
        let engine = RefineEngine::new(&graph, &machines, part.clone(), 8.0, Framework::A);
        b.bench(format!("single_turn_scan_n{n}"), || {
            black_box(engine.most_dissatisfied(0, 1e-9))
        });

        // Engine construction (adjacency tables) — the per-epoch setup cost.
        b.bench(format!("engine_build_n{n}"), || {
            RefineEngine::new(&graph, &machines, part.clone(), 8.0, Framework::A).potential()
        });

        if n <= 1_000 {
            // Dense rebuild (native mirror of the L1 kernel).
            b.bench(format!("dense_cost_matrices_n{n}"), || {
                dense_cost_matrices(&graph, &machines, &part, 8.0).n
            });
        }
    }

    // Distributed protocol at paper scale.
    {
        let mut rng = Pcg32::new(77);
        let graph = Arc::new(preferential_attachment(1_000, 2, &mut rng));
        let part = random_partition(&graph, k, 2);
        let mut cfg = BenchConfig::coarse();
        cfg.max_iters = 5;
        cfg.samples = 5;
        let mut bd = Bencher::new("refine_hotpath_distributed").with_config(cfg);
        bd.bench("distributed_refine_n1000_k8", || {
            run_distributed(
                Arc::clone(&graph),
                &machines,
                part.clone(),
                &DistributedOptions::default(),
            )
            .transfers
        });
        let _ = bd.write_csv();
    }

    let _ = b.write_csv();
}

//! PJRT runtime micro-benchmarks: compile-once cost and per-call
//! execution latency of the AOT refine_step artifacts across the padded
//! size ladder (§Perf target: < 10 ms round-trip at N=1024).
//!
//! Skips politely if `make artifacts` has not run, and requires building
//! with `--features pjrt` (vendored `xla` crate) at all.

#[cfg(feature = "pjrt")]
fn main() {
    use gtip::experiments::common::StudySetup;
    use gtip::graph::generators::preferential_attachment;
    use gtip::partition::{MachineConfig, Partition};
    use gtip::runtime::cost_eval::PjrtCostEvaluator;
    use gtip::util::bench::{BenchConfig, Bencher};
    use gtip::util::rng::Pcg32;

    let mut eval = match PjrtCostEvaluator::from_default_dir() {
        Ok(e) => e,
        Err(e) => {
            println!("SKIP bench_runtime: {e} (run `make artifacts`)");
            return;
        }
    };

    let mut cfg = BenchConfig::default();
    cfg.samples = 10;
    let mut b = Bencher::new("runtime").with_config(cfg);

    // Paper shape (230 nodes -> n256 artifact).
    {
        let setup = StudySetup::default();
        let mut rng = Pcg32::new(1);
        let graph = setup.graph(&mut rng);
        let part = setup.initial(&graph, &mut rng);
        b.bench("pjrt_refine_step_n230_pad256", || {
            eval.evaluate(&graph, &setup.machines, &part, 8.0).unwrap().c0
        });
    }

    // Ladder sizes.
    for &n in &[500usize, 1_000] {
        let mut rng = Pcg32::new(n as u64);
        let graph = preferential_attachment(n, 2, &mut rng);
        let machines = MachineConfig::homogeneous(5);
        let part =
            Partition::from_assignment(&graph, 5, (0..n).map(|i| i % 5).collect());
        b.bench(format!("pjrt_refine_step_n{n}"), || {
            eval.evaluate(&graph, &machines, &part, 8.0).unwrap().c0
        });
    }

    // Native dense evaluation for comparison.
    {
        let mut rng = Pcg32::new(9);
        let graph = preferential_attachment(1_000, 2, &mut rng);
        let machines = MachineConfig::homogeneous(5);
        let part =
            Partition::from_assignment(&graph, 5, (0..1_000).map(|i| i % 5).collect());
        b.bench("native_dense_cost_matrices_n1000", || {
            gtip::game::cost::dense_cost_matrices(&graph, &machines, &part, 8.0).n
        });
    }
    let _ = b.write_csv();
}

#[cfg(not(feature = "pjrt"))]
fn main() {
    println!("SKIP bench_runtime: built without the `pjrt` feature (vendored xla crate required)");
}

//! PDES engine micro-benchmarks: LP-ticks/second of the optimistic
//! simulator across graph sizes and workloads (§Perf target: >= 1e6
//! LP-ticks/sec).

use gtip::graph::generators::preferential_attachment;
use gtip::partition::{MachineConfig, Partition};
use gtip::sim::engine::{SimEngine, SimOptions};
use gtip::sim::workload::{FloodWorkload, WorkloadOptions};
use gtip::util::bench::{BenchConfig, Bencher};
use gtip::util::rng::Pcg32;

fn main() {
    let mut cfg = BenchConfig::coarse();
    cfg.samples = 3;
    cfg.max_iters = 3;
    let mut b = Bencher::new("simulator").with_config(cfg);

    for &n in &[230usize, 1_000] {
        let mut rng = Pcg32::new(n as u64);
        let graph = preferential_attachment(n, 2, &mut rng);
        let machines = MachineConfig::homogeneous(5);
        let assignment: Vec<usize> = (0..n).map(|i| i % 5).collect();
        let workload = FloodWorkload::generate(
            &graph,
            &WorkloadOptions {
                threads: n / 4,
                horizon_ticks: 2_000,
                ..Default::default()
            },
            &mut rng,
        );

        // Count LP-ticks of one full run for the throughput figure.
        let total_lp_ticks;
        {
            let part = Partition::from_assignment(&graph, 5, assignment.clone());
            let mut engine = SimEngine::new(
                &graph,
                machines.clone(),
                part,
                SimOptions::default(),
                workload.injections.clone(),
            );
            let stats = engine.run_to_completion();
            total_lp_ticks = stats.ticks * n as u64;
        }

        let r = b.bench_elems(format!("sim_run_n{n}"), total_lp_ticks, || {
            let part = Partition::from_assignment(&graph, 5, assignment.clone());
            let mut engine = SimEngine::new(
                &graph,
                machines.clone(),
                part,
                SimOptions::default(),
                workload.injections.clone(),
            );
            engine.run_to_completion().ticks
        });
        println!(
            "    -> {:.3e} LP-ticks/sec",
            total_lp_ticks as f64 / r.per_iter.mean
        );
    }
    let _ = b.write_csv();
}

//! PDES engine benchmarks: LP-ticks/second of the optimistic simulator
//! across graph sizes and workloads (ROADMAP target: >= 1e7 LP-ticks/s
//! on 1e5-LP graphs; pre-worklist engine measured ~1e6).
//!
//! Emits `results/BENCH_sim.json` (merged with `bench_dynamic`'s
//! closed-loop group) so the perf trajectory is machine-readable:
//! optimized vs naive-reference LP-ticks/s, events/s, and the
//! parallelism sweep on the 1e5-LP specialized-geometric headline case.
//!
//! Env knobs: `GTIP_BENCH_SMOKE=1` shrinks the headline graph for CI
//! smoke runs; `GTIP_BENCH_MEASURE_MS` / `GTIP_BENCH_WARMUP_MS` tune
//! the micro-bench harness as usual.

use std::time::Instant;

use gtip::graph::generators::{preferential_attachment, specialized_geometric};
use gtip::graph::Graph;
use gtip::partition::{MachineConfig, Partition};
use gtip::sim::engine::{SimEngine, SimOptions, SimStats};
use gtip::sim::legacy::LegacyEngine;
use gtip::sim::reference::ReferenceEngine;
use gtip::sim::workload::{FloodWorkload, WorkloadOptions};
use gtip::util::bench::{write_json_group, BenchConfig, Bencher, JsonVal};
use gtip::util::rng::Pcg32;

struct HeadlineSetup {
    graph: Graph,
    machines: MachineConfig,
    assignment: Vec<usize>,
    workload: FloodWorkload,
    k: usize,
}

fn headline_setup(n: usize, threads: usize) -> HeadlineSetup {
    let mut rng = Pcg32::new(2011);
    let graph = specialized_geometric(n, 15, 3, &mut rng);
    let k = 8;
    let machines = MachineConfig::homogeneous(k);
    let assignment: Vec<usize> = (0..n).map(|i| i % k).collect();
    let workload = FloodWorkload::generate(
        &graph,
        &WorkloadOptions { threads, horizon_ticks: 2_000, ..Default::default() },
        &mut rng,
    );
    HeadlineSetup { graph, machines, assignment, workload, k }
}

fn sim_options(parallelism: usize, max_ticks: u64) -> SimOptions {
    SimOptions { parallelism, max_ticks, ..Default::default() }
}

/// One timed optimized run; returns (stats, host seconds).
fn run_optimized(setup: &HeadlineSetup, parallelism: usize, max_ticks: u64) -> (SimStats, f64) {
    let part =
        Partition::from_assignment(&setup.graph, setup.k, setup.assignment.clone());
    let mut engine = SimEngine::new(
        &setup.graph,
        setup.machines.clone(),
        part,
        sim_options(parallelism, max_ticks),
        setup.workload.injections.clone(),
    );
    let t0 = Instant::now();
    let stats = engine.run_to_completion();
    (stats, t0.elapsed().as_secs_f64())
}

/// One timed run of the frozen pre-rewrite engine (`sim::legacy`): the
/// map/set-per-LP layout the data-oriented hot path replaced. Same
/// semantics and options as [`SimEngine`], so its stats must match
/// bit-for-bit.
fn run_legacy(setup: &HeadlineSetup, parallelism: usize, max_ticks: u64) -> (SimStats, f64) {
    let part =
        Partition::from_assignment(&setup.graph, setup.k, setup.assignment.clone());
    let mut engine = LegacyEngine::new(
        &setup.graph,
        setup.machines.clone(),
        part,
        sim_options(parallelism, max_ticks),
        setup.workload.injections.clone(),
    );
    let t0 = Instant::now();
    let stats = engine.run_to_completion();
    (stats, t0.elapsed().as_secs_f64())
}

/// One timed naive-reference run (tick-capped: it is the slow baseline
/// the optimization is measured against).
fn run_reference(setup: &HeadlineSetup, max_ticks: u64) -> (SimStats, f64) {
    let part =
        Partition::from_assignment(&setup.graph, setup.k, setup.assignment.clone());
    let mut engine = ReferenceEngine::new(
        &setup.graph,
        setup.machines.clone(),
        part,
        sim_options(1, max_ticks),
        setup.workload.injections.clone(),
    );
    let t0 = Instant::now();
    let stats = engine.run_to_completion();
    (stats, t0.elapsed().as_secs_f64())
}

fn lp_ticks_per_sec(n: usize, stats: &SimStats, secs: f64) -> f64 {
    stats.ticks as f64 * n as f64 / secs.max(1e-9)
}

fn main() {
    let smoke = std::env::var("GTIP_BENCH_SMOKE")
        .map_or(false, |v| !v.is_empty() && v != "0");
    let mut cfg = BenchConfig::coarse();
    cfg.samples = 3;
    cfg.max_iters = 3;
    let mut b = Bencher::new("simulator").with_config(cfg);
    let mut json: Vec<(String, JsonVal)> = Vec::new();

    // Small preferential-attachment cases (host-time trend via the
    // micro harness, as before).
    let mut small_cases: Vec<JsonVal> = Vec::new();
    for &n in &[230usize, 1_000] {
        let mut rng = Pcg32::new(n as u64);
        let graph = preferential_attachment(n, 2, &mut rng);
        let machines = MachineConfig::homogeneous(5);
        let assignment: Vec<usize> = (0..n).map(|i| i % 5).collect();
        let workload = FloodWorkload::generate(
            &graph,
            &WorkloadOptions { threads: n / 4, horizon_ticks: 2_000, ..Default::default() },
            &mut rng,
        );

        // Count LP-ticks of one full run for the throughput figure.
        let total_lp_ticks;
        {
            let part = Partition::from_assignment(&graph, 5, assignment.clone());
            let mut engine = SimEngine::new(
                &graph,
                machines.clone(),
                part,
                SimOptions::default(),
                workload.injections.clone(),
            );
            let stats = engine.run_to_completion();
            total_lp_ticks = stats.ticks * n as u64;
        }

        let r = b.bench_elems(format!("sim_run_n{n}"), total_lp_ticks, || {
            let part = Partition::from_assignment(&graph, 5, assignment.clone());
            let mut engine = SimEngine::new(
                &graph,
                machines.clone(),
                part,
                SimOptions::default(),
                workload.injections.clone(),
            );
            engine.run_to_completion().ticks
        });
        let tps = total_lp_ticks as f64 / r.per_iter.mean;
        println!("    -> {tps:.3e} LP-ticks/sec");
        small_cases.push(JsonVal::Obj(vec![
            ("n".into(), JsonVal::Int(n as u64)),
            ("lp_ticks_per_sec".into(), JsonVal::Num(tps)),
        ]));
    }
    json.push(("small_cases".into(), JsonVal::Arr(small_cases)));

    // Headline: 1e5-LP specialized-geometric graph (ISSUE 2 acceptance
    // case), optimized engine vs the retained naive reference.
    let (n, threads, ref_ticks) =
        if smoke { (20_000, 120, 500) } else { (100_000, 400, 2_000) };
    println!("building specialized-geometric headline graph (n = {n}) ...");
    let setup = headline_setup(n, threads);
    println!(
        "  graph ready: {} nodes, {} edges",
        setup.graph.node_count(),
        setup.graph.edge_count()
    );

    // Matched-window comparison: both engines simulate the SAME first
    // `ref_ticks` wall ticks (bit-identical work), so the speedup is
    // host-time over identical simulated spans — fast-forwarding the
    // idle drain tail cannot inflate it.
    let (ref_stats, ref_secs) = run_reference(&setup, ref_ticks);
    let ref_tps = lp_ticks_per_sec(n, &ref_stats, ref_secs);
    println!(
        "  reference (naive) : {} ticks in {ref_secs:.2}s -> {ref_tps:.3e} LP-ticks/s",
        ref_stats.ticks
    );
    let (opt_win_stats, opt_win_secs) = run_optimized(&setup, 1, ref_ticks);
    assert_eq!(
        opt_win_stats.events_processed, ref_stats.events_processed,
        "optimized and reference diverged inside the matched window"
    );
    let opt_win_tps = lp_ticks_per_sec(n, &opt_win_stats, opt_win_secs);
    let speedup = opt_win_tps / ref_tps.max(1e-12);
    println!(
        "  optimized, same {ref_ticks}-tick window: {opt_win_secs:.3}s -> {opt_win_tps:.3e} \
         LP-ticks/s ({speedup:.1}x the reference; acceptance: >= 10x)"
    );

    let mut parallel_json: Vec<(String, JsonVal)> = Vec::new();
    let mut first_run: Option<(SimStats, f64)> = None;
    for &p in &[1usize, 2, 4] {
        let (stats, secs) = run_optimized(&setup, p, 500_000);
        let tps = lp_ticks_per_sec(n, &stats, secs);
        println!(
            "  optimized (p = {p}) : {} ticks, {} events in {secs:.2}s -> {tps:.3e} LP-ticks/s",
            stats.ticks, stats.events_processed
        );
        parallel_json.push((format!("p{p}"), JsonVal::Num(tps)));
        if let Some((s0, _)) = &first_run {
            assert_eq!(
                s0, &stats,
                "parallelism {p} diverged from sequential — determinism bug"
            );
        } else {
            first_run = Some((stats, secs));
        }
    }
    let (opt_stats, opt_secs) = first_run.expect("ran at least once");
    let opt_tps = lp_ticks_per_sec(n, &opt_stats, opt_secs);

    json.push((
        "headline".into(),
        JsonVal::Obj(vec![
            ("graph".into(), JsonVal::Str("specialized_geometric".into())),
            ("n".into(), JsonVal::Int(n as u64)),
            ("threads".into(), JsonVal::Int(threads as u64)),
            ("smoke".into(), JsonVal::Bool(smoke)),
            ("ticks".into(), JsonVal::Int(opt_stats.ticks)),
            ("events_processed".into(), JsonVal::Int(opt_stats.events_processed)),
            ("truncated".into(), JsonVal::Bool(opt_stats.truncated)),
            ("host_seconds".into(), JsonVal::Num(opt_secs)),
            ("full_run_lp_ticks_per_sec".into(), JsonVal::Num(opt_tps)),
            (
                "events_per_sec".into(),
                JsonVal::Num(opt_stats.events_processed as f64 / opt_secs.max(1e-9)),
            ),
            // Matched-window figures (same simulated span for both
            // engines — the honest acceptance comparison).
            ("window_ticks".into(), JsonVal::Int(ref_ticks)),
            ("reference_lp_ticks_per_sec".into(), JsonVal::Num(ref_tps)),
            ("window_lp_ticks_per_sec".into(), JsonVal::Num(opt_win_tps)),
            ("speedup_vs_reference".into(), JsonVal::Num(speedup)),
            ("parallel_lp_ticks_per_sec".into(), JsonVal::Obj(parallel_json)),
        ]),
    ));

    // Hot-path before/after (ISSUE 7): the frozen pre-rewrite engine
    // (`sim::legacy` — HashMap thread slots, per-event Vec history,
    // sorted-Vec worklist) vs the data-oriented rewrite, on the SAME
    // matched window at parallelism 1/2/4. Stats must agree bit-for-bit
    // — only the layout changed — so the throughput ratio isolates the
    // data-structure work.
    let mut hotpath_json: Vec<(String, JsonVal)> = vec![
        ("n".into(), JsonVal::Int(n as u64)),
        ("window_ticks".into(), JsonVal::Int(ref_ticks)),
        ("smoke".into(), JsonVal::Bool(smoke)),
    ];
    let mut hotpath_parallel: Vec<(String, JsonVal)> = Vec::new();
    let mut headline_before = 0.0f64;
    let mut headline_after = 0.0f64;
    for &p in &[1usize, 2, 4] {
        let (old_stats, old_secs) = run_legacy(&setup, p, ref_ticks);
        let (new_stats, new_secs) = run_optimized(&setup, p, ref_ticks);
        assert_eq!(
            old_stats, new_stats,
            "legacy and rewritten engines diverged at p = {p} — the rewrite changed semantics"
        );
        let before = lp_ticks_per_sec(n, &old_stats, old_secs);
        let after = lp_ticks_per_sec(n, &new_stats, new_secs);
        println!(
            "  hotpath (p = {p}) : legacy {before:.3e} -> rewritten {after:.3e} LP-ticks/s \
             ({:.2}x)",
            after / before.max(1e-12)
        );
        hotpath_parallel.push((
            format!("p{p}"),
            JsonVal::Obj(vec![
                ("before_window_lp_ticks_per_sec".into(), JsonVal::Num(before)),
                ("window_lp_ticks_per_sec".into(), JsonVal::Num(after)),
                ("improvement_ratio".into(), JsonVal::Num(after / before.max(1e-12))),
            ]),
        ));
        if p == 1 {
            headline_before = before;
            headline_after = after;
        }
    }
    hotpath_json.push(("before_window_lp_ticks_per_sec".into(), JsonVal::Num(headline_before)));
    hotpath_json.push(("window_lp_ticks_per_sec".into(), JsonVal::Num(headline_after)));
    hotpath_json.push((
        "improvement_ratio".into(),
        JsonVal::Num(headline_after / headline_before.max(1e-12)),
    ));
    hotpath_json.push(("parallel".into(), JsonVal::Obj(hotpath_parallel)));
    if headline_after <= headline_before {
        println!(
            "  !!! hotpath regression: rewritten engine ({headline_after:.3e}) is not faster \
             than the pre-rewrite layout ({headline_before:.3e}) on this host"
        );
    }
    json.push(("hotpath".into(), JsonVal::Obj(hotpath_json)));

    let _ = b.write_csv();
    match write_json_group("results/BENCH_sim.json", "simulator", &JsonVal::Obj(json)) {
        Ok(path) => println!("(wrote {})", path.display()),
        Err(e) => eprintln!("(BENCH_sim.json write failed: {e})"),
    }
}

//! Bench + regeneration harness for paper Table I (§5.1).
//!
//! Prints the table rows exactly as `gtip experiment table1` does and
//! measures the cost of regenerating one full trial (graph generation +
//! initial partitioning + refinement under both frameworks).

use gtip::experiments::common::{run_tracked, StudySetup};
use gtip::experiments::table1;
use gtip::game::cost::Framework;
use gtip::util::bench::Bencher;
use gtip::util::rng::Pcg32;

fn main() {
    // Regenerate the table (the artifact of record for EXPERIMENTS.md).
    let report = table1::run(&StudySetup::default(), 5, 2011);
    println!("{}", report.to_table().to_text());
    println!(
        "Framework A best on BOTH global costs in {}/5 trials (paper: 5/5)\n",
        report.a_wins_both()
    );

    // Measure.
    let mut b = Bencher::new("table1");
    let setup = StudySetup::default();
    b.bench("one_trial_both_frameworks_n230", || {
        let mut rng = Pcg32::new(7);
        let graph = setup.graph(&mut rng);
        let initial = setup.initial(&graph, &mut rng);
        let a = run_tracked(&graph, &setup.machines, initial.clone(), setup.mu, Framework::A);
        let bb = run_tracked(&graph, &setup.machines, initial, setup.mu, Framework::B);
        (a.iterations, bb.iterations)
    });
    b.bench("refine_only_framework_a_n230", || {
        let mut rng = Pcg32::new(8);
        let graph = setup.graph(&mut rng);
        let initial = setup.initial(&graph, &mut rng);
        run_tracked(&graph, &setup.machines, initial, setup.mu, Framework::A).iterations
    });
    let _ = b.write_csv();
}

//! Experiment / run configuration.
//!
//! A single plain-text `key = value` format (serde is unavailable in the
//! offline vendor set) shared by the CLI, the examples and the experiment
//! harnesses, so every run is reproducible from a recorded config file.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::game::cost::Framework;
use crate::graph::generators::GraphFamily;

/// Full run configuration with paper-default values.
#[derive(Debug, Clone)]
pub struct Config {
    /// Random seed for everything derived from this run.
    pub seed: u64,
    /// Graph family for synthetic workloads.
    pub family: GraphFamily,
    /// Number of LPs (nodes). Paper §5.1 uses 230.
    pub nodes: usize,
    /// Raw machine speeds; normalized internally. Paper §5.1 uses
    /// (0.1, 0.2, 0.3, 0.3, 0.1).
    pub speeds: Vec<f64>,
    /// Relative weight of the inter-machine rollback-delay cost (μ).
    /// Paper §5.1 uses 8.
    pub mu: f64,
    /// Cost framework for refinement.
    pub framework: Framework,
    /// PDES: wall-clock ticks between partition refinements
    /// (`partition-refine-freq`, Table III). 0 = never refine.
    pub refine_every: u64,
    /// PDES: number of packet-flow threads injected.
    pub threads: usize,
    /// PDES: flood hop limit (`event-count` initial value).
    pub hop_limit: u32,
    /// PDES: inter-machine event delay in wall-clock ticks (`event-tick`).
    pub inter_machine_delay: u64,
    /// PDES: per-event base processing time in wall-clock ticks.
    pub base_process_time: u64,
    /// Hot-spot model: number of simultaneous traffic hot spots.
    pub hot_spots: usize,
    /// Hot-spot model: ticks between hot-spot relocations.
    pub hot_spot_period: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            seed: 42,
            family: GraphFamily::PreferentialAttachment,
            nodes: 230,
            speeds: vec![0.1, 0.2, 0.3, 0.3, 0.1],
            mu: 8.0,
            framework: Framework::A,
            refine_every: 500,
            threads: 60,
            hop_limit: 4,
            inter_machine_delay: 3,
            base_process_time: 1,
            hot_spots: 3,
            hot_spot_period: 400,
        }
    }
}

impl Config {
    /// Parse from `key = value` text. Unknown keys are rejected (typo
    /// safety); omitted keys keep defaults.
    pub fn from_str_cfg(text: &str) -> Result<Config> {
        let mut cfg = Config::default();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| Error::Config(format!("line {}: expected key = value", lineno + 1)))?;
            let key = key.trim();
            let value = value.trim();
            let bad = |e: String| Error::Config(format!("line {}: {key}: {e}", lineno + 1));
            match key {
                "seed" => cfg.seed = value.parse().map_err(|e: std::num::ParseIntError| bad(e.to_string()))?,
                "family" => cfg.family = value.parse().map_err(bad)?,
                "nodes" => cfg.nodes = value.parse().map_err(|e: std::num::ParseIntError| bad(e.to_string()))?,
                "speeds" => {
                    cfg.speeds = value
                        .split(',')
                        .map(|s| s.trim().parse::<f64>())
                        .collect::<std::result::Result<Vec<_>, _>>()
                        .map_err(|e| bad(e.to_string()))?;
                }
                "mu" => cfg.mu = value.parse().map_err(|e: std::num::ParseFloatError| bad(e.to_string()))?,
                "framework" => cfg.framework = value.parse().map_err(bad)?,
                "refine_every" => cfg.refine_every = value.parse().map_err(|e: std::num::ParseIntError| bad(e.to_string()))?,
                "threads" => cfg.threads = value.parse().map_err(|e: std::num::ParseIntError| bad(e.to_string()))?,
                "hop_limit" => cfg.hop_limit = value.parse().map_err(|e: std::num::ParseIntError| bad(e.to_string()))?,
                "inter_machine_delay" => cfg.inter_machine_delay = value.parse().map_err(|e: std::num::ParseIntError| bad(e.to_string()))?,
                "base_process_time" => cfg.base_process_time = value.parse().map_err(|e: std::num::ParseIntError| bad(e.to_string()))?,
                "hot_spots" => cfg.hot_spots = value.parse().map_err(|e: std::num::ParseIntError| bad(e.to_string()))?,
                "hot_spot_period" => cfg.hot_spot_period = value.parse().map_err(|e: std::num::ParseIntError| bad(e.to_string()))?,
                other => return Err(Error::Config(format!("line {}: unknown key {other:?}", lineno + 1))),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Config> {
        let text = std::fs::read_to_string(path)?;
        Config::from_str_cfg(&text)
    }

    /// Serialize back to the text format (round-trips through parse).
    pub fn to_text(&self) -> String {
        let speeds =
            self.speeds.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(",");
        let family = match self.family {
            GraphFamily::Table1 => "table1",
            GraphFamily::PreferentialAttachment => "pa",
            GraphFamily::Geometric => "geo",
            GraphFamily::ErdosRenyi => "er",
        };
        format!(
            "seed = {}\nfamily = {}\nnodes = {}\nspeeds = {}\nmu = {}\nframework = {}\nrefine_every = {}\nthreads = {}\nhop_limit = {}\ninter_machine_delay = {}\nbase_process_time = {}\nhot_spots = {}\nhot_spot_period = {}\n",
            self.seed,
            family,
            self.nodes,
            speeds,
            self.mu,
            self.framework,
            self.refine_every,
            self.threads,
            self.hop_limit,
            self.inter_machine_delay,
            self.base_process_time,
            self.hot_spots,
            self.hot_spot_period,
        )
    }

    /// Sanity constraints.
    pub fn validate(&self) -> Result<()> {
        if self.nodes < 2 {
            return Err(Error::Config("nodes must be >= 2".into()));
        }
        if self.speeds.is_empty() || self.speeds.iter().any(|&s| s <= 0.0) {
            return Err(Error::Config("speeds must be positive and non-empty".into()));
        }
        if self.mu < 0.0 {
            return Err(Error::Config("mu must be >= 0".into()));
        }
        Ok(())
    }

    /// The machine pool this config describes.
    pub fn machines(&self) -> crate::partition::MachineConfig {
        crate::partition::MachineConfig::from_speeds(&self.speeds)
    }
}

/// Generic key=value bag for ad-hoc experiment parameters (kept separate
/// from [`Config`] so experiment harnesses can record extra sweep axes).
#[derive(Debug, Clone, Default)]
pub struct ParamBag(pub BTreeMap<String, String>);

impl ParamBag {
    pub fn set(&mut self, k: impl Into<String>, v: impl ToString) {
        self.0.insert(k.into(), v.to_string());
    }
    pub fn to_text(&self) -> String {
        self.0.iter().map(|(k, v)| format!("{k} = {v}\n")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = Config::default();
        assert_eq!(c.nodes, 230);
        assert_eq!(c.mu, 8.0);
        assert_eq!(c.speeds, vec![0.1, 0.2, 0.3, 0.3, 0.1]);
    }

    #[test]
    fn round_trip() {
        let c = Config::default();
        let text = c.to_text();
        let c2 = Config::from_str_cfg(&text).unwrap();
        assert_eq!(c2.nodes, c.nodes);
        assert_eq!(c2.mu, c.mu);
        assert_eq!(c2.framework, c.framework);
        assert_eq!(c2.family, c.family);
        assert_eq!(c2.refine_every, c.refine_every);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(Config::from_str_cfg("bogus = 1\n").is_err());
    }

    #[test]
    fn comments_and_blanks_ok() {
        let c = Config::from_str_cfg("# hi\n\nseed = 7\n").unwrap();
        assert_eq!(c.seed, 7);
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(Config::from_str_cfg("nodes = 1\n").is_err());
        assert!(Config::from_str_cfg("mu = -3\n").is_err());
        assert!(Config::from_str_cfg("speeds = 0,1\n").is_err());
    }

    #[test]
    fn param_bag_text() {
        let mut b = ParamBag::default();
        b.set("freq", 500);
        b.set("arm", "A");
        let t = b.to_text();
        assert!(t.contains("freq = 500"));
        assert!(t.contains("arm = A"));
    }
}

//! Message bus connecting machine actors: one mpsc queue per machine,
//! shared overhead accounting, and optional injected per-message latency
//! to emulate remotely-connected machines (the paper's Ethernet case).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::coordinator::protocol::{Message, OverheadStats};
use crate::partition::MachineId;

/// A machine's endpoint: its inbox plus senders to everyone.
pub struct Endpoint {
    pub id: MachineId,
    inbox: Receiver<Message>,
    peers: Vec<Sender<Message>>,
    stats: Arc<Mutex<OverheadStats>>,
    latency: Duration,
}

impl Endpoint {
    /// Send a message to machine `to` (recorded in the shared stats).
    pub fn send(&self, to: MachineId, msg: Message) {
        if !self.latency.is_zero() {
            std::thread::sleep(self.latency);
        }
        self.stats.lock().expect("stats poisoned").record(&msg);
        // A closed peer (already shut down) is fine to ignore.
        let _ = self.peers[to].send(msg);
    }

    /// Broadcast to every machine except self.
    pub fn broadcast_others(&self, msg: &Message) {
        for to in 0..self.peers.len() {
            if to != self.id {
                self.send(to, msg.clone());
            }
        }
    }

    /// Blocking receive.
    pub fn recv(&self) -> Option<Message> {
        self.inbox.recv().ok()
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<Message> {
        self.inbox.try_recv().ok()
    }

    /// Number of machines on the bus.
    pub fn machine_count(&self) -> usize {
        self.peers.len()
    }
}

/// Build a K-machine bus. Returns one endpoint per machine and the shared
/// overhead statistics handle.
pub fn build_bus(k: usize, latency: Duration) -> (Vec<Endpoint>, Arc<Mutex<OverheadStats>>) {
    assert!(k >= 1);
    let stats = Arc::new(Mutex::new(OverheadStats::default()));
    let mut senders = Vec::with_capacity(k);
    let mut receivers = Vec::with_capacity(k);
    for _ in 0..k {
        let (tx, rx) = channel();
        senders.push(tx);
        receivers.push(rx);
    }
    let endpoints = receivers
        .into_iter()
        .enumerate()
        .map(|(id, inbox)| Endpoint {
            id,
            inbox,
            peers: senders.clone(),
            stats: Arc::clone(&stats),
            latency,
        })
        .collect();
    (endpoints, stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn point_to_point_delivery() {
        let (mut eps, _) = build_bus(3, Duration::ZERO);
        let c = eps.pop().unwrap();
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        a.send(1, Message::Shutdown);
        assert!(matches!(b.recv(), Some(Message::Shutdown)));
        assert!(c.try_recv().is_none());
    }

    #[test]
    fn broadcast_excludes_self() {
        let (mut eps, _) = build_bus(3, Duration::ZERO);
        let c = eps.pop().unwrap();
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        a.broadcast_others(&Message::Shutdown);
        assert!(matches!(b.recv(), Some(Message::Shutdown)));
        assert!(matches!(c.recv(), Some(Message::Shutdown)));
        assert!(a.try_recv().is_none());
    }

    #[test]
    fn stats_shared_across_endpoints() {
        let (eps, stats) = build_bus(2, Duration::ZERO);
        eps[0].send(1, Message::TakeMyTurn { consecutive_forfeits: 0, transfers_so_far: 0 });
        eps[1].send(0, Message::TakeMyTurn { consecutive_forfeits: 1, transfers_so_far: 0 });
        assert_eq!(stats.lock().unwrap().take_my_turn.messages, 2);
    }

    #[test]
    fn fifo_per_sender() {
        let (mut eps, _) = build_bus(2, Duration::ZERO);
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        for i in 0..10 {
            a.send(1, Message::TakeMyTurn { consecutive_forfeits: i, transfers_so_far: 0 });
        }
        for i in 0..10 {
            match b.recv() {
                Some(Message::TakeMyTurn { consecutive_forfeits, .. }) => {
                    assert_eq!(consecutive_forfeits, i)
                }
                other => panic!("unexpected {other:?}"),
            }
        }
    }
}

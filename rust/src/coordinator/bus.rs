//! Message bus connecting machine actors.
//!
//! [`Bus`] is the transport abstraction the refinement protocol runs
//! over: the in-process [`Endpoint`] here (one mpsc queue per machine,
//! shared overhead accounting, optional injected per-message latency to
//! emulate remotely-connected machines) and the real-socket
//! [`crate::coordinator::net::TcpEndpoint`] both implement it, so
//! `machine_loop` is written once and is oblivious to the transport.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::coordinator::protocol::{Message, OverheadStats};
use crate::partition::MachineId;

/// Result of a timeout-aware receive.
#[derive(Debug)]
pub enum RecvOutcome {
    /// A protocol message arrived.
    Msg(Message),
    /// Nothing arrived within the timeout. A healthy ring always has a
    /// message in flight, so this means a peer died or hung — the actor
    /// loop bails out instead of deadlocking.
    TimedOut,
    /// The transport is gone (every sender dropped / socket closed).
    Disconnected,
    /// A send to the named machine failed (dead socket, unencodable
    /// frame). Only the TCP transport produces this — the in-process
    /// bus never does — and it lets the actor loop name the dead peer
    /// immediately instead of waiting out the full receive timeout.
    SendFailed(MachineId),
}

/// Transport seen by one machine actor. Exactly one receive primitive —
/// the timeout-aware [`Bus::recv_timeout`] — so blocking-vs-polling
/// duplication can't creep back into the protocol loop, and a dropped
/// peer can never deadlock the TCP path.
pub trait Bus {
    /// This machine's id.
    fn id(&self) -> MachineId;

    /// Number of machines on the bus.
    fn machine_count(&self) -> usize;

    /// Send a message to machine `to` (recorded in the overhead stats;
    /// `to == self.id()` loops back to the own inbox).
    fn send(&self, to: MachineId, msg: Message);

    /// Receive the next message, waiting at most `timeout`.
    fn recv_timeout(&self, timeout: Duration) -> RecvOutcome;

    /// Broadcast to every machine except self.
    fn broadcast_others(&self, msg: &Message) {
        for to in 0..self.machine_count() {
            if to != self.id() {
                self.send(to, msg.clone());
            }
        }
    }
}

/// Borrowed buses are buses too — lets an adapter like
/// [`crate::coordinator::distributed::RackBus`] wrap a transport by
/// reference while the owner (e.g. a cluster leader that still needs
/// its endpoint afterwards) keeps it.
impl<B: Bus + ?Sized> Bus for &B {
    fn id(&self) -> MachineId {
        (**self).id()
    }

    fn machine_count(&self) -> usize {
        (**self).machine_count()
    }

    fn send(&self, to: MachineId, msg: Message) {
        (**self).send(to, msg)
    }

    fn recv_timeout(&self, timeout: Duration) -> RecvOutcome {
        (**self).recv_timeout(timeout)
    }
}

/// Timeout used by convenience blocking receives; effectively forever,
/// but finite so a wedged test still terminates.
const BLOCKING_RECV_TIMEOUT: Duration = Duration::from_secs(600);

/// A machine's in-process endpoint: its inbox plus senders to everyone.
pub struct Endpoint {
    pub id: MachineId,
    inbox: Receiver<Message>,
    peers: Vec<Sender<Message>>,
    stats: Arc<Mutex<OverheadStats>>,
    latency: Duration,
}

impl Bus for Endpoint {
    fn id(&self) -> MachineId {
        self.id
    }

    fn machine_count(&self) -> usize {
        self.peers.len()
    }

    fn send(&self, to: MachineId, msg: Message) {
        if !self.latency.is_zero() {
            std::thread::sleep(self.latency);
        }
        self.stats.lock().expect("stats poisoned").record(&msg);
        // A closed peer (already shut down) is fine to ignore.
        let _ = self.peers[to].send(msg);
    }

    fn recv_timeout(&self, timeout: Duration) -> RecvOutcome {
        match self.inbox.recv_timeout(timeout) {
            Ok(msg) => RecvOutcome::Msg(msg),
            Err(RecvTimeoutError::Timeout) => RecvOutcome::TimedOut,
            Err(RecvTimeoutError::Disconnected) => RecvOutcome::Disconnected,
        }
    }
}

impl Endpoint {
    /// Blocking receive (thin wrapper over [`Bus::recv_timeout`]).
    pub fn recv(&self) -> Option<Message> {
        match Bus::recv_timeout(self, BLOCKING_RECV_TIMEOUT) {
            RecvOutcome::Msg(m) => Some(m),
            _ => None,
        }
    }

    /// Non-blocking receive (thin wrapper over [`Bus::recv_timeout`]).
    pub fn try_recv(&self) -> Option<Message> {
        match Bus::recv_timeout(self, Duration::ZERO) {
            RecvOutcome::Msg(m) => Some(m),
            _ => None,
        }
    }
}

/// Build a K-machine bus. Returns one endpoint per machine and the shared
/// overhead statistics handle.
pub fn build_bus(k: usize, latency: Duration) -> (Vec<Endpoint>, Arc<Mutex<OverheadStats>>) {
    assert!(k >= 1);
    let stats = Arc::new(Mutex::new(OverheadStats::default()));
    let mut senders = Vec::with_capacity(k);
    let mut receivers = Vec::with_capacity(k);
    for _ in 0..k {
        let (tx, rx) = channel();
        senders.push(tx);
        receivers.push(rx);
    }
    let endpoints = receivers
        .into_iter()
        .enumerate()
        .map(|(id, inbox)| Endpoint {
            id,
            inbox,
            peers: senders.clone(),
            stats: Arc::clone(&stats),
            latency,
        })
        .collect();
    (endpoints, stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shutdown() -> Message {
        Message::Shutdown { total_transfers: 0, converged: true }
    }

    #[test]
    fn point_to_point_delivery() {
        let (mut eps, _) = build_bus(3, Duration::ZERO);
        let c = eps.pop().unwrap();
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        a.send(1, shutdown());
        assert!(matches!(b.recv(), Some(Message::Shutdown { .. })));
        assert!(c.try_recv().is_none());
    }

    #[test]
    fn broadcast_excludes_self() {
        let (mut eps, _) = build_bus(3, Duration::ZERO);
        let c = eps.pop().unwrap();
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        a.broadcast_others(&shutdown());
        assert!(matches!(b.recv(), Some(Message::Shutdown { .. })));
        assert!(matches!(c.recv(), Some(Message::Shutdown { .. })));
        assert!(a.try_recv().is_none());
    }

    #[test]
    fn stats_shared_across_endpoints() {
        let (eps, stats) = build_bus(2, Duration::ZERO);
        eps[0].send(1, Message::TakeMyTurn { consecutive_forfeits: 0, transfers_so_far: 0 });
        eps[1].send(0, Message::TakeMyTurn { consecutive_forfeits: 1, transfers_so_far: 0 });
        assert_eq!(stats.lock().unwrap().take_my_turn.messages, 2);
    }

    #[test]
    fn fifo_per_sender() {
        let (mut eps, _) = build_bus(2, Duration::ZERO);
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        for i in 0..10 {
            a.send(1, Message::TakeMyTurn { consecutive_forfeits: i, transfers_so_far: 0 });
        }
        for i in 0..10 {
            match b.recv() {
                Some(Message::TakeMyTurn { consecutive_forfeits, .. }) => {
                    assert_eq!(consecutive_forfeits, i)
                }
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn recv_timeout_reports_timeout_on_silence() {
        // A dead/silent peer shows up as TimedOut, the signal the actor
        // loop turns into a bounded exit instead of a deadlock. (Full
        // Disconnected needs every sender gone, which the in-process
        // bus only sees at teardown.)
        let (eps, _) = build_bus(2, Duration::ZERO);
        let started = std::time::Instant::now();
        assert!(matches!(eps[1].recv_timeout(Duration::from_millis(10)), RecvOutcome::TimedOut));
        assert!(started.elapsed() < Duration::from_secs(5));
    }
}

//! The distributed refinement driver: spawns one thread per machine,
//! runs the Fig. 2 trigger protocol to convergence, and assembles the
//! refined partition (plus measured synchronization overhead).
//!
//! Protocol per machine thread (Fig. 2 verbatim, with a convergence
//! counter riding on the token):
//!
//! ```text
//! repeat
//!   wait for trigger
//!   if ReceiveNodeTrigger   -> adopt node, update local costs
//!   if RegularUpdateTrigger -> apply transfer, update local costs
//!   if TakeMyTurnTrigger    ->
//!        transfer most dissatisfied node (or forfeit);
//!        send ReceiveNodeTrigger to destination;
//!        send RegularUpdateTrigger to all others;
//!        send TakeMyTurnTrigger to the next machine
//! until convergence (token records K consecutive forfeits)
//! ```

use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::bus::{build_bus, Endpoint};
use crate::coordinator::machine::{MachineActor, TurnDecision};
use crate::coordinator::protocol::{Message, OverheadStats};
use crate::game::cost::Framework;
use crate::graph::Graph;
use crate::partition::{MachineConfig, MachineId, Partition};

/// Options for a distributed run.
#[derive(Debug, Clone)]
pub struct DistributedOptions {
    pub mu: f64,
    pub framework: Framework,
    /// Dissatisfaction threshold treated as zero.
    pub epsilon: f64,
    /// Injected per-message latency (0 = local cluster).
    pub latency: Duration,
    /// Safety cap on total transfers.
    pub max_transfers: usize,
}

impl Default for DistributedOptions {
    fn default() -> Self {
        DistributedOptions {
            mu: 8.0,
            framework: Framework::A,
            epsilon: 1e-9,
            latency: Duration::ZERO,
            max_transfers: 1_000_000,
        }
    }
}

/// Result of a distributed refinement.
#[derive(Debug, Clone)]
pub struct DistributedReport {
    /// The refined (equilibrium) partition.
    pub partition: Partition,
    /// Total transfers executed across machines.
    pub transfers: usize,
    /// Measured message/byte counts per type.
    pub overhead: OverheadStats,
    /// True if the ring detected convergence (vs hitting the cap).
    pub converged: bool,
}

/// One machine's thread body. Returns its final local assignment replica
/// and transfer count for the leader to assemble + cross-check.
fn machine_loop(
    mut actor: MachineActor,
    endpoint: Endpoint,
    epsilon: f64,
    max_transfers: usize,
) -> (Vec<MachineId>, usize, bool) {
    let k = endpoint.machine_count();
    let mut converged = false;
    while let Some(msg) = endpoint.recv() {
        match msg {
            Message::ReceiveNode { node, from, to } => {
                actor.apply_local_transfer(node, from, to);
            }
            Message::RegularUpdate { node, from, to, loads } => {
                actor.apply_local_transfer(node, from, to);
                debug_assert!(actor.loads_agree(&loads), "aggregate-state divergence");
                let _ = loads;
            }
            Message::TakeMyTurn { consecutive_forfeits, transfers_so_far } => {
                let decision = if transfers_so_far >= max_transfers {
                    TurnDecision::Forfeit // cap reached: drain to shutdown
                } else {
                    actor.take_turn(epsilon)
                };
                let next = (actor.id + 1) % k;
                match decision {
                    TurnDecision::Transfer { node, to, .. } => {
                        let total_transfers = transfers_so_far + 1;
                        endpoint.send(to, Message::ReceiveNode { node, from: actor.id, to });
                        let update = Message::RegularUpdate {
                            node,
                            from: actor.id,
                            to,
                            loads: actor.loads().to_vec(),
                        };
                        for m in 0..k {
                            if m != actor.id && m != to {
                                endpoint.send(m, update.clone());
                            }
                        }
                        if total_transfers >= max_transfers {
                            // Cap reached: shut the ring down.
                            endpoint.broadcast_others(&Message::Shutdown);
                            break;
                        }
                        endpoint.send(
                            next,
                            Message::TakeMyTurn {
                                consecutive_forfeits: 0,
                                transfers_so_far: total_transfers,
                            },
                        );
                    }
                    TurnDecision::Forfeit => {
                        let f = consecutive_forfeits + 1;
                        if f >= k {
                            converged = true;
                            endpoint.broadcast_others(&Message::Shutdown);
                            break;
                        }
                        endpoint.send(
                            next,
                            Message::TakeMyTurn { consecutive_forfeits: f, transfers_so_far },
                        );
                    }
                }
            }
            Message::Shutdown => {
                converged = true;
                break;
            }
        }
    }
    (actor.assignment().to_vec(), actor.transfers_made, converged)
}

/// Run the distributed refinement protocol to convergence.
pub fn run_distributed(
    graph: Arc<Graph>,
    machines: &MachineConfig,
    initial: Partition,
    options: &DistributedOptions,
) -> DistributedReport {
    let k = machines.count();
    let (endpoints, stats) = build_bus(k, options.latency);

    // Kick the ring: machine 0 takes the first turn.
    endpoints[0]
        .peers_send_self(Message::TakeMyTurn { consecutive_forfeits: 0, transfers_so_far: 0 });

    let mut handles = Vec::with_capacity(k);
    for endpoint in endpoints {
        let actor = MachineActor::new(
            endpoint.id,
            Arc::clone(&graph),
            machines.clone(),
            &initial,
            options.mu,
            options.framework,
        );
        let epsilon = options.epsilon;
        let max_transfers = options.max_transfers;
        handles.push(std::thread::spawn(move || {
            machine_loop(actor, endpoint, epsilon, max_transfers)
        }));
    }

    let mut assignments: Vec<(Vec<MachineId>, usize, bool)> = Vec::with_capacity(k);
    for h in handles {
        assignments.push(h.join().expect("machine thread panicked"));
    }

    // All replicas must agree; assemble the final partition from any.
    let reference = assignments[0].0.clone();
    for (a, _, _) in &assignments {
        assert_eq!(a, &reference, "machine replicas diverged");
    }
    let transfers: usize = assignments.iter().map(|(_, t, _)| *t).sum();
    let converged = assignments.iter().any(|(_, _, c)| *c);
    let partition = Partition::from_assignment(&graph, k, reference);
    let overhead = stats.lock().expect("stats").clone();
    DistributedReport { partition, transfers, overhead, converged }
}

impl Endpoint {
    /// Send a message to *this* endpoint's own inbox (used by the leader
    /// to inject the initial token before handing the endpoint to its
    /// thread).
    pub fn peers_send_self(&self, msg: Message) {
        self.send(self.id, msg);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::game::cost::CostModel;
    use crate::game::refine::{RefineEngine, RefineOptions};
    use crate::graph::generators::{table1_graph, WeightModel};
    use crate::util::rng::Pcg32;

    fn setup(seed: u64, n: usize) -> (Arc<Graph>, MachineConfig, Partition) {
        let mut rng = Pcg32::new(seed);
        let g = Arc::new(table1_graph(n, 3, 6, WeightModel::default(), &mut rng));
        let machines = MachineConfig::from_speeds(&[0.1, 0.2, 0.3, 0.3, 0.1]);
        let assignment: Vec<usize> = (0..n).map(|_| rng.index(5)).collect();
        let part = Partition::from_assignment(&g, 5, assignment);
        (g, machines, part)
    }

    #[test]
    fn distributed_reaches_nash_equilibrium() {
        let (g, machines, part) = setup(1, 60);
        let report =
            run_distributed(Arc::clone(&g), &machines, part, &DistributedOptions::default());
        assert!(report.converged);
        report.partition.validate(&g).unwrap();
        let model = CostModel::new(&g, machines, 8.0, Framework::A);
        for i in 0..g.node_count() {
            let (j, _) = model.dissatisfaction(&report.partition, i);
            assert!(j <= 1e-6, "node {i} dissatisfied: {j}");
        }
    }

    #[test]
    fn distributed_matches_sequential_exactly() {
        // Same start, same deterministic token order => identical result.
        let (g, machines, part) = setup(2, 50);
        let mut seq = RefineEngine::new(&g, &machines, part.clone(), 8.0, Framework::A);
        let seq_report = seq.run(&RefineOptions::default());
        let dist =
            run_distributed(Arc::clone(&g), &machines, part, &DistributedOptions::default());
        assert_eq!(dist.transfers, seq_report.transfers);
        assert_eq!(dist.partition.assignment(), seq.partition().assignment());
    }

    #[test]
    fn transfer_cap_halts_ring() {
        let (g, machines, part) = setup(3, 60);
        let opts = DistributedOptions { max_transfers: 2, ..Default::default() };
        let report = run_distributed(Arc::clone(&g), &machines, part, &opts);
        assert!(report.transfers <= 2 + 1, "cap grossly exceeded: {}", report.transfers);
    }

    #[test]
    fn overhead_counts_messages() {
        let (g, machines, part) = setup(4, 60);
        let report =
            run_distributed(Arc::clone(&g), &machines, part, &DistributedOptions::default());
        let o = &report.overhead;
        assert!(o.take_my_turn.messages as usize >= report.transfers);
        // Each transfer => 1 receive_node + (K-2) regular updates.
        assert_eq!(o.receive_node.messages as usize, report.transfers);
        assert_eq!(o.regular_update.messages as usize, report.transfers * 3);
    }

    #[test]
    fn framework_b_also_converges_distributed() {
        let (g, machines, part) = setup(5, 60);
        let opts = DistributedOptions { framework: Framework::B, ..Default::default() };
        let report = run_distributed(Arc::clone(&g), &machines, part, &opts);
        assert!(report.converged);
        let model = CostModel::new(&g, machines, 8.0, Framework::B);
        for i in 0..g.node_count() {
            let (j, _) = model.dissatisfaction(&report.partition, i);
            assert!(j <= 1e-6);
        }
    }
}

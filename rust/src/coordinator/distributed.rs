//! The distributed refinement driver: spawns one actor per machine,
//! runs the Fig. 2 trigger protocol to convergence, and assembles the
//! refined partition (plus measured synchronization overhead).
//!
//! Protocol per machine actor (Fig. 2 verbatim, with a convergence
//! counter riding on the token):
//!
//! ```text
//! repeat
//!   wait for trigger
//!   if ReceiveNodeTrigger   -> adopt node, update local costs
//!   if RegularUpdateTrigger -> apply transfer, update local costs
//!   if TakeMyTurnTrigger    ->
//!        transfer most dissatisfied node (or forfeit);
//!        send ReceiveNodeTrigger to destination;
//!        send RegularUpdateTrigger to all others;
//!        send TakeMyTurnTrigger to the next machine
//! until convergence (token records K consecutive forfeits)
//! ```
//!
//! [`machine_loop`] is generic over [`Bus`], so the same loop runs on
//! the in-process mpsc ring ([`build_bus`]) and on real TCP sockets
//! ([`crate::coordinator::net`]). Two transport realities it absorbs:
//!
//! * **Reordering** — TCP gives FIFO per connection but nothing across
//!   connections, so transfers apply strictly in their global sequence
//!   order (buffered in a tiny map until in order), the turn token is
//!   deferred until the replica has caught up to the token's transfer
//!   count, and `Shutdown` only takes effect once the announced total
//!   has been applied. On the in-process bus all of this is a no-op.
//! * **Peer loss** — every receive goes through the single
//!   timeout-aware [`Bus::recv_timeout`]; a dead peer turns into a
//!   bounded [`LoopOutcome::timed_out`] exit instead of a deadlock.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::coordinator::bus::{build_bus, Bus, RecvOutcome};
use crate::coordinator::machine::{MachineActor, TurnDecision};
use crate::coordinator::protocol::{Message, OverheadStats};
use crate::game::cost::Framework;
use crate::graph::{Graph, NodeId};
use crate::partition::{MachineConfig, MachineId, Partition};

/// Options for a distributed run.
#[derive(Debug, Clone)]
pub struct DistributedOptions {
    pub mu: f64,
    pub framework: Framework,
    /// Per-move migration surcharge of the augmented game (DESIGN.md
    /// §9); rides `Setup` on the TCP transport so every worker prices
    /// moves identically to the in-process path.
    pub migration_charge: f64,
    /// Dissatisfaction threshold treated as zero.
    pub epsilon: f64,
    /// Injected per-message latency (0 = local cluster; ignored by the
    /// TCP transport, which has real latency).
    pub latency: Duration,
    /// Safety cap on total transfers.
    pub max_transfers: usize,
    /// How long an actor waits for the next trigger before concluding a
    /// peer died. A healthy ring always has a message in flight, so
    /// this only fires on failure.
    pub recv_timeout: Duration,
}

impl Default for DistributedOptions {
    fn default() -> Self {
        DistributedOptions {
            mu: 8.0,
            framework: Framework::A,
            migration_charge: 0.0,
            epsilon: 1e-9,
            latency: Duration::ZERO,
            max_transfers: 1_000_000,
            recv_timeout: Duration::from_secs(30),
        }
    }
}

/// Result of a distributed refinement.
#[derive(Debug, Clone)]
pub struct DistributedReport {
    /// The refined (equilibrium) partition.
    pub partition: Partition,
    /// Total transfers executed across machines.
    pub transfers: usize,
    /// Measured message/byte counts per type (exact wire bytes).
    pub overhead: OverheadStats,
    /// True if the ring detected convergence (vs hitting the cap).
    pub converged: bool,
    /// True if any actor gave up waiting on a dead peer.
    pub timed_out: bool,
}

/// What one actor's [`machine_loop`] ended with.
#[derive(Debug, Clone)]
pub struct LoopOutcome {
    /// The actor's final local assignment replica.
    pub assignment: Vec<MachineId>,
    /// Transfers this actor executed itself.
    pub transfers_made: usize,
    /// Transfers this actor applied to its replica — the global total
    /// at a clean exit (every transfer reaches every replica).
    pub transfers_applied: u64,
    /// Saw convergence (received `Shutdown`, or detected K forfeits).
    pub converged: bool,
    /// Gave up waiting on a peer.
    pub timed_out: bool,
    /// The peer a failed send named, if the exit came from
    /// [`RecvOutcome::SendFailed`] rather than plain silence. Feeds the
    /// leader's death diagnosis (TCP transport only).
    pub dead_peer: Option<MachineId>,
}

/// A transfer waiting to be applied in global sequence order.
type PendingTransfer = (NodeId, MachineId, MachineId, Option<Vec<f64>>);

/// One machine's actor loop over any [`Bus`]. Public because the TCP
/// leader (`coordinator::net`) and the multi-process `gtip serve`
/// worker drive it directly with a single endpoint, and failure tests
/// run it against partially-dead rings.
///
/// Each invocation runs one refinement round at a *fixed* fleet size
/// `k`: elastic membership (eviction to K−1 on a death, admission
/// back to K+1 on a join, DESIGN.md §10) happens strictly *between*
/// rounds at epoch boundaries, so the loop never observes the fleet
/// changing mid-round.
pub fn machine_loop<B: Bus>(
    mut actor: MachineActor,
    bus: &B,
    epsilon: f64,
    max_transfers: usize,
    recv_timeout: Duration,
) -> LoopOutcome {
    let k = bus.machine_count();
    let mut converged = false;
    let mut timed_out = false;
    let mut dead_peer = None;
    // Next global transfer sequence number to apply locally.
    let mut next_seq: u64 = 0;
    // Transfers that arrived ahead of order (cross-connection races on
    // real sockets; always empty on the in-process bus).
    let mut pending: BTreeMap<u64, PendingTransfer> = BTreeMap::new();
    // A turn token held back until the replica catches up with it.
    let mut token: Option<(usize, usize)> = None;
    // Shutdown announcement: stop once `next_seq` reaches the total
    // (the flag records whether the ring converged or hit the cap).
    let mut shutdown_at: Option<(u64, bool)> = None;

    loop {
        // Apply every transfer that is now in order.
        while let Some((node, from, to, loads)) = pending.remove(&next_seq) {
            actor.apply_local_transfer(node, from, to);
            if let Some(loads) = loads {
                debug_assert!(actor.loads_agree(&loads), "aggregate-state divergence");
                let _ = loads;
            }
            next_seq += 1;
        }
        // Honor a shutdown once the replica has the announced total.
        if let Some((total, was_convergence)) = shutdown_at {
            if next_seq >= total {
                converged = was_convergence;
                break;
            }
        }
        // Take a held turn once every earlier transfer is applied.
        if let Some((consecutive_forfeits, transfers_so_far)) = token {
            if next_seq >= transfers_so_far as u64 {
                token = None;
                let decision = if transfers_so_far >= max_transfers {
                    TurnDecision::Forfeit // cap reached: drain to shutdown
                } else {
                    actor.take_turn(epsilon)
                };
                let next = (actor.id + 1) % k;
                match decision {
                    TurnDecision::Transfer { node, to, .. } => {
                        let seq = transfers_so_far as u64;
                        next_seq = seq + 1; // executed locally by take_turn
                        let total_transfers = transfers_so_far + 1;
                        bus.send(to, Message::ReceiveNode { seq, node, from: actor.id, to });
                        let update = Message::RegularUpdate {
                            seq,
                            node,
                            from: actor.id,
                            to,
                            loads: actor.loads().to_vec(),
                        };
                        for m in 0..k {
                            if m != actor.id && m != to {
                                bus.send(m, update.clone());
                            }
                        }
                        if total_transfers >= max_transfers {
                            // Cap reached (not convergence): shut down.
                            bus.broadcast_others(&Message::Shutdown {
                                total_transfers: total_transfers as u64,
                                converged: false,
                            });
                            break;
                        }
                        bus.send(
                            next,
                            Message::TakeMyTurn {
                                consecutive_forfeits: 0,
                                transfers_so_far: total_transfers,
                            },
                        );
                    }
                    TurnDecision::Forfeit => {
                        let f = consecutive_forfeits + 1;
                        if f >= k {
                            converged = true;
                            bus.broadcast_others(&Message::Shutdown {
                                total_transfers: transfers_so_far as u64,
                                converged: true,
                            });
                            break;
                        }
                        bus.send(
                            next,
                            Message::TakeMyTurn { consecutive_forfeits: f, transfers_so_far },
                        );
                    }
                }
                continue;
            }
        }
        match bus.recv_timeout(recv_timeout) {
            RecvOutcome::Msg(Message::ReceiveNode { seq, node, from, to }) => {
                pending.insert(seq, (node, from, to, None));
            }
            RecvOutcome::Msg(Message::RegularUpdate { seq, node, from, to, loads }) => {
                pending.insert(seq, (node, from, to, Some(loads)));
            }
            RecvOutcome::Msg(Message::TakeMyTurn { consecutive_forfeits, transfers_so_far }) => {
                token = Some((consecutive_forfeits, transfers_so_far));
            }
            RecvOutcome::Msg(Message::Shutdown { total_transfers, converged }) => {
                shutdown_at = Some((total_transfers, converged));
            }
            RecvOutcome::TimedOut => {
                timed_out = true;
                break;
            }
            RecvOutcome::SendFailed(m) => {
                // A peer's socket is gone: the ring can never close, so
                // exit through the same bounded path as a timeout —
                // but carrying the dead peer's name for the diagnosis.
                timed_out = true;
                dead_peer = Some(m);
                break;
            }
            RecvOutcome::Disconnected => break,
        }
    }
    LoopOutcome {
        assignment: actor.assignment().to_vec(),
        transfers_made: actor.transfers_made,
        transfers_applied: next_seq,
        converged,
        timed_out,
        dead_peer,
    }
}

/// Run the full K-actor protocol over a prebuilt set of endpoints (one
/// per machine, any transport) and assemble the report. `stats` is the
/// accounting handle shared by (or aggregating over) the endpoints.
pub fn run_over_endpoints<B>(
    endpoints: Vec<B>,
    graph: Arc<Graph>,
    machines: &MachineConfig,
    initial: Partition,
    options: &DistributedOptions,
    stats: Arc<Mutex<OverheadStats>>,
) -> DistributedReport
where
    B: Bus + Send + 'static,
{
    let k = machines.count();
    assert_eq!(endpoints.len(), k, "need one endpoint per machine");

    // Kick the ring: machine 0 takes the first turn.
    endpoints[0].send(0, Message::TakeMyTurn { consecutive_forfeits: 0, transfers_so_far: 0 });

    let mut handles = Vec::with_capacity(k);
    for endpoint in endpoints {
        let actor = MachineActor::new(
            endpoint.id(),
            Arc::clone(&graph),
            machines.clone(),
            &initial,
            options.mu,
            options.framework,
            options.migration_charge,
        );
        let epsilon = options.epsilon;
        let max_transfers = options.max_transfers;
        let recv_timeout = options.recv_timeout;
        handles.push(std::thread::spawn(move || {
            machine_loop(actor, &endpoint, epsilon, max_transfers, recv_timeout)
        }));
    }

    let mut outcomes: Vec<LoopOutcome> = Vec::with_capacity(k);
    for h in handles {
        outcomes.push(h.join().expect("machine thread panicked"));
    }

    let timed_out = outcomes.iter().any(|o| o.timed_out);
    if !timed_out {
        // All replicas must agree on a clean exit.
        let reference = &outcomes[0].assignment;
        for o in &outcomes {
            assert_eq!(&o.assignment, reference, "machine replicas diverged");
            debug_assert_eq!(
                o.transfers_applied, outcomes[0].transfers_applied,
                "replicas applied different transfer totals"
            );
        }
    }
    let transfers: usize = outcomes.iter().map(|o| o.transfers_made).sum();
    let converged = !timed_out && outcomes.iter().any(|o| o.converged);
    let partition = Partition::from_assignment(&graph, k, outcomes[0].assignment.clone());
    let overhead = stats.lock().expect("stats").clone();
    DistributedReport { partition, transfers, overhead, converged, timed_out }
}

/// Run the distributed refinement protocol to convergence on the
/// in-process thread ring.
pub fn run_distributed(
    graph: Arc<Graph>,
    machines: &MachineConfig,
    initial: Partition,
    options: &DistributedOptions,
) -> DistributedReport {
    let k = machines.count();
    let (endpoints, stats) = build_bus(k, options.latency);
    run_over_endpoints(endpoints, graph, machines, initial, options, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::game::cost::CostModel;
    use crate::game::refine::{RefineEngine, RefineOptions};
    use crate::graph::generators::{table1_graph, WeightModel};
    use crate::util::rng::Pcg32;

    fn setup(seed: u64, n: usize) -> (Arc<Graph>, MachineConfig, Partition) {
        let mut rng = Pcg32::new(seed);
        let g = Arc::new(table1_graph(n, 3, 6, WeightModel::default(), &mut rng));
        let machines = MachineConfig::from_speeds(&[0.1, 0.2, 0.3, 0.3, 0.1]);
        let assignment: Vec<usize> = (0..n).map(|_| rng.index(5)).collect();
        let part = Partition::from_assignment(&g, 5, assignment);
        (g, machines, part)
    }

    #[test]
    fn distributed_reaches_nash_equilibrium() {
        let (g, machines, part) = setup(1, 60);
        let report =
            run_distributed(Arc::clone(&g), &machines, part, &DistributedOptions::default());
        assert!(report.converged);
        assert!(!report.timed_out);
        report.partition.validate(&g).unwrap();
        let model = CostModel::new(&g, machines, 8.0, Framework::A);
        for i in 0..g.node_count() {
            let (j, _) = model.dissatisfaction(&report.partition, i);
            assert!(j <= 1e-6, "node {i} dissatisfied: {j}");
        }
    }

    #[test]
    fn distributed_matches_sequential_exactly() {
        // Same start, same deterministic token order => identical result.
        let (g, machines, part) = setup(2, 50);
        let mut seq = RefineEngine::new(&g, &machines, part.clone(), 8.0, Framework::A);
        let seq_report = seq.run(&RefineOptions::default());
        let dist =
            run_distributed(Arc::clone(&g), &machines, part, &DistributedOptions::default());
        assert_eq!(dist.transfers, seq_report.transfers);
        assert_eq!(dist.partition.assignment(), seq.partition().assignment());
    }

    /// The augmented (migration-charged) game is transport-invariant:
    /// the distributed ring with a nonzero charge reproduces the
    /// charged sequential engine exactly (same transfers, same final
    /// assignment), and converges to an augmented Nash equilibrium.
    #[test]
    fn charged_distributed_matches_charged_sequential() {
        let (g, machines, part) = setup(7, 60);
        let charge = 5.0;
        let mut seq = RefineEngine::new(&g, &machines, part.clone(), 8.0, Framework::A)
            .with_migration_charge(charge);
        let seq_report = seq.run(&RefineOptions::default());
        let opts = DistributedOptions { migration_charge: charge, ..Default::default() };
        let dist = run_distributed(Arc::clone(&g), &machines, part, &opts);
        assert!(dist.converged);
        assert_eq!(dist.transfers, seq_report.transfers);
        assert_eq!(dist.partition.assignment(), seq.partition().assignment());
        // Augmented Nash: no node's raw gain beats the charge.
        let model = CostModel::new(&g, machines, 8.0, Framework::A).with_migration_charge(charge);
        for i in 0..g.node_count() {
            let (j, _) = model.dissatisfaction(&dist.partition, i);
            assert!(j <= 1e-6, "node {i} still augmented-dissatisfied: {j}");
        }
    }

    #[test]
    fn transfer_cap_halts_ring() {
        let (g, machines, part) = setup(3, 60);
        let opts = DistributedOptions { max_transfers: 2, ..Default::default() };
        let report = run_distributed(Arc::clone(&g), &machines, part, &opts);
        assert!(report.transfers <= 2 + 1, "cap grossly exceeded: {}", report.transfers);
    }

    #[test]
    fn overhead_counts_messages() {
        let (g, machines, part) = setup(4, 60);
        let report =
            run_distributed(Arc::clone(&g), &machines, part, &DistributedOptions::default());
        let o = &report.overhead;
        assert!(o.take_my_turn.messages as usize >= report.transfers);
        // Each transfer => 1 receive_node + (K-2) regular updates.
        assert_eq!(o.receive_node.messages as usize, report.transfers);
        assert_eq!(o.regular_update.messages as usize, report.transfers * 3);
    }

    #[test]
    fn framework_b_also_converges_distributed() {
        let (g, machines, part) = setup(5, 60);
        let opts = DistributedOptions { framework: Framework::B, ..Default::default() };
        let report = run_distributed(Arc::clone(&g), &machines, part, &opts);
        assert!(report.converged);
        let model = CostModel::new(&g, machines, 8.0, Framework::B);
        for i in 0..g.node_count() {
            let (j, _) = model.dissatisfaction(&report.partition, i);
            assert!(j <= 1e-6);
        }
    }

    /// Dead peer: the ring forwards the token toward a machine whose
    /// endpoint was dropped. Every surviving actor must exit through
    /// the recv timeout within bounded time — no deadlock. (The full
    /// regression lives in `integration_coordinator.rs` via
    /// `testkit::assert_ring_unwinds_on_dead_peer`, on both
    /// transports.)
    #[test]
    fn dropped_peer_times_out_instead_of_deadlocking() {
        let (g, machines, part) = setup(6, 60);
        let k = machines.count();
        let (mut endpoints, _stats) = build_bus(k, Duration::ZERO);
        drop(endpoints.pop().unwrap()); // machine K-1 dies before the round
        crate::util::testkit::assert_ring_unwinds_on_dead_peer(
            endpoints,
            &g,
            &machines,
            &part,
            Duration::from_millis(150),
        );
    }
}

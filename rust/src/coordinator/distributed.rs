//! The distributed refinement driver: spawns one actor per machine,
//! runs the Fig. 2 trigger protocol to convergence, and assembles the
//! refined partition (plus measured synchronization overhead).
//!
//! Protocol per machine actor (Fig. 2 verbatim, with a convergence
//! counter riding on the token):
//!
//! ```text
//! repeat
//!   wait for trigger
//!   if ReceiveNodeTrigger   -> adopt node, update local costs
//!   if RegularUpdateTrigger -> apply transfer, update local costs
//!   if TakeMyTurnTrigger    ->
//!        transfer most dissatisfied node (or forfeit);
//!        send ReceiveNodeTrigger to destination;
//!        send RegularUpdateTrigger to all others;
//!        send TakeMyTurnTrigger to the next machine
//! until convergence (token records K consecutive forfeits)
//! ```
//!
//! [`machine_loop`] is generic over [`Bus`], so the same loop runs on
//! the in-process mpsc ring ([`build_bus`]) and on real TCP sockets
//! ([`crate::coordinator::net`]). Two transport realities it absorbs:
//!
//! * **Reordering** — TCP gives FIFO per connection but nothing across
//!   connections, so transfers apply strictly in their global sequence
//!   order (buffered in a tiny map until in order), the turn token is
//!   deferred until the replica has caught up to the token's transfer
//!   count, and `Shutdown` only takes effect once the announced total
//!   has been applied. On the in-process bus all of this is a no-op.
//! * **Peer loss** — every receive goes through the single
//!   timeout-aware [`Bus::recv_timeout`]; a dead peer turns into a
//!   bounded [`LoopOutcome::timed_out`] exit instead of a deadlock.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::coordinator::bus::{build_bus, Bus, RecvOutcome};
use crate::coordinator::machine::{MachineActor, TurnDecision};
use crate::coordinator::protocol::{Message, OverheadStats};
use crate::game::cost::Framework;
use crate::game::hierarchy::{guarded_map_back, RackLayout};
use crate::graph::{Graph, NodeId};
use crate::partition::{MachineConfig, MachineId, Partition};

/// Options for a distributed run.
#[derive(Debug, Clone)]
pub struct DistributedOptions {
    pub mu: f64,
    pub framework: Framework,
    /// Per-move migration surcharge of the augmented game (DESIGN.md
    /// §9); rides `Setup` on the TCP transport so every worker prices
    /// moves identically to the in-process path.
    pub migration_charge: f64,
    /// Dissatisfaction threshold treated as zero.
    pub epsilon: f64,
    /// Injected per-message latency (0 = local cluster; ignored by the
    /// TCP transport, which has real latency).
    pub latency: Duration,
    /// Safety cap on total transfers.
    pub max_transfers: usize,
    /// How long an actor waits for the next trigger before concluding a
    /// peer died. A healthy ring always has a message in flight, so
    /// this only fires on failure.
    pub recv_timeout: Duration,
}

impl Default for DistributedOptions {
    fn default() -> Self {
        DistributedOptions {
            mu: 8.0,
            framework: Framework::A,
            migration_charge: 0.0,
            epsilon: 1e-9,
            latency: Duration::ZERO,
            max_transfers: 1_000_000,
            recv_timeout: Duration::from_secs(30),
        }
    }
}

/// Result of a distributed refinement.
#[derive(Debug, Clone)]
pub struct DistributedReport {
    /// The refined (equilibrium) partition.
    pub partition: Partition,
    /// Total transfers executed across machines.
    pub transfers: usize,
    /// Measured message/byte counts per type (exact wire bytes).
    pub overhead: OverheadStats,
    /// True if the ring detected convergence (vs hitting the cap).
    pub converged: bool,
    /// True if any actor gave up waiting on a dead peer.
    pub timed_out: bool,
}

/// What one actor's [`machine_loop`] ended with.
#[derive(Debug, Clone)]
pub struct LoopOutcome {
    /// The actor's final local assignment replica.
    pub assignment: Vec<MachineId>,
    /// Transfers this actor executed itself.
    pub transfers_made: usize,
    /// Transfers this actor applied to its replica — the global total
    /// at a clean exit (every transfer reaches every replica).
    pub transfers_applied: u64,
    /// Saw convergence (received `Shutdown`, or detected K forfeits).
    pub converged: bool,
    /// Gave up waiting on a peer.
    pub timed_out: bool,
    /// The peer a failed send named, if the exit came from
    /// [`RecvOutcome::SendFailed`] rather than plain silence. Feeds the
    /// leader's death diagnosis (TCP transport only).
    pub dead_peer: Option<MachineId>,
}

/// A transfer waiting to be applied in global sequence order.
type PendingTransfer = (NodeId, MachineId, MachineId, Option<Vec<f64>>);

/// One machine's actor loop over any [`Bus`]. Public because the TCP
/// leader (`coordinator::net`) and the multi-process `gtip serve`
/// worker drive it directly with a single endpoint, and failure tests
/// run it against partially-dead rings.
///
/// Each invocation runs one refinement round at a *fixed* fleet size
/// `k`: elastic membership (eviction to K−1 on a death, admission
/// back to K+1 on a join, DESIGN.md §10) happens strictly *between*
/// rounds at epoch boundaries, so the loop never observes the fleet
/// changing mid-round.
pub fn machine_loop<B: Bus>(
    actor: MachineActor,
    bus: &B,
    epsilon: f64,
    max_transfers: usize,
    recv_timeout: Duration,
) -> LoopOutcome {
    let scope: Vec<MachineId> = (0..bus.machine_count()).collect();
    machine_loop_scoped(actor, bus, &scope, epsilon, max_transfers, recv_timeout)
}

/// [`machine_loop`] restricted to a rack ring (DESIGN.md §12): the turn
/// token circulates over `scope` only, convergence is `scope.len()`
/// consecutive forfeits, and transfers / updates / shutdowns go to
/// scope members only — machines outside the scope never hear from
/// this ring, which is what makes rack subgames exactly independent.
/// The caller kicks the ring by pre-enqueueing the first `TakeMyTurn`
/// into one member's inbox (a self-send works on both transports).
/// `scope` must be ascending, contain the actor's id, and be identical
/// across the ring's members; the flat loop is the `scope == 0..K`
/// special case.
pub fn machine_loop_scoped<B: Bus>(
    mut actor: MachineActor,
    bus: &B,
    scope: &[MachineId],
    epsilon: f64,
    max_transfers: usize,
    recv_timeout: Duration,
) -> LoopOutcome {
    let k = scope.len();
    let pos = scope.iter().position(|&m| m == actor.id).expect("actor must be in its scope");
    let next = scope[(pos + 1) % k];
    let mut converged = false;
    let mut timed_out = false;
    let mut dead_peer = None;
    // Next global transfer sequence number to apply locally.
    let mut next_seq: u64 = 0;
    // Transfers that arrived ahead of order (cross-connection races on
    // real sockets; always empty on the in-process bus).
    let mut pending: BTreeMap<u64, PendingTransfer> = BTreeMap::new();
    // A turn token held back until the replica catches up with it.
    let mut token: Option<(usize, usize)> = None;
    // Shutdown announcement: stop once `next_seq` reaches the total
    // (the flag records whether the ring converged or hit the cap).
    let mut shutdown_at: Option<(u64, bool)> = None;

    loop {
        // Apply every transfer that is now in order.
        while let Some((node, from, to, loads)) = pending.remove(&next_seq) {
            actor.apply_local_transfer(node, from, to);
            if let Some(loads) = loads {
                debug_assert!(actor.loads_agree(&loads), "aggregate-state divergence");
                let _ = loads;
            }
            next_seq += 1;
        }
        // Honor a shutdown once the replica has the announced total.
        if let Some((total, was_convergence)) = shutdown_at {
            if next_seq >= total {
                converged = was_convergence;
                break;
            }
        }
        // Take a held turn once every earlier transfer is applied.
        if let Some((consecutive_forfeits, transfers_so_far)) = token {
            if next_seq >= transfers_so_far as u64 {
                token = None;
                let decision = if transfers_so_far >= max_transfers {
                    TurnDecision::Forfeit // cap reached: drain to shutdown
                } else {
                    actor.take_turn(epsilon)
                };
                match decision {
                    TurnDecision::Transfer { node, to, .. } => {
                        let seq = transfers_so_far as u64;
                        next_seq = seq + 1; // executed locally by take_turn
                        let total_transfers = transfers_so_far + 1;
                        bus.send(to, Message::ReceiveNode { seq, node, from: actor.id, to });
                        let update = Message::RegularUpdate {
                            seq,
                            node,
                            from: actor.id,
                            to,
                            loads: actor.loads().to_vec(),
                        };
                        for &m in scope {
                            if m != actor.id && m != to {
                                bus.send(m, update.clone());
                            }
                        }
                        if total_transfers >= max_transfers {
                            // Cap reached (not convergence): shut down.
                            let stop = Message::Shutdown {
                                total_transfers: total_transfers as u64,
                                converged: false,
                            };
                            for &m in scope {
                                if m != actor.id {
                                    bus.send(m, stop.clone());
                                }
                            }
                            break;
                        }
                        bus.send(
                            next,
                            Message::TakeMyTurn {
                                consecutive_forfeits: 0,
                                transfers_so_far: total_transfers,
                            },
                        );
                    }
                    TurnDecision::Forfeit => {
                        let f = consecutive_forfeits + 1;
                        if f >= k {
                            converged = true;
                            let stop = Message::Shutdown {
                                total_transfers: transfers_so_far as u64,
                                converged: true,
                            };
                            for &m in scope {
                                if m != actor.id {
                                    bus.send(m, stop.clone());
                                }
                            }
                            break;
                        }
                        bus.send(
                            next,
                            Message::TakeMyTurn { consecutive_forfeits: f, transfers_so_far },
                        );
                    }
                }
                continue;
            }
        }
        match bus.recv_timeout(recv_timeout) {
            RecvOutcome::Msg(Message::ReceiveNode { seq, node, from, to }) => {
                pending.insert(seq, (node, from, to, None));
            }
            RecvOutcome::Msg(Message::RegularUpdate { seq, node, from, to, loads }) => {
                pending.insert(seq, (node, from, to, Some(loads)));
            }
            RecvOutcome::Msg(Message::RackUpdate { seq, node, from, to, rack_loads }) => {
                // Normally demoted to `RegularUpdate` by [`RackBus`]
                // before it reaches the loop; accept the raw frame too
                // so a leader driving its endpoint directly still works.
                pending.insert(seq, (node, from, to, Some(rack_loads)));
            }
            RecvOutcome::Msg(Message::TakeMyTurn { consecutive_forfeits, transfers_so_far }) => {
                token = Some((consecutive_forfeits, transfers_so_far));
            }
            RecvOutcome::Msg(Message::Shutdown { total_transfers, converged }) => {
                shutdown_at = Some((total_transfers, converged));
            }
            RecvOutcome::TimedOut => {
                timed_out = true;
                break;
            }
            RecvOutcome::SendFailed(m) => {
                // A peer's socket is gone: the ring can never close, so
                // exit through the same bounded path as a timeout —
                // but carrying the dead peer's name for the diagnosis.
                timed_out = true;
                dead_peer = Some(m);
                break;
            }
            RecvOutcome::Disconnected => break,
        }
    }
    LoopOutcome {
        assignment: actor.assignment().to_vec(),
        transfers_made: actor.transfers_made,
        transfers_applied: next_seq,
        converged,
        timed_out,
        dead_peer,
    }
}

/// Adapter that lets rack leaders play the outer (rack-level) game over
/// any transport: machine ids on this bus are *rack* ids. `send`
/// promotes the outer game's `RegularUpdate` aggregates to
/// [`Message::RackUpdate`] (R rack loads — the O(K_rack) cross-rack
/// frame, counted apart in [`OverheadStats`]) and routes every message
/// to the destination rack's leader on the inner bus; `recv_timeout`
/// demotes incoming `RackUpdate`s back, so [`machine_loop`] stays
/// oblivious to both the transport and the level it is playing at.
pub struct RackBus<B: Bus> {
    inner: B,
    rack: usize,
    leaders: Vec<MachineId>,
}

impl<B: Bus> RackBus<B> {
    /// `rack` is this endpoint's own rack id; `leaders[r]` is rack
    /// `r`'s leader on the inner bus (the identity map in-process,
    /// [`RackLayout::leaders`] over TCP).
    pub fn new(inner: B, rack: usize, leaders: Vec<MachineId>) -> Self {
        assert!(rack < leaders.len(), "rack id out of range");
        RackBus { inner, rack, leaders }
    }
}

impl<B: Bus> Bus for RackBus<B> {
    fn id(&self) -> MachineId {
        self.rack
    }

    fn machine_count(&self) -> usize {
        self.leaders.len()
    }

    fn send(&self, to: MachineId, msg: Message) {
        let msg = match msg {
            Message::RegularUpdate { seq, node, from, to, loads } => {
                Message::RackUpdate { seq, node, from, to, rack_loads: loads }
            }
            other => other,
        };
        self.inner.send(self.leaders[to], msg);
    }

    fn recv_timeout(&self, timeout: Duration) -> RecvOutcome {
        match self.inner.recv_timeout(timeout) {
            RecvOutcome::Msg(Message::RackUpdate { seq, node, from, to, rack_loads }) => {
                RecvOutcome::Msg(Message::RegularUpdate { seq, node, from, to, loads: rack_loads })
            }
            RecvOutcome::SendFailed(m) => {
                // Name the dead peer by rack where possible.
                RecvOutcome::SendFailed(self.leaders.iter().position(|&l| l == m).unwrap_or(m))
            }
            other => other,
        }
    }
}

/// Run the full K-actor protocol over a prebuilt set of endpoints (one
/// per machine, any transport) and assemble the report. `stats` is the
/// accounting handle shared by (or aggregating over) the endpoints.
pub fn run_over_endpoints<B>(
    endpoints: Vec<B>,
    graph: Arc<Graph>,
    machines: &MachineConfig,
    initial: Partition,
    options: &DistributedOptions,
    stats: Arc<Mutex<OverheadStats>>,
) -> DistributedReport
where
    B: Bus + Send + 'static,
{
    let k = machines.count();
    assert_eq!(endpoints.len(), k, "need one endpoint per machine");

    // Kick the ring: machine 0 takes the first turn.
    endpoints[0].send(0, Message::TakeMyTurn { consecutive_forfeits: 0, transfers_so_far: 0 });

    let mut handles = Vec::with_capacity(k);
    for endpoint in endpoints {
        let actor = MachineActor::new(
            endpoint.id(),
            Arc::clone(&graph),
            machines.clone(),
            &initial,
            options.mu,
            options.framework,
            options.migration_charge,
        );
        let epsilon = options.epsilon;
        let max_transfers = options.max_transfers;
        let recv_timeout = options.recv_timeout;
        handles.push(std::thread::spawn(move || {
            machine_loop(actor, &endpoint, epsilon, max_transfers, recv_timeout)
        }));
    }

    let mut outcomes: Vec<LoopOutcome> = Vec::with_capacity(k);
    for h in handles {
        outcomes.push(h.join().expect("machine thread panicked"));
    }

    let timed_out = outcomes.iter().any(|o| o.timed_out);
    if !timed_out {
        // All replicas must agree on a clean exit.
        let reference = &outcomes[0].assignment;
        for o in &outcomes {
            assert_eq!(&o.assignment, reference, "machine replicas diverged");
            debug_assert_eq!(
                o.transfers_applied, outcomes[0].transfers_applied,
                "replicas applied different transfer totals"
            );
        }
    }
    let transfers: usize = outcomes.iter().map(|o| o.transfers_made).sum();
    let converged = !timed_out && outcomes.iter().any(|o| o.converged);
    let partition = Partition::from_assignment(&graph, k, outcomes[0].assignment.clone());
    let overhead = stats.lock().expect("stats").clone();
    DistributedReport { partition, transfers, overhead, converged, timed_out }
}

/// Run the distributed refinement protocol to convergence on the
/// in-process thread ring.
pub fn run_distributed(
    graph: Arc<Graph>,
    machines: &MachineConfig,
    initial: Partition,
    options: &DistributedOptions,
) -> DistributedReport {
    let k = machines.count();
    let (endpoints, stats) = build_bus(k, options.latency);
    run_over_endpoints(endpoints, graph, machines, initial, options, stats)
}

/// Run the two-level refinement (DESIGN.md §12) over prebuilt endpoint
/// sets: an outer rack-quotient round where one actor per rack
/// exchanges `RackUpdate` aggregates over a [`RackBus`], the shared
/// [`guarded_map_back`], then one concurrent scoped ring per rack.
/// `outer_endpoints` must carry ids `0..R` (each standing for one
/// rack), `inner_endpoints` ids `0..K`; both the in-process ring
/// ([`run_distributed_hierarchical`]) and the loopback-TCP parity
/// harness (`coordinator::net`) route through this one orchestrator.
/// Mirrors [`crate::game::hierarchy::refine_hierarchical`] decision for
/// decision — a parity test asserts bit-identical assignments — and on
/// a singleton layout reproduces [`run_distributed`] exactly.
#[allow(clippy::too_many_arguments)]
pub fn run_hierarchical_over_endpoints<BO, BI>(
    outer_endpoints: Vec<BO>,
    outer_stats: Arc<Mutex<OverheadStats>>,
    inner_endpoints: Vec<BI>,
    inner_stats: Arc<Mutex<OverheadStats>>,
    graph: Arc<Graph>,
    machines: &MachineConfig,
    initial: Partition,
    layout: &RackLayout,
    options: &DistributedOptions,
) -> DistributedReport
where
    BO: Bus + Send + 'static,
    BI: Bus + Send + 'static,
{
    let k = machines.count();
    assert_eq!(layout.machine_count(), k, "rack layout must cover the fleet");
    let racks = layout.rack_count();
    assert_eq!(outer_endpoints.len(), racks, "need one outer endpoint per rack");
    assert_eq!(inner_endpoints.len(), k, "need one inner endpoint per machine");

    // Phase 1: the outer game — one actor per rack on the quotient.
    let qconfig = layout.quotient_config(machines);
    let qassign = layout.quotient_assignment(initial.assignment());
    let qpart = Partition::from_assignment(&graph, racks, qassign);
    outer_endpoints[0]
        .send(0, Message::TakeMyTurn { consecutive_forfeits: 0, transfers_so_far: 0 });
    let mut handles = Vec::with_capacity(racks);
    for endpoint in outer_endpoints {
        let actor = MachineActor::new(
            endpoint.id(),
            Arc::clone(&graph),
            qconfig.clone(),
            &qpart,
            options.mu,
            options.framework,
            options.migration_charge,
        );
        let epsilon = options.epsilon;
        let max_transfers = options.max_transfers;
        let recv_timeout = options.recv_timeout;
        handles.push(std::thread::spawn(move || {
            // These standalone meshes number racks directly, so every
            // rack leads itself: the identity leader map.
            let rack = endpoint.id();
            let bus = RackBus::new(endpoint, rack, (0..racks).collect());
            machine_loop(actor, &bus, epsilon, max_transfers, recv_timeout)
        }));
    }
    let mut outer_outcomes: Vec<LoopOutcome> = Vec::with_capacity(racks);
    for h in handles {
        outer_outcomes.push(h.join().expect("outer machine thread panicked"));
    }
    let outer_timed_out = outer_outcomes.iter().any(|o| o.timed_out);
    if !outer_timed_out {
        let reference = &outer_outcomes[0].assignment;
        for o in &outer_outcomes {
            assert_eq!(&o.assignment, reference, "outer replicas diverged");
        }
    }
    let outer_converged = !outer_timed_out && outer_outcomes.iter().any(|o| o.converged);

    // Guarded map-back to machines (the one guard all deployments share).
    let mapped = guarded_map_back(
        &graph,
        machines,
        layout,
        initial.assignment(),
        &outer_outcomes[0].assignment,
        options.mu,
        options.framework,
    );
    let outer_transfers: usize = if mapped.accepted {
        outer_outcomes.iter().map(|o| o.transfers_made).sum()
    } else {
        0
    };
    let start = Partition::from_assignment(&graph, k, mapped.assignment);

    // Phase 2: one concurrent scoped ring per rack. Each ring's leader
    // kicks itself; cross-rack messages never flow, so within a rack
    // every replica sees an identical full-K state.
    for r in 0..racks {
        let leader = layout.leader(r);
        inner_endpoints[leader]
            .send(leader, Message::TakeMyTurn { consecutive_forfeits: 0, transfers_so_far: 0 });
    }
    let mut handles = Vec::with_capacity(k);
    for endpoint in inner_endpoints {
        let scope = layout.members(layout.rack_of(endpoint.id())).to_vec();
        let actor = MachineActor::new(
            endpoint.id(),
            Arc::clone(&graph),
            machines.clone(),
            &start,
            options.mu,
            options.framework,
            options.migration_charge,
        )
        .with_scope(scope.clone());
        let epsilon = options.epsilon;
        let max_transfers = options.max_transfers;
        let recv_timeout = options.recv_timeout;
        handles.push(std::thread::spawn(move || {
            machine_loop_scoped(actor, &endpoint, &scope, epsilon, max_transfers, recv_timeout)
        }));
    }
    let mut inner_outcomes: Vec<LoopOutcome> = Vec::with_capacity(k);
    for h in handles {
        inner_outcomes.push(h.join().expect("inner machine thread panicked"));
    }
    let inner_timed_out = inner_outcomes.iter().any(|o| o.timed_out);
    if !inner_timed_out {
        for r in 0..racks {
            let reference = &inner_outcomes[layout.leader(r)].assignment;
            for &m in layout.members(r) {
                assert_eq!(&inner_outcomes[m].assignment, reference, "rack {r} replicas diverged");
            }
        }
    }
    // Merge: each node's final machine comes from its rack's own ring.
    let assignment: Vec<MachineId> = (0..graph.node_count())
        .map(|i| {
            let r = layout.rack_of(start.machine_of(i));
            inner_outcomes[layout.leader(r)].assignment[i]
        })
        .collect();

    let transfers =
        outer_transfers + inner_outcomes.iter().map(|o| o.transfers_made).sum::<usize>();
    let converged =
        outer_converged && !inner_timed_out && inner_outcomes.iter().all(|o| o.converged);
    let mut overhead = outer_stats.lock().expect("stats").clone();
    overhead.add(&inner_stats.lock().expect("stats"));
    DistributedReport {
        partition: Partition::from_assignment(&graph, k, assignment),
        transfers,
        overhead,
        converged,
        timed_out: outer_timed_out || inner_timed_out,
    }
}

/// Run the two-level refinement on the in-process thread ring: fresh
/// mpsc meshes for both levels, fed through
/// [`run_hierarchical_over_endpoints`].
pub fn run_distributed_hierarchical(
    graph: Arc<Graph>,
    machines: &MachineConfig,
    initial: Partition,
    layout: &RackLayout,
    options: &DistributedOptions,
) -> DistributedReport {
    let (outer_endpoints, outer_stats) = build_bus(layout.rack_count(), options.latency);
    let (inner_endpoints, inner_stats) = build_bus(machines.count(), options.latency);
    run_hierarchical_over_endpoints(
        outer_endpoints,
        outer_stats,
        inner_endpoints,
        inner_stats,
        graph,
        machines,
        initial,
        layout,
        options,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::game::cost::CostModel;
    use crate::game::refine::{RefineEngine, RefineOptions};
    use crate::graph::generators::{table1_graph, WeightModel};
    use crate::util::rng::Pcg32;

    fn setup(seed: u64, n: usize) -> (Arc<Graph>, MachineConfig, Partition) {
        let mut rng = Pcg32::new(seed);
        let g = Arc::new(table1_graph(n, 3, 6, WeightModel::default(), &mut rng));
        let machines = MachineConfig::from_speeds(&[0.1, 0.2, 0.3, 0.3, 0.1]);
        let assignment: Vec<usize> = (0..n).map(|_| rng.index(5)).collect();
        let part = Partition::from_assignment(&g, 5, assignment);
        (g, machines, part)
    }

    #[test]
    fn distributed_reaches_nash_equilibrium() {
        let (g, machines, part) = setup(1, 60);
        let report =
            run_distributed(Arc::clone(&g), &machines, part, &DistributedOptions::default());
        assert!(report.converged);
        assert!(!report.timed_out);
        report.partition.validate(&g).unwrap();
        let model = CostModel::new(&g, machines, 8.0, Framework::A);
        for i in 0..g.node_count() {
            let (j, _) = model.dissatisfaction(&report.partition, i);
            assert!(j <= 1e-6, "node {i} dissatisfied: {j}");
        }
    }

    #[test]
    fn distributed_matches_sequential_exactly() {
        // Same start, same deterministic token order => identical result.
        let (g, machines, part) = setup(2, 50);
        let mut seq = RefineEngine::new(&g, &machines, part.clone(), 8.0, Framework::A);
        let seq_report = seq.run(&RefineOptions::default());
        let dist =
            run_distributed(Arc::clone(&g), &machines, part, &DistributedOptions::default());
        assert_eq!(dist.transfers, seq_report.transfers);
        assert_eq!(dist.partition.assignment(), seq.partition().assignment());
    }

    /// The augmented (migration-charged) game is transport-invariant:
    /// the distributed ring with a nonzero charge reproduces the
    /// charged sequential engine exactly (same transfers, same final
    /// assignment), and converges to an augmented Nash equilibrium.
    #[test]
    fn charged_distributed_matches_charged_sequential() {
        let (g, machines, part) = setup(7, 60);
        let charge = 5.0;
        let mut seq = RefineEngine::new(&g, &machines, part.clone(), 8.0, Framework::A)
            .with_migration_charge(charge);
        let seq_report = seq.run(&RefineOptions::default());
        let opts = DistributedOptions { migration_charge: charge, ..Default::default() };
        let dist = run_distributed(Arc::clone(&g), &machines, part, &opts);
        assert!(dist.converged);
        assert_eq!(dist.transfers, seq_report.transfers);
        assert_eq!(dist.partition.assignment(), seq.partition().assignment());
        // Augmented Nash: no node's raw gain beats the charge.
        let model = CostModel::new(&g, machines, 8.0, Framework::A).with_migration_charge(charge);
        for i in 0..g.node_count() {
            let (j, _) = model.dissatisfaction(&dist.partition, i);
            assert!(j <= 1e-6, "node {i} still augmented-dissatisfied: {j}");
        }
    }

    #[test]
    fn transfer_cap_halts_ring() {
        let (g, machines, part) = setup(3, 60);
        let opts = DistributedOptions { max_transfers: 2, ..Default::default() };
        let report = run_distributed(Arc::clone(&g), &machines, part, &opts);
        assert!(report.transfers <= 2 + 1, "cap grossly exceeded: {}", report.transfers);
    }

    #[test]
    fn overhead_counts_messages() {
        let (g, machines, part) = setup(4, 60);
        let report =
            run_distributed(Arc::clone(&g), &machines, part, &DistributedOptions::default());
        let o = &report.overhead;
        assert!(o.take_my_turn.messages as usize >= report.transfers);
        // Each transfer => 1 receive_node + (K-2) regular updates.
        assert_eq!(o.receive_node.messages as usize, report.transfers);
        assert_eq!(o.regular_update.messages as usize, report.transfers * 3);
    }

    #[test]
    fn framework_b_also_converges_distributed() {
        let (g, machines, part) = setup(5, 60);
        let opts = DistributedOptions { framework: Framework::B, ..Default::default() };
        let report = run_distributed(Arc::clone(&g), &machines, part, &opts);
        assert!(report.converged);
        let model = CostModel::new(&g, machines, 8.0, Framework::B);
        for i in 0..g.node_count() {
            let (j, _) = model.dissatisfaction(&report.partition, i);
            assert!(j <= 1e-6);
        }
    }

    /// The in-process hierarchical orchestrator mirrors the sequential
    /// two-level pass decision for decision: same outer token ring,
    /// same guard, same scoped inner rings — so assignments and
    /// transfer counts must match exactly, charged or not, in both
    /// frameworks.
    #[test]
    fn hierarchical_distributed_matches_sequential_hierarchy_exactly() {
        use crate::game::hierarchy::refine_hierarchical;
        for &(fw, charge) in &[(Framework::A, 0.0), (Framework::A, 5.0), (Framework::B, 0.0)] {
            let (g, machines, part) = setup(8, 60);
            let layout = RackLayout::new(vec![0, 0, 0, 1, 1]).unwrap();
            let (seq_part, seq_report) = refine_hierarchical(
                &g,
                &machines,
                part.clone(),
                8.0,
                fw,
                charge,
                &layout,
                &RefineOptions::default(),
            );
            let opts = DistributedOptions {
                framework: fw,
                migration_charge: charge,
                ..Default::default()
            };
            let dist = run_distributed_hierarchical(Arc::clone(&g), &machines, part, &layout, &opts);
            assert!(!dist.timed_out);
            assert_eq!(dist.transfers, seq_report.transfers, "{fw:?}/{charge}");
            assert_eq!(dist.partition.assignment(), seq_part.assignment(), "{fw:?}/{charge}");
            assert_eq!(dist.converged, seq_report.converged, "{fw:?}/{charge}");
        }
    }

    /// One machine per rack: the hierarchy degenerates to the flat
    /// protocol and must reproduce it exactly.
    #[test]
    fn singleton_racks_hierarchical_distributed_matches_flat() {
        let (g, machines, part) = setup(2, 50);
        let layout = RackLayout::singletons(5);
        let flat = run_distributed(
            Arc::clone(&g),
            &machines,
            part.clone(),
            &DistributedOptions::default(),
        );
        let hier = run_distributed_hierarchical(
            Arc::clone(&g),
            &machines,
            part,
            &layout,
            &DistributedOptions::default(),
        );
        assert_eq!(hier.partition.assignment(), flat.partition.assignment());
        assert_eq!(hier.transfers, flat.transfers);
        assert!(hier.converged);
        assert!(!hier.timed_out);
    }

    /// The rack bus promotes outgoing aggregates to `RackUpdate` (33 +
    /// 8R wire bytes — R racks, not K machines), demotes them back on
    /// receipt, and books them under their own counter.
    #[test]
    fn rack_bus_promotes_and_demotes_aggregates() {
        let (mut eps, stats) = build_bus(2, Duration::ZERO);
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        let ra = RackBus::new(a, 0, vec![0, 1]);
        let rb = RackBus::new(b, 1, vec![0, 1]);
        let loads = vec![1.0, 2.0];
        ra.send(1, Message::RegularUpdate { seq: 0, node: 7, from: 0, to: 1, loads: loads.clone() });
        match rb.recv_timeout(Duration::from_secs(5)) {
            RecvOutcome::Msg(Message::RegularUpdate { seq, node, loads: got, .. }) => {
                assert_eq!((seq, node), (0, 7));
                assert_eq!(got, loads);
            }
            other => panic!("unexpected {other:?}"),
        }
        let s = stats.lock().unwrap();
        assert_eq!(s.rack_update.messages, 1);
        assert_eq!(s.regular_update.messages, 0);
        assert_eq!(s.bytes_per_rack_update(), (33 + 8 * 2) as f64);
    }

    /// Dead peer: the ring forwards the token toward a machine whose
    /// endpoint was dropped. Every surviving actor must exit through
    /// the recv timeout within bounded time — no deadlock. (The full
    /// regression lives in `integration_coordinator.rs` via
    /// `testkit::assert_ring_unwinds_on_dead_peer`, on both
    /// transports.)
    #[test]
    fn dropped_peer_times_out_instead_of_deadlocking() {
        let (g, machines, part) = setup(6, 60);
        let k = machines.count();
        let (mut endpoints, _stats) = build_bus(k, Duration::ZERO);
        drop(endpoints.pop().unwrap()); // machine K-1 dies before the round
        crate::util::testkit::assert_ring_unwinds_on_dead_peer(
            endpoints,
            &g,
            &machines,
            &part,
            Duration::from_millis(150),
        );
    }
}

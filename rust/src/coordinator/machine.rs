//! A machine actor: the per-machine participant in the distributed
//! refinement protocol (paper Fig. 2, quoted in module tests).
//!
//! Each machine owns the subset of LPs assigned to it and keeps a local
//! replica of the assignment + the O(K) aggregate loads, synchronized
//! purely through `ReceiveNode` / `RegularUpdate` messages. On its turn
//! it picks its most dissatisfied node via the same [`CostModel`] the
//! sequential engine uses, executes the transfer locally, and notifies
//! the others. This mirrors the paper exactly: "machines exchange nodes
//! using knowledge of the node costs, i.e., they play the game on behalf
//! of the nodes that currently belong to their partition."

use std::sync::Arc;

use crate::game::cost::{CostModel, Framework};
use crate::graph::{Graph, NodeId};
use crate::partition::{MachineConfig, MachineId, Partition};

/// What a machine decided on its turn.
#[derive(Debug, Clone, Copy)]
pub enum TurnDecision {
    Forfeit,
    Transfer { node: NodeId, to: MachineId, dissatisfaction: f64 },
}

/// Machine-local state.
pub struct MachineActor {
    pub id: MachineId,
    graph: Arc<Graph>,
    machines: MachineConfig,
    mu: f64,
    framework: Framework,
    /// Per-move migration surcharge of the augmented game (DESIGN.md
    /// §9); must match the other machines' charge exactly or replicas
    /// pick different transfers.
    migration_charge: f64,
    /// Local replica of the full assignment (content-wise a machine only
    /// *needs* its own members + their neighbors; a dense replica is the
    /// simplest O(N)-memory / O(1)-update-traffic realization).
    part: Partition,
    /// Nodes this machine currently owns.
    members: Vec<NodeId>,
    /// Candidate machines this actor may move nodes to (ascending). In
    /// the two-level hierarchy (DESIGN.md §12) the inner game scopes
    /// every rack member to its rack; `None` plays the flat game over
    /// all K machines.
    scope: Option<Vec<MachineId>>,
    /// Transfers this machine has executed.
    pub transfers_made: usize,
}

impl MachineActor {
    pub fn new(
        id: MachineId,
        graph: Arc<Graph>,
        machines: MachineConfig,
        initial: &Partition,
        mu: f64,
        framework: Framework,
        migration_charge: f64,
    ) -> Self {
        assert!(
            migration_charge >= 0.0 && migration_charge.is_finite(),
            "migration charge must be finite and >= 0"
        );
        let members = initial.members(id);
        MachineActor {
            id,
            graph,
            machines,
            mu,
            framework,
            migration_charge,
            part: initial.clone(),
            members,
            scope: None,
            transfers_made: 0,
        }
    }

    /// Builder: restrict this actor's transfer targets to `scope` (the
    /// inner rack subgame). The scope must be ascending, in range, and
    /// contain the actor's own machine; all rack members must use the
    /// identical scope or replicas pick different transfers.
    pub fn with_scope(mut self, scope: Vec<MachineId>) -> Self {
        assert!(scope.windows(2).all(|w| w[0] < w[1]), "scope must be ascending");
        assert!(scope.iter().all(|&m| m < self.machines.count()), "scope machine out of range");
        assert!(scope.contains(&self.id), "actor {} outside its own scope", self.id);
        self.scope = Some(scope);
        self
    }

    fn model(&self) -> CostModel<'_> {
        CostModel::new(&self.graph, self.machines.clone(), self.mu, self.framework)
            .with_migration_charge(self.migration_charge)
    }

    /// Current members (sorted copy; for reporting).
    pub fn members(&self) -> Vec<NodeId> {
        let mut m = self.members.clone();
        m.sort_unstable();
        m
    }

    /// Local view of the aggregate loads.
    pub fn loads(&self) -> &[f64] {
        self.part.loads()
    }

    /// Local view of the full assignment.
    pub fn assignment(&self) -> &[MachineId] {
        self.part.assignment()
    }

    /// Fig. 2 `TakeMyTurnTrigger` body: find and execute the transfer of
    /// the most dissatisfied owned node. Mutates local state only; the
    /// caller (the actor loop) is responsible for sending the triggers.
    pub fn take_turn(&mut self, epsilon: f64) -> TurnDecision {
        let model = self.model();
        let mut best: Option<(NodeId, f64, MachineId)> = None;
        let mut adj = vec![0.0f64; model.k()];
        for &i in &self.members {
            let (j, target) = match &self.scope {
                None => model.dissatisfaction(&self.part, i),
                Some(scope) => {
                    let s = model.adj_row(&self.part, i, &mut adj);
                    model.dissatisfaction_scoped_with_adj(&self.part, i, s, &adj, scope)
                }
            };
            if j > epsilon {
                match best {
                    Some((_, bj, _)) if bj >= j => {}
                    _ => best = Some((i, j, target)),
                }
            }
        }
        match best {
            None => TurnDecision::Forfeit,
            Some((node, dissatisfaction, to)) => {
                self.apply_local_transfer(node, self.id, to);
                self.transfers_made += 1;
                TurnDecision::Transfer { node, to, dissatisfaction }
            }
        }
    }

    /// Apply a transfer to the local replica (own turn, `ReceiveNode`, or
    /// `RegularUpdate`). Keeps `members` in sync.
    pub fn apply_local_transfer(&mut self, node: NodeId, from: MachineId, to: MachineId) {
        debug_assert_eq!(self.part.machine_of(node), from, "replica divergence");
        self.part.transfer(&self.graph, node, to);
        if from == self.id {
            if let Some(pos) = self.members.iter().position(|&m| m == node) {
                self.members.swap_remove(pos);
            }
        }
        if to == self.id && !self.members.contains(&node) {
            self.members.push(node);
        }
    }

    /// Cross-check the local aggregate loads against a reference vector
    /// (from a `RegularUpdate`); returns false on divergence.
    pub fn loads_agree(&self, reference: &[f64]) -> bool {
        self.part
            .loads()
            .iter()
            .zip(reference.iter())
            .all(|(a, b)| (a - b).abs() <= 1e-6 * (1.0 + b.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{table1_graph, WeightModel};
    use crate::util::rng::Pcg32;

    fn setup() -> (Arc<Graph>, MachineConfig, Partition) {
        let mut rng = Pcg32::new(3);
        let g = Arc::new(table1_graph(40, 3, 6, WeightModel::default(), &mut rng));
        let machines = MachineConfig::homogeneous(4);
        let assignment: Vec<usize> = (0..40).map(|_| rng.index(4)).collect();
        let part = Partition::from_assignment(&g, 4, assignment);
        (g, machines, part)
    }

    #[test]
    fn members_initialized_from_partition() {
        let (g, machines, part) = setup();
        let m = MachineActor::new(1, g, machines, &part, 8.0, Framework::A, 0.0);
        assert_eq!(m.members(), part.members(1));
    }

    #[test]
    fn turn_transfers_most_dissatisfied() {
        let (g, machines, part) = setup();
        let mut m = MachineActor::new(0, Arc::clone(&g), machines.clone(), &part, 8.0, Framework::A, 0.0);
        match m.take_turn(1e-9) {
            TurnDecision::Transfer { node, to, dissatisfaction } => {
                assert!(dissatisfaction > 0.0);
                assert_ne!(to, 0);
                // The node left machine 0's member list and the replica moved it.
                assert!(!m.members().contains(&node));
                assert_eq!(m.assignment()[node], to);
                assert_eq!(m.transfers_made, 1);
            }
            TurnDecision::Forfeit => {
                // Possible but unlikely on a random partition; accept only
                // if truly no node is dissatisfied.
                let model = CostModel::new(&g, machines, 8.0, Framework::A);
                for &i in &part.members(0) {
                    let (j, _) = model.dissatisfaction(&part, i);
                    assert!(j <= 1e-9);
                }
            }
        }
    }

    #[test]
    fn replicas_converge_under_update_stream() {
        let (g, machines, part) = setup();
        let mut a = MachineActor::new(0, Arc::clone(&g), machines.clone(), &part, 8.0, Framework::A, 0.0);
        let mut b = MachineActor::new(1, Arc::clone(&g), machines.clone(), &part, 8.0, Framework::A, 0.0);
        // a executes turns; b applies the updates; replicas stay equal.
        for _ in 0..5 {
            match a.take_turn(1e-9) {
                TurnDecision::Transfer { node, to, .. } => {
                    b.apply_local_transfer(node, 0, to);
                    assert!(b.loads_agree(a.loads()));
                    assert_eq!(a.assignment(), b.assignment());
                }
                TurnDecision::Forfeit => break,
            }
        }
    }

    #[test]
    fn receive_node_adds_member() {
        let (g, machines, part) = setup();
        let mut b = MachineActor::new(1, g, machines, &part, 8.0, Framework::A, 0.0);
        // Find a node owned by machine 0 and hand it to machine 1.
        let node = part.members(0)[0];
        b.apply_local_transfer(node, 0, 1);
        assert!(b.members().contains(&node));
        assert_eq!(b.assignment()[node], 1);
    }
}

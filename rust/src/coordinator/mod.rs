//! Distributed refinement coordinator (paper Fig. 1/2, §4.5).
//!
//! The sequential [`crate::game::refine::RefineEngine`] proves the
//! algorithm; this module *distributes* it the way the paper describes:
//! one actor per machine (here: one OS thread per machine), communicating
//! only through messages:
//!
//! * `TakeMyTurnTrigger` — the token circulating round-robin; its holder
//!   transfers its most dissatisfied node (or forfeits).
//! * `ReceiveNodeTrigger` — tells the destination machine it now owns a
//!   node.
//! * `RegularUpdateTrigger` — tells every other machine about the
//!   transfer plus the new O(K) load aggregates, which is the *only*
//!   global state anyone needs (§4.5): overhead per transfer is O(K),
//!   independent of the number of simulated nodes N.
//!
//! The message bus counts messages and bytes per type so the §4.5
//! feasibility claim is *measured*, not asserted
//! (see `OverheadStats` and `rust/tests/integration_coordinator.rs`).
//!
//! Transports: the protocol loop is generic over [`bus::Bus`] — the
//! in-process mpsc ring ([`bus::build_bus`]) and the real-socket TCP
//! mesh ([`net`]) produce bit-identical refinement results and
//! identical (exact, on-the-wire) overhead accounting. [`net`] also
//! hosts the multi-process cluster (`gtip serve` workers + the
//! `gtip dynamic --transport tcp` leader); see DESIGN.md §8 for the
//! wire format.

pub mod bus;
pub mod distributed;
pub mod machine;
pub mod net;
pub mod protocol;

pub use bus::{Bus, RecvOutcome};
pub use distributed::{
    run_distributed, run_distributed_hierarchical, DistributedOptions, DistributedReport,
};
pub use net::{ClusterLeader, TcpEndpoint, WireError};
pub use protocol::{Message, OverheadStats};

//! Real network transport for the distributed coordinator: a std-only,
//! length-prefixed binary wire codec for [`Message`] (plus the control
//! frames of the multi-process epoch protocol), a [`TcpEndpoint`]
//! implementing [`Bus`] over a full mesh of loopback-or-LAN sockets,
//! deterministic machine-id handshakes with retry/backoff dialing, and
//! the leader/worker pair ([`ClusterLeader`] / [`serve`]) that lets
//! `gtip dynamic --transport tcp` drive refinement rounds across real
//! OS processes.
//!
//! ## Frame layout
//!
//! Every frame is `u32 LE payload length || payload`; the payload is a
//! 1-byte tag followed by fixed-width little-endian fields (`u64`
//! counts, `u32` machine ids, IEEE-754 `f64` loads; vectors are a `u32`
//! length followed by the elements). Tags 1–4 are the Fig. 2 protocol
//! messages — their encoded size is exactly
//! [`Message::wire_bytes`], which both transports feed into
//! [`OverheadStats`], so the measured §4.5 overhead is the true
//! on-the-wire byte count. Tags 16+ are control frames (handshake,
//! epoch setup/begin, per-round stats report, goodbye); control bytes
//! are accounted separately in [`NetStats`] and never touch
//! [`OverheadStats`], keeping the feasibility metric about the game's
//! aggregate-state exchange only.
//!
//! ## Connection lifecycle
//!
//! Machine `i` of K listens on `addrs[i]` and dials every other
//! machine with retry + exponential backoff; each outbound connection
//! opens with a `Hello` frame (`magic || version || machine id ||
//! machine count`), so the acceptor learns deterministically who is on
//! the other end. Each inbound connection gets a reader thread that
//! decodes frames and routes protocol messages to the endpoint's inbox
//! and control frames to its control queue. Shutdown is graceful: the
//! leader broadcasts `Goodbye`, workers exit, sockets close, readers
//! see EOF and stop.
//!
//! ## Epoch barrier
//!
//! One refinement round per `EpochBegin` (which re-syncs graph weights
//! and the warm-start assignment — O(N) control traffic that exists in
//! any measurement-driven deployment and is reported separately from
//! the O(K) game traffic). After a round converges, every worker sends
//! its [`OverheadStats`] delta as `RoundStats`; the leader waits for
//! all K−1 reports before the next epoch, which doubles as the barrier
//! that keeps rounds from interleaving on the wire.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::bus::{Bus, RecvOutcome};
use crate::coordinator::distributed::{
    machine_loop, run_over_endpoints, DistributedOptions, DistributedReport,
};
use crate::coordinator::machine::MachineActor;
use crate::coordinator::protocol::{Counter, Message, OverheadStats};
use crate::game::cost::Framework;
use crate::graph::{Graph, GraphBuilder};
use crate::partition::{MachineConfig, MachineId, Partition};

/// First bytes of every `Hello` payload after the tag.
pub const WIRE_MAGIC: [u8; 4] = *b"GTIP";
/// Wire protocol version; bumped on any layout change. v2 added the
/// migration charge of the augmented game to `Setup` — the `Hello`
/// handshake rejects any peer speaking another version, so the decode
/// of the widened layout is version-gated at connection time and a
/// v1/v2 mix can never half-parse a fixture.
pub const WIRE_VERSION: u16 = 2;
/// Upper bound on a single frame payload; larger prefixes are rejected
/// before any allocation happens.
pub const MAX_FRAME_BYTES: usize = 1 << 24;

/// Message tags (1–4 mirror [`Message`]; 16+ are control frames).
const TAG_TAKE_MY_TURN: u8 = 1;
const TAG_RECEIVE_NODE: u8 = 2;
const TAG_REGULAR_UPDATE: u8 = 3;
const TAG_SHUTDOWN: u8 = 4;
const TAG_HELLO: u8 = 16;
const TAG_SETUP: u8 = 17;
const TAG_EPOCH_BEGIN: u8 = 18;
const TAG_ROUND_STATS: u8 = 19;
const TAG_GOODBYE: u8 = 20;

/// Errors of the wire codec and connection lifecycle.
#[derive(Debug)]
pub enum WireError {
    /// Frame payload ended before the advertised fields.
    Truncated { needed: usize, got: usize },
    /// Decoded fields left unconsumed payload bytes behind.
    TrailingBytes { extra: usize },
    /// Length prefix exceeds [`MAX_FRAME_BYTES`].
    Oversized { len: usize },
    /// Unknown frame tag.
    BadTag(u8),
    /// Handshake did not start with [`WIRE_MAGIC`].
    BadMagic,
    /// Peer speaks a different [`WIRE_VERSION`].
    BadVersion { theirs: u16 },
    /// The socket closed mid-stream.
    Closed,
    /// Underlying socket error.
    Io(String),
    /// The peer violated the epoch protocol.
    Protocol(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { needed, got } => {
                write!(f, "truncated frame: needed {needed} bytes, got {got}")
            }
            WireError::TrailingBytes { extra } => {
                write!(f, "malformed frame: {extra} unconsumed trailing bytes")
            }
            WireError::Oversized { len } => {
                write!(f, "oversized frame: {len} bytes > max {MAX_FRAME_BYTES}")
            }
            WireError::BadTag(t) => write!(f, "unknown frame tag {t}"),
            WireError::BadMagic => write!(f, "bad handshake magic (not a gtip peer?)"),
            WireError::BadVersion { theirs } => {
                write!(f, "wire version mismatch: peer {theirs}, ours {WIRE_VERSION}")
            }
            WireError::Closed => write!(f, "connection closed"),
            WireError::Io(e) => write!(f, "socket error: {e}"),
            WireError::Protocol(e) => write!(f, "protocol violation: {e}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            WireError::Closed
        } else {
            WireError::Io(e.to_string())
        }
    }
}

/// Control frames + protocol messages — everything that crosses a
/// socket.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// A Fig. 2 protocol message (the only frames [`OverheadStats`]
    /// counts).
    Msg(Message),
    /// Connection handshake: who is dialing, and how big they think the
    /// cluster is.
    Hello { version: u16, machine: u32, machines: u32 },
    /// Leader → workers, once: the shared fixture (machine speeds, game
    /// options, graph topology + weights).
    Setup(SetupFrame),
    /// Leader → workers, per refinement round: fresh measured weights
    /// and the warm-start assignment.
    EpochBegin(EpochFrame),
    /// Worker → leader after each round: the worker's [`OverheadStats`]
    /// delta for that round (the leader aggregates them; waiting for
    /// all K−1 doubles as the epoch barrier).
    RoundStats(OverheadStats),
    /// Leader → workers: the run is over; exit cleanly.
    Goodbye,
}

/// Payload of [`Frame::Setup`].
#[derive(Debug, Clone, PartialEq)]
pub struct SetupFrame {
    pub speeds: Vec<f64>,
    pub mu: f64,
    pub framework: Framework,
    /// Per-move migration surcharge of the augmented game (DESIGN.md
    /// §9). Workers must price moves with exactly the leader's charge
    /// or replicas pick different transfers (wire v2).
    pub migration_charge: f64,
    pub epsilon: f64,
    pub max_transfers: u64,
    pub recv_timeout_ms: u64,
    pub node_weights: Vec<f64>,
    /// `(u, v, weight)` for every edge, in the leader graph's edge
    /// order (workers re-install per-epoch weights in this order).
    pub edges: Vec<(u32, u32, f64)>,
}

/// Payload of [`Frame::EpochBegin`].
#[derive(Debug, Clone, PartialEq)]
pub struct EpochFrame {
    pub epoch: u64,
    pub node_weights: Vec<f64>,
    /// One weight per edge, in [`SetupFrame::edges`] order.
    pub edge_weights: Vec<f64>,
    pub assignment: Vec<u32>,
}

// ---------------------------------------------------------------------
// Codec
// ---------------------------------------------------------------------

fn put_u16(b: &mut Vec<u8>, v: u16) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(b: &mut Vec<u8>, v: f64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_f64s(b: &mut Vec<u8>, vs: &[f64]) {
    put_u32(b, vs.len() as u32);
    for &v in vs {
        put_f64(b, v);
    }
}

/// Bounded reader over a frame payload; every accessor fails with
/// [`WireError::Truncated`] instead of panicking on short input.
struct Dec<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(b: &'a [u8]) -> Self {
        Dec { b, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.pos + n > self.b.len() {
            return Err(WireError::Truncated { needed: self.pos + n, got: self.b.len() });
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().expect("len 2")))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("len 4")))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("len 8")))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().expect("len 8")))
    }

    /// Length-prefixed f64 vector; the length is validated against the
    /// remaining payload before any allocation.
    fn f64s(&mut self) -> Result<Vec<f64>, WireError> {
        let len = self.u32()? as usize;
        if self.pos + 8 * len > self.b.len() {
            return Err(WireError::Truncated { needed: self.pos + 8 * len, got: self.b.len() });
        }
        (0..len).map(|_| self.f64()).collect()
    }

    fn finish(self) -> Result<(), WireError> {
        if self.pos != self.b.len() {
            return Err(WireError::TrailingBytes { extra: self.b.len() - self.pos });
        }
        Ok(())
    }
}

fn encode_payload(frame: &Frame, b: &mut Vec<u8>) {
    match frame {
        Frame::Msg(Message::TakeMyTurn { consecutive_forfeits, transfers_so_far }) => {
            b.push(TAG_TAKE_MY_TURN);
            put_u64(b, *consecutive_forfeits as u64);
            put_u64(b, *transfers_so_far as u64);
        }
        Frame::Msg(Message::ReceiveNode { seq, node, from, to }) => {
            b.push(TAG_RECEIVE_NODE);
            put_u64(b, *seq);
            put_u64(b, *node as u64);
            put_u32(b, *from as u32);
            put_u32(b, *to as u32);
        }
        Frame::Msg(Message::RegularUpdate { seq, node, from, to, loads }) => {
            b.push(TAG_REGULAR_UPDATE);
            put_u64(b, *seq);
            put_u64(b, *node as u64);
            put_u32(b, *from as u32);
            put_u32(b, *to as u32);
            put_f64s(b, loads);
        }
        Frame::Msg(Message::Shutdown { total_transfers, converged }) => {
            b.push(TAG_SHUTDOWN);
            put_u64(b, *total_transfers);
            b.push(u8::from(*converged));
        }
        Frame::Hello { version, machine, machines } => {
            b.push(TAG_HELLO);
            b.extend_from_slice(&WIRE_MAGIC);
            put_u16(b, *version);
            put_u32(b, *machine);
            put_u32(b, *machines);
        }
        Frame::Setup(s) => {
            b.push(TAG_SETUP);
            put_f64s(b, &s.speeds);
            put_f64(b, s.mu);
            b.push(match s.framework {
                Framework::A => 0,
                Framework::B => 1,
            });
            put_f64(b, s.migration_charge);
            put_f64(b, s.epsilon);
            put_u64(b, s.max_transfers);
            put_u64(b, s.recv_timeout_ms);
            put_f64s(b, &s.node_weights);
            put_u32(b, s.edges.len() as u32);
            for &(u, v, w) in &s.edges {
                put_u32(b, u);
                put_u32(b, v);
                put_f64(b, w);
            }
        }
        Frame::EpochBegin(e) => {
            b.push(TAG_EPOCH_BEGIN);
            put_u64(b, e.epoch);
            put_f64s(b, &e.node_weights);
            put_f64s(b, &e.edge_weights);
            put_u32(b, e.assignment.len() as u32);
            for &a in &e.assignment {
                put_u32(b, a);
            }
        }
        Frame::RoundStats(s) => {
            b.push(TAG_ROUND_STATS);
            for c in [&s.take_my_turn, &s.receive_node, &s.regular_update, &s.shutdown] {
                put_u64(b, c.messages);
                put_u64(b, c.bytes);
            }
        }
        Frame::Goodbye => b.push(TAG_GOODBYE),
    }
}

/// Encode a frame as `u32 LE payload length || payload`.
pub fn encode_frame(frame: &Frame) -> Vec<u8> {
    let mut payload = Vec::with_capacity(64);
    encode_payload(frame, &mut payload);
    let mut out = Vec::with_capacity(4 + payload.len());
    put_u32(&mut out, payload.len() as u32);
    out.extend_from_slice(&payload);
    out
}

/// Decode one frame payload (the bytes after the length prefix).
/// Rejects unknown tags, short payloads, and trailing garbage — never
/// panics on malformed input.
pub fn decode_payload(payload: &[u8]) -> Result<Frame, WireError> {
    let mut d = Dec::new(payload);
    let tag = d.u8()?;
    let frame = match tag {
        TAG_TAKE_MY_TURN => Frame::Msg(Message::TakeMyTurn {
            consecutive_forfeits: d.u64()? as usize,
            transfers_so_far: d.u64()? as usize,
        }),
        TAG_RECEIVE_NODE => Frame::Msg(Message::ReceiveNode {
            seq: d.u64()?,
            node: d.u64()? as usize,
            from: d.u32()? as MachineId,
            to: d.u32()? as MachineId,
        }),
        TAG_REGULAR_UPDATE => Frame::Msg(Message::RegularUpdate {
            seq: d.u64()?,
            node: d.u64()? as usize,
            from: d.u32()? as MachineId,
            to: d.u32()? as MachineId,
            loads: d.f64s()?,
        }),
        TAG_SHUTDOWN => Frame::Msg(Message::Shutdown {
            total_transfers: d.u64()?,
            converged: match d.u8()? {
                0 => false,
                1 => true,
                other => {
                    return Err(WireError::Protocol(format!("bad converged byte {other}")))
                }
            },
        }),
        TAG_HELLO => {
            if d.take(4)? != WIRE_MAGIC {
                return Err(WireError::BadMagic);
            }
            let version = d.u16()?;
            if version != WIRE_VERSION {
                return Err(WireError::BadVersion { theirs: version });
            }
            Frame::Hello { version, machine: d.u32()?, machines: d.u32()? }
        }
        TAG_SETUP => {
            let speeds = d.f64s()?;
            let mu = d.f64()?;
            let framework = match d.u8()? {
                0 => Framework::A,
                1 => Framework::B,
                other => return Err(WireError::Protocol(format!("bad framework byte {other}"))),
            };
            Frame::Setup(SetupFrame {
                speeds,
                mu,
                framework,
                migration_charge: d.f64()?,
                epsilon: d.f64()?,
                max_transfers: d.u64()?,
                recv_timeout_ms: d.u64()?,
                node_weights: d.f64s()?,
                edges: {
                    let len = d.u32()? as usize;
                    let mut edges = Vec::new();
                    for _ in 0..len {
                        edges.push((d.u32()?, d.u32()?, d.f64()?));
                    }
                    edges
                },
            })
        }
        TAG_EPOCH_BEGIN => Frame::EpochBegin(EpochFrame {
            epoch: d.u64()?,
            node_weights: d.f64s()?,
            edge_weights: d.f64s()?,
            assignment: {
                let len = d.u32()? as usize;
                if 4 * len > payload.len() {
                    return Err(WireError::Truncated { needed: 4 * len, got: payload.len() });
                }
                (0..len).map(|_| d.u32()).collect::<Result<_, _>>()?
            },
        }),
        TAG_ROUND_STATS => {
            let mut cs = [Counter::default(); 4];
            for c in cs.iter_mut() {
                c.messages = d.u64()?;
                c.bytes = d.u64()?;
            }
            Frame::RoundStats(OverheadStats {
                take_my_turn: cs[0],
                receive_node: cs[1],
                regular_update: cs[2],
                shutdown: cs[3],
            })
        }
        TAG_GOODBYE => Frame::Goodbye,
        other => return Err(WireError::BadTag(other)),
    };
    d.finish()?;
    Ok(frame)
}

/// Read one length-prefixed frame from a stream.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame, WireError> {
    let mut prefix = [0u8; 4];
    r.read_exact(&mut prefix)?;
    let len = u32::from_le_bytes(prefix) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(WireError::Oversized { len });
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    decode_payload(&payload)
}

/// Write one frame to a stream.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> Result<usize, WireError> {
    let bytes = encode_frame(frame);
    w.write_all(&bytes)?;
    Ok(bytes.len())
}

// ---------------------------------------------------------------------
// TCP endpoint
// ---------------------------------------------------------------------

/// Byte/message accounting of the control plane (handshakes, epoch
/// setup/begin, stats reports) — kept apart from [`OverheadStats`] so
/// the §4.5 metric stays about the game's O(K) state exchange.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NetStats {
    pub control_messages: u64,
    pub control_bytes: u64,
}

/// One machine's socket-backed endpoint: a listener's worth of inbound
/// reader threads feeding an inbox, plus one outbound stream per peer.
pub struct TcpEndpoint {
    id: MachineId,
    k: usize,
    inbox: Receiver<Message>,
    inbox_tx: Sender<Message>,
    ctrl: Receiver<(MachineId, Frame)>,
    outs: Vec<Option<Mutex<TcpStream>>>,
    stats: Arc<Mutex<OverheadStats>>,
    net: Arc<Mutex<NetStats>>,
}

impl Bus for TcpEndpoint {
    fn id(&self) -> MachineId {
        self.id
    }

    fn machine_count(&self) -> usize {
        self.k
    }

    fn send(&self, to: MachineId, msg: Message) {
        self.stats.lock().expect("stats poisoned").record(&msg);
        if to == self.id {
            // Loopback without touching the network (the ring kick).
            let _ = self.inbox_tx.send(msg);
            return;
        }
        let bytes = encode_frame(&Frame::Msg(msg.clone()));
        debug_assert_eq!(bytes.len(), msg.wire_bytes(), "codec vs wire_bytes drift");
        if let Some(stream) = &self.outs[to] {
            // A dead peer is fine to ignore, exactly like the closed
            // mpsc sender on the in-process bus.
            let _ = stream.lock().expect("stream poisoned").write_all(&bytes);
        }
    }

    fn recv_timeout(&self, timeout: Duration) -> RecvOutcome {
        match self.inbox.recv_timeout(timeout) {
            Ok(msg) => RecvOutcome::Msg(msg),
            Err(RecvTimeoutError::Timeout) => RecvOutcome::TimedOut,
            Err(RecvTimeoutError::Disconnected) => RecvOutcome::Disconnected,
        }
    }
}

impl TcpEndpoint {
    /// Send a control frame to one peer.
    pub fn send_ctrl(&self, to: MachineId, frame: &Frame) -> Result<(), WireError> {
        let stream = self.outs[to]
            .as_ref()
            .ok_or_else(|| WireError::Protocol(format!("no connection to machine {to}")))?;
        let bytes = encode_frame(frame);
        stream.lock().expect("stream poisoned").write_all(&bytes)?;
        let mut net = self.net.lock().expect("net stats poisoned");
        net.control_messages += 1;
        net.control_bytes += bytes.len() as u64;
        Ok(())
    }

    /// Send a control frame to every peer.
    pub fn broadcast_ctrl(&self, frame: &Frame) -> Result<(), WireError> {
        for to in 0..self.k {
            if to != self.id {
                self.send_ctrl(to, frame)?;
            }
        }
        Ok(())
    }

    /// Receive the next control frame (tagged with its sender).
    pub fn recv_ctrl(&self, timeout: Duration) -> Result<(MachineId, Frame), WireError> {
        match self.ctrl.recv_timeout(timeout) {
            Ok(pair) => Ok(pair),
            Err(RecvTimeoutError::Timeout) => {
                Err(WireError::Protocol("timed out waiting for a control frame".into()))
            }
            Err(RecvTimeoutError::Disconnected) => Err(WireError::Closed),
        }
    }

    /// Snapshot of the protocol-message accounting.
    pub fn stats_snapshot(&self) -> OverheadStats {
        self.stats.lock().expect("stats poisoned").clone()
    }

    /// Snapshot of the control-plane accounting.
    pub fn net_snapshot(&self) -> NetStats {
        *self.net.lock().expect("net stats poisoned")
    }
}

/// Initial dial backoff; doubles up to [`DIAL_BACKOFF_MAX`].
const DIAL_BACKOFF_START: Duration = Duration::from_millis(25);
const DIAL_BACKOFF_MAX: Duration = Duration::from_millis(800);
/// Poll interval of the bounded accept loop.
const ACCEPT_POLL: Duration = Duration::from_millis(20);

/// Validate one inbound connection's `Hello` handshake.
fn handshake_inbound(
    mut stream: TcpStream,
    id: MachineId,
    k: usize,
    deadline: Instant,
    seen: &[bool],
) -> Result<(MachineId, TcpStream), WireError> {
    stream.set_nonblocking(false)?;
    let left = deadline.saturating_duration_since(Instant::now()).max(Duration::from_millis(1));
    stream.set_read_timeout(Some(left))?;
    let hello = read_frame(&mut stream)?;
    let Frame::Hello { machine, machines, .. } = hello else {
        return Err(WireError::Protocol(format!("expected Hello, got {hello:?}")));
    };
    let peer = machine as MachineId;
    if machines as usize != k || peer >= k || peer == id {
        return Err(WireError::Protocol(format!(
            "peer says machine {machine}/{machines}, we are {id}/{k}"
        )));
    }
    if seen[peer] {
        return Err(WireError::Protocol(format!("duplicate dial from machine {peer}")));
    }
    stream.set_read_timeout(None)?;
    stream.set_nodelay(true)?;
    Ok((peer, stream))
}

/// Accept inbound connections until one valid `Hello` per peer has
/// arrived. A single bad connection (port scanner, garbage handshake,
/// stray re-dial) is dropped with a note — never allowed to kill the
/// mesh join; only the overall deadline fails it.
fn accept_peers(
    listener: TcpListener,
    id: MachineId,
    k: usize,
    deadline: Instant,
) -> Result<Vec<(MachineId, TcpStream)>, WireError> {
    listener.set_nonblocking(true)?;
    let mut inbound: Vec<(MachineId, TcpStream)> = Vec::with_capacity(k - 1);
    let mut seen = vec![false; k];
    while inbound.len() < k - 1 {
        match listener.accept() {
            Ok((stream, addr)) => {
                // Per-connection handshake; any failure drops only this
                // socket.
                match handshake_inbound(stream, id, k, deadline, &seen) {
                    Ok((peer, stream)) => {
                        seen[peer] = true;
                        inbound.push((peer, stream));
                    }
                    Err(e) => {
                        eprintln!("gtip net: dropping inbound connection from {addr}: {e}");
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(WireError::Protocol(format!(
                        "timed out waiting for {} inbound peers (have {})",
                        k - 1,
                        inbound.len()
                    )));
                }
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(inbound)
}

/// Dial one peer with retry + backoff until `deadline`.
fn dial_peer(addr: &str, deadline: Instant) -> Result<TcpStream, WireError> {
    let mut backoff = DIAL_BACKOFF_START;
    loop {
        match TcpStream::connect(addr) {
            Ok(stream) => {
                stream.set_nodelay(true)?;
                return Ok(stream);
            }
            Err(e) => {
                if Instant::now() + backoff >= deadline {
                    return Err(WireError::Io(format!("dialing {addr}: {e}")));
                }
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(DIAL_BACKOFF_MAX);
            }
        }
    }
}

/// Build machine `id`'s endpoint from an already-bound listener:
/// full-mesh dial with deterministic `Hello` handshakes, then one
/// reader thread per inbound connection.
fn mesh_with_listener(
    listener: TcpListener,
    id: MachineId,
    addrs: &[String],
    connect_timeout: Duration,
    stats: Arc<Mutex<OverheadStats>>,
) -> Result<TcpEndpoint, WireError> {
    let k = addrs.len();
    assert!(id < k, "machine id {id} out of range for {k} machines");
    let deadline = Instant::now() + connect_timeout;

    let accept_handle = if k > 1 {
        Some(std::thread::spawn(move || accept_peers(listener, id, k, deadline)))
    } else {
        None
    };

    // Dial everyone else (ascending machine order for determinism).
    let mut outs: Vec<Option<Mutex<TcpStream>>> = (0..k).map(|_| None).collect();
    for (peer, addr) in addrs.iter().enumerate() {
        if peer == id {
            continue;
        }
        let mut stream = dial_peer(addr, deadline)?;
        write_frame(
            &mut stream,
            &Frame::Hello { version: WIRE_VERSION, machine: id as u32, machines: k as u32 },
        )?;
        outs[peer] = Some(Mutex::new(stream));
    }

    let inbound = match accept_handle {
        Some(h) => h.join().expect("accept thread panicked")?,
        None => Vec::new(),
    };

    let (inbox_tx, inbox) = channel();
    let (ctrl_tx, ctrl) = channel();
    for (peer, mut stream) in inbound {
        let inbox_tx = inbox_tx.clone();
        let ctrl_tx = ctrl_tx.clone();
        std::thread::spawn(move || loop {
            match read_frame(&mut stream) {
                Ok(Frame::Msg(msg)) => {
                    if inbox_tx.send(msg).is_err() {
                        break;
                    }
                }
                Ok(frame) => {
                    if ctrl_tx.send((peer, frame)).is_err() {
                        break;
                    }
                }
                Err(WireError::Closed) => break,
                Err(e) => {
                    eprintln!("gtip net: reader for machine {peer} stopped: {e}");
                    break;
                }
            }
        });
    }

    Ok(TcpEndpoint {
        id,
        k,
        inbox,
        inbox_tx,
        ctrl,
        outs,
        stats,
        net: Arc::new(Mutex::new(NetStats::default())),
    })
}

/// Join the mesh as machine `id`: bind `addrs[id]`, dial everyone else.
pub fn connect_mesh(
    id: MachineId,
    addrs: &[String],
    connect_timeout: Duration,
    stats: Arc<Mutex<OverheadStats>>,
) -> Result<TcpEndpoint, WireError> {
    let listener = TcpListener::bind(addrs[id].as_str())
        .map_err(|e| WireError::Io(format!("binding {}: {e}", addrs[id])))?;
    mesh_with_listener(listener, id, addrs, connect_timeout, stats)
}

/// A K-machine loopback mesh inside one process (OS-assigned ports),
/// sharing one [`OverheadStats`] handle exactly like the in-process
/// bus — the test harness for transport equivalence.
pub fn build_tcp_bus_local(
    k: usize,
) -> Result<(Vec<TcpEndpoint>, Arc<Mutex<OverheadStats>>), WireError> {
    assert!(k >= 1);
    let stats = Arc::new(Mutex::new(OverheadStats::default()));
    let mut listeners = Vec::with_capacity(k);
    let mut addrs = Vec::with_capacity(k);
    for _ in 0..k {
        let l = TcpListener::bind("127.0.0.1:0")?;
        addrs.push(l.local_addr()?.to_string());
        listeners.push(l);
    }
    let mut handles = Vec::with_capacity(k);
    for (id, listener) in listeners.into_iter().enumerate() {
        let addrs = addrs.clone();
        let stats = Arc::clone(&stats);
        handles.push(std::thread::spawn(move || {
            mesh_with_listener(listener, id, &addrs, Duration::from_secs(10), stats)
        }));
    }
    let mut endpoints = Vec::with_capacity(k);
    for h in handles {
        endpoints.push(h.join().expect("mesh thread panicked")?);
    }
    Ok((endpoints, stats))
}

/// [`crate::coordinator::run_distributed`], but over a real loopback
/// TCP mesh — same options, same deterministic result.
pub fn run_distributed_tcp_local(
    graph: Arc<Graph>,
    machines: &MachineConfig,
    initial: Partition,
    options: &DistributedOptions,
) -> Result<DistributedReport, WireError> {
    let (endpoints, stats) = build_tcp_bus_local(machines.count())?;
    Ok(run_over_endpoints(endpoints, graph, machines, initial, options, stats))
}

// ---------------------------------------------------------------------
// Multi-process cluster: leader + serve
// ---------------------------------------------------------------------

/// How long a worker waits for the next `EpochBegin` — the leader
/// simulates a whole epoch in between, so this is generous.
const EPOCH_WAIT: Duration = Duration::from_secs(600);

/// Machine 0's handle on a multi-process cluster: owns the leader
/// endpoint and runs one refinement round per [`ClusterLeader::refine`]
/// call, aggregating the workers' overhead reports.
pub struct ClusterLeader {
    ep: TcpEndpoint,
    opts: DistributedOptions,
    epoch: u64,
}

impl ClusterLeader {
    /// Join the mesh as machine 0 and wait for every worker.
    pub fn connect(
        addrs: &[String],
        opts: DistributedOptions,
        connect_timeout: Duration,
    ) -> Result<ClusterLeader, WireError> {
        let stats = Arc::new(Mutex::new(OverheadStats::default()));
        let ep = connect_mesh(0, addrs, connect_timeout, stats)?;
        Ok(ClusterLeader { ep, opts, epoch: 0 })
    }

    pub fn machine_count(&self) -> usize {
        self.ep.machine_count()
    }

    /// Control-plane accounting so far (handshake/setup/epoch frames).
    pub fn net_stats(&self) -> NetStats {
        self.ep.net_snapshot()
    }

    /// Broadcast the shared fixture. Must be called once, before the
    /// first [`ClusterLeader::refine`].
    pub fn setup(&self, graph: &Graph, machines: &MachineConfig) -> Result<(), WireError> {
        if machines.count() != self.ep.machine_count() {
            return Err(WireError::Protocol(format!(
                "cluster has {} machines but the fixture wants {}",
                self.ep.machine_count(),
                machines.count()
            )));
        }
        self.ep.broadcast_ctrl(&Frame::Setup(SetupFrame {
            speeds: machines.speeds().to_vec(),
            mu: self.opts.mu,
            framework: self.opts.framework,
            migration_charge: self.opts.migration_charge,
            epsilon: self.opts.epsilon,
            max_transfers: self.opts.max_transfers as u64,
            recv_timeout_ms: self.opts.recv_timeout.as_millis() as u64,
            node_weights: graph.node_weights().to_vec(),
            edges: graph.edges().map(|(u, v, w)| (u as u32, v as u32, w)).collect(),
        }))
    }

    /// Run one refinement round across the cluster: re-sync weights and
    /// the warm-start assignment, play machine 0's part of the ring,
    /// then collect every worker's overhead report (the epoch barrier).
    pub fn refine(
        &mut self,
        graph: &Graph,
        machines: &MachineConfig,
        initial: Partition,
    ) -> Result<DistributedReport, WireError> {
        let k = self.ep.machine_count();
        let epoch = self.epoch;
        self.epoch += 1;
        self.ep.broadcast_ctrl(&Frame::EpochBegin(EpochFrame {
            epoch,
            node_weights: graph.node_weights().to_vec(),
            edge_weights: graph.edges().map(|(_, _, w)| w).collect(),
            assignment: initial.assignment().iter().map(|&m| m as u32).collect(),
        }))?;

        let before = self.ep.stats_snapshot();
        let actor = MachineActor::new(
            0,
            Arc::new(graph.clone()),
            machines.clone(),
            &initial,
            self.opts.mu,
            self.opts.framework,
            self.opts.migration_charge,
        );
        self.ep.send(0, Message::TakeMyTurn { consecutive_forfeits: 0, transfers_so_far: 0 });
        let outcome =
            machine_loop(actor, &self.ep, self.opts.epsilon, self.opts.max_transfers, self.opts.recv_timeout);
        if outcome.timed_out {
            return Err(WireError::Protocol(
                "refinement round timed out waiting on a peer".into(),
            ));
        }

        // Barrier: one RoundStats per worker closes the round.
        let mut overhead = self.ep.stats_snapshot().delta_since(&before);
        let mut seen = vec![false; k];
        seen[0] = true;
        let mut remaining = k - 1;
        while remaining > 0 {
            match self.ep.recv_ctrl(self.opts.recv_timeout)? {
                (peer, Frame::RoundStats(s)) if !seen[peer] => {
                    seen[peer] = true;
                    overhead.add(&s);
                    remaining -= 1;
                }
                (peer, frame) => {
                    return Err(WireError::Protocol(format!(
                        "unexpected control frame from machine {peer} during barrier: {frame:?}"
                    )));
                }
            }
        }

        // Every transfer reaches every replica, so the leader's applied
        // count *is* the global transfer total.
        let partition = Partition::from_assignment(graph, k, outcome.assignment);
        Ok(DistributedReport {
            partition,
            transfers: outcome.transfers_applied as usize,
            overhead,
            converged: outcome.converged,
            timed_out: false,
        })
    }

    /// Graceful shutdown: tell every worker the run is over.
    pub fn shutdown(self) -> Result<(), WireError> {
        self.ep.broadcast_ctrl(&Frame::Goodbye)
    }
}

/// What a worker did over its lifetime (printed by `gtip serve`).
#[derive(Debug, Clone)]
pub struct ServeSummary {
    pub machine_id: MachineId,
    pub epochs: u64,
    pub overhead: OverheadStats,
    pub control: NetStats,
}

/// Run machine `machine_id`'s side of the multi-process cluster: join
/// the mesh, receive the fixture, then play one refinement round per
/// `EpochBegin` until `Goodbye`. This is the body of `gtip serve`.
pub fn serve(
    machine_id: MachineId,
    addrs: &[String],
    connect_timeout: Duration,
) -> Result<ServeSummary, WireError> {
    if machine_id == 0 {
        return Err(WireError::Protocol(
            "machine 0 is the driver; run `gtip dynamic --transport tcp` instead of serve".into(),
        ));
    }
    if machine_id >= addrs.len() {
        return Err(WireError::Protocol(format!(
            "--machine-id {machine_id} out of range for {} peers",
            addrs.len()
        )));
    }
    let stats = Arc::new(Mutex::new(OverheadStats::default()));
    let ep = connect_mesh(machine_id, addrs, connect_timeout, Arc::clone(&stats))?;
    let k = addrs.len();

    // Fixture first.
    let setup = match ep.recv_ctrl(EPOCH_WAIT)? {
        (0, Frame::Setup(s)) => s,
        (0, Frame::Goodbye) => {
            return Ok(ServeSummary {
                machine_id,
                epochs: 0,
                overhead: ep.stats_snapshot(),
                control: ep.net_snapshot(),
            })
        }
        (peer, frame) => {
            return Err(WireError::Protocol(format!(
                "expected Setup from the leader, got {frame:?} from machine {peer}"
            )))
        }
    };
    if setup.speeds.len() != k {
        return Err(WireError::Protocol(format!(
            "fixture has {} machines but the mesh has {k}",
            setup.speeds.len()
        )));
    }
    // Validate before handing anything to constructors that assert —
    // a buggy or skewed leader must produce a clean protocol error,
    // not abort the worker process.
    let speed_sum: f64 = setup.speeds.iter().sum();
    if setup.speeds.iter().any(|&s| !(s > 0.0)) || (speed_sum - 1.0).abs() > 1e-6 {
        return Err(WireError::Protocol(format!(
            "fixture speeds are not normalized positive weights (sum {speed_sum})"
        )));
    }
    let n = setup.node_weights.len();
    if let Some(&(u, v, _)) = setup
        .edges
        .iter()
        .find(|&&(u, v, _)| u as usize >= n || v as usize >= n || u == v)
    {
        return Err(WireError::Protocol(format!(
            "fixture edge ({u}, {v}) is out of range for {n} nodes"
        )));
    }
    if !weights_valid(&setup.node_weights)
        || !weights_valid_iter(setup.edges.iter().map(|&(_, _, w)| w))
    {
        return Err(WireError::Protocol(
            "fixture weights must be finite and non-negative".into(),
        ));
    }
    if !(setup.migration_charge.is_finite() && setup.migration_charge >= 0.0) {
        return Err(WireError::Protocol(format!(
            "fixture migration charge {} must be finite and non-negative",
            setup.migration_charge
        )));
    }
    // Adopt the leader's normalized speeds verbatim — renormalizing
    // here could drift each weight by an ulp and diverge the replicas.
    let machines = MachineConfig::from_normalized(setup.speeds.clone());
    let mut builder = GraphBuilder::with_nodes(n);
    for &(u, v, w) in &setup.edges {
        builder.add_edge(u as usize, v as usize, w);
    }
    for (i, &w) in setup.node_weights.iter().enumerate() {
        builder.set_node_weight(i, w);
    }
    let mut graph = builder.build();
    // Edge order of the built graph — per-epoch weights arrive in the
    // leader's edge order, which matches because both graphs share the
    // same topology.
    let edge_order: Vec<(usize, usize)> = graph.edges().map(|(u, v, _)| (u, v)).collect();
    if edge_order.len() != setup.edges.len() {
        return Err(WireError::Protocol("fixture edge list had duplicates".into()));
    }
    let recv_timeout = Duration::from_millis(setup.recv_timeout_ms.max(1));
    let mut epochs = 0u64;

    loop {
        match ep.recv_ctrl(EPOCH_WAIT)? {
            (0, Frame::EpochBegin(e)) => {
                if e.node_weights.len() != n || e.edge_weights.len() != edge_order.len() {
                    return Err(WireError::Protocol(format!(
                        "epoch {} weight vectors do not match the fixture shape",
                        e.epoch
                    )));
                }
                if e.assignment.len() != n {
                    return Err(WireError::Protocol(format!(
                        "epoch {} assignment length {} != {n}",
                        e.epoch,
                        e.assignment.len()
                    )));
                }
                if !weights_valid(&e.node_weights) || !weights_valid(&e.edge_weights) {
                    return Err(WireError::Protocol(format!(
                        "epoch {} weights must be finite and non-negative",
                        e.epoch
                    )));
                }
                graph.set_node_weights(&e.node_weights);
                for (&(u, v), &w) in edge_order.iter().zip(&e.edge_weights) {
                    graph.set_edge_weight(u, v, w);
                }
                let assignment: Vec<MachineId> =
                    e.assignment.iter().map(|&a| a as MachineId).collect();
                if let Some(&bad) = assignment.iter().find(|&&a| a >= k) {
                    return Err(WireError::Protocol(format!(
                        "epoch {} assignment names machine {bad} but K={k}",
                        e.epoch
                    )));
                }
                let part = Partition::from_assignment(&graph, k, assignment);
                let before = ep.stats_snapshot();
                let actor = MachineActor::new(
                    machine_id,
                    Arc::new(graph.clone()),
                    machines.clone(),
                    &part,
                    setup.mu,
                    setup.framework,
                    setup.migration_charge,
                );
                let outcome = machine_loop(
                    actor,
                    &ep,
                    setup.epsilon,
                    setup.max_transfers as usize,
                    recv_timeout,
                );
                if outcome.timed_out {
                    return Err(WireError::Protocol(format!(
                        "epoch {}: refinement round timed out waiting on a peer",
                        e.epoch
                    )));
                }
                let delta = ep.stats_snapshot().delta_since(&before);
                ep.send_ctrl(0, &Frame::RoundStats(delta))?;
                epochs += 1;
            }
            (0, Frame::Goodbye) => break,
            (peer, frame) => {
                return Err(WireError::Protocol(format!(
                    "unexpected control frame from machine {peer}: {frame:?}"
                )))
            }
        }
    }
    Ok(ServeSummary {
        machine_id,
        epochs,
        overhead: ep.stats_snapshot(),
        control: ep.net_snapshot(),
    })
}

/// Weights arriving off the wire must be finite and non-negative —
/// the graph constructors assert exactly that, and a worker must turn
/// a bad leader into a protocol error, not an abort.
fn weights_valid(ws: &[f64]) -> bool {
    weights_valid_iter(ws.iter().copied())
}

fn weights_valid_iter(mut ws: impl Iterator<Item = f64>) -> bool {
    ws.all(|w| w.is_finite() && w >= 0.0)
}

/// Parse a `host:port,host:port,...` peers list (shared by the
/// `serve` and `dynamic --transport tcp` CLI paths).
pub fn parse_peers(spec: &str) -> Result<Vec<String>, WireError> {
    let peers: Vec<String> =
        spec.split(',').map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect();
    if peers.len() < 2 {
        return Err(WireError::Protocol(format!(
            "--peers needs at least 2 comma-separated host:port entries, got {spec:?}"
        )));
    }
    let mut seen = BTreeMap::new();
    for (i, p) in peers.iter().enumerate() {
        if !p.contains(':') {
            return Err(WireError::Protocol(format!("peer {p:?} is not host:port")));
        }
        if let Some(first) = seen.insert(p.clone(), i) {
            return Err(WireError::Protocol(format!(
                "peer {p:?} listed twice (positions {first} and {i})"
            )));
        }
    }
    Ok(peers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::distributed::run_distributed;
    use crate::graph::generators::{table1_graph, WeightModel};
    use crate::util::rng::Pcg32;

    fn all_message_shapes() -> Vec<Message> {
        vec![
            Message::TakeMyTurn { consecutive_forfeits: 3, transfers_so_far: 17 },
            Message::ReceiveNode { seq: 9, node: 1234, from: 2, to: 0 },
            Message::RegularUpdate {
                seq: 10,
                node: 7,
                from: 1,
                to: 3,
                loads: vec![0.25, -1.5, 3.75, f64::MAX, 0.0],
            },
            Message::Shutdown { total_transfers: 42, converged: true },
            Message::Shutdown { total_transfers: 7, converged: false },
        ]
    }

    #[test]
    fn message_round_trip_and_exact_sizes() {
        for msg in all_message_shapes() {
            let bytes = encode_frame(&Frame::Msg(msg.clone()));
            assert_eq!(bytes.len(), msg.wire_bytes(), "{}", msg.tag());
            let decoded = decode_payload(&bytes[4..]).unwrap();
            assert_eq!(decoded, Frame::Msg(msg));
        }
    }

    #[test]
    fn control_frames_round_trip() {
        let frames = vec![
            Frame::Hello { version: WIRE_VERSION, machine: 2, machines: 5 },
            Frame::Setup(SetupFrame {
                speeds: vec![0.25, 0.75],
                mu: 8.0,
                framework: Framework::B,
                migration_charge: 3.25,
                epsilon: 1e-9,
                max_transfers: 1_000_000,
                recv_timeout_ms: 30_000,
                node_weights: vec![1.0, 2.0, 3.0],
                edges: vec![(0, 1, 1.5), (1, 2, 2.5)],
            }),
            Frame::EpochBegin(EpochFrame {
                epoch: 4,
                node_weights: vec![0.5; 3],
                edge_weights: vec![1.0, 2.0],
                assignment: vec![0, 1, 0],
            }),
            Frame::RoundStats(OverheadStats {
                take_my_turn: Counter { messages: 5, bytes: 105 },
                ..Default::default()
            }),
            Frame::Goodbye,
        ];
        for f in frames {
            let bytes = encode_frame(&f);
            assert_eq!(decode_payload(&bytes[4..]).unwrap(), f);
        }
    }

    #[test]
    fn truncated_frames_error_cleanly() {
        for msg in all_message_shapes() {
            let bytes = encode_frame(&Frame::Msg(msg));
            // Every strict prefix of the payload must fail without
            // panicking.
            for cut in 0..bytes.len() - 4 {
                assert!(
                    decode_payload(&bytes[4..4 + cut]).is_err(),
                    "prefix of {cut} bytes decoded"
                );
            }
        }
    }

    #[test]
    fn trailing_garbage_rejected() {
        let mut bytes = encode_frame(&Frame::Goodbye);
        bytes.push(0xFF);
        assert!(matches!(
            decode_payload(&bytes[4..]),
            Err(WireError::TrailingBytes { extra: 1 })
        ));
    }

    #[test]
    fn bad_tag_and_oversized_rejected() {
        assert!(matches!(decode_payload(&[0xEE]), Err(WireError::BadTag(0xEE))));
        // Oversized length prefix rejected before allocation.
        let mut stream = Vec::new();
        put_u32(&mut stream, (MAX_FRAME_BYTES + 1) as u32);
        let mut cursor = &stream[..];
        assert!(matches!(read_frame(&mut cursor), Err(WireError::Oversized { .. })));
    }

    #[test]
    fn lying_vector_length_is_truncation_not_panic() {
        // RegularUpdate claiming 1000 loads but carrying none.
        let mut payload = vec![TAG_REGULAR_UPDATE];
        put_u64(&mut payload, 0);
        put_u64(&mut payload, 1);
        put_u32(&mut payload, 0);
        put_u32(&mut payload, 1);
        put_u32(&mut payload, 1000);
        assert!(matches!(decode_payload(&payload), Err(WireError::Truncated { .. })));
    }

    #[test]
    fn handshake_version_and_magic_enforced() {
        let mut payload = vec![TAG_HELLO];
        payload.extend_from_slice(b"NOPE");
        put_u16(&mut payload, WIRE_VERSION);
        put_u32(&mut payload, 0);
        put_u32(&mut payload, 2);
        assert!(matches!(decode_payload(&payload), Err(WireError::BadMagic)));

        let mut payload = vec![TAG_HELLO];
        payload.extend_from_slice(&WIRE_MAGIC);
        put_u16(&mut payload, WIRE_VERSION + 1);
        put_u32(&mut payload, 0);
        put_u32(&mut payload, 2);
        assert!(matches!(decode_payload(&payload), Err(WireError::BadVersion { .. })));
    }

    #[test]
    fn parse_peers_validates() {
        let ok = parse_peers("127.0.0.1:7000, 127.0.0.1:7001,127.0.0.1:7002").unwrap();
        assert_eq!(ok.len(), 3);
        assert!(parse_peers("127.0.0.1:7000").is_err());
        assert!(parse_peers("localhost,also-no-port").is_err());
        assert!(parse_peers("h:1,h:1").is_err());
    }

    #[test]
    fn tcp_loopback_mesh_delivers_and_counts_exact_bytes() {
        let (eps, stats) = build_tcp_bus_local(3).unwrap();
        let msg = Message::RegularUpdate { seq: 0, node: 5, from: 0, to: 2, loads: vec![1.0; 3] };
        eps[0].send(1, msg.clone());
        match eps[1].recv_timeout(Duration::from_secs(5)) {
            RecvOutcome::Msg(got) => assert_eq!(got, msg),
            other => panic!("no delivery: {other:?}"),
        }
        let s = stats.lock().unwrap();
        assert_eq!(s.regular_update.messages, 1);
        assert_eq!(s.regular_update.bytes, msg.wire_bytes() as u64);
    }

    #[test]
    fn tcp_local_refinement_matches_in_process_exactly() {
        let mut rng = Pcg32::new(8);
        let g = Arc::new(table1_graph(50, 3, 6, WeightModel::default(), &mut rng));
        let machines = MachineConfig::from_speeds(&[0.2, 0.3, 0.5]);
        let assignment: Vec<usize> = (0..50).map(|_| rng.index(3)).collect();
        let part = Partition::from_assignment(&g, 3, assignment);
        let opts = DistributedOptions::default();

        let inproc = run_distributed(Arc::clone(&g), &machines, part.clone(), &opts);
        let tcp = run_distributed_tcp_local(Arc::clone(&g), &machines, part, &opts).unwrap();
        assert_eq!(tcp.partition.assignment(), inproc.partition.assignment());
        assert_eq!(tcp.transfers, inproc.transfers);
        assert_eq!(tcp.overhead, inproc.overhead, "wire accounting must be transport-invariant");
        assert_eq!(tcp.converged, inproc.converged);
    }

    /// The migration charge is transport-invariant too: a nonzero
    /// charge over real sockets reproduces the in-process augmented
    /// game bit-for-bit (assignment, transfers, wire accounting).
    #[test]
    fn charged_tcp_matches_in_process_exactly() {
        let mut rng = Pcg32::new(12);
        let g = Arc::new(table1_graph(50, 3, 6, WeightModel::default(), &mut rng));
        let machines = MachineConfig::from_speeds(&[0.2, 0.3, 0.5]);
        let assignment: Vec<usize> = (0..50).map(|_| rng.index(3)).collect();
        let part = Partition::from_assignment(&g, 3, assignment);
        let opts = DistributedOptions { migration_charge: 4.0, ..Default::default() };

        let inproc = run_distributed(Arc::clone(&g), &machines, part.clone(), &opts);
        let tcp = run_distributed_tcp_local(Arc::clone(&g), &machines, part, &opts).unwrap();
        assert_eq!(tcp.partition.assignment(), inproc.partition.assignment());
        assert_eq!(tcp.transfers, inproc.transfers);
        assert_eq!(tcp.overhead, inproc.overhead);
        assert!(tcp.converged && inproc.converged);
    }
}

//! Real network transport for the distributed coordinator: a std-only,
//! length-prefixed binary wire codec for [`Message`] (plus the control
//! frames of the multi-process epoch protocol), a [`TcpEndpoint`]
//! implementing [`Bus`] over a full mesh of loopback-or-LAN sockets,
//! deterministic machine-id handshakes with retry/backoff dialing, and
//! the leader/worker pair ([`ClusterLeader`] / [`serve`]) that lets
//! `gtip dynamic --transport tcp` drive refinement rounds across real
//! OS processes.
//!
//! ## Frame layout
//!
//! Every frame is `u32 LE payload length || payload`; the payload is a
//! 1-byte tag followed by fixed-width little-endian fields (`u64`
//! counts, `u32` machine ids, IEEE-754 `f64` loads; vectors are a `u32`
//! length followed by the elements). Tags 1–4 are the Fig. 2 protocol
//! messages — their encoded size is exactly
//! [`Message::wire_bytes`], which both transports feed into
//! [`OverheadStats`], so the measured §4.5 overhead is the true
//! on-the-wire byte count. Tags 16+ are control frames (handshake,
//! epoch setup/begin, per-round stats report, goodbye); control bytes
//! are accounted separately in [`NetStats`] and never touch
//! [`OverheadStats`], keeping the feasibility metric about the game's
//! aggregate-state exchange only.
//!
//! ## Connection lifecycle
//!
//! Machine `i` of K listens on `addrs[i]` and dials every other
//! machine with retry + exponential backoff; each outbound connection
//! opens with a `Hello` frame (`magic || version || machine id ||
//! machine count`), so the acceptor learns deterministically who is on
//! the other end. Each inbound connection gets a reader thread that
//! decodes frames and routes protocol messages to the endpoint's inbox
//! and control frames to its control queue. Shutdown is graceful: the
//! leader broadcasts `Goodbye`, workers exit, sockets close, readers
//! see EOF and stop.
//!
//! ## Epoch barrier
//!
//! One refinement round per `EpochBegin` (which re-syncs graph weights
//! and the warm-start assignment — O(N) control traffic that exists in
//! any measurement-driven deployment and is reported separately from
//! the O(K) game traffic). After a round converges, every worker sends
//! its [`OverheadStats`] delta as `RoundStats`; the leader waits for
//! all K−1 reports before the next epoch, which doubles as the barrier
//! that keeps rounds from interleaving on the wire.
//!
//! ## Failure recovery (wire v3)
//!
//! A worker death no longer unwinds the whole cluster. A timed-out or
//! send-failed round leaves the leader's endpoint intact; the leader
//! then *diagnoses* which peers are dead ([`ClusterLeader::diagnose_dead`]:
//! recorded send failures plus workers that never reported `RoundStats`
//! within a grace period — live workers report their stats even after a
//! timed-out round) and *re-forms* the cluster around the survivors
//! ([`ClusterLeader::recover`]): it compacts its endpoint to the
//! surviving wire ids, broadcasts `Restore` (the survivor list plus
//! renormalized speeds), and waits for a `RestoreAck` from every
//! survivor before the next `EpochBegin` — the ack barrier keeps stale
//! round traffic from interleaving with the restored epoch. Workers
//! renumber themselves by their position in the survivor list (the
//! leader, wire 0, is always logical 0). The simulation itself is
//! restored leader-side from the last epoch-boundary snapshot
//! (`sim::snapshot`, DESIGN.md §10).
//!
//! ## Elastic join (wire v4)
//!
//! Elastic *join* is the same machinery run in reverse. A joining
//! `gtip serve --join` re-binds its original address slot, dials the
//! leader, and sends `Join { machine, speed }`; the leader queues the
//! request and admits it at the **next epoch boundary** — never
//! mid-epoch, because the boundary is where a consistent checkpoint
//! exists. Admission ([`ClusterLeader::admit`]) extends the mesh the
//! way `Restore` shrinks it: the leader dials the joiner back, calls
//! [`TcpEndpoint::extend`] (the inverse of [`TcpEndpoint::compact`] —
//! the joiner re-occupies its immutable wire id, survivors renumber by
//! position in the grown member list), broadcasts `Admit` (members +
//! renormalized speeds), ships the newcomer a full `Setup` plus the
//! epoch-boundary snapshot as a `Catchup` payload, and blocks on an
//! `AdmitAck` from every member. Survivors dial the joiner and accept
//! its return dial before acking; a member that cannot reach the
//! joiner simply withholds its ack, the barrier times out, and the
//! leader rolls the mesh back to the old membership with a `Restore`
//! barrier — the fleet stays at K and the run continues. The
//! refinement game then migrates LPs toward the empty newcomer on the
//! next epoch (Thm 4.1 descends from any feasible start; DESIGN.md
//! §9/§10).
//!
//! Known limitation: diagnosis is evidence-based (send errors + missing
//! stats reports), so a worker that is alive but silent past the grace
//! period is treated as dead and evicted; it exits with a protocol
//! error when its epoch wait (derived from the configured receive
//! timeout, [`epoch_wait`]) expires. The run still completes on the
//! remaining machines, and the evicted worker can re-enter through the
//! join path above.

use std::collections::{BTreeMap, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::bus::{Bus, RecvOutcome};
use crate::coordinator::distributed::{
    machine_loop, machine_loop_scoped, run_hierarchical_over_endpoints, run_over_endpoints,
    DistributedOptions, DistributedReport, RackBus,
};
use crate::coordinator::machine::MachineActor;
use crate::coordinator::protocol::{Counter, Message, OverheadStats};
use crate::game::cost::Framework;
use crate::game::hierarchy::{guarded_map_back, RackLayout};
use crate::graph::{Graph, GraphBuilder};
use crate::partition::{MachineConfig, MachineId, Partition};

/// First bytes of every `Hello` payload after the tag.
pub const WIRE_MAGIC: [u8; 4] = *b"GTIP";
/// Wire protocol version; bumped on any layout change. v2 added the
/// migration charge of the augmented game to `Setup`; v3 added the
/// elastic-membership control frames (`Restore`, `Join`, `RestoreAck`);
/// v4 made `Join` live and added the admission frames (`Admit`,
/// `AdmitAck`, `Catchup`); v5 added the two-level hierarchy (DESIGN.md
/// §12): the `RackUpdate` aggregate message, the phased `EpochBegin`,
/// rack-aware `Setup`/`Join`/`Admit` fields, and `RackResult`. The
/// `Hello` handshake rejects any peer speaking another version, so
/// decoding is version-gated at connection time and a mixed-version
/// cluster can never half-parse a frame.
pub const WIRE_VERSION: u16 = 5;
/// Upper bound on a single frame payload; larger prefixes are rejected
/// before any allocation happens.
pub const MAX_FRAME_BYTES: usize = 1 << 24;

/// Message tags (1–5 mirror [`Message`]; 16+ are control frames).
const TAG_TAKE_MY_TURN: u8 = 1;
const TAG_RECEIVE_NODE: u8 = 2;
const TAG_REGULAR_UPDATE: u8 = 3;
const TAG_SHUTDOWN: u8 = 4;
const TAG_RACK_UPDATE: u8 = 5;
const TAG_HELLO: u8 = 16;
const TAG_SETUP: u8 = 17;
const TAG_EPOCH_BEGIN: u8 = 18;
const TAG_ROUND_STATS: u8 = 19;
const TAG_GOODBYE: u8 = 20;
const TAG_RESTORE: u8 = 21;
const TAG_JOIN: u8 = 22;
const TAG_RESTORE_ACK: u8 = 23;
const TAG_ADMIT: u8 = 24;
const TAG_ADMIT_ACK: u8 = 25;
const TAG_CATCHUP: u8 = 26;
const TAG_RACK_RESULT: u8 = 27;

/// Errors of the wire codec and connection lifecycle.
#[derive(Debug)]
pub enum WireError {
    /// Frame payload ended before the advertised fields.
    Truncated { needed: usize, got: usize },
    /// Decoded fields left unconsumed payload bytes behind.
    TrailingBytes { extra: usize },
    /// Length prefix exceeds [`MAX_FRAME_BYTES`].
    Oversized { len: usize },
    /// Unknown frame tag.
    BadTag(u8),
    /// Handshake did not start with [`WIRE_MAGIC`].
    BadMagic,
    /// Peer speaks a different [`WIRE_VERSION`].
    BadVersion { theirs: u16 },
    /// The socket closed mid-stream.
    Closed,
    /// Underlying socket error.
    Io(String),
    /// The peer violated the epoch protocol.
    Protocol(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { needed, got } => {
                write!(f, "truncated frame: needed {needed} bytes, got {got}")
            }
            WireError::TrailingBytes { extra } => {
                write!(f, "malformed frame: {extra} unconsumed trailing bytes")
            }
            WireError::Oversized { len } => {
                write!(f, "oversized frame: {len} bytes > max {MAX_FRAME_BYTES}")
            }
            WireError::BadTag(t) => write!(f, "unknown frame tag {t}"),
            WireError::BadMagic => write!(f, "bad handshake magic (not a gtip peer?)"),
            WireError::BadVersion { theirs } => {
                write!(f, "wire version mismatch: peer {theirs}, ours {WIRE_VERSION}")
            }
            WireError::Closed => write!(f, "connection closed"),
            WireError::Io(e) => write!(f, "socket error: {e}"),
            WireError::Protocol(e) => write!(f, "protocol violation: {e}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            WireError::Closed
        } else {
            WireError::Io(e.to_string())
        }
    }
}

/// Control frames + protocol messages — everything that crosses a
/// socket.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// A Fig. 2 protocol message (the only frames [`OverheadStats`]
    /// counts).
    Msg(Message),
    /// Connection handshake: who is dialing, and how big they think the
    /// cluster is.
    Hello { version: u16, machine: u32, machines: u32 },
    /// Leader → workers, once: the shared fixture (machine speeds, game
    /// options, graph topology + weights).
    Setup(SetupFrame),
    /// Leader → workers, per refinement round: fresh measured weights
    /// and the warm-start assignment.
    EpochBegin(EpochFrame),
    /// Worker → leader after each round: the worker's [`OverheadStats`]
    /// delta for that round (the leader aggregates them; waiting for
    /// all K−1 doubles as the epoch barrier).
    RoundStats(OverheadStats),
    /// Leader → workers: the run is over; exit cleanly.
    Goodbye,
    /// Leader → survivors after a worker death (wire v3): re-form the
    /// cluster. `survivors` lists the surviving *wire* ids of the
    /// original mesh in ascending order (always including 0, the
    /// leader); each survivor's new logical id is its position in the
    /// list. `speeds` are the renormalized relative speeds in that new
    /// order. A worker not on the list has been evicted — it will
    /// never receive this frame (the leader compacts first), and times
    /// out on its own.
    Restore { survivors: Vec<u32>, speeds: Vec<f64> },
    /// Joiner → leader (wire v4): announce this machine (its immutable
    /// wire id) and its relative speed, asking to be admitted at the
    /// next epoch boundary. `speed` is relative to the current fleet's
    /// average machine — 1.0 means "as fast as a typical member".
    /// `rack` (wire v5) is the rack the joiner wants to land in;
    /// `u32::MAX` means "leader's choice" (the emptiest rack), and the
    /// value is ignored entirely on a flat cluster.
    Join { machine: u32, speed: f64, rack: u32 },
    /// Survivor → leader (wire v3): compaction applied, ready for the
    /// next epoch. `machine` echoes the sender's original wire id so
    /// the leader can cross-check its survivor bookkeeping.
    RestoreAck { machine: u32 },
    /// Leader → everyone at an admission (wire v4): grow the mesh back
    /// around `members` — the new member *wire* ids, ascending, always
    /// including 0 (the leader) and `joiner`. Each member's new
    /// logical id is its position in the list; `speeds` are the
    /// renormalized relative speeds in that order. The exact mirror of
    /// [`Frame::Restore`], which shrinks the same list. `rack` (wire
    /// v5) is the rack the joiner lands in — already resolved by the
    /// leader, never `u32::MAX`; 0 (and ignored) on a flat cluster.
    Admit { members: Vec<u32>, joiner: u32, speeds: Vec<f64>, rack: u32 },
    /// Member → leader (wire v4): mesh extension applied (the member
    /// dialed the joiner and accepted its return dial), ready for the
    /// next epoch. `machine` echoes the sender's wire id, like
    /// [`Frame::RestoreAck`].
    AdmitAck { machine: u32 },
    /// Leader → joiner, once per admission (wire v4): the encoded
    /// epoch-boundary [`crate::sim::Snapshot`] the run is at, so the
    /// newcomer can cross-check the fixture it was shipped in `Setup`
    /// against the exact state the cluster resumes from.
    Catchup { snapshot: Vec<u8> },
    /// Rack leader → cluster leader after an inner (phase-2) round
    /// (wire v5): the rack's scoped-ring outcome. `assignment` lists
    /// `(node, machine)` for every node the rack owned at phase start —
    /// cross-rack traffic never flows in phase 2, so only the owning
    /// rack knows where its nodes ended up. The leader of the rack
    /// containing machine 0 never sends this; the cluster leader played
    /// that ring itself.
    RackResult { rack: u32, transfers: u64, converged: bool, assignment: Vec<(u32, u32)> },
}

/// Payload of [`Frame::Setup`].
#[derive(Debug, Clone, PartialEq)]
pub struct SetupFrame {
    pub speeds: Vec<f64>,
    pub mu: f64,
    pub framework: Framework,
    /// Per-move migration surcharge of the augmented game (DESIGN.md
    /// §9). Workers must price moves with exactly the leader's charge
    /// or replicas pick different transfers (wire v2).
    pub migration_charge: f64,
    pub epsilon: f64,
    pub max_transfers: u64,
    pub recv_timeout_ms: u64,
    pub node_weights: Vec<f64>,
    /// `(u, v, weight)` for every edge, in the leader graph's edge
    /// order (workers re-install per-epoch weights in this order).
    pub edges: Vec<(u32, u32, f64)>,
    /// Machine → rack map for the two-level hierarchy (wire v5), one
    /// entry per machine; empty means a flat (single-level) cluster.
    pub racks: Vec<u32>,
}

/// Payload of [`Frame::EpochBegin`].
#[derive(Debug, Clone, PartialEq)]
pub struct EpochFrame {
    pub epoch: u64,
    /// Which level this round plays (wire v5): 0 = flat (single-level),
    /// 1 = the outer rack-quotient game (rack leaders only), 2 = the
    /// inner per-rack scoped rings. A hierarchical epoch is one
    /// phase-1 round followed by one phase-2 round under the same
    /// `epoch` number.
    pub phase: u8,
    pub node_weights: Vec<f64>,
    /// One weight per edge, in [`SetupFrame::edges`] order.
    pub edge_weights: Vec<f64>,
    pub assignment: Vec<u32>,
}

// ---------------------------------------------------------------------
// Codec
// ---------------------------------------------------------------------

fn put_u16(b: &mut Vec<u8>, v: u16) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(b: &mut Vec<u8>, v: f64) {
    b.extend_from_slice(&v.to_le_bytes());
}

/// Checked narrowing for ids and lengths crossing the wire. A graph,
/// cluster, or vector beyond the u32 wire range must fail loudly at
/// encode time — an unchecked `as u32` would silently truncate into a
/// wrong-but-plausible frame the peer happily applies.
fn wire_u32(v: usize) -> Result<u32, WireError> {
    u32::try_from(v).map_err(|_| WireError::Protocol(format!("{v} exceeds the u32 wire range")))
}

fn put_f64s(b: &mut Vec<u8>, vs: &[f64]) -> Result<(), WireError> {
    put_u32(b, wire_u32(vs.len())?);
    for &v in vs {
        put_f64(b, v);
    }
    Ok(())
}

/// Bounded reader over a frame payload; every accessor fails with
/// [`WireError::Truncated`] instead of panicking on short input.
struct Dec<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(b: &'a [u8]) -> Self {
        Dec { b, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.pos + n > self.b.len() {
            return Err(WireError::Truncated { needed: self.pos + n, got: self.b.len() });
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().expect("len 2")))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("len 4")))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("len 8")))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().expect("len 8")))
    }

    /// Length-prefixed f64 vector; the length is validated against the
    /// remaining payload before any allocation.
    fn f64s(&mut self) -> Result<Vec<f64>, WireError> {
        let len = self.u32()? as usize;
        if self.pos + 8 * len > self.b.len() {
            return Err(WireError::Truncated { needed: self.pos + 8 * len, got: self.b.len() });
        }
        (0..len).map(|_| self.f64()).collect()
    }

    fn finish(self) -> Result<(), WireError> {
        if self.pos != self.b.len() {
            return Err(WireError::TrailingBytes { extra: self.b.len() - self.pos });
        }
        Ok(())
    }
}

fn encode_payload(frame: &Frame, b: &mut Vec<u8>) -> Result<(), WireError> {
    match frame {
        Frame::Msg(Message::TakeMyTurn { consecutive_forfeits, transfers_so_far }) => {
            b.push(TAG_TAKE_MY_TURN);
            put_u64(b, *consecutive_forfeits as u64);
            put_u64(b, *transfers_so_far as u64);
        }
        Frame::Msg(Message::ReceiveNode { seq, node, from, to }) => {
            b.push(TAG_RECEIVE_NODE);
            put_u64(b, *seq);
            put_u64(b, *node as u64);
            put_u32(b, wire_u32(*from)?);
            put_u32(b, wire_u32(*to)?);
        }
        Frame::Msg(Message::RegularUpdate { seq, node, from, to, loads }) => {
            b.push(TAG_REGULAR_UPDATE);
            put_u64(b, *seq);
            put_u64(b, *node as u64);
            put_u32(b, wire_u32(*from)?);
            put_u32(b, wire_u32(*to)?);
            put_f64s(b, loads)?;
        }
        Frame::Msg(Message::RackUpdate { seq, node, from, to, rack_loads }) => {
            b.push(TAG_RACK_UPDATE);
            put_u64(b, *seq);
            put_u64(b, *node as u64);
            put_u32(b, wire_u32(*from)?);
            put_u32(b, wire_u32(*to)?);
            put_f64s(b, rack_loads)?;
        }
        Frame::Msg(Message::Shutdown { total_transfers, converged }) => {
            b.push(TAG_SHUTDOWN);
            put_u64(b, *total_transfers);
            b.push(u8::from(*converged));
        }
        Frame::Hello { version, machine, machines } => {
            b.push(TAG_HELLO);
            b.extend_from_slice(&WIRE_MAGIC);
            put_u16(b, *version);
            put_u32(b, *machine);
            put_u32(b, *machines);
        }
        Frame::Setup(s) => {
            b.push(TAG_SETUP);
            put_f64s(b, &s.speeds)?;
            put_f64(b, s.mu);
            b.push(match s.framework {
                Framework::A => 0,
                Framework::B => 1,
            });
            put_f64(b, s.migration_charge);
            put_f64(b, s.epsilon);
            put_u64(b, s.max_transfers);
            put_u64(b, s.recv_timeout_ms);
            put_f64s(b, &s.node_weights)?;
            put_u32(b, wire_u32(s.edges.len())?);
            for &(u, v, w) in &s.edges {
                put_u32(b, u);
                put_u32(b, v);
                put_f64(b, w);
            }
            put_u32(b, wire_u32(s.racks.len())?);
            for &r in &s.racks {
                put_u32(b, r);
            }
        }
        Frame::EpochBegin(e) => {
            b.push(TAG_EPOCH_BEGIN);
            put_u64(b, e.epoch);
            b.push(e.phase);
            put_f64s(b, &e.node_weights)?;
            put_f64s(b, &e.edge_weights)?;
            put_u32(b, wire_u32(e.assignment.len())?);
            for &a in &e.assignment {
                put_u32(b, a);
            }
        }
        Frame::RoundStats(s) => {
            b.push(TAG_ROUND_STATS);
            for c in
                [&s.take_my_turn, &s.receive_node, &s.regular_update, &s.rack_update, &s.shutdown]
            {
                put_u64(b, c.messages);
                put_u64(b, c.bytes);
            }
        }
        Frame::Goodbye => b.push(TAG_GOODBYE),
        Frame::Restore { survivors, speeds } => {
            b.push(TAG_RESTORE);
            put_u32(b, wire_u32(survivors.len())?);
            for &s in survivors {
                put_u32(b, s);
            }
            put_f64s(b, speeds)?;
        }
        Frame::Join { machine, speed, rack } => {
            b.push(TAG_JOIN);
            put_u32(b, *machine);
            put_f64(b, *speed);
            put_u32(b, *rack);
        }
        Frame::RestoreAck { machine } => {
            b.push(TAG_RESTORE_ACK);
            put_u32(b, *machine);
        }
        Frame::Admit { members, joiner, speeds, rack } => {
            b.push(TAG_ADMIT);
            put_u32(b, wire_u32(members.len())?);
            for &m in members {
                put_u32(b, m);
            }
            put_u32(b, *joiner);
            put_f64s(b, speeds)?;
            put_u32(b, *rack);
        }
        Frame::AdmitAck { machine } => {
            b.push(TAG_ADMIT_ACK);
            put_u32(b, *machine);
        }
        Frame::Catchup { snapshot } => {
            b.push(TAG_CATCHUP);
            put_u32(b, wire_u32(snapshot.len())?);
            b.extend_from_slice(snapshot);
        }
        Frame::RackResult { rack, transfers, converged, assignment } => {
            b.push(TAG_RACK_RESULT);
            put_u32(b, *rack);
            put_u64(b, *transfers);
            b.push(u8::from(*converged));
            put_u32(b, wire_u32(assignment.len())?);
            for &(node, machine) in assignment {
                put_u32(b, node);
                put_u32(b, machine);
            }
        }
    }
    Ok(())
}

/// Encode a frame as `u32 LE payload length || payload`. Fails (rather
/// than truncating) on ids or lengths beyond the u32 wire range and on
/// payloads over [`MAX_FRAME_BYTES`] — the write-side mirror of the
/// read-side `Oversized` rejection.
pub fn encode_frame(frame: &Frame) -> Result<Vec<u8>, WireError> {
    let mut payload = Vec::with_capacity(64);
    encode_payload(frame, &mut payload)?;
    if payload.len() > MAX_FRAME_BYTES {
        return Err(WireError::Oversized { len: payload.len() });
    }
    let mut out = Vec::with_capacity(4 + payload.len());
    put_u32(&mut out, payload.len() as u32);
    out.extend_from_slice(&payload);
    Ok(out)
}

/// Decode one frame payload (the bytes after the length prefix).
/// Rejects unknown tags, short payloads, and trailing garbage — never
/// panics on malformed input.
pub fn decode_payload(payload: &[u8]) -> Result<Frame, WireError> {
    let mut d = Dec::new(payload);
    let tag = d.u8()?;
    let frame = match tag {
        TAG_TAKE_MY_TURN => Frame::Msg(Message::TakeMyTurn {
            consecutive_forfeits: d.u64()? as usize,
            transfers_so_far: d.u64()? as usize,
        }),
        TAG_RECEIVE_NODE => Frame::Msg(Message::ReceiveNode {
            seq: d.u64()?,
            node: d.u64()? as usize,
            from: d.u32()? as MachineId,
            to: d.u32()? as MachineId,
        }),
        TAG_REGULAR_UPDATE => Frame::Msg(Message::RegularUpdate {
            seq: d.u64()?,
            node: d.u64()? as usize,
            from: d.u32()? as MachineId,
            to: d.u32()? as MachineId,
            loads: d.f64s()?,
        }),
        TAG_RACK_UPDATE => Frame::Msg(Message::RackUpdate {
            seq: d.u64()?,
            node: d.u64()? as usize,
            from: d.u32()? as MachineId,
            to: d.u32()? as MachineId,
            rack_loads: d.f64s()?,
        }),
        TAG_SHUTDOWN => Frame::Msg(Message::Shutdown {
            total_transfers: d.u64()?,
            converged: match d.u8()? {
                0 => false,
                1 => true,
                other => {
                    return Err(WireError::Protocol(format!("bad converged byte {other}")))
                }
            },
        }),
        TAG_HELLO => {
            if d.take(4)? != WIRE_MAGIC {
                return Err(WireError::BadMagic);
            }
            let version = d.u16()?;
            if version != WIRE_VERSION {
                return Err(WireError::BadVersion { theirs: version });
            }
            Frame::Hello { version, machine: d.u32()?, machines: d.u32()? }
        }
        TAG_SETUP => {
            let speeds = d.f64s()?;
            let mu = d.f64()?;
            let framework = match d.u8()? {
                0 => Framework::A,
                1 => Framework::B,
                other => return Err(WireError::Protocol(format!("bad framework byte {other}"))),
            };
            Frame::Setup(SetupFrame {
                speeds,
                mu,
                framework,
                migration_charge: d.f64()?,
                epsilon: d.f64()?,
                max_transfers: d.u64()?,
                recv_timeout_ms: d.u64()?,
                node_weights: d.f64s()?,
                edges: {
                    let len = d.u32()? as usize;
                    let mut edges = Vec::new();
                    for _ in 0..len {
                        edges.push((d.u32()?, d.u32()?, d.f64()?));
                    }
                    edges
                },
                racks: {
                    let len = d.u32()? as usize;
                    if 4 * len > payload.len() {
                        return Err(WireError::Truncated { needed: 4 * len, got: payload.len() });
                    }
                    (0..len).map(|_| d.u32()).collect::<Result<_, _>>()?
                },
            })
        }
        TAG_EPOCH_BEGIN => Frame::EpochBegin(EpochFrame {
            epoch: d.u64()?,
            phase: d.u8()?,
            node_weights: d.f64s()?,
            edge_weights: d.f64s()?,
            assignment: {
                let len = d.u32()? as usize;
                if 4 * len > payload.len() {
                    return Err(WireError::Truncated { needed: 4 * len, got: payload.len() });
                }
                (0..len).map(|_| d.u32()).collect::<Result<_, _>>()?
            },
        }),
        TAG_ROUND_STATS => {
            let mut cs = [Counter::default(); 5];
            for c in cs.iter_mut() {
                c.messages = d.u64()?;
                c.bytes = d.u64()?;
            }
            Frame::RoundStats(OverheadStats {
                take_my_turn: cs[0],
                receive_node: cs[1],
                regular_update: cs[2],
                rack_update: cs[3],
                shutdown: cs[4],
            })
        }
        TAG_GOODBYE => Frame::Goodbye,
        TAG_RESTORE => {
            let len = d.u32()? as usize;
            if 4 * len > payload.len() {
                return Err(WireError::Truncated { needed: 4 * len, got: payload.len() });
            }
            Frame::Restore {
                survivors: (0..len).map(|_| d.u32()).collect::<Result<_, _>>()?,
                speeds: d.f64s()?,
            }
        }
        TAG_JOIN => Frame::Join { machine: d.u32()?, speed: d.f64()?, rack: d.u32()? },
        TAG_RESTORE_ACK => Frame::RestoreAck { machine: d.u32()? },
        TAG_ADMIT => {
            let len = d.u32()? as usize;
            if 4 * len > payload.len() {
                return Err(WireError::Truncated { needed: 4 * len, got: payload.len() });
            }
            Frame::Admit {
                members: (0..len).map(|_| d.u32()).collect::<Result<_, _>>()?,
                joiner: d.u32()?,
                speeds: d.f64s()?,
                rack: d.u32()?,
            }
        }
        TAG_ADMIT_ACK => Frame::AdmitAck { machine: d.u32()? },
        TAG_CATCHUP => {
            let len = d.u32()? as usize;
            if len > payload.len() {
                return Err(WireError::Truncated { needed: len, got: payload.len() });
            }
            Frame::Catchup { snapshot: d.take(len)?.to_vec() }
        }
        TAG_RACK_RESULT => {
            let rack = d.u32()?;
            let transfers = d.u64()?;
            let converged = match d.u8()? {
                0 => false,
                1 => true,
                other => {
                    return Err(WireError::Protocol(format!("bad converged byte {other}")))
                }
            };
            let len = d.u32()? as usize;
            if 8 * len > payload.len() {
                return Err(WireError::Truncated { needed: 8 * len, got: payload.len() });
            }
            Frame::RackResult {
                rack,
                transfers,
                converged,
                assignment: (0..len)
                    .map(|_| Ok((d.u32()?, d.u32()?)))
                    .collect::<Result<_, WireError>>()?,
            }
        }
        other => return Err(WireError::BadTag(other)),
    };
    d.finish()?;
    Ok(frame)
}

/// Read one length-prefixed frame from a stream.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame, WireError> {
    let mut prefix = [0u8; 4];
    r.read_exact(&mut prefix)?;
    let len = u32::from_le_bytes(prefix) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(WireError::Oversized { len });
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    decode_payload(&payload)
}

/// Write one frame to a stream.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> Result<usize, WireError> {
    let bytes = encode_frame(frame)?;
    w.write_all(&bytes)?;
    Ok(bytes.len())
}

/// Recover the guard from a possibly-poisoned mutex. The shared state
/// behind these locks (accounting counters, an outbound socket) stays
/// internally consistent even if a holder panicked mid-update, so one
/// panicking reader/actor thread must degrade to a clean [`WireError`]
/// elsewhere — not cascade `expect("poisoned")` aborts through every
/// thread that touches the same stats handle.
fn lock_unpoisoned<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------------
// TCP endpoint
// ---------------------------------------------------------------------

/// Byte/message accounting of the control plane (handshakes, epoch
/// setup/begin, stats reports) — kept apart from [`OverheadStats`] so
/// the §4.5 metric stays about the game's O(K) state exchange.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NetStats {
    pub control_messages: u64,
    pub control_bytes: u64,
}

/// Send failures recorded at the send site (satellite of the recovery
/// protocol): `map` keeps the first error per logical peer for the
/// leader's death diagnosis, `fresh` queues not-yet-reported peers so
/// the actor loop sees a [`RecvOutcome::SendFailed`] instead of
/// waiting out the full receive timeout.
#[derive(Default)]
struct SendFailures {
    map: BTreeMap<MachineId, String>,
    fresh: VecDeque<MachineId>,
}

/// One machine's socket-backed endpoint: a listener's worth of inbound
/// reader threads feeding an inbox, plus one outbound stream per peer.
///
/// After a [`TcpEndpoint::compact`] (cluster re-formation around the
/// survivors of a worker death) the endpoint distinguishes *wire* ids
/// — the immutable machine numbers of the original mesh, which the
/// reader threads and `outs` slots keep forever — from *logical* ids,
/// the dense `0..k` numbering the refinement protocol runs on. Before
/// any compaction the two coincide.
pub struct TcpEndpoint {
    /// Current logical id (== position of `wire_id` in the survivor
    /// list after a compaction).
    id: MachineId,
    /// Current logical machine count.
    k: usize,
    /// This machine's immutable id in the original mesh.
    wire_id: MachineId,
    /// logical id → wire id (ascending; identity before compaction).
    wire_of: Vec<MachineId>,
    /// wire id → logical id (`None` = evicted peer).
    logical_of: Vec<Option<MachineId>>,
    inbox: Receiver<Message>,
    inbox_tx: Sender<Message>,
    ctrl: Receiver<(MachineId, Frame)>,
    /// Kept so [`TcpEndpoint::extend`] can hand new reader threads the
    /// same control channel the original mesh readers feed.
    ctrl_tx: Sender<(MachineId, Frame)>,
    /// The bound listener (nonblocking), retained past mesh formation
    /// so an admission can accept the joiner's return dial on the same
    /// address the peer list names for this machine.
    listener: TcpListener,
    /// Outbound streams, indexed by *wire* id.
    outs: Vec<Option<Mutex<TcpStream>>>,
    stats: Arc<Mutex<OverheadStats>>,
    net: Arc<Mutex<NetStats>>,
    failures: Mutex<SendFailures>,
}

impl Bus for TcpEndpoint {
    fn id(&self) -> MachineId {
        self.id
    }

    fn machine_count(&self) -> usize {
        self.k
    }

    fn send(&self, to: MachineId, msg: Message) {
        if to == self.id {
            // Loopback without touching the network (the ring kick).
            lock_unpoisoned(&self.stats).record(&msg);
            let _ = self.inbox_tx.send(msg);
            return;
        }
        let bytes = match encode_frame(&Frame::Msg(msg.clone())) {
            Ok(bytes) => bytes,
            Err(e) => {
                self.record_send_failure(to, format!("encoding for machine {to}: {e}"));
                return;
            }
        };
        debug_assert_eq!(bytes.len(), msg.wire_bytes(), "codec vs wire_bytes drift");
        lock_unpoisoned(&self.stats).record(&msg);
        let wire = self.wire_of[to];
        match &self.outs[wire] {
            Some(stream) => {
                // A dead peer must not be silently ignored: record the
                // failure at the send site so the actor loop exits
                // through `SendFailed` and the leader's diagnosis can
                // name the peer, instead of every machine waiting out
                // its receive timeout on a ring that can never close.
                if let Err(e) = lock_unpoisoned(stream).write_all(&bytes) {
                    self.record_send_failure(to, format!("sending to machine {to}: {e}"));
                }
            }
            None => self.record_send_failure(to, format!("no connection to machine {to}")),
        }
    }

    fn recv_timeout(&self, timeout: Duration) -> RecvOutcome {
        if let Some(m) = lock_unpoisoned(&self.failures).fresh.pop_front() {
            return RecvOutcome::SendFailed(m);
        }
        match self.inbox.recv_timeout(timeout) {
            Ok(msg) => RecvOutcome::Msg(msg),
            Err(RecvTimeoutError::Timeout) => RecvOutcome::TimedOut,
            Err(RecvTimeoutError::Disconnected) => RecvOutcome::Disconnected,
        }
    }
}

impl TcpEndpoint {
    /// This machine's immutable id in the original mesh.
    pub fn wire_id(&self) -> MachineId {
        self.wire_id
    }

    /// The wire id behind a current logical id.
    pub fn wire_of(&self, logical: MachineId) -> MachineId {
        self.wire_of[logical]
    }

    fn record_send_failure(&self, to: MachineId, what: String) {
        let mut f = lock_unpoisoned(&self.failures);
        if !f.map.contains_key(&to) {
            f.map.insert(to, what);
            f.fresh.push_back(to);
        }
    }

    /// Drain and return the recorded send failures (logical peer →
    /// first error). Feeds the leader's death diagnosis.
    pub fn take_send_failures(&self) -> BTreeMap<MachineId, String> {
        let mut f = lock_unpoisoned(&self.failures);
        f.fresh.clear();
        std::mem::take(&mut f.map)
    }

    /// Discard buffered protocol messages (stale traffic from an
    /// aborted round). Returns how many were dropped.
    pub fn drain_inbox(&self) -> usize {
        let mut n = 0;
        while self.inbox.try_recv().is_ok() {
            n += 1;
        }
        n
    }

    /// Re-form the endpoint around `survivors_wire` — the surviving
    /// wire ids of the original mesh, ascending, including this
    /// machine. Logical ids become positions in the list; outbound
    /// streams to evicted peers are closed; recorded send failures
    /// (which name old logical ids) are cleared.
    pub fn compact(&mut self, survivors_wire: &[MachineId]) -> Result<(), WireError> {
        if survivors_wire.is_empty() || !survivors_wire.windows(2).all(|w| w[0] < w[1]) {
            return Err(WireError::Protocol(
                "survivor list must be non-empty and strictly ascending".into(),
            ));
        }
        if *survivors_wire.last().expect("non-empty") >= self.logical_of.len() {
            return Err(WireError::Protocol(format!(
                "survivor list names wire id {} but the mesh had {} machines",
                survivors_wire.last().expect("non-empty"),
                self.logical_of.len()
            )));
        }
        let me = survivors_wire.iter().position(|&w| w == self.wire_id).ok_or_else(|| {
            WireError::Protocol(format!(
                "this machine (wire id {}) is missing from the survivor list",
                self.wire_id
            ))
        })?;
        for wire in 0..self.logical_of.len() {
            if !survivors_wire.contains(&wire) {
                self.outs[wire] = None; // closes the socket to the evicted peer
            }
        }
        self.logical_of = vec![None; self.logical_of.len()];
        for (logical, &wire) in survivors_wire.iter().enumerate() {
            self.logical_of[wire] = Some(logical);
        }
        self.wire_of = survivors_wire.to_vec();
        self.k = survivors_wire.len();
        self.id = me;
        let mut f = lock_unpoisoned(&self.failures);
        f.map.clear();
        f.fresh.clear();
        Ok(())
    }

    /// Whether a wire id currently maps to a live logical peer.
    pub fn wire_is_active(&self, wire: MachineId) -> bool {
        self.logical_of.get(wire).copied().flatten().is_some()
    }

    /// Re-form the endpoint around `members_wire` — the new member wire
    /// ids, ascending, including this machine and `joiner` — installing
    /// `out` as the outbound stream to the joiner and spawning a reader
    /// on `inbound`, the joiner's dial to us. The exact mirror of
    /// [`TcpEndpoint::compact`]: logical ids become positions in the
    /// list, and stale send failures are cleared. The joiner must be a
    /// currently-evicted wire id, and the other members must be exactly
    /// the current mesh — an admission only ever grows the fleet by
    /// one.
    pub fn extend(
        &mut self,
        members_wire: &[MachineId],
        joiner: MachineId,
        out: TcpStream,
        inbound: TcpStream,
    ) -> Result<(), WireError> {
        if members_wire.is_empty() || !members_wire.windows(2).all(|w| w[0] < w[1]) {
            return Err(WireError::Protocol(
                "member list must be non-empty and strictly ascending".into(),
            ));
        }
        if *members_wire.last().expect("non-empty") >= self.logical_of.len() {
            return Err(WireError::Protocol(format!(
                "member list names wire id {} but the mesh had {} machines",
                members_wire.last().expect("non-empty"),
                self.logical_of.len()
            )));
        }
        if !members_wire.contains(&joiner) {
            return Err(WireError::Protocol(format!(
                "joiner (wire id {joiner}) is missing from the member list"
            )));
        }
        if self.wire_is_active(joiner) || joiner == self.wire_id {
            return Err(WireError::Protocol(format!(
                "joiner wire id {joiner} is already an active member"
            )));
        }
        let me = members_wire.iter().position(|&w| w == self.wire_id).ok_or_else(|| {
            WireError::Protocol(format!(
                "this machine (wire id {}) is missing from the member list",
                self.wire_id
            ))
        })?;
        let others: Vec<MachineId> =
            members_wire.iter().copied().filter(|&w| w != joiner).collect();
        if others != self.wire_of {
            return Err(WireError::Protocol(format!(
                "member list minus the joiner is {others:?} but the current mesh is {:?}",
                self.wire_of
            )));
        }
        self.outs[joiner] = Some(Mutex::new(out));
        spawn_reader(inbound, joiner, self.inbox_tx.clone(), self.ctrl_tx.clone());
        self.logical_of = vec![None; self.logical_of.len()];
        for (logical, &wire) in members_wire.iter().enumerate() {
            self.logical_of[wire] = Some(logical);
        }
        self.wire_of = members_wire.to_vec();
        self.k = members_wire.len();
        self.id = me;
        let mut f = lock_unpoisoned(&self.failures);
        f.map.clear();
        f.fresh.clear();
        Ok(())
    }

    /// Send a control frame to one peer (logical id). A write failure
    /// is recorded (it is death-diagnosis evidence) as well as
    /// returned.
    pub fn send_ctrl(&self, to: MachineId, frame: &Frame) -> Result<(), WireError> {
        let wire = self.wire_of[to];
        let stream = match self.outs[wire].as_ref() {
            Some(stream) => stream,
            None => {
                self.record_send_failure(to, format!("no connection to machine {to}"));
                return Err(WireError::Protocol(format!("no connection to machine {to}")));
            }
        };
        let bytes = encode_frame(frame)?;
        if let Err(e) = lock_unpoisoned(stream).write_all(&bytes) {
            self.record_send_failure(to, format!("sending a control frame to machine {to}: {e}"));
            return Err(e.into());
        }
        let mut net = lock_unpoisoned(&self.net);
        net.control_messages += 1;
        net.control_bytes += bytes.len() as u64;
        Ok(())
    }

    /// Send a control frame to every peer.
    pub fn broadcast_ctrl(&self, frame: &Frame) -> Result<(), WireError> {
        for to in 0..self.k {
            if to != self.id {
                self.send_ctrl(to, frame)?;
            }
        }
        Ok(())
    }

    /// Receive the next control frame (tagged with its sender's
    /// current logical id). Frames from evicted peers are dropped.
    pub fn recv_ctrl(&self, timeout: Duration) -> Result<(MachineId, Frame), WireError> {
        let deadline = Instant::now() + timeout;
        loop {
            let left = deadline.saturating_duration_since(Instant::now());
            match self.ctrl.recv_timeout(left) {
                Ok((wire, frame)) => {
                    match self.logical_of.get(wire).copied().flatten() {
                        Some(logical) => return Ok((logical, frame)),
                        None => continue, // stale frame from an evicted peer
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    return Err(WireError::Protocol(
                        "timed out waiting for a control frame".into(),
                    ))
                }
                Err(RecvTimeoutError::Disconnected) => return Err(WireError::Closed),
            }
        }
    }

    /// Snapshot of the protocol-message accounting.
    pub fn stats_snapshot(&self) -> OverheadStats {
        lock_unpoisoned(&self.stats).clone()
    }

    /// Snapshot of the control-plane accounting.
    pub fn net_snapshot(&self) -> NetStats {
        *lock_unpoisoned(&self.net)
    }
}

/// Initial dial backoff; doubles up to [`DIAL_BACKOFF_MAX`].
const DIAL_BACKOFF_START: Duration = Duration::from_millis(25);
const DIAL_BACKOFF_MAX: Duration = Duration::from_millis(800);
/// Poll interval of the bounded accept loop.
const ACCEPT_POLL: Duration = Duration::from_millis(20);

/// Validate one inbound connection's `Hello` handshake.
fn handshake_inbound(
    mut stream: TcpStream,
    id: MachineId,
    k: usize,
    deadline: Instant,
    seen: &[bool],
) -> Result<(MachineId, TcpStream), WireError> {
    stream.set_nonblocking(false)?;
    // A fully elapsed deadline must fail *now*. The old code clamped
    // the remaining window up to 1 ms and read anyway, so a peer that
    // kept connecting could stretch the handshake far past the bound
    // the recovery grace-window math (DESIGN.md §10) relies on.
    let left = deadline.saturating_duration_since(Instant::now());
    if left.is_zero() {
        return Err(WireError::Protocol("handshake deadline already passed".into()));
    }
    stream.set_read_timeout(Some(left))?;
    let hello = read_frame(&mut stream)?;
    let Frame::Hello { machine, machines, .. } = hello else {
        return Err(WireError::Protocol(format!("expected Hello, got {hello:?}")));
    };
    let peer = machine as MachineId;
    if machines as usize != k || peer >= k || peer == id {
        return Err(WireError::Protocol(format!(
            "peer says machine {machine}/{machines}, we are {id}/{k}"
        )));
    }
    if seen[peer] {
        return Err(WireError::Protocol(format!("duplicate dial from machine {peer}")));
    }
    stream.set_read_timeout(None)?;
    stream.set_nodelay(true)?;
    Ok((peer, stream))
}

/// Accept inbound connections until one valid `Hello` per peer has
/// arrived. A single bad connection (port scanner, garbage handshake,
/// stray re-dial) is dropped with a note — never allowed to kill the
/// mesh join; only the overall deadline fails it.
fn accept_peers(
    listener: TcpListener,
    id: MachineId,
    k: usize,
    deadline: Instant,
) -> Result<Vec<(MachineId, TcpStream)>, WireError> {
    listener.set_nonblocking(true)?;
    let mut inbound: Vec<(MachineId, TcpStream)> = Vec::with_capacity(k - 1);
    let mut seen = vec![false; k];
    while inbound.len() < k - 1 {
        match listener.accept() {
            Ok((stream, addr)) => {
                // Per-connection handshake; any failure drops only this
                // socket.
                match handshake_inbound(stream, id, k, deadline, &seen) {
                    Ok((peer, stream)) => {
                        seen[peer] = true;
                        inbound.push((peer, stream));
                    }
                    Err(e) => {
                        eprintln!("gtip net: dropping inbound connection from {addr}: {e}");
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(WireError::Protocol(format!(
                        "timed out waiting for {} inbound peers (have {})",
                        k - 1,
                        inbound.len()
                    )));
                }
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(inbound)
}

/// Dial one peer with retry + backoff until `deadline`.
fn dial_peer(addr: &str, deadline: Instant) -> Result<TcpStream, WireError> {
    let mut backoff = DIAL_BACKOFF_START;
    loop {
        match TcpStream::connect(addr) {
            Ok(stream) => {
                stream.set_nodelay(true)?;
                return Ok(stream);
            }
            Err(e) => {
                // Keep trying until the deadline itself has passed —
                // the old `now + backoff >= deadline` check gave up
                // one whole backoff early, wasting the final window.
                let now = Instant::now();
                if now >= deadline {
                    return Err(WireError::Io(format!("dialing {addr}: {e}")));
                }
                std::thread::sleep(backoff.min(deadline - now));
                backoff = (backoff * 2).min(DIAL_BACKOFF_MAX);
            }
        }
    }
}

/// Build machine `id`'s endpoint from an already-bound listener:
/// full-mesh dial with deterministic `Hello` handshakes, then one
/// reader thread per inbound connection.
fn mesh_with_listener(
    listener: TcpListener,
    id: MachineId,
    addrs: &[String],
    connect_timeout: Duration,
    stats: Arc<Mutex<OverheadStats>>,
) -> Result<TcpEndpoint, WireError> {
    let k = addrs.len();
    assert!(id < k, "machine id {id} out of range for {k} machines");
    let deadline = Instant::now() + connect_timeout;

    // The accept thread runs on a clone; the original is retained in
    // the endpoint so a later admission can accept a joiner's dial.
    // Clones share the file description, so the nonblocking mode set
    // here applies to both — post-mesh accepts poll `WouldBlock`.
    listener.set_nonblocking(true)?;
    let accept_handle = if k > 1 {
        let acceptor = listener.try_clone()?;
        Some(std::thread::spawn(move || accept_peers(acceptor, id, k, deadline)))
    } else {
        None
    };

    // Dial everyone else (ascending machine order for determinism).
    let mut outs: Vec<Option<Mutex<TcpStream>>> = (0..k).map(|_| None).collect();
    for (peer, addr) in addrs.iter().enumerate() {
        if peer == id {
            continue;
        }
        let mut stream = dial_peer(addr, deadline)?;
        write_frame(
            &mut stream,
            &Frame::Hello { version: WIRE_VERSION, machine: wire_u32(id)?, machines: wire_u32(k)? },
        )?;
        outs[peer] = Some(Mutex::new(stream));
    }

    let inbound = match accept_handle {
        Some(h) => h.join().expect("accept thread panicked")?,
        None => Vec::new(),
    };

    let (inbox_tx, inbox) = channel();
    let (ctrl_tx, ctrl) = channel();
    for (peer, stream) in inbound {
        spawn_reader(stream, peer, inbox_tx.clone(), ctrl_tx.clone());
    }

    Ok(TcpEndpoint {
        id,
        k,
        wire_id: id,
        wire_of: (0..k).collect(),
        logical_of: (0..k).map(Some).collect(),
        inbox,
        inbox_tx,
        ctrl,
        ctrl_tx,
        listener,
        outs,
        stats,
        net: Arc::new(Mutex::new(NetStats::default())),
        failures: Mutex::new(SendFailures::default()),
    })
}

/// One reader thread per inbound connection: protocol messages go to
/// the shared inbox, everything else to the control channel, keyed by
/// the sender's immutable *wire* id (`recv_ctrl` translates to the
/// current logical id, dropping frames from evicted peers).
fn spawn_reader(
    mut stream: TcpStream,
    wire_peer: MachineId,
    inbox_tx: Sender<Message>,
    ctrl_tx: Sender<(MachineId, Frame)>,
) {
    std::thread::spawn(move || loop {
        match read_frame(&mut stream) {
            Ok(Frame::Msg(msg)) => {
                if inbox_tx.send(msg).is_err() {
                    break;
                }
            }
            Ok(frame) => {
                if ctrl_tx.send((wire_peer, frame)).is_err() {
                    break;
                }
            }
            Err(WireError::Closed) => break,
            Err(e) => {
                eprintln!("gtip net: reader for machine {wire_peer} stopped: {e}");
                break;
            }
        }
    });
}

/// Join the mesh as machine `id`: bind `addrs[id]`, dial everyone else.
pub fn connect_mesh(
    id: MachineId,
    addrs: &[String],
    connect_timeout: Duration,
    stats: Arc<Mutex<OverheadStats>>,
) -> Result<TcpEndpoint, WireError> {
    let listener = TcpListener::bind(addrs[id].as_str())
        .map_err(|e| WireError::Io(format!("binding {}: {e}", addrs[id])))?;
    mesh_with_listener(listener, id, addrs, connect_timeout, stats)
}

/// A K-machine loopback mesh inside one process (OS-assigned ports),
/// sharing one [`OverheadStats`] handle exactly like the in-process
/// bus — the test harness for transport equivalence.
pub fn build_tcp_bus_local(
    k: usize,
) -> Result<(Vec<TcpEndpoint>, Arc<Mutex<OverheadStats>>), WireError> {
    assert!(k >= 1);
    let stats = Arc::new(Mutex::new(OverheadStats::default()));
    let mut listeners = Vec::with_capacity(k);
    let mut addrs = Vec::with_capacity(k);
    for _ in 0..k {
        let l = TcpListener::bind("127.0.0.1:0")?;
        addrs.push(l.local_addr()?.to_string());
        listeners.push(l);
    }
    let mut handles = Vec::with_capacity(k);
    for (id, listener) in listeners.into_iter().enumerate() {
        let addrs = addrs.clone();
        let stats = Arc::clone(&stats);
        handles.push(std::thread::spawn(move || {
            mesh_with_listener(listener, id, &addrs, Duration::from_secs(10), stats)
        }));
    }
    let mut endpoints = Vec::with_capacity(k);
    for h in handles {
        endpoints.push(h.join().expect("mesh thread panicked")?);
    }
    Ok((endpoints, stats))
}

/// [`crate::coordinator::run_distributed`], but over a real loopback
/// TCP mesh — same options, same deterministic result.
pub fn run_distributed_tcp_local(
    graph: Arc<Graph>,
    machines: &MachineConfig,
    initial: Partition,
    options: &DistributedOptions,
) -> Result<DistributedReport, WireError> {
    let (endpoints, stats) = build_tcp_bus_local(machines.count())?;
    Ok(run_over_endpoints(endpoints, graph, machines, initial, options, stats))
}

/// [`crate::coordinator::distributed::run_distributed_hierarchical`],
/// but with both levels' meshes on real loopback TCP sockets — the
/// `RackUpdate` aggregates and the scoped rings cross actual wires,
/// and the parity tests assert the result is bit-identical to the
/// in-process hierarchy.
pub fn run_distributed_hierarchical_tcp_local(
    graph: Arc<Graph>,
    machines: &MachineConfig,
    initial: Partition,
    layout: &RackLayout,
    options: &DistributedOptions,
) -> Result<DistributedReport, WireError> {
    let (outer_endpoints, outer_stats) = build_tcp_bus_local(layout.rack_count())?;
    let (inner_endpoints, inner_stats) = build_tcp_bus_local(machines.count())?;
    Ok(run_hierarchical_over_endpoints(
        outer_endpoints,
        outer_stats,
        inner_endpoints,
        inner_stats,
        graph,
        machines,
        initial,
        layout,
        options,
    ))
}

// ---------------------------------------------------------------------
// Multi-process cluster: leader + serve
// ---------------------------------------------------------------------

/// Floor on the derived epoch wait: even with a very aggressive
/// receive timeout a healthy leader needs real time to simulate an
/// epoch window, so a worker never gives up faster than this.
const EPOCH_WAIT_FLOOR: Duration = Duration::from_secs(5);

/// How long a worker waits for the next `EpochBegin`. The leader
/// simulates a whole epoch in between, so this is generous — ten
/// receive timeouts — but it *scales with the configured timeout*
/// instead of the old hard-coded 600 s, which left a worker whose
/// leader had died hanging for ten minutes regardless of
/// `--recv-timeout-ms`.
fn epoch_wait(recv_timeout: Duration) -> Duration {
    recv_timeout.saturating_mul(10).max(EPOCH_WAIT_FLOOR)
}

/// Machine 0's handle on a multi-process cluster: owns the leader
/// endpoint and runs one refinement round per [`ClusterLeader::refine`]
/// call, aggregating the workers' overhead reports.
pub struct ClusterLeader {
    ep: TcpEndpoint,
    opts: DistributedOptions,
    epoch: u64,
    /// Which machines (current logical ids) delivered their
    /// `RoundStats` in the round in flight. Kept on the leader — not
    /// rebuilt inside the barrier loop — because a failed round's
    /// partial barrier is evidence [`ClusterLeader::diagnose_dead`]
    /// must not lose: a worker whose report was already consumed
    /// will not send it again.
    reported: Vec<bool>,
    /// The original peer list — wire id → address. An admission dials
    /// the joiner at its listed address.
    addrs: Vec<String>,
    /// Patience of the admission handshake's ack barrier (and of the
    /// rollback barrier should it fail). Must stay *longer* than the
    /// workers' own dial window (one receive timeout), or a survivor
    /// still dialing a dead joiner would miss the rollback broadcast.
    admit_window: Duration,
    /// Validated join requests queued by the acceptor thread.
    pending: Receiver<JoinRequest>,
    /// Requests drained from the channel but not yet admitted (e.g. a
    /// second joiner arriving while one admission is in flight).
    pending_buf: VecDeque<JoinRequest>,
    /// Tells the acceptor thread to stop accepting joiners.
    acceptor_stop: Arc<AtomicBool>,
    /// Two-level rack layout (wire v5, DESIGN.md §12); `None` plays the
    /// flat single-level game. Ships to workers in `Setup` and tracks
    /// membership changes (recovery shrinks it, admission grows it).
    layout: Option<RackLayout>,
}

/// One validated `Join` handshake, queued until the next epoch
/// boundary. The stream is the joiner's dial to the leader — it
/// becomes the leader's inbound reader for the joiner on admission.
pub struct JoinRequest {
    /// The joiner's immutable wire id (its slot in the peer list).
    pub wire_id: MachineId,
    /// Self-reported relative speed (1.0 = an average machine).
    pub speed: f64,
    /// Requested rack (wire v5); `None` = leader's choice. Ignored on
    /// a flat cluster.
    pub rack: Option<usize>,
    stream: TcpStream,
}

impl ClusterLeader {
    /// Join the mesh as machine 0 and wait for every worker.
    pub fn connect(
        addrs: &[String],
        opts: DistributedOptions,
        connect_timeout: Duration,
    ) -> Result<ClusterLeader, WireError> {
        let stats = Arc::new(Mutex::new(OverheadStats::default()));
        let ep = connect_mesh(0, addrs, connect_timeout, stats)?;
        let k = ep.machine_count();
        // The admission acceptor listens for joiners on a clone of the
        // leader's (now idle) mesh listener for the rest of the run.
        let acceptor = ep.listener.try_clone()?;
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, pending) = channel();
        {
            let stop = Arc::clone(&stop);
            let k_orig = addrs.len();
            std::thread::spawn(move || join_acceptor(acceptor, k_orig, stop, tx));
        }
        let admit_window = opts.recv_timeout.saturating_mul(2);
        Ok(ClusterLeader {
            ep,
            opts,
            epoch: 0,
            reported: vec![false; k],
            addrs: addrs.to_vec(),
            admit_window,
            pending,
            pending_buf: VecDeque::new(),
            acceptor_stop: stop,
            layout: None,
        })
    }

    /// Install the two-level rack layout (DESIGN.md §12). Must be
    /// called before [`ClusterLeader::setup`] so the machine → rack map
    /// ships with the fixture; every subsequent
    /// [`ClusterLeader::refine`] then plays the hierarchical game. A
    /// singleton layout (every machine its own rack) is accepted and
    /// reproduces the flat game bit-for-bit.
    pub fn set_racks(&mut self, layout: RackLayout) -> Result<(), WireError> {
        if layout.machine_count() != self.ep.machine_count() {
            return Err(WireError::Protocol(format!(
                "rack layout covers {} machines but the cluster has {}",
                layout.machine_count(),
                self.ep.machine_count()
            )));
        }
        self.layout = Some(layout);
        Ok(())
    }

    /// Override the admission/rollback barrier patience (defaults to
    /// twice the receive timeout).
    pub fn set_admit_window(&mut self, window: Duration) {
        self.admit_window = window.max(Duration::from_millis(1));
    }

    pub fn machine_count(&self) -> usize {
        self.ep.machine_count()
    }

    /// Control-plane accounting so far (handshake/setup/epoch frames).
    pub fn net_stats(&self) -> NetStats {
        self.ep.net_snapshot()
    }

    /// The shared fixture as a `Setup` frame (broadcast at startup,
    /// and re-sent to a joiner on admission).
    fn setup_frame(&self, graph: &Graph, machines: &MachineConfig) -> Result<Frame, WireError> {
        Ok(Frame::Setup(SetupFrame {
            speeds: machines.speeds().to_vec(),
            mu: self.opts.mu,
            framework: self.opts.framework,
            migration_charge: self.opts.migration_charge,
            epsilon: self.opts.epsilon,
            max_transfers: self.opts.max_transfers as u64,
            recv_timeout_ms: self.opts.recv_timeout.as_millis() as u64,
            node_weights: graph.node_weights().to_vec(),
            edges: graph
                .edges()
                .map(|(u, v, w)| Ok((wire_u32(u)?, wire_u32(v)?, w)))
                .collect::<Result<_, WireError>>()?,
            racks: match &self.layout {
                Some(l) => {
                    l.rack_of_slice().iter().map(|&r| wire_u32(r)).collect::<Result<_, _>>()?
                }
                None => Vec::new(),
            },
        }))
    }

    /// Broadcast the shared fixture. Must be called once, before the
    /// first [`ClusterLeader::refine`].
    pub fn setup(&self, graph: &Graph, machines: &MachineConfig) -> Result<(), WireError> {
        if machines.count() != self.ep.machine_count() {
            return Err(WireError::Protocol(format!(
                "cluster has {} machines but the fixture wants {}",
                self.ep.machine_count(),
                machines.count()
            )));
        }
        self.ep.broadcast_ctrl(&self.setup_frame(graph, machines)?)
    }

    /// Run one refinement round across the cluster: re-sync weights and
    /// the warm-start assignment, play machine 0's part of the ring (or
    /// the two hierarchical phases if a rack layout is installed), then
    /// collect every worker's overhead report (the epoch barrier).
    pub fn refine(
        &mut self,
        graph: &Graph,
        machines: &MachineConfig,
        initial: Partition,
    ) -> Result<DistributedReport, WireError> {
        match self.layout.clone() {
            Some(layout) => self.refine_hierarchical(graph, machines, initial, &layout),
            None => self.refine_flat(graph, machines, initial),
        }
    }

    /// `EpochBegin` broadcast shared by the flat round and both
    /// hierarchical phases. Attempts every peer even after a failure:
    /// the live peers must receive the round so they can later prove
    /// themselves to the death diagnosis with a RoundStats (a failed
    /// send is recorded by `send_ctrl` as evidence against the dead
    /// one).
    fn broadcast_begin(&mut self, begin: &Frame) -> Result<(), WireError> {
        let k = self.ep.machine_count();
        let mut lost_at_broadcast = Vec::new();
        for to in 1..k {
            if let Err(e) = self.ep.send_ctrl(to, begin) {
                eprintln!("gtip leader: EpochBegin to machine {to} failed: {e}");
                lost_at_broadcast.push(to);
            }
        }
        if !lost_at_broadcast.is_empty() {
            return Err(WireError::Protocol(format!(
                "EpochBegin broadcast lost machine(s) {lost_at_broadcast:?}"
            )));
        }
        Ok(())
    }

    /// The epoch frame for one round phase.
    fn epoch_frame(
        &self,
        epoch: u64,
        phase: u8,
        graph: &Graph,
        assignment: &[MachineId],
    ) -> Result<Frame, WireError> {
        Ok(Frame::EpochBegin(EpochFrame {
            epoch,
            phase,
            node_weights: graph.node_weights().to_vec(),
            edge_weights: graph.edges().map(|(_, _, w)| w).collect(),
            assignment: assignment.iter().map(|&m| wire_u32(m)).collect::<Result<_, _>>()?,
        }))
    }

    fn refine_flat(
        &mut self,
        graph: &Graph,
        machines: &MachineConfig,
        initial: Partition,
    ) -> Result<DistributedReport, WireError> {
        let k = self.ep.machine_count();
        if machines.count() != k {
            return Err(WireError::Protocol(format!(
                "cluster has {k} machines but the round's fixture wants {}",
                machines.count()
            )));
        }
        // Any message still buffered here is stale traffic from an
        // aborted round (post-recovery); the broadcast below opens a
        // fresh round, so this is the one safe point to discard it.
        self.ep.drain_inbox();
        self.reported = vec![false; k];
        self.reported[0] = true;
        let epoch = self.epoch;
        self.epoch += 1;
        let begin = self.epoch_frame(epoch, 0, graph, initial.assignment())?;
        self.broadcast_begin(&begin)?;

        let before = self.ep.stats_snapshot();
        let actor = MachineActor::new(
            0,
            Arc::new(graph.clone()),
            machines.clone(),
            &initial,
            self.opts.mu,
            self.opts.framework,
            self.opts.migration_charge,
        );
        self.ep.send(0, Message::TakeMyTurn { consecutive_forfeits: 0, transfers_so_far: 0 });
        let outcome =
            machine_loop(actor, &self.ep, self.opts.epsilon, self.opts.max_transfers, self.opts.recv_timeout);
        if outcome.timed_out {
            return Err(WireError::Protocol(match outcome.dead_peer {
                Some(m) => format!("refinement round lost machine {m} (send failed)"),
                None => "refinement round timed out waiting on a peer".into(),
            }));
        }

        // Barrier: one RoundStats per worker closes the round. Who has
        // reported lives on `self` so a barrier that fails part-way
        // leaves the evidence for `diagnose_dead`.
        let mut overhead = self.ep.stats_snapshot().delta_since(&before);
        let mut remaining = k - 1;
        while remaining > 0 {
            match self.ep.recv_ctrl(self.opts.recv_timeout)? {
                (peer, Frame::RoundStats(s)) if !self.reported[peer] => {
                    self.reported[peer] = true;
                    overhead.add(&s);
                    remaining -= 1;
                }
                (peer, frame) => {
                    return Err(WireError::Protocol(format!(
                        "unexpected control frame from machine {peer} during barrier: {frame:?}"
                    )));
                }
            }
        }

        // Every transfer reaches every replica, so the leader's applied
        // count *is* the global transfer total.
        let partition = Partition::from_assignment(graph, k, outcome.assignment);
        Ok(DistributedReport {
            partition,
            transfers: outcome.transfers_applied as usize,
            overhead,
            converged: outcome.converged,
            timed_out: false,
        })
    }

    /// One hierarchical epoch (DESIGN.md §12): a phase-1 outer round
    /// where the leader and the other rack leaders exchange O(R)
    /// `RackUpdate` aggregates over a [`RackBus`], the guarded
    /// map-back, then a phase-2 round of concurrent per-rack scoped
    /// rings. Non-leader racks ship their ring outcome back in a
    /// `RackResult`; the leader merges them into the final partition.
    fn refine_hierarchical(
        &mut self,
        graph: &Graph,
        machines: &MachineConfig,
        initial: Partition,
        layout: &RackLayout,
    ) -> Result<DistributedReport, WireError> {
        let k = self.ep.machine_count();
        if machines.count() != k {
            return Err(WireError::Protocol(format!(
                "cluster has {k} machines but the round's fixture wants {}",
                machines.count()
            )));
        }
        if layout.machine_count() != k {
            return Err(WireError::Protocol(format!(
                "rack layout covers {} machines but the cluster has {k}",
                layout.machine_count()
            )));
        }
        let racks = layout.rack_count();
        self.ep.drain_inbox();
        self.reported = vec![false; k];
        self.reported[0] = true;
        let epoch = self.epoch;
        self.epoch += 1;

        // Phase 1: the outer game on the rack quotient. Machine 0
        // always leads its own rack (it is the smallest id), and kicks
        // rack 0 — possibly itself — exactly like the in-process ring.
        let begin = self.epoch_frame(epoch, 1, graph, initial.assignment())?;
        self.broadcast_begin(&begin)?;
        let before = self.ep.stats_snapshot();
        let my_rack = layout.rack_of(0);
        let qconfig = layout.quotient_config(machines);
        let qpart = Partition::from_assignment(
            graph,
            racks,
            layout.quotient_assignment(initial.assignment()),
        );
        let actor = MachineActor::new(
            my_rack,
            Arc::new(graph.clone()),
            qconfig,
            &qpart,
            self.opts.mu,
            self.opts.framework,
            self.opts.migration_charge,
        );
        let outer = {
            let bus = RackBus::new(&self.ep, my_rack, layout.leaders());
            bus.send(0, Message::TakeMyTurn { consecutive_forfeits: 0, transfers_so_far: 0 });
            let opts = &self.opts;
            machine_loop(actor, &bus, opts.epsilon, opts.max_transfers, opts.recv_timeout)
        };
        if outer.timed_out {
            return Err(WireError::Protocol(match outer.dead_peer {
                Some(r) => format!("outer round lost rack {r}'s leader (send failed)"),
                None => "outer round timed out waiting on a rack leader".into(),
            }));
        }
        // Phase-1 barrier: every worker reports, spectators included.
        let mut worker_stats = OverheadStats::default();
        self.stats_barrier(&mut worker_stats)?;

        // Guarded map-back to machines (shared with every other
        // deployment of the hierarchy).
        let mapped = guarded_map_back(
            graph,
            machines,
            layout,
            initial.assignment(),
            &outer.assignment,
            self.opts.mu,
            self.opts.framework,
        );
        let outer_transfers =
            if mapped.accepted { outer.transfers_applied as usize } else { 0 };
        let start = Partition::from_assignment(graph, k, mapped.assignment);

        // Phase 2: concurrent scoped rings, one per rack. The leader
        // plays (and kicks) its own rack's ring; every other rack's
        // leader kicks its own.
        self.reported = vec![false; k];
        self.reported[0] = true;
        let begin = self.epoch_frame(epoch, 2, graph, start.assignment())?;
        self.broadcast_begin(&begin)?;
        let scope = layout.members(my_rack).to_vec();
        let actor = MachineActor::new(
            0,
            Arc::new(graph.clone()),
            machines.clone(),
            &start,
            self.opts.mu,
            self.opts.framework,
            self.opts.migration_charge,
        )
        .with_scope(scope.clone());
        self.ep.send(0, Message::TakeMyTurn { consecutive_forfeits: 0, transfers_so_far: 0 });
        let inner = machine_loop_scoped(
            actor,
            &self.ep,
            &scope,
            self.opts.epsilon,
            self.opts.max_transfers,
            self.opts.recv_timeout,
        );
        if inner.timed_out {
            return Err(WireError::Protocol(match inner.dead_peer {
                Some(m) => format!("inner round lost machine {m} (send failed)"),
                None => "inner round timed out waiting on a rack member".into(),
            }));
        }

        // Phase-2 barrier: K−1 RoundStats plus one RackResult from
        // every rack the leader is not in, in any interleaving.
        let mut assignment = inner.assignment.clone();
        let mut transfers = outer_transfers + inner.transfers_applied as usize;
        let mut converged = outer.converged && inner.converged;
        let mut got_rack = vec![false; racks];
        got_rack[my_rack] = true;
        let mut remaining_stats = k - 1;
        let mut remaining_racks = racks - 1;
        while remaining_stats > 0 || remaining_racks > 0 {
            match self.ep.recv_ctrl(self.opts.recv_timeout)? {
                (peer, Frame::RoundStats(s)) if !self.reported[peer] => {
                    self.reported[peer] = true;
                    worker_stats.add(&s);
                    remaining_stats -= 1;
                }
                (peer, Frame::RackResult { rack, transfers: t, converged: c, assignment: a }) => {
                    let rack = rack as usize;
                    if rack >= racks || got_rack[rack] || layout.leader(rack) != peer {
                        return Err(WireError::Protocol(format!(
                            "machine {peer} sent an invalid RackResult for rack {rack}"
                        )));
                    }
                    got_rack[rack] = true;
                    for &(node, machine) in &a {
                        let (node, machine) = (node as usize, machine as MachineId);
                        let valid = node < assignment.len()
                            && machine < k
                            && layout.rack_of(machine) == rack
                            && layout.rack_of(start.machine_of(node)) == rack;
                        if !valid {
                            return Err(WireError::Protocol(format!(
                                "rack {rack} reported an out-of-rack move of node {node}"
                            )));
                        }
                        assignment[node] = machine;
                    }
                    transfers += t as usize;
                    converged = converged && c;
                    remaining_racks -= 1;
                }
                (peer, frame) => {
                    return Err(WireError::Protocol(format!(
                        "unexpected control frame from machine {peer} during barrier: {frame:?}"
                    )));
                }
            }
        }
        let mut overhead = self.ep.stats_snapshot().delta_since(&before);
        overhead.add(&worker_stats);
        Ok(DistributedReport {
            partition: Partition::from_assignment(graph, k, assignment),
            transfers,
            overhead,
            converged,
            timed_out: false,
        })
    }

    /// Barrier on K−1 worker `RoundStats`, folding them into `into`.
    fn stats_barrier(&mut self, into: &mut OverheadStats) -> Result<(), WireError> {
        let mut remaining = self.ep.machine_count() - 1;
        while remaining > 0 {
            match self.ep.recv_ctrl(self.opts.recv_timeout)? {
                (peer, Frame::RoundStats(s)) if !self.reported[peer] => {
                    self.reported[peer] = true;
                    into.add(&s);
                    remaining -= 1;
                }
                (peer, frame) => {
                    return Err(WireError::Protocol(format!(
                        "unexpected control frame from machine {peer} during barrier: {frame:?}"
                    )));
                }
            }
        }
        Ok(())
    }

    /// After a failed [`ClusterLeader::refine`], work out which
    /// workers are dead. Evidence is twofold: send failures recorded
    /// at the leader's own sockets, and silence — any worker that does
    /// not deliver its `RoundStats` within one receive-timeout grace
    /// window. Live workers send `RoundStats` even after a timed-out
    /// round precisely so they can prove themselves here.
    ///
    /// Returns the dead machines' *current logical ids*, ascending.
    /// An alive-but-stalled worker that stays silent past the grace
    /// window is evicted too — see the module doc's known limitation.
    pub fn diagnose_dead(&mut self) -> Result<Vec<MachineId>, WireError> {
        let k = self.ep.machine_count();
        // Workers whose RoundStats the failed round's barrier already
        // consumed have proven themselves; they will not report twice.
        let mut alive = std::mem::take(&mut self.reported);
        alive.resize(k, false);
        alive[0] = true;
        // 2x the round timeout: a live worker only discovers the dead
        // ring after waiting out its own `recv_timeout`, and its
        // RoundStats still has to cross the wire after that.
        let deadline = Instant::now() + self.opts.recv_timeout * 2;
        while alive.iter().any(|&a| !a) {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            match self.ep.recv_ctrl(left) {
                Ok((peer, Frame::RoundStats(_))) => alive[peer] = true,
                Ok(_) => continue, // stale traffic from the aborted round
                Err(WireError::Protocol(_)) => break, // grace window elapsed
                Err(e) => return Err(e),
            }
        }
        let failed = self.ep.take_send_failures();
        // Empty means every worker answered the post-mortem: the
        // failure was not a worker death and the caller should
        // propagate its original error instead of recovering.
        let dead: Vec<MachineId> =
            (1..k).filter(|m| !alive[*m] || failed.contains_key(m)).collect();
        for m in &dead {
            let why = failed.get(m).cloned().unwrap_or_else(|| "no RoundStats within grace".into());
            eprintln!("gtip leader: machine {m} presumed dead ({why})");
        }
        Ok(dead)
    }

    /// Re-form the cluster around the survivors of `dead` (current
    /// logical ids) and hand every survivor its new identity and the
    /// renormalized speeds. Blocks until every survivor acknowledges —
    /// the ack doubles as a barrier keeping stale round traffic out of
    /// the next epoch.
    pub fn recover(
        &mut self,
        dead: &[MachineId],
        machines_after: &MachineConfig,
    ) -> Result<(), WireError> {
        let k = self.ep.machine_count();
        if dead.is_empty() || dead.contains(&0) {
            return Err(WireError::Protocol(
                "recovery needs a non-empty dead list that excludes the leader".into(),
            ));
        }
        if machines_after.count() + dead.len() != k {
            return Err(WireError::Protocol(format!(
                "{} survivors + {} dead != {k} machines",
                machines_after.count(),
                dead.len()
            )));
        }
        let survivors_wire: Vec<MachineId> =
            (0..k).filter(|m| !dead.contains(m)).map(|m| self.ep.wire_of(m)).collect();
        if let Some(l) = &self.layout {
            // Shrink the rack layout with the fleet (dead are current
            // logical ids, exactly what `without_machines` wants).
            self.layout = Some(l.without_machines(dead).map_err(WireError::Protocol)?);
        }
        self.ep.compact(&survivors_wire)?;
        self.ep.drain_inbox();
        self.reported = vec![false; self.ep.machine_count()];
        let frame = Frame::Restore {
            survivors: survivors_wire
                .iter()
                .map(|&w| wire_u32(w))
                .collect::<Result<_, _>>()?,
            speeds: machines_after.speeds().to_vec(),
        };
        self.ep.broadcast_ctrl(&frame)?;
        self.await_restore_acks(self.opts.recv_timeout)
    }

    /// Ack barrier after a `Restore` broadcast: every member confirms
    /// it compacted to the same membership before the next epoch's
    /// traffic starts. Shared by [`ClusterLeader::recover`] and the
    /// admission rollback; stale `RoundStats` (post-mortem reports)
    /// and `AdmitAck`s (a survivor that extended before the rollback)
    /// are skipped.
    fn await_restore_acks(&mut self, patience: Duration) -> Result<(), WireError> {
        let k_after = self.ep.machine_count();
        let mut acked = vec![false; k_after];
        acked[0] = true;
        let mut remaining = k_after - 1;
        while remaining > 0 {
            match self.ep.recv_ctrl(patience)? {
                (peer, Frame::RestoreAck { machine }) => {
                    if self.ep.wire_of(peer) != machine as MachineId {
                        return Err(WireError::Protocol(format!(
                            "machine {peer} acked the restore as wire id {machine}, expected {}",
                            self.ep.wire_of(peer)
                        )));
                    }
                    if !acked[peer] {
                        acked[peer] = true;
                        remaining -= 1;
                    }
                }
                (_, Frame::RoundStats(_)) => continue, // stale post-mortem report
                (_, Frame::AdmitAck { .. }) => continue, // stale pre-rollback ack
                (peer, frame) => {
                    return Err(WireError::Protocol(format!(
                        "unexpected control frame from machine {peer} during restore: {frame:?}"
                    )));
                }
            }
        }
        Ok(())
    }

    /// The logical id (= list position) a currently-evicted wire id
    /// would take on admission: wire ids stay ascending, so the joiner
    /// slots in between its wire-id neighbours and every member to its
    /// right shifts up by one. The driver needs this *before*
    /// [`ClusterLeader::admit`] to build the K+1 speed vector and
    /// remap the engine assignment.
    pub fn joiner_position(&self, wire: MachineId) -> usize {
        self.ep.wire_of.iter().filter(|&&w| w < wire).count()
    }

    /// Next queued join request, if any. Requests from a wire id that
    /// is currently an active member are rejected here (Goodbye), and
    /// a newer request from the same wire id supersedes an older one —
    /// the joiner only re-dials after its previous attempt was
    /// rejected or closed, so the older stream is dead.
    pub fn pending_join(&mut self) -> Option<JoinRequest> {
        while let Ok(req) = self.pending.try_recv() {
            self.pending_buf.push_back(req);
        }
        while let Some(mut req) = self.pending_buf.pop_front() {
            if self.ep.wire_is_active(req.wire_id) {
                eprintln!(
                    "gtip leader: rejecting Join from wire id {} (already an active member)",
                    req.wire_id
                );
                let _ = write_frame(&mut req.stream, &Frame::Goodbye);
                continue;
            }
            if self.pending_buf.iter().any(|r| r.wire_id == req.wire_id) {
                continue; // superseded by a newer request from the same joiner
            }
            return Some(req);
        }
        None
    }

    /// Admit a joiner at an epoch boundary: dial it, extend the mesh,
    /// broadcast `Admit`, ship the joiner the fixture (`Setup`) plus
    /// the boundary snapshot (`Catchup`), and run the ack barrier.
    ///
    /// `machines_after` is the renormalized K+1 speed vector with the
    /// joiner at [`ClusterLeader::joiner_position`]; `snapshot` is the
    /// encoded boundary checkpoint *already remapped* to the K+1
    /// numbering. Returns `Ok(true)` if the joiner is in, `Ok(false)`
    /// if the admission failed but the cluster rolled back cleanly to
    /// its previous membership (the run continues at K), and `Err` if
    /// the rollback itself failed.
    pub fn admit(
        &mut self,
        req: JoinRequest,
        graph: &Graph,
        machines_before: &MachineConfig,
        machines_after: &MachineConfig,
        snapshot: &[u8],
    ) -> Result<bool, WireError> {
        let joiner = req.wire_id;
        let k_orig = self.addrs.len();
        if joiner == 0 || joiner >= k_orig || self.ep.wire_is_active(joiner) {
            return Err(WireError::Protocol(format!(
                "wire id {joiner} is not an admissible joiner"
            )));
        }
        let old_members = self.ep.wire_of.clone();
        if machines_before.count() != old_members.len()
            || machines_after.count() != old_members.len() + 1
        {
            return Err(WireError::Protocol(format!(
                "admission fixtures have {}/{} machines for a {}-member mesh",
                machines_before.count(),
                machines_after.count(),
                old_members.len()
            )));
        }
        // Dial the joiner first: a failure here leaves the mesh
        // untouched, so no rollback is needed — just drop the request
        // (the joiner will re-dial when its stream closes).
        let deadline = Instant::now() + self.admit_window;
        let mut out = match dial_peer(&self.addrs[joiner], deadline) {
            Ok(out) => out,
            Err(e) => {
                eprintln!("gtip leader: cannot dial joiner {joiner}: {e}");
                return Ok(false);
            }
        };
        if let Err(e) = write_frame(
            &mut out,
            &Frame::Hello { version: WIRE_VERSION, machine: 0, machines: wire_u32(k_orig)? },
        ) {
            eprintln!("gtip leader: hello to joiner {joiner} failed: {e}");
            return Ok(false);
        }
        let mut members = old_members.clone();
        let pos = self.joiner_position(joiner);
        members.insert(pos, joiner);
        // Resolve the joiner's rack before the mesh grows: honor the
        // request if it names an existing rack (or the next fresh one),
        // otherwise place it in the emptiest rack. Flat clusters ship 0.
        let old_layout = self.layout.clone();
        let joiner_rack = match &old_layout {
            Some(l) => match req.rack {
                Some(r) if r <= l.rack_count() => r,
                Some(r) => {
                    eprintln!(
                        "gtip leader: joiner asked for rack {r} of {}; using the emptiest",
                        l.rack_count()
                    );
                    l.join_rack()
                }
                None => l.join_rack(),
            },
            None => 0,
        };
        self.ep.extend(&members, joiner, out, req.stream)?;
        if let Some(l) = &old_layout {
            // Grow the layout first so the joiner's Setup ships it.
            self.layout = Some(l.with_inserted(pos, joiner_rack).map_err(WireError::Protocol)?);
        }

        let result = (|| -> Result<(), WireError> {
            self.ep.broadcast_ctrl(&Frame::Admit {
                members: members.iter().map(|&w| wire_u32(w)).collect::<Result<_, _>>()?,
                joiner: wire_u32(joiner)?,
                speeds: machines_after.speeds().to_vec(),
                rack: wire_u32(joiner_rack)?,
            })?;
            self.ep.send_ctrl(pos, &self.setup_frame(graph, machines_after)?)?;
            self.ep.send_ctrl(pos, &Frame::Catchup { snapshot: snapshot.to_vec() })?;
            // Ack barrier: every member (joiner included) confirms the
            // extended mesh before the next epoch's traffic starts.
            let k_new = members.len();
            let mut acked = vec![false; k_new];
            acked[0] = true;
            let mut remaining = k_new - 1;
            while remaining > 0 {
                match self.ep.recv_ctrl(self.admit_window)? {
                    (peer, Frame::AdmitAck { machine }) => {
                        if self.ep.wire_of(peer) != machine as MachineId {
                            return Err(WireError::Protocol(format!(
                                "machine {peer} acked the admit as wire id {machine}, expected {}",
                                self.ep.wire_of(peer)
                            )));
                        }
                        if !acked[peer] {
                            acked[peer] = true;
                            remaining -= 1;
                        }
                    }
                    (_, Frame::RoundStats(_)) => continue, // stale report
                    (peer, frame) => {
                        return Err(WireError::Protocol(format!(
                            "unexpected control frame from machine {peer} during admit: {frame:?}"
                        )));
                    }
                }
            }
            Ok(())
        })();

        match result {
            Ok(()) => {
                self.ep.drain_inbox();
                self.reported = vec![false; self.ep.machine_count()];
                Ok(true)
            }
            Err(e) => {
                eprintln!(
                    "gtip leader: admission of wire id {joiner} failed ({e}); rolling back to K={}",
                    old_members.len()
                );
                self.layout = old_layout;
                self.rollback_admit(&old_members, machines_before)?;
                Ok(false)
            }
        }
    }

    /// Undo a failed admission: compact back to the old membership and
    /// re-run the restore barrier so every survivor is provably back
    /// on the pre-admission mesh before the run continues.
    fn rollback_admit(
        &mut self,
        old_members: &[MachineId],
        machines_before: &MachineConfig,
    ) -> Result<(), WireError> {
        self.ep.compact(old_members)?;
        self.ep.drain_inbox();
        self.reported = vec![false; self.ep.machine_count()];
        self.ep.broadcast_ctrl(&Frame::Restore {
            survivors: old_members.iter().map(|&w| wire_u32(w)).collect::<Result<_, _>>()?,
            speeds: machines_before.speeds().to_vec(),
        })?;
        // A survivor may still be stuck dialing the dead joiner for up
        // to its own handshake window (one receive timeout) before it
        // sees this Restore — hence the longer admit-window patience.
        self.await_restore_acks(self.admit_window)
    }

    /// Graceful shutdown: tell every worker the run is over, and turn
    /// away any joiner still waiting at the door.
    pub fn shutdown(mut self) -> Result<(), WireError> {
        self.acceptor_stop.store(true, Ordering::Relaxed);
        while let Some(mut req) = self.pending_join() {
            let _ = write_frame(&mut req.stream, &Frame::Goodbye);
        }
        self.ep.broadcast_ctrl(&Frame::Goodbye)
    }
}

impl Drop for ClusterLeader {
    fn drop(&mut self) {
        self.acceptor_stop.store(true, Ordering::Relaxed);
    }
}

/// How long the acceptor gives one joiner to complete its
/// `Hello` + `Join` handshake before dropping the connection.
const JOIN_HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(2);

/// The leader's admission acceptor: runs for the whole cluster
/// lifetime on a clone of the (nonblocking) mesh listener, validating
/// `Hello` + `Join` handshakes and queueing good ones for the driver
/// to pick up at the next epoch boundary — a mid-epoch `Join` is
/// thereby deferred, never dropped. Semantic rejects get a `Goodbye`
/// so the joiner can distinguish "no" from "not yet".
fn join_acceptor(
    listener: TcpListener,
    k_orig: usize,
    stop: Arc<AtomicBool>,
    tx: Sender<JoinRequest>,
) {
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        match listener.accept() {
            Ok((stream, addr)) => match join_handshake(stream, k_orig) {
                Ok(req) => {
                    eprintln!(
                        "gtip leader: queued Join from wire id {} (speed {})",
                        req.wire_id, req.speed
                    );
                    if tx.send(req).is_err() {
                        return; // leader dropped
                    }
                }
                Err((e, stream)) => {
                    eprintln!("gtip leader: dropping join dial from {addr}: {e}");
                    if let Some(mut stream) = stream {
                        let _ = write_frame(&mut stream, &Frame::Goodbye);
                    }
                }
            },
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(e) => {
                eprintln!("gtip leader: join acceptor error: {e}");
                std::thread::sleep(ACCEPT_POLL);
            }
        }
    }
}

/// Validate one would-be joiner's `Hello` + `Join`. On a *semantic*
/// reject the stream is returned so the caller can send a `Goodbye`
/// (telling the joiner to give up rather than retry); on an I/O or
/// codec failure it is simply dropped.
fn join_handshake(
    mut stream: TcpStream,
    k_orig: usize,
) -> Result<JoinRequest, (WireError, Option<TcpStream>)> {
    let io = |e: WireError| (e, None);
    stream.set_nonblocking(false).map_err(|e| io(e.into()))?;
    stream.set_read_timeout(Some(JOIN_HANDSHAKE_TIMEOUT)).map_err(|e| io(e.into()))?;
    let hello = read_frame(&mut stream).map_err(io)?;
    let Frame::Hello { machine, machines, .. } = hello else {
        return Err((WireError::Protocol(format!("expected Hello, got {hello:?}")), None));
    };
    let wire_id = machine as MachineId;
    if machines as usize != k_orig || wire_id == 0 || wire_id >= k_orig {
        return Err((
            WireError::Protocol(format!(
                "joiner says machine {machine}/{machines}, cluster is {k_orig} machines"
            )),
            Some(stream),
        ));
    }
    let join = read_frame(&mut stream).map_err(io)?;
    let Frame::Join { machine: jm, speed, rack } = join else {
        return Err((WireError::Protocol(format!("expected Join, got {join:?}")), None));
    };
    if jm as MachineId != wire_id {
        return Err((
            WireError::Protocol(format!("Join names machine {jm} but Hello said {machine}")),
            Some(stream),
        ));
    }
    if !(speed.is_finite() && speed > 0.0) {
        return Err((
            WireError::Protocol(format!("join speed {speed} must be finite and positive")),
            Some(stream),
        ));
    }
    stream.set_read_timeout(None).map_err(|e| io(e.into()))?;
    stream.set_nodelay(true).map_err(|e| io(e.into()))?;
    // u32::MAX = "leader's choice"; anything else is a request the
    // leader validates against its layout at admission time.
    let rack = if rack == u32::MAX { None } else { Some(rack as usize) };
    Ok(JoinRequest { wire_id, speed, rack, stream })
}

/// What a worker did over its lifetime (printed by `gtip serve`).
#[derive(Debug, Clone)]
pub struct ServeSummary {
    pub machine_id: MachineId,
    pub epochs: u64,
    pub overhead: OverheadStats,
    pub control: NetStats,
}

/// Run machine `machine_id`'s side of the multi-process cluster: join
/// the mesh, receive the fixture, then play one refinement round per
/// `EpochBegin` until `Goodbye`. This is the body of `gtip serve`.
pub fn serve(
    machine_id: MachineId,
    addrs: &[String],
    connect_timeout: Duration,
) -> Result<ServeSummary, WireError> {
    if machine_id == 0 {
        return Err(WireError::Protocol(
            "machine 0 is the driver; run `gtip dynamic --transport tcp` instead of serve".into(),
        ));
    }
    if machine_id >= addrs.len() {
        return Err(WireError::Protocol(format!(
            "--machine-id {machine_id} out of range for {} peers",
            addrs.len()
        )));
    }
    let stats = Arc::new(Mutex::new(OverheadStats::default()));
    let ep = connect_mesh(machine_id, addrs, connect_timeout, Arc::clone(&stats))?;
    // Fault injection for the recovery tests: "setup" dies after the
    // fixture is validated, "epoch:N" dies on receiving EpochBegin N,
    // "stats" dies just before reporting RoundStats, "admit" dies on
    // receiving Admit (joiner side). Exit code 86 marks an intentional
    // death (the harness asserts on it).
    let die = std::env::var("GTIP_SERVE_DIE").unwrap_or_default();

    // Fixture first. The wait derives from the dial window — the
    // leader sets up right after the mesh forms; once the fixture is
    // in hand the loop waits on the fixture's own receive timeout.
    let setup = match ep.recv_ctrl(epoch_wait(connect_timeout))? {
        (0, Frame::Setup(s)) => s,
        (0, Frame::Goodbye) => {
            return Ok(ServeSummary {
                machine_id,
                epochs: 0,
                overhead: ep.stats_snapshot(),
                control: ep.net_snapshot(),
            })
        }
        (peer, frame) => {
            return Err(WireError::Protocol(format!(
                "expected Setup from the leader, got {frame:?} from machine {peer}"
            )))
        }
    };
    let fixture = WorkerFixture::from_setup(&setup, addrs.len())?;
    if die == "setup" {
        eprintln!("gtip serve: GTIP_SERVE_DIE=setup — dying after fixture validation");
        std::process::exit(86);
    }
    run_worker_loop(ep, addrs, fixture, &die)
}

/// Everything a worker keeps between epochs, validated once from the
/// `Setup` frame. Shared by the original-mesh path (`serve`) and the
/// admission path (`serve_join`).
struct WorkerFixture {
    machines: MachineConfig,
    graph: Graph,
    /// Edge order of the built graph — per-epoch weights arrive in
    /// the leader's edge order, which matches because both graphs
    /// share the same topology.
    edge_order: Vec<(usize, usize)>,
    mu: f64,
    framework: Framework,
    migration_charge: f64,
    epsilon: f64,
    max_transfers: usize,
    recv_timeout: Duration,
    /// Two-level rack layout (wire v5); `None` on a flat cluster.
    /// Indexed by *logical* id, so membership changes (`Restore`,
    /// `Admit`) must update it in lockstep with the endpoint.
    layout: Option<RackLayout>,
}

impl WorkerFixture {
    /// Validate before handing anything to constructors that assert —
    /// a buggy or skewed leader must produce a clean protocol error,
    /// not abort the worker process.
    fn from_setup(setup: &SetupFrame, k: usize) -> Result<WorkerFixture, WireError> {
        if setup.speeds.len() != k {
            return Err(WireError::Protocol(format!(
                "fixture has {} machines but the mesh has {k}",
                setup.speeds.len()
            )));
        }
        let speed_sum: f64 = setup.speeds.iter().sum();
        if setup.speeds.iter().any(|&s| !(s > 0.0)) || (speed_sum - 1.0).abs() > 1e-6 {
            return Err(WireError::Protocol(format!(
                "fixture speeds are not normalized positive weights (sum {speed_sum})"
            )));
        }
        let n = setup.node_weights.len();
        if let Some(&(u, v, _)) = setup
            .edges
            .iter()
            .find(|&&(u, v, _)| u as usize >= n || v as usize >= n || u == v)
        {
            return Err(WireError::Protocol(format!(
                "fixture edge ({u}, {v}) is out of range for {n} nodes"
            )));
        }
        if !weights_valid(&setup.node_weights)
            || !weights_valid_iter(setup.edges.iter().map(|&(_, _, w)| w))
        {
            return Err(WireError::Protocol(
                "fixture weights must be finite and non-negative".into(),
            ));
        }
        if !(setup.migration_charge.is_finite() && setup.migration_charge >= 0.0) {
            return Err(WireError::Protocol(format!(
                "fixture migration charge {} must be finite and non-negative",
                setup.migration_charge
            )));
        }
        // Adopt the leader's normalized speeds verbatim — renormalizing
        // here could drift each weight by an ulp and diverge replicas.
        let machines = MachineConfig::from_normalized(setup.speeds.clone());
        let mut builder = GraphBuilder::with_nodes(n);
        for &(u, v, w) in &setup.edges {
            builder.add_edge(u as usize, v as usize, w);
        }
        for (i, &w) in setup.node_weights.iter().enumerate() {
            builder.set_node_weight(i, w);
        }
        let graph = builder.build();
        let edge_order: Vec<(usize, usize)> = graph.edges().map(|(u, v, _)| (u, v)).collect();
        if edge_order.len() != setup.edges.len() {
            return Err(WireError::Protocol("fixture edge list had duplicates".into()));
        }
        Ok(WorkerFixture {
            machines,
            graph,
            edge_order,
            mu: setup.mu,
            framework: setup.framework,
            migration_charge: setup.migration_charge,
            epsilon: setup.epsilon,
            max_transfers: setup.max_transfers as usize,
            recv_timeout: Duration::from_millis(setup.recv_timeout_ms.max(1)),
            layout: if setup.racks.is_empty() {
                None
            } else {
                if setup.racks.len() != k {
                    return Err(WireError::Protocol(format!(
                        "fixture has {} rack entries but the mesh has {k} machines",
                        setup.racks.len()
                    )));
                }
                let rack_of: Vec<usize> = setup.racks.iter().map(|&r| r as usize).collect();
                Some(RackLayout::new(rack_of).map_err(WireError::Protocol)?)
            },
        })
    }
}

/// The worker's steady state: one refinement round per `EpochBegin`,
/// membership shrinking via `Restore` and growing via `Admit`, until
/// `Goodbye`. The endpoint's own logical id / machine count track the
/// membership changes (compact and extend renumber in place).
fn run_worker_loop(
    mut ep: TcpEndpoint,
    addrs: &[String],
    mut fixture: WorkerFixture,
    die: &str,
) -> Result<ServeSummary, WireError> {
    let machine_id = ep.wire_id();
    let n = fixture.graph.node_weights().len();
    let mut epochs = 0u64;
    loop {
        match ep.recv_ctrl(epoch_wait(fixture.recv_timeout))? {
            (0, Frame::EpochBegin(e)) => {
                if die == format!("epoch:{}", e.epoch) {
                    eprintln!(
                        "gtip serve: GTIP_SERVE_DIE={die} — dying on EpochBegin {}",
                        e.epoch
                    );
                    std::process::exit(86);
                }
                let k = ep.machine_count();
                if e.node_weights.len() != n || e.edge_weights.len() != fixture.edge_order.len()
                {
                    return Err(WireError::Protocol(format!(
                        "epoch {} weight vectors do not match the fixture shape",
                        e.epoch
                    )));
                }
                if e.assignment.len() != n {
                    return Err(WireError::Protocol(format!(
                        "epoch {} assignment length {} != {n}",
                        e.epoch,
                        e.assignment.len()
                    )));
                }
                if !weights_valid(&e.node_weights) || !weights_valid(&e.edge_weights) {
                    return Err(WireError::Protocol(format!(
                        "epoch {} weights must be finite and non-negative",
                        e.epoch
                    )));
                }
                fixture.graph.set_node_weights(&e.node_weights);
                for (&(u, v), &w) in fixture.edge_order.iter().zip(&e.edge_weights) {
                    fixture.graph.set_edge_weight(u, v, w);
                }
                let assignment: Vec<MachineId> =
                    e.assignment.iter().map(|&a| a as MachineId).collect();
                if let Some(&bad) = assignment.iter().find(|&&a| a >= k) {
                    return Err(WireError::Protocol(format!(
                        "epoch {} assignment names machine {bad} but K={k}",
                        e.epoch
                    )));
                }
                let part = Partition::from_assignment(&fixture.graph, k, assignment);
                let before = ep.stats_snapshot();
                let outcome = match (e.phase, &fixture.layout) {
                    // Flat round: the original single-level ring.
                    (0, _) => {
                        let actor = MachineActor::new(
                            ep.id(),
                            Arc::new(fixture.graph.clone()),
                            fixture.machines.clone(),
                            &part,
                            fixture.mu,
                            fixture.framework,
                            fixture.migration_charge,
                        );
                        Some(machine_loop(
                            actor,
                            &ep,
                            fixture.epsilon,
                            fixture.max_transfers,
                            fixture.recv_timeout,
                        ))
                    }
                    // Outer game: rack leaders play the quotient over a
                    // RackBus; everyone else spectates and still
                    // reports a (zero-delta) RoundStats below.
                    (1, Some(layout)) => {
                        if layout.is_leader(ep.id()) {
                            let rack = layout.rack_of(ep.id());
                            let qpart = Partition::from_assignment(
                                &fixture.graph,
                                layout.rack_count(),
                                layout.quotient_assignment(part.assignment()),
                            );
                            let actor = MachineActor::new(
                                rack,
                                Arc::new(fixture.graph.clone()),
                                layout.quotient_config(&fixture.machines),
                                &qpart,
                                fixture.mu,
                                fixture.framework,
                                fixture.migration_charge,
                            );
                            let bus = RackBus::new(&ep, rack, layout.leaders());
                            Some(machine_loop(
                                actor,
                                &bus,
                                fixture.epsilon,
                                fixture.max_transfers,
                                fixture.recv_timeout,
                            ))
                        } else {
                            None
                        }
                    }
                    // Inner game: the scoped ring of this machine's
                    // rack. Each rack's leader kicks its own ring (the
                    // cluster leader kicks its rack on its side).
                    (2, Some(layout)) => {
                        let scope = layout.members(layout.rack_of(ep.id())).to_vec();
                        let actor = MachineActor::new(
                            ep.id(),
                            Arc::new(fixture.graph.clone()),
                            fixture.machines.clone(),
                            &part,
                            fixture.mu,
                            fixture.framework,
                            fixture.migration_charge,
                        )
                        .with_scope(scope.clone());
                        if layout.is_leader(ep.id()) {
                            ep.send(
                                ep.id(),
                                Message::TakeMyTurn {
                                    consecutive_forfeits: 0,
                                    transfers_so_far: 0,
                                },
                            );
                        }
                        Some(machine_loop_scoped(
                            actor,
                            &ep,
                            &scope,
                            fixture.epsilon,
                            fixture.max_transfers,
                            fixture.recv_timeout,
                        ))
                    }
                    (1 | 2, None) => {
                        return Err(WireError::Protocol(format!(
                            "epoch {} opened phase {} but the fixture is flat",
                            e.epoch, e.phase
                        )))
                    }
                    (p, _) => {
                        return Err(WireError::Protocol(format!(
                            "epoch {} opened unknown phase {p}",
                            e.epoch
                        )))
                    }
                };
                let timed_out = outcome.as_ref().is_some_and(|o| o.timed_out);
                if let Some(o) = outcome.as_ref().filter(|o| o.timed_out) {
                    // A peer died mid-round. Do NOT unwind: report the
                    // round's stats anyway — that report is this
                    // worker's proof of life for the leader's death
                    // diagnosis — then wait for the leader's Restore.
                    eprintln!(
                        "gtip serve: epoch {} round lost a peer{}; awaiting restore",
                        e.epoch,
                        match o.dead_peer {
                            Some(m) => format!(" (machine {m})"),
                            None => String::new(),
                        }
                    );
                }
                if die == "stats" {
                    eprintln!("gtip serve: GTIP_SERVE_DIE=stats — dying before RoundStats");
                    std::process::exit(86);
                }
                let delta = ep.stats_snapshot().delta_since(&before);
                ep.send_ctrl(0, &Frame::RoundStats(delta))?;
                // A rack leader (other than the cluster leader's own
                // rack) ships its phase-2 ring outcome home: phase 2
                // never moves a node across racks, so only the owning
                // rack knows its nodes' final machines.
                if e.phase == 2 && !timed_out {
                    if let (Some(layout), Some(o)) = (&fixture.layout, &outcome) {
                        let rack = layout.rack_of(ep.id());
                        if layout.is_leader(ep.id()) && !layout.members(rack).contains(&0) {
                            let pairs = part
                                .assignment()
                                .iter()
                                .enumerate()
                                .filter(|&(_, &m)| layout.rack_of(m) == rack)
                                .map(|(i, _)| Ok((wire_u32(i)?, wire_u32(o.assignment[i])?)))
                                .collect::<Result<_, WireError>>()?;
                            ep.send_ctrl(
                                0,
                                &Frame::RackResult {
                                    rack: wire_u32(rack)?,
                                    transfers: o.transfers_applied,
                                    converged: o.converged,
                                    assignment: pairs,
                                },
                            )?;
                        }
                    }
                }
                // A hierarchical epoch spans phases 1 and 2; count it
                // once, when its second half completes.
                if !timed_out && e.phase != 1 {
                    epochs += 1;
                }
            }
            (0, Frame::Restore { survivors, speeds }) => {
                let wish: Vec<MachineId> =
                    survivors.iter().map(|&w| w as MachineId).collect();
                if speeds.len() != wish.len() {
                    return Err(WireError::Protocol(format!(
                        "restore has {} survivors but {} speeds",
                        wish.len(),
                        speeds.len()
                    )));
                }
                let speed_sum: f64 = speeds.iter().sum();
                if speeds.iter().any(|&s| !(s > 0.0)) || (speed_sum - 1.0).abs() > 1e-6 {
                    return Err(WireError::Protocol(format!(
                        "restore speeds are not normalized positive weights (sum {speed_sum})"
                    )));
                }
                if !wish.contains(&ep.wire_id()) {
                    // The leader evicted us — presumed dead (e.g. a
                    // transient stall past the grace window). Bow out
                    // cleanly; the survivors carry the run.
                    eprintln!(
                        "gtip serve: evicted by restore (wire id {}); exiting",
                        ep.wire_id()
                    );
                    break;
                }
                // Dead machines by *current* logical id — computed
                // before the compaction renumbers everything.
                let dead: Vec<MachineId> =
                    (0..ep.machine_count()).filter(|&m| !wish.contains(&ep.wire_of(m))).collect();
                ep.compact(&wish)?;
                ep.drain_inbox();
                fixture.machines = MachineConfig::from_normalized(speeds.clone());
                if let Some(l) = fixture.layout.take() {
                    fixture.layout =
                        Some(l.without_machines(&dead).map_err(WireError::Protocol)?);
                }
                ep.send_ctrl(0, &Frame::RestoreAck { machine: wire_u32(ep.wire_id())? })?;
                eprintln!(
                    "gtip serve: restored as machine {}/{} (wire id {})",
                    ep.id(),
                    ep.machine_count(),
                    ep.wire_id()
                );
            }
            (0, Frame::Admit { members, joiner, speeds, rack }) => {
                let members: Vec<MachineId> =
                    members.iter().map(|&w| w as MachineId).collect();
                let joiner = joiner as MachineId;
                if speeds.len() != members.len() {
                    return Err(WireError::Protocol(format!(
                        "admit has {} members but {} speeds",
                        members.len(),
                        speeds.len()
                    )));
                }
                let speed_sum: f64 = speeds.iter().sum();
                if speeds.iter().any(|&s| !(s > 0.0)) || (speed_sum - 1.0).abs() > 1e-6 {
                    return Err(WireError::Protocol(format!(
                        "admit speeds are not normalized positive weights (sum {speed_sum})"
                    )));
                }
                // Dial the joiner, accept its return dial, extend. A
                // failure here is NOT fatal: the joiner may have died
                // mid-admission. Stay on the old mesh and wait — the
                // leader's ack barrier will time out and broadcast a
                // rollback Restore, which the arm above handles (an
                // identity compact if we never extended).
                let deadline = Instant::now() + fixture.recv_timeout;
                match survivor_admit(&mut ep, addrs, &members, joiner, deadline) {
                    Ok(()) => {
                        ep.drain_inbox();
                        fixture.machines = MachineConfig::from_normalized(speeds.clone());
                        if let Some(l) = fixture.layout.take() {
                            // Mirror the leader's with_inserted: the
                            // joiner's logical id is its member-list
                            // position, its rack rides the frame.
                            let pos =
                                members.iter().position(|&w| w == joiner).ok_or_else(|| {
                                    WireError::Protocol(format!(
                                        "admit member list omits joiner {joiner}"
                                    ))
                                })?;
                            let r = if rack == u32::MAX {
                                l.join_rack()
                            } else {
                                rack as usize
                            };
                            fixture.layout =
                                Some(l.with_inserted(pos, r).map_err(WireError::Protocol)?);
                        }
                        ep.send_ctrl(
                            0,
                            &Frame::AdmitAck { machine: wire_u32(ep.wire_id())? },
                        )?;
                        eprintln!(
                            "gtip serve: admitted wire id {joiner}; now machine {}/{} (wire id {})",
                            ep.id(),
                            ep.machine_count(),
                            ep.wire_id()
                        );
                    }
                    Err(e) => {
                        eprintln!(
                            "gtip serve: admit of wire id {joiner} failed ({e}); awaiting rollback"
                        );
                    }
                }
            }
            (0, Frame::Goodbye) => break,
            (peer, frame) => {
                return Err(WireError::Protocol(format!(
                    "unexpected control frame from machine {peer}: {frame:?}"
                )))
            }
        }
    }
    Ok(ServeSummary {
        machine_id,
        epochs,
        overhead: ep.stats_snapshot(),
        control: ep.net_snapshot(),
    })
}

/// A survivor's half of an admission: dial the joiner, introduce
/// ourselves, accept the joiner's return dial on the retained mesh
/// listener, and extend the endpoint. The deadline is one receive
/// timeout — strictly shorter than the leader's ack-barrier patience,
/// so a dead joiner still leaves time to observe the rollback
/// `Restore` that follows.
fn survivor_admit(
    ep: &mut TcpEndpoint,
    addrs: &[String],
    members: &[MachineId],
    joiner: MachineId,
    deadline: Instant,
) -> Result<(), WireError> {
    if joiner >= addrs.len() {
        return Err(WireError::Protocol(format!(
            "admit names joiner {joiner} but the peer list has {} entries",
            addrs.len()
        )));
    }
    let mut out = dial_peer(&addrs[joiner], deadline)?;
    write_frame(
        &mut out,
        &Frame::Hello {
            version: WIRE_VERSION,
            machine: wire_u32(ep.wire_id())?,
            machines: wire_u32(addrs.len())?,
        },
    )?;
    let inbound = accept_wire_peer(&ep.listener, joiner, addrs.len(), deadline)?;
    ep.extend(members, joiner, out, inbound)
}

/// Accept connections on the retained (nonblocking) mesh listener
/// until the expected wire peer's `Hello` arrives. Strangers and
/// garbage handshakes are dropped with a note, exactly like the
/// original mesh accept; only the deadline fails the wait.
fn accept_wire_peer(
    listener: &TcpListener,
    expect_wire: MachineId,
    k_orig: usize,
    deadline: Instant,
) -> Result<TcpStream, WireError> {
    loop {
        match listener.accept() {
            Ok((mut stream, addr)) => {
                let hello = (|| -> Result<MachineId, WireError> {
                    stream.set_nonblocking(false)?;
                    let left = deadline.saturating_duration_since(Instant::now());
                    if left.is_zero() {
                        return Err(WireError::Protocol(
                            "handshake deadline already passed".into(),
                        ));
                    }
                    stream.set_read_timeout(Some(left))?;
                    match read_frame(&mut stream)? {
                        Frame::Hello { machine, machines, .. }
                            if machines as usize == k_orig =>
                        {
                            Ok(machine as MachineId)
                        }
                        frame => {
                            Err(WireError::Protocol(format!("expected Hello, got {frame:?}")))
                        }
                    }
                })();
                match hello {
                    Ok(peer) if peer == expect_wire => {
                        stream.set_read_timeout(None)?;
                        stream.set_nodelay(true)?;
                        return Ok(stream);
                    }
                    Ok(peer) => eprintln!(
                        "gtip net: dropping dial from machine {peer} while expecting {expect_wire}"
                    ),
                    Err(e) => {
                        eprintln!("gtip net: dropping inbound connection from {addr}: {e}")
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(WireError::Protocol(format!(
                        "timed out waiting for wire id {expect_wire}'s dial"
                    )));
                }
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// How long a turned-away joiner pauses before re-dialing the leader.
const JOIN_RETRY_PAUSE: Duration = Duration::from_millis(300);

/// Run a *joining* machine's side of the cluster: bind our listed
/// address, dial the leader with `Hello` + `Join`, wait (up to
/// `admit_window`) for the leader to dial back at an epoch boundary,
/// complete the mesh extension, check the `Setup` + `Catchup` the
/// leader ships, ack, and fall into the normal worker loop. This is
/// the body of `gtip serve --join`.
///
/// A rejection (`Goodbye`, or the leader simply closing the join
/// stream — e.g. the run predates wire v4, or the cluster is still
/// forming) is retried until `connect_timeout` runs out. Once a
/// `Join` has been *accepted into the queue* (neither rejected nor
/// closed) the joiner does NOT re-dial within the admit window:
/// re-dialing would queue a duplicate request whose leader-side
/// stream half is already dead.
pub fn serve_join(
    machine_id: MachineId,
    addrs: &[String],
    speed: f64,
    rack: Option<usize>,
    connect_timeout: Duration,
    admit_window: Duration,
) -> Result<ServeSummary, WireError> {
    if machine_id == 0 {
        return Err(WireError::Protocol(
            "machine 0 is the driver; it cannot join its own cluster".into(),
        ));
    }
    if machine_id >= addrs.len() {
        return Err(WireError::Protocol(format!(
            "--machine-id {machine_id} out of range for {} peers",
            addrs.len()
        )));
    }
    if !(speed.is_finite() && speed > 0.0) {
        return Err(WireError::Protocol(format!("--speed {speed} must be finite and positive")));
    }
    let k_orig = addrs.len();
    let die = std::env::var("GTIP_SERVE_DIE").unwrap_or_default();

    // Bind with retry: the predecessor we replace may hold the port
    // until its process is fully reaped.
    let bind_deadline = Instant::now() + connect_timeout;
    let listener = loop {
        match TcpListener::bind(addrs[machine_id].as_str()) {
            Ok(l) => break l,
            Err(e) => {
                if Instant::now() >= bind_deadline {
                    return Err(WireError::Io(format!("binding {}: {e}", addrs[machine_id])));
                }
                std::thread::sleep(JOIN_RETRY_PAUSE);
            }
        }
    };
    listener.set_nonblocking(true)?;

    let overall = Instant::now() + connect_timeout;
    // Members' dials that complete before the leader's own — separate
    // connections have no ordering guarantee — are stashed here.
    let mut stash: Vec<(MachineId, TcpStream)> = Vec::new();
    let no_peer_seen = vec![false; k_orig];
    let (leader_out, leader_in) = 'attempt: loop {
        let mut out = dial_peer(&addrs[0], overall)?;
        write_frame(
            &mut out,
            &Frame::Hello {
                version: WIRE_VERSION,
                machine: wire_u32(machine_id)?,
                machines: wire_u32(k_orig)?,
            },
        )?;
        let rack_wire = match rack {
            Some(r) => {
                let w = wire_u32(r)?;
                if w == u32::MAX {
                    return Err(WireError::Protocol(format!("--rack {r} is reserved")));
                }
                w
            }
            None => u32::MAX,
        };
        write_frame(
            &mut out,
            &Frame::Join { machine: wire_u32(machine_id)?, speed, rack: rack_wire },
        )?;
        out.set_nonblocking(true)?;
        eprintln!(
            "gtip serve: join request sent (wire id {machine_id}, speed {speed}); waiting for admission"
        );
        let wait_deadline = Instant::now() + admit_window;
        loop {
            // Rejection check: the leader writes Goodbye (or just
            // closes the stream) to turn us down.
            let mut peeked = [0u8; 1];
            let rejected = match out.peek(&mut peeked) {
                Ok(0) => Some("join stream closed".to_string()),
                Ok(_) => {
                    out.set_nonblocking(false)?;
                    out.set_read_timeout(Some(JOIN_HANDSHAKE_TIMEOUT))?;
                    match read_frame(&mut out) {
                        Ok(Frame::Goodbye) => Some("join rejected by the leader".to_string()),
                        Err(WireError::Closed) => Some("join stream closed".to_string()),
                        Ok(frame) => {
                            return Err(WireError::Protocol(format!(
                                "unexpected frame on the join stream: {frame:?}"
                            )))
                        }
                        Err(e) => return Err(e),
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => None,
                Err(e) => Some(format!("join stream error: {e}")),
            };
            if let Some(why) = rejected {
                if Instant::now() >= overall {
                    return Err(WireError::Protocol(format!(
                        "{why}; connect window exhausted"
                    )));
                }
                eprintln!("gtip serve: {why}; retrying");
                std::thread::sleep(JOIN_RETRY_PAUSE);
                continue 'attempt;
            }
            // Admission check: the leader dials our listener first,
            // then the other members (whose dials may still arrive in
            // any order relative to the leader's).
            match listener.accept() {
                Ok((stream, addr)) => {
                    let deadline = Instant::now() + JOIN_HANDSHAKE_TIMEOUT;
                    match handshake_inbound(stream, machine_id, k_orig, deadline, &no_peer_seen)
                    {
                        Ok((0, stream)) => break 'attempt (out, stream),
                        Ok((peer, stream)) => {
                            if stash.iter().any(|(p, _)| *p == peer) {
                                eprintln!(
                                    "gtip serve: dropping duplicate dial from machine {peer}"
                                );
                            } else {
                                stash.push((peer, stream));
                            }
                        }
                        Err(e) => {
                            eprintln!("gtip serve: dropping inbound connection from {addr}: {e}")
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
                Err(e) => return Err(e.into()),
            }
            if Instant::now() >= wait_deadline {
                return Err(WireError::Protocol(format!(
                    "not admitted within the {admit_window:?} admit window"
                )));
            }
            std::thread::sleep(ACCEPT_POLL);
        }
    };

    let mut leader_out = leader_out;
    leader_out.set_nonblocking(false)?;
    let mut leader_in = leader_in;
    // The Admit broadcast follows the leader's dial immediately.
    leader_in.set_read_timeout(Some(admit_window))?;
    let admit = read_frame(&mut leader_in)?;
    // The joiner's rack arrives again inside the fresh Setup's full
    // machine → rack map, so the Admit copy is redundant here.
    let Frame::Admit { members, joiner, speeds, rack: _ } = admit else {
        return Err(WireError::Protocol(format!("expected Admit, got {admit:?}")));
    };
    if joiner as MachineId != machine_id {
        return Err(WireError::Protocol(format!(
            "admit names joiner {joiner}, we are {machine_id}"
        )));
    }
    let members: Vec<MachineId> = members.iter().map(|&w| w as MachineId).collect();
    if members.len() < 2
        || !members.windows(2).all(|w| w[0] < w[1])
        || *members.last().expect("non-empty") >= k_orig
        || members[0] != 0
        || !members.contains(&machine_id)
    {
        return Err(WireError::Protocol(format!("admit member list {members:?} is invalid")));
    }
    if speeds.len() != members.len() {
        return Err(WireError::Protocol(format!(
            "admit has {} members but {} speeds",
            members.len(),
            speeds.len()
        )));
    }
    if die == "admit" {
        eprintln!("gtip serve: GTIP_SERVE_DIE=admit — dying on Admit");
        std::process::exit(86);
    }
    leader_in.set_read_timeout(None)?;

    // Complete the mesh: dial every other member, collect their dials
    // (some may already be stashed from the wait loop).
    let deadline = Instant::now() + admit_window;
    let mut outs: Vec<Option<Mutex<TcpStream>>> = (0..k_orig).map(|_| None).collect();
    outs[0] = Some(Mutex::new(leader_out));
    for &m in &members {
        if m == 0 || m == machine_id {
            continue;
        }
        let mut s = dial_peer(&addrs[m], deadline)?;
        write_frame(
            &mut s,
            &Frame::Hello {
                version: WIRE_VERSION,
                machine: wire_u32(machine_id)?,
                machines: wire_u32(k_orig)?,
            },
        )?;
        outs[m] = Some(Mutex::new(s));
    }
    let expected: Vec<MachineId> =
        members.iter().copied().filter(|&m| m != 0 && m != machine_id).collect();
    let mut have: Vec<(MachineId, TcpStream)> = Vec::new();
    for (peer, stream) in stash {
        if expected.contains(&peer) && !have.iter().any(|(p, _)| *p == peer) {
            have.push((peer, stream));
        }
    }
    while have.len() < expected.len() {
        match listener.accept() {
            Ok((stream, addr)) => {
                match handshake_inbound(stream, machine_id, k_orig, deadline, &no_peer_seen) {
                    Ok((peer, stream))
                        if expected.contains(&peer) && !have.iter().any(|(p, _)| *p == peer) =>
                    {
                        have.push((peer, stream))
                    }
                    Ok((peer, _)) => {
                        eprintln!("gtip serve: dropping unexpected dial from machine {peer}")
                    }
                    Err(e) => {
                        eprintln!("gtip serve: dropping inbound connection from {addr}: {e}")
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(WireError::Protocol(format!(
                        "timed out waiting for member dials (have {}/{})",
                        have.len(),
                        expected.len()
                    )));
                }
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(e) => return Err(e.into()),
        }
    }

    // Hand-build the endpoint — the mesh helper assumes a full K-way
    // dial, but a joiner's mesh is the admitted membership.
    let pos = members.iter().position(|&w| w == machine_id).expect("validated above");
    let (inbox_tx, inbox) = channel();
    let (ctrl_tx, ctrl) = channel();
    spawn_reader(leader_in, 0, inbox_tx.clone(), ctrl_tx.clone());
    for (peer, stream) in have {
        spawn_reader(stream, peer, inbox_tx.clone(), ctrl_tx.clone());
    }
    let mut logical_of = vec![None; k_orig];
    for (logical, &wire) in members.iter().enumerate() {
        logical_of[wire] = Some(logical);
    }
    let ep = TcpEndpoint {
        id: pos,
        k: members.len(),
        wire_id: machine_id,
        wire_of: members.clone(),
        logical_of,
        inbox,
        inbox_tx,
        ctrl,
        ctrl_tx,
        listener,
        outs,
        stats: Arc::new(Mutex::new(OverheadStats::default())),
        net: Arc::new(Mutex::new(NetStats::default())),
        failures: Mutex::new(SendFailures::default()),
    };

    // Fixture + catch-up snapshot, then ack the admission.
    let setup = match ep.recv_ctrl(admit_window)? {
        (0, Frame::Setup(s)) => s,
        (peer, frame) => {
            return Err(WireError::Protocol(format!(
                "expected Setup from the leader, got {frame:?} from machine {peer}"
            )))
        }
    };
    let fixture = WorkerFixture::from_setup(&setup, members.len())?;
    match ep.recv_ctrl(admit_window)? {
        (0, Frame::Catchup { snapshot }) => {
            let snap = crate::sim::Snapshot::decode(&snapshot)
                .map_err(|e| WireError::Protocol(format!("catch-up snapshot: {e}")))?;
            snap.validate_catchup(members.len(), fixture.graph.node_weights().len())
                .map_err(WireError::Protocol)?;
            eprintln!("gtip serve: caught up from {}", snap.summary());
        }
        (peer, frame) => {
            return Err(WireError::Protocol(format!(
                "expected Catchup from the leader, got {frame:?} from machine {peer}"
            )))
        }
    }
    ep.send_ctrl(0, &Frame::AdmitAck { machine: wire_u32(machine_id)? })?;
    eprintln!("gtip serve: admitted as machine {pos}/{} (wire id {machine_id})", members.len());
    run_worker_loop(ep, addrs, fixture, &die)
}

/// Weights arriving off the wire must be finite and non-negative —
/// the graph constructors assert exactly that, and a worker must turn
/// a bad leader into a protocol error, not an abort.
fn weights_valid(ws: &[f64]) -> bool {
    weights_valid_iter(ws.iter().copied())
}

fn weights_valid_iter(mut ws: impl Iterator<Item = f64>) -> bool {
    ws.all(|w| w.is_finite() && w >= 0.0)
}

/// Parse a `host:port,host:port,...` peers list (shared by the
/// `serve` and `dynamic --transport tcp` CLI paths).
pub fn parse_peers(spec: &str) -> Result<Vec<String>, WireError> {
    let peers: Vec<String> =
        spec.split(',').map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect();
    if peers.len() < 2 {
        return Err(WireError::Protocol(format!(
            "--peers needs at least 2 comma-separated host:port entries, got {spec:?}"
        )));
    }
    let mut seen = BTreeMap::new();
    for (i, p) in peers.iter().enumerate() {
        if !p.contains(':') {
            return Err(WireError::Protocol(format!("peer {p:?} is not host:port")));
        }
        if let Some(first) = seen.insert(p.clone(), i) {
            return Err(WireError::Protocol(format!(
                "peer {p:?} listed twice (positions {first} and {i})"
            )));
        }
    }
    Ok(peers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::distributed::{run_distributed, run_distributed_hierarchical};
    use crate::graph::generators::{table1_graph, WeightModel};
    use crate::util::rng::Pcg32;

    fn all_message_shapes() -> Vec<Message> {
        vec![
            Message::TakeMyTurn { consecutive_forfeits: 3, transfers_so_far: 17 },
            Message::ReceiveNode { seq: 9, node: 1234, from: 2, to: 0 },
            Message::RegularUpdate {
                seq: 10,
                node: 7,
                from: 1,
                to: 3,
                loads: vec![0.25, -1.5, 3.75, f64::MAX, 0.0],
            },
            Message::RackUpdate { seq: 11, node: 8, from: 0, to: 1, rack_loads: vec![0.5, 1.5] },
            Message::Shutdown { total_transfers: 42, converged: true },
            Message::Shutdown { total_transfers: 7, converged: false },
        ]
    }

    #[test]
    fn message_round_trip_and_exact_sizes() {
        for msg in all_message_shapes() {
            let bytes = encode_frame(&Frame::Msg(msg.clone())).unwrap();
            assert_eq!(bytes.len(), msg.wire_bytes(), "{}", msg.tag());
            let decoded = decode_payload(&bytes[4..]).unwrap();
            assert_eq!(decoded, Frame::Msg(msg));
        }
    }

    #[test]
    fn control_frames_round_trip() {
        let frames = vec![
            Frame::Hello { version: WIRE_VERSION, machine: 2, machines: 5 },
            Frame::Setup(SetupFrame {
                speeds: vec![0.25, 0.75],
                mu: 8.0,
                framework: Framework::B,
                migration_charge: 3.25,
                epsilon: 1e-9,
                max_transfers: 1_000_000,
                recv_timeout_ms: 30_000,
                node_weights: vec![1.0, 2.0, 3.0],
                edges: vec![(0, 1, 1.5), (1, 2, 2.5)],
                racks: vec![0, 1],
            }),
            Frame::EpochBegin(EpochFrame {
                epoch: 4,
                phase: 2,
                node_weights: vec![0.5; 3],
                edge_weights: vec![1.0, 2.0],
                assignment: vec![0, 1, 0],
            }),
            Frame::RoundStats(OverheadStats {
                take_my_turn: Counter { messages: 5, bytes: 105 },
                ..Default::default()
            }),
            Frame::Restore { survivors: vec![0, 2, 3], speeds: vec![0.25, 0.25, 0.5] },
            Frame::Join { machine: 4, speed: 0.125, rack: u32::MAX },
            Frame::Join { machine: 5, speed: 0.5, rack: 1 },
            Frame::RestoreAck { machine: 3 },
            Frame::Admit {
                members: vec![0, 2, 3],
                joiner: 2,
                speeds: vec![0.25, 0.25, 0.5],
                rack: 1,
            },
            Frame::RackResult {
                rack: 1,
                transfers: 3,
                converged: true,
                assignment: vec![(5, 2), (9, 3)],
            },
            Frame::RackResult { rack: 0, transfers: 0, converged: false, assignment: vec![] },
            Frame::AdmitAck { machine: 2 },
            Frame::Catchup { snapshot: vec![] },
            Frame::Catchup { snapshot: vec![0xDE, 0xAD, 0xBE, 0xEF] },
            Frame::Goodbye,
        ];
        for f in frames {
            let bytes = encode_frame(&f).unwrap();
            assert_eq!(decode_payload(&bytes[4..]).unwrap(), f);
        }
    }

    /// A `Catchup` whose declared snapshot length exceeds the actual
    /// payload must be a clean truncation error, not a panic or a
    /// huge-allocation attempt.
    #[test]
    fn lying_catchup_length_is_truncation_not_panic() {
        let mut payload = vec![TAG_CATCHUP];
        put_u32(&mut payload, 100); // claims 100 snapshot bytes...
        payload.extend_from_slice(&[0u8; 10]); // ...carries 10
        assert!(matches!(decode_payload(&payload), Err(WireError::Truncated { .. })));
    }

    /// Node/machine ids that do not fit the u32 wire format must come
    /// back as a clean error from the encoder, not a silent truncation.
    #[test]
    fn oversize_ids_rejected_at_encode_time() {
        if std::mem::size_of::<usize>() <= 4 {
            return; // the bug cannot exist on 32-bit targets
        }
        let huge = u32::MAX as usize + 1;
        let msg = Message::ReceiveNode { seq: 0, node: 1, from: huge, to: 0 };
        assert!(encode_frame(&Frame::Msg(msg)).is_err());
        assert!(wire_u32(huge).is_err());
        assert_eq!(wire_u32(u32::MAX as usize).unwrap(), u32::MAX);
    }

    #[test]
    fn truncated_frames_error_cleanly() {
        for msg in all_message_shapes() {
            let bytes = encode_frame(&Frame::Msg(msg)).unwrap();
            // Every strict prefix of the payload must fail without
            // panicking.
            for cut in 0..bytes.len() - 4 {
                assert!(
                    decode_payload(&bytes[4..4 + cut]).is_err(),
                    "prefix of {cut} bytes decoded"
                );
            }
        }
    }

    #[test]
    fn trailing_garbage_rejected() {
        let mut bytes = encode_frame(&Frame::Goodbye).unwrap();
        bytes.push(0xFF);
        assert!(matches!(
            decode_payload(&bytes[4..]),
            Err(WireError::TrailingBytes { extra: 1 })
        ));
    }

    #[test]
    fn bad_tag_and_oversized_rejected() {
        assert!(matches!(decode_payload(&[0xEE]), Err(WireError::BadTag(0xEE))));
        // Oversized length prefix rejected before allocation.
        let mut stream = Vec::new();
        put_u32(&mut stream, (MAX_FRAME_BYTES + 1) as u32);
        let mut cursor = &stream[..];
        assert!(matches!(read_frame(&mut cursor), Err(WireError::Oversized { .. })));
    }

    #[test]
    fn lying_vector_length_is_truncation_not_panic() {
        // RegularUpdate claiming 1000 loads but carrying none.
        let mut payload = vec![TAG_REGULAR_UPDATE];
        put_u64(&mut payload, 0);
        put_u64(&mut payload, 1);
        put_u32(&mut payload, 0);
        put_u32(&mut payload, 1);
        put_u32(&mut payload, 1000);
        assert!(matches!(decode_payload(&payload), Err(WireError::Truncated { .. })));
    }

    #[test]
    fn handshake_version_and_magic_enforced() {
        let mut payload = vec![TAG_HELLO];
        payload.extend_from_slice(b"NOPE");
        put_u16(&mut payload, WIRE_VERSION);
        put_u32(&mut payload, 0);
        put_u32(&mut payload, 2);
        assert!(matches!(decode_payload(&payload), Err(WireError::BadMagic)));

        let mut payload = vec![TAG_HELLO];
        payload.extend_from_slice(&WIRE_MAGIC);
        put_u16(&mut payload, WIRE_VERSION + 1);
        put_u32(&mut payload, 0);
        put_u32(&mut payload, 2);
        assert!(matches!(decode_payload(&payload), Err(WireError::BadVersion { .. })));
    }

    #[test]
    fn parse_peers_validates() {
        let ok = parse_peers("127.0.0.1:7000, 127.0.0.1:7001,127.0.0.1:7002").unwrap();
        assert_eq!(ok.len(), 3);
        assert!(parse_peers("127.0.0.1:7000").is_err());
        assert!(parse_peers("localhost,also-no-port").is_err());
        assert!(parse_peers("h:1,h:1").is_err());
    }

    #[test]
    fn tcp_loopback_mesh_delivers_and_counts_exact_bytes() {
        let (eps, stats) = build_tcp_bus_local(3).unwrap();
        let msg = Message::RegularUpdate { seq: 0, node: 5, from: 0, to: 2, loads: vec![1.0; 3] };
        eps[0].send(1, msg.clone());
        match eps[1].recv_timeout(Duration::from_secs(5)) {
            RecvOutcome::Msg(got) => assert_eq!(got, msg),
            other => panic!("no delivery: {other:?}"),
        }
        let s = stats.lock().unwrap();
        assert_eq!(s.regular_update.messages, 1);
        assert_eq!(s.regular_update.bytes, msg.wire_bytes() as u64);
    }

    #[test]
    fn tcp_local_refinement_matches_in_process_exactly() {
        let mut rng = Pcg32::new(8);
        let g = Arc::new(table1_graph(50, 3, 6, WeightModel::default(), &mut rng));
        let machines = MachineConfig::from_speeds(&[0.2, 0.3, 0.5]);
        let assignment: Vec<usize> = (0..50).map(|_| rng.index(3)).collect();
        let part = Partition::from_assignment(&g, 3, assignment);
        let opts = DistributedOptions::default();

        let inproc = run_distributed(Arc::clone(&g), &machines, part.clone(), &opts);
        let tcp = run_distributed_tcp_local(Arc::clone(&g), &machines, part, &opts).unwrap();
        assert_eq!(tcp.partition.assignment(), inproc.partition.assignment());
        assert_eq!(tcp.transfers, inproc.transfers);
        assert_eq!(tcp.overhead, inproc.overhead, "wire accounting must be transport-invariant");
        assert_eq!(tcp.converged, inproc.converged);
    }

    /// The migration charge is transport-invariant too: a nonzero
    /// charge over real sockets reproduces the in-process augmented
    /// game bit-for-bit (assignment, transfers, wire accounting).
    #[test]
    fn charged_tcp_matches_in_process_exactly() {
        let mut rng = Pcg32::new(12);
        let g = Arc::new(table1_graph(50, 3, 6, WeightModel::default(), &mut rng));
        let machines = MachineConfig::from_speeds(&[0.2, 0.3, 0.5]);
        let assignment: Vec<usize> = (0..50).map(|_| rng.index(3)).collect();
        let part = Partition::from_assignment(&g, 3, assignment);
        let opts = DistributedOptions { migration_charge: 4.0, ..Default::default() };

        let inproc = run_distributed(Arc::clone(&g), &machines, part.clone(), &opts);
        let tcp = run_distributed_tcp_local(Arc::clone(&g), &machines, part, &opts).unwrap();
        assert_eq!(tcp.partition.assignment(), inproc.partition.assignment());
        assert_eq!(tcp.transfers, inproc.transfers);
        assert_eq!(tcp.overhead, inproc.overhead);
        assert!(tcp.converged && inproc.converged);
    }

    /// The two-level hierarchy is transport-invariant too: the TCP
    /// wiring of the phased epoch (RackBus over real sockets, scoped
    /// inner rings) reproduces the in-process hierarchical run
    /// bit-for-bit — assignment, transfers, wire accounting on both
    /// levels, convergence.
    #[test]
    fn hierarchical_tcp_matches_in_process_exactly() {
        let mut rng = Pcg32::new(8);
        let g = Arc::new(table1_graph(50, 3, 6, WeightModel::default(), &mut rng));
        let machines = MachineConfig::from_speeds(&[0.2, 0.3, 0.3, 0.2]);
        let assignment: Vec<usize> = (0..50).map(|_| rng.index(4)).collect();
        let part = Partition::from_assignment(&g, 4, assignment);
        let layout = RackLayout::new(vec![0, 0, 1, 1]).unwrap();
        let opts = DistributedOptions::default();

        let inproc =
            run_distributed_hierarchical(Arc::clone(&g), &machines, part.clone(), &layout, &opts);
        let tcp =
            run_distributed_hierarchical_tcp_local(Arc::clone(&g), &machines, part, &layout, &opts)
                .unwrap();
        assert_eq!(tcp.partition.assignment(), inproc.partition.assignment());
        assert_eq!(tcp.transfers, inproc.transfers);
        assert_eq!(tcp.overhead, inproc.overhead, "wire accounting must be transport-invariant");
        assert_eq!(tcp.converged, inproc.converged);
    }

    /// Singleton racks over TCP degenerate to the flat TCP game
    /// bit-for-bit on the assignment (the hierarchy's identity
    /// baseline, DESIGN.md §12, carried across the wire).
    #[test]
    fn singleton_racks_hierarchical_tcp_matches_flat_tcp() {
        let mut rng = Pcg32::new(12);
        let g = Arc::new(table1_graph(50, 3, 6, WeightModel::default(), &mut rng));
        let machines = MachineConfig::from_speeds(&[0.2, 0.3, 0.5]);
        let assignment: Vec<usize> = (0..50).map(|_| rng.index(3)).collect();
        let part = Partition::from_assignment(&g, 3, assignment);
        let layout = RackLayout::singletons(3);
        let opts = DistributedOptions::default();

        let flat =
            run_distributed_tcp_local(Arc::clone(&g), &machines, part.clone(), &opts).unwrap();
        let hier =
            run_distributed_hierarchical_tcp_local(Arc::clone(&g), &machines, part, &layout, &opts)
                .unwrap();
        assert_eq!(hier.partition.assignment(), flat.partition.assignment());
        assert_eq!(hier.transfers, flat.transfers);
        assert_eq!(hier.converged, flat.converged);
    }

    /// A `RackResult` whose declared assignment length exceeds the
    /// actual payload must be a clean truncation error, not a panic or
    /// a huge-allocation attempt.
    #[test]
    fn lying_rack_result_length_is_truncation_not_panic() {
        let mut payload = vec![TAG_RACK_RESULT];
        put_u32(&mut payload, 1); // rack
        payload.extend_from_slice(&3u64.to_le_bytes()); // transfers
        payload.push(1); // converged
        put_u32(&mut payload, 1000); // claims 1000 pairs...
        payload.extend_from_slice(&[0u8; 16]); // ...carries 2
        assert!(matches!(decode_payload(&payload), Err(WireError::Truncated { .. })));
    }

    /// The dial loop must keep retrying until the deadline itself has
    /// passed. The old `now + backoff >= deadline` check surrendered
    /// one whole backoff early: against a refusing port with a 300 ms
    /// deadline it gave up at ~175 ms (25+50+100 slept, next backoff
    /// 200 crossing the line). The fix retries into the final window.
    #[test]
    fn dial_retries_until_the_deadline_itself() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener); // now the port refuses connections
        let start = Instant::now();
        let deadline = start + Duration::from_millis(300);
        assert!(dial_peer(&addr, deadline).is_err());
        assert!(
            start.elapsed() >= Duration::from_millis(250),
            "dial gave up a backoff early: {:?}",
            start.elapsed()
        );
    }

    /// A panic while holding the shared stats lock must not take the
    /// whole endpoint down with `expect("poisoned")` — the guard is
    /// recovered and traffic keeps flowing.
    #[test]
    fn poisoned_stats_lock_recovers() {
        let (eps, stats) = build_tcp_bus_local(2).unwrap();
        let poisoner = Arc::clone(&stats);
        let _ = std::thread::spawn(move || {
            let _guard = poisoner.lock().unwrap();
            panic!("poison the stats lock");
        })
        .join();
        assert!(stats.lock().is_err(), "lock should be poisoned");

        let msg = Message::TakeMyTurn { consecutive_forfeits: 0, transfers_so_far: 0 };
        eps[0].send(1, msg.clone());
        match eps[1].recv_timeout(Duration::from_secs(5)) {
            RecvOutcome::Msg(got) => assert_eq!(got, msg),
            other => panic!("no delivery through poisoned lock: {other:?}"),
        }
        assert_eq!(eps[0].stats_snapshot().take_my_turn.messages, 1);
    }

    /// An unsendable message surfaces as `SendFailed` at the sender's
    /// next receive instead of the peer silently never hearing from us.
    #[test]
    fn send_failure_surfaces_instead_of_silence() {
        if std::mem::size_of::<usize>() <= 4 {
            return;
        }
        let (eps, _stats) = build_tcp_bus_local(2).unwrap();
        let huge = u32::MAX as usize + 1;
        eps[0].send(1, Message::ReceiveNode { seq: 0, node: 0, from: huge, to: 1 });
        match eps[0].recv_timeout(Duration::from_millis(10)) {
            RecvOutcome::SendFailed(1) => {}
            other => panic!("expected SendFailed(1), got {other:?}"),
        }
        assert!(eps[0].take_send_failures().contains_key(&1));
    }

    /// Compaction renumbers the survivors densely and re-routes both
    /// planes (protocol + control) through the new logical ids.
    #[test]
    fn compact_renumbers_and_reroutes() {
        let (mut eps, _stats) = build_tcp_bus_local(3).unwrap();
        let mut ep2 = eps.pop().unwrap();
        let ep1 = eps.pop().unwrap();
        let mut ep0 = eps.pop().unwrap();
        drop(ep1); // wire machine 1 dies

        ep0.compact(&[0, 2]).unwrap();
        ep2.compact(&[0, 2]).unwrap();
        assert_eq!((ep0.id(), ep0.machine_count()), (0, 2));
        assert_eq!((ep2.id(), ep2.machine_count()), (1, 2));
        assert_eq!(ep2.wire_id(), 2);

        let msg = Message::TakeMyTurn { consecutive_forfeits: 1, transfers_so_far: 2 };
        ep0.send(1, msg.clone()); // logical 1 now means wire 2
        match ep2.recv_timeout(Duration::from_secs(5)) {
            RecvOutcome::Msg(got) => assert_eq!(got, msg),
            other => panic!("no delivery after compaction: {other:?}"),
        }

        ep2.send_ctrl(0, &Frame::RestoreAck { machine: 2 }).unwrap();
        match ep2.recv_ctrl(Duration::from_millis(50)) {
            Err(WireError::Protocol(_)) => {} // nothing inbound for ep2
            other => panic!("unexpected ctrl on ep2: {other:?}"),
        }
        match ep0.recv_ctrl(Duration::from_secs(5)).unwrap() {
            (1, Frame::RestoreAck { machine: 2 }) => {}
            other => panic!("bad ctrl routing after compaction: {other:?}"),
        }

        // Compaction rejects nonsense survivor lists.
        assert!(ep0.compact(&[]).is_err());
        assert!(ep0.compact(&[2, 0]).is_err());
        assert!(ep0.compact(&[2]).is_err()); // missing this machine
        assert!(ep0.compact(&[0, 7]).is_err()); // out of range
    }

    /// A connected loopback socket pair — stands in for the joiner's
    /// dial / the survivor's dial-back during an admission.
    fn stream_pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let dialed = TcpStream::connect(addr).unwrap();
        let (accepted, _) = listener.accept().unwrap();
        (dialed, accepted)
    }

    /// Extension is the exact mirror of compaction: after an eviction
    /// to [0, 2], wire 1 is re-admitted and both planes (protocol +
    /// control) route through the re-grown logical ids — including the
    /// fresh streams to/from the joiner. Bad member lists and joins
    /// for still-active wire ids are rejected without disturbing the
    /// mesh.
    #[test]
    fn extend_readmits_and_reroutes() {
        let (mut eps, _stats) = build_tcp_bus_local(3).unwrap();
        let mut ep2 = eps.pop().unwrap();
        let ep1 = eps.pop().unwrap();
        let mut ep0 = eps.pop().unwrap();
        drop(ep1); // wire machine 1 dies
        ep0.compact(&[0, 2]).unwrap();
        ep2.compact(&[0, 2]).unwrap();

        // Rejection cases first — none of these may touch the mesh.
        let (out, inbound) = stream_pair();
        assert!(ep0.extend(&[0, 1], 1, out, inbound).is_err(), "members minus joiner != mesh");
        let (out, inbound) = stream_pair();
        assert!(ep0.extend(&[0, 1, 2], 2, out, inbound).is_err(), "joiner 2 is still active");
        let (out, inbound) = stream_pair();
        assert!(ep0.extend(&[0, 1, 2], 0, out, inbound).is_err(), "joiner 0 is this machine");
        let (out, inbound) = stream_pair();
        assert!(ep0.extend(&[0, 2], 1, out, inbound).is_err(), "joiner missing from members");
        let (out, inbound) = stream_pair();
        assert!(ep0.extend(&[0, 1, 7], 1, out, inbound).is_err(), "wire id out of range");
        assert_eq!((ep0.id(), ep0.machine_count()), (0, 2), "failed extends must not mutate");
        assert!(!ep0.wire_is_active(1));

        // The real re-admission: wire 1 rejoins on fresh socket pairs.
        let (joiner_to_0, inbound0) = stream_pair();
        let (out0, joiner_from_0) = stream_pair();
        ep0.extend(&[0, 1, 2], 1, out0, inbound0).unwrap();
        let (joiner_to_2, inbound2) = stream_pair();
        let (out2, _joiner_from_2) = stream_pair();
        ep2.extend(&[0, 1, 2], 1, out2, inbound2).unwrap();
        assert_eq!((ep0.id(), ep0.machine_count()), (0, 3));
        assert_eq!((ep2.id(), ep2.machine_count()), (2, 3));
        assert!(ep0.wire_is_active(1));

        // Protocol plane, outbound: logical 1 now reaches the joiner.
        let msg = Message::TakeMyTurn { consecutive_forfeits: 3, transfers_so_far: 4 };
        ep0.send(1, msg.clone());
        let mut joiner_rx = joiner_from_0;
        match read_frame(&mut joiner_rx).unwrap() {
            Frame::Msg(got) => assert_eq!(got, msg),
            other => panic!("joiner expected the protocol message, got {other:?}"),
        }

        // Protocol plane, inbound: the joiner's traffic lands in the
        // survivor's inbox tagged with the re-grown logical id.
        let msg = Message::TakeMyTurn { consecutive_forfeits: 5, transfers_so_far: 6 };
        let mut joiner_tx = joiner_to_2;
        joiner_tx.write_all(&encode_frame(&Frame::Msg(msg.clone())).unwrap()).unwrap();
        match ep2.recv_timeout(Duration::from_secs(5)) {
            RecvOutcome::Msg(got) => assert_eq!(got, msg),
            other => panic!("no delivery from the joiner after extension: {other:?}"),
        }

        // Control plane: the joiner's AdmitAck arrives as logical 1.
        let mut joiner_ctrl = joiner_to_0;
        joiner_ctrl
            .write_all(&encode_frame(&Frame::AdmitAck { machine: 1 }).unwrap())
            .unwrap();
        match ep0.recv_ctrl(Duration::from_secs(5)).unwrap() {
            (1, Frame::AdmitAck { machine: 1 }) => {}
            other => panic!("bad ctrl routing after extension: {other:?}"),
        }

        // And the survivors' original streams still route: wire 2 is
        // logical 2 again.
        ep2.send_ctrl(0, &Frame::RestoreAck { machine: 2 }).unwrap();
        match ep0.recv_ctrl(Duration::from_secs(5)).unwrap() {
            (2, Frame::RestoreAck { machine: 2 }) => {}
            other => panic!("survivor ctrl lost after extension: {other:?}"),
        }

        // A second extend for the now-active joiner must be refused.
        let (out, inbound) = stream_pair();
        assert!(ep0.extend(&[0, 1, 2], 1, out, inbound).is_err(), "joiner 1 is now active");
    }

    /// The handshake must fail *immediately* once its deadline has
    /// passed — even for a peer whose valid `Hello` is already sitting
    /// in the socket buffer. The old code clamped the remaining window
    /// up to 1 ms and read anyway, letting connect-spamming peers
    /// stretch the accept loop past the recovery grace-window bound.
    #[test]
    fn handshake_rejects_once_the_deadline_has_passed() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        // The Hello itself is perfectly valid and already delivered...
        let hello =
            encode_frame(&Frame::Hello { version: WIRE_VERSION, machine: 1, machines: 2 })
                .unwrap();
        client.write_all(&hello).unwrap();
        client.flush().unwrap();
        // ...but the deadline expired before the accept got to it.
        let deadline = Instant::now();
        std::thread::sleep(Duration::from_millis(5));
        let start = Instant::now();
        let result = handshake_inbound(stream, 0, 2, deadline, &[false; 2]);
        assert!(result.is_err(), "an expired deadline must reject even a valid Hello");
        assert!(
            start.elapsed() < Duration::from_secs(1),
            "the rejection must be immediate, not a blocking read: {:?}",
            start.elapsed()
        );
    }

    /// A worker whose leader goes silent (alive socket, no frames) must
    /// give up after the *derived* epoch wait — ten receive timeouts,
    /// floored at 5 s — not the old hard-coded 600 s. With a 200 ms
    /// fixture timeout the floor governs: the worker exits in ~5 s.
    #[test]
    fn silent_leader_bounds_the_workers_wait() {
        assert_eq!(epoch_wait(Duration::from_millis(200)), Duration::from_secs(5));
        assert_eq!(epoch_wait(Duration::from_secs(2)), Duration::from_secs(20));
        assert_eq!(epoch_wait(Duration::MAX), Duration::MAX); // saturates, no overflow

        let (mut eps, _stats) = build_tcp_bus_local(2).unwrap();
        let ep1 = eps.pop().unwrap();
        let _ep0 = eps.pop().unwrap(); // the leader: alive but silent
        let setup = SetupFrame {
            speeds: vec![0.5, 0.5],
            mu: 8.0,
            framework: Framework::A,
            migration_charge: 0.0,
            epsilon: 1e-9,
            max_transfers: 1000,
            recv_timeout_ms: 200,
            node_weights: vec![1.0, 1.0],
            edges: vec![(0, 1, 1.0)],
            racks: vec![],
        };
        let fixture = WorkerFixture::from_setup(&setup, 2).unwrap();
        let addrs: Vec<String> = vec!["127.0.0.1:1".into(), "127.0.0.1:2".into()];
        let start = Instant::now();
        let worker = std::thread::spawn(move || run_worker_loop(ep1, &addrs, fixture, ""));
        // Poll rather than join so a regression to an unbounded wait
        // fails the test at 60 s instead of hanging CI for 600.
        while !worker.is_finished() {
            assert!(
                start.elapsed() < Duration::from_secs(60),
                "worker still waiting after {:?} — epoch wait not derived from recv timeout",
                start.elapsed()
            );
            std::thread::sleep(Duration::from_millis(100));
        }
        let waited = start.elapsed();
        let result = worker.join().expect("worker thread must not panic");
        assert!(result.is_err(), "a silent leader must surface as an error, not success");
        assert!(
            waited >= Duration::from_secs(4),
            "worker gave up before the derived epoch wait: {waited:?}"
        );
    }
}

//! Layer 1 of the coordinator's network stack (DESIGN.md §13): the
//! length-prefixed binary wire codec. Everything that crosses a socket
//! is defined here — the [`Frame`] tags for the Fig. 2 protocol
//! messages and the control plane (wire v1–v5), the fixed-width
//! little-endian field encoders/decoders, and [`WireError`]. This
//! layer knows nothing about sockets beyond the [`Read`]/[`Write`]
//! traits; sessions, the mesh, and the cluster roles all build on it.

use std::io::{Read, Write};

use crate::coordinator::protocol::{Counter, Message, OverheadStats};
use crate::game::cost::Framework;
use crate::partition::MachineId;

/// First bytes of every `Hello` payload after the tag.
pub const WIRE_MAGIC: [u8; 4] = *b"GTIP";
/// Wire protocol version; bumped on any layout change. v2 added the
/// migration charge of the augmented game to `Setup`; v3 added the
/// elastic-membership control frames (`Restore`, `Join`, `RestoreAck`);
/// v4 made `Join` live and added the admission frames (`Admit`,
/// `AdmitAck`, `Catchup`); v5 added the two-level hierarchy (DESIGN.md
/// §12): the `RackUpdate` aggregate message, the phased `EpochBegin`,
/// rack-aware `Setup`/`Join`/`Admit` fields, and `RackResult`. The
/// `Hello` handshake rejects any peer speaking another version, so
/// decoding is version-gated at connection time and a mixed-version
/// cluster can never half-parse a frame.
pub const WIRE_VERSION: u16 = 5;
/// Upper bound on a single frame payload; larger prefixes are rejected
/// before any allocation happens.
pub const MAX_FRAME_BYTES: usize = 1 << 24;

/// Message tags (1–5 mirror [`Message`]; 16+ are control frames).
const TAG_TAKE_MY_TURN: u8 = 1;
const TAG_RECEIVE_NODE: u8 = 2;
const TAG_REGULAR_UPDATE: u8 = 3;
const TAG_SHUTDOWN: u8 = 4;
const TAG_RACK_UPDATE: u8 = 5;
const TAG_HELLO: u8 = 16;
const TAG_SETUP: u8 = 17;
const TAG_EPOCH_BEGIN: u8 = 18;
const TAG_ROUND_STATS: u8 = 19;
const TAG_GOODBYE: u8 = 20;
const TAG_RESTORE: u8 = 21;
const TAG_JOIN: u8 = 22;
const TAG_RESTORE_ACK: u8 = 23;
const TAG_ADMIT: u8 = 24;
const TAG_ADMIT_ACK: u8 = 25;
const TAG_CATCHUP: u8 = 26;
const TAG_RACK_RESULT: u8 = 27;

/// Errors of the wire codec and connection lifecycle.
#[derive(Debug)]
pub enum WireError {
    /// Frame payload ended before the advertised fields.
    Truncated { needed: usize, got: usize },
    /// Decoded fields left unconsumed payload bytes behind.
    TrailingBytes { extra: usize },
    /// Length prefix exceeds [`MAX_FRAME_BYTES`].
    Oversized { len: usize },
    /// Unknown frame tag.
    BadTag(u8),
    /// Handshake did not start with [`WIRE_MAGIC`].
    BadMagic,
    /// Peer speaks a different [`WIRE_VERSION`].
    BadVersion { theirs: u16 },
    /// The socket closed mid-stream.
    Closed,
    /// Underlying socket error.
    Io(String),
    /// The peer violated the epoch protocol.
    Protocol(String),
    /// A lower-level failure annotated with the peer (wire id) and the
    /// protocol state it surfaced in. Every error that reaches the CLI
    /// takes this form — "peer 3, awaiting AdmitAck: …" — so an
    /// operator can tell *who* stalled a barrier and *where*.
    Context { peer: MachineId, state: String, inner: Box<WireError> },
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { needed, got } => {
                write!(f, "truncated frame: needed {needed} bytes, got {got}")
            }
            WireError::TrailingBytes { extra } => {
                write!(f, "malformed frame: {extra} unconsumed trailing bytes")
            }
            WireError::Oversized { len } => {
                write!(f, "oversized frame: {len} bytes > max {MAX_FRAME_BYTES}")
            }
            WireError::BadTag(t) => write!(f, "unknown frame tag {t}"),
            WireError::BadMagic => write!(f, "bad handshake magic (not a gtip peer?)"),
            WireError::BadVersion { theirs } => {
                write!(f, "wire version mismatch: peer {theirs}, ours {WIRE_VERSION}")
            }
            WireError::Closed => write!(f, "connection closed"),
            WireError::Io(e) => write!(f, "socket error: {e}"),
            WireError::Protocol(e) => write!(f, "protocol violation: {e}"),
            WireError::Context { peer, state, inner } => {
                write!(f, "peer {peer}, {state}: {inner}")
            }
        }
    }
}

impl std::error::Error for WireError {}

impl WireError {
    /// Annotate this error with the peer (wire id) and protocol state
    /// it surfaced in, e.g. `e.while_awaiting("awaiting AdmitAck", 3)`.
    /// Applied at the outermost leader/worker surfaces only — never
    /// inside primitives like `recv_ctrl`, whose callers (death
    /// diagnosis) match on the un-wrapped variants.
    pub fn while_awaiting(self, state: impl Into<String>, peer_wire: MachineId) -> WireError {
        WireError::Context { peer: peer_wire, state: state.into(), inner: Box::new(self) }
    }
}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            WireError::Closed
        } else {
            WireError::Io(e.to_string())
        }
    }
}

/// Control frames + protocol messages — everything that crosses a
/// socket.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// A Fig. 2 protocol message (the only frames [`OverheadStats`]
    /// counts).
    Msg(Message),
    /// Connection handshake: who is dialing, and how big they think the
    /// cluster is.
    Hello { version: u16, machine: u32, machines: u32 },
    /// Leader → workers, once: the shared fixture (machine speeds, game
    /// options, graph topology + weights).
    Setup(SetupFrame),
    /// Leader → workers, per refinement round: fresh measured weights
    /// and the warm-start assignment.
    EpochBegin(EpochFrame),
    /// Worker → leader after each round: the worker's [`OverheadStats`]
    /// delta for that round (the leader aggregates them; waiting for
    /// all K−1 doubles as the epoch barrier).
    RoundStats(OverheadStats),
    /// Leader → workers: the run is over; exit cleanly.
    Goodbye,
    /// Leader → survivors after a worker death (wire v3): re-form the
    /// cluster. `survivors` lists the surviving *wire* ids of the
    /// original mesh in ascending order (always including 0, the
    /// leader); each survivor's new logical id is its position in the
    /// list. `speeds` are the renormalized relative speeds in that new
    /// order. A worker not on the list has been evicted — it will
    /// never receive this frame (the leader compacts first), and times
    /// out on its own.
    Restore { survivors: Vec<u32>, speeds: Vec<f64> },
    /// Joiner → leader (wire v4): announce this machine (its immutable
    /// wire id) and its relative speed, asking to be admitted at the
    /// next epoch boundary. `speed` is relative to the current fleet's
    /// average machine — 1.0 means "as fast as a typical member".
    /// `rack` (wire v5) is the rack the joiner wants to land in;
    /// `u32::MAX` means "leader's choice" (the emptiest rack), and the
    /// value is ignored entirely on a flat cluster.
    Join { machine: u32, speed: f64, rack: u32 },
    /// Survivor → leader (wire v3): compaction applied, ready for the
    /// next epoch. `machine` echoes the sender's original wire id so
    /// the leader can cross-check its survivor bookkeeping.
    RestoreAck { machine: u32 },
    /// Leader → everyone at an admission (wire v4): grow the mesh back
    /// around `members` — the new member *wire* ids, ascending, always
    /// including 0 (the leader) and `joiner`. Each member's new
    /// logical id is its position in the list; `speeds` are the
    /// renormalized relative speeds in that order. The exact mirror of
    /// [`Frame::Restore`], which shrinks the same list. `rack` (wire
    /// v5) is the rack the joiner lands in — already resolved by the
    /// leader, never `u32::MAX`; 0 (and ignored) on a flat cluster.
    Admit { members: Vec<u32>, joiner: u32, speeds: Vec<f64>, rack: u32 },
    /// Member → leader (wire v4): mesh extension applied (the member
    /// dialed the joiner and accepted its return dial), ready for the
    /// next epoch. `machine` echoes the sender's wire id, like
    /// [`Frame::RestoreAck`].
    AdmitAck { machine: u32 },
    /// Leader → joiner, once per admission (wire v4): the encoded
    /// epoch-boundary [`crate::sim::Snapshot`] the run is at, so the
    /// newcomer can cross-check the fixture it was shipped in `Setup`
    /// against the exact state the cluster resumes from.
    Catchup { snapshot: Vec<u8> },
    /// Rack leader → cluster leader after an inner (phase-2) round
    /// (wire v5): the rack's scoped-ring outcome. `assignment` lists
    /// `(node, machine)` for every node the rack owned at phase start —
    /// cross-rack traffic never flows in phase 2, so only the owning
    /// rack knows where its nodes ended up. The leader of the rack
    /// containing machine 0 never sends this; the cluster leader played
    /// that ring itself.
    RackResult { rack: u32, transfers: u64, converged: bool, assignment: Vec<(u32, u32)> },
}

/// Payload of [`Frame::Setup`].
#[derive(Debug, Clone, PartialEq)]
pub struct SetupFrame {
    pub speeds: Vec<f64>,
    pub mu: f64,
    pub framework: Framework,
    /// Per-move migration surcharge of the augmented game (DESIGN.md
    /// §9). Workers must price moves with exactly the leader's charge
    /// or replicas pick different transfers (wire v2).
    pub migration_charge: f64,
    pub epsilon: f64,
    pub max_transfers: u64,
    pub recv_timeout_ms: u64,
    pub node_weights: Vec<f64>,
    /// `(u, v, weight)` for every edge, in the leader graph's edge
    /// order (workers re-install per-epoch weights in this order).
    pub edges: Vec<(u32, u32, f64)>,
    /// Machine → rack map for the two-level hierarchy (wire v5), one
    /// entry per machine; empty means a flat (single-level) cluster.
    pub racks: Vec<u32>,
}

/// Payload of [`Frame::EpochBegin`].
#[derive(Debug, Clone, PartialEq)]
pub struct EpochFrame {
    pub epoch: u64,
    /// Which level this round plays (wire v5): 0 = flat (single-level),
    /// 1 = the outer rack-quotient game (rack leaders only), 2 = the
    /// inner per-rack scoped rings. A hierarchical epoch is one
    /// phase-1 round followed by one phase-2 round under the same
    /// `epoch` number.
    pub phase: u8,
    pub node_weights: Vec<f64>,
    /// One weight per edge, in [`SetupFrame::edges`] order.
    pub edge_weights: Vec<f64>,
    pub assignment: Vec<u32>,
}

// ---------------------------------------------------------------------
// Codec
// ---------------------------------------------------------------------

fn put_u16(b: &mut Vec<u8>, v: u16) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(b: &mut Vec<u8>, v: f64) {
    b.extend_from_slice(&v.to_le_bytes());
}

/// Checked narrowing for ids and lengths crossing the wire. A graph,
/// cluster, or vector beyond the u32 wire range must fail loudly at
/// encode time — an unchecked `as u32` would silently truncate into a
/// wrong-but-plausible frame the peer happily applies.
pub(super) fn wire_u32(v: usize) -> Result<u32, WireError> {
    u32::try_from(v).map_err(|_| WireError::Protocol(format!("{v} exceeds the u32 wire range")))
}

fn put_f64s(b: &mut Vec<u8>, vs: &[f64]) -> Result<(), WireError> {
    put_u32(b, wire_u32(vs.len())?);
    for &v in vs {
        put_f64(b, v);
    }
    Ok(())
}

/// Bounded reader over a frame payload; every accessor fails with
/// [`WireError::Truncated`] instead of panicking on short input.
struct Dec<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(b: &'a [u8]) -> Self {
        Dec { b, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.pos + n > self.b.len() {
            return Err(WireError::Truncated { needed: self.pos + n, got: self.b.len() });
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().expect("len 2")))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("len 4")))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("len 8")))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().expect("len 8")))
    }

    /// Length-prefixed f64 vector; the length is validated against the
    /// remaining payload before any allocation.
    fn f64s(&mut self) -> Result<Vec<f64>, WireError> {
        let len = self.u32()? as usize;
        if self.pos + 8 * len > self.b.len() {
            return Err(WireError::Truncated { needed: self.pos + 8 * len, got: self.b.len() });
        }
        (0..len).map(|_| self.f64()).collect()
    }

    fn finish(self) -> Result<(), WireError> {
        if self.pos != self.b.len() {
            return Err(WireError::TrailingBytes { extra: self.b.len() - self.pos });
        }
        Ok(())
    }
}

fn encode_payload(frame: &Frame, b: &mut Vec<u8>) -> Result<(), WireError> {
    match frame {
        Frame::Msg(Message::TakeMyTurn { consecutive_forfeits, transfers_so_far }) => {
            b.push(TAG_TAKE_MY_TURN);
            put_u64(b, *consecutive_forfeits as u64);
            put_u64(b, *transfers_so_far as u64);
        }
        Frame::Msg(Message::ReceiveNode { seq, node, from, to }) => {
            b.push(TAG_RECEIVE_NODE);
            put_u64(b, *seq);
            put_u64(b, *node as u64);
            put_u32(b, wire_u32(*from)?);
            put_u32(b, wire_u32(*to)?);
        }
        Frame::Msg(Message::RegularUpdate { seq, node, from, to, loads }) => {
            b.push(TAG_REGULAR_UPDATE);
            put_u64(b, *seq);
            put_u64(b, *node as u64);
            put_u32(b, wire_u32(*from)?);
            put_u32(b, wire_u32(*to)?);
            put_f64s(b, loads)?;
        }
        Frame::Msg(Message::RackUpdate { seq, node, from, to, rack_loads }) => {
            b.push(TAG_RACK_UPDATE);
            put_u64(b, *seq);
            put_u64(b, *node as u64);
            put_u32(b, wire_u32(*from)?);
            put_u32(b, wire_u32(*to)?);
            put_f64s(b, rack_loads)?;
        }
        Frame::Msg(Message::Shutdown { total_transfers, converged }) => {
            b.push(TAG_SHUTDOWN);
            put_u64(b, *total_transfers);
            b.push(u8::from(*converged));
        }
        Frame::Hello { version, machine, machines } => {
            b.push(TAG_HELLO);
            b.extend_from_slice(&WIRE_MAGIC);
            put_u16(b, *version);
            put_u32(b, *machine);
            put_u32(b, *machines);
        }
        Frame::Setup(s) => {
            b.push(TAG_SETUP);
            put_f64s(b, &s.speeds)?;
            put_f64(b, s.mu);
            b.push(match s.framework {
                Framework::A => 0,
                Framework::B => 1,
            });
            put_f64(b, s.migration_charge);
            put_f64(b, s.epsilon);
            put_u64(b, s.max_transfers);
            put_u64(b, s.recv_timeout_ms);
            put_f64s(b, &s.node_weights)?;
            put_u32(b, wire_u32(s.edges.len())?);
            for &(u, v, w) in &s.edges {
                put_u32(b, u);
                put_u32(b, v);
                put_f64(b, w);
            }
            put_u32(b, wire_u32(s.racks.len())?);
            for &r in &s.racks {
                put_u32(b, r);
            }
        }
        Frame::EpochBegin(e) => {
            b.push(TAG_EPOCH_BEGIN);
            put_u64(b, e.epoch);
            b.push(e.phase);
            put_f64s(b, &e.node_weights)?;
            put_f64s(b, &e.edge_weights)?;
            put_u32(b, wire_u32(e.assignment.len())?);
            for &a in &e.assignment {
                put_u32(b, a);
            }
        }
        Frame::RoundStats(s) => {
            b.push(TAG_ROUND_STATS);
            for c in
                [&s.take_my_turn, &s.receive_node, &s.regular_update, &s.rack_update, &s.shutdown]
            {
                put_u64(b, c.messages);
                put_u64(b, c.bytes);
            }
        }
        Frame::Goodbye => b.push(TAG_GOODBYE),
        Frame::Restore { survivors, speeds } => {
            b.push(TAG_RESTORE);
            put_u32(b, wire_u32(survivors.len())?);
            for &s in survivors {
                put_u32(b, s);
            }
            put_f64s(b, speeds)?;
        }
        Frame::Join { machine, speed, rack } => {
            b.push(TAG_JOIN);
            put_u32(b, *machine);
            put_f64(b, *speed);
            put_u32(b, *rack);
        }
        Frame::RestoreAck { machine } => {
            b.push(TAG_RESTORE_ACK);
            put_u32(b, *machine);
        }
        Frame::Admit { members, joiner, speeds, rack } => {
            b.push(TAG_ADMIT);
            put_u32(b, wire_u32(members.len())?);
            for &m in members {
                put_u32(b, m);
            }
            put_u32(b, *joiner);
            put_f64s(b, speeds)?;
            put_u32(b, *rack);
        }
        Frame::AdmitAck { machine } => {
            b.push(TAG_ADMIT_ACK);
            put_u32(b, *machine);
        }
        Frame::Catchup { snapshot } => {
            b.push(TAG_CATCHUP);
            put_u32(b, wire_u32(snapshot.len())?);
            b.extend_from_slice(snapshot);
        }
        Frame::RackResult { rack, transfers, converged, assignment } => {
            b.push(TAG_RACK_RESULT);
            put_u32(b, *rack);
            put_u64(b, *transfers);
            b.push(u8::from(*converged));
            put_u32(b, wire_u32(assignment.len())?);
            for &(node, machine) in assignment {
                put_u32(b, node);
                put_u32(b, machine);
            }
        }
    }
    Ok(())
}

/// Encode a frame as `u32 LE payload length || payload`. Fails (rather
/// than truncating) on ids or lengths beyond the u32 wire range and on
/// payloads over [`MAX_FRAME_BYTES`] — the write-side mirror of the
/// read-side `Oversized` rejection.
pub fn encode_frame(frame: &Frame) -> Result<Vec<u8>, WireError> {
    let mut payload = Vec::with_capacity(64);
    encode_payload(frame, &mut payload)?;
    if payload.len() > MAX_FRAME_BYTES {
        return Err(WireError::Oversized { len: payload.len() });
    }
    let mut out = Vec::with_capacity(4 + payload.len());
    put_u32(&mut out, payload.len() as u32);
    out.extend_from_slice(&payload);
    Ok(out)
}

/// Decode one frame payload (the bytes after the length prefix).
/// Rejects unknown tags, short payloads, and trailing garbage — never
/// panics on malformed input.
pub fn decode_payload(payload: &[u8]) -> Result<Frame, WireError> {
    let mut d = Dec::new(payload);
    let tag = d.u8()?;
    let frame = match tag {
        TAG_TAKE_MY_TURN => Frame::Msg(Message::TakeMyTurn {
            consecutive_forfeits: d.u64()? as usize,
            transfers_so_far: d.u64()? as usize,
        }),
        TAG_RECEIVE_NODE => Frame::Msg(Message::ReceiveNode {
            seq: d.u64()?,
            node: d.u64()? as usize,
            from: d.u32()? as MachineId,
            to: d.u32()? as MachineId,
        }),
        TAG_REGULAR_UPDATE => Frame::Msg(Message::RegularUpdate {
            seq: d.u64()?,
            node: d.u64()? as usize,
            from: d.u32()? as MachineId,
            to: d.u32()? as MachineId,
            loads: d.f64s()?,
        }),
        TAG_RACK_UPDATE => Frame::Msg(Message::RackUpdate {
            seq: d.u64()?,
            node: d.u64()? as usize,
            from: d.u32()? as MachineId,
            to: d.u32()? as MachineId,
            rack_loads: d.f64s()?,
        }),
        TAG_SHUTDOWN => Frame::Msg(Message::Shutdown {
            total_transfers: d.u64()?,
            converged: match d.u8()? {
                0 => false,
                1 => true,
                other => {
                    return Err(WireError::Protocol(format!("bad converged byte {other}")))
                }
            },
        }),
        TAG_HELLO => {
            if d.take(4)? != WIRE_MAGIC {
                return Err(WireError::BadMagic);
            }
            let version = d.u16()?;
            if version != WIRE_VERSION {
                return Err(WireError::BadVersion { theirs: version });
            }
            Frame::Hello { version, machine: d.u32()?, machines: d.u32()? }
        }
        TAG_SETUP => {
            let speeds = d.f64s()?;
            let mu = d.f64()?;
            let framework = match d.u8()? {
                0 => Framework::A,
                1 => Framework::B,
                other => return Err(WireError::Protocol(format!("bad framework byte {other}"))),
            };
            Frame::Setup(SetupFrame {
                speeds,
                mu,
                framework,
                migration_charge: d.f64()?,
                epsilon: d.f64()?,
                max_transfers: d.u64()?,
                recv_timeout_ms: d.u64()?,
                node_weights: d.f64s()?,
                edges: {
                    let len = d.u32()? as usize;
                    let mut edges = Vec::new();
                    for _ in 0..len {
                        edges.push((d.u32()?, d.u32()?, d.f64()?));
                    }
                    edges
                },
                racks: {
                    let len = d.u32()? as usize;
                    if 4 * len > payload.len() {
                        return Err(WireError::Truncated { needed: 4 * len, got: payload.len() });
                    }
                    (0..len).map(|_| d.u32()).collect::<Result<_, _>>()?
                },
            })
        }
        TAG_EPOCH_BEGIN => Frame::EpochBegin(EpochFrame {
            epoch: d.u64()?,
            phase: d.u8()?,
            node_weights: d.f64s()?,
            edge_weights: d.f64s()?,
            assignment: {
                let len = d.u32()? as usize;
                if 4 * len > payload.len() {
                    return Err(WireError::Truncated { needed: 4 * len, got: payload.len() });
                }
                (0..len).map(|_| d.u32()).collect::<Result<_, _>>()?
            },
        }),
        TAG_ROUND_STATS => {
            let mut cs = [Counter::default(); 5];
            for c in cs.iter_mut() {
                c.messages = d.u64()?;
                c.bytes = d.u64()?;
            }
            Frame::RoundStats(OverheadStats {
                take_my_turn: cs[0],
                receive_node: cs[1],
                regular_update: cs[2],
                rack_update: cs[3],
                shutdown: cs[4],
            })
        }
        TAG_GOODBYE => Frame::Goodbye,
        TAG_RESTORE => {
            let len = d.u32()? as usize;
            if 4 * len > payload.len() {
                return Err(WireError::Truncated { needed: 4 * len, got: payload.len() });
            }
            Frame::Restore {
                survivors: (0..len).map(|_| d.u32()).collect::<Result<_, _>>()?,
                speeds: d.f64s()?,
            }
        }
        TAG_JOIN => Frame::Join { machine: d.u32()?, speed: d.f64()?, rack: d.u32()? },
        TAG_RESTORE_ACK => Frame::RestoreAck { machine: d.u32()? },
        TAG_ADMIT => {
            let len = d.u32()? as usize;
            if 4 * len > payload.len() {
                return Err(WireError::Truncated { needed: 4 * len, got: payload.len() });
            }
            Frame::Admit {
                members: (0..len).map(|_| d.u32()).collect::<Result<_, _>>()?,
                joiner: d.u32()?,
                speeds: d.f64s()?,
                rack: d.u32()?,
            }
        }
        TAG_ADMIT_ACK => Frame::AdmitAck { machine: d.u32()? },
        TAG_CATCHUP => {
            let len = d.u32()? as usize;
            if len > payload.len() {
                return Err(WireError::Truncated { needed: len, got: payload.len() });
            }
            Frame::Catchup { snapshot: d.take(len)?.to_vec() }
        }
        TAG_RACK_RESULT => {
            let rack = d.u32()?;
            let transfers = d.u64()?;
            let converged = match d.u8()? {
                0 => false,
                1 => true,
                other => {
                    return Err(WireError::Protocol(format!("bad converged byte {other}")))
                }
            };
            let len = d.u32()? as usize;
            if 8 * len > payload.len() {
                return Err(WireError::Truncated { needed: 8 * len, got: payload.len() });
            }
            Frame::RackResult {
                rack,
                transfers,
                converged,
                assignment: (0..len)
                    .map(|_| Ok((d.u32()?, d.u32()?)))
                    .collect::<Result<_, WireError>>()?,
            }
        }
        other => return Err(WireError::BadTag(other)),
    };
    d.finish()?;
    Ok(frame)
}

/// Read one length-prefixed frame from a stream.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame, WireError> {
    let mut prefix = [0u8; 4];
    r.read_exact(&mut prefix)?;
    let len = u32::from_le_bytes(prefix) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(WireError::Oversized { len });
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    decode_payload(&payload)
}

/// Write one frame to a stream.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> Result<usize, WireError> {
    let bytes = encode_frame(frame)?;
    w.write_all(&bytes)?;
    Ok(bytes.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_message_shapes() -> Vec<Message> {
        vec![
            Message::TakeMyTurn { consecutive_forfeits: 3, transfers_so_far: 17 },
            Message::ReceiveNode { seq: 9, node: 1234, from: 2, to: 0 },
            Message::RegularUpdate {
                seq: 10,
                node: 7,
                from: 1,
                to: 3,
                loads: vec![0.25, -1.5, 3.75, f64::MAX, 0.0],
            },
            Message::RackUpdate { seq: 11, node: 8, from: 0, to: 1, rack_loads: vec![0.5, 1.5] },
            Message::Shutdown { total_transfers: 42, converged: true },
            Message::Shutdown { total_transfers: 7, converged: false },
        ]
    }
    #[test]
    fn message_round_trip_and_exact_sizes() {
        for msg in all_message_shapes() {
            let bytes = encode_frame(&Frame::Msg(msg.clone())).unwrap();
            assert_eq!(bytes.len(), msg.wire_bytes(), "{}", msg.tag());
            let decoded = decode_payload(&bytes[4..]).unwrap();
            assert_eq!(decoded, Frame::Msg(msg));
        }
    }

    #[test]
    fn control_frames_round_trip() {
        let frames = vec![
            Frame::Hello { version: WIRE_VERSION, machine: 2, machines: 5 },
            Frame::Setup(SetupFrame {
                speeds: vec![0.25, 0.75],
                mu: 8.0,
                framework: Framework::B,
                migration_charge: 3.25,
                epsilon: 1e-9,
                max_transfers: 1_000_000,
                recv_timeout_ms: 30_000,
                node_weights: vec![1.0, 2.0, 3.0],
                edges: vec![(0, 1, 1.5), (1, 2, 2.5)],
                racks: vec![0, 1],
            }),
            Frame::EpochBegin(EpochFrame {
                epoch: 4,
                phase: 2,
                node_weights: vec![0.5; 3],
                edge_weights: vec![1.0, 2.0],
                assignment: vec![0, 1, 0],
            }),
            Frame::RoundStats(OverheadStats {
                take_my_turn: Counter { messages: 5, bytes: 105 },
                ..Default::default()
            }),
            Frame::Restore { survivors: vec![0, 2, 3], speeds: vec![0.25, 0.25, 0.5] },
            Frame::Join { machine: 4, speed: 0.125, rack: u32::MAX },
            Frame::Join { machine: 5, speed: 0.5, rack: 1 },
            Frame::RestoreAck { machine: 3 },
            Frame::Admit {
                members: vec![0, 2, 3],
                joiner: 2,
                speeds: vec![0.25, 0.25, 0.5],
                rack: 1,
            },
            Frame::RackResult {
                rack: 1,
                transfers: 3,
                converged: true,
                assignment: vec![(5, 2), (9, 3)],
            },
            Frame::RackResult { rack: 0, transfers: 0, converged: false, assignment: vec![] },
            Frame::AdmitAck { machine: 2 },
            Frame::Catchup { snapshot: vec![] },
            Frame::Catchup { snapshot: vec![0xDE, 0xAD, 0xBE, 0xEF] },
            Frame::Goodbye,
        ];
        for f in frames {
            let bytes = encode_frame(&f).unwrap();
            assert_eq!(decode_payload(&bytes[4..]).unwrap(), f);
        }
    }

    /// A `Catchup` whose declared snapshot length exceeds the actual
    /// payload must be a clean truncation error, not a panic or a
    /// huge-allocation attempt.
    #[test]
    fn lying_catchup_length_is_truncation_not_panic() {
        let mut payload = vec![TAG_CATCHUP];
        put_u32(&mut payload, 100); // claims 100 snapshot bytes...
        payload.extend_from_slice(&[0u8; 10]); // ...carries 10
        assert!(matches!(decode_payload(&payload), Err(WireError::Truncated { .. })));
    }

    /// Node/machine ids that do not fit the u32 wire format must come
    /// back as a clean error from the encoder, not a silent truncation.
    #[test]
    fn oversize_ids_rejected_at_encode_time() {
        if std::mem::size_of::<usize>() <= 4 {
            return; // the bug cannot exist on 32-bit targets
        }
        let huge = u32::MAX as usize + 1;
        let msg = Message::ReceiveNode { seq: 0, node: 1, from: huge, to: 0 };
        assert!(encode_frame(&Frame::Msg(msg)).is_err());
        assert!(wire_u32(huge).is_err());
        assert_eq!(wire_u32(u32::MAX as usize).unwrap(), u32::MAX);
    }

    #[test]
    fn truncated_frames_error_cleanly() {
        for msg in all_message_shapes() {
            let bytes = encode_frame(&Frame::Msg(msg)).unwrap();
            // Every strict prefix of the payload must fail without
            // panicking.
            for cut in 0..bytes.len() - 4 {
                assert!(
                    decode_payload(&bytes[4..4 + cut]).is_err(),
                    "prefix of {cut} bytes decoded"
                );
            }
        }
    }

    #[test]
    fn trailing_garbage_rejected() {
        let mut bytes = encode_frame(&Frame::Goodbye).unwrap();
        bytes.push(0xFF);
        assert!(matches!(
            decode_payload(&bytes[4..]),
            Err(WireError::TrailingBytes { extra: 1 })
        ));
    }

    #[test]
    fn bad_tag_and_oversized_rejected() {
        assert!(matches!(decode_payload(&[0xEE]), Err(WireError::BadTag(0xEE))));
        // Oversized length prefix rejected before allocation.
        let mut stream = Vec::new();
        put_u32(&mut stream, (MAX_FRAME_BYTES + 1) as u32);
        let mut cursor = &stream[..];
        assert!(matches!(read_frame(&mut cursor), Err(WireError::Oversized { .. })));
    }

    #[test]
    fn lying_vector_length_is_truncation_not_panic() {
        // RegularUpdate claiming 1000 loads but carrying none.
        let mut payload = vec![TAG_REGULAR_UPDATE];
        put_u64(&mut payload, 0);
        put_u64(&mut payload, 1);
        put_u32(&mut payload, 0);
        put_u32(&mut payload, 1);
        put_u32(&mut payload, 1000);
        assert!(matches!(decode_payload(&payload), Err(WireError::Truncated { .. })));
    }

    #[test]
    fn handshake_version_and_magic_enforced() {
        let mut payload = vec![TAG_HELLO];
        payload.extend_from_slice(b"NOPE");
        put_u16(&mut payload, WIRE_VERSION);
        put_u32(&mut payload, 0);
        put_u32(&mut payload, 2);
        assert!(matches!(decode_payload(&payload), Err(WireError::BadMagic)));

        let mut payload = vec![TAG_HELLO];
        payload.extend_from_slice(&WIRE_MAGIC);
        put_u16(&mut payload, WIRE_VERSION + 1);
        put_u32(&mut payload, 0);
        put_u32(&mut payload, 2);
        assert!(matches!(decode_payload(&payload), Err(WireError::BadVersion { .. })));
    }
    /// A `RackResult` whose declared assignment length exceeds the
    /// actual payload must be a clean truncation error, not a panic or
    /// a huge-allocation attempt.
    #[test]
    fn lying_rack_result_length_is_truncation_not_panic() {
        let mut payload = vec![TAG_RACK_RESULT];
        put_u32(&mut payload, 1); // rack
        payload.extend_from_slice(&3u64.to_le_bytes()); // transfers
        payload.push(1); // converged
        put_u32(&mut payload, 1000); // claims 1000 pairs...
        payload.extend_from_slice(&[0u8; 16]); // ...carries 2
        assert!(matches!(decode_payload(&payload), Err(WireError::Truncated { .. })));
    }

    /// Satellite of the layering refactor: an error surfaced to the
    /// CLI names the peer wire id and the protocol state it died in,
    /// with the underlying failure preserved verbatim.
    #[test]
    fn context_names_the_peer_and_the_protocol_state() {
        let inner = WireError::Protocol("timed out waiting for a control frame".into());
        let msg = inner.while_awaiting("awaiting AdmitAck", 3).to_string();
        assert!(msg.contains("peer 3, awaiting AdmitAck"), "{msg}");
        assert!(msg.contains("timed out waiting for a control frame"), "{msg}");

        let io = WireError::Io("dialing 127.0.0.1:9: refused".into());
        let msg = io.while_awaiting("dialing", 2).to_string();
        assert!(msg.contains("peer 2, dialing"), "{msg}");
        assert!(msg.contains("refused"), "{msg}");
    }
}

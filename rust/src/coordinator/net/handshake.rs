//! Hello/version negotiation for the coordinator's network stack
//! (DESIGN.md §13). Every socket that enters the mesh passes through
//! one of these deadline-bounded handshakes: [`accept_peers`] forms
//! the initial mesh, [`accept_wire_peer`] re-admits a known wire id
//! after recovery or join, and [`join_handshake`] vets a would-be
//! joiner's `Hello` + `Join` before the leader decides on admission.
//! The deadline logic is strict — a fully elapsed deadline rejects
//! even a valid `Hello` already sitting in the socket buffer, so
//! connect-spamming peers cannot stretch the recovery grace window.

use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use super::codec::{read_frame, Frame, WireError};
use super::leader::JoinRequest;
use super::session::ACCEPT_POLL;
use crate::partition::MachineId;

/// How long the acceptor gives one joiner to complete its
/// `Hello` + `Join` handshake before dropping the connection.
pub(super) const JOIN_HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(2);

/// Validate one inbound connection's `Hello` handshake.
pub(super) fn handshake_inbound(
    mut stream: TcpStream,
    id: MachineId,
    k: usize,
    deadline: Instant,
    seen: &[bool],
) -> Result<(MachineId, TcpStream), WireError> {
    stream.set_nonblocking(false)?;
    // A fully elapsed deadline must fail *now*. The old code clamped
    // the remaining window up to 1 ms and read anyway, so a peer that
    // kept connecting could stretch the handshake far past the bound
    // the recovery grace-window math (DESIGN.md §10) relies on.
    let left = deadline.saturating_duration_since(Instant::now());
    if left.is_zero() {
        return Err(WireError::Protocol("handshake deadline already passed".into()));
    }
    stream.set_read_timeout(Some(left))?;
    let hello = read_frame(&mut stream)?;
    let Frame::Hello { machine, machines, .. } = hello else {
        return Err(WireError::Protocol(format!("expected Hello, got {hello:?}")));
    };
    let peer = machine as MachineId;
    if machines as usize != k || peer >= k || peer == id {
        return Err(WireError::Protocol(format!(
            "peer says machine {machine}/{machines}, we are {id}/{k}"
        )));
    }
    if seen[peer] {
        return Err(WireError::Protocol(format!("duplicate dial from machine {peer}")));
    }
    stream.set_read_timeout(None)?;
    stream.set_nodelay(true)?;
    Ok((peer, stream))
}

/// Accept inbound connections until one valid `Hello` per peer has
/// arrived. A single bad connection (port scanner, garbage handshake,
/// stray re-dial) is dropped with a note — never allowed to kill the
/// mesh join; only the overall deadline fails it.
pub(super) fn accept_peers(
    listener: TcpListener,
    id: MachineId,
    k: usize,
    deadline: Instant,
) -> Result<Vec<(MachineId, TcpStream)>, WireError> {
    listener.set_nonblocking(true)?;
    let mut inbound: Vec<(MachineId, TcpStream)> = Vec::with_capacity(k - 1);
    let mut seen = vec![false; k];
    while inbound.len() < k - 1 {
        match listener.accept() {
            Ok((stream, addr)) => {
                // Per-connection handshake; any failure drops only this
                // socket.
                match handshake_inbound(stream, id, k, deadline, &seen) {
                    Ok((peer, stream)) => {
                        seen[peer] = true;
                        inbound.push((peer, stream));
                    }
                    Err(e) => {
                        eprintln!("gtip net: dropping inbound connection from {addr}: {e}");
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(WireError::Protocol(format!(
                        "timed out waiting for {} inbound peers (have {})",
                        k - 1,
                        inbound.len()
                    )));
                }
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(inbound)
}

/// Validate one would-be joiner's `Hello` + `Join`. On a *semantic*
/// reject the stream is returned so the caller can send a `Goodbye`
/// (telling the joiner to give up rather than retry); on an I/O or
/// codec failure it is simply dropped.
pub(super) fn join_handshake(
    mut stream: TcpStream,
    k_orig: usize,
) -> Result<JoinRequest, (WireError, Option<TcpStream>)> {
    let io = |e: WireError| (e, None);
    stream.set_nonblocking(false).map_err(|e| io(e.into()))?;
    stream.set_read_timeout(Some(JOIN_HANDSHAKE_TIMEOUT)).map_err(|e| io(e.into()))?;
    let hello = read_frame(&mut stream).map_err(io)?;
    let Frame::Hello { machine, machines, .. } = hello else {
        return Err((WireError::Protocol(format!("expected Hello, got {hello:?}")), None));
    };
    let wire_id = machine as MachineId;
    if machines as usize != k_orig || wire_id == 0 || wire_id >= k_orig {
        return Err((
            WireError::Protocol(format!(
                "joiner says machine {machine}/{machines}, cluster is {k_orig} machines"
            )),
            Some(stream),
        ));
    }
    let join = read_frame(&mut stream).map_err(io)?;
    let Frame::Join { machine: jm, speed, rack } = join else {
        return Err((WireError::Protocol(format!("expected Join, got {join:?}")), None));
    };
    if jm as MachineId != wire_id {
        return Err((
            WireError::Protocol(format!("Join names machine {jm} but Hello said {machine}")),
            Some(stream),
        ));
    }
    if !(speed.is_finite() && speed > 0.0) {
        return Err((
            WireError::Protocol(format!("join speed {speed} must be finite and positive")),
            Some(stream),
        ));
    }
    stream.set_read_timeout(None).map_err(|e| io(e.into()))?;
    stream.set_nodelay(true).map_err(|e| io(e.into()))?;
    // u32::MAX = "leader's choice"; anything else is a request the
    // leader validates against its layout at admission time.
    let rack = if rack == u32::MAX { None } else { Some(rack as usize) };
    Ok(JoinRequest { wire_id, speed, rack, stream })
}

/// Accept connections on the retained (nonblocking) mesh listener
/// until the expected wire peer's `Hello` arrives. Strangers and
/// garbage handshakes are dropped with a note, exactly like the
/// original mesh accept; only the deadline fails the wait.
pub(super) fn accept_wire_peer(
    listener: &TcpListener,
    expect_wire: MachineId,
    k_orig: usize,
    deadline: Instant,
) -> Result<TcpStream, WireError> {
    loop {
        match listener.accept() {
            Ok((mut stream, addr)) => {
                let hello = (|| -> Result<MachineId, WireError> {
                    stream.set_nonblocking(false)?;
                    let left = deadline.saturating_duration_since(Instant::now());
                    if left.is_zero() {
                        return Err(WireError::Protocol(
                            "handshake deadline already passed".into(),
                        ));
                    }
                    stream.set_read_timeout(Some(left))?;
                    match read_frame(&mut stream)? {
                        Frame::Hello { machine, machines, .. }
                            if machines as usize == k_orig =>
                        {
                            Ok(machine as MachineId)
                        }
                        frame => {
                            Err(WireError::Protocol(format!("expected Hello, got {frame:?}")))
                        }
                    }
                })();
                match hello {
                    Ok(peer) if peer == expect_wire => {
                        stream.set_read_timeout(None)?;
                        stream.set_nodelay(true)?;
                        return Ok(stream);
                    }
                    Ok(peer) => eprintln!(
                        "gtip net: dropping dial from machine {peer} while expecting {expect_wire}"
                    ),
                    Err(e) => {
                        eprintln!("gtip net: dropping inbound connection from {addr}: {e}")
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(WireError::Protocol(format!(
                        "timed out waiting for wire id {expect_wire}'s dial"
                    )));
                }
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(e) => return Err(e.into()),
        }
    }
}

#[cfg(test)]
mod tests {
    use std::io::Write;

    use super::super::codec::{encode_frame, WIRE_VERSION};
    use super::*;

    /// The handshake must fail *immediately* once its deadline has
    /// passed — even for a peer whose valid `Hello` is already sitting
    /// in the socket buffer. The old code clamped the remaining window
    /// up to 1 ms and read anyway, letting connect-spamming peers
    /// stretch the accept loop past the recovery grace-window bound.
    #[test]
    fn handshake_rejects_once_the_deadline_has_passed() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        // The Hello itself is perfectly valid and already delivered...
        let hello =
            encode_frame(&Frame::Hello { version: WIRE_VERSION, machine: 1, machines: 2 })
                .unwrap();
        client.write_all(&hello).unwrap();
        client.flush().unwrap();
        // ...but the deadline expired before the accept got to it.
        let deadline = Instant::now();
        std::thread::sleep(Duration::from_millis(5));
        let start = Instant::now();
        let result = handshake_inbound(stream, 0, 2, deadline, &[false; 2]);
        assert!(result.is_err(), "an expired deadline must reject even a valid Hello");
        assert!(
            start.elapsed() < Duration::from_secs(1),
            "the rejection must be immediate, not a blocking read: {:?}",
            start.elapsed()
        );
    }
}

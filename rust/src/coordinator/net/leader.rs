//! Layer 4a of the coordinator's network stack (DESIGN.md §13):
//! machine 0's cluster orchestration. [`ClusterLeader`] owns the
//! leader endpoint and drives the run — `Setup` broadcast, one
//! [`ClusterLeader::refine`] per epoch boundary (flat, or the phased
//! hierarchical rounds of DESIGN.md §12), the `RoundStats` barriers,
//! death diagnosis and `Restore` recovery, and `Join` admission with
//! rollback. Barrier failures are annotated with the peer wire id and
//! the frame being awaited before they surface to the driver/CLI.

use std::collections::VecDeque;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::bus::Bus;
use crate::coordinator::distributed::{
    machine_loop, machine_loop_scoped, DistributedOptions, DistributedReport, RackBus,
};
use crate::coordinator::machine::MachineActor;
use crate::coordinator::protocol::{Message, OverheadStats};
use crate::game::hierarchy::{guarded_map_back, RackLayout};
use crate::graph::Graph;
use crate::partition::{MachineConfig, MachineId, Partition};

use super::codec::{wire_u32, write_frame, EpochFrame, Frame, SetupFrame, WireError, WIRE_VERSION};
use super::handshake::join_handshake;
use super::mesh::{connect_mesh, NetStats, TcpEndpoint};
use super::session::{dial_peer, ACCEPT_POLL};

/// Machine 0's handle on a multi-process cluster: owns the leader
/// endpoint and runs one refinement round per [`ClusterLeader::refine`]
/// call, aggregating the workers' overhead reports.
pub struct ClusterLeader {
    ep: TcpEndpoint,
    opts: DistributedOptions,
    epoch: u64,
    /// Which machines (current logical ids) delivered their
    /// `RoundStats` in the round in flight. Kept on the leader — not
    /// rebuilt inside the barrier loop — because a failed round's
    /// partial barrier is evidence [`ClusterLeader::diagnose_dead`]
    /// must not lose: a worker whose report was already consumed
    /// will not send it again.
    reported: Vec<bool>,
    /// The original peer list — wire id → address. An admission dials
    /// the joiner at its listed address.
    addrs: Vec<String>,
    /// Patience of the admission handshake's ack barrier (and of the
    /// rollback barrier should it fail). Must stay *longer* than the
    /// workers' own dial window (one receive timeout), or a survivor
    /// still dialing a dead joiner would miss the rollback broadcast.
    admit_window: Duration,
    /// Validated join requests queued by the acceptor thread.
    pending: Receiver<JoinRequest>,
    /// Requests drained from the channel but not yet admitted (e.g. a
    /// second joiner arriving while one admission is in flight).
    pending_buf: VecDeque<JoinRequest>,
    /// Tells the acceptor thread to stop accepting joiners.
    acceptor_stop: Arc<AtomicBool>,
    /// Two-level rack layout (wire v5, DESIGN.md §12); `None` plays the
    /// flat single-level game. Ships to workers in `Setup` and tracks
    /// membership changes (recovery shrinks it, admission grows it).
    layout: Option<RackLayout>,
}

/// One validated `Join` handshake, queued until the next epoch
/// boundary. The stream is the joiner's dial to the leader — it
/// becomes the leader's inbound reader for the joiner on admission.
pub struct JoinRequest {
    /// The joiner's immutable wire id (its slot in the peer list).
    pub wire_id: MachineId,
    /// Self-reported relative speed (1.0 = an average machine).
    pub speed: f64,
    /// Requested rack (wire v5); `None` = leader's choice. Ignored on
    /// a flat cluster.
    pub rack: Option<usize>,
    pub(super) stream: TcpStream,
}

impl ClusterLeader {
    /// Join the mesh as machine 0 and wait for every worker.
    pub fn connect(
        addrs: &[String],
        opts: DistributedOptions,
        connect_timeout: Duration,
    ) -> Result<ClusterLeader, WireError> {
        let stats = Arc::new(Mutex::new(OverheadStats::default()));
        let ep = connect_mesh(0, addrs, connect_timeout, stats)?;
        let k = ep.machine_count();
        // The admission acceptor listens for joiners on a clone of the
        // leader's (now idle) mesh listener for the rest of the run.
        let acceptor = ep.listener.try_clone()?;
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, pending) = channel();
        {
            let stop = Arc::clone(&stop);
            let k_orig = addrs.len();
            std::thread::spawn(move || join_acceptor(acceptor, k_orig, stop, tx));
        }
        let admit_window = opts.recv_timeout.saturating_mul(2);
        Ok(ClusterLeader {
            ep,
            opts,
            epoch: 0,
            reported: vec![false; k],
            addrs: addrs.to_vec(),
            admit_window,
            pending,
            pending_buf: VecDeque::new(),
            acceptor_stop: stop,
            layout: None,
        })
    }

    /// Install the two-level rack layout (DESIGN.md §12). Must be
    /// called before [`ClusterLeader::setup`] so the machine → rack map
    /// ships with the fixture; every subsequent
    /// [`ClusterLeader::refine`] then plays the hierarchical game. A
    /// singleton layout (every machine its own rack) is accepted and
    /// reproduces the flat game bit-for-bit.
    pub fn set_racks(&mut self, layout: RackLayout) -> Result<(), WireError> {
        if layout.machine_count() != self.ep.machine_count() {
            return Err(WireError::Protocol(format!(
                "rack layout covers {} machines but the cluster has {}",
                layout.machine_count(),
                self.ep.machine_count()
            )));
        }
        self.layout = Some(layout);
        Ok(())
    }

    /// Override the admission/rollback barrier patience (defaults to
    /// twice the receive timeout).
    pub fn set_admit_window(&mut self, window: Duration) {
        self.admit_window = window.max(Duration::from_millis(1));
    }

    pub fn machine_count(&self) -> usize {
        self.ep.machine_count()
    }

    /// Control-plane accounting so far (handshake/setup/epoch frames).
    pub fn net_stats(&self) -> NetStats {
        self.ep.net_snapshot()
    }

    /// The shared fixture as a `Setup` frame (broadcast at startup,
    /// and re-sent to a joiner on admission).
    fn setup_frame(&self, graph: &Graph, machines: &MachineConfig) -> Result<Frame, WireError> {
        Ok(Frame::Setup(SetupFrame {
            speeds: machines.speeds().to_vec(),
            mu: self.opts.mu,
            framework: self.opts.framework,
            migration_charge: self.opts.migration_charge,
            epsilon: self.opts.epsilon,
            max_transfers: self.opts.max_transfers as u64,
            recv_timeout_ms: self.opts.recv_timeout.as_millis() as u64,
            node_weights: graph.node_weights().to_vec(),
            edges: graph
                .edges()
                .map(|(u, v, w)| Ok((wire_u32(u)?, wire_u32(v)?, w)))
                .collect::<Result<_, WireError>>()?,
            racks: match &self.layout {
                Some(l) => {
                    l.rack_of_slice().iter().map(|&r| wire_u32(r)).collect::<Result<_, _>>()?
                }
                None => Vec::new(),
            },
        }))
    }

    /// Broadcast the shared fixture. Must be called once, before the
    /// first [`ClusterLeader::refine`].
    pub fn setup(&self, graph: &Graph, machines: &MachineConfig) -> Result<(), WireError> {
        if machines.count() != self.ep.machine_count() {
            return Err(WireError::Protocol(format!(
                "cluster has {} machines but the fixture wants {}",
                self.ep.machine_count(),
                machines.count()
            )));
        }
        self.ep.broadcast_ctrl(&self.setup_frame(graph, machines)?)
    }

    /// Run one refinement round across the cluster: re-sync weights and
    /// the warm-start assignment, play machine 0's part of the ring (or
    /// the two hierarchical phases if a rack layout is installed), then
    /// collect every worker's overhead report (the epoch barrier).
    pub fn refine(
        &mut self,
        graph: &Graph,
        machines: &MachineConfig,
        initial: Partition,
    ) -> Result<DistributedReport, WireError> {
        match self.layout.clone() {
            Some(layout) => self.refine_hierarchical(graph, machines, initial, &layout),
            None => self.refine_flat(graph, machines, initial),
        }
    }

    /// `EpochBegin` broadcast shared by the flat round and both
    /// hierarchical phases. Attempts every peer even after a failure:
    /// the live peers must receive the round so they can later prove
    /// themselves to the death diagnosis with a RoundStats (a failed
    /// send is recorded by `send_ctrl` as evidence against the dead
    /// one).
    fn broadcast_begin(&mut self, begin: &Frame) -> Result<(), WireError> {
        let k = self.ep.machine_count();
        let mut lost_at_broadcast = Vec::new();
        for to in 1..k {
            if let Err(e) = self.ep.send_ctrl(to, begin) {
                eprintln!("gtip leader: EpochBegin to machine {to} failed: {e}");
                lost_at_broadcast.push(to);
            }
        }
        if !lost_at_broadcast.is_empty() {
            return Err(WireError::Protocol(format!(
                "EpochBegin broadcast lost machine(s) {lost_at_broadcast:?}"
            )));
        }
        Ok(())
    }

    /// The epoch frame for one round phase.
    fn epoch_frame(
        &self,
        epoch: u64,
        phase: u8,
        graph: &Graph,
        assignment: &[MachineId],
    ) -> Result<Frame, WireError> {
        Ok(Frame::EpochBegin(EpochFrame {
            epoch,
            phase,
            node_weights: graph.node_weights().to_vec(),
            edge_weights: graph.edges().map(|(_, _, w)| w).collect(),
            assignment: assignment.iter().map(|&m| wire_u32(m)).collect::<Result<_, _>>()?,
        }))
    }

    fn refine_flat(
        &mut self,
        graph: &Graph,
        machines: &MachineConfig,
        initial: Partition,
    ) -> Result<DistributedReport, WireError> {
        let k = self.ep.machine_count();
        if machines.count() != k {
            return Err(WireError::Protocol(format!(
                "cluster has {k} machines but the round's fixture wants {}",
                machines.count()
            )));
        }
        // Any message still buffered here is stale traffic from an
        // aborted round (post-recovery); the broadcast below opens a
        // fresh round, so this is the one safe point to discard it.
        self.ep.drain_inbox();
        self.reported = vec![false; k];
        self.reported[0] = true;
        let epoch = self.epoch;
        self.epoch += 1;
        let begin = self.epoch_frame(epoch, 0, graph, initial.assignment())?;
        self.broadcast_begin(&begin)?;

        let before = self.ep.stats_snapshot();
        let actor = MachineActor::new(
            0,
            Arc::new(graph.clone()),
            machines.clone(),
            &initial,
            self.opts.mu,
            self.opts.framework,
            self.opts.migration_charge,
        );
        self.ep.send(0, Message::TakeMyTurn { consecutive_forfeits: 0, transfers_so_far: 0 });
        let outcome =
            machine_loop(actor, &self.ep, self.opts.epsilon, self.opts.max_transfers, self.opts.recv_timeout);
        if outcome.timed_out {
            return Err(WireError::Protocol(match outcome.dead_peer {
                Some(m) => format!("refinement round lost machine {m} (send failed)"),
                None => "refinement round timed out waiting on a peer".into(),
            }));
        }

        // Barrier: one RoundStats per worker closes the round. Who has
        // reported lives on `self` so a barrier that fails part-way
        // leaves the evidence for `diagnose_dead`.
        let mut overhead = self.ep.stats_snapshot().delta_since(&before);
        let mut remaining = k - 1;
        while remaining > 0 {
            let waiting = self.first_unreported_wire();
            match self.recv_awaiting(self.opts.recv_timeout, "awaiting RoundStats", waiting)? {
                (peer, Frame::RoundStats(s)) if !self.reported[peer] => {
                    self.reported[peer] = true;
                    overhead.add(&s);
                    remaining -= 1;
                }
                (peer, frame) => {
                    return Err(WireError::Protocol(format!(
                        "unexpected control frame from machine {peer} during barrier: {frame:?}"
                    )));
                }
            }
        }

        // Every transfer reaches every replica, so the leader's applied
        // count *is* the global transfer total.
        let partition = Partition::from_assignment(graph, k, outcome.assignment);
        Ok(DistributedReport {
            partition,
            transfers: outcome.transfers_applied as usize,
            overhead,
            converged: outcome.converged,
            timed_out: false,
        })
    }

    /// One hierarchical epoch (DESIGN.md §12): a phase-1 outer round
    /// where the leader and the other rack leaders exchange O(R)
    /// `RackUpdate` aggregates over a [`RackBus`], the guarded
    /// map-back, then a phase-2 round of concurrent per-rack scoped
    /// rings. Non-leader racks ship their ring outcome back in a
    /// `RackResult`; the leader merges them into the final partition.
    fn refine_hierarchical(
        &mut self,
        graph: &Graph,
        machines: &MachineConfig,
        initial: Partition,
        layout: &RackLayout,
    ) -> Result<DistributedReport, WireError> {
        let k = self.ep.machine_count();
        if machines.count() != k {
            return Err(WireError::Protocol(format!(
                "cluster has {k} machines but the round's fixture wants {}",
                machines.count()
            )));
        }
        if layout.machine_count() != k {
            return Err(WireError::Protocol(format!(
                "rack layout covers {} machines but the cluster has {k}",
                layout.machine_count()
            )));
        }
        let racks = layout.rack_count();
        self.ep.drain_inbox();
        self.reported = vec![false; k];
        self.reported[0] = true;
        let epoch = self.epoch;
        self.epoch += 1;

        // Phase 1: the outer game on the rack quotient. Machine 0
        // always leads its own rack (it is the smallest id), and kicks
        // rack 0 — possibly itself — exactly like the in-process ring.
        let begin = self.epoch_frame(epoch, 1, graph, initial.assignment())?;
        self.broadcast_begin(&begin)?;
        let before = self.ep.stats_snapshot();
        let my_rack = layout.rack_of(0);
        let qconfig = layout.quotient_config(machines);
        let qpart = Partition::from_assignment(
            graph,
            racks,
            layout.quotient_assignment(initial.assignment()),
        );
        let actor = MachineActor::new(
            my_rack,
            Arc::new(graph.clone()),
            qconfig,
            &qpart,
            self.opts.mu,
            self.opts.framework,
            self.opts.migration_charge,
        );
        let outer = {
            let bus = RackBus::new(&self.ep, my_rack, layout.leaders());
            bus.send(0, Message::TakeMyTurn { consecutive_forfeits: 0, transfers_so_far: 0 });
            let opts = &self.opts;
            machine_loop(actor, &bus, opts.epsilon, opts.max_transfers, opts.recv_timeout)
        };
        if outer.timed_out {
            return Err(WireError::Protocol(match outer.dead_peer {
                Some(r) => format!("outer round lost rack {r}'s leader (send failed)"),
                None => "outer round timed out waiting on a rack leader".into(),
            }));
        }
        // Phase-1 barrier: every worker reports, spectators included.
        let mut worker_stats = OverheadStats::default();
        self.stats_barrier(&mut worker_stats)?;

        // Guarded map-back to machines (shared with every other
        // deployment of the hierarchy).
        let mapped = guarded_map_back(
            graph,
            machines,
            layout,
            initial.assignment(),
            &outer.assignment,
            self.opts.mu,
            self.opts.framework,
        );
        let outer_transfers =
            if mapped.accepted { outer.transfers_applied as usize } else { 0 };
        let start = Partition::from_assignment(graph, k, mapped.assignment);

        // Phase 2: concurrent scoped rings, one per rack. The leader
        // plays (and kicks) its own rack's ring; every other rack's
        // leader kicks its own.
        self.reported = vec![false; k];
        self.reported[0] = true;
        let begin = self.epoch_frame(epoch, 2, graph, start.assignment())?;
        self.broadcast_begin(&begin)?;
        let scope = layout.members(my_rack).to_vec();
        let actor = MachineActor::new(
            0,
            Arc::new(graph.clone()),
            machines.clone(),
            &start,
            self.opts.mu,
            self.opts.framework,
            self.opts.migration_charge,
        )
        .with_scope(scope.clone());
        self.ep.send(0, Message::TakeMyTurn { consecutive_forfeits: 0, transfers_so_far: 0 });
        let inner = machine_loop_scoped(
            actor,
            &self.ep,
            &scope,
            self.opts.epsilon,
            self.opts.max_transfers,
            self.opts.recv_timeout,
        );
        if inner.timed_out {
            return Err(WireError::Protocol(match inner.dead_peer {
                Some(m) => format!("inner round lost machine {m} (send failed)"),
                None => "inner round timed out waiting on a rack member".into(),
            }));
        }

        // Phase-2 barrier: K−1 RoundStats plus one RackResult from
        // every rack the leader is not in, in any interleaving.
        let mut assignment = inner.assignment.clone();
        let mut transfers = outer_transfers + inner.transfers_applied as usize;
        let mut converged = outer.converged && inner.converged;
        let mut got_rack = vec![false; racks];
        got_rack[my_rack] = true;
        let mut remaining_stats = k - 1;
        let mut remaining_racks = racks - 1;
        while remaining_stats > 0 || remaining_racks > 0 {
            let (state, waiting) = if remaining_stats > 0 {
                ("awaiting RoundStats", self.first_unreported_wire())
            } else {
                let rack = (0..racks).find(|&r| !got_rack[r]).unwrap_or(0);
                ("awaiting RackResult", self.ep.wire_of(layout.leader(rack)))
            };
            match self.recv_awaiting(self.opts.recv_timeout, state, waiting)? {
                (peer, Frame::RoundStats(s)) if !self.reported[peer] => {
                    self.reported[peer] = true;
                    worker_stats.add(&s);
                    remaining_stats -= 1;
                }
                (peer, Frame::RackResult { rack, transfers: t, converged: c, assignment: a }) => {
                    let rack = rack as usize;
                    if rack >= racks || got_rack[rack] || layout.leader(rack) != peer {
                        return Err(WireError::Protocol(format!(
                            "machine {peer} sent an invalid RackResult for rack {rack}"
                        )));
                    }
                    got_rack[rack] = true;
                    for &(node, machine) in &a {
                        let (node, machine) = (node as usize, machine as MachineId);
                        let valid = node < assignment.len()
                            && machine < k
                            && layout.rack_of(machine) == rack
                            && layout.rack_of(start.machine_of(node)) == rack;
                        if !valid {
                            return Err(WireError::Protocol(format!(
                                "rack {rack} reported an out-of-rack move of node {node}"
                            )));
                        }
                        assignment[node] = machine;
                    }
                    transfers += t as usize;
                    converged = converged && c;
                    remaining_racks -= 1;
                }
                (peer, frame) => {
                    return Err(WireError::Protocol(format!(
                        "unexpected control frame from machine {peer} during barrier: {frame:?}"
                    )));
                }
            }
        }
        let mut overhead = self.ep.stats_snapshot().delta_since(&before);
        overhead.add(&worker_stats);
        Ok(DistributedReport {
            partition: Partition::from_assignment(graph, k, assignment),
            transfers,
            overhead,
            converged,
            timed_out: false,
        })
    }

    /// `recv_ctrl` with barrier context: a failure names the peer the
    /// barrier is still waiting on (wire id) and the frame it awaits,
    /// so the error that reaches the CLI reads "peer 3, awaiting
    /// AdmitAck: …" instead of a bare timeout.
    fn recv_awaiting(
        &self,
        timeout: Duration,
        state: &str,
        peer_wire: MachineId,
    ) -> Result<(MachineId, Frame), WireError> {
        self.ep.recv_ctrl(timeout).map_err(|e| e.while_awaiting(state, peer_wire))
    }

    /// The wire id of the first peer whose `RoundStats` the round in
    /// flight is still missing (context for barrier errors).
    fn first_unreported_wire(&self) -> MachineId {
        let k = self.ep.machine_count();
        let logical = (0..k).find(|&m| !self.reported[m]).unwrap_or(0);
        self.ep.wire_of(logical)
    }

    /// Barrier on K−1 worker `RoundStats`, folding them into `into`.
    fn stats_barrier(&mut self, into: &mut OverheadStats) -> Result<(), WireError> {
        let mut remaining = self.ep.machine_count() - 1;
        while remaining > 0 {
            let waiting = self.first_unreported_wire();
            match self.recv_awaiting(self.opts.recv_timeout, "awaiting RoundStats", waiting)? {
                (peer, Frame::RoundStats(s)) if !self.reported[peer] => {
                    self.reported[peer] = true;
                    into.add(&s);
                    remaining -= 1;
                }
                (peer, frame) => {
                    return Err(WireError::Protocol(format!(
                        "unexpected control frame from machine {peer} during barrier: {frame:?}"
                    )));
                }
            }
        }
        Ok(())
    }

    /// After a failed [`ClusterLeader::refine`], work out which
    /// workers are dead. Evidence is twofold: send failures recorded
    /// at the leader's own sockets, and silence — any worker that does
    /// not deliver its `RoundStats` within one receive-timeout grace
    /// window. Live workers send `RoundStats` even after a timed-out
    /// round precisely so they can prove themselves here.
    ///
    /// Returns the dead machines' *current logical ids*, ascending.
    /// An alive-but-stalled worker that stays silent past the grace
    /// window is evicted too — see the module doc's known limitation.
    pub fn diagnose_dead(&mut self) -> Result<Vec<MachineId>, WireError> {
        let k = self.ep.machine_count();
        // Workers whose RoundStats the failed round's barrier already
        // consumed have proven themselves; they will not report twice.
        let mut alive = std::mem::take(&mut self.reported);
        alive.resize(k, false);
        alive[0] = true;
        // 2x the round timeout: a live worker only discovers the dead
        // ring after waiting out its own `recv_timeout`, and its
        // RoundStats still has to cross the wire after that.
        let deadline = Instant::now() + self.opts.recv_timeout * 2;
        while alive.iter().any(|&a| !a) {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            match self.ep.recv_ctrl(left) {
                Ok((peer, Frame::RoundStats(_))) => alive[peer] = true,
                Ok(_) => continue, // stale traffic from the aborted round
                Err(WireError::Protocol(_)) => break, // grace window elapsed
                Err(e) => return Err(e),
            }
        }
        let failed = self.ep.take_send_failures();
        // Empty means every worker answered the post-mortem: the
        // failure was not a worker death and the caller should
        // propagate its original error instead of recovering.
        let dead: Vec<MachineId> =
            (1..k).filter(|m| !alive[*m] || failed.contains_key(m)).collect();
        for m in &dead {
            let why = failed.get(m).cloned().unwrap_or_else(|| "no RoundStats within grace".into());
            eprintln!("gtip leader: machine {m} presumed dead ({why})");
        }
        Ok(dead)
    }

    /// Re-form the cluster around the survivors of `dead` (current
    /// logical ids) and hand every survivor its new identity and the
    /// renormalized speeds. Blocks until every survivor acknowledges —
    /// the ack doubles as a barrier keeping stale round traffic out of
    /// the next epoch.
    pub fn recover(
        &mut self,
        dead: &[MachineId],
        machines_after: &MachineConfig,
    ) -> Result<(), WireError> {
        let k = self.ep.machine_count();
        if dead.is_empty() || dead.contains(&0) {
            return Err(WireError::Protocol(
                "recovery needs a non-empty dead list that excludes the leader".into(),
            ));
        }
        if machines_after.count() + dead.len() != k {
            return Err(WireError::Protocol(format!(
                "{} survivors + {} dead != {k} machines",
                machines_after.count(),
                dead.len()
            )));
        }
        let survivors_wire: Vec<MachineId> =
            (0..k).filter(|m| !dead.contains(m)).map(|m| self.ep.wire_of(m)).collect();
        if let Some(l) = &self.layout {
            // Shrink the rack layout with the fleet (dead are current
            // logical ids, exactly what `without_machines` wants).
            self.layout = Some(l.without_machines(dead).map_err(WireError::Protocol)?);
        }
        self.ep.compact(&survivors_wire)?;
        self.ep.drain_inbox();
        self.reported = vec![false; self.ep.machine_count()];
        let frame = Frame::Restore {
            survivors: survivors_wire
                .iter()
                .map(|&w| wire_u32(w))
                .collect::<Result<_, _>>()?,
            speeds: machines_after.speeds().to_vec(),
        };
        self.ep.broadcast_ctrl(&frame)?;
        self.await_restore_acks(self.opts.recv_timeout)
    }

    /// Ack barrier after a `Restore` broadcast: every member confirms
    /// it compacted to the same membership before the next epoch's
    /// traffic starts. Shared by [`ClusterLeader::recover`] and the
    /// admission rollback; stale `RoundStats` (post-mortem reports)
    /// and `AdmitAck`s (a survivor that extended before the rollback)
    /// are skipped.
    fn await_restore_acks(&mut self, patience: Duration) -> Result<(), WireError> {
        let k_after = self.ep.machine_count();
        let mut acked = vec![false; k_after];
        acked[0] = true;
        let mut remaining = k_after - 1;
        while remaining > 0 {
            let unacked = (0..k_after).find(|&m| !acked[m]).unwrap_or(0);
            let waiting = self.ep.wire_of(unacked);
            match self.recv_awaiting(patience, "awaiting RestoreAck", waiting)? {
                (peer, Frame::RestoreAck { machine }) => {
                    if self.ep.wire_of(peer) != machine as MachineId {
                        return Err(WireError::Protocol(format!(
                            "machine {peer} acked the restore as wire id {machine}, expected {}",
                            self.ep.wire_of(peer)
                        )));
                    }
                    if !acked[peer] {
                        acked[peer] = true;
                        remaining -= 1;
                    }
                }
                (_, Frame::RoundStats(_)) => continue, // stale post-mortem report
                (_, Frame::AdmitAck { .. }) => continue, // stale pre-rollback ack
                (peer, frame) => {
                    return Err(WireError::Protocol(format!(
                        "unexpected control frame from machine {peer} during restore: {frame:?}"
                    )));
                }
            }
        }
        Ok(())
    }

    /// The logical id (= list position) a currently-evicted wire id
    /// would take on admission: wire ids stay ascending, so the joiner
    /// slots in between its wire-id neighbours and every member to its
    /// right shifts up by one. The driver needs this *before*
    /// [`ClusterLeader::admit`] to build the K+1 speed vector and
    /// remap the engine assignment.
    pub fn joiner_position(&self, wire: MachineId) -> usize {
        self.ep.wire_of.iter().filter(|&&w| w < wire).count()
    }

    /// Next queued join request, if any. Requests from a wire id that
    /// is currently an active member are rejected here (Goodbye), and
    /// a newer request from the same wire id supersedes an older one —
    /// the joiner only re-dials after its previous attempt was
    /// rejected or closed, so the older stream is dead.
    pub fn pending_join(&mut self) -> Option<JoinRequest> {
        while let Ok(req) = self.pending.try_recv() {
            self.pending_buf.push_back(req);
        }
        while let Some(mut req) = self.pending_buf.pop_front() {
            if self.ep.wire_is_active(req.wire_id) {
                eprintln!(
                    "gtip leader: rejecting Join from wire id {} (already an active member)",
                    req.wire_id
                );
                let _ = write_frame(&mut req.stream, &Frame::Goodbye);
                continue;
            }
            if self.pending_buf.iter().any(|r| r.wire_id == req.wire_id) {
                continue; // superseded by a newer request from the same joiner
            }
            return Some(req);
        }
        None
    }

    /// Admit a joiner at an epoch boundary: dial it, extend the mesh,
    /// broadcast `Admit`, ship the joiner the fixture (`Setup`) plus
    /// the boundary snapshot (`Catchup`), and run the ack barrier.
    ///
    /// `machines_after` is the renormalized K+1 speed vector with the
    /// joiner at [`ClusterLeader::joiner_position`]; `snapshot` is the
    /// encoded boundary checkpoint *already remapped* to the K+1
    /// numbering. Returns `Ok(true)` if the joiner is in, `Ok(false)`
    /// if the admission failed but the cluster rolled back cleanly to
    /// its previous membership (the run continues at K), and `Err` if
    /// the rollback itself failed.
    pub fn admit(
        &mut self,
        req: JoinRequest,
        graph: &Graph,
        machines_before: &MachineConfig,
        machines_after: &MachineConfig,
        snapshot: &[u8],
    ) -> Result<bool, WireError> {
        let joiner = req.wire_id;
        let k_orig = self.addrs.len();
        if joiner == 0 || joiner >= k_orig || self.ep.wire_is_active(joiner) {
            return Err(WireError::Protocol(format!(
                "wire id {joiner} is not an admissible joiner"
            )));
        }
        let old_members = self.ep.wire_of.clone();
        if machines_before.count() != old_members.len()
            || machines_after.count() != old_members.len() + 1
        {
            return Err(WireError::Protocol(format!(
                "admission fixtures have {}/{} machines for a {}-member mesh",
                machines_before.count(),
                machines_after.count(),
                old_members.len()
            )));
        }
        // Dial the joiner first: a failure here leaves the mesh
        // untouched, so no rollback is needed — just drop the request
        // (the joiner will re-dial when its stream closes).
        let deadline = Instant::now() + self.admit_window;
        let mut out = match dial_peer(&self.addrs[joiner], deadline) {
            Ok(out) => out,
            Err(e) => {
                eprintln!("gtip leader: cannot dial joiner {joiner}: {e}");
                return Ok(false);
            }
        };
        if let Err(e) = write_frame(
            &mut out,
            &Frame::Hello { version: WIRE_VERSION, machine: 0, machines: wire_u32(k_orig)? },
        ) {
            eprintln!("gtip leader: hello to joiner {joiner} failed: {e}");
            return Ok(false);
        }
        let mut members = old_members.clone();
        let pos = self.joiner_position(joiner);
        members.insert(pos, joiner);
        // Resolve the joiner's rack before the mesh grows: honor the
        // request if it names an existing rack (or the next fresh one),
        // otherwise place it in the emptiest rack. Flat clusters ship 0.
        let old_layout = self.layout.clone();
        let joiner_rack = match &old_layout {
            Some(l) => match req.rack {
                Some(r) if r <= l.rack_count() => r,
                Some(r) => {
                    eprintln!(
                        "gtip leader: joiner asked for rack {r} of {}; using the emptiest",
                        l.rack_count()
                    );
                    l.join_rack()
                }
                None => l.join_rack(),
            },
            None => 0,
        };
        self.ep.extend(&members, joiner, out, req.stream)?;
        if let Some(l) = &old_layout {
            // Grow the layout first so the joiner's Setup ships it.
            self.layout = Some(l.with_inserted(pos, joiner_rack).map_err(WireError::Protocol)?);
        }

        let result = (|| -> Result<(), WireError> {
            self.ep.broadcast_ctrl(&Frame::Admit {
                members: members.iter().map(|&w| wire_u32(w)).collect::<Result<_, _>>()?,
                joiner: wire_u32(joiner)?,
                speeds: machines_after.speeds().to_vec(),
                rack: wire_u32(joiner_rack)?,
            })?;
            self.ep.send_ctrl(pos, &self.setup_frame(graph, machines_after)?)?;
            self.ep.send_ctrl(pos, &Frame::Catchup { snapshot: snapshot.to_vec() })?;
            // Ack barrier: every member (joiner included) confirms the
            // extended mesh before the next epoch's traffic starts.
            let k_new = members.len();
            let mut acked = vec![false; k_new];
            acked[0] = true;
            let mut remaining = k_new - 1;
            while remaining > 0 {
                let unacked = (0..k_new).find(|&m| !acked[m]).unwrap_or(0);
                let waiting = self.ep.wire_of(unacked);
                match self.recv_awaiting(self.admit_window, "awaiting AdmitAck", waiting)? {
                    (peer, Frame::AdmitAck { machine }) => {
                        if self.ep.wire_of(peer) != machine as MachineId {
                            return Err(WireError::Protocol(format!(
                                "machine {peer} acked the admit as wire id {machine}, expected {}",
                                self.ep.wire_of(peer)
                            )));
                        }
                        if !acked[peer] {
                            acked[peer] = true;
                            remaining -= 1;
                        }
                    }
                    (_, Frame::RoundStats(_)) => continue, // stale report
                    (peer, frame) => {
                        return Err(WireError::Protocol(format!(
                            "unexpected control frame from machine {peer} during admit: {frame:?}"
                        )));
                    }
                }
            }
            Ok(())
        })();

        match result {
            Ok(()) => {
                self.ep.drain_inbox();
                self.reported = vec![false; self.ep.machine_count()];
                Ok(true)
            }
            Err(e) => {
                eprintln!(
                    "gtip leader: admission of wire id {joiner} failed ({e}); rolling back to K={}",
                    old_members.len()
                );
                self.layout = old_layout;
                self.rollback_admit(&old_members, machines_before)?;
                Ok(false)
            }
        }
    }

    /// Undo a failed admission: compact back to the old membership and
    /// re-run the restore barrier so every survivor is provably back
    /// on the pre-admission mesh before the run continues.
    fn rollback_admit(
        &mut self,
        old_members: &[MachineId],
        machines_before: &MachineConfig,
    ) -> Result<(), WireError> {
        self.ep.compact(old_members)?;
        self.ep.drain_inbox();
        self.reported = vec![false; self.ep.machine_count()];
        self.ep.broadcast_ctrl(&Frame::Restore {
            survivors: old_members.iter().map(|&w| wire_u32(w)).collect::<Result<_, _>>()?,
            speeds: machines_before.speeds().to_vec(),
        })?;
        // A survivor may still be stuck dialing the dead joiner for up
        // to its own handshake window (one receive timeout) before it
        // sees this Restore — hence the longer admit-window patience.
        self.await_restore_acks(self.admit_window)
    }

    /// Graceful shutdown: tell every worker the run is over, and turn
    /// away any joiner still waiting at the door.
    pub fn shutdown(mut self) -> Result<(), WireError> {
        self.acceptor_stop.store(true, Ordering::Relaxed);
        while let Some(mut req) = self.pending_join() {
            let _ = write_frame(&mut req.stream, &Frame::Goodbye);
        }
        self.ep.broadcast_ctrl(&Frame::Goodbye)
    }
}

impl Drop for ClusterLeader {
    fn drop(&mut self) {
        self.acceptor_stop.store(true, Ordering::Relaxed);
    }
}

/// The leader's admission acceptor: runs for the whole cluster
/// lifetime on a clone of the (nonblocking) mesh listener, validating
/// `Hello` + `Join` handshakes and queueing good ones for the driver
/// to pick up at the next epoch boundary — a mid-epoch `Join` is
/// thereby deferred, never dropped. Semantic rejects get a `Goodbye`
/// so the joiner can distinguish "no" from "not yet".
fn join_acceptor(
    listener: TcpListener,
    k_orig: usize,
    stop: Arc<AtomicBool>,
    tx: Sender<JoinRequest>,
) {
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        match listener.accept() {
            Ok((stream, addr)) => match join_handshake(stream, k_orig) {
                Ok(req) => {
                    eprintln!(
                        "gtip leader: queued Join from wire id {} (speed {})",
                        req.wire_id, req.speed
                    );
                    if tx.send(req).is_err() {
                        return; // leader dropped
                    }
                }
                Err((e, stream)) => {
                    eprintln!("gtip leader: dropping join dial from {addr}: {e}");
                    if let Some(mut stream) = stream {
                        let _ = write_frame(&mut stream, &Frame::Goodbye);
                    }
                }
            },
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(e) => {
                eprintln!("gtip leader: join acceptor error: {e}");
                std::thread::sleep(ACCEPT_POLL);
            }
        }
    }
}

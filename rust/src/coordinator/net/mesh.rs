//! Layer 3 of the coordinator's network stack (DESIGN.md §13): the
//! full-mesh TCP endpoint. [`TcpEndpoint`] is the [`Bus`] impl over
//! real sockets — one [`FramedConn`] per outbound peer, one reader
//! thread per inbound connection — with the wire-id/logical-id split
//! that lets [`TcpEndpoint::compact`] (eviction) and
//! [`TcpEndpoint::extend`] (admission) re-form a live mesh. The
//! loopback harnesses used by the transport-equivalence tests live
//! here too. Dialing, handshakes, and framing come from the layers
//! below; epoch orchestration belongs to the roles above.

use std::collections::{BTreeMap, VecDeque};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::bus::{Bus, RecvOutcome};
use crate::coordinator::distributed::{
    run_hierarchical_over_endpoints, run_over_endpoints, DistributedOptions, DistributedReport,
};
use crate::coordinator::protocol::{Message, OverheadStats};
use crate::game::hierarchy::RackLayout;
use crate::graph::Graph;
use crate::partition::{MachineConfig, MachineId, Partition};

use super::codec::{encode_frame, read_frame, wire_u32, write_frame, Frame, WireError, WIRE_VERSION};
use super::handshake::accept_peers;
use super::session::{dial_peer, lock_unpoisoned, FramedConn};

/// Byte/message accounting of the control plane (handshakes, epoch
/// setup/begin, stats reports) — kept apart from [`OverheadStats`] so
/// the §4.5 metric stays about the game's O(K) state exchange.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NetStats {
    pub control_messages: u64,
    pub control_bytes: u64,
}

/// Send failures recorded at the send site (satellite of the recovery
/// protocol): `map` keeps the first error per logical peer for the
/// leader's death diagnosis, `fresh` queues not-yet-reported peers so
/// the actor loop sees a [`RecvOutcome::SendFailed`] instead of
/// waiting out the full receive timeout.
#[derive(Default)]
pub(super) struct SendFailures {
    map: BTreeMap<MachineId, String>,
    fresh: VecDeque<MachineId>,
}

/// One machine's socket-backed endpoint: a listener's worth of inbound
/// reader threads feeding an inbox, plus one outbound stream per peer.
///
/// After a [`TcpEndpoint::compact`] (cluster re-formation around the
/// survivors of a worker death) the endpoint distinguishes *wire* ids
/// — the immutable machine numbers of the original mesh, which the
/// reader threads and `outs` slots keep forever — from *logical* ids,
/// the dense `0..k` numbering the refinement protocol runs on. Before
/// any compaction the two coincide.
pub struct TcpEndpoint {
    /// Current logical id (== position of `wire_id` in the survivor
    /// list after a compaction).
    pub(super) id: MachineId,
    /// Current logical machine count.
    pub(super) k: usize,
    /// This machine's immutable id in the original mesh.
    pub(super) wire_id: MachineId,
    /// logical id → wire id (ascending; identity before compaction).
    pub(super) wire_of: Vec<MachineId>,
    /// wire id → logical id (`None` = evicted peer).
    pub(super) logical_of: Vec<Option<MachineId>>,
    pub(super) inbox: Receiver<Message>,
    pub(super) inbox_tx: Sender<Message>,
    pub(super) ctrl: Receiver<(MachineId, Frame)>,
    /// Kept so [`TcpEndpoint::extend`] can hand new reader threads the
    /// same control channel the original mesh readers feed.
    pub(super) ctrl_tx: Sender<(MachineId, Frame)>,
    /// The bound listener (nonblocking), retained past mesh formation
    /// so an admission can accept the joiner's return dial on the same
    /// address the peer list names for this machine.
    pub(super) listener: TcpListener,
    /// Outbound framed sessions, indexed by *wire* id.
    pub(super) outs: Vec<Option<FramedConn>>,
    pub(super) stats: Arc<Mutex<OverheadStats>>,
    pub(super) net: Arc<Mutex<NetStats>>,
    pub(super) failures: Mutex<SendFailures>,
}

impl Bus for TcpEndpoint {
    fn id(&self) -> MachineId {
        self.id
    }

    fn machine_count(&self) -> usize {
        self.k
    }

    fn send(&self, to: MachineId, msg: Message) {
        if to == self.id {
            // Loopback without touching the network (the ring kick).
            lock_unpoisoned(&self.stats).record(&msg);
            let _ = self.inbox_tx.send(msg);
            return;
        }
        let bytes = match encode_frame(&Frame::Msg(msg.clone())) {
            Ok(bytes) => bytes,
            Err(e) => {
                self.record_send_failure(to, format!("encoding for machine {to}: {e}"));
                return;
            }
        };
        debug_assert_eq!(bytes.len(), msg.wire_bytes(), "codec vs wire_bytes drift");
        lock_unpoisoned(&self.stats).record(&msg);
        let wire = self.wire_of[to];
        match &self.outs[wire] {
            Some(conn) => {
                // A dead peer must not be silently ignored: record the
                // failure at the send site so the actor loop exits
                // through `SendFailed` and the leader's diagnosis can
                // name the peer, instead of every machine waiting out
                // its receive timeout on a ring that can never close.
                if let Err(e) = conn.send_bytes(&bytes) {
                    self.record_send_failure(to, format!("sending to machine {to}: {e}"));
                }
            }
            None => self.record_send_failure(to, format!("no connection to machine {to}")),
        }
    }

    fn recv_timeout(&self, timeout: Duration) -> RecvOutcome {
        if let Some(m) = lock_unpoisoned(&self.failures).fresh.pop_front() {
            return RecvOutcome::SendFailed(m);
        }
        match self.inbox.recv_timeout(timeout) {
            Ok(msg) => RecvOutcome::Msg(msg),
            Err(RecvTimeoutError::Timeout) => RecvOutcome::TimedOut,
            Err(RecvTimeoutError::Disconnected) => RecvOutcome::Disconnected,
        }
    }
}

impl TcpEndpoint {
    /// This machine's immutable id in the original mesh.
    pub fn wire_id(&self) -> MachineId {
        self.wire_id
    }

    /// The wire id behind a current logical id.
    pub fn wire_of(&self, logical: MachineId) -> MachineId {
        self.wire_of[logical]
    }

    fn record_send_failure(&self, to: MachineId, what: String) {
        let mut f = lock_unpoisoned(&self.failures);
        if !f.map.contains_key(&to) {
            f.map.insert(to, what);
            f.fresh.push_back(to);
        }
    }

    /// Drain and return the recorded send failures (logical peer →
    /// first error). Feeds the leader's death diagnosis.
    pub fn take_send_failures(&self) -> BTreeMap<MachineId, String> {
        let mut f = lock_unpoisoned(&self.failures);
        f.fresh.clear();
        std::mem::take(&mut f.map)
    }

    /// Discard buffered protocol messages (stale traffic from an
    /// aborted round). Returns how many were dropped.
    pub fn drain_inbox(&self) -> usize {
        let mut n = 0;
        while self.inbox.try_recv().is_ok() {
            n += 1;
        }
        n
    }

    /// Re-form the endpoint around `survivors_wire` — the surviving
    /// wire ids of the original mesh, ascending, including this
    /// machine. Logical ids become positions in the list; outbound
    /// streams to evicted peers are closed; recorded send failures
    /// (which name old logical ids) are cleared.
    pub fn compact(&mut self, survivors_wire: &[MachineId]) -> Result<(), WireError> {
        if survivors_wire.is_empty() || !survivors_wire.windows(2).all(|w| w[0] < w[1]) {
            return Err(WireError::Protocol(
                "survivor list must be non-empty and strictly ascending".into(),
            ));
        }
        if *survivors_wire.last().expect("non-empty") >= self.logical_of.len() {
            return Err(WireError::Protocol(format!(
                "survivor list names wire id {} but the mesh had {} machines",
                survivors_wire.last().expect("non-empty"),
                self.logical_of.len()
            )));
        }
        let me = survivors_wire.iter().position(|&w| w == self.wire_id).ok_or_else(|| {
            WireError::Protocol(format!(
                "this machine (wire id {}) is missing from the survivor list",
                self.wire_id
            ))
        })?;
        for wire in 0..self.logical_of.len() {
            if !survivors_wire.contains(&wire) {
                self.outs[wire] = None; // closes the socket to the evicted peer
            }
        }
        self.logical_of = vec![None; self.logical_of.len()];
        for (logical, &wire) in survivors_wire.iter().enumerate() {
            self.logical_of[wire] = Some(logical);
        }
        self.wire_of = survivors_wire.to_vec();
        self.k = survivors_wire.len();
        self.id = me;
        let mut f = lock_unpoisoned(&self.failures);
        f.map.clear();
        f.fresh.clear();
        Ok(())
    }

    /// Whether a wire id currently maps to a live logical peer.
    pub fn wire_is_active(&self, wire: MachineId) -> bool {
        self.logical_of.get(wire).copied().flatten().is_some()
    }

    /// Re-form the endpoint around `members_wire` — the new member wire
    /// ids, ascending, including this machine and `joiner` — installing
    /// `out` as the outbound stream to the joiner and spawning a reader
    /// on `inbound`, the joiner's dial to us. The exact mirror of
    /// [`TcpEndpoint::compact`]: logical ids become positions in the
    /// list, and stale send failures are cleared. The joiner must be a
    /// currently-evicted wire id, and the other members must be exactly
    /// the current mesh — an admission only ever grows the fleet by
    /// one.
    pub fn extend(
        &mut self,
        members_wire: &[MachineId],
        joiner: MachineId,
        out: TcpStream,
        inbound: TcpStream,
    ) -> Result<(), WireError> {
        if members_wire.is_empty() || !members_wire.windows(2).all(|w| w[0] < w[1]) {
            return Err(WireError::Protocol(
                "member list must be non-empty and strictly ascending".into(),
            ));
        }
        if *members_wire.last().expect("non-empty") >= self.logical_of.len() {
            return Err(WireError::Protocol(format!(
                "member list names wire id {} but the mesh had {} machines",
                members_wire.last().expect("non-empty"),
                self.logical_of.len()
            )));
        }
        if !members_wire.contains(&joiner) {
            return Err(WireError::Protocol(format!(
                "joiner (wire id {joiner}) is missing from the member list"
            )));
        }
        if self.wire_is_active(joiner) || joiner == self.wire_id {
            return Err(WireError::Protocol(format!(
                "joiner wire id {joiner} is already an active member"
            )));
        }
        let me = members_wire.iter().position(|&w| w == self.wire_id).ok_or_else(|| {
            WireError::Protocol(format!(
                "this machine (wire id {}) is missing from the member list",
                self.wire_id
            ))
        })?;
        let others: Vec<MachineId> =
            members_wire.iter().copied().filter(|&w| w != joiner).collect();
        if others != self.wire_of {
            return Err(WireError::Protocol(format!(
                "member list minus the joiner is {others:?} but the current mesh is {:?}",
                self.wire_of
            )));
        }
        self.outs[joiner] = Some(FramedConn::new(out));
        spawn_reader(inbound, joiner, self.inbox_tx.clone(), self.ctrl_tx.clone());
        self.logical_of = vec![None; self.logical_of.len()];
        for (logical, &wire) in members_wire.iter().enumerate() {
            self.logical_of[wire] = Some(logical);
        }
        self.wire_of = members_wire.to_vec();
        self.k = members_wire.len();
        self.id = me;
        let mut f = lock_unpoisoned(&self.failures);
        f.map.clear();
        f.fresh.clear();
        Ok(())
    }

    /// Send a control frame to one peer (logical id). A write failure
    /// is recorded (it is death-diagnosis evidence) as well as
    /// returned.
    pub fn send_ctrl(&self, to: MachineId, frame: &Frame) -> Result<(), WireError> {
        let wire = self.wire_of[to];
        let conn = match self.outs[wire].as_ref() {
            Some(conn) => conn,
            None => {
                self.record_send_failure(to, format!("no connection to machine {to}"));
                return Err(WireError::Protocol(format!("no connection to machine {to}")));
            }
        };
        let bytes = encode_frame(frame)?;
        if let Err(e) = conn.send_bytes(&bytes) {
            self.record_send_failure(to, format!("sending a control frame to machine {to}: {e}"));
            return Err(e.into());
        }
        let mut net = lock_unpoisoned(&self.net);
        net.control_messages += 1;
        net.control_bytes += bytes.len() as u64;
        Ok(())
    }

    /// Send a control frame to every peer.
    pub fn broadcast_ctrl(&self, frame: &Frame) -> Result<(), WireError> {
        for to in 0..self.k {
            if to != self.id {
                self.send_ctrl(to, frame)?;
            }
        }
        Ok(())
    }

    /// Receive the next control frame (tagged with its sender's
    /// current logical id). Frames from evicted peers are dropped.
    pub fn recv_ctrl(&self, timeout: Duration) -> Result<(MachineId, Frame), WireError> {
        let deadline = Instant::now() + timeout;
        loop {
            let left = deadline.saturating_duration_since(Instant::now());
            match self.ctrl.recv_timeout(left) {
                Ok((wire, frame)) => {
                    match self.logical_of.get(wire).copied().flatten() {
                        Some(logical) => return Ok((logical, frame)),
                        None => continue, // stale frame from an evicted peer
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    return Err(WireError::Protocol(
                        "timed out waiting for a control frame".into(),
                    ))
                }
                Err(RecvTimeoutError::Disconnected) => return Err(WireError::Closed),
            }
        }
    }

    /// Snapshot of the protocol-message accounting.
    pub fn stats_snapshot(&self) -> OverheadStats {
        lock_unpoisoned(&self.stats).clone()
    }

    /// Snapshot of the control-plane accounting.
    pub fn net_snapshot(&self) -> NetStats {
        *lock_unpoisoned(&self.net)
    }
}

/// Build machine `id`'s endpoint from an already-bound listener:
/// full-mesh dial with deterministic `Hello` handshakes, then one
/// reader thread per inbound connection.
fn mesh_with_listener(
    listener: TcpListener,
    id: MachineId,
    addrs: &[String],
    connect_timeout: Duration,
    stats: Arc<Mutex<OverheadStats>>,
) -> Result<TcpEndpoint, WireError> {
    let k = addrs.len();
    assert!(id < k, "machine id {id} out of range for {k} machines");
    let deadline = Instant::now() + connect_timeout;

    // The accept thread runs on a clone; the original is retained in
    // the endpoint so a later admission can accept a joiner's dial.
    // Clones share the file description, so the nonblocking mode set
    // here applies to both — post-mesh accepts poll `WouldBlock`.
    listener.set_nonblocking(true)?;
    let accept_handle = if k > 1 {
        let acceptor = listener.try_clone()?;
        Some(std::thread::spawn(move || accept_peers(acceptor, id, k, deadline)))
    } else {
        None
    };

    // Dial everyone else (ascending machine order for determinism).
    let mut outs: Vec<Option<FramedConn>> = (0..k).map(|_| None).collect();
    for (peer, addr) in addrs.iter().enumerate() {
        if peer == id {
            continue;
        }
        let mut stream =
            dial_peer(addr, deadline).map_err(|e| e.while_awaiting("dialing", peer))?;
        write_frame(
            &mut stream,
            &Frame::Hello { version: WIRE_VERSION, machine: wire_u32(id)?, machines: wire_u32(k)? },
        )?;
        outs[peer] = Some(FramedConn::new(stream));
    }

    let inbound = match accept_handle {
        Some(h) => h.join().expect("accept thread panicked")?,
        None => Vec::new(),
    };

    let (inbox_tx, inbox) = channel();
    let (ctrl_tx, ctrl) = channel();
    for (peer, stream) in inbound {
        spawn_reader(stream, peer, inbox_tx.clone(), ctrl_tx.clone());
    }

    Ok(TcpEndpoint {
        id,
        k,
        wire_id: id,
        wire_of: (0..k).collect(),
        logical_of: (0..k).map(Some).collect(),
        inbox,
        inbox_tx,
        ctrl,
        ctrl_tx,
        listener,
        outs,
        stats,
        net: Arc::new(Mutex::new(NetStats::default())),
        failures: Mutex::new(SendFailures::default()),
    })
}

/// One reader thread per inbound connection: protocol messages go to
/// the shared inbox, everything else to the control channel, keyed by
/// the sender's immutable *wire* id (`recv_ctrl` translates to the
/// current logical id, dropping frames from evicted peers).
pub(super) fn spawn_reader(
    mut stream: TcpStream,
    wire_peer: MachineId,
    inbox_tx: Sender<Message>,
    ctrl_tx: Sender<(MachineId, Frame)>,
) {
    std::thread::spawn(move || loop {
        match read_frame(&mut stream) {
            Ok(Frame::Msg(msg)) => {
                if inbox_tx.send(msg).is_err() {
                    break;
                }
            }
            Ok(frame) => {
                if ctrl_tx.send((wire_peer, frame)).is_err() {
                    break;
                }
            }
            Err(WireError::Closed) => break,
            Err(e) => {
                eprintln!("gtip net: reader for machine {wire_peer} stopped: {e}");
                break;
            }
        }
    });
}

/// Join the mesh as machine `id`: bind `addrs[id]`, dial everyone else.
pub fn connect_mesh(
    id: MachineId,
    addrs: &[String],
    connect_timeout: Duration,
    stats: Arc<Mutex<OverheadStats>>,
) -> Result<TcpEndpoint, WireError> {
    let listener = TcpListener::bind(addrs[id].as_str())
        .map_err(|e| WireError::Io(format!("binding {}: {e}", addrs[id])))?;
    mesh_with_listener(listener, id, addrs, connect_timeout, stats)
}

/// A K-machine loopback mesh inside one process (OS-assigned ports),
/// sharing one [`OverheadStats`] handle exactly like the in-process
/// bus — the test harness for transport equivalence.
pub fn build_tcp_bus_local(
    k: usize,
) -> Result<(Vec<TcpEndpoint>, Arc<Mutex<OverheadStats>>), WireError> {
    assert!(k >= 1);
    let stats = Arc::new(Mutex::new(OverheadStats::default()));
    let mut listeners = Vec::with_capacity(k);
    let mut addrs = Vec::with_capacity(k);
    for _ in 0..k {
        let l = TcpListener::bind("127.0.0.1:0")?;
        addrs.push(l.local_addr()?.to_string());
        listeners.push(l);
    }
    let mut handles = Vec::with_capacity(k);
    for (id, listener) in listeners.into_iter().enumerate() {
        let addrs = addrs.clone();
        let stats = Arc::clone(&stats);
        handles.push(std::thread::spawn(move || {
            mesh_with_listener(listener, id, &addrs, Duration::from_secs(10), stats)
        }));
    }
    let mut endpoints = Vec::with_capacity(k);
    for h in handles {
        endpoints.push(h.join().expect("mesh thread panicked")?);
    }
    Ok((endpoints, stats))
}

/// [`crate::coordinator::run_distributed`], but over a real loopback
/// TCP mesh — same options, same deterministic result.
pub fn run_distributed_tcp_local(
    graph: Arc<Graph>,
    machines: &MachineConfig,
    initial: Partition,
    options: &DistributedOptions,
) -> Result<DistributedReport, WireError> {
    let (endpoints, stats) = build_tcp_bus_local(machines.count())?;
    Ok(run_over_endpoints(endpoints, graph, machines, initial, options, stats))
}

/// [`crate::coordinator::distributed::run_distributed_hierarchical`],
/// but with both levels' meshes on real loopback TCP sockets — the
/// `RackUpdate` aggregates and the scoped rings cross actual wires,
/// and the parity tests assert the result is bit-identical to the
/// in-process hierarchy.
pub fn run_distributed_hierarchical_tcp_local(
    graph: Arc<Graph>,
    machines: &MachineConfig,
    initial: Partition,
    layout: &RackLayout,
    options: &DistributedOptions,
) -> Result<DistributedReport, WireError> {
    let (outer_endpoints, outer_stats) = build_tcp_bus_local(layout.rack_count())?;
    let (inner_endpoints, inner_stats) = build_tcp_bus_local(machines.count())?;
    Ok(run_hierarchical_over_endpoints(
        outer_endpoints,
        outer_stats,
        inner_endpoints,
        inner_stats,
        graph,
        machines,
        initial,
        layout,
        options,
    ))
}

#[cfg(test)]
mod tests {
    use std::io::Write;

    use super::*;

    #[test]
    fn tcp_loopback_mesh_delivers_and_counts_exact_bytes() {
        let (eps, stats) = build_tcp_bus_local(3).unwrap();
        let msg = Message::RegularUpdate { seq: 0, node: 5, from: 0, to: 2, loads: vec![1.0; 3] };
        eps[0].send(1, msg.clone());
        match eps[1].recv_timeout(Duration::from_secs(5)) {
            RecvOutcome::Msg(got) => assert_eq!(got, msg),
            other => panic!("no delivery: {other:?}"),
        }
        let s = stats.lock().unwrap();
        assert_eq!(s.regular_update.messages, 1);
        assert_eq!(s.regular_update.bytes, msg.wire_bytes() as u64);
    }

    /// A panic while holding the shared stats lock must not take the
    /// whole endpoint down with `expect("poisoned")` — the guard is
    /// recovered and traffic keeps flowing.
    #[test]
    fn poisoned_stats_lock_recovers() {
        let (eps, stats) = build_tcp_bus_local(2).unwrap();
        let poisoner = Arc::clone(&stats);
        let _ = std::thread::spawn(move || {
            let _guard = poisoner.lock().unwrap();
            panic!("poison the stats lock");
        })
        .join();
        assert!(stats.lock().is_err(), "lock should be poisoned");

        let msg = Message::TakeMyTurn { consecutive_forfeits: 0, transfers_so_far: 0 };
        eps[0].send(1, msg.clone());
        match eps[1].recv_timeout(Duration::from_secs(5)) {
            RecvOutcome::Msg(got) => assert_eq!(got, msg),
            other => panic!("no delivery through poisoned lock: {other:?}"),
        }
        assert_eq!(eps[0].stats_snapshot().take_my_turn.messages, 1);
    }

    /// An unsendable message surfaces as `SendFailed` at the sender's
    /// next receive instead of the peer silently never hearing from us.
    #[test]
    fn send_failure_surfaces_instead_of_silence() {
        if std::mem::size_of::<usize>() <= 4 {
            return;
        }
        let (eps, _stats) = build_tcp_bus_local(2).unwrap();
        let huge = u32::MAX as usize + 1;
        eps[0].send(1, Message::ReceiveNode { seq: 0, node: 0, from: huge, to: 1 });
        match eps[0].recv_timeout(Duration::from_millis(10)) {
            RecvOutcome::SendFailed(1) => {}
            other => panic!("expected SendFailed(1), got {other:?}"),
        }
        assert!(eps[0].take_send_failures().contains_key(&1));
    }

    /// Compaction renumbers the survivors densely and re-routes both
    /// planes (protocol + control) through the new logical ids.
    #[test]
    fn compact_renumbers_and_reroutes() {
        let (mut eps, _stats) = build_tcp_bus_local(3).unwrap();
        let mut ep2 = eps.pop().unwrap();
        let ep1 = eps.pop().unwrap();
        let mut ep0 = eps.pop().unwrap();
        drop(ep1); // wire machine 1 dies

        ep0.compact(&[0, 2]).unwrap();
        ep2.compact(&[0, 2]).unwrap();
        assert_eq!((ep0.id(), ep0.machine_count()), (0, 2));
        assert_eq!((ep2.id(), ep2.machine_count()), (1, 2));
        assert_eq!(ep2.wire_id(), 2);

        let msg = Message::TakeMyTurn { consecutive_forfeits: 1, transfers_so_far: 2 };
        ep0.send(1, msg.clone()); // logical 1 now means wire 2
        match ep2.recv_timeout(Duration::from_secs(5)) {
            RecvOutcome::Msg(got) => assert_eq!(got, msg),
            other => panic!("no delivery after compaction: {other:?}"),
        }

        ep2.send_ctrl(0, &Frame::RestoreAck { machine: 2 }).unwrap();
        match ep2.recv_ctrl(Duration::from_millis(50)) {
            Err(WireError::Protocol(_)) => {} // nothing inbound for ep2
            other => panic!("unexpected ctrl on ep2: {other:?}"),
        }
        match ep0.recv_ctrl(Duration::from_secs(5)).unwrap() {
            (1, Frame::RestoreAck { machine: 2 }) => {}
            other => panic!("bad ctrl routing after compaction: {other:?}"),
        }

        // Compaction rejects nonsense survivor lists.
        assert!(ep0.compact(&[]).is_err());
        assert!(ep0.compact(&[2, 0]).is_err());
        assert!(ep0.compact(&[2]).is_err()); // missing this machine
        assert!(ep0.compact(&[0, 7]).is_err()); // out of range
    }

    /// A connected loopback socket pair — stands in for the joiner's
    /// dial / the survivor's dial-back during an admission.
    fn stream_pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let dialed = TcpStream::connect(addr).unwrap();
        let (accepted, _) = listener.accept().unwrap();
        (dialed, accepted)
    }

    /// Extension is the exact mirror of compaction: after an eviction
    /// to [0, 2], wire 1 is re-admitted and both planes (protocol +
    /// control) route through the re-grown logical ids — including the
    /// fresh streams to/from the joiner. Bad member lists and joins
    /// for still-active wire ids are rejected without disturbing the
    /// mesh.
    #[test]
    fn extend_readmits_and_reroutes() {
        let (mut eps, _stats) = build_tcp_bus_local(3).unwrap();
        let mut ep2 = eps.pop().unwrap();
        let ep1 = eps.pop().unwrap();
        let mut ep0 = eps.pop().unwrap();
        drop(ep1); // wire machine 1 dies
        ep0.compact(&[0, 2]).unwrap();
        ep2.compact(&[0, 2]).unwrap();

        // Rejection cases first — none of these may touch the mesh.
        let (out, inbound) = stream_pair();
        assert!(ep0.extend(&[0, 1], 1, out, inbound).is_err(), "members minus joiner != mesh");
        let (out, inbound) = stream_pair();
        assert!(ep0.extend(&[0, 1, 2], 2, out, inbound).is_err(), "joiner 2 is still active");
        let (out, inbound) = stream_pair();
        assert!(ep0.extend(&[0, 1, 2], 0, out, inbound).is_err(), "joiner 0 is this machine");
        let (out, inbound) = stream_pair();
        assert!(ep0.extend(&[0, 2], 1, out, inbound).is_err(), "joiner missing from members");
        let (out, inbound) = stream_pair();
        assert!(ep0.extend(&[0, 1, 7], 1, out, inbound).is_err(), "wire id out of range");
        assert_eq!((ep0.id(), ep0.machine_count()), (0, 2), "failed extends must not mutate");
        assert!(!ep0.wire_is_active(1));

        // The real re-admission: wire 1 rejoins on fresh socket pairs.
        let (joiner_to_0, inbound0) = stream_pair();
        let (out0, joiner_from_0) = stream_pair();
        ep0.extend(&[0, 1, 2], 1, out0, inbound0).unwrap();
        let (joiner_to_2, inbound2) = stream_pair();
        let (out2, _joiner_from_2) = stream_pair();
        ep2.extend(&[0, 1, 2], 1, out2, inbound2).unwrap();
        assert_eq!((ep0.id(), ep0.machine_count()), (0, 3));
        assert_eq!((ep2.id(), ep2.machine_count()), (2, 3));
        assert!(ep0.wire_is_active(1));

        // Protocol plane, outbound: logical 1 now reaches the joiner.
        let msg = Message::TakeMyTurn { consecutive_forfeits: 3, transfers_so_far: 4 };
        ep0.send(1, msg.clone());
        let mut joiner_rx = joiner_from_0;
        match read_frame(&mut joiner_rx).unwrap() {
            Frame::Msg(got) => assert_eq!(got, msg),
            other => panic!("joiner expected the protocol message, got {other:?}"),
        }

        // Protocol plane, inbound: the joiner's traffic lands in the
        // survivor's inbox tagged with the re-grown logical id.
        let msg = Message::TakeMyTurn { consecutive_forfeits: 5, transfers_so_far: 6 };
        let mut joiner_tx = joiner_to_2;
        joiner_tx.write_all(&encode_frame(&Frame::Msg(msg.clone())).unwrap()).unwrap();
        match ep2.recv_timeout(Duration::from_secs(5)) {
            RecvOutcome::Msg(got) => assert_eq!(got, msg),
            other => panic!("no delivery from the joiner after extension: {other:?}"),
        }

        // Control plane: the joiner's AdmitAck arrives as logical 1.
        let mut joiner_ctrl = joiner_to_0;
        joiner_ctrl
            .write_all(&encode_frame(&Frame::AdmitAck { machine: 1 }).unwrap())
            .unwrap();
        match ep0.recv_ctrl(Duration::from_secs(5)).unwrap() {
            (1, Frame::AdmitAck { machine: 1 }) => {}
            other => panic!("bad ctrl routing after extension: {other:?}"),
        }

        // And the survivors' original streams still route: wire 2 is
        // logical 2 again.
        ep2.send_ctrl(0, &Frame::RestoreAck { machine: 2 }).unwrap();
        match ep0.recv_ctrl(Duration::from_secs(5)).unwrap() {
            (2, Frame::RestoreAck { machine: 2 }) => {}
            other => panic!("survivor ctrl lost after extension: {other:?}"),
        }

        // A second extend for the now-active joiner must be refused.
        let (out, inbound) = stream_pair();
        assert!(ep0.extend(&[0, 1, 2], 1, out, inbound).is_err(), "joiner 1 is now active");
    }
}

//! Real network transport for the distributed coordinator: a std-only,
//! length-prefixed binary wire codec for [`Message`] (plus the control
//! frames of the multi-process epoch protocol), a [`TcpEndpoint`]
//! implementing [`Bus`] over a full mesh of loopback-or-LAN sockets,
//! deterministic machine-id handshakes with retry/backoff dialing, and
//! the leader/worker pair ([`ClusterLeader`] / [`serve`]) that lets
//! `gtip dynamic --transport tcp` drive refinement rounds across real
//! OS processes.
//!
//! ## Frame layout
//!
//! Every frame is `u32 LE payload length || payload`; the payload is a
//! 1-byte tag followed by fixed-width little-endian fields (`u64`
//! counts, `u32` machine ids, IEEE-754 `f64` loads; vectors are a `u32`
//! length followed by the elements). Tags 1–4 are the Fig. 2 protocol
//! messages — their encoded size is exactly
//! [`Message::wire_bytes`], which both transports feed into
//! [`OverheadStats`], so the measured §4.5 overhead is the true
//! on-the-wire byte count. Tags 16+ are control frames (handshake,
//! epoch setup/begin, per-round stats report, goodbye); control bytes
//! are accounted separately in [`NetStats`] and never touch
//! [`OverheadStats`], keeping the feasibility metric about the game's
//! aggregate-state exchange only.
//!
//! ## Connection lifecycle
//!
//! Machine `i` of K listens on `addrs[i]` and dials every other
//! machine with retry + exponential backoff; each outbound connection
//! opens with a `Hello` frame (`magic || version || machine id ||
//! machine count`), so the acceptor learns deterministically who is on
//! the other end. Each inbound connection gets a reader thread that
//! decodes frames and routes protocol messages to the endpoint's inbox
//! and control frames to its control queue. Shutdown is graceful: the
//! leader broadcasts `Goodbye`, workers exit, sockets close, readers
//! see EOF and stop.
//!
//! ## Epoch barrier
//!
//! One refinement round per `EpochBegin` (which re-syncs graph weights
//! and the warm-start assignment — O(N) control traffic that exists in
//! any measurement-driven deployment and is reported separately from
//! the O(K) game traffic). After a round converges, every worker sends
//! its [`OverheadStats`] delta as `RoundStats`; the leader waits for
//! all K−1 reports before the next epoch, which doubles as the barrier
//! that keeps rounds from interleaving on the wire.
//!
//! ## Failure recovery (wire v3)
//!
//! A worker death no longer unwinds the whole cluster. A timed-out or
//! send-failed round leaves the leader's endpoint intact; the leader
//! then *diagnoses* which peers are dead ([`ClusterLeader::diagnose_dead`]:
//! recorded send failures plus workers that never reported `RoundStats`
//! within a grace period — live workers report their stats even after a
//! timed-out round) and *re-forms* the cluster around the survivors
//! ([`ClusterLeader::recover`]): it compacts its endpoint to the
//! surviving wire ids, broadcasts `Restore` (the survivor list plus
//! renormalized speeds), and waits for a `RestoreAck` from every
//! survivor before the next `EpochBegin` — the ack barrier keeps stale
//! round traffic from interleaving with the restored epoch. Workers
//! renumber themselves by their position in the survivor list (the
//! leader, wire 0, is always logical 0). The simulation itself is
//! restored leader-side from the last epoch-boundary snapshot
//! (`sim::snapshot`, DESIGN.md §10).
//!
//! ## Elastic join (wire v4)
//!
//! Elastic *join* is the same machinery run in reverse. A joining
//! `gtip serve --join` re-binds its original address slot, dials the
//! leader, and sends `Join { machine, speed }`; the leader queues the
//! request and admits it at the **next epoch boundary** — never
//! mid-epoch, because the boundary is where a consistent checkpoint
//! exists. Admission ([`ClusterLeader::admit`]) extends the mesh the
//! way `Restore` shrinks it: the leader dials the joiner back, calls
//! [`TcpEndpoint::extend`] (the inverse of [`TcpEndpoint::compact`] —
//! the joiner re-occupies its immutable wire id, survivors renumber by
//! position in the grown member list), broadcasts `Admit` (members +
//! renormalized speeds), ships the newcomer a full `Setup` plus the
//! epoch-boundary snapshot as a `Catchup` payload, and blocks on an
//! `AdmitAck` from every member. Survivors dial the joiner and accept
//! its return dial before acking; a member that cannot reach the
//! joiner simply withholds its ack, the barrier times out, and the
//! leader rolls the mesh back to the old membership with a `Restore`
//! barrier — the fleet stays at K and the run continues. The
//! refinement game then migrates LPs toward the empty newcomer on the
//! next epoch (Thm 4.1 descends from any feasible start; DESIGN.md
//! §9/§10).
//!
//! Known limitation: diagnosis is evidence-based (send errors + missing
//! stats reports), so a worker that is alive but silent past the grace
//! period is treated as dead and evicted; it exits with a protocol
//! error when its epoch wait (derived from the configured receive
//! timeout) expires. The run still completes on the
//! remaining machines, and the evicted worker can re-enter through the
//! join path above.

//!
//! [`Message`]: crate::coordinator::protocol::Message
//! [`Message::wire_bytes`]: crate::coordinator::protocol::Message::wire_bytes
//! [`Bus`]: crate::coordinator::bus::Bus
//! [`OverheadStats`]: crate::coordinator::protocol::OverheadStats

pub mod codec;
pub mod handshake;
pub mod leader;
pub mod mesh;
pub mod session;
pub mod worker;

// Layer 1: the wire codec — frames and the wire error type.
pub use codec::{decode_payload, encode_frame, read_frame, write_frame};
pub use codec::{EpochFrame, Frame, SetupFrame, WireError};
pub use codec::{MAX_FRAME_BYTES, WIRE_MAGIC, WIRE_VERSION};

// Layer 2: the single-socket session primitive and the shared dial loop.
pub use session::{dial_retry, FramedConn};

// Layer 3: the mesh endpoint and its loopback harnesses.
pub use mesh::{build_tcp_bus_local, connect_mesh, run_distributed_tcp_local};
pub use mesh::{run_distributed_hierarchical_tcp_local, NetStats, TcpEndpoint};

// Layer 4: the cluster roles — leader orchestration and the worker loops.
pub use leader::{ClusterLeader, JoinRequest};
pub use worker::{serve, serve_join, ServeSummary};

use std::collections::BTreeMap;

/// Parse a `host:port,host:port,...` peers list (shared by the
/// `serve` and `dynamic --transport tcp` CLI paths).
pub fn parse_peers(spec: &str) -> Result<Vec<String>, WireError> {
    let peers: Vec<String> =
        spec.split(',').map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect();
    if peers.len() < 2 {
        return Err(WireError::Protocol(format!(
            "--peers needs at least 2 comma-separated host:port entries, got {spec:?}"
        )));
    }
    let mut seen = BTreeMap::new();
    for (i, p) in peers.iter().enumerate() {
        if !p.contains(':') {
            return Err(WireError::Protocol(format!("peer {p:?} is not host:port")));
        }
        if let Some(first) = seen.insert(p.clone(), i) {
            return Err(WireError::Protocol(format!(
                "peer {p:?} listed twice (positions {first} and {i})"
            )));
        }
    }
    Ok(peers)
}

#[cfg(test)]
mod tests;

//! Layer 2 of the coordinator's network stack (DESIGN.md §13): one
//! socket's worth of session machinery, plus the timing primitives
//! every higher layer shares.
//!
//! [`FramedConn`] owns a single connected socket and gives it framed
//! writes (serialized by an internal lock, with the *first* failure
//! recorded as death-diagnosis evidence) and deadline-bounded framed
//! reads. The mesh keeps one per outbound peer; a future resident
//! service front-end (`serve-api`, ROADMAP) can speak the wire through
//! this type alone without dragging in the mesh or the cluster leader.
//!
//! [`dial_retry`] is the one retry/backoff loop behind initial mesh
//! formation, admission dial-backs, and `serve --join` slot binding —
//! its deadline semantics ("keep trying until the deadline itself has
//! passed") are tested here once instead of re-proved at three call
//! sites.

use std::io::Write;
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::codec::{encode_frame, read_frame, Frame, WireError};

/// Initial dial backoff; doubles up to [`DIAL_BACKOFF_MAX`].
pub(super) const DIAL_BACKOFF_START: Duration = Duration::from_millis(25);
pub(super) const DIAL_BACKOFF_MAX: Duration = Duration::from_millis(800);
/// Poll interval of the bounded accept loop.
pub(super) const ACCEPT_POLL: Duration = Duration::from_millis(20);

/// Floor on the derived epoch wait: even with a very aggressive
/// receive timeout a healthy leader needs real time to simulate an
/// epoch window, so a worker never gives up faster than this.
const EPOCH_WAIT_FLOOR: Duration = Duration::from_secs(5);

/// How long a worker waits for the next `EpochBegin`. The leader
/// simulates a whole epoch in between, so this is generous — ten
/// receive timeouts — but it *scales with the configured timeout*
/// instead of the old hard-coded 600 s, which left a worker whose
/// leader had died hanging for ten minutes regardless of
/// `--recv-timeout-ms`.
pub(super) fn epoch_wait(recv_timeout: Duration) -> Duration {
    recv_timeout.saturating_mul(10).max(EPOCH_WAIT_FLOOR)
}

/// Recover the guard from a possibly-poisoned mutex. The shared state
/// behind these locks (accounting counters, an outbound socket) stays
/// internally consistent even if a holder panicked mid-update, so one
/// panicking reader/actor thread must degrade to a clean [`WireError`]
/// elsewhere — not cascade `expect("poisoned")` aborts through every
/// thread that touches the same stats handle.
pub(super) fn lock_unpoisoned<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Run `attempt` with retry + exponential backoff until it succeeds or
/// `deadline` has passed, returning the last error. This is the single
/// retry loop behind mesh dialing, admission dial-backs, and join-slot
/// binding; the deadline semantics matter: the loop keeps trying until
/// the deadline *itself* has passed (the old `now + backoff >= deadline`
/// check gave up one whole backoff early, wasting the final window),
/// and each sleep is clamped to the time remaining.
pub fn dial_retry<T>(
    deadline: Instant,
    start: Duration,
    max: Duration,
    mut attempt: impl FnMut() -> std::io::Result<T>,
) -> std::io::Result<T> {
    let mut backoff = start;
    loop {
        match attempt() {
            Ok(value) => return Ok(value),
            Err(e) => {
                let now = Instant::now();
                if now >= deadline {
                    return Err(e);
                }
                std::thread::sleep(backoff.min(deadline - now));
                backoff = backoff.saturating_mul(2).min(max);
            }
        }
    }
}

/// Dial one peer with retry + backoff until `deadline`.
pub(super) fn dial_peer(addr: &str, deadline: Instant) -> Result<TcpStream, WireError> {
    let attempt = || TcpStream::connect(addr);
    let stream = dial_retry(deadline, DIAL_BACKOFF_START, DIAL_BACKOFF_MAX, attempt)
        .map_err(|e| WireError::Io(format!("dialing {addr}: {e}")))?;
    stream.set_nodelay(true)?;
    Ok(stream)
}

/// A framed connection owning one connected socket — the primitive the
/// mesh sends every frame through, and the seam a future front-end
/// builds on. Writes are length-prefixed by the codec and serialized
/// by an internal lock so reader threads and the main thread can share
/// the socket; the first write failure is recorded on the connection
/// (evidence for the leader's death diagnosis) as well as returned.
pub struct FramedConn {
    stream: Mutex<TcpStream>,
    failure: Mutex<Option<String>>,
}

impl FramedConn {
    /// Wrap one connected socket.
    pub fn new(stream: TcpStream) -> FramedConn {
        FramedConn { stream: Mutex::new(stream), failure: Mutex::new(None) }
    }

    /// Encode and send one frame; returns the wire byte count.
    pub fn send(&self, frame: &Frame) -> Result<usize, WireError> {
        let bytes = encode_frame(frame)?;
        self.send_bytes(&bytes)?;
        Ok(bytes.len())
    }

    /// Send pre-encoded frame bytes (the mesh encodes once per message
    /// so its accounting sees the exact wire size). The first failure
    /// is recorded for [`FramedConn::take_send_failure`] and returned
    /// raw so callers keep their own error wording.
    pub(super) fn send_bytes(&self, bytes: &[u8]) -> std::io::Result<()> {
        let result = lock_unpoisoned(&self.stream).write_all(bytes);
        if let Err(e) = &result {
            let mut failure = lock_unpoisoned(&self.failure);
            if failure.is_none() {
                *failure = Some(e.to_string());
            }
        }
        result
    }

    /// Receive one frame, waiting at most `timeout` for it to arrive.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<Frame, WireError> {
        let mut stream = lock_unpoisoned(&self.stream);
        stream.set_read_timeout(Some(timeout.max(Duration::from_millis(1))))?;
        let frame = read_frame(&mut *stream);
        stream.set_read_timeout(None)?;
        frame
    }

    /// The first send failure recorded on this connection, if any.
    /// Taking it drains the record.
    pub fn take_send_failure(&self) -> Option<String> {
        lock_unpoisoned(&self.failure).take()
    }

    /// Unwrap the socket (e.g. to hand it to a reader thread).
    pub fn into_stream(self) -> TcpStream {
        self.stream.into_inner().unwrap_or_else(|e| e.into_inner())
    }
}

#[cfg(test)]
mod tests {
    use std::net::{Shutdown, TcpListener};

    use super::*;

    /// The dial loop must keep retrying until the deadline itself has
    /// passed. The old `now + backoff >= deadline` check surrendered
    /// one whole backoff early: against a refusing port with a 300 ms
    /// deadline it gave up at ~175 ms (25+50+100 slept, next backoff
    /// 200 crossing the line). The fix retries into the final window.
    #[test]
    fn dial_retries_until_the_deadline_itself() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener); // now the port refuses connections
        let start = Instant::now();
        let deadline = start + Duration::from_millis(300);
        assert!(dial_peer(&addr, deadline).is_err());
        assert!(
            start.elapsed() >= Duration::from_millis(250),
            "dial gave up a backoff early: {:?}",
            start.elapsed()
        );
    }

    /// Same property for the shared loop itself, independent of any
    /// socket: an always-failing attempt is retried into the final
    /// window, and the deadline bounds the total wait.
    #[test]
    fn dial_retry_keeps_trying_into_the_final_window() {
        let start = Instant::now();
        let deadline = start + Duration::from_millis(300);
        let mut attempts = 0u32;
        let result = dial_retry(deadline, DIAL_BACKOFF_START, DIAL_BACKOFF_MAX, || {
            attempts += 1;
            Err::<(), _>(std::io::Error::new(std::io::ErrorKind::ConnectionRefused, "refused"))
        });
        assert!(result.is_err());
        assert!(
            start.elapsed() >= Duration::from_millis(250),
            "gave up a backoff early after {attempts} attempts: {:?}",
            start.elapsed()
        );
        assert!(attempts >= 4, "stopped attempting before the deadline: {attempts}");
        assert!(start.elapsed() < Duration::from_secs(3), "overshot the deadline");
    }

    /// The first success wins immediately — no extra sleeps, and the
    /// value comes back intact.
    #[test]
    fn dial_retry_returns_the_first_success() {
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut attempts = 0u32;
        let value = dial_retry(deadline, Duration::from_millis(1), Duration::from_millis(2), || {
            attempts += 1;
            if attempts < 3 {
                Err(std::io::Error::new(std::io::ErrorKind::ConnectionRefused, "not yet"))
            } else {
                Ok(attempts)
            }
        })
        .unwrap();
        assert_eq!((value, attempts), (3, 3));
    }

    fn stream_pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let a = TcpStream::connect(addr).unwrap();
        let (b, _) = listener.accept().unwrap();
        (a, b)
    }

    #[test]
    fn framed_conn_round_trips_frames() {
        let (a, b) = stream_pair();
        let (a, b) = (FramedConn::new(a), FramedConn::new(b));
        let sent = a.send(&Frame::RestoreAck { machine: 7 }).unwrap();
        assert!(sent > 4, "frame shorter than its own length prefix: {sent}");
        let got = b.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(got, Frame::RestoreAck { machine: 7 });
        // An empty window maps to a clean timeout error, not a hang.
        assert!(b.recv_timeout(Duration::from_millis(20)).is_err());
        assert!(a.take_send_failure().is_none());
    }

    #[test]
    fn framed_conn_records_the_first_send_failure() {
        let (a, _b) = stream_pair();
        a.shutdown(Shutdown::Write).unwrap();
        let conn = FramedConn::new(a);
        assert!(conn.send(&Frame::Goodbye).is_err());
        let why = conn.take_send_failure().expect("first failure recorded");
        assert!(!why.is_empty());
        assert!(conn.take_send_failure().is_none(), "take drains the record");
    }
}

//! Transport parity: the refinement game over real sockets must
//! reproduce the in-process runs bit-for-bit (assignment, transfers,
//! wire accounting), flat and hierarchical, charged and not. These
//! exercise the whole stack end to end — codec, session, mesh,
//! leader, worker — through the public `*_tcp_local` entry points.

use std::sync::Arc;

use crate::coordinator::distributed::{
    run_distributed, run_distributed_hierarchical, DistributedOptions,
};
use crate::game::hierarchy::RackLayout;
use crate::graph::generators::{table1_graph, WeightModel};
use crate::partition::{MachineConfig, Partition};
use crate::util::rng::Pcg32;

use super::*;

#[test]
fn parse_peers_validates() {
    let ok = parse_peers("127.0.0.1:7000, 127.0.0.1:7001,127.0.0.1:7002").unwrap();
    assert_eq!(ok.len(), 3);
    assert!(parse_peers("127.0.0.1:7000").is_err());
    assert!(parse_peers("localhost,also-no-port").is_err());
    assert!(parse_peers("h:1,h:1").is_err());
}

#[test]
fn tcp_local_refinement_matches_in_process_exactly() {
    let mut rng = Pcg32::new(8);
    let g = Arc::new(table1_graph(50, 3, 6, WeightModel::default(), &mut rng));
    let machines = MachineConfig::from_speeds(&[0.2, 0.3, 0.5]);
    let assignment: Vec<usize> = (0..50).map(|_| rng.index(3)).collect();
    let part = Partition::from_assignment(&g, 3, assignment);
    let opts = DistributedOptions::default();

    let inproc = run_distributed(Arc::clone(&g), &machines, part.clone(), &opts);
    let tcp = run_distributed_tcp_local(Arc::clone(&g), &machines, part, &opts).unwrap();
    assert_eq!(tcp.partition.assignment(), inproc.partition.assignment());
    assert_eq!(tcp.transfers, inproc.transfers);
    assert_eq!(tcp.overhead, inproc.overhead, "wire accounting must be transport-invariant");
    assert_eq!(tcp.converged, inproc.converged);
}

/// The migration charge is transport-invariant too: a nonzero
/// charge over real sockets reproduces the in-process augmented
/// game bit-for-bit (assignment, transfers, wire accounting).
#[test]
fn charged_tcp_matches_in_process_exactly() {
    let mut rng = Pcg32::new(12);
    let g = Arc::new(table1_graph(50, 3, 6, WeightModel::default(), &mut rng));
    let machines = MachineConfig::from_speeds(&[0.2, 0.3, 0.5]);
    let assignment: Vec<usize> = (0..50).map(|_| rng.index(3)).collect();
    let part = Partition::from_assignment(&g, 3, assignment);
    let opts = DistributedOptions { migration_charge: 4.0, ..Default::default() };

    let inproc = run_distributed(Arc::clone(&g), &machines, part.clone(), &opts);
    let tcp = run_distributed_tcp_local(Arc::clone(&g), &machines, part, &opts).unwrap();
    assert_eq!(tcp.partition.assignment(), inproc.partition.assignment());
    assert_eq!(tcp.transfers, inproc.transfers);
    assert_eq!(tcp.overhead, inproc.overhead);
    assert!(tcp.converged && inproc.converged);
}

/// The two-level hierarchy is transport-invariant too: the TCP
/// wiring of the phased epoch (RackBus over real sockets, scoped
/// inner rings) reproduces the in-process hierarchical run
/// bit-for-bit — assignment, transfers, wire accounting on both
/// levels, convergence.
#[test]
fn hierarchical_tcp_matches_in_process_exactly() {
    let mut rng = Pcg32::new(8);
    let g = Arc::new(table1_graph(50, 3, 6, WeightModel::default(), &mut rng));
    let machines = MachineConfig::from_speeds(&[0.2, 0.3, 0.3, 0.2]);
    let assignment: Vec<usize> = (0..50).map(|_| rng.index(4)).collect();
    let part = Partition::from_assignment(&g, 4, assignment);
    let layout = RackLayout::new(vec![0, 0, 1, 1]).unwrap();
    let opts = DistributedOptions::default();

    let inproc =
        run_distributed_hierarchical(Arc::clone(&g), &machines, part.clone(), &layout, &opts);
    let tcp =
        run_distributed_hierarchical_tcp_local(Arc::clone(&g), &machines, part, &layout, &opts)
            .unwrap();
    assert_eq!(tcp.partition.assignment(), inproc.partition.assignment());
    assert_eq!(tcp.transfers, inproc.transfers);
    assert_eq!(tcp.overhead, inproc.overhead, "wire accounting must be transport-invariant");
    assert_eq!(tcp.converged, inproc.converged);
}

/// Singleton racks over TCP degenerate to the flat TCP game
/// bit-for-bit on the assignment (the hierarchy's identity
/// baseline, DESIGN.md §12, carried across the wire).
#[test]
fn singleton_racks_hierarchical_tcp_matches_flat_tcp() {
    let mut rng = Pcg32::new(12);
    let g = Arc::new(table1_graph(50, 3, 6, WeightModel::default(), &mut rng));
    let machines = MachineConfig::from_speeds(&[0.2, 0.3, 0.5]);
    let assignment: Vec<usize> = (0..50).map(|_| rng.index(3)).collect();
    let part = Partition::from_assignment(&g, 3, assignment);
    let layout = RackLayout::singletons(3);
    let opts = DistributedOptions::default();

    let flat = run_distributed_tcp_local(Arc::clone(&g), &machines, part.clone(), &opts).unwrap();
    let hier =
        run_distributed_hierarchical_tcp_local(Arc::clone(&g), &machines, part, &layout, &opts)
            .unwrap();
    assert_eq!(hier.partition.assignment(), flat.partition.assignment());
    assert_eq!(hier.transfers, flat.transfers);
    assert_eq!(hier.converged, flat.converged);
}

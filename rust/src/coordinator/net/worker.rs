//! Layer 4b of the coordinator's network stack (DESIGN.md §13): the
//! worker side of the cluster. [`serve`] joins the original mesh and
//! [`serve_join`] dials into a live cluster for admission; both fall
//! into the same steady-state loop — one refinement round per
//! `EpochBegin` (flat or phased hierarchical), membership shrinking
//! via `Restore` and growing via `Admit`, until `Goodbye`. The
//! `GTIP_SERVE_DIE` fault injection for the recovery tests lives here.
//! Wait failures are annotated with the peer wire id and the frame
//! being awaited before they surface to the CLI.

use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::bus::Bus;
use crate::coordinator::distributed::{machine_loop, machine_loop_scoped, RackBus};
use crate::coordinator::machine::MachineActor;
use crate::coordinator::protocol::{Message, OverheadStats};
use crate::game::cost::Framework;
use crate::game::hierarchy::RackLayout;
use crate::graph::{Graph, GraphBuilder};
use crate::partition::{MachineConfig, MachineId, Partition};

use super::codec::{read_frame, wire_u32, write_frame, Frame, SetupFrame, WireError, WIRE_VERSION};
use super::handshake::{accept_wire_peer, handshake_inbound, JOIN_HANDSHAKE_TIMEOUT};
use super::mesh::{connect_mesh, spawn_reader, NetStats, SendFailures, TcpEndpoint};
use super::session::{dial_peer, dial_retry, epoch_wait, FramedConn, ACCEPT_POLL};

/// `recv_ctrl` with context: a worker's wait failures name the leader
/// (wire id 0) and the frame the worker is blocked on, so the error
/// that reaches the CLI reads "peer 0, awaiting EpochBegin: …".
fn recv_from_leader(
    ep: &TcpEndpoint,
    timeout: Duration,
    state: &str,
) -> Result<(MachineId, Frame), WireError> {
    ep.recv_ctrl(timeout).map_err(|e| e.while_awaiting(state, 0))
}

/// What a worker did over its lifetime (printed by `gtip serve`).
#[derive(Debug, Clone)]
pub struct ServeSummary {
    pub machine_id: MachineId,
    pub epochs: u64,
    pub overhead: OverheadStats,
    pub control: NetStats,
}

/// Run machine `machine_id`'s side of the multi-process cluster: join
/// the mesh, receive the fixture, then play one refinement round per
/// `EpochBegin` until `Goodbye`. This is the body of `gtip serve`.
pub fn serve(
    machine_id: MachineId,
    addrs: &[String],
    connect_timeout: Duration,
) -> Result<ServeSummary, WireError> {
    if machine_id == 0 {
        return Err(WireError::Protocol(
            "machine 0 is the driver; run `gtip dynamic --transport tcp` instead of serve".into(),
        ));
    }
    if machine_id >= addrs.len() {
        return Err(WireError::Protocol(format!(
            "--machine-id {machine_id} out of range for {} peers",
            addrs.len()
        )));
    }
    let stats = Arc::new(Mutex::new(OverheadStats::default()));
    let ep = connect_mesh(machine_id, addrs, connect_timeout, Arc::clone(&stats))?;
    // Fault injection for the recovery tests: "setup" dies after the
    // fixture is validated, "epoch:N" dies on receiving EpochBegin N,
    // "stats" dies just before reporting RoundStats, "admit" dies on
    // receiving Admit (joiner side). Exit code 86 marks an intentional
    // death (the harness asserts on it).
    let die = std::env::var("GTIP_SERVE_DIE").unwrap_or_default();

    // Fixture first. The wait derives from the dial window — the
    // leader sets up right after the mesh forms; once the fixture is
    // in hand the loop waits on the fixture's own receive timeout.
    let setup = match recv_from_leader(&ep, epoch_wait(connect_timeout), "awaiting Setup")? {
        (0, Frame::Setup(s)) => s,
        (0, Frame::Goodbye) => {
            return Ok(ServeSummary {
                machine_id,
                epochs: 0,
                overhead: ep.stats_snapshot(),
                control: ep.net_snapshot(),
            })
        }
        (peer, frame) => {
            return Err(WireError::Protocol(format!(
                "expected Setup from the leader, got {frame:?} from machine {peer}"
            )))
        }
    };
    let fixture = WorkerFixture::from_setup(&setup, addrs.len())?;
    if die == "setup" {
        eprintln!("gtip serve: GTIP_SERVE_DIE=setup — dying after fixture validation");
        std::process::exit(86);
    }
    run_worker_loop(ep, addrs, fixture, &die)
}

/// Everything a worker keeps between epochs, validated once from the
/// `Setup` frame. Shared by the original-mesh path (`serve`) and the
/// admission path (`serve_join`).
struct WorkerFixture {
    machines: MachineConfig,
    graph: Graph,
    /// Edge order of the built graph — per-epoch weights arrive in
    /// the leader's edge order, which matches because both graphs
    /// share the same topology.
    edge_order: Vec<(usize, usize)>,
    mu: f64,
    framework: Framework,
    migration_charge: f64,
    epsilon: f64,
    max_transfers: usize,
    recv_timeout: Duration,
    /// Two-level rack layout (wire v5); `None` on a flat cluster.
    /// Indexed by *logical* id, so membership changes (`Restore`,
    /// `Admit`) must update it in lockstep with the endpoint.
    layout: Option<RackLayout>,
}

impl WorkerFixture {
    /// Validate before handing anything to constructors that assert —
    /// a buggy or skewed leader must produce a clean protocol error,
    /// not abort the worker process.
    fn from_setup(setup: &SetupFrame, k: usize) -> Result<WorkerFixture, WireError> {
        if setup.speeds.len() != k {
            return Err(WireError::Protocol(format!(
                "fixture has {} machines but the mesh has {k}",
                setup.speeds.len()
            )));
        }
        let speed_sum: f64 = setup.speeds.iter().sum();
        if setup.speeds.iter().any(|&s| !(s > 0.0)) || (speed_sum - 1.0).abs() > 1e-6 {
            return Err(WireError::Protocol(format!(
                "fixture speeds are not normalized positive weights (sum {speed_sum})"
            )));
        }
        let n = setup.node_weights.len();
        if let Some(&(u, v, _)) = setup
            .edges
            .iter()
            .find(|&&(u, v, _)| u as usize >= n || v as usize >= n || u == v)
        {
            return Err(WireError::Protocol(format!(
                "fixture edge ({u}, {v}) is out of range for {n} nodes"
            )));
        }
        if !weights_valid(&setup.node_weights)
            || !weights_valid_iter(setup.edges.iter().map(|&(_, _, w)| w))
        {
            return Err(WireError::Protocol(
                "fixture weights must be finite and non-negative".into(),
            ));
        }
        if !(setup.migration_charge.is_finite() && setup.migration_charge >= 0.0) {
            return Err(WireError::Protocol(format!(
                "fixture migration charge {} must be finite and non-negative",
                setup.migration_charge
            )));
        }
        // Adopt the leader's normalized speeds verbatim — renormalizing
        // here could drift each weight by an ulp and diverge replicas.
        let machines = MachineConfig::from_normalized(setup.speeds.clone());
        let mut builder = GraphBuilder::with_nodes(n);
        for &(u, v, w) in &setup.edges {
            builder.add_edge(u as usize, v as usize, w);
        }
        for (i, &w) in setup.node_weights.iter().enumerate() {
            builder.set_node_weight(i, w);
        }
        let graph = builder.build();
        let edge_order: Vec<(usize, usize)> = graph.edges().map(|(u, v, _)| (u, v)).collect();
        if edge_order.len() != setup.edges.len() {
            return Err(WireError::Protocol("fixture edge list had duplicates".into()));
        }
        Ok(WorkerFixture {
            machines,
            graph,
            edge_order,
            mu: setup.mu,
            framework: setup.framework,
            migration_charge: setup.migration_charge,
            epsilon: setup.epsilon,
            max_transfers: setup.max_transfers as usize,
            recv_timeout: Duration::from_millis(setup.recv_timeout_ms.max(1)),
            layout: if setup.racks.is_empty() {
                None
            } else {
                if setup.racks.len() != k {
                    return Err(WireError::Protocol(format!(
                        "fixture has {} rack entries but the mesh has {k} machines",
                        setup.racks.len()
                    )));
                }
                let rack_of: Vec<usize> = setup.racks.iter().map(|&r| r as usize).collect();
                Some(RackLayout::new(rack_of).map_err(WireError::Protocol)?)
            },
        })
    }
}

/// The worker's steady state: one refinement round per `EpochBegin`,
/// membership shrinking via `Restore` and growing via `Admit`, until
/// `Goodbye`. The endpoint's own logical id / machine count track the
/// membership changes (compact and extend renumber in place).
fn run_worker_loop(
    mut ep: TcpEndpoint,
    addrs: &[String],
    mut fixture: WorkerFixture,
    die: &str,
) -> Result<ServeSummary, WireError> {
    let machine_id = ep.wire_id();
    let n = fixture.graph.node_weights().len();
    let mut epochs = 0u64;
    loop {
        match recv_from_leader(&ep, epoch_wait(fixture.recv_timeout), "awaiting EpochBegin")? {
            (0, Frame::EpochBegin(e)) => {
                if die == format!("epoch:{}", e.epoch) {
                    eprintln!(
                        "gtip serve: GTIP_SERVE_DIE={die} — dying on EpochBegin {}",
                        e.epoch
                    );
                    std::process::exit(86);
                }
                let k = ep.machine_count();
                if e.node_weights.len() != n || e.edge_weights.len() != fixture.edge_order.len()
                {
                    return Err(WireError::Protocol(format!(
                        "epoch {} weight vectors do not match the fixture shape",
                        e.epoch
                    )));
                }
                if e.assignment.len() != n {
                    return Err(WireError::Protocol(format!(
                        "epoch {} assignment length {} != {n}",
                        e.epoch,
                        e.assignment.len()
                    )));
                }
                if !weights_valid(&e.node_weights) || !weights_valid(&e.edge_weights) {
                    return Err(WireError::Protocol(format!(
                        "epoch {} weights must be finite and non-negative",
                        e.epoch
                    )));
                }
                fixture.graph.set_node_weights(&e.node_weights);
                for (&(u, v), &w) in fixture.edge_order.iter().zip(&e.edge_weights) {
                    fixture.graph.set_edge_weight(u, v, w);
                }
                let assignment: Vec<MachineId> =
                    e.assignment.iter().map(|&a| a as MachineId).collect();
                if let Some(&bad) = assignment.iter().find(|&&a| a >= k) {
                    return Err(WireError::Protocol(format!(
                        "epoch {} assignment names machine {bad} but K={k}",
                        e.epoch
                    )));
                }
                let part = Partition::from_assignment(&fixture.graph, k, assignment);
                let before = ep.stats_snapshot();
                let outcome = match (e.phase, &fixture.layout) {
                    // Flat round: the original single-level ring.
                    (0, _) => {
                        let actor = MachineActor::new(
                            ep.id(),
                            Arc::new(fixture.graph.clone()),
                            fixture.machines.clone(),
                            &part,
                            fixture.mu,
                            fixture.framework,
                            fixture.migration_charge,
                        );
                        Some(machine_loop(
                            actor,
                            &ep,
                            fixture.epsilon,
                            fixture.max_transfers,
                            fixture.recv_timeout,
                        ))
                    }
                    // Outer game: rack leaders play the quotient over a
                    // RackBus; everyone else spectates and still
                    // reports a (zero-delta) RoundStats below.
                    (1, Some(layout)) => {
                        if layout.is_leader(ep.id()) {
                            let rack = layout.rack_of(ep.id());
                            let qpart = Partition::from_assignment(
                                &fixture.graph,
                                layout.rack_count(),
                                layout.quotient_assignment(part.assignment()),
                            );
                            let actor = MachineActor::new(
                                rack,
                                Arc::new(fixture.graph.clone()),
                                layout.quotient_config(&fixture.machines),
                                &qpart,
                                fixture.mu,
                                fixture.framework,
                                fixture.migration_charge,
                            );
                            let bus = RackBus::new(&ep, rack, layout.leaders());
                            Some(machine_loop(
                                actor,
                                &bus,
                                fixture.epsilon,
                                fixture.max_transfers,
                                fixture.recv_timeout,
                            ))
                        } else {
                            None
                        }
                    }
                    // Inner game: the scoped ring of this machine's
                    // rack. Each rack's leader kicks its own ring (the
                    // cluster leader kicks its rack on its side).
                    (2, Some(layout)) => {
                        let scope = layout.members(layout.rack_of(ep.id())).to_vec();
                        let actor = MachineActor::new(
                            ep.id(),
                            Arc::new(fixture.graph.clone()),
                            fixture.machines.clone(),
                            &part,
                            fixture.mu,
                            fixture.framework,
                            fixture.migration_charge,
                        )
                        .with_scope(scope.clone());
                        if layout.is_leader(ep.id()) {
                            ep.send(
                                ep.id(),
                                Message::TakeMyTurn {
                                    consecutive_forfeits: 0,
                                    transfers_so_far: 0,
                                },
                            );
                        }
                        Some(machine_loop_scoped(
                            actor,
                            &ep,
                            &scope,
                            fixture.epsilon,
                            fixture.max_transfers,
                            fixture.recv_timeout,
                        ))
                    }
                    (1 | 2, None) => {
                        return Err(WireError::Protocol(format!(
                            "epoch {} opened phase {} but the fixture is flat",
                            e.epoch, e.phase
                        )))
                    }
                    (p, _) => {
                        return Err(WireError::Protocol(format!(
                            "epoch {} opened unknown phase {p}",
                            e.epoch
                        )))
                    }
                };
                let timed_out = outcome.as_ref().is_some_and(|o| o.timed_out);
                if let Some(o) = outcome.as_ref().filter(|o| o.timed_out) {
                    // A peer died mid-round. Do NOT unwind: report the
                    // round's stats anyway — that report is this
                    // worker's proof of life for the leader's death
                    // diagnosis — then wait for the leader's Restore.
                    eprintln!(
                        "gtip serve: epoch {} round lost a peer{}; awaiting restore",
                        e.epoch,
                        match o.dead_peer {
                            Some(m) => format!(" (machine {m})"),
                            None => String::new(),
                        }
                    );
                }
                if die == "stats" {
                    eprintln!("gtip serve: GTIP_SERVE_DIE=stats — dying before RoundStats");
                    std::process::exit(86);
                }
                let delta = ep.stats_snapshot().delta_since(&before);
                ep.send_ctrl(0, &Frame::RoundStats(delta))?;
                // A rack leader (other than the cluster leader's own
                // rack) ships its phase-2 ring outcome home: phase 2
                // never moves a node across racks, so only the owning
                // rack knows its nodes' final machines.
                if e.phase == 2 && !timed_out {
                    if let (Some(layout), Some(o)) = (&fixture.layout, &outcome) {
                        let rack = layout.rack_of(ep.id());
                        if layout.is_leader(ep.id()) && !layout.members(rack).contains(&0) {
                            let pairs = part
                                .assignment()
                                .iter()
                                .enumerate()
                                .filter(|&(_, &m)| layout.rack_of(m) == rack)
                                .map(|(i, _)| Ok((wire_u32(i)?, wire_u32(o.assignment[i])?)))
                                .collect::<Result<_, WireError>>()?;
                            ep.send_ctrl(
                                0,
                                &Frame::RackResult {
                                    rack: wire_u32(rack)?,
                                    transfers: o.transfers_applied,
                                    converged: o.converged,
                                    assignment: pairs,
                                },
                            )?;
                        }
                    }
                }
                // A hierarchical epoch spans phases 1 and 2; count it
                // once, when its second half completes.
                if !timed_out && e.phase != 1 {
                    epochs += 1;
                }
            }
            (0, Frame::Restore { survivors, speeds }) => {
                let wish: Vec<MachineId> =
                    survivors.iter().map(|&w| w as MachineId).collect();
                if speeds.len() != wish.len() {
                    return Err(WireError::Protocol(format!(
                        "restore has {} survivors but {} speeds",
                        wish.len(),
                        speeds.len()
                    )));
                }
                let speed_sum: f64 = speeds.iter().sum();
                if speeds.iter().any(|&s| !(s > 0.0)) || (speed_sum - 1.0).abs() > 1e-6 {
                    return Err(WireError::Protocol(format!(
                        "restore speeds are not normalized positive weights (sum {speed_sum})"
                    )));
                }
                if !wish.contains(&ep.wire_id()) {
                    // The leader evicted us — presumed dead (e.g. a
                    // transient stall past the grace window). Bow out
                    // cleanly; the survivors carry the run.
                    eprintln!(
                        "gtip serve: evicted by restore (wire id {}); exiting",
                        ep.wire_id()
                    );
                    break;
                }
                // Dead machines by *current* logical id — computed
                // before the compaction renumbers everything.
                let dead: Vec<MachineId> =
                    (0..ep.machine_count()).filter(|&m| !wish.contains(&ep.wire_of(m))).collect();
                ep.compact(&wish)?;
                ep.drain_inbox();
                fixture.machines = MachineConfig::from_normalized(speeds.clone());
                if let Some(l) = fixture.layout.take() {
                    fixture.layout =
                        Some(l.without_machines(&dead).map_err(WireError::Protocol)?);
                }
                ep.send_ctrl(0, &Frame::RestoreAck { machine: wire_u32(ep.wire_id())? })?;
                eprintln!(
                    "gtip serve: restored as machine {}/{} (wire id {})",
                    ep.id(),
                    ep.machine_count(),
                    ep.wire_id()
                );
            }
            (0, Frame::Admit { members, joiner, speeds, rack }) => {
                let members: Vec<MachineId> =
                    members.iter().map(|&w| w as MachineId).collect();
                let joiner = joiner as MachineId;
                if speeds.len() != members.len() {
                    return Err(WireError::Protocol(format!(
                        "admit has {} members but {} speeds",
                        members.len(),
                        speeds.len()
                    )));
                }
                let speed_sum: f64 = speeds.iter().sum();
                if speeds.iter().any(|&s| !(s > 0.0)) || (speed_sum - 1.0).abs() > 1e-6 {
                    return Err(WireError::Protocol(format!(
                        "admit speeds are not normalized positive weights (sum {speed_sum})"
                    )));
                }
                // Dial the joiner, accept its return dial, extend. A
                // failure here is NOT fatal: the joiner may have died
                // mid-admission. Stay on the old mesh and wait — the
                // leader's ack barrier will time out and broadcast a
                // rollback Restore, which the arm above handles (an
                // identity compact if we never extended).
                let deadline = Instant::now() + fixture.recv_timeout;
                match survivor_admit(&mut ep, addrs, &members, joiner, deadline) {
                    Ok(()) => {
                        ep.drain_inbox();
                        fixture.machines = MachineConfig::from_normalized(speeds.clone());
                        if let Some(l) = fixture.layout.take() {
                            // Mirror the leader's with_inserted: the
                            // joiner's logical id is its member-list
                            // position, its rack rides the frame.
                            let pos =
                                members.iter().position(|&w| w == joiner).ok_or_else(|| {
                                    WireError::Protocol(format!(
                                        "admit member list omits joiner {joiner}"
                                    ))
                                })?;
                            let r = if rack == u32::MAX {
                                l.join_rack()
                            } else {
                                rack as usize
                            };
                            fixture.layout =
                                Some(l.with_inserted(pos, r).map_err(WireError::Protocol)?);
                        }
                        ep.send_ctrl(
                            0,
                            &Frame::AdmitAck { machine: wire_u32(ep.wire_id())? },
                        )?;
                        eprintln!(
                            "gtip serve: admitted wire id {joiner}; now machine {}/{} (wire id {})",
                            ep.id(),
                            ep.machine_count(),
                            ep.wire_id()
                        );
                    }
                    Err(e) => {
                        eprintln!(
                            "gtip serve: admit of wire id {joiner} failed ({e}); awaiting rollback"
                        );
                    }
                }
            }
            (0, Frame::Goodbye) => break,
            (peer, frame) => {
                return Err(WireError::Protocol(format!(
                    "unexpected control frame from machine {peer}: {frame:?}"
                )))
            }
        }
    }
    Ok(ServeSummary {
        machine_id,
        epochs,
        overhead: ep.stats_snapshot(),
        control: ep.net_snapshot(),
    })
}

/// A survivor's half of an admission: dial the joiner, introduce
/// ourselves, accept the joiner's return dial on the retained mesh
/// listener, and extend the endpoint. The deadline is one receive
/// timeout — strictly shorter than the leader's ack-barrier patience,
/// so a dead joiner still leaves time to observe the rollback
/// `Restore` that follows.
fn survivor_admit(
    ep: &mut TcpEndpoint,
    addrs: &[String],
    members: &[MachineId],
    joiner: MachineId,
    deadline: Instant,
) -> Result<(), WireError> {
    if joiner >= addrs.len() {
        return Err(WireError::Protocol(format!(
            "admit names joiner {joiner} but the peer list has {} entries",
            addrs.len()
        )));
    }
    let mut out = dial_peer(&addrs[joiner], deadline)?;
    write_frame(
        &mut out,
        &Frame::Hello {
            version: WIRE_VERSION,
            machine: wire_u32(ep.wire_id())?,
            machines: wire_u32(addrs.len())?,
        },
    )?;
    let inbound = accept_wire_peer(&ep.listener, joiner, addrs.len(), deadline)?;
    ep.extend(members, joiner, out, inbound)
}

/// How long a turned-away joiner pauses before re-dialing the leader.
const JOIN_RETRY_PAUSE: Duration = Duration::from_millis(300);

/// Run a *joining* machine's side of the cluster: bind our listed
/// address, dial the leader with `Hello` + `Join`, wait (up to
/// `admit_window`) for the leader to dial back at an epoch boundary,
/// complete the mesh extension, check the `Setup` + `Catchup` the
/// leader ships, ack, and fall into the normal worker loop. This is
/// the body of `gtip serve --join`.
///
/// A rejection (`Goodbye`, or the leader simply closing the join
/// stream — e.g. the run predates wire v4, or the cluster is still
/// forming) is retried until `connect_timeout` runs out. Once a
/// `Join` has been *accepted into the queue* (neither rejected nor
/// closed) the joiner does NOT re-dial within the admit window:
/// re-dialing would queue a duplicate request whose leader-side
/// stream half is already dead.
pub fn serve_join(
    machine_id: MachineId,
    addrs: &[String],
    speed: f64,
    rack: Option<usize>,
    connect_timeout: Duration,
    admit_window: Duration,
) -> Result<ServeSummary, WireError> {
    if machine_id == 0 {
        return Err(WireError::Protocol(
            "machine 0 is the driver; it cannot join its own cluster".into(),
        ));
    }
    if machine_id >= addrs.len() {
        return Err(WireError::Protocol(format!(
            "--machine-id {machine_id} out of range for {} peers",
            addrs.len()
        )));
    }
    if !(speed.is_finite() && speed > 0.0) {
        return Err(WireError::Protocol(format!("--speed {speed} must be finite and positive")));
    }
    let k_orig = addrs.len();
    let die = std::env::var("GTIP_SERVE_DIE").unwrap_or_default();

    // Bind with retry: the predecessor we replace may hold the port
    // until its process is fully reaped.
    let bind_deadline = Instant::now() + connect_timeout;
    let bind = || TcpListener::bind(addrs[machine_id].as_str());
    let listener = dial_retry(bind_deadline, JOIN_RETRY_PAUSE, JOIN_RETRY_PAUSE, bind)
        .map_err(|e| WireError::Io(format!("binding {}: {e}", addrs[machine_id])))?;
    listener.set_nonblocking(true)?;

    let overall = Instant::now() + connect_timeout;
    // Members' dials that complete before the leader's own — separate
    // connections have no ordering guarantee — are stashed here.
    let mut stash: Vec<(MachineId, TcpStream)> = Vec::new();
    let no_peer_seen = vec![false; k_orig];
    let (leader_out, leader_in) = 'attempt: loop {
        let mut out = dial_peer(&addrs[0], overall)?;
        write_frame(
            &mut out,
            &Frame::Hello {
                version: WIRE_VERSION,
                machine: wire_u32(machine_id)?,
                machines: wire_u32(k_orig)?,
            },
        )?;
        let rack_wire = match rack {
            Some(r) => {
                let w = wire_u32(r)?;
                if w == u32::MAX {
                    return Err(WireError::Protocol(format!("--rack {r} is reserved")));
                }
                w
            }
            None => u32::MAX,
        };
        write_frame(
            &mut out,
            &Frame::Join { machine: wire_u32(machine_id)?, speed, rack: rack_wire },
        )?;
        out.set_nonblocking(true)?;
        eprintln!(
            "gtip serve: join request sent (wire id {machine_id}, speed {speed}); waiting for admission"
        );
        let wait_deadline = Instant::now() + admit_window;
        loop {
            // Rejection check: the leader writes Goodbye (or just
            // closes the stream) to turn us down.
            let mut peeked = [0u8; 1];
            let rejected = match out.peek(&mut peeked) {
                Ok(0) => Some("join stream closed".to_string()),
                Ok(_) => {
                    out.set_nonblocking(false)?;
                    out.set_read_timeout(Some(JOIN_HANDSHAKE_TIMEOUT))?;
                    match read_frame(&mut out) {
                        Ok(Frame::Goodbye) => Some("join rejected by the leader".to_string()),
                        Err(WireError::Closed) => Some("join stream closed".to_string()),
                        Ok(frame) => {
                            return Err(WireError::Protocol(format!(
                                "unexpected frame on the join stream: {frame:?}"
                            )))
                        }
                        Err(e) => return Err(e),
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => None,
                Err(e) => Some(format!("join stream error: {e}")),
            };
            if let Some(why) = rejected {
                if Instant::now() >= overall {
                    return Err(WireError::Protocol(format!(
                        "{why}; connect window exhausted"
                    )));
                }
                eprintln!("gtip serve: {why}; retrying");
                std::thread::sleep(JOIN_RETRY_PAUSE);
                continue 'attempt;
            }
            // Admission check: the leader dials our listener first,
            // then the other members (whose dials may still arrive in
            // any order relative to the leader's).
            match listener.accept() {
                Ok((stream, addr)) => {
                    let deadline = Instant::now() + JOIN_HANDSHAKE_TIMEOUT;
                    match handshake_inbound(stream, machine_id, k_orig, deadline, &no_peer_seen)
                    {
                        Ok((0, stream)) => break 'attempt (out, stream),
                        Ok((peer, stream)) => {
                            if stash.iter().any(|(p, _)| *p == peer) {
                                eprintln!(
                                    "gtip serve: dropping duplicate dial from machine {peer}"
                                );
                            } else {
                                stash.push((peer, stream));
                            }
                        }
                        Err(e) => {
                            eprintln!("gtip serve: dropping inbound connection from {addr}: {e}")
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
                Err(e) => return Err(e.into()),
            }
            if Instant::now() >= wait_deadline {
                return Err(WireError::Protocol(format!(
                    "not admitted within the {admit_window:?} admit window"
                )));
            }
            std::thread::sleep(ACCEPT_POLL);
        }
    };

    let mut leader_out = leader_out;
    leader_out.set_nonblocking(false)?;
    let mut leader_in = leader_in;
    // The Admit broadcast follows the leader's dial immediately.
    leader_in.set_read_timeout(Some(admit_window))?;
    let admit = read_frame(&mut leader_in).map_err(|e| e.while_awaiting("awaiting Admit", 0))?;
    // The joiner's rack arrives again inside the fresh Setup's full
    // machine → rack map, so the Admit copy is redundant here.
    let Frame::Admit { members, joiner, speeds, rack: _ } = admit else {
        return Err(WireError::Protocol(format!("expected Admit, got {admit:?}")));
    };
    if joiner as MachineId != machine_id {
        return Err(WireError::Protocol(format!(
            "admit names joiner {joiner}, we are {machine_id}"
        )));
    }
    let members: Vec<MachineId> = members.iter().map(|&w| w as MachineId).collect();
    if members.len() < 2
        || !members.windows(2).all(|w| w[0] < w[1])
        || *members.last().expect("non-empty") >= k_orig
        || members[0] != 0
        || !members.contains(&machine_id)
    {
        return Err(WireError::Protocol(format!("admit member list {members:?} is invalid")));
    }
    if speeds.len() != members.len() {
        return Err(WireError::Protocol(format!(
            "admit has {} members but {} speeds",
            members.len(),
            speeds.len()
        )));
    }
    if die == "admit" {
        eprintln!("gtip serve: GTIP_SERVE_DIE=admit — dying on Admit");
        std::process::exit(86);
    }
    leader_in.set_read_timeout(None)?;

    // Complete the mesh: dial every other member, collect their dials
    // (some may already be stashed from the wait loop).
    let deadline = Instant::now() + admit_window;
    let mut outs: Vec<Option<FramedConn>> = (0..k_orig).map(|_| None).collect();
    outs[0] = Some(FramedConn::new(leader_out));
    for &m in &members {
        if m == 0 || m == machine_id {
            continue;
        }
        let mut s = dial_peer(&addrs[m], deadline)?;
        write_frame(
            &mut s,
            &Frame::Hello {
                version: WIRE_VERSION,
                machine: wire_u32(machine_id)?,
                machines: wire_u32(k_orig)?,
            },
        )?;
        outs[m] = Some(FramedConn::new(s));
    }
    let expected: Vec<MachineId> =
        members.iter().copied().filter(|&m| m != 0 && m != machine_id).collect();
    let mut have: Vec<(MachineId, TcpStream)> = Vec::new();
    for (peer, stream) in stash {
        if expected.contains(&peer) && !have.iter().any(|(p, _)| *p == peer) {
            have.push((peer, stream));
        }
    }
    while have.len() < expected.len() {
        match listener.accept() {
            Ok((stream, addr)) => {
                match handshake_inbound(stream, machine_id, k_orig, deadline, &no_peer_seen) {
                    Ok((peer, stream))
                        if expected.contains(&peer) && !have.iter().any(|(p, _)| *p == peer) =>
                    {
                        have.push((peer, stream))
                    }
                    Ok((peer, _)) => {
                        eprintln!("gtip serve: dropping unexpected dial from machine {peer}")
                    }
                    Err(e) => {
                        eprintln!("gtip serve: dropping inbound connection from {addr}: {e}")
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(WireError::Protocol(format!(
                        "timed out waiting for member dials (have {}/{})",
                        have.len(),
                        expected.len()
                    )));
                }
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(e) => return Err(e.into()),
        }
    }

    // Hand-build the endpoint — the mesh helper assumes a full K-way
    // dial, but a joiner's mesh is the admitted membership.
    let pos = members.iter().position(|&w| w == machine_id).expect("validated above");
    let (inbox_tx, inbox) = channel();
    let (ctrl_tx, ctrl) = channel();
    spawn_reader(leader_in, 0, inbox_tx.clone(), ctrl_tx.clone());
    for (peer, stream) in have {
        spawn_reader(stream, peer, inbox_tx.clone(), ctrl_tx.clone());
    }
    let mut logical_of = vec![None; k_orig];
    for (logical, &wire) in members.iter().enumerate() {
        logical_of[wire] = Some(logical);
    }
    let ep = TcpEndpoint {
        id: pos,
        k: members.len(),
        wire_id: machine_id,
        wire_of: members.clone(),
        logical_of,
        inbox,
        inbox_tx,
        ctrl,
        ctrl_tx,
        listener,
        outs,
        stats: Arc::new(Mutex::new(OverheadStats::default())),
        net: Arc::new(Mutex::new(NetStats::default())),
        failures: Mutex::new(SendFailures::default()),
    };

    // Fixture + catch-up snapshot, then ack the admission.
    let setup = match recv_from_leader(&ep, admit_window, "awaiting Setup")? {
        (0, Frame::Setup(s)) => s,
        (peer, frame) => {
            return Err(WireError::Protocol(format!(
                "expected Setup from the leader, got {frame:?} from machine {peer}"
            )))
        }
    };
    let fixture = WorkerFixture::from_setup(&setup, members.len())?;
    match recv_from_leader(&ep, admit_window, "awaiting Catchup")? {
        (0, Frame::Catchup { snapshot }) => {
            let snap = crate::sim::Snapshot::decode(&snapshot)
                .map_err(|e| WireError::Protocol(format!("catch-up snapshot: {e}")))?;
            snap.validate_catchup(members.len(), fixture.graph.node_weights().len())
                .map_err(WireError::Protocol)?;
            eprintln!("gtip serve: caught up from {}", snap.summary());
        }
        (peer, frame) => {
            return Err(WireError::Protocol(format!(
                "expected Catchup from the leader, got {frame:?} from machine {peer}"
            )))
        }
    }
    ep.send_ctrl(0, &Frame::AdmitAck { machine: wire_u32(machine_id)? })?;
    eprintln!("gtip serve: admitted as machine {pos}/{} (wire id {machine_id})", members.len());
    run_worker_loop(ep, addrs, fixture, &die)
}

/// Weights arriving off the wire must be finite and non-negative —
/// the graph constructors assert exactly that, and a worker must turn
/// a bad leader into a protocol error, not an abort.
fn weights_valid(ws: &[f64]) -> bool {
    weights_valid_iter(ws.iter().copied())
}

fn weights_valid_iter(mut ws: impl Iterator<Item = f64>) -> bool {
    ws.all(|w| w.is_finite() && w >= 0.0)
}

#[cfg(test)]
mod tests {
    use crate::coordinator::net::build_tcp_bus_local;

    use super::*;

    /// A worker whose leader goes silent (alive socket, no frames) must
    /// give up after the *derived* epoch wait — ten receive timeouts,
    /// floored at 5 s — not the old hard-coded 600 s. With a 200 ms
    /// fixture timeout the floor governs: the worker exits in ~5 s.
    #[test]
    fn silent_leader_bounds_the_workers_wait() {
        assert_eq!(epoch_wait(Duration::from_millis(200)), Duration::from_secs(5));
        assert_eq!(epoch_wait(Duration::from_secs(2)), Duration::from_secs(20));
        assert_eq!(epoch_wait(Duration::MAX), Duration::MAX); // saturates, no overflow

        let (mut eps, _stats) = build_tcp_bus_local(2).unwrap();
        let ep1 = eps.pop().unwrap();
        let _ep0 = eps.pop().unwrap(); // the leader: alive but silent
        let setup = SetupFrame {
            speeds: vec![0.5, 0.5],
            mu: 8.0,
            framework: Framework::A,
            migration_charge: 0.0,
            epsilon: 1e-9,
            max_transfers: 1000,
            recv_timeout_ms: 200,
            node_weights: vec![1.0, 1.0],
            edges: vec![(0, 1, 1.0)],
            racks: vec![],
        };
        let fixture = WorkerFixture::from_setup(&setup, 2).unwrap();
        let addrs: Vec<String> = vec!["127.0.0.1:1".into(), "127.0.0.1:2".into()];
        let start = Instant::now();
        let worker = std::thread::spawn(move || run_worker_loop(ep1, &addrs, fixture, ""));
        // Poll rather than join so a regression to an unbounded wait
        // fails the test at 60 s instead of hanging CI for 600.
        while !worker.is_finished() {
            assert!(
                start.elapsed() < Duration::from_secs(60),
                "worker still waiting after {:?} — epoch wait not derived from recv timeout",
                start.elapsed()
            );
            std::thread::sleep(Duration::from_millis(100));
        }
        let waited = start.elapsed();
        let result = worker.join().expect("worker thread must not panic");
        let err = match result {
            Ok(_) => panic!("a silent leader must surface as an error, not success"),
            Err(e) => e.to_string(),
        };
        assert!(
            err.contains("peer 0, awaiting EpochBegin"),
            "the error must name the silent peer and the awaited frame: {err}"
        );
        assert!(
            waited >= Duration::from_secs(4),
            "worker gave up before the derived epoch wait: {waited:?}"
        );
    }
}

//! Wire protocol of the distributed refinement (paper Fig. 2), plus
//! overhead accounting used to verify the §4.5 feasibility claim.
//!
//! Every transfer carries a global sequence number (the ring-wide
//! transfer count at the moment it executed). On the in-process bus the
//! single mpsc queue per machine already delivers causally, but over
//! TCP a `RegularUpdate` from machine *m* and the turn token relayed
//! through machine *n* travel on different connections and may arrive
//! out of order; the sequence number lets every replica apply transfers
//! in the unique global order regardless of arrival interleaving (see
//! `coordinator::distributed::machine_loop`). `Shutdown` announces the
//! final transfer count for the same reason: a receiver only stops once
//! its replica has caught up to the announced total.

use crate::graph::NodeId;
use crate::partition::MachineId;

/// Messages exchanged between machine actors. Mirrors Fig. 2's triggers.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// The round-robin turn token. Carries the count of consecutive
    /// forfeits so the ring can detect convergence (all K forfeited) and
    /// the global transfer count so the safety cap is ring-wide.
    TakeMyTurn { consecutive_forfeits: usize, transfers_so_far: usize },
    /// "You now own `node`" — sent to the destination machine of a
    /// transfer. `seq` is the 0-based global index of this transfer.
    ReceiveNode { seq: u64, node: NodeId, from: MachineId, to: MachineId },
    /// Transfer notification + fresh aggregate loads, broadcast to all
    /// other machines. `loads` has length K — the machine-level aggregate
    /// state of §4.5.
    RegularUpdate { seq: u64, node: NodeId, from: MachineId, to: MachineId, loads: Vec<f64> },
    /// The outer (rack-level) game's aggregate exchange (DESIGN.md §12,
    /// wire v5): same layout as [`Message::RegularUpdate`], but `from` /
    /// `to` are *rack* ids and `rack_loads` has length R — one aggregate
    /// per rack, the O(K_rack) quantity only rack leaders exchange.
    /// Counted apart from `RegularUpdate` so the hierarchy's cross-rack
    /// bytes are measurable on their own.
    RackUpdate { seq: u64, node: NodeId, from: MachineId, to: MachineId, rack_loads: Vec<f64> },
    /// Stop once the local replica has applied `total_transfers`
    /// transfers. `converged` says why the ring stopped — a genuine
    /// Nash equilibrium (K consecutive forfeits) vs the transfer cap —
    /// so every machine reports the same outcome on every transport.
    Shutdown { total_transfers: u64, converged: bool },
}

/// Bytes of the length prefix framing every message on the wire.
pub const FRAME_PREFIX_BYTES: usize = 4;

impl Message {
    /// Short type tag for statistics.
    pub fn tag(&self) -> &'static str {
        match self {
            Message::TakeMyTurn { .. } => "take_my_turn",
            Message::ReceiveNode { .. } => "receive_node",
            Message::RegularUpdate { .. } => "regular_update",
            Message::RackUpdate { .. } => "rack_update",
            Message::Shutdown { .. } => "shutdown",
        }
    }

    /// Exact serialized size in bytes, including the length prefix —
    /// `coordinator::net::encode_message` produces exactly this many
    /// bytes (asserted by a codec property test), and both transports
    /// feed it into [`OverheadStats`] so the measured overhead is the
    /// true on-the-wire cost. This is the quantity whose independence
    /// from N the §4.5 claim is about: `TakeMyTurn`, `ReceiveNode`, and
    /// `Shutdown` are O(1); `RegularUpdate` is O(K).
    pub fn wire_bytes(&self) -> usize {
        FRAME_PREFIX_BYTES
            + match self {
                // tag + forfeits u64 + transfers u64
                Message::TakeMyTurn { .. } => 1 + 8 + 8,
                // tag + seq u64 + node u64 + from u32 + to u32
                Message::ReceiveNode { .. } => 1 + 8 + 8 + 4 + 4,
                // ReceiveNode layout + loads length u32 + K f64s
                Message::RegularUpdate { loads, .. } => 1 + 8 + 8 + 4 + 4 + 4 + 8 * loads.len(),
                // RegularUpdate layout with R f64s: 33 + 8R framed — the
                // O(K_rack) cross-rack quantity of the overhead table.
                Message::RackUpdate { rack_loads, .. } => {
                    1 + 8 + 8 + 4 + 4 + 4 + 8 * rack_loads.len()
                }
                // tag + total u64 + converged u8
                Message::Shutdown { .. } => 1 + 8 + 1,
            }
    }
}

/// Per-type message counters (lock-free on the hot path is unnecessary:
/// updates happen per message, machine count is tiny).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct OverheadStats {
    pub take_my_turn: Counter,
    pub receive_node: Counter,
    pub regular_update: Counter,
    pub rack_update: Counter,
    pub shutdown: Counter,
}

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Counter {
    pub messages: u64,
    pub bytes: u64,
}

impl Counter {
    fn add(&mut self, other: &Counter) {
        self.messages += other.messages;
        self.bytes += other.bytes;
    }
}

impl OverheadStats {
    pub fn record(&mut self, msg: &Message) {
        let c = match msg {
            Message::TakeMyTurn { .. } => &mut self.take_my_turn,
            Message::ReceiveNode { .. } => &mut self.receive_node,
            Message::RegularUpdate { .. } => &mut self.regular_update,
            Message::RackUpdate { .. } => &mut self.rack_update,
            Message::Shutdown { .. } => &mut self.shutdown,
        };
        c.messages += 1;
        c.bytes += msg.wire_bytes() as u64;
    }

    /// Fold another machine's counters into this one (the multi-process
    /// leader aggregates the per-machine `RoundStats` reports this way).
    pub fn add(&mut self, other: &OverheadStats) {
        self.take_my_turn.add(&other.take_my_turn);
        self.receive_node.add(&other.receive_node);
        self.regular_update.add(&other.regular_update);
        self.rack_update.add(&other.rack_update);
        self.shutdown.add(&other.shutdown);
    }

    /// Counters since `baseline` (which must be an earlier snapshot of
    /// this same accumulator).
    pub fn delta_since(&self, baseline: &OverheadStats) -> OverheadStats {
        fn sub(a: Counter, b: Counter) -> Counter {
            Counter { messages: a.messages - b.messages, bytes: a.bytes - b.bytes }
        }
        OverheadStats {
            take_my_turn: sub(self.take_my_turn, baseline.take_my_turn),
            receive_node: sub(self.receive_node, baseline.receive_node),
            regular_update: sub(self.regular_update, baseline.regular_update),
            rack_update: sub(self.rack_update, baseline.rack_update),
            shutdown: sub(self.shutdown, baseline.shutdown),
        }
    }

    pub fn total_messages(&self) -> u64 {
        self.take_my_turn.messages
            + self.receive_node.messages
            + self.regular_update.messages
            + self.rack_update.messages
            + self.shutdown.messages
    }

    pub fn total_bytes(&self) -> u64 {
        self.take_my_turn.bytes
            + self.receive_node.bytes
            + self.regular_update.bytes
            + self.rack_update.bytes
            + self.shutdown.bytes
    }

    /// Synchronization bytes per executed transfer — the paper's
    /// feasibility metric. One transfer costs 1 `ReceiveNode` + (K−2)
    /// `RegularUpdate`s: O(K²) bytes total, **independent of N**.
    pub fn bytes_per_transfer(&self, transfers: u64) -> f64 {
        if transfers == 0 {
            return 0.0;
        }
        (self.receive_node.bytes + self.regular_update.bytes) as f64 / transfers as f64
    }

    /// Mean bytes of one aggregate-state broadcast (`RegularUpdate`) —
    /// exactly `33 + 8K` on the wire, the §4.5 O(K) quantity.
    pub fn bytes_per_regular_update(&self) -> f64 {
        if self.regular_update.messages == 0 {
            return 0.0;
        }
        self.regular_update.bytes as f64 / self.regular_update.messages as f64
    }

    /// Mean bytes of one cross-rack aggregate exchange (`RackUpdate`) —
    /// exactly `33 + 8R` on the wire, the O(K_rack) quantity of the
    /// hierarchy's overhead claim (DESIGN.md §12).
    pub fn bytes_per_rack_update(&self) -> f64 {
        if self.rack_update.messages == 0 {
            return 0.0;
        }
        self.rack_update.bytes as f64 / self.rack_update.messages as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_are_n_independent() {
        let a = Message::ReceiveNode { seq: 0, node: 3, from: 0, to: 1 };
        let b = Message::ReceiveNode { seq: u64::MAX, node: 3_000_000, from: 0, to: 1 };
        assert_eq!(a.wire_bytes(), b.wire_bytes());
        assert_eq!(a.wire_bytes(), 4 + 25);
        let u = Message::RegularUpdate { seq: 1, node: 1, from: 0, to: 1, loads: vec![0.0; 5] };
        assert_eq!(u.wire_bytes(), 4 + 29 + 40);
        // RackUpdate scales with R (rack count), not K (machine count).
        let r = Message::RackUpdate { seq: 1, node: 1, from: 0, to: 1, rack_loads: vec![0.0; 2] };
        assert_eq!(r.wire_bytes(), 4 + 29 + 16);
        assert_eq!(
            Message::Shutdown { total_transfers: 9, converged: true }.wire_bytes(),
            4 + 10
        );
        assert_eq!(
            Message::TakeMyTurn { consecutive_forfeits: 0, transfers_so_far: 0 }.wire_bytes(),
            4 + 17
        );
    }

    #[test]
    fn stats_accumulate_by_tag() {
        let mut s = OverheadStats::default();
        s.record(&Message::TakeMyTurn { consecutive_forfeits: 0, transfers_so_far: 0 });
        s.record(&Message::Shutdown { total_transfers: 0, converged: true });
        s.record(&Message::RegularUpdate { seq: 0, node: 0, from: 0, to: 1, loads: vec![0.0; 4] });
        s.record(&Message::RackUpdate { seq: 0, node: 0, from: 0, to: 1, rack_loads: vec![0.0; 2] });
        assert_eq!(s.total_messages(), 4);
        assert_eq!(s.take_my_turn.messages, 1);
        assert_eq!(s.regular_update.bytes, (4 + 29 + 32) as u64);
        assert_eq!(s.bytes_per_regular_update(), (4 + 29 + 32) as f64);
        assert_eq!(s.rack_update.bytes, (4 + 29 + 16) as u64);
        assert_eq!(s.bytes_per_rack_update(), (4 + 29 + 16) as f64);
    }

    #[test]
    fn stats_add_and_delta_round_trip() {
        let mut a = OverheadStats::default();
        a.record(&Message::Shutdown { total_transfers: 0, converged: false });
        let snapshot = a.clone();
        a.record(&Message::TakeMyTurn { consecutive_forfeits: 1, transfers_so_far: 2 });
        let delta = a.delta_since(&snapshot);
        assert_eq!(delta.shutdown.messages, 0);
        assert_eq!(delta.take_my_turn.messages, 1);
        let mut sum = snapshot.clone();
        sum.add(&delta);
        assert_eq!(sum, a);
    }

    #[test]
    fn bytes_per_transfer_guard_against_zero() {
        let s = OverheadStats::default();
        assert_eq!(s.bytes_per_transfer(0), 0.0);
        assert_eq!(s.bytes_per_regular_update(), 0.0);
        assert_eq!(s.bytes_per_rack_update(), 0.0);
    }

    #[test]
    fn tags_stable() {
        assert_eq!(Message::Shutdown { total_transfers: 0, converged: true }.tag(), "shutdown");
        assert_eq!(
            Message::TakeMyTurn { consecutive_forfeits: 1, transfers_so_far: 0 }.tag(),
            "take_my_turn"
        );
        assert_eq!(
            Message::RackUpdate { seq: 0, node: 0, from: 0, to: 0, rack_loads: vec![] }.tag(),
            "rack_update"
        );
    }
}

//! Wire protocol of the distributed refinement (paper Fig. 2), plus
//! overhead accounting used to verify the §4.5 feasibility claim.

use crate::graph::NodeId;
use crate::partition::MachineId;

/// Messages exchanged between machine actors. Mirrors Fig. 2's triggers.
#[derive(Debug, Clone)]
pub enum Message {
    /// The round-robin turn token. Carries the count of consecutive
    /// forfeits so the ring can detect convergence (all K forfeited) and
    /// the global transfer count so the safety cap is ring-wide.
    TakeMyTurn { consecutive_forfeits: usize, transfers_so_far: usize },
    /// "You now own `node`" — sent to the destination machine of a
    /// transfer.
    ReceiveNode { node: NodeId, from: MachineId, to: MachineId },
    /// Transfer notification + fresh aggregate loads, broadcast to all
    /// other machines. `loads` has length K — the machine-level aggregate
    /// state of §4.5.
    RegularUpdate { node: NodeId, from: MachineId, to: MachineId, loads: Vec<f64> },
    /// Convergence reached; stop and report.
    Shutdown,
}

impl Message {
    /// Short type tag for statistics.
    pub fn tag(&self) -> &'static str {
        match self {
            Message::TakeMyTurn { .. } => "take_my_turn",
            Message::ReceiveNode { .. } => "receive_node",
            Message::RegularUpdate { .. } => "regular_update",
            Message::Shutdown => "shutdown",
        }
    }

    /// Approximate serialized size in bytes. This is the quantity whose
    /// independence from N the §4.5 claim is about: `TakeMyTurn` and
    /// `ReceiveNode` are O(1); `RegularUpdate` is O(K).
    pub fn approx_bytes(&self) -> usize {
        match self {
            Message::TakeMyTurn { .. } => 1 + 8 + 8,
            Message::ReceiveNode { .. } => 1 + 8 + 4 + 4,
            Message::RegularUpdate { loads, .. } => 1 + 8 + 4 + 4 + 8 * loads.len(),
            Message::Shutdown => 1,
        }
    }
}

/// Per-type message counters (lock-free on the hot path is unnecessary:
/// updates happen per message, machine count is tiny).
#[derive(Debug, Clone, Default)]
pub struct OverheadStats {
    pub take_my_turn: Counter,
    pub receive_node: Counter,
    pub regular_update: Counter,
    pub shutdown: Counter,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct Counter {
    pub messages: u64,
    pub bytes: u64,
}

impl OverheadStats {
    pub fn record(&mut self, msg: &Message) {
        let c = match msg {
            Message::TakeMyTurn { .. } => &mut self.take_my_turn,
            Message::ReceiveNode { .. } => &mut self.receive_node,
            Message::RegularUpdate { .. } => &mut self.regular_update,
            Message::Shutdown => &mut self.shutdown,
        };
        c.messages += 1;
        c.bytes += msg.approx_bytes() as u64;
    }

    pub fn total_messages(&self) -> u64 {
        self.take_my_turn.messages
            + self.receive_node.messages
            + self.regular_update.messages
            + self.shutdown.messages
    }

    pub fn total_bytes(&self) -> u64 {
        self.take_my_turn.bytes
            + self.receive_node.bytes
            + self.regular_update.bytes
            + self.shutdown.bytes
    }

    /// Synchronization bytes per executed transfer — the paper's
    /// feasibility metric. One transfer costs 1 `ReceiveNode` + (K−1)
    /// `RegularUpdate`s: O(K²) bytes total, **independent of N**.
    pub fn bytes_per_transfer(&self, transfers: u64) -> f64 {
        if transfers == 0 {
            return 0.0;
        }
        (self.receive_node.bytes + self.regular_update.bytes) as f64 / transfers as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_are_n_independent() {
        let a = Message::ReceiveNode { node: 3, from: 0, to: 1 };
        let b = Message::ReceiveNode { node: 3_000_000, from: 0, to: 1 };
        assert_eq!(a.approx_bytes(), b.approx_bytes());
        let u = Message::RegularUpdate { node: 1, from: 0, to: 1, loads: vec![0.0; 5] };
        assert_eq!(u.approx_bytes(), 1 + 8 + 4 + 4 + 40);
    }

    #[test]
    fn stats_accumulate_by_tag() {
        let mut s = OverheadStats::default();
        s.record(&Message::TakeMyTurn { consecutive_forfeits: 0, transfers_so_far: 0 });
        s.record(&Message::Shutdown);
        s.record(&Message::RegularUpdate { node: 0, from: 0, to: 1, loads: vec![0.0; 4] });
        assert_eq!(s.total_messages(), 3);
        assert_eq!(s.take_my_turn.messages, 1);
        assert_eq!(s.regular_update.bytes, (1 + 8 + 4 + 4 + 32) as u64);
    }

    #[test]
    fn bytes_per_transfer_guard_against_zero() {
        let s = OverheadStats::default();
        assert_eq!(s.bytes_per_transfer(0), 0.0);
    }

    #[test]
    fn tags_stable() {
        assert_eq!(Message::Shutdown.tag(), "shutdown");
        assert_eq!(Message::TakeMyTurn { consecutive_forfeits: 1, transfers_so_far: 0 }.tag(), "take_my_turn");
    }
}

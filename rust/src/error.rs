//! Library-wide error type.

/// Errors produced by GTIP library operations.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("graph error: {0}")]
    Graph(String),

    #[error("partition error: {0}")]
    Partition(String),

    #[error("simulation error: {0}")]
    Sim(String),

    #[error("coordinator error: {0}")]
    Coordinator(String),

    #[error("runtime (PJRT) error: {0}")]
    Runtime(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

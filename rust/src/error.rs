//! Library-wide error type (hand-rolled `Display`/`Error` impls —
//! `thiserror` is unavailable offline).

/// Errors produced by GTIP library operations.
#[derive(Debug)]
pub enum Error {
    Graph(String),
    Partition(String),
    Sim(String),
    Coordinator(String),
    Runtime(String),
    Config(String),
    Io(std::io::Error),
    Xla(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Graph(m) => write!(f, "graph error: {m}"),
            Error::Partition(m) => write!(f, "partition error: {m}"),
            Error::Sim(m) => write!(f, "simulation error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Runtime(m) => write!(f, "runtime (PJRT) error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

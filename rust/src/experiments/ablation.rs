//! Ablation studies for design choices the paper calls out.
//!
//! * [`mu_sweep`] — §5.1's explicit trend: "as μ was increased, the
//!   number of runs where the C̃_i framework performed better increased,
//!   but again only in terms of its own global cost (C̃0)". We sweep μ
//!   and count B-wins-own-cost per μ level.
//! * [`initial_partition_ablation`] — §4.1's motivation for the
//!   focal-node initial partitioning: compare equilibrium quality (and
//!   iterations) from App.-A hop-growth starts vs uniform-random starts.
//! * [`cluster_escape_ablation`] — §4.4/§7: how often do cluster
//!   (multi-node) transfers improve a single-node Nash equilibrium, and
//!   by how much.

use crate::experiments::common::{run_tracked, StudySetup};
use crate::game::cluster::{cluster_escape, ClusterOptions};
use crate::game::cost::Framework;
use crate::game::refine::{RefineEngine, RefineOptions};
use crate::partition::baselines::random_partition;
use crate::partition::global_cost;
use crate::util::rng::Pcg32;
use crate::util::table::Table;

/// One μ level of the sweep.
#[derive(Debug, Clone)]
pub struct MuPoint {
    pub mu: f64,
    pub runs: usize,
    /// Runs where B's equilibrium had strictly lower C̃0 than A's.
    pub b_wins_own: usize,
    /// Runs where A's equilibrium was better on both costs.
    pub a_wins_both: usize,
}

/// §5.1 μ-trend: sweep μ, fixed graphs/initials across levels.
pub fn mu_sweep(nodes: usize, runs_per_mu: usize, mus: &[f64], seed: u64) -> Vec<MuPoint> {
    let mut out = Vec::with_capacity(mus.len());
    for &mu in mus {
        let mut b_wins_own = 0;
        let mut a_wins_both = 0;
        for r in 0..runs_per_mu {
            let mut rng = Pcg32::new(seed.wrapping_add(r as u64)); // same graphs per μ level
            let setup = StudySetup { nodes, mu, ..Default::default() };
            let graph = setup.graph(&mut rng);
            let initial = setup.initial(&graph, &mut rng);
            let a = run_tracked(&graph, &setup.machines, initial.clone(), mu, Framework::A);
            let b = run_tracked(&graph, &setup.machines, initial, mu, Framework::B);
            if b.c0_tilde < a.c0_tilde - 1e-9 {
                b_wins_own += 1;
            }
            if a.c0 <= b.c0 + 1e-9 && a.c0_tilde <= b.c0_tilde + 1e-9 {
                a_wins_both += 1;
            }
        }
        out.push(MuPoint { mu, runs: runs_per_mu, b_wins_own, a_wins_both });
    }
    out
}

/// Initial-partitioning ablation result.
#[derive(Debug, Clone)]
pub struct InitAblation {
    pub runs: usize,
    pub mean_c0_grow: f64,
    pub mean_c0_random: f64,
    pub mean_iters_grow: f64,
    pub mean_iters_random: f64,
}

/// App.-A hop-growth start vs uniform-random start (framework A).
pub fn initial_partition_ablation(nodes: usize, runs: usize, seed: u64) -> InitAblation {
    let setup = StudySetup { nodes, ..Default::default() };
    let mut c0g = 0.0;
    let mut c0r = 0.0;
    let mut itg = 0.0;
    let mut itr = 0.0;
    for r in 0..runs {
        let mut rng = Pcg32::new(seed.wrapping_add(100 + r as u64));
        let graph = setup.graph(&mut rng);
        let grow = setup.initial(&graph, &mut rng);
        let rand = random_partition(&graph, setup.machines.count(), &mut rng);
        let a = run_tracked(&graph, &setup.machines, grow, setup.mu, Framework::A);
        let b = run_tracked(&graph, &setup.machines, rand, setup.mu, Framework::A);
        c0g += a.c0;
        c0r += b.c0;
        itg += a.iterations as f64;
        itr += b.iterations as f64;
    }
    let n = runs as f64;
    InitAblation {
        runs,
        mean_c0_grow: c0g / n,
        mean_c0_random: c0r / n,
        mean_iters_grow: itg / n,
        mean_iters_random: itr / n,
    }
}

/// Cluster-escape ablation result.
#[derive(Debug, Clone)]
pub struct ClusterAblation {
    pub runs: usize,
    /// Runs where at least one cluster move improved the equilibrium.
    pub improved_runs: usize,
    /// Mean relative C0 improvement over the single-node equilibrium.
    pub mean_rel_improvement: f64,
}

/// §4.4/§7: value of coordinated (cluster) moves on top of single-node
/// equilibria.
pub fn cluster_escape_ablation(nodes: usize, runs: usize, seed: u64) -> ClusterAblation {
    let setup = StudySetup { nodes, ..Default::default() };
    let mut improved_runs = 0;
    let mut rel = 0.0;
    for r in 0..runs {
        let mut rng = Pcg32::new(seed.wrapping_add(500 + r as u64));
        let graph = setup.graph(&mut rng);
        let initial = setup.initial(&graph, &mut rng);
        let mut engine =
            RefineEngine::new(&graph, &setup.machines, initial, setup.mu, Framework::A);
        let _ = engine.run(&RefineOptions::default());
        let mut part = engine.into_partition();
        let before = global_cost::c0(&graph, &setup.machines, &part, setup.mu);
        let moves = cluster_escape(
            &graph,
            &setup.machines,
            &mut part,
            setup.mu,
            Framework::A,
            &ClusterOptions::default(),
        );
        let after = global_cost::c0(&graph, &setup.machines, &part, setup.mu);
        if !moves.is_empty() {
            improved_runs += 1;
        }
        rel += (before - after) / before.max(1.0);
    }
    ClusterAblation {
        runs,
        improved_runs,
        mean_rel_improvement: rel / runs as f64,
    }
}

/// CLI entry: run all three ablations and print tables.
pub fn run_and_report(seed: u64, quick: bool) {
    let (nodes, runs) = if quick { (120, 8) } else { (230, 20) };

    // μ sweep.
    let mus = [2.0, 8.0, 32.0];
    let points = mu_sweep(nodes, runs, &mus, seed);
    let mut t = Table::new(
        "Ablation: effect of mu (paper §5.1: B wins its own cost more often as mu grows)",
        &["mu", "runs", "B wins own C~0", "A wins both"],
    );
    for p in &points {
        t.row(&[
            format!("{}", p.mu),
            p.runs.to_string(),
            p.b_wins_own.to_string(),
            p.a_wins_both.to_string(),
        ]);
    }
    println!("{}", t.to_text());
    let _ = t.write_csv("ablation_mu");

    // Initial partitioning.
    let init = initial_partition_ablation(nodes, runs, seed);
    let mut t2 = Table::new(
        "Ablation: App.-A focal-node initial partitioning vs random start (framework A)",
        &["metric", "focal-grow", "random"],
    );
    t2.row(&[
        "mean C0 at equilibrium".into(),
        format!("{:.0}", init.mean_c0_grow),
        format!("{:.0}", init.mean_c0_random),
    ]);
    t2.row(&[
        "mean iterations".into(),
        format!("{:.1}", init.mean_iters_grow),
        format!("{:.1}", init.mean_iters_random),
    ]);
    println!("{}", t2.to_text());
    let _ = t2.write_csv("ablation_initial");

    // Cluster escape.
    let cl = cluster_escape_ablation(nodes, runs, seed);
    let mut t3 = Table::new(
        "Ablation: cluster (multi-node) transfers on top of single-node equilibria (§4.4/§7)",
        &["runs", "runs improved", "mean rel C0 improvement"],
    );
    t3.row(&[
        cl.runs.to_string(),
        cl.improved_runs.to_string(),
        format!("{:.4}", cl.mean_rel_improvement),
    ]);
    println!("{}", t3.to_text());
    let _ = t3.write_csv("ablation_cluster");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mu_sweep_structurally_sane() {
        // The paper's side-note claims B wins its own cost more often as
        // μ grows; in our weight regime the measured trend is the
        // OPPOSITE (at high μ the shared cut term dominates both local
        // costs, so the frameworks' moves coincide and B ties instead of
        // winning) — recorded as a non-reproducing secondary claim in
        // EXPERIMENTS.md. Here we assert only structural sanity: counts
        // bounded by runs, and A's overall dominance (the primary §5.1
        // claim) holding at every μ level.
        let points = mu_sweep(100, 10, &[1.0, 8.0, 32.0], 7);
        assert_eq!(points.len(), 3);
        for p in &points {
            assert!(p.b_wins_own <= p.runs);
            assert!(p.a_wins_both <= p.runs);
            assert!(
                p.a_wins_both * 2 >= p.runs,
                "A lost dominance at mu={}: {p:?}",
                p.mu
            );
        }
    }

    #[test]
    fn initial_partition_helps_or_ties() {
        let r = initial_partition_ablation(100, 6, 11);
        // The focal-grow start should not be *worse* than random in
        // equilibrium quality (paper's §4.1 motivation), and typically
        // converges in fewer iterations.
        assert!(
            r.mean_c0_grow <= r.mean_c0_random * 1.02,
            "grow {} vs random {}",
            r.mean_c0_grow,
            r.mean_c0_random
        );
        assert!(r.mean_iters_grow <= r.mean_iters_random * 1.2);
    }

    #[test]
    fn cluster_escape_never_hurts() {
        let r = cluster_escape_ablation(100, 6, 13);
        assert!(r.mean_rel_improvement >= -1e-12);
        assert!(r.improved_runs <= r.runs);
    }
}

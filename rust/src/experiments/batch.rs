//! §5.1 batch study: 50 random graph realizations × 10 initial
//! partitions (also sweeping μ and machine speeds across realizations),
//! counting (a) in how many runs each framework converges to better
//! values of *both* global costs, and (b) the average number of
//! discrepancy steps — iterations that increase the other framework's
//! global cost (paper: ≈0.2 C0-discrepancies vs ≈5.2 C̃0-discrepancies,
//! i.e. framework A searches more "broadly" yet almost never hurts C̃0).

use crate::experiments::common::{run_tracked, StudySetup};
use crate::game::cost::Framework;
use crate::partition::MachineConfig;
use crate::util::rng::Pcg32;
use crate::util::table::Table;

/// Aggregate result of the batch study.
#[derive(Debug, Clone, Default)]
pub struct BatchReport {
    pub runs: usize,
    /// Runs where A reached lower-or-equal values of both C0 and C̃0.
    pub a_wins_both: usize,
    /// Runs where B beat A on its own cost C̃0 (the paper's "1 out of
    /// 50" case).
    pub b_wins_own: usize,
    /// Runs where B beat A on both costs.
    pub b_wins_both: usize,
    /// Mean number of C0-increasing steps per run under framework B.
    pub avg_c0_discrepancies: f64,
    /// Mean number of C̃0-increasing steps per run under framework A.
    pub avg_c0_tilde_discrepancies: f64,
    /// Mean iterations to convergence (A / B).
    pub avg_iters_a: f64,
    pub avg_iters_b: f64,
}

impl BatchReport {
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(
            "Batch study (50 graphs x 10 initial partitions in the paper)",
            &["metric", "value", "paper"],
        );
        let rows: &[(&str, String, &str)] = &[
            ("runs", self.runs.to_string(), "50"),
            ("A wins both costs", self.a_wins_both.to_string(), "49/50"),
            ("B wins own cost only", self.b_wins_own.to_string(), "1/50"),
            ("B wins both costs", self.b_wins_both.to_string(), "0/50"),
            (
                "avg C0-discrepancies (under B)",
                format!("{:.2}", self.avg_c0_discrepancies),
                "~0.2",
            ),
            (
                "avg C~0-discrepancies (under A)",
                format!("{:.2}", self.avg_c0_tilde_discrepancies),
                "~5.2",
            ),
            ("avg iterations (A)", format!("{:.1}", self.avg_iters_a), "-"),
            ("avg iterations (B)", format!("{:.1}", self.avg_iters_b), "-"),
        ];
        for (m, v, p) in rows {
            t.row(&[m.to_string(), v.clone(), p.to_string()]);
        }
        t
    }
}

/// Run the batch study: `realizations` graphs × `initials` starting
/// partitions. μ and the speed profile vary across realizations, as in
/// the paper ("we also varied the relative weight μ and normalized
/// machine speeds w_k").
pub fn run(nodes: usize, realizations: usize, initials: usize, seed: u64) -> BatchReport {
    let speed_profiles: [&[f64]; 3] =
        [&[0.1, 0.2, 0.3, 0.3, 0.1], &[0.2, 0.2, 0.2, 0.2, 0.2], &[0.05, 0.15, 0.3, 0.35, 0.15]];
    let mus = [4.0, 8.0, 16.0];

    let mut report = BatchReport::default();
    let mut sum_c0_disc = 0.0;
    let mut sum_c0t_disc = 0.0;
    let mut sum_it_a = 0.0;
    let mut sum_it_b = 0.0;

    for real in 0..realizations {
        let mut rng = Pcg32::new(seed.wrapping_add(1000 + real as u64));
        let setup = StudySetup {
            nodes,
            machines: MachineConfig::from_speeds(speed_profiles[real % speed_profiles.len()]),
            mu: mus[real % mus.len()],
        };
        let graph = setup.graph(&mut rng);

        // Aggregate over the initial partitions of this realization: the
        // paper counts per-run results; a "run" is (graph, initial).
        for init_idx in 0..initials {
            let mut init_rng = rng.fork(init_idx as u64);
            let initial = setup.initial(&graph, &mut init_rng);
            let a =
                run_tracked(&graph, &setup.machines, initial.clone(), setup.mu, Framework::A);
            let b = run_tracked(&graph, &setup.machines, initial, setup.mu, Framework::B);

            report.runs += 1;
            let tol = 1e-9;
            let a_both = a.c0 <= b.c0 + tol && a.c0_tilde <= b.c0_tilde + tol;
            let b_both = b.c0 <= a.c0 + tol && b.c0_tilde <= a.c0_tilde + tol;
            if a_both {
                report.a_wins_both += 1;
            }
            if b_both && !a_both {
                report.b_wins_both += 1;
            } else if b.c0_tilde < a.c0_tilde - tol && !b_both {
                report.b_wins_own += 1;
            }
            sum_c0_disc += b.c0_discrepancies as f64;
            sum_c0t_disc += a.c0_tilde_discrepancies as f64;
            sum_it_a += a.iterations as f64;
            sum_it_b += b.iterations as f64;
        }
    }
    let n = report.runs as f64;
    report.avg_c0_discrepancies = sum_c0_disc / n;
    report.avg_c0_tilde_discrepancies = sum_c0t_disc / n;
    report.avg_iters_a = sum_it_a / n;
    report.avg_iters_b = sum_it_b / n;
    report
}

/// CLI entry with paper-scale parameters.
pub fn run_and_report(seed: u64, quick: bool) -> BatchReport {
    let (realizations, initials) = if quick { (10, 3) } else { (50, 10) };
    let report = run(230, realizations, initials, seed);
    let table = report.to_table();
    println!("{}", table.to_text());
    if let Ok(path) = table.write_csv("batch_study") {
        println!("(wrote {})", path.display());
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_batch_shapes_match_paper() {
        // 8 realizations x 2 initials at N=100 — fast but statistically
        // meaningful for the *direction* of every claim.
        let r = run(100, 8, 2, 3);
        assert_eq!(r.runs, 16);
        // A should dominate in the overwhelming majority of runs.
        assert!(
            r.a_wins_both as f64 >= 0.7 * r.runs as f64,
            "A won both in only {}/{} runs",
            r.a_wins_both,
            r.runs
        );
        // The discrepancy asymmetry is the key §5.1 observation.
        assert!(
            r.avg_c0_tilde_discrepancies > r.avg_c0_discrepancies,
            "expected C~0-discrepancies ({}) > C0-discrepancies ({})",
            r.avg_c0_tilde_discrepancies,
            r.avg_c0_discrepancies
        );
    }

    #[test]
    fn win_counts_partition_runs() {
        let r = run(80, 6, 2, 9);
        assert!(r.a_wins_both + r.b_wins_both <= r.runs);
    }

    #[test]
    fn table_lists_paper_reference_values() {
        let r = run(60, 2, 1, 1);
        let txt = r.to_table().to_text();
        assert!(txt.contains("~5.2"));
        assert!(txt.contains("49/50"));
    }
}

//! `gtip` command-line interface.
//!
//! ```text
//! gtip partition  [--family pa|geo|er|table1] [--nodes N] [--k K | --speeds s1,s2,...]
//!                 [--mu MU] [--framework A|B] [--seed S] [--graph FILE]
//!                 [--distributed] [--anneal] [--save FILE]
//! gtip simulate   [--family ...] [--nodes N] [--k K] [--refine-every T]
//!                 [--framework A|B] [--mu MU] [--threads N] [--seed S]
//! gtip dynamic    [--scenario hotspot|flash|diurnal|failure] [--nodes N] [--k K]
//!                 [--epoch-ticks E] [--estimator instant|ewma|hysteresis]
//!                 [--backend sequential|distributed] [--framework A|B]
//!                 [--threads N] [--horizon T] [--seed S] [--compare]
//! gtip fuzz       [--budget N] [--seed S] [--nodes N] [--k K] [--horizon T]
//!                 [--threads N] [--epoch-ticks E] [--framework A|B] [--top K]
//!                 [--speed-seed S] [--inter-delay D] [--intra-delay D]
//!                 [--corpus-dir DIR] [--replay FILE] [--no-shrink] [--no-oracle]
//! gtip experiment table1|batch|fig7|fig8|fig9|fig10|ablation|all [--seed S] [--quick]
//! gtip artifacts  [--dir DIR]         # verify PJRT artifacts vs native
//! gtip help
//! ```
//!
//! Errors are plain `Box<dyn Error>` (`anyhow` is unavailable offline);
//! every sub-error type converts via `?`.

use crate::util::cli::Args;

use super::cmd::{
    cmd_artifacts, cmd_bench_gate, cmd_churn_sweep, cmd_dynamic, cmd_experiment, cmd_fuzz,
    cmd_hierarchy_bench, cmd_partition, cmd_serve, cmd_simulate, cmd_snapshot, CliResult,
};

const HELP: &str = "gtip — Game Theoretic Iterative Partitioning (Kurve et al., TOMACS 2011)

USAGE:
  gtip partition  [--family pa|geo|er|table1] [--nodes N] [--k K] [--speeds s1,..]
                  [--mu MU] [--framework A|B] [--seed S] [--graph FILE]
                  [--distributed] [--anneal] [--save FILE]
  gtip simulate   [--family ...] [--nodes N] [--k K] [--refine-every T]
                  [--framework A|B] [--mu MU] [--threads N] [--seed S]
                  [--parallelism P]
  gtip dynamic    [--scenario hotspot|flash|diurnal|failure] [--nodes N] [--k K]
                  [--epoch-ticks E] [--estimator instant|ewma|hysteresis]
                  [--backend sequential|distributed] [--framework A|B]
                  [--threads N] [--horizon T] [--ticks-per-transfer C]
                  [--tick-value V] [--migration-charge CMIG]
                  [--seed S] [--compare] [--parallelism P]
                  [--transport inproc|tcp] [--peers host:port,...]
                  [--connect-timeout-ms MS] [--recv-timeout-ms MS]
                  [--admit-window-ms MS] [--report-json FILE]
                  [--checkpoint-dir DIR] [--restore FILE]
                  [--racks r0,r1,...]   # rack of each machine (two-level game)
  gtip churn-sweep [--scenarios hotspot,flash] [--nodes N] [--k K] [--threads N]
                  [--horizon T] [--epoch-ticks E] [--framework A|B] [--seed S]
                  [--charges 0,2,8,32] [--tick-value V] [--out FILE]
  gtip hierarchy-bench [--sizes 120,240,360] [--k K] [--racks r0,r1,...]
                  [--seed S] [--framework A|B] [--mu MU] [--out FILE]
  gtip serve      --machine-id K --peers host:port,host:port,...
                  [--connect-timeout-ms MS] [--checkpoint-dir DIR]
                  [--join] [--speed S] [--rack R] [--admit-window-ms MS]
  gtip snapshot   --inspect FILE      # print a checkpoint's summary + verify round-trip
  gtip fuzz       [--budget N] [--seed S] [--nodes N] [--k K] [--horizon T]
                  [--threads N] [--epoch-ticks E] [--framework A|B] [--top K]
                  [--migration-charge CMIG] [--speed-seed S]
                  [--inter-delay D] [--intra-delay D]
                  [--corpus-dir DIR] [--replay FILE]
                  [--no-shrink] [--no-oracle]
  gtip bench-gate [--baseline FILE] [--measured FILE]
  gtip experiment table1|batch|fig7|fig8|fig9|fig10|ablation|all [--seed S] [--quick]
  gtip artifacts  [--dir DIR]
  gtip help
";

/// Entry point used by `main.rs`; returns the process exit code.
pub fn main() -> i32 {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn run(args: &Args) -> CliResult {
    match args.subcommand() {
        Some("partition") => cmd_partition(args),
        Some("simulate") => cmd_simulate(args),
        Some("dynamic") => cmd_dynamic(args),
        Some("serve") => cmd_serve(args),
        Some("churn-sweep") => cmd_churn_sweep(args),
        Some("hierarchy-bench") => cmd_hierarchy_bench(args),
        Some("snapshot") => cmd_snapshot(args),
        Some("bench-gate") => cmd_bench_gate(args),
        Some("fuzz") => cmd_fuzz(args),
        Some("experiment") => cmd_experiment(args),
        Some("artifacts") => cmd_artifacts(args),
        Some("help") | None => {
            print!("{HELP}");
            Ok(())
        }
        Some(other) => Err(format!("unknown subcommand {other:?}\n{HELP}").into()),
    }
}

#[cfg(test)]
mod tests {
    use crate::util::bench::{parse_json, JsonVal};

    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn help_runs() {
        run(&parse(&["help"])).unwrap();
    }

    #[test]
    fn unknown_subcommand_errors() {
        assert!(run(&parse(&["frobnicate"])).is_err());
    }

    #[test]
    fn partition_small_sequential() {
        run(&parse(&["partition", "--nodes", "60", "--seed", "3", "--k", "3"])).unwrap();
    }

    #[test]
    fn partition_distributed_small() {
        run(&parse(&["partition", "--nodes", "50", "--seed", "4", "--k", "3", "--distributed"]))
            .unwrap();
    }

    #[test]
    fn simulate_small() {
        run(&parse(&[
            "simulate",
            "--nodes",
            "80",
            "--threads",
            "30",
            "--refine-every",
            "200",
            "--seed",
            "5",
            "--k",
            "3",
        ]))
        .unwrap();
    }

    #[test]
    fn dynamic_small_closed_loop() {
        run(&parse(&[
            "dynamic",
            "--scenario",
            "hotspot",
            "--nodes",
            "90",
            "--threads",
            "40",
            "--horizon",
            "800",
            "--epoch-ticks",
            "150",
            "--seed",
            "6",
            "--k",
            "3",
        ]))
        .unwrap();
    }

    #[test]
    fn dynamic_compare_mode() {
        run(&parse(&[
            "dynamic",
            "--scenario",
            "flash",
            "--nodes",
            "80",
            "--threads",
            "40",
            "--horizon",
            "800",
            "--epoch-ticks",
            "150",
            "--estimator",
            "hysteresis",
            "--seed",
            "7",
            "--k",
            "3",
            "--compare",
        ]))
        .unwrap();
    }

    /// `--racks` drives the closed loop through the two-level game on
    /// both backends (sequential plays `refine_hierarchical`, the
    /// distributed backend runs the phased RackBus protocol).
    #[test]
    fn dynamic_small_closed_loop_hierarchical() {
        for backend in ["sequential", "distributed"] {
            run(&parse(&[
                "dynamic",
                "--scenario",
                "hotspot",
                "--nodes",
                "90",
                "--threads",
                "40",
                "--horizon",
                "600",
                "--epoch-ticks",
                "150",
                "--seed",
                "6",
                "--k",
                "4",
                "--racks",
                "0,0,1,1",
                "--backend",
                backend,
            ]))
            .unwrap();
        }
    }

    #[test]
    fn dynamic_rejects_bad_scenario() {
        assert!(run(&parse(&["dynamic", "--scenario", "bogus"])).is_err());
    }

    #[test]
    fn dynamic_rejects_bad_rack_maps() {
        // Wrong machine count.
        assert!(run(&parse(&["dynamic", "--k", "3", "--racks", "0,1"])).is_err());
        // Sparse rack numbering.
        assert!(run(&parse(&["dynamic", "--k", "3", "--racks", "0,0,2"])).is_err());
        // Unparseable entry.
        assert!(run(&parse(&["dynamic", "--k", "3", "--racks", "0,x,1"])).is_err());
    }

    #[test]
    fn dynamic_rejects_bad_transport_combinations() {
        assert!(run(&parse(&["dynamic", "--transport", "carrier-pigeon"])).is_err());
        // tcp needs a peers list...
        assert!(run(&parse(&["dynamic", "--transport", "tcp"])).is_err());
        // ...a distributed backend...
        assert!(run(&parse(&[
            "dynamic",
            "--transport",
            "tcp",
            "--backend",
            "sequential",
            "--peers",
            "127.0.0.1:1,127.0.0.1:2",
        ]))
        .is_err());
        // ...no --compare, and K matching the peer count.
        assert!(run(&parse(&[
            "dynamic",
            "--transport",
            "tcp",
            "--peers",
            "127.0.0.1:1,127.0.0.1:2",
            "--compare",
        ]))
        .is_err());
        assert!(run(&parse(&[
            "dynamic",
            "--transport",
            "tcp",
            "--peers",
            "127.0.0.1:1,127.0.0.1:2",
            "--k",
            "3",
        ]))
        .is_err());
    }

    #[test]
    fn dynamic_report_json_written_with_overhead() {
        let path = std::env::temp_dir().join(format!("gtip_report_{}.json", std::process::id()));
        let path_s = path.to_string_lossy().to_string();
        run(&parse(&[
            "dynamic",
            "--scenario",
            "hotspot",
            "--nodes",
            "80",
            "--threads",
            "40",
            "--horizon",
            "600",
            "--epoch-ticks",
            "150",
            "--seed",
            "11",
            "--k",
            "3",
            "--backend",
            "distributed",
            "--report-json",
            &path_s,
        ]))
        .unwrap();
        let doc = parse_json(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let dynamic = doc.get("dynamic").expect("dynamic group");
        assert!(dynamic.get("assignment").and_then(|a| a.as_arr()).is_some());
        assert!(dynamic.get("overhead").and_then(|o| o.get("total_bytes")).is_some());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn serve_validates_its_arguments() {
        assert!(run(&parse(&["serve"])).is_err());
        assert!(run(&parse(&["serve", "--machine-id", "1"])).is_err());
        // Machine 0 is the driver's seat.
        assert!(run(&parse(&[
            "serve",
            "--machine-id",
            "0",
            "--peers",
            "127.0.0.1:1,127.0.0.1:2",
        ]))
        .is_err());
        // Out-of-range id.
        assert!(run(&parse(&[
            "serve",
            "--machine-id",
            "7",
            "--peers",
            "127.0.0.1:1,127.0.0.1:2",
        ]))
        .is_err());
        // Join-only flags require --join.
        assert!(run(&parse(&[
            "serve",
            "--machine-id",
            "1",
            "--peers",
            "127.0.0.1:1,127.0.0.1:2",
            "--speed",
            "2.0",
        ]))
        .is_err());
        // A joiner's speed must be a positive weight.
        assert!(run(&parse(&[
            "serve",
            "--machine-id",
            "1",
            "--peers",
            "127.0.0.1:1,127.0.0.1:2",
            "--join",
            "--speed",
            "0",
        ]))
        .is_err());
        // Machine 0 cannot join its own cluster either.
        assert!(run(&parse(&[
            "serve",
            "--machine-id",
            "0",
            "--peers",
            "127.0.0.1:1,127.0.0.1:2",
            "--join",
        ]))
        .is_err());
    }

    #[test]
    fn bench_gate_passes_and_fails_by_schema() {
        let dir = std::env::temp_dir().join(format!("gtip_gate_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let baseline = dir.join("baseline.json");
        let measured = dir.join("measured.json");
        std::fs::write(&baseline, r#"{"simulator": {"headline": {"ticks": null}}}"#).unwrap();
        std::fs::write(&measured, r#"{"simulator": {"headline": {"ticks": 9, "extra": 1}}}"#)
            .unwrap();
        run(&parse(&[
            "bench-gate",
            "--baseline",
            baseline.to_str().unwrap(),
            "--measured",
            measured.to_str().unwrap(),
        ]))
        .unwrap();
        // Drop a required key => schema regression.
        std::fs::write(&measured, r#"{"simulator": {"other": 1}}"#).unwrap();
        assert!(run(&parse(&[
            "bench-gate",
            "--baseline",
            baseline.to_str().unwrap(),
            "--measured",
            measured.to_str().unwrap(),
        ]))
        .is_err());
        // Missing measured file is also a failure.
        assert!(run(&parse(&[
            "bench-gate",
            "--baseline",
            baseline.to_str().unwrap(),
            "--measured",
            dir.join("nope.json").to_str().unwrap(),
        ]))
        .is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dynamic_rejects_compare_with_report_json() {
        assert!(run(&parse(&["dynamic", "--compare", "--report-json", "/tmp/x.json"])).is_err());
    }

    #[test]
    fn dynamic_rejects_degenerate_workloads() {
        assert!(run(&parse(&["dynamic", "--threads", "0"])).is_err());
        assert!(run(&parse(&["dynamic", "--threads", "100001"])).is_err());
        assert!(run(&parse(&["dynamic", "--horizon", "0"])).is_err());
        assert!(run(&parse(&["dynamic", "--nodes", "0"])).is_err());
    }

    /// The full checkpoint pipeline through the CLI: a run with
    /// `--checkpoint-dir` emits epoch snapshots, `snapshot --inspect`
    /// verifies one (including its byte-identical re-encode), and a
    /// `--restore` run resumes it to completion with a report whose
    /// json carries the recovery/fleet fields.
    #[test]
    fn checkpoint_inspect_restore_round_trips() {
        let dir = std::env::temp_dir().join(format!("gtip_cli_ckpt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let dir_s = dir.to_string_lossy().to_string();
        run(&parse(&[
            "dynamic",
            "--scenario",
            "hotspot",
            "--nodes",
            "80",
            "--threads",
            "40",
            "--horizon",
            "600",
            "--epoch-ticks",
            "150",
            "--seed",
            "12",
            "--k",
            "3",
            "--checkpoint-dir",
            &dir_s,
        ]))
        .unwrap();
        let first = dir.join("epoch-0000.snap");
        assert!(first.exists(), "--checkpoint-dir must emit epoch snapshots");
        run(&parse(&["snapshot", "--inspect", first.to_str().unwrap()])).unwrap();

        let report = std::env::temp_dir().join(format!("gtip_cli_restore_{}.json", std::process::id()));
        let report_s = report.to_string_lossy().to_string();
        run(&parse(&[
            "dynamic",
            "--restore",
            first.to_str().unwrap(),
            "--epoch-ticks",
            "150",
            "--report-json",
            &report_s,
        ]))
        .unwrap();
        let doc = parse_json(&std::fs::read_to_string(&report).unwrap()).unwrap();
        let dynamic = doc.get("dynamic").expect("dynamic group");
        assert_eq!(dynamic.get("recoveries").and_then(|v| v.as_u64()), Some(0));
        assert_eq!(dynamic.get("admissions").and_then(|v| v.as_u64()), Some(0));
        assert_eq!(dynamic.get("machines").and_then(|v| v.as_u64()), Some(3));
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_file(&report);
    }

    #[test]
    fn snapshot_command_validates_usage() {
        // --inspect is required, and the file must exist and decode.
        assert!(run(&parse(&["snapshot"])).is_err());
        assert!(run(&parse(&["snapshot", "--inspect", "/nonexistent/gtip.snap"])).is_err());
    }

    #[test]
    fn dynamic_rejects_restore_with_compare() {
        assert!(run(&parse(&["dynamic", "--restore", "/tmp/x.snap", "--compare"])).is_err());
    }

    #[test]
    fn fuzz_tiny_campaign_then_replay_round_trips() {
        let dir = std::env::temp_dir().join(format!("gtip_cli_fuzz_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let dir_s = dir.to_string_lossy().to_string();
        run(&parse(&[
            "fuzz",
            "--budget",
            "5",
            "--nodes",
            "40",
            "--k",
            "3",
            "--threads",
            "24",
            "--horizon",
            "400",
            "--top",
            "1",
            "--no-shrink",
            "--no-oracle",
            "--seed",
            "9",
            "--corpus-dir",
            &dir_s,
        ]))
        .unwrap();
        // Replay the schedule the campaign just persisted; the stored
        // objectives must reproduce byte-for-byte.
        let entry = std::fs::read_dir(&dir)
            .expect("campaign wrote no corpus dir")
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .find(|p| p.extension().is_some_and(|x| x == "json"))
            .expect("campaign wrote no corpus file");
        run(&parse(&["fuzz", "--replay", entry.to_str().unwrap(), "--no-oracle"])).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dynamic_accepts_migration_charge_flags() {
        run(&parse(&[
            "dynamic",
            "--scenario",
            "hotspot",
            "--nodes",
            "80",
            "--threads",
            "40",
            "--horizon",
            "600",
            "--epoch-ticks",
            "150",
            "--seed",
            "19",
            "--k",
            "3",
            "--ticks-per-transfer",
            "3",
            "--migration-charge",
            "2.5",
        ]))
        .unwrap();
        assert!(run(&parse(&["dynamic", "--migration-charge", "-1"])).is_err());
        assert!(run(&parse(&["dynamic", "--migration-charge", "nan"])).is_err());
        assert!(run(&parse(&["dynamic", "--tick-value", "-2"])).is_err());
    }

    #[test]
    fn churn_sweep_writes_tradeoff_group() {
        let dir = std::env::temp_dir().join(format!("gtip_churn_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("BENCH_churn.json");
        let out_s = out.to_string_lossy().to_string();
        run(&parse(&[
            "churn-sweep",
            "--scenarios",
            "hotspot,flash",
            "--nodes",
            "70",
            "--k",
            "3",
            "--threads",
            "40",
            "--horizon",
            "600",
            "--epoch-ticks",
            "150",
            "--charges",
            "0,8,1000000000000",
            "--seed",
            "21",
            "--out",
            &out_s,
        ]))
        .unwrap();
        let doc = parse_json(&std::fs::read_to_string(&out).unwrap()).unwrap();
        let group = doc.get("churn_tradeoff").expect("churn_tradeoff group");
        for scenario in ["hotspot", "flash"] {
            let s = group.get(scenario).unwrap_or_else(|| panic!("missing {scenario}"));
            for charge in ["charge_0", "charge_8", "charge_1000000000000"] {
                let row = s.get(charge).unwrap_or_else(|| panic!("{scenario}: missing {charge}"));
                assert!(row.get("transfers").and_then(JsonVal::as_u64).is_some());
                assert!(row.get("speedup").and_then(JsonVal::as_f64).is_some());
                assert!(row.get("migration_ticks").and_then(JsonVal::as_u64).is_some());
                assert!(row.get("frozen_ticks").and_then(JsonVal::as_u64).is_some());
                assert!(row.get("rebalanced_ticks").and_then(JsonVal::as_u64).is_some());
                assert_eq!(
                    row.get("truncated").and_then(JsonVal::as_bool),
                    Some(false),
                    "{scenario}/{charge}: small fixture must drain un-truncated"
                );
            }
            // Only the provable endpoint claim: a 1e12-tick charge is
            // orders of magnitude above any raw gain measured weights
            // can produce (loads ~1e3-1e4, b/w ~1e3 => gains ~1e7), so
            // the top rung freezes the balancer entirely (middle rungs
            // are data, not a theorem — the sweep records the
            // monotonicity verdict instead of asserting it).
            let top = s
                .get("charge_1000000000000")
                .and_then(|r| r.get("transfers"))
                .and_then(JsonVal::as_u64)
                .expect("top-rung transfers");
            assert_eq!(top, 0, "{scenario}: prohibitive charge must freeze the balancer");
            assert!(s.get("transfers_strictly_decreasing").and_then(JsonVal::as_bool).is_some());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The hierarchy bench runs the two-level game over several graph
    /// sizes and merges a `hierarchy` group whose per-N rows carry the
    /// cross-rack overhead counters; the headline flatness verdict
    /// (every RackUpdate exactly 33 + 8R framed bytes, N-independent)
    /// must hold or the command itself fails.
    #[test]
    fn hierarchy_bench_writes_group_with_flat_rack_bytes() {
        let dir = std::env::temp_dir().join(format!("gtip_hier_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("BENCH_hier.json");
        let out_s = out.to_string_lossy().to_string();
        run(&parse(&[
            "hierarchy-bench",
            "--sizes",
            "40,80",
            "--k",
            "6",
            "--racks",
            "0,0,1,1,2,2",
            "--seed",
            "7",
            "--out",
            &out_s,
        ]))
        .unwrap();
        let doc = parse_json(&std::fs::read_to_string(&out).unwrap()).unwrap();
        let group = doc.get("hierarchy").expect("hierarchy group");
        assert_eq!(group.get("racks").and_then(JsonVal::as_u64), Some(3));
        assert_eq!(
            group.get("rack_update_bytes_flat_across_n").and_then(JsonVal::as_bool),
            Some(true)
        );
        for n in ["n_40", "n_80"] {
            let row = group.get(n).unwrap_or_else(|| panic!("missing {n}"));
            assert!(row.get("rack_update_messages").and_then(JsonVal::as_u64).is_some());
            // 33 + 8*3 = 57 framed bytes per cross-rack aggregate.
            assert_eq!(
                row.get("rack_update_bytes_per_message").and_then(JsonVal::as_f64),
                Some(57.0),
                "{n}: RackUpdate must cost 33 + 8R bytes"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn hierarchy_bench_rejects_degenerate_options() {
        assert!(run(&parse(&["hierarchy-bench", "--sizes", ""])).is_err());
        assert!(run(&parse(&["hierarchy-bench", "--k", "0"])).is_err());
        // Rack map must cover the fleet.
        assert!(run(&parse(&["hierarchy-bench", "--k", "4", "--racks", "0,1"])).is_err());
    }

    #[test]
    fn churn_sweep_rejects_degenerate_options() {
        assert!(run(&parse(&["churn-sweep", "--charges", "4,4"])).is_err());
        assert!(run(&parse(&["churn-sweep", "--charges", "8,2"])).is_err());
        assert!(run(&parse(&["churn-sweep", "--scenarios", "bogus"])).is_err());
        assert!(run(&parse(&["churn-sweep", "--nodes", "0"])).is_err());
    }

    #[test]
    fn fuzz_rejects_degenerate_options() {
        assert!(run(&parse(&["fuzz", "--budget", "0"])).is_err());
        assert!(run(&parse(&["fuzz", "--nodes", "0"])).is_err());
        assert!(run(&parse(&["fuzz", "--replay", "/nonexistent/corpus.json"])).is_err());
    }

    #[test]
    fn experiment_requires_name() {
        assert!(run(&parse(&["experiment"])).is_err());
        assert!(run(&parse(&["experiment", "bogus"])).is_err());
    }
}

//! `gtip` command-line interface.
//!
//! ```text
//! gtip partition  [--family pa|geo|er|table1] [--nodes N] [--k K | --speeds s1,s2,...]
//!                 [--mu MU] [--framework A|B] [--seed S] [--graph FILE]
//!                 [--distributed] [--anneal] [--save FILE]
//! gtip simulate   [--family ...] [--nodes N] [--k K] [--refine-every T]
//!                 [--framework A|B] [--mu MU] [--threads N] [--seed S]
//! gtip dynamic    [--scenario hotspot|flash|diurnal|failure] [--nodes N] [--k K]
//!                 [--epoch-ticks E] [--estimator instant|ewma|hysteresis]
//!                 [--backend sequential|distributed] [--framework A|B]
//!                 [--threads N] [--horizon T] [--seed S] [--compare]
//! gtip fuzz       [--budget N] [--seed S] [--nodes N] [--k K] [--horizon T]
//!                 [--threads N] [--epoch-ticks E] [--framework A|B] [--top K]
//!                 [--speed-seed S] [--inter-delay D] [--intra-delay D]
//!                 [--corpus-dir DIR] [--replay FILE] [--no-shrink] [--no-oracle]
//! gtip experiment table1|batch|fig7|fig8|fig9|fig10|ablation|all [--seed S] [--quick]
//! gtip artifacts  [--dir DIR]         # verify PJRT artifacts vs native
//! gtip help
//! ```
//!
//! Errors are plain `Box<dyn Error>` (`anyhow` is unavailable offline);
//! every sub-error type converts via `?`.

use std::sync::Arc;
use std::time::Duration;

use crate::config::Config;
use crate::coordinator::net::{self, ClusterLeader};
use crate::coordinator::{run_distributed, run_distributed_hierarchical, DistributedOptions};
use crate::game::annealing::{anneal_then_refine, AnnealOptions};
use crate::game::cost::Framework;
use crate::game::hierarchy::RackLayout;
use crate::game::refine::{RefineEngine, RefineOptions};
use crate::graph::generators::{generate, GraphFamily};
use crate::partition::initial::grow_partition;
use crate::partition::{global_cost, MachineConfig};
use crate::sim::driver::{run_dynamic, DriverOptions};
use crate::sim::dynamic::{
    compare_frozen_vs_rebalanced, CompareReport, DynamicDriver, DynamicOptions, EstimatorKind,
    RefineBackend, WeightEstimator,
};
use crate::sim::engine::SimOptions;
use crate::sim::fuzz::{
    run_fuzz, save_corpus, EvalOptions, FuzzCase, FuzzFixture, FuzzOptions,
};
use crate::sim::scenario::{Scenario, ScenarioKind, ScenarioOptions, MAX_SCHEDULE_THREADS};
use crate::sim::workload::{FloodWorkload, WorkloadOptions};
use crate::util::bench::{parse_json, write_json_group, JsonVal};
use crate::util::cli::Args;
use crate::util::rng::Pcg32;

/// CLI-level result: any error type boxes into it via `?`.
type CliResult = Result<(), Box<dyn std::error::Error>>;

const HELP: &str = "gtip — Game Theoretic Iterative Partitioning (Kurve et al., TOMACS 2011)

USAGE:
  gtip partition  [--family pa|geo|er|table1] [--nodes N] [--k K] [--speeds s1,..]
                  [--mu MU] [--framework A|B] [--seed S] [--graph FILE]
                  [--distributed] [--anneal] [--save FILE]
  gtip simulate   [--family ...] [--nodes N] [--k K] [--refine-every T]
                  [--framework A|B] [--mu MU] [--threads N] [--seed S]
                  [--parallelism P]
  gtip dynamic    [--scenario hotspot|flash|diurnal|failure] [--nodes N] [--k K]
                  [--epoch-ticks E] [--estimator instant|ewma|hysteresis]
                  [--backend sequential|distributed] [--framework A|B]
                  [--threads N] [--horizon T] [--ticks-per-transfer C]
                  [--tick-value V] [--migration-charge CMIG]
                  [--seed S] [--compare] [--parallelism P]
                  [--transport inproc|tcp] [--peers host:port,...]
                  [--connect-timeout-ms MS] [--recv-timeout-ms MS]
                  [--admit-window-ms MS] [--report-json FILE]
                  [--checkpoint-dir DIR] [--restore FILE]
                  [--racks r0,r1,...]   # rack of each machine (two-level game)
  gtip churn-sweep [--scenarios hotspot,flash] [--nodes N] [--k K] [--threads N]
                  [--horizon T] [--epoch-ticks E] [--framework A|B] [--seed S]
                  [--charges 0,2,8,32] [--tick-value V] [--out FILE]
  gtip hierarchy-bench [--sizes 120,240,360] [--k K] [--racks r0,r1,...]
                  [--seed S] [--framework A|B] [--mu MU] [--out FILE]
  gtip serve      --machine-id K --peers host:port,host:port,...
                  [--connect-timeout-ms MS] [--checkpoint-dir DIR]
                  [--join] [--speed S] [--rack R] [--admit-window-ms MS]
  gtip snapshot   --inspect FILE      # print a checkpoint's summary + verify round-trip
  gtip fuzz       [--budget N] [--seed S] [--nodes N] [--k K] [--horizon T]
                  [--threads N] [--epoch-ticks E] [--framework A|B] [--top K]
                  [--migration-charge CMIG] [--speed-seed S]
                  [--inter-delay D] [--intra-delay D]
                  [--corpus-dir DIR] [--replay FILE]
                  [--no-shrink] [--no-oracle]
  gtip bench-gate [--baseline FILE] [--measured FILE]
  gtip experiment table1|batch|fig7|fig8|fig9|fig10|ablation|all [--seed S] [--quick]
  gtip artifacts  [--dir DIR]
  gtip help
";

/// Entry point used by `main.rs`; returns the process exit code.
pub fn main() -> i32 {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn run(args: &Args) -> CliResult {
    match args.subcommand() {
        Some("partition") => cmd_partition(args),
        Some("simulate") => cmd_simulate(args),
        Some("dynamic") => cmd_dynamic(args),
        Some("serve") => cmd_serve(args),
        Some("churn-sweep") => cmd_churn_sweep(args),
        Some("hierarchy-bench") => cmd_hierarchy_bench(args),
        Some("snapshot") => cmd_snapshot(args),
        Some("bench-gate") => cmd_bench_gate(args),
        Some("fuzz") => cmd_fuzz(args),
        Some("experiment") => cmd_experiment(args),
        Some("artifacts") => cmd_artifacts(args),
        Some("help") | None => {
            print!("{HELP}");
            Ok(())
        }
        Some(other) => Err(format!("unknown subcommand {other:?}\n{HELP}").into()),
    }
}

fn machines_from_args(args: &Args) -> Result<MachineConfig, Box<dyn std::error::Error>> {
    if let Some(speeds) = args.opt_list::<f64>("speeds")? {
        Ok(MachineConfig::from_speeds(&speeds))
    } else {
        let k = args.opt_or::<usize>("k", 5)?;
        Ok(MachineConfig::homogeneous(k))
    }
}

fn cmd_partition(args: &Args) -> CliResult {
    let seed = args.opt_or::<u64>("seed", Config::default().seed)?;
    let mu = args.opt_or::<f64>("mu", 8.0)?;
    let framework: Framework = args.str_or("framework", "A").parse()?;
    let machines = machines_from_args(args)?;
    let mut rng = Pcg32::new(seed);

    let graph = if let Some(path) = args.opt_str("graph") {
        crate::graph::io::load_graph(path)?
    } else {
        let family: GraphFamily = args.str_or("family", "table1").parse()?;
        let nodes = args.opt_or::<usize>("nodes", 230)?;
        generate(family, nodes, &mut rng)
    };

    println!(
        "graph: {} nodes, {} edges; K={} machines; mu={mu}; framework {framework}",
        graph.node_count(),
        graph.edge_count(),
        machines.count()
    );
    let initial = grow_partition(&graph, &machines, &mut rng);
    let (c0_i, c0t_i) = global_cost::both(&graph, &machines, &initial, mu);
    println!("initial partition:   C0 = {c0_i:.0}   C~0 = {c0t_i:.0}   counts = {:?}", initial.counts());

    if args.flag("distributed") {
        let report = run_distributed(
            Arc::new(graph.clone()),
            &machines,
            initial,
            &DistributedOptions { mu, framework, ..Default::default() },
        );
        let (c0, c0t) = global_cost::both(&graph, &machines, &report.partition, mu);
        println!(
            "distributed refine:  C0 = {c0:.0}   C~0 = {c0t:.0}   transfers = {}   counts = {:?}",
            report.transfers,
            report.partition.counts()
        );
        println!(
            "sync overhead: {} msgs, {} bytes total, {:.1} bytes/transfer (O(K), N-independent)",
            report.overhead.total_messages(),
            report.overhead.total_bytes(),
            report.overhead.bytes_per_transfer(report.transfers as u64),
        );
    } else if args.flag("anneal") {
        let (part, potential) = anneal_then_refine(
            &graph,
            &machines,
            initial,
            mu,
            framework,
            &AnnealOptions::default(),
            &mut rng,
        );
        let (c0, c0t) = global_cost::both(&graph, &machines, &part, mu);
        println!(
            "anneal+refine:       C0 = {c0:.0}   C~0 = {c0t:.0}   potential = {potential:.0}   counts = {:?}",
            part.counts()
        );
    } else {
        let mut engine = RefineEngine::new(&graph, &machines, initial, mu, framework);
        let report = engine.run(&RefineOptions::default());
        let (c0, c0t) = global_cost::both(&graph, &machines, engine.partition(), mu);
        println!(
            "iterative refine:    C0 = {c0:.0}   C~0 = {c0t:.0}   transfers = {}   converged = {}   counts = {:?}",
            report.transfers,
            report.converged,
            engine.partition().counts()
        );
    }

    if let Some(path) = args.opt_str("save") {
        crate::graph::io::save_graph(&graph, path)?;
        println!("(saved graph to {path})");
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> CliResult {
    let seed = args.opt_or::<u64>("seed", 42)?;
    let family: GraphFamily = args.str_or("family", "pa").parse()?;
    let nodes = args.opt_or::<usize>("nodes", 230)?;
    let machines = machines_from_args(args)?;
    let refine_every = args.opt_or::<u64>("refine-every", 500)?;
    let framework: Framework = args.str_or("framework", "A").parse()?;
    let mu = args.opt_or::<f64>("mu", 8.0)?;
    let threads = args.opt_or::<usize>("threads", 150)?;
    let parallelism = args.opt_or::<usize>("parallelism", 1)?;

    let mut rng = Pcg32::new(seed);
    let graph = generate(family, nodes, &mut rng);
    let workload = FloodWorkload::generate(
        &graph,
        &WorkloadOptions { threads, ..Default::default() },
        &mut rng,
    );
    let driver = DriverOptions {
        sim: SimOptions { trace_every: 50, parallelism, ..Default::default() },
        refine_every,
        framework,
        mu,
        ticks_per_transfer: 0,
    };
    let report = run_dynamic(&graph, &machines, workload, &driver, &mut rng);
    println!(
        "simulation time: {} wall ticks  (events {}, forwards {}, cross-machine {}, rollbacks {}, anti-messages {})",
        report.total_time(),
        report.stats.events_processed,
        report.stats.events_forwarded,
        report.stats.cross_machine_forwards,
        report.stats.rollbacks,
        report.stats.antimessages_sent,
    );
    println!(
        "refinement epochs: {}   node transfers: {}   truncated: {}",
        report.refinements, report.transfers, report.stats.truncated
    );
    Ok(())
}

/// The closed-loop §6.1 title scenario: scripted drifting workload,
/// epoch-windowed load measurement, estimator-smoothed re-weighting,
/// warm-started refinement, live migration, per-epoch reporting.
fn cmd_dynamic(args: &Args) -> CliResult {
    let seed = args.opt_or::<u64>("seed", 2011)?;
    let family: GraphFamily = args.str_or("family", "pa").parse()?;
    let nodes = args.opt_or::<usize>("nodes", 150)?;
    let machines = machines_from_args(args)?;
    let scenario_kind: ScenarioKind = args.str_or("scenario", "hotspot").parse()?;
    let epoch_ticks = args.opt_or::<u64>("epoch-ticks", 200)?;
    let framework: Framework = args.str_or("framework", "A").parse()?;
    let mu = args.opt_or::<f64>("mu", 8.0)?;
    let estimator_kind: EstimatorKind = args.str_or("estimator", "ewma").parse()?;
    let backend: RefineBackend = args.str_or("backend", "sequential").parse()?;
    let threads = args.opt_or::<usize>("threads", 160)?;
    let horizon = args.opt_or::<u64>("horizon", 2_400)?;
    let ticks_per_transfer = args.opt_or::<u64>("ticks-per-transfer", 0)?;
    // In-game surcharge: explicit --migration-charge wins; otherwise it
    // derives as ticks_per_transfer x tick_value so the game prices
    // exactly what the report bills (DESIGN.md §9).
    let tick_value = args.opt_or::<f64>("tick-value", 1.0)?;
    if !(tick_value >= 0.0 && tick_value.is_finite()) {
        return Err("--tick-value must be finite and >= 0".into());
    }
    let migration_charge = match args.opt::<f64>("migration-charge")? {
        Some(c) => c,
        None => ticks_per_transfer as f64 * tick_value,
    };
    if !(migration_charge >= 0.0 && migration_charge.is_finite()) {
        return Err("--migration-charge must be finite and >= 0".into());
    }
    let parallelism = args.opt_or::<usize>("parallelism", 1)?;
    let transport = args.str_or("transport", "inproc").to_string();
    let connect_timeout = Duration::from_millis(args.opt_or::<u64>("connect-timeout-ms", 30_000)?);
    // How long the cluster waits on a silent peer before declaring it
    // dead (rides Setup, so workers use it too). The 30s default is
    // safe for congested CI; kill-a-worker tests dial it down so death
    // diagnosis is quick.
    let recv_timeout = Duration::from_millis(args.opt_or::<u64>("recv-timeout-ms", 30_000)?.max(1));
    // Patience of the admission handshake's ack barrier (leader side).
    // Defaults to 2× recv_timeout inside ClusterLeader; only override
    // when a test needs the rollback path to trip quickly.
    let admit_window = args.opt::<u64>("admit-window-ms")?.map(Duration::from_millis);
    let tcp = match transport.as_str() {
        "inproc" | "in-process" | "local" => false,
        "tcp" => true,
        other => return Err(format!("unknown transport {other:?} (expected inproc|tcp)").into()),
    };
    let backend = if tcp {
        if args.flag("compare") {
            return Err("--compare runs two arms and is not supported with --transport tcp".into());
        }
        if backend != RefineBackend::Distributed && args.opt_str("backend").is_some() {
            return Err("--transport tcp requires --backend distributed".into());
        }
        RefineBackend::Distributed
    } else {
        backend
    };
    if nodes == 0 {
        return Err("--nodes must be >= 1".into());
    }
    if threads == 0 {
        return Err("--threads must be >= 1".into());
    }
    if threads as u64 > MAX_SCHEDULE_THREADS {
        return Err(format!("--threads must be <= {MAX_SCHEDULE_THREADS}").into());
    }
    if horizon == 0 {
        return Err("--horizon must be >= 1".into());
    }
    let checkpoint_dir = args.opt_str("checkpoint-dir").map(std::path::PathBuf::from);
    // Two-level hierarchy (DESIGN.md §12): `--racks "0,0,1,1"` names the
    // rack of each machine. Validated against the fleet the run starts
    // with — on `--restore` that is the snapshot's K, not `--k`.
    let racks = match args.opt_str("racks") {
        Some(spec) => {
            let k = match args.opt_str("restore") {
                Some(path) => {
                    crate::sim::Snapshot::read_from(std::path::Path::new(path))?.machine_count()
                }
                None => machines.count(),
            };
            Some(crate::game::hierarchy::RackLayout::parse(spec, k)?)
        }
        None => None,
    };

    let options = DynamicOptions {
        sim: SimOptions { trace_every: 50, parallelism, ..Default::default() },
        epoch_ticks,
        framework,
        mu,
        backend,
        ticks_per_transfer,
        migration_charge,
        max_refinements: 0,
        checkpoint_dir,
        racks,
    };

    // Resume from an epoch-boundary checkpoint instead of generating a
    // fixture: topology, fleet, pending events, estimator memory and
    // cumulative counters all come from the file (DESIGN.md §10).
    if let Some(path) = args.opt_str("restore") {
        if args.flag("compare") {
            return Err("--restore resumes one arm; it cannot be combined with --compare".into());
        }
        let snap = crate::sim::Snapshot::read_from(std::path::Path::new(path))?;
        let graph = snap.build_graph();
        println!(
            "restore {path}: {} LPs, K={}, epoch {}, {} ticks simulated",
            graph.node_count(),
            snap.machine_count(),
            snap.epoch,
            snap.engine.stats.ticks,
        );
        let estimator = WeightEstimator::of_kind(estimator_kind);
        let mut driver = DynamicDriver::from_snapshot(&graph, &snap, estimator, options);
        if tcp {
            let peers = net::parse_peers(args.req_str("peers")?)?;
            if peers.len() != snap.machine_count() {
                return Err(format!(
                    "--peers lists {} machines but the snapshot has K={}",
                    peers.len(),
                    snap.machine_count()
                )
                .into());
            }
            let mut leader = ClusterLeader::connect(
                &peers,
                DistributedOptions {
                    mu,
                    framework,
                    migration_charge,
                    recv_timeout,
                    ..Default::default()
                },
                connect_timeout,
            )?;
            if let Some(w) = admit_window {
                leader.set_admit_window(w);
            }
            driver.attach_cluster(leader)?;
        }
        let report = driver.try_run()?;
        let title = format!("gtip dynamic — restored from {path}");
        println!("{}", report.epoch_table(&title).to_text());
        println!(
            "total: {} wall ticks  (events {}, rollbacks {}, {} refinements, {} transfers, truncated {})",
            report.total_time(),
            report.stats.events_processed,
            report.stats.rollbacks,
            report.refinements(),
            report.transfers,
            report.stats.truncated,
        );
        if let Some(out) = args.opt_str("report-json") {
            // Final measured weights, like the live path — so the cost
            // here is directly comparable with the run that wrote the
            // checkpoint (net-smoke's recovery gate relies on this).
            let json = dynamic_report_json(
                &report,
                driver.engine().partition().assignment(),
                driver.weighted_graph(),
                driver.machines(),
                mu,
            );
            if let Some(dir) = std::path::Path::new(out).parent() {
                if !dir.as_os_str().is_empty() {
                    std::fs::create_dir_all(dir)?;
                }
            }
            std::fs::write(out, json.sorted().render() + "\n")?;
            println!("(wrote {out})");
        }
        return Ok(());
    }

    let mut rng = Pcg32::new(seed);
    let graph = generate(family, nodes, &mut rng);
    let scenario = Scenario::build(
        scenario_kind,
        &graph,
        &ScenarioOptions { threads, horizon_ticks: horizon, ..Default::default() },
        &mut rng,
    );
    println!(
        "scenario {scenario_kind} ({}): {} LPs, {} edges, K={}, {} floods over {horizon} ticks",
        scenario_kind.describe(),
        graph.node_count(),
        graph.edge_count(),
        machines.count(),
        scenario.len(),
    );
    println!(
        "loop: epoch={epoch_ticks} ticks, estimator {estimator_kind}, backend {backend}, framework {framework}, mu={mu}, c_mig={migration_charge}"
    );
    if let Some(l) = &options.racks {
        println!(
            "hierarchy: two-level game, {} racks over K={} machines",
            l.rack_count(),
            l.machine_count()
        );
    }

    let initial = grow_partition(&graph, &machines, &mut rng);
    let estimator = WeightEstimator::of_kind(estimator_kind);

    if args.flag("compare") {
        if args.opt_str("report-json").is_some() {
            return Err("--report-json only supports single-arm runs (drop --compare)".into());
        }
        let report = compare_frozen_vs_rebalanced(
            &graph,
            &machines,
            &initial,
            &scenario.injections,
            estimator,
            &options,
        );
        let title = format!("gtip dynamic — {scenario_kind} (rebalanced arm)");
        println!("{}", report.rebalanced.epoch_table(&title).to_text());
        println!(
            "frozen     : {:>7} wall ticks  (rollbacks {:>6}, cross-machine {:>6})",
            report.frozen.total_time(),
            report.frozen.stats.rollbacks,
            report.frozen.stats.cross_machine_forwards,
        );
        println!(
            "rebalanced : {:>7} wall ticks  (rollbacks {:>6}, cross-machine {:>6}, {} refinements, {} transfers)",
            report.rebalanced.total_time(),
            report.rebalanced.stats.rollbacks,
            report.rebalanced.stats.cross_machine_forwards,
            report.rebalanced.refinements(),
            report.rebalanced.transfers,
        );
        println!("speedup from closed-loop rebalancing: {:.2}x", report.speedup());
    } else {
        let mut driver = DynamicDriver::new(
            &graph,
            machines.clone(),
            initial,
            scenario.injections,
            estimator,
            options,
        );
        if tcp {
            let peers = net::parse_peers(args.req_str("peers")?)?;
            if peers.len() != machines.count() {
                return Err(format!(
                    "--peers lists {} machines but K={} (peer 0 is this driver)",
                    peers.len(),
                    machines.count()
                )
                .into());
            }
            println!(
                "transport tcp: leading a {}-process cluster (this process = machine 0 @ {})",
                peers.len(),
                peers[0]
            );
            let mut leader = ClusterLeader::connect(
                &peers,
                DistributedOptions {
                    mu,
                    framework,
                    migration_charge,
                    recv_timeout,
                    ..Default::default()
                },
                connect_timeout,
            )?;
            if let Some(w) = admit_window {
                leader.set_admit_window(w);
            }
            driver.attach_cluster(leader)?;
        }
        let report = driver.try_run()?;
        let title = format!("gtip dynamic — {scenario_kind}");
        println!("{}", report.epoch_table(&title).to_text());
        println!(
            "total: {} wall ticks  (events {}, rollbacks {}, {} refinements, {} transfers, truncated {})",
            report.total_time(),
            report.stats.events_processed,
            report.stats.rollbacks,
            report.refinements(),
            report.transfers,
            report.stats.truncated,
        );
        if let Some(o) = report.total_overhead() {
            println!(
                "coordinator sync: {} msgs, {} bytes on the wire, {:.1} bytes/transfer, {:.1} bytes/RegularUpdate (O(K), N-independent)",
                o.total_messages(),
                o.total_bytes(),
                o.bytes_per_transfer(report.transfers as u64),
                o.bytes_per_regular_update(),
            );
            if o.rack_update.messages > 0 {
                println!(
                    "cross-rack sync: {} RackUpdate msgs, {} bytes, {:.1} bytes/RackUpdate (O(R), K- and N-independent)",
                    o.rack_update.messages,
                    o.rack_update.bytes,
                    o.bytes_per_rack_update(),
                );
            }
        }
        if report.recoveries() > 0 {
            println!(
                "recovered from {} worker death(s); fleet now K={}",
                report.recoveries(),
                driver.machines().count(),
            );
        }
        if report.admissions() > 0 {
            println!(
                "admitted {} joiner(s); fleet now K={}",
                report.admissions(),
                driver.machines().count(),
            );
        }
        if let Some(path) = args.opt_str("report-json") {
            // `driver.machines()` and `driver.weighted_graph()`, not
            // the pre-run config: a recovery shrinks the fleet (and an
            // admission grows it), and the final assignment was
            // refined on the final measured weights — costing it
            // against the stale K or the initial weights would be
            // wrong (and would make the recovered run incomparable
            // with a `--restore recovery-NNNN.snap` replay).
            let json = dynamic_report_json(
                &report,
                driver.engine().partition().assignment(),
                driver.weighted_graph(),
                driver.machines(),
                mu,
            );
            if let Some(dir) = std::path::Path::new(path).parent() {
                if !dir.as_os_str().is_empty() {
                    std::fs::create_dir_all(dir)?;
                }
            }
            std::fs::write(path, json.sorted().render() + "\n")?;
            println!("(wrote {path})");
        }
    }
    Ok(())
}

/// Transport-invariant summary of a closed-loop run: the `net-smoke`
/// CI job byte-compares this JSON between the TCP multi-process run
/// and the in-process run on the same fixture.
fn dynamic_report_json(
    report: &crate::sim::dynamic::DynamicReport,
    final_assignment: &[usize],
    graph: &crate::graph::Graph,
    machines: &MachineConfig,
    mu: f64,
) -> JsonVal {
    let part = crate::partition::Partition::from_assignment(
        graph,
        machines.count(),
        final_assignment.to_vec(),
    );
    let (c0, c0t) = global_cost::both(graph, machines, &part, mu);
    let mut fields = vec![
        (
            "assignment".into(),
            JsonVal::Arr(final_assignment.iter().map(|&m| JsonVal::Int(m as u64)).collect()),
        ),
        ("global_cost_c0".into(), JsonVal::Num(c0)),
        ("global_cost_c0_tilde".into(), JsonVal::Num(c0t)),
        ("ticks".into(), JsonVal::Int(report.stats.ticks)),
        ("events_processed".into(), JsonVal::Int(report.stats.events_processed)),
        ("rollbacks".into(), JsonVal::Int(report.stats.rollbacks)),
        ("transfers".into(), JsonVal::Int(report.transfers as u64)),
        ("refinements".into(), JsonVal::Int(report.refinements() as u64)),
        ("recoveries".into(), JsonVal::Int(report.recoveries() as u64)),
        ("admissions".into(), JsonVal::Int(report.admissions() as u64)),
        ("machines".into(), JsonVal::Int(machines.count() as u64)),
        (
            "racks".into(),
            JsonVal::Int(report.epochs.iter().map(|e| e.racks).max().unwrap_or(0) as u64),
        ),
    ];
    if let Some(o) = report.total_overhead() {
        let counter = |c: &crate::coordinator::protocol::Counter| {
            JsonVal::Obj(vec![
                ("messages".into(), JsonVal::Int(c.messages)),
                ("bytes".into(), JsonVal::Int(c.bytes)),
            ])
        };
        fields.push((
            "overhead".into(),
            JsonVal::Obj(vec![
                ("take_my_turn".into(), counter(&o.take_my_turn)),
                ("receive_node".into(), counter(&o.receive_node)),
                ("regular_update".into(), counter(&o.regular_update)),
                ("rack_update".into(), counter(&o.rack_update)),
                ("shutdown".into(), counter(&o.shutdown)),
                ("total_messages".into(), JsonVal::Int(o.total_messages())),
                ("total_bytes".into(), JsonVal::Int(o.total_bytes())),
                (
                    "sync_bytes_per_transfer".into(),
                    JsonVal::Num(o.bytes_per_transfer(report.transfers as u64)),
                ),
                (
                    "regular_update_bytes_per_message".into(),
                    JsonVal::Num(o.bytes_per_regular_update()),
                ),
                (
                    "rack_update_bytes_per_message".into(),
                    JsonVal::Num(o.bytes_per_rack_update()),
                ),
            ]),
        ));
    }
    JsonVal::Obj(vec![("dynamic".into(), JsonVal::Obj(fields))])
}

/// Inspect an epoch-boundary checkpoint: print its summary and verify
/// the decode→re-encode round trip is byte-identical (the determinism
/// gate DESIGN.md §10 promises for every `.snap` file).
fn cmd_snapshot(args: &Args) -> CliResult {
    let path = args
        .opt_str("inspect")
        .ok_or("usage: gtip snapshot --inspect FILE")?;
    let bytes = std::fs::read(path)?;
    let snap = crate::sim::Snapshot::decode(&bytes)?;
    println!("{}", snap.summary());
    let reencoded = snap.encode();
    if reencoded != bytes {
        return Err(format!(
            "round-trip diverged: {} bytes on disk, {} re-encoded",
            bytes.len(),
            reencoded.len()
        )
        .into());
    }
    println!("round-trip: {} bytes, re-encode byte-identical", bytes.len());
    Ok(())
}

/// Worker side of the multi-process cluster: block until the leader
/// (machine 0, `gtip dynamic --transport tcp`) connects, then play one
/// refinement round per epoch until it says goodbye. With `--join`,
/// instead of waiting for the leader's mesh dial, ask a *live* cluster
/// to re-admit this machine id (DESIGN.md §10): send `Join`, wait out
/// the admission handshake (`--admit-window-ms`), catch up from the
/// leader's boundary snapshot, and serve from there. `--speed` is the
/// joiner's self-reported relative speed (1.0 = an average machine of
/// the original fleet).
fn cmd_serve(args: &Args) -> CliResult {
    let machine_id = args.opt::<usize>("machine-id")?.ok_or("--machine-id is required")?;
    let peers = net::parse_peers(args.req_str("peers")?)?;
    let connect_timeout = Duration::from_millis(args.opt_or::<u64>("connect-timeout-ms", 30_000)?);
    if args.opt_str("checkpoint-dir").is_some() {
        // Accepted so one launch template serves every rank: snapshots
        // are taken leader-side (machine 0 owns the engine), so a
        // worker has nothing to write there.
        println!("note: checkpoints are taken by the leader; --checkpoint-dir is a no-op on serve");
    }
    let summary = if args.flag("join") {
        let speed = args.opt_or::<f64>("speed", 1.0)?;
        if !(speed > 0.0 && speed.is_finite()) {
            return Err("--speed must be finite and > 0".into());
        }
        // Rack the joiner asks to be placed in (hierarchical clusters,
        // DESIGN.md §12). Omitted = leader's choice (least-loaded rack);
        // ignored by flat clusters.
        let rack = args.opt::<usize>("rack")?;
        let admit_window =
            Duration::from_millis(args.opt_or::<u64>("admit-window-ms", 120_000)?.max(1));
        println!(
            "gtip serve: machine {machine_id}/{} joining the live cluster via {} (leader @ {})",
            peers.len(),
            peers.get(machine_id).map(String::as_str).unwrap_or("?"),
            peers[0],
        );
        net::serve_join(machine_id, &peers, speed, rack, connect_timeout, admit_window)?
    } else {
        if args.opt_str("speed").is_some()
            || args.opt_str("admit-window-ms").is_some()
            || args.opt_str("rack").is_some()
        {
            return Err("--speed / --rack / --admit-window-ms only apply with --join".into());
        }
        println!(
            "gtip serve: machine {machine_id}/{} listening on {} (leader @ {})",
            peers.len(),
            peers.get(machine_id).map(String::as_str).unwrap_or("?"),
            peers[0],
        );
        net::serve(machine_id, &peers, connect_timeout)?
    };
    println!(
        "served {} refinement epochs as machine {}: sent {} sync msgs / {} bytes, {} control msgs / {} bytes",
        summary.epochs,
        summary.machine_id,
        summary.overhead.total_messages(),
        summary.overhead.total_bytes(),
        summary.control.control_messages,
        summary.control.control_bytes,
    );
    Ok(())
}

/// Quantify the churn/hysteresis trade-off of migration-cost-aware
/// refinement (DESIGN.md §9): sweep the per-transfer charge over fixed
/// scenario fixtures, run the frozen-vs-rebalanced comparison at each
/// level — the charge is billed as wall ticks AND priced inside the
/// game (`c_mig = ticks · tick_value`) — and merge a `churn_tradeoff`
/// group (transfers, migration ticks, speedup per level) into the
/// machine-readable bench report that `gtip bench-gate` validates.
fn cmd_churn_sweep(args: &Args) -> CliResult {
    let seed = args.opt_or::<u64>("seed", 2011)?;
    let nodes = args.opt_or::<usize>("nodes", 120)?;
    let k = args.opt_or::<usize>("k", 4)?;
    let threads = args.opt_or::<usize>("threads", 100)?;
    let horizon = args.opt_or::<u64>("horizon", 1_600)?;
    let epoch_ticks = args.opt_or::<u64>("epoch-ticks", 200)?;
    let framework: Framework = args.str_or("framework", "A").parse()?;
    let tick_value = args.opt_or::<f64>("tick-value", 1.0)?;
    let out = args.str_or("out", "results/BENCH_sim.json").to_string();
    if nodes == 0 || k == 0 || threads == 0 || horizon == 0 || epoch_ticks == 0 {
        return Err("--nodes, --k, --threads, --horizon, --epoch-ticks must be >= 1".into());
    }
    if threads as u64 > MAX_SCHEDULE_THREADS {
        return Err(format!("--threads must be <= {MAX_SCHEDULE_THREADS}").into());
    }
    if !(tick_value >= 0.0 && tick_value.is_finite()) {
        return Err("--tick-value must be finite and >= 0".into());
    }
    let charges: Vec<u64> =
        args.opt_list::<u64>("charges")?.unwrap_or_else(|| vec![0, 2, 8, 32]);
    if charges.is_empty() {
        return Err("--charges needs at least one level".into());
    }
    if charges.windows(2).any(|w| w[1] <= w[0]) {
        return Err("--charges must be strictly increasing".into());
    }
    let scenario_kinds: Vec<ScenarioKind> = args
        .str_or("scenarios", "hotspot,flash")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| s.parse::<ScenarioKind>())
        .collect::<Result<_, _>>()?;
    if scenario_kinds.is_empty() {
        return Err("--scenarios needs at least one scenario".into());
    }
    for (i, a) in scenario_kinds.iter().enumerate() {
        if scenario_kinds[..i].contains(a) {
            return Err(format!(
                "--scenarios lists {} twice (duplicate JSON keys in the report)",
                a.name()
            )
            .into());
        }
    }

    println!(
        "churn sweep: {} scenario(s), charges {:?} ticks/transfer (tick value {tick_value}), \
         {nodes} LPs, K={k}, {threads} floods over {horizon} ticks, epoch {epoch_ticks}, framework {framework}",
        scenario_kinds.len(),
        charges,
    );
    let mut group: Vec<(String, JsonVal)> = vec![
        ("smoke".into(), JsonVal::Bool(std::env::var("GTIP_BENCH_SMOKE").is_ok())),
        (
            "charges".into(),
            JsonVal::Arr(charges.iter().map(|&c| JsonVal::Int(c)).collect()),
        ),
    ];
    let mut strictly_decreasing_everywhere = 0usize;
    for kind in &scenario_kinds {
        let fixture = crate::util::testkit::ScenarioFixture::new(*kind, seed)
            .nodes(nodes)
            .machines(k)
            .threads(threads)
            .horizon(horizon)
            .build();
        println!("  {:<8} charge | transfers | migration_ticks | frozen | rebalanced | speedup", kind.name());
        // The frozen arm never refines, so it is charge-independent:
        // run it once per scenario and reuse it at every charge level.
        let frozen = DynamicDriver::new(
            &fixture.graph,
            fixture.machines.clone(),
            fixture.initial.clone(),
            fixture.scenario.injections.clone(),
            WeightEstimator::instantaneous(),
            DynamicOptions {
                sim: SimOptions { max_ticks: 2_000_000, ..Default::default() },
                epoch_ticks: 0,
                framework,
                ..Default::default()
            },
        )
        .run_owned();
        let mut rows: Vec<(String, JsonVal)> = Vec::new();
        let mut transfer_curve: Vec<u64> = Vec::new();
        for &charge in &charges {
            let options = DynamicOptions {
                sim: SimOptions { max_ticks: 2_000_000, ..Default::default() },
                epoch_ticks,
                framework,
                ..Default::default()
            }
            .charge_transfers(charge, tick_value);
            let rebalanced = DynamicDriver::new(
                &fixture.graph,
                fixture.machines.clone(),
                fixture.initial.clone(),
                fixture.scenario.injections.clone(),
                WeightEstimator::ewma(0.5),
                options,
            )
            .run_owned();
            let transfers = rebalanced.transfers as u64;
            let truncated = frozen.stats.truncated || rebalanced.stats.truncated;
            let speedup = CompareReport::speedup_of(frozen.total_time(), rebalanced.total_time());
            println!(
                "  {:<8} {:>6} | {:>9} | {:>15} | {:>6} | {:>10} | {:.3}x{}",
                kind.name(),
                charge,
                transfers,
                rebalanced.migration_ticks,
                frozen.total_time(),
                rebalanced.total_time(),
                speedup,
                if truncated { "  [TRUNCATED at the tick cap — numbers understate]" } else { "" },
            );
            transfer_curve.push(transfers);
            rows.push((
                format!("charge_{charge}"),
                JsonVal::Obj(vec![
                    ("transfers".into(), JsonVal::Int(transfers)),
                    ("migration_ticks".into(), JsonVal::Int(rebalanced.migration_ticks)),
                    ("frozen_ticks".into(), JsonVal::Int(frozen.total_time())),
                    ("rebalanced_ticks".into(), JsonVal::Int(rebalanced.total_time())),
                    ("speedup".into(), JsonVal::Num(speedup)),
                    ("truncated".into(), JsonVal::Bool(truncated)),
                ]),
            ));
        }
        // "Strictly decreasing" with two refinements: it needs at least
        // one real comparison (a single-level sweep can't vacuously
        // claim it), and a 0 -> 0 plateau at high charges counts — the
        // balancer is fully damped, which is the behavior the flag
        // exists to demonstrate, not a violation of it.
        let strictly_decreasing = transfer_curve.len() >= 2
            && transfer_curve.windows(2).all(|w| w[1] < w[0] || (w[0] == 0 && w[1] == 0));
        if strictly_decreasing {
            strictly_decreasing_everywhere += 1;
        }
        rows.push((
            "transfers_strictly_decreasing".into(),
            JsonVal::Bool(strictly_decreasing),
        ));
        group.push((kind.name().to_string(), JsonVal::Obj(rows)));
    }
    println!(
        "transfers strictly decreasing with the charge on {strictly_decreasing_everywhere}/{} scenario(s)",
        scenario_kinds.len()
    );
    let path = write_json_group(&out, "churn_tradeoff", &JsonVal::Obj(group))?;
    println!("(merged churn_tradeoff into {})", path.display());
    Ok(())
}

/// Measure the two-level hierarchy's coordination overhead (DESIGN.md
/// §12): run the in-process hierarchical refinement over several graph
/// sizes on a fixed fleet/rack layout and merge a `hierarchy` group
/// into the bench report. The table demonstrates the O(K_rack +
/// K_machine) claim: a cross-rack `RackUpdate` costs exactly `33 + 8R`
/// framed bytes — scaling with the rack count R, not the machine count
/// K, and independent of N — while the inner games' `RegularUpdate`s
/// stay at the flat `33 + 8K`.
fn cmd_hierarchy_bench(args: &Args) -> CliResult {
    let seed = args.opt_or::<u64>("seed", 2011)?;
    let k = args.opt_or::<usize>("k", 9)?;
    let mu = args.opt_or::<f64>("mu", 8.0)?;
    let framework: Framework = args.str_or("framework", "A").parse()?;
    let out = args.str_or("out", "results/BENCH_sim.json").to_string();
    let sizes: Vec<usize> =
        args.opt_list::<usize>("sizes")?.unwrap_or_else(|| vec![120, 240, 360]);
    if sizes.is_empty() || sizes.iter().any(|&n| n == 0) {
        return Err("--sizes needs at least one size, all >= 1".into());
    }
    if k == 0 {
        return Err("--k must be >= 1".into());
    }
    // Default: K=9 over R=3 equal racks. A 2-rack outer ring never
    // broadcasts a RackUpdate (a transfer notifies only its
    // counterpart, via ReceiveNode), so the measurable default keeps
    // R >= 3.
    let layout = match args.opt_str("racks") {
        Some(spec) => RackLayout::parse(spec, k)?,
        None => {
            let per = k.div_ceil(3);
            RackLayout::new((0..k).map(|m| m / per).collect())?
        }
    };
    let racks = layout.rack_count();
    println!(
        "hierarchy bench: K={k} machines over R={racks} racks, sizes {sizes:?}, \
         framework {framework}, mu={mu}"
    );

    let mut group: Vec<(String, JsonVal)> = vec![
        ("smoke".into(), JsonVal::Bool(std::env::var("GTIP_BENCH_SMOKE").is_ok())),
        ("machines".into(), JsonVal::Int(k as u64)),
        ("racks".into(), JsonVal::Int(racks as u64)),
    ];
    println!("       N | transfers | rack_update msgs | bytes/RackUpdate | bytes/RegularUpdate");
    let mut per_message: Vec<f64> = Vec::new();
    for &n in &sizes {
        let mut rng = Pcg32::new(seed);
        let graph = generate(GraphFamily::PreferentialAttachment, n, &mut rng);
        let machines = MachineConfig::homogeneous(k);
        // A uniform random start (not the balanced grower) so the
        // outer game has genuine cross-rack imbalance to descend —
        // otherwise zero RackUpdates flow and there is nothing to
        // measure.
        let assignment: Vec<usize> = (0..n).map(|_| rng.index(k)).collect();
        let initial =
            crate::partition::Partition::from_assignment(&graph, k, assignment);
        let report = run_distributed_hierarchical(
            Arc::new(graph),
            &machines,
            initial,
            &layout,
            &DistributedOptions { mu, framework, ..Default::default() },
        );
        let o = &report.overhead;
        println!(
            "  {n:>6} | {:>9} | {:>16} | {:>16.1} | {:>19.1}",
            report.transfers,
            o.rack_update.messages,
            o.bytes_per_rack_update(),
            o.bytes_per_regular_update(),
        );
        if o.rack_update.messages > 0 {
            per_message.push(o.bytes_per_rack_update());
        }
        group.push((
            format!("n_{n}"),
            JsonVal::Obj(vec![
                ("transfers".into(), JsonVal::Int(report.transfers as u64)),
                ("converged".into(), JsonVal::Bool(report.converged)),
                ("rack_update_messages".into(), JsonVal::Int(o.rack_update.messages)),
                ("rack_update_bytes".into(), JsonVal::Int(o.rack_update.bytes)),
                (
                    "rack_update_bytes_per_message".into(),
                    JsonVal::Num(o.bytes_per_rack_update()),
                ),
                (
                    "regular_update_bytes_per_message".into(),
                    JsonVal::Num(o.bytes_per_regular_update()),
                ),
                ("total_bytes".into(), JsonVal::Int(o.total_bytes())),
            ]),
        ));
    }
    // The headline check: every observed cross-rack aggregate frame is
    // exactly 33 + 8R bytes — flat across N (and across K at fixed R).
    let expected = (33 + 8 * racks) as f64;
    let flat = !per_message.is_empty() && per_message.iter().all(|&b| b == expected);
    println!(
        "cross-rack aggregate bytes/message: expected {expected} (33 + 8R), flat across N: {flat}"
    );
    group.push(("rack_update_bytes_expected".into(), JsonVal::Num(expected)));
    group.push(("rack_update_bytes_flat_across_n".into(), JsonVal::Bool(flat)));
    if !flat {
        return Err(format!(
            "hierarchy bench: cross-rack aggregate bytes not flat at 33+8R={expected}: {per_message:?}"
        )
        .into());
    }
    let path = write_json_group(&out, "hierarchy", &JsonVal::Obj(group))?;
    println!("(merged hierarchy into {})", path.display());
    Ok(())
}

/// Schema gate for the bench trajectory: every group/key present in
/// the committed baseline must appear in the measured report, so a
/// bench that silently stops emitting a metric fails CI instead of
/// shipping an empty trajectory.
fn cmd_bench_gate(args: &Args) -> CliResult {
    let baseline_path = args.str_or("baseline", "results/BENCH_baseline.json");
    let measured_path = args.str_or("measured", "results/BENCH_sim.json");
    let baseline = parse_json(&std::fs::read_to_string(baseline_path).map_err(|e| {
        format!("reading baseline {baseline_path}: {e}")
    })?)
    .map_err(|e| format!("parsing {baseline_path}: {e}"))?;
    let measured = parse_json(&std::fs::read_to_string(measured_path).map_err(|e| {
        format!("reading measured {measured_path}: {e}")
    })?)
    .map_err(|e| format!("parsing {measured_path}: {e}"))?;

    let mut missing = Vec::new();
    fn walk(baseline: &JsonVal, measured: &JsonVal, path: &str, missing: &mut Vec<String>) {
        if let JsonVal::Obj(kvs) = baseline {
            for (k, sub) in kvs {
                let child = if path.is_empty() { k.clone() } else { format!("{path}.{k}") };
                match measured.get(k) {
                    Some(m) => walk(sub, m, &child, missing),
                    None => missing.push(child),
                }
            }
        }
    }
    walk(&baseline, &measured, "", &mut missing);
    if missing.is_empty() {
        println!("bench gate OK: {measured_path} covers every key of {baseline_path}");
        Ok(())
    } else {
        for m in &missing {
            eprintln!("bench gate: {measured_path} is missing {m}");
        }
        Err(format!(
            "schema regression: {} key(s) present in {baseline_path} but absent from {measured_path}",
            missing.len()
        )
        .into())
    }
}

/// Adversarial scenario fuzzing (`sim::fuzz`): search the drift-schedule
/// genome space for worst-case workloads, shrink the winners, and
/// persist them as a replayable corpus — or replay one corpus file.
fn cmd_fuzz(args: &Args) -> CliResult {
    let budget = args.opt_or::<usize>("budget", 200)?;
    let seed = args.opt_or::<u64>("seed", 2011)?;
    let nodes = args.opt_or::<usize>("nodes", 96)?;
    let k = args.opt_or::<usize>("k", 4)?;
    let horizon = args.opt_or::<u64>("horizon", 1_200)?;
    let threads = args.opt_or::<u32>("threads", 120)?;
    let epoch_ticks = args.opt_or::<u64>("epoch-ticks", 150)?;
    let framework: Framework = args.str_or("framework", "A").parse()?;
    let top_k = args.opt_or::<usize>("top", 3)?;
    let corpus_dir = args.str_or("corpus-dir", "results/fuzz_corpus").to_string();
    if nodes == 0 || k == 0 || horizon == 0 || threads == 0 {
        return Err("--nodes, --k, --horizon and --threads must be >= 1".into());
    }
    if threads as u64 > MAX_SCHEDULE_THREADS {
        return Err(format!("--threads must be <= {MAX_SCHEDULE_THREADS}").into());
    }
    let migration_charge = args.opt_or::<f64>("migration-charge", 0.0)?;
    if !(migration_charge >= 0.0 && migration_charge.is_finite()) {
        return Err("--migration-charge must be finite and >= 0".into());
    }
    // Engine-configuration knobs (also mutated by the search itself):
    // 0 = homogeneous machine speeds, the pre-config-fuzz default.
    let speed_seed = args.opt_or::<u64>("speed-seed", 0)?;
    let inter_delay = args.opt_or::<u64>("inter-delay", 3)?;
    let intra_delay = args.opt_or::<u64>("intra-delay", 0)?;
    let fixture = FuzzFixture { graph_seed: seed, nodes, machines: k, speed_seed };
    let eval = EvalOptions {
        epoch_ticks,
        framework,
        migration_charge,
        inter_machine_delay: inter_delay,
        intra_machine_delay: intra_delay,
        oracle: !args.flag("no-oracle"),
        ..Default::default()
    };

    if let Some(path) = args.opt_str("replay") {
        let case = FuzzCase::load(path)?;
        println!(
            "replaying {:?}: {} genes, {} threads over {} ticks on fixture (seed {}, {} LPs, K={})",
            case.name,
            case.schedule.genes.len(),
            case.schedule.total_threads(),
            case.schedule.horizon_ticks,
            case.fixture.graph_seed,
            case.fixture.nodes,
            case.fixture.machines,
        );
        // Replay under the settings the stored objectives were measured
        // with; CLI eval flags apply only to files that carry none.
        let eval = match &case.eval {
            Some(stored) => {
                println!(
                    "using stored eval settings: epoch {} ticks, framework {}, delays {}/{}, oracle {}",
                    stored.epoch_ticks,
                    stored.framework,
                    stored.inter_machine_delay,
                    stored.intra_machine_delay,
                    stored.oracle
                );
                stored.clone()
            }
            None => eval,
        };
        let obj = crate::sim::fuzz::evaluate(&case.fixture, &case.schedule, &eval)?;
        println!(
            "frozen {} ticks | rebalanced {} ticks | gap {:.3}x | rollbacks {} | transfers {} | refinements {}",
            obj.frozen_ticks,
            obj.rebalanced_ticks,
            obj.gap,
            obj.rollbacks,
            obj.transfers,
            obj.refinements,
        );
        println!(
            "descent violations: {} | oracle divergence: {} | truncated: frozen {} / rebalanced {}",
            obj.descent_violations,
            obj.oracle_divergence,
            obj.frozen_truncated,
            obj.rebalanced_truncated,
        );
        if let Some(stored) = &case.objectives {
            if obj.bit_eq(stored) {
                println!("replay matches the stored objectives byte-for-byte");
            } else {
                return Err(format!(
                    "replay DIVERGED from stored objectives:\n  stored   {stored:?}\n  measured {obj:?}"
                )
                .into());
            }
        }
        if obj.is_bug() {
            return Err("replayed schedule exposes a bug-class finding (see above)".into());
        }
        return Ok(());
    }

    let options = FuzzOptions {
        budget,
        seed,
        fixture,
        horizon_ticks: horizon,
        thread_budget: threads,
        hop_limit: 4,
        eval,
        top_k,
        shrink: !args.flag("no-shrink"),
        verbose: true,
    };
    println!(
        "fuzzing drift schedules: budget {budget}, fixture (seed {seed}, {nodes} LPs, K={k}), \
         horizon {horizon}, {threads} threads, epoch {epoch_ticks}, framework {framework}"
    );
    let outcome = run_fuzz(&options)?;
    println!(
        "campaign done: {} evaluations, hand-written best gap {:.3}x",
        outcome.evaluations, outcome.handwritten_best_gap
    );
    for f in &outcome.found {
        println!(
            "  #{} {}: gap {:.3}x, score {:.3}, {} genes (from {}), {} threads{}",
            f.rank,
            f.name,
            f.objectives.gap,
            f.objectives.score(),
            f.schedule.genes.len(),
            f.genes_before_shrink,
            f.schedule.total_threads(),
            if f.objectives.is_bug() { "  [BUG-CLASS FINDING]" } else { "" },
        );
    }
    let written = save_corpus(std::path::Path::new(&corpus_dir), &outcome)?;
    for p in &written {
        println!("(wrote {})", p.display());
    }
    if outcome.beat_handwritten() {
        println!(
            "worst found schedule beats every hand-written scenario \
             ({:.3}x > {:.3}x)",
            outcome.found.first().map(|f| f.objectives.gap).unwrap_or(0.0),
            outcome.handwritten_best_gap
        );
    } else {
        println!(
            "note: no found schedule beat the hand-written best gap {:.3}x \
             (raise --budget to search longer)",
            outcome.handwritten_best_gap
        );
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> CliResult {
    let which = args
        .positionals
        .get(1)
        .map(String::as_str)
        .ok_or("experiment name required: table1|batch|fig7|fig8|fig9|fig10|ablation|all")?;
    let seed = args.opt_or::<u64>("seed", 2011)?;
    let quick = args.flag("quick");
    match which {
        "table1" => {
            crate::experiments::table1::run_and_report(seed);
        }
        "batch" => {
            crate::experiments::batch::run_and_report(seed, quick);
        }
        "fig7" => {
            crate::experiments::figs78::run_and_report(
                GraphFamily::PreferentialAttachment,
                seed,
                quick,
            );
        }
        "fig8" => {
            crate::experiments::figs78::run_and_report(GraphFamily::Geometric, seed, quick);
        }
        "ablation" => {
            crate::experiments::ablation::run_and_report(seed, quick);
        }
        "fig9" | "fig10" | "fig9_10" => {
            crate::experiments::fig9_10::run_and_report(seed, quick);
        }
        "all" => {
            crate::experiments::table1::run_and_report(seed);
            crate::experiments::batch::run_and_report(seed, quick);
            crate::experiments::figs78::run_and_report(
                GraphFamily::PreferentialAttachment,
                seed,
                quick,
            );
            crate::experiments::figs78::run_and_report(GraphFamily::Geometric, seed, quick);
            crate::experiments::fig9_10::run_and_report(seed, quick);
        }
        other => return Err(format!("unknown experiment {other:?}").into()),
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_artifacts(args: &Args) -> CliResult {
    use crate::runtime::cost_eval::{max_rel_error_vs_native, PjrtCostEvaluator};
    let dir = args.str_or("dir", "artifacts").to_string();
    let mut eval = PjrtCostEvaluator::from_dir(&dir)?;
    println!("artifacts dir {dir}: max padded size {} nodes", eval.max_nodes());

    let mut rng = Pcg32::new(7);
    let setup = crate::experiments::common::StudySetup::default();
    let graph = setup.graph(&mut rng);
    let part = setup.initial(&graph, &mut rng);
    let out = eval.evaluate(&graph, &setup.machines, &part, setup.mu)?;
    let err = max_rel_error_vs_native(&graph, &setup.machines, &part, setup.mu, &out);
    println!(
        "verified refine_step on N={} K={}: PJRT vs native max rel error = {err:.2e}",
        out.n, out.k
    );
    if err >= 1e-3 {
        return Err(format!("artifact/native divergence: {err}").into());
    }
    println!("artifacts OK");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_artifacts(_args: &Args) -> CliResult {
    Err("the `artifacts` subcommand requires building with `--features pjrt` \
         (vendored xla crate; see DESIGN.md §7)"
        .into())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn help_runs() {
        run(&parse(&["help"])).unwrap();
    }

    #[test]
    fn unknown_subcommand_errors() {
        assert!(run(&parse(&["frobnicate"])).is_err());
    }

    #[test]
    fn partition_small_sequential() {
        run(&parse(&["partition", "--nodes", "60", "--seed", "3", "--k", "3"])).unwrap();
    }

    #[test]
    fn partition_distributed_small() {
        run(&parse(&["partition", "--nodes", "50", "--seed", "4", "--k", "3", "--distributed"]))
            .unwrap();
    }

    #[test]
    fn simulate_small() {
        run(&parse(&[
            "simulate",
            "--nodes",
            "80",
            "--threads",
            "30",
            "--refine-every",
            "200",
            "--seed",
            "5",
            "--k",
            "3",
        ]))
        .unwrap();
    }

    #[test]
    fn dynamic_small_closed_loop() {
        run(&parse(&[
            "dynamic",
            "--scenario",
            "hotspot",
            "--nodes",
            "90",
            "--threads",
            "40",
            "--horizon",
            "800",
            "--epoch-ticks",
            "150",
            "--seed",
            "6",
            "--k",
            "3",
        ]))
        .unwrap();
    }

    #[test]
    fn dynamic_compare_mode() {
        run(&parse(&[
            "dynamic",
            "--scenario",
            "flash",
            "--nodes",
            "80",
            "--threads",
            "40",
            "--horizon",
            "800",
            "--epoch-ticks",
            "150",
            "--estimator",
            "hysteresis",
            "--seed",
            "7",
            "--k",
            "3",
            "--compare",
        ]))
        .unwrap();
    }

    /// `--racks` drives the closed loop through the two-level game on
    /// both backends (sequential plays `refine_hierarchical`, the
    /// distributed backend runs the phased RackBus protocol).
    #[test]
    fn dynamic_small_closed_loop_hierarchical() {
        for backend in ["sequential", "distributed"] {
            run(&parse(&[
                "dynamic",
                "--scenario",
                "hotspot",
                "--nodes",
                "90",
                "--threads",
                "40",
                "--horizon",
                "600",
                "--epoch-ticks",
                "150",
                "--seed",
                "6",
                "--k",
                "4",
                "--racks",
                "0,0,1,1",
                "--backend",
                backend,
            ]))
            .unwrap();
        }
    }

    #[test]
    fn dynamic_rejects_bad_scenario() {
        assert!(run(&parse(&["dynamic", "--scenario", "bogus"])).is_err());
    }

    #[test]
    fn dynamic_rejects_bad_rack_maps() {
        // Wrong machine count.
        assert!(run(&parse(&["dynamic", "--k", "3", "--racks", "0,1"])).is_err());
        // Sparse rack numbering.
        assert!(run(&parse(&["dynamic", "--k", "3", "--racks", "0,0,2"])).is_err());
        // Unparseable entry.
        assert!(run(&parse(&["dynamic", "--k", "3", "--racks", "0,x,1"])).is_err());
    }

    #[test]
    fn dynamic_rejects_bad_transport_combinations() {
        assert!(run(&parse(&["dynamic", "--transport", "carrier-pigeon"])).is_err());
        // tcp needs a peers list...
        assert!(run(&parse(&["dynamic", "--transport", "tcp"])).is_err());
        // ...a distributed backend...
        assert!(run(&parse(&[
            "dynamic",
            "--transport",
            "tcp",
            "--backend",
            "sequential",
            "--peers",
            "127.0.0.1:1,127.0.0.1:2",
        ]))
        .is_err());
        // ...no --compare, and K matching the peer count.
        assert!(run(&parse(&[
            "dynamic",
            "--transport",
            "tcp",
            "--peers",
            "127.0.0.1:1,127.0.0.1:2",
            "--compare",
        ]))
        .is_err());
        assert!(run(&parse(&[
            "dynamic",
            "--transport",
            "tcp",
            "--peers",
            "127.0.0.1:1,127.0.0.1:2",
            "--k",
            "3",
        ]))
        .is_err());
    }

    #[test]
    fn dynamic_report_json_written_with_overhead() {
        let path = std::env::temp_dir().join(format!("gtip_report_{}.json", std::process::id()));
        let path_s = path.to_string_lossy().to_string();
        run(&parse(&[
            "dynamic",
            "--scenario",
            "hotspot",
            "--nodes",
            "80",
            "--threads",
            "40",
            "--horizon",
            "600",
            "--epoch-ticks",
            "150",
            "--seed",
            "11",
            "--k",
            "3",
            "--backend",
            "distributed",
            "--report-json",
            &path_s,
        ]))
        .unwrap();
        let doc = parse_json(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let dynamic = doc.get("dynamic").expect("dynamic group");
        assert!(dynamic.get("assignment").and_then(|a| a.as_arr()).is_some());
        assert!(dynamic.get("overhead").and_then(|o| o.get("total_bytes")).is_some());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn serve_validates_its_arguments() {
        assert!(run(&parse(&["serve"])).is_err());
        assert!(run(&parse(&["serve", "--machine-id", "1"])).is_err());
        // Machine 0 is the driver's seat.
        assert!(run(&parse(&[
            "serve",
            "--machine-id",
            "0",
            "--peers",
            "127.0.0.1:1,127.0.0.1:2",
        ]))
        .is_err());
        // Out-of-range id.
        assert!(run(&parse(&[
            "serve",
            "--machine-id",
            "7",
            "--peers",
            "127.0.0.1:1,127.0.0.1:2",
        ]))
        .is_err());
        // Join-only flags require --join.
        assert!(run(&parse(&[
            "serve",
            "--machine-id",
            "1",
            "--peers",
            "127.0.0.1:1,127.0.0.1:2",
            "--speed",
            "2.0",
        ]))
        .is_err());
        // A joiner's speed must be a positive weight.
        assert!(run(&parse(&[
            "serve",
            "--machine-id",
            "1",
            "--peers",
            "127.0.0.1:1,127.0.0.1:2",
            "--join",
            "--speed",
            "0",
        ]))
        .is_err());
        // Machine 0 cannot join its own cluster either.
        assert!(run(&parse(&[
            "serve",
            "--machine-id",
            "0",
            "--peers",
            "127.0.0.1:1,127.0.0.1:2",
            "--join",
        ]))
        .is_err());
    }

    #[test]
    fn bench_gate_passes_and_fails_by_schema() {
        let dir = std::env::temp_dir().join(format!("gtip_gate_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let baseline = dir.join("baseline.json");
        let measured = dir.join("measured.json");
        std::fs::write(&baseline, r#"{"simulator": {"headline": {"ticks": null}}}"#).unwrap();
        std::fs::write(&measured, r#"{"simulator": {"headline": {"ticks": 9, "extra": 1}}}"#)
            .unwrap();
        run(&parse(&[
            "bench-gate",
            "--baseline",
            baseline.to_str().unwrap(),
            "--measured",
            measured.to_str().unwrap(),
        ]))
        .unwrap();
        // Drop a required key => schema regression.
        std::fs::write(&measured, r#"{"simulator": {"other": 1}}"#).unwrap();
        assert!(run(&parse(&[
            "bench-gate",
            "--baseline",
            baseline.to_str().unwrap(),
            "--measured",
            measured.to_str().unwrap(),
        ]))
        .is_err());
        // Missing measured file is also a failure.
        assert!(run(&parse(&[
            "bench-gate",
            "--baseline",
            baseline.to_str().unwrap(),
            "--measured",
            dir.join("nope.json").to_str().unwrap(),
        ]))
        .is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dynamic_rejects_compare_with_report_json() {
        assert!(run(&parse(&["dynamic", "--compare", "--report-json", "/tmp/x.json"])).is_err());
    }

    #[test]
    fn dynamic_rejects_degenerate_workloads() {
        assert!(run(&parse(&["dynamic", "--threads", "0"])).is_err());
        assert!(run(&parse(&["dynamic", "--threads", "100001"])).is_err());
        assert!(run(&parse(&["dynamic", "--horizon", "0"])).is_err());
        assert!(run(&parse(&["dynamic", "--nodes", "0"])).is_err());
    }

    /// The full checkpoint pipeline through the CLI: a run with
    /// `--checkpoint-dir` emits epoch snapshots, `snapshot --inspect`
    /// verifies one (including its byte-identical re-encode), and a
    /// `--restore` run resumes it to completion with a report whose
    /// json carries the recovery/fleet fields.
    #[test]
    fn checkpoint_inspect_restore_round_trips() {
        let dir = std::env::temp_dir().join(format!("gtip_cli_ckpt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let dir_s = dir.to_string_lossy().to_string();
        run(&parse(&[
            "dynamic",
            "--scenario",
            "hotspot",
            "--nodes",
            "80",
            "--threads",
            "40",
            "--horizon",
            "600",
            "--epoch-ticks",
            "150",
            "--seed",
            "12",
            "--k",
            "3",
            "--checkpoint-dir",
            &dir_s,
        ]))
        .unwrap();
        let first = dir.join("epoch-0000.snap");
        assert!(first.exists(), "--checkpoint-dir must emit epoch snapshots");
        run(&parse(&["snapshot", "--inspect", first.to_str().unwrap()])).unwrap();

        let report = std::env::temp_dir().join(format!("gtip_cli_restore_{}.json", std::process::id()));
        let report_s = report.to_string_lossy().to_string();
        run(&parse(&[
            "dynamic",
            "--restore",
            first.to_str().unwrap(),
            "--epoch-ticks",
            "150",
            "--report-json",
            &report_s,
        ]))
        .unwrap();
        let doc = parse_json(&std::fs::read_to_string(&report).unwrap()).unwrap();
        let dynamic = doc.get("dynamic").expect("dynamic group");
        assert_eq!(dynamic.get("recoveries").and_then(|v| v.as_u64()), Some(0));
        assert_eq!(dynamic.get("admissions").and_then(|v| v.as_u64()), Some(0));
        assert_eq!(dynamic.get("machines").and_then(|v| v.as_u64()), Some(3));
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_file(&report);
    }

    #[test]
    fn snapshot_command_validates_usage() {
        // --inspect is required, and the file must exist and decode.
        assert!(run(&parse(&["snapshot"])).is_err());
        assert!(run(&parse(&["snapshot", "--inspect", "/nonexistent/gtip.snap"])).is_err());
    }

    #[test]
    fn dynamic_rejects_restore_with_compare() {
        assert!(run(&parse(&["dynamic", "--restore", "/tmp/x.snap", "--compare"])).is_err());
    }

    #[test]
    fn fuzz_tiny_campaign_then_replay_round_trips() {
        let dir = std::env::temp_dir().join(format!("gtip_cli_fuzz_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let dir_s = dir.to_string_lossy().to_string();
        run(&parse(&[
            "fuzz",
            "--budget",
            "5",
            "--nodes",
            "40",
            "--k",
            "3",
            "--threads",
            "24",
            "--horizon",
            "400",
            "--top",
            "1",
            "--no-shrink",
            "--no-oracle",
            "--seed",
            "9",
            "--corpus-dir",
            &dir_s,
        ]))
        .unwrap();
        // Replay the schedule the campaign just persisted; the stored
        // objectives must reproduce byte-for-byte.
        let entry = std::fs::read_dir(&dir)
            .expect("campaign wrote no corpus dir")
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .find(|p| p.extension().is_some_and(|x| x == "json"))
            .expect("campaign wrote no corpus file");
        run(&parse(&["fuzz", "--replay", entry.to_str().unwrap(), "--no-oracle"])).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dynamic_accepts_migration_charge_flags() {
        run(&parse(&[
            "dynamic",
            "--scenario",
            "hotspot",
            "--nodes",
            "80",
            "--threads",
            "40",
            "--horizon",
            "600",
            "--epoch-ticks",
            "150",
            "--seed",
            "19",
            "--k",
            "3",
            "--ticks-per-transfer",
            "3",
            "--migration-charge",
            "2.5",
        ]))
        .unwrap();
        assert!(run(&parse(&["dynamic", "--migration-charge", "-1"])).is_err());
        assert!(run(&parse(&["dynamic", "--migration-charge", "nan"])).is_err());
        assert!(run(&parse(&["dynamic", "--tick-value", "-2"])).is_err());
    }

    #[test]
    fn churn_sweep_writes_tradeoff_group() {
        let dir = std::env::temp_dir().join(format!("gtip_churn_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("BENCH_churn.json");
        let out_s = out.to_string_lossy().to_string();
        run(&parse(&[
            "churn-sweep",
            "--scenarios",
            "hotspot,flash",
            "--nodes",
            "70",
            "--k",
            "3",
            "--threads",
            "40",
            "--horizon",
            "600",
            "--epoch-ticks",
            "150",
            "--charges",
            "0,8,1000000000000",
            "--seed",
            "21",
            "--out",
            &out_s,
        ]))
        .unwrap();
        let doc = parse_json(&std::fs::read_to_string(&out).unwrap()).unwrap();
        let group = doc.get("churn_tradeoff").expect("churn_tradeoff group");
        for scenario in ["hotspot", "flash"] {
            let s = group.get(scenario).unwrap_or_else(|| panic!("missing {scenario}"));
            for charge in ["charge_0", "charge_8", "charge_1000000000000"] {
                let row = s.get(charge).unwrap_or_else(|| panic!("{scenario}: missing {charge}"));
                assert!(row.get("transfers").and_then(JsonVal::as_u64).is_some());
                assert!(row.get("speedup").and_then(JsonVal::as_f64).is_some());
                assert!(row.get("migration_ticks").and_then(JsonVal::as_u64).is_some());
                assert!(row.get("frozen_ticks").and_then(JsonVal::as_u64).is_some());
                assert!(row.get("rebalanced_ticks").and_then(JsonVal::as_u64).is_some());
                assert_eq!(
                    row.get("truncated").and_then(JsonVal::as_bool),
                    Some(false),
                    "{scenario}/{charge}: small fixture must drain un-truncated"
                );
            }
            // Only the provable endpoint claim: a 1e12-tick charge is
            // orders of magnitude above any raw gain measured weights
            // can produce (loads ~1e3-1e4, b/w ~1e3 => gains ~1e7), so
            // the top rung freezes the balancer entirely (middle rungs
            // are data, not a theorem — the sweep records the
            // monotonicity verdict instead of asserting it).
            let top = s
                .get("charge_1000000000000")
                .and_then(|r| r.get("transfers"))
                .and_then(JsonVal::as_u64)
                .expect("top-rung transfers");
            assert_eq!(top, 0, "{scenario}: prohibitive charge must freeze the balancer");
            assert!(s.get("transfers_strictly_decreasing").and_then(JsonVal::as_bool).is_some());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The hierarchy bench runs the two-level game over several graph
    /// sizes and merges a `hierarchy` group whose per-N rows carry the
    /// cross-rack overhead counters; the headline flatness verdict
    /// (every RackUpdate exactly 33 + 8R framed bytes, N-independent)
    /// must hold or the command itself fails.
    #[test]
    fn hierarchy_bench_writes_group_with_flat_rack_bytes() {
        let dir = std::env::temp_dir().join(format!("gtip_hier_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("BENCH_hier.json");
        let out_s = out.to_string_lossy().to_string();
        run(&parse(&[
            "hierarchy-bench",
            "--sizes",
            "40,80",
            "--k",
            "6",
            "--racks",
            "0,0,1,1,2,2",
            "--seed",
            "7",
            "--out",
            &out_s,
        ]))
        .unwrap();
        let doc = parse_json(&std::fs::read_to_string(&out).unwrap()).unwrap();
        let group = doc.get("hierarchy").expect("hierarchy group");
        assert_eq!(group.get("racks").and_then(JsonVal::as_u64), Some(3));
        assert_eq!(
            group.get("rack_update_bytes_flat_across_n").and_then(JsonVal::as_bool),
            Some(true)
        );
        for n in ["n_40", "n_80"] {
            let row = group.get(n).unwrap_or_else(|| panic!("missing {n}"));
            assert!(row.get("rack_update_messages").and_then(JsonVal::as_u64).is_some());
            // 33 + 8*3 = 57 framed bytes per cross-rack aggregate.
            assert_eq!(
                row.get("rack_update_bytes_per_message").and_then(JsonVal::as_f64),
                Some(57.0),
                "{n}: RackUpdate must cost 33 + 8R bytes"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn hierarchy_bench_rejects_degenerate_options() {
        assert!(run(&parse(&["hierarchy-bench", "--sizes", ""])).is_err());
        assert!(run(&parse(&["hierarchy-bench", "--k", "0"])).is_err());
        // Rack map must cover the fleet.
        assert!(run(&parse(&["hierarchy-bench", "--k", "4", "--racks", "0,1"])).is_err());
    }

    #[test]
    fn churn_sweep_rejects_degenerate_options() {
        assert!(run(&parse(&["churn-sweep", "--charges", "4,4"])).is_err());
        assert!(run(&parse(&["churn-sweep", "--charges", "8,2"])).is_err());
        assert!(run(&parse(&["churn-sweep", "--scenarios", "bogus"])).is_err());
        assert!(run(&parse(&["churn-sweep", "--nodes", "0"])).is_err());
    }

    #[test]
    fn fuzz_rejects_degenerate_options() {
        assert!(run(&parse(&["fuzz", "--budget", "0"])).is_err());
        assert!(run(&parse(&["fuzz", "--nodes", "0"])).is_err());
        assert!(run(&parse(&["fuzz", "--replay", "/nonexistent/corpus.json"])).is_err());
    }

    #[test]
    fn experiment_requires_name() {
        assert!(run(&parse(&["experiment"])).is_err());
        assert!(run(&parse(&["experiment", "bogus"])).is_err());
    }
}

//! The closed-loop subcommands: `gtip dynamic` (the full
//! simulate → estimate → refine → migrate loop, in-process or over an
//! attached TCP cluster, with checkpoints and churn), `gtip snapshot`
//! (inspect a checkpoint file), and `gtip serve` (run one worker of a
//! distributed cluster).

use std::time::Duration;

use crate::coordinator::net::{self, ClusterLeader};
use crate::coordinator::DistributedOptions;
use crate::game::cost::Framework;
use crate::game::hierarchy::RackLayout;
use crate::graph::generators::{generate, GraphFamily};
use crate::partition::initial::grow_partition;
use crate::partition::{global_cost, MachineConfig};
use crate::sim::dynamic::{
    compare_frozen_vs_rebalanced, DynamicDriver, DynamicOptions, EstimatorKind, RefineBackend,
    WeightEstimator,
};
use crate::sim::engine::SimOptions;
use crate::sim::scenario::{Scenario, ScenarioKind, ScenarioOptions, MAX_SCHEDULE_THREADS};
use crate::util::bench::JsonVal;
use crate::util::cli::Args;
use crate::util::rng::Pcg32;

use super::{machines_from_args, CliResult};

pub(crate) fn cmd_dynamic(args: &Args) -> CliResult {
    let seed = args.opt_or::<u64>("seed", 2011)?;
    let family: GraphFamily = args.str_or("family", "pa").parse()?;
    let nodes = args.opt_or::<usize>("nodes", 150)?;
    let machines = machines_from_args(args)?;
    let scenario_kind: ScenarioKind = args.str_or("scenario", "hotspot").parse()?;
    let epoch_ticks = args.opt_or::<u64>("epoch-ticks", 200)?;
    let framework: Framework = args.str_or("framework", "A").parse()?;
    let mu = args.opt_or::<f64>("mu", 8.0)?;
    let estimator_kind: EstimatorKind = args.str_or("estimator", "ewma").parse()?;
    let backend: RefineBackend = args.str_or("backend", "sequential").parse()?;
    let threads = args.opt_or::<usize>("threads", 160)?;
    let horizon = args.opt_or::<u64>("horizon", 2_400)?;
    let ticks_per_transfer = args.opt_or::<u64>("ticks-per-transfer", 0)?;
    // In-game surcharge: explicit --migration-charge wins; otherwise it
    // derives as ticks_per_transfer x tick_value so the game prices
    // exactly what the report bills (DESIGN.md §9).
    let tick_value = args.opt_or::<f64>("tick-value", 1.0)?;
    if !(tick_value >= 0.0 && tick_value.is_finite()) {
        return Err("--tick-value must be finite and >= 0".into());
    }
    let migration_charge = match args.opt::<f64>("migration-charge")? {
        Some(c) => c,
        None => ticks_per_transfer as f64 * tick_value,
    };
    if !(migration_charge >= 0.0 && migration_charge.is_finite()) {
        return Err("--migration-charge must be finite and >= 0".into());
    }
    let parallelism = args.opt_or::<usize>("parallelism", 1)?;
    let transport = args.str_or("transport", "inproc").to_string();
    let connect_timeout = Duration::from_millis(args.opt_or::<u64>("connect-timeout-ms", 30_000)?);
    // How long the cluster waits on a silent peer before declaring it
    // dead (rides Setup, so workers use it too). The 30s default is
    // safe for congested CI; kill-a-worker tests dial it down so death
    // diagnosis is quick.
    let recv_timeout = Duration::from_millis(args.opt_or::<u64>("recv-timeout-ms", 30_000)?.max(1));
    // Patience of the admission handshake's ack barrier (leader side).
    // Defaults to 2× recv_timeout inside ClusterLeader; only override
    // when a test needs the rollback path to trip quickly.
    let admit_window = args.opt::<u64>("admit-window-ms")?.map(Duration::from_millis);
    let tcp = match transport.as_str() {
        "inproc" | "in-process" | "local" => false,
        "tcp" => true,
        other => return Err(format!("unknown transport {other:?} (expected inproc|tcp)").into()),
    };
    let backend = if tcp {
        if args.flag("compare") {
            return Err("--compare runs two arms and is not supported with --transport tcp".into());
        }
        if backend != RefineBackend::Distributed && args.opt_str("backend").is_some() {
            return Err("--transport tcp requires --backend distributed".into());
        }
        RefineBackend::Distributed
    } else {
        backend
    };
    if nodes == 0 {
        return Err("--nodes must be >= 1".into());
    }
    if threads == 0 {
        return Err("--threads must be >= 1".into());
    }
    if threads as u64 > MAX_SCHEDULE_THREADS {
        return Err(format!("--threads must be <= {MAX_SCHEDULE_THREADS}").into());
    }
    if horizon == 0 {
        return Err("--horizon must be >= 1".into());
    }
    let checkpoint_dir = args.opt_str("checkpoint-dir").map(std::path::PathBuf::from);
    // Two-level hierarchy (DESIGN.md §12): `--racks "0,0,1,1"` names the
    // rack of each machine. Validated against the fleet the run starts
    // with — on `--restore` that is the snapshot's K, not `--k`.
    let racks = match args.opt_str("racks") {
        Some(spec) => {
            let k = match args.opt_str("restore") {
                Some(path) => {
                    crate::sim::Snapshot::read_from(std::path::Path::new(path))?.machine_count()
                }
                None => machines.count(),
            };
            Some(crate::game::hierarchy::RackLayout::parse(spec, k)?)
        }
        None => None,
    };

    let options = DynamicOptions {
        sim: SimOptions { trace_every: 50, parallelism, ..Default::default() },
        epoch_ticks,
        framework,
        mu,
        backend,
        ticks_per_transfer,
        migration_charge,
        max_refinements: 0,
        checkpoint_dir,
        racks,
    };

    // Resume from an epoch-boundary checkpoint instead of generating a
    // fixture: topology, fleet, pending events, estimator memory and
    // cumulative counters all come from the file (DESIGN.md §10).
    if let Some(path) = args.opt_str("restore") {
        if args.flag("compare") {
            return Err("--restore resumes one arm; it cannot be combined with --compare".into());
        }
        let snap = crate::sim::Snapshot::read_from(std::path::Path::new(path))?;
        let graph = snap.build_graph();
        println!(
            "restore {path}: {} LPs, K={}, epoch {}, {} ticks simulated",
            graph.node_count(),
            snap.machine_count(),
            snap.epoch,
            snap.engine.stats.ticks,
        );
        let estimator = WeightEstimator::of_kind(estimator_kind);
        let mut driver = DynamicDriver::from_snapshot(&graph, &snap, estimator, options);
        if tcp {
            let peers = net::parse_peers(args.req_str("peers")?)?;
            if peers.len() != snap.machine_count() {
                return Err(format!(
                    "--peers lists {} machines but the snapshot has K={}",
                    peers.len(),
                    snap.machine_count()
                )
                .into());
            }
            let mut leader = ClusterLeader::connect(
                &peers,
                DistributedOptions {
                    mu,
                    framework,
                    migration_charge,
                    recv_timeout,
                    ..Default::default()
                },
                connect_timeout,
            )?;
            if let Some(w) = admit_window {
                leader.set_admit_window(w);
            }
            driver.attach_cluster(leader)?;
        }
        let report = driver.try_run()?;
        let title = format!("gtip dynamic — restored from {path}");
        println!("{}", report.epoch_table(&title).to_text());
        println!(
            "total: {} wall ticks  (events {}, rollbacks {}, {} refinements, {} transfers, truncated {})",
            report.total_time(),
            report.stats.events_processed,
            report.stats.rollbacks,
            report.refinements(),
            report.transfers,
            report.stats.truncated,
        );
        if let Some(out) = args.opt_str("report-json") {
            // Final measured weights, like the live path — so the cost
            // here is directly comparable with the run that wrote the
            // checkpoint (net-smoke's recovery gate relies on this).
            let json = dynamic_report_json(
                &report,
                driver.engine().partition().assignment(),
                driver.weighted_graph(),
                driver.machines(),
                mu,
            );
            if let Some(dir) = std::path::Path::new(out).parent() {
                if !dir.as_os_str().is_empty() {
                    std::fs::create_dir_all(dir)?;
                }
            }
            std::fs::write(out, json.sorted().render() + "\n")?;
            println!("(wrote {out})");
        }
        return Ok(());
    }

    let mut rng = Pcg32::new(seed);
    let graph = generate(family, nodes, &mut rng);
    let scenario = Scenario::build(
        scenario_kind,
        &graph,
        &ScenarioOptions { threads, horizon_ticks: horizon, ..Default::default() },
        &mut rng,
    );
    println!(
        "scenario {scenario_kind} ({}): {} LPs, {} edges, K={}, {} floods over {horizon} ticks",
        scenario_kind.describe(),
        graph.node_count(),
        graph.edge_count(),
        machines.count(),
        scenario.len(),
    );
    println!(
        "loop: epoch={epoch_ticks} ticks, estimator {estimator_kind}, backend {backend}, framework {framework}, mu={mu}, c_mig={migration_charge}"
    );
    if let Some(l) = &options.racks {
        println!(
            "hierarchy: two-level game, {} racks over K={} machines",
            l.rack_count(),
            l.machine_count()
        );
    }

    let initial = grow_partition(&graph, &machines, &mut rng);
    let estimator = WeightEstimator::of_kind(estimator_kind);

    if args.flag("compare") {
        if args.opt_str("report-json").is_some() {
            return Err("--report-json only supports single-arm runs (drop --compare)".into());
        }
        let report = compare_frozen_vs_rebalanced(
            &graph,
            &machines,
            &initial,
            &scenario.injections,
            estimator,
            &options,
        );
        let title = format!("gtip dynamic — {scenario_kind} (rebalanced arm)");
        println!("{}", report.rebalanced.epoch_table(&title).to_text());
        println!(
            "frozen     : {:>7} wall ticks  (rollbacks {:>6}, cross-machine {:>6})",
            report.frozen.total_time(),
            report.frozen.stats.rollbacks,
            report.frozen.stats.cross_machine_forwards,
        );
        println!(
            "rebalanced : {:>7} wall ticks  (rollbacks {:>6}, cross-machine {:>6}, {} refinements, {} transfers)",
            report.rebalanced.total_time(),
            report.rebalanced.stats.rollbacks,
            report.rebalanced.stats.cross_machine_forwards,
            report.rebalanced.refinements(),
            report.rebalanced.transfers,
        );
        println!("speedup from closed-loop rebalancing: {:.2}x", report.speedup());
    } else {
        let mut driver = DynamicDriver::new(
            &graph,
            machines.clone(),
            initial,
            scenario.injections,
            estimator,
            options,
        );
        if tcp {
            let peers = net::parse_peers(args.req_str("peers")?)?;
            if peers.len() != machines.count() {
                return Err(format!(
                    "--peers lists {} machines but K={} (peer 0 is this driver)",
                    peers.len(),
                    machines.count()
                )
                .into());
            }
            println!(
                "transport tcp: leading a {}-process cluster (this process = machine 0 @ {})",
                peers.len(),
                peers[0]
            );
            let mut leader = ClusterLeader::connect(
                &peers,
                DistributedOptions {
                    mu,
                    framework,
                    migration_charge,
                    recv_timeout,
                    ..Default::default()
                },
                connect_timeout,
            )?;
            if let Some(w) = admit_window {
                leader.set_admit_window(w);
            }
            driver.attach_cluster(leader)?;
        }
        let report = driver.try_run()?;
        let title = format!("gtip dynamic — {scenario_kind}");
        println!("{}", report.epoch_table(&title).to_text());
        println!(
            "total: {} wall ticks  (events {}, rollbacks {}, {} refinements, {} transfers, truncated {})",
            report.total_time(),
            report.stats.events_processed,
            report.stats.rollbacks,
            report.refinements(),
            report.transfers,
            report.stats.truncated,
        );
        if let Some(o) = report.total_overhead() {
            println!(
                "coordinator sync: {} msgs, {} bytes on the wire, {:.1} bytes/transfer, {:.1} bytes/RegularUpdate (O(K), N-independent)",
                o.total_messages(),
                o.total_bytes(),
                o.bytes_per_transfer(report.transfers as u64),
                o.bytes_per_regular_update(),
            );
            if o.rack_update.messages > 0 {
                println!(
                    "cross-rack sync: {} RackUpdate msgs, {} bytes, {:.1} bytes/RackUpdate (O(R), K- and N-independent)",
                    o.rack_update.messages,
                    o.rack_update.bytes,
                    o.bytes_per_rack_update(),
                );
            }
        }
        if report.recoveries() > 0 {
            println!(
                "recovered from {} worker death(s); fleet now K={}",
                report.recoveries(),
                driver.machines().count(),
            );
        }
        if report.admissions() > 0 {
            println!(
                "admitted {} joiner(s); fleet now K={}",
                report.admissions(),
                driver.machines().count(),
            );
        }
        if let Some(path) = args.opt_str("report-json") {
            // `driver.machines()` and `driver.weighted_graph()`, not
            // the pre-run config: a recovery shrinks the fleet (and an
            // admission grows it), and the final assignment was
            // refined on the final measured weights — costing it
            // against the stale K or the initial weights would be
            // wrong (and would make the recovered run incomparable
            // with a `--restore recovery-NNNN.snap` replay).
            let json = dynamic_report_json(
                &report,
                driver.engine().partition().assignment(),
                driver.weighted_graph(),
                driver.machines(),
                mu,
            );
            if let Some(dir) = std::path::Path::new(path).parent() {
                if !dir.as_os_str().is_empty() {
                    std::fs::create_dir_all(dir)?;
                }
            }
            std::fs::write(path, json.sorted().render() + "\n")?;
            println!("(wrote {path})");
        }
    }
    Ok(())
}

/// Transport-invariant summary of a closed-loop run: the `net-smoke`
/// CI job byte-compares this JSON between the TCP multi-process run
/// and the in-process run on the same fixture.
fn dynamic_report_json(
    report: &crate::sim::dynamic::DynamicReport,
    final_assignment: &[usize],
    graph: &crate::graph::Graph,
    machines: &MachineConfig,
    mu: f64,
) -> JsonVal {
    let part = crate::partition::Partition::from_assignment(
        graph,
        machines.count(),
        final_assignment.to_vec(),
    );
    let (c0, c0t) = global_cost::both(graph, machines, &part, mu);
    let mut fields = vec![
        (
            "assignment".into(),
            JsonVal::Arr(final_assignment.iter().map(|&m| JsonVal::Int(m as u64)).collect()),
        ),
        ("global_cost_c0".into(), JsonVal::Num(c0)),
        ("global_cost_c0_tilde".into(), JsonVal::Num(c0t)),
        ("ticks".into(), JsonVal::Int(report.stats.ticks)),
        ("events_processed".into(), JsonVal::Int(report.stats.events_processed)),
        ("rollbacks".into(), JsonVal::Int(report.stats.rollbacks)),
        ("transfers".into(), JsonVal::Int(report.transfers as u64)),
        ("refinements".into(), JsonVal::Int(report.refinements() as u64)),
        ("recoveries".into(), JsonVal::Int(report.recoveries() as u64)),
        ("admissions".into(), JsonVal::Int(report.admissions() as u64)),
        ("machines".into(), JsonVal::Int(machines.count() as u64)),
        (
            "racks".into(),
            JsonVal::Int(report.epochs.iter().map(|e| e.racks).max().unwrap_or(0) as u64),
        ),
    ];
    if let Some(o) = report.total_overhead() {
        let counter = |c: &crate::coordinator::protocol::Counter| {
            JsonVal::Obj(vec![
                ("messages".into(), JsonVal::Int(c.messages)),
                ("bytes".into(), JsonVal::Int(c.bytes)),
            ])
        };
        fields.push((
            "overhead".into(),
            JsonVal::Obj(vec![
                ("take_my_turn".into(), counter(&o.take_my_turn)),
                ("receive_node".into(), counter(&o.receive_node)),
                ("regular_update".into(), counter(&o.regular_update)),
                ("rack_update".into(), counter(&o.rack_update)),
                ("shutdown".into(), counter(&o.shutdown)),
                ("total_messages".into(), JsonVal::Int(o.total_messages())),
                ("total_bytes".into(), JsonVal::Int(o.total_bytes())),
                (
                    "sync_bytes_per_transfer".into(),
                    JsonVal::Num(o.bytes_per_transfer(report.transfers as u64)),
                ),
                (
                    "regular_update_bytes_per_message".into(),
                    JsonVal::Num(o.bytes_per_regular_update()),
                ),
                (
                    "rack_update_bytes_per_message".into(),
                    JsonVal::Num(o.bytes_per_rack_update()),
                ),
            ]),
        ));
    }
    JsonVal::Obj(vec![("dynamic".into(), JsonVal::Obj(fields))])
}

/// Inspect an epoch-boundary checkpoint: print its summary and verify
/// the decode→re-encode round trip is byte-identical (the determinism
/// gate DESIGN.md §10 promises for every `.snap` file).
pub(crate) fn cmd_snapshot(args: &Args) -> CliResult {
    let path = args
        .opt_str("inspect")
        .ok_or("usage: gtip snapshot --inspect FILE")?;
    let bytes = std::fs::read(path)?;
    let snap = crate::sim::Snapshot::decode(&bytes)?;
    println!("{}", snap.summary());
    let reencoded = snap.encode();
    if reencoded != bytes {
        return Err(format!(
            "round-trip diverged: {} bytes on disk, {} re-encoded",
            bytes.len(),
            reencoded.len()
        )
        .into());
    }
    println!("round-trip: {} bytes, re-encode byte-identical", bytes.len());
    Ok(())
}

/// Worker side of the multi-process cluster: block until the leader
/// (machine 0, `gtip dynamic --transport tcp`) connects, then play one
/// refinement round per epoch until it says goodbye. With `--join`,
/// instead of waiting for the leader's mesh dial, ask a *live* cluster
/// to re-admit this machine id (DESIGN.md §10): send `Join`, wait out
/// the admission handshake (`--admit-window-ms`), catch up from the
/// leader's boundary snapshot, and serve from there. `--speed` is the
/// joiner's self-reported relative speed (1.0 = an average machine of
/// the original fleet).
pub(crate) fn cmd_serve(args: &Args) -> CliResult {
    let machine_id = args.opt::<usize>("machine-id")?.ok_or("--machine-id is required")?;
    let peers = net::parse_peers(args.req_str("peers")?)?;
    let connect_timeout = Duration::from_millis(args.opt_or::<u64>("connect-timeout-ms", 30_000)?);
    if args.opt_str("checkpoint-dir").is_some() {
        // Accepted so one launch template serves every rank: snapshots
        // are taken leader-side (machine 0 owns the engine), so a
        // worker has nothing to write there.
        println!("note: checkpoints are taken by the leader; --checkpoint-dir is a no-op on serve");
    }
    let summary = if args.flag("join") {
        let speed = args.opt_or::<f64>("speed", 1.0)?;
        if !(speed > 0.0 && speed.is_finite()) {
            return Err("--speed must be finite and > 0".into());
        }
        // Rack the joiner asks to be placed in (hierarchical clusters,
        // DESIGN.md §12). Omitted = leader's choice (least-loaded rack);
        // ignored by flat clusters.
        let rack = args.opt::<usize>("rack")?;
        let admit_window =
            Duration::from_millis(args.opt_or::<u64>("admit-window-ms", 120_000)?.max(1));
        println!(
            "gtip serve: machine {machine_id}/{} joining the live cluster via {} (leader @ {})",
            peers.len(),
            peers.get(machine_id).map(String::as_str).unwrap_or("?"),
            peers[0],
        );
        net::serve_join(machine_id, &peers, speed, rack, connect_timeout, admit_window)?
    } else {
        if args.opt_str("speed").is_some()
            || args.opt_str("admit-window-ms").is_some()
            || args.opt_str("rack").is_some()
        {
            return Err("--speed / --rack / --admit-window-ms only apply with --join".into());
        }
        println!(
            "gtip serve: machine {machine_id}/{} listening on {} (leader @ {})",
            peers.len(),
            peers.get(machine_id).map(String::as_str).unwrap_or("?"),
            peers[0],
        );
        net::serve(machine_id, &peers, connect_timeout)?
    };
    println!(
        "served {} refinement epochs as machine {}: sent {} sync msgs / {} bytes, {} control msgs / {} bytes",
        summary.epochs,
        summary.machine_id,
        summary.overhead.total_messages(),
        summary.overhead.total_bytes(),
        summary.control.control_messages,
        summary.control.control_bytes,
    );
    Ok(())
}

/// Quantify the churn/hysteresis trade-off of migration-cost-aware
/// refinement (DESIGN.md §9): sweep the per-transfer charge over fixed
/// scenario fixtures, run the frozen-vs-rebalanced comparison at each
/// level — the charge is billed as wall ticks AND priced inside the
/// game (`c_mig = ticks · tick_value`) — and merge a `churn_tradeoff`
/// group (transfers, migration ticks, speedup per level) into the

//! The paper-reproduction subcommands: `gtip experiment <name>`
//! (Table 1, the batch study, figures 7-10, the ablation) and
//! `gtip artifacts` (verify exported PJRT artifacts against the
//! native cost path; stub unless built with `--features pjrt`).

use crate::graph::generators::GraphFamily;
use crate::util::cli::Args;

use super::CliResult;

pub(crate) fn cmd_experiment(args: &Args) -> CliResult {
    let which = args
        .positionals
        .get(1)
        .map(String::as_str)
        .ok_or("experiment name required: table1|batch|fig7|fig8|fig9|fig10|ablation|all")?;
    let seed = args.opt_or::<u64>("seed", 2011)?;
    let quick = args.flag("quick");
    match which {
        "table1" => {
            crate::experiments::table1::run_and_report(seed);
        }
        "batch" => {
            crate::experiments::batch::run_and_report(seed, quick);
        }
        "fig7" => {
            crate::experiments::figs78::run_and_report(
                GraphFamily::PreferentialAttachment,
                seed,
                quick,
            );
        }
        "fig8" => {
            crate::experiments::figs78::run_and_report(GraphFamily::Geometric, seed, quick);
        }
        "ablation" => {
            crate::experiments::ablation::run_and_report(seed, quick);
        }
        "fig9" | "fig10" | "fig9_10" => {
            crate::experiments::fig9_10::run_and_report(seed, quick);
        }
        "all" => {
            crate::experiments::table1::run_and_report(seed);
            crate::experiments::batch::run_and_report(seed, quick);
            crate::experiments::figs78::run_and_report(
                GraphFamily::PreferentialAttachment,
                seed,
                quick,
            );
            crate::experiments::figs78::run_and_report(GraphFamily::Geometric, seed, quick);
            crate::experiments::fig9_10::run_and_report(seed, quick);
        }
        other => return Err(format!("unknown experiment {other:?}").into()),
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
pub(crate) fn cmd_artifacts(args: &Args) -> CliResult {
    use crate::runtime::cost_eval::{max_rel_error_vs_native, PjrtCostEvaluator};
    use crate::util::rng::Pcg32;
    let dir = args.str_or("dir", "artifacts").to_string();
    let mut eval = PjrtCostEvaluator::from_dir(&dir)?;
    println!("artifacts dir {dir}: max padded size {} nodes", eval.max_nodes());

    let mut rng = Pcg32::new(7);
    let setup = crate::experiments::common::StudySetup::default();
    let graph = setup.graph(&mut rng);
    let part = setup.initial(&graph, &mut rng);
    let out = eval.evaluate(&graph, &setup.machines, &part, setup.mu)?;
    let err = max_rel_error_vs_native(&graph, &setup.machines, &part, setup.mu, &out);
    println!(
        "verified refine_step on N={} K={}: PJRT vs native max rel error = {err:.2e}",
        out.n, out.k
    );
    if err >= 1e-3 {
        return Err(format!("artifact/native divergence: {err}").into());
    }
    println!("artifacts OK");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
pub(crate) fn cmd_artifacts(_args: &Args) -> CliResult {
    Err("the `artifacts` subcommand requires building with `--features pjrt` \
         (vendored xla crate; see DESIGN.md §7)"
        .into())
}

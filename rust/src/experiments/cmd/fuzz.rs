//! The `gtip fuzz` subcommand: drive the search-based fuzz campaign
//! over drift schedules and persist reproducible findings to the
//! corpus.

use crate::game::cost::Framework;
use crate::sim::fuzz::{run_fuzz, save_corpus, EvalOptions, FuzzCase, FuzzFixture, FuzzOptions};
use crate::sim::scenario::MAX_SCHEDULE_THREADS;
use crate::util::cli::Args;

use super::CliResult;

pub(crate) fn cmd_fuzz(args: &Args) -> CliResult {
    let budget = args.opt_or::<usize>("budget", 200)?;
    let seed = args.opt_or::<u64>("seed", 2011)?;
    let nodes = args.opt_or::<usize>("nodes", 96)?;
    let k = args.opt_or::<usize>("k", 4)?;
    let horizon = args.opt_or::<u64>("horizon", 1_200)?;
    let threads = args.opt_or::<u32>("threads", 120)?;
    let epoch_ticks = args.opt_or::<u64>("epoch-ticks", 150)?;
    let framework: Framework = args.str_or("framework", "A").parse()?;
    let top_k = args.opt_or::<usize>("top", 3)?;
    let corpus_dir = args.str_or("corpus-dir", "results/fuzz_corpus").to_string();
    if nodes == 0 || k == 0 || horizon == 0 || threads == 0 {
        return Err("--nodes, --k, --horizon and --threads must be >= 1".into());
    }
    if threads as u64 > MAX_SCHEDULE_THREADS {
        return Err(format!("--threads must be <= {MAX_SCHEDULE_THREADS}").into());
    }
    let migration_charge = args.opt_or::<f64>("migration-charge", 0.0)?;
    if !(migration_charge >= 0.0 && migration_charge.is_finite()) {
        return Err("--migration-charge must be finite and >= 0".into());
    }
    // Engine-configuration knobs (also mutated by the search itself):
    // 0 = homogeneous machine speeds, the pre-config-fuzz default.
    let speed_seed = args.opt_or::<u64>("speed-seed", 0)?;
    let inter_delay = args.opt_or::<u64>("inter-delay", 3)?;
    let intra_delay = args.opt_or::<u64>("intra-delay", 0)?;
    let fixture = FuzzFixture { graph_seed: seed, nodes, machines: k, speed_seed };
    let eval = EvalOptions {
        epoch_ticks,
        framework,
        migration_charge,
        inter_machine_delay: inter_delay,
        intra_machine_delay: intra_delay,
        oracle: !args.flag("no-oracle"),
        ..Default::default()
    };

    if let Some(path) = args.opt_str("replay") {
        let case = FuzzCase::load(path)?;
        println!(
            "replaying {:?}: {} genes, {} threads over {} ticks on fixture (seed {}, {} LPs, K={})",
            case.name,
            case.schedule.genes.len(),
            case.schedule.total_threads(),
            case.schedule.horizon_ticks,
            case.fixture.graph_seed,
            case.fixture.nodes,
            case.fixture.machines,
        );
        // Replay under the settings the stored objectives were measured
        // with; CLI eval flags apply only to files that carry none.
        let eval = match &case.eval {
            Some(stored) => {
                println!(
                    "using stored eval settings: epoch {} ticks, framework {}, delays {}/{}, oracle {}",
                    stored.epoch_ticks,
                    stored.framework,
                    stored.inter_machine_delay,
                    stored.intra_machine_delay,
                    stored.oracle
                );
                stored.clone()
            }
            None => eval,
        };
        let obj = crate::sim::fuzz::evaluate(&case.fixture, &case.schedule, &eval)?;
        println!(
            "frozen {} ticks | rebalanced {} ticks | gap {:.3}x | rollbacks {} | transfers {} | refinements {}",
            obj.frozen_ticks,
            obj.rebalanced_ticks,
            obj.gap,
            obj.rollbacks,
            obj.transfers,
            obj.refinements,
        );
        println!(
            "descent violations: {} | oracle divergence: {} | truncated: frozen {} / rebalanced {}",
            obj.descent_violations,
            obj.oracle_divergence,
            obj.frozen_truncated,
            obj.rebalanced_truncated,
        );
        if let Some(stored) = &case.objectives {
            if obj.bit_eq(stored) {
                println!("replay matches the stored objectives byte-for-byte");
            } else {
                return Err(format!(
                    "replay DIVERGED from stored objectives:\n  stored   {stored:?}\n  measured {obj:?}"
                )
                .into());
            }
        }
        if obj.is_bug() {
            return Err("replayed schedule exposes a bug-class finding (see above)".into());
        }
        return Ok(());
    }

    let options = FuzzOptions {
        budget,
        seed,
        fixture,
        horizon_ticks: horizon,
        thread_budget: threads,
        hop_limit: 4,
        eval,
        top_k,
        shrink: !args.flag("no-shrink"),
        verbose: true,
    };
    println!(
        "fuzzing drift schedules: budget {budget}, fixture (seed {seed}, {nodes} LPs, K={k}), \
         horizon {horizon}, {threads} threads, epoch {epoch_ticks}, framework {framework}"
    );
    let outcome = run_fuzz(&options)?;
    println!(
        "campaign done: {} evaluations, hand-written best gap {:.3}x",
        outcome.evaluations, outcome.handwritten_best_gap
    );
    for f in &outcome.found {
        println!(
            "  #{} {}: gap {:.3}x, score {:.3}, {} genes (from {}), {} threads{}",
            f.rank,
            f.name,
            f.objectives.gap,
            f.objectives.score(),
            f.schedule.genes.len(),
            f.genes_before_shrink,
            f.schedule.total_threads(),
            if f.objectives.is_bug() { "  [BUG-CLASS FINDING]" } else { "" },
        );
    }
    let written = save_corpus(std::path::Path::new(&corpus_dir), &outcome)?;
    for p in &written {
        println!("(wrote {})", p.display());
    }
    if outcome.beat_handwritten() {
        println!(
            "worst found schedule beats every hand-written scenario \
             ({:.3}x > {:.3}x)",
            outcome.found.first().map(|f| f.objectives.gap).unwrap_or(0.0),
            outcome.handwritten_best_gap
        );
    } else {
        println!(
            "note: no found schedule beat the hand-written best gap {:.3}x \
             (raise --budget to search longer)",
            outcome.handwritten_best_gap
        );
    }
    Ok(())
}

//! Implementations of the `gtip` subcommands, one module per command
//! family, plus the helpers they share. The thin dispatcher
//! (`super::cli`) only matches the subcommand name and hands the raw
//! [`Args`] to one of the `cmd_*` entry points re-exported here.

use crate::partition::MachineConfig;
use crate::util::cli::Args;

mod dynamic;
mod experiment;
mod fuzz;
mod partition;
mod sweeps;

pub(crate) use dynamic::{cmd_dynamic, cmd_serve, cmd_snapshot};
pub(crate) use experiment::{cmd_artifacts, cmd_experiment};
pub(crate) use fuzz::cmd_fuzz;
pub(crate) use partition::{cmd_partition, cmd_simulate};
pub(crate) use sweeps::{cmd_bench_gate, cmd_churn_sweep, cmd_hierarchy_bench};

/// CLI-level result: any error type boxes into it via `?`.
pub(crate) type CliResult = Result<(), Box<dyn std::error::Error>>;

/// Machine pool shared by the subcommands: heterogeneous if `--speeds`
/// is given, else `--k` identical machines.
pub(crate) fn machines_from_args(
    args: &Args,
) -> Result<MachineConfig, Box<dyn std::error::Error>> {
    if let Some(speeds) = args.opt_list::<f64>("speeds")? {
        Ok(MachineConfig::from_speeds(&speeds))
    } else {
        let k = args.opt_or::<usize>("k", 5)?;
        Ok(MachineConfig::homogeneous(k))
    }
}

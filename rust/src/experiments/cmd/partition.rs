//! The one-shot partitioning subcommands: `gtip partition` (build a
//! graph, refine it, report the cost ratio) and `gtip simulate` (run
//! the PDES engine over a fixed partition and print throughput).

use std::sync::Arc;

use crate::config::Config;
use crate::coordinator::{run_distributed, DistributedOptions};
use crate::game::annealing::{anneal_then_refine, AnnealOptions};
use crate::game::cost::Framework;
use crate::game::refine::{RefineEngine, RefineOptions};
use crate::graph::generators::{generate, GraphFamily};
use crate::partition::global_cost;
use crate::partition::initial::grow_partition;
use crate::sim::driver::{run_dynamic, DriverOptions};
use crate::sim::engine::SimOptions;
use crate::sim::workload::{FloodWorkload, WorkloadOptions};
use crate::util::cli::Args;
use crate::util::rng::Pcg32;

use super::{machines_from_args, CliResult};

pub(crate) fn cmd_partition(args: &Args) -> CliResult {
    let seed = args.opt_or::<u64>("seed", Config::default().seed)?;
    let mu = args.opt_or::<f64>("mu", 8.0)?;
    let framework: Framework = args.str_or("framework", "A").parse()?;
    let machines = machines_from_args(args)?;
    let mut rng = Pcg32::new(seed);

    let graph = if let Some(path) = args.opt_str("graph") {
        crate::graph::io::load_graph(path)?
    } else {
        let family: GraphFamily = args.str_or("family", "table1").parse()?;
        let nodes = args.opt_or::<usize>("nodes", 230)?;
        generate(family, nodes, &mut rng)
    };

    println!(
        "graph: {} nodes, {} edges; K={} machines; mu={mu}; framework {framework}",
        graph.node_count(),
        graph.edge_count(),
        machines.count()
    );
    let initial = grow_partition(&graph, &machines, &mut rng);
    let (c0_i, c0t_i) = global_cost::both(&graph, &machines, &initial, mu);
    println!("initial partition:   C0 = {c0_i:.0}   C~0 = {c0t_i:.0}   counts = {:?}", initial.counts());

    if args.flag("distributed") {
        let report = run_distributed(
            Arc::new(graph.clone()),
            &machines,
            initial,
            &DistributedOptions { mu, framework, ..Default::default() },
        );
        let (c0, c0t) = global_cost::both(&graph, &machines, &report.partition, mu);
        println!(
            "distributed refine:  C0 = {c0:.0}   C~0 = {c0t:.0}   transfers = {}   counts = {:?}",
            report.transfers,
            report.partition.counts()
        );
        println!(
            "sync overhead: {} msgs, {} bytes total, {:.1} bytes/transfer (O(K), N-independent)",
            report.overhead.total_messages(),
            report.overhead.total_bytes(),
            report.overhead.bytes_per_transfer(report.transfers as u64),
        );
    } else if args.flag("anneal") {
        let (part, potential) = anneal_then_refine(
            &graph,
            &machines,
            initial,
            mu,
            framework,
            &AnnealOptions::default(),
            &mut rng,
        );
        let (c0, c0t) = global_cost::both(&graph, &machines, &part, mu);
        println!(
            "anneal+refine:       C0 = {c0:.0}   C~0 = {c0t:.0}   potential = {potential:.0}   counts = {:?}",
            part.counts()
        );
    } else {
        let mut engine = RefineEngine::new(&graph, &machines, initial, mu, framework);
        let report = engine.run(&RefineOptions::default());
        let (c0, c0t) = global_cost::both(&graph, &machines, engine.partition(), mu);
        println!(
            "iterative refine:    C0 = {c0:.0}   C~0 = {c0t:.0}   transfers = {}   converged = {}   counts = {:?}",
            report.transfers,
            report.converged,
            engine.partition().counts()
        );
    }

    if let Some(path) = args.opt_str("save") {
        crate::graph::io::save_graph(&graph, path)?;
        println!("(saved graph to {path})");
    }
    Ok(())
}

pub(crate) fn cmd_simulate(args: &Args) -> CliResult {
    let seed = args.opt_or::<u64>("seed", 42)?;
    let family: GraphFamily = args.str_or("family", "pa").parse()?;
    let nodes = args.opt_or::<usize>("nodes", 230)?;
    let machines = machines_from_args(args)?;
    let refine_every = args.opt_or::<u64>("refine-every", 500)?;
    let framework: Framework = args.str_or("framework", "A").parse()?;
    let mu = args.opt_or::<f64>("mu", 8.0)?;
    let threads = args.opt_or::<usize>("threads", 150)?;
    let parallelism = args.opt_or::<usize>("parallelism", 1)?;

    let mut rng = Pcg32::new(seed);
    let graph = generate(family, nodes, &mut rng);
    let workload = FloodWorkload::generate(
        &graph,
        &WorkloadOptions { threads, ..Default::default() },
        &mut rng,
    );
    let driver = DriverOptions {
        sim: SimOptions { trace_every: 50, parallelism, ..Default::default() },
        refine_every,
        framework,
        mu,
        ticks_per_transfer: 0,
    };
    let report = run_dynamic(&graph, &machines, workload, &driver, &mut rng);
    println!(
        "simulation time: {} wall ticks  (events {}, forwards {}, cross-machine {}, rollbacks {}, anti-messages {})",
        report.total_time(),
        report.stats.events_processed,
        report.stats.events_forwarded,
        report.stats.cross_machine_forwards,
        report.stats.rollbacks,
        report.stats.antimessages_sent,
    );
    println!(
        "refinement epochs: {}   node transfers: {}   truncated: {}",
        report.refinements, report.transfers, report.stats.truncated
    );
    Ok(())
}

/// The closed-loop §6.1 title scenario: scripted drifting workload,
/// epoch-windowed load measurement, estimator-smoothed re-weighting,

//! The measurement subcommands: `gtip churn-sweep` (frozen vs
//! rebalanced across a churn grid), `gtip hierarchy-bench` (flat vs
//! rack-aware refinement), and `gtip bench-gate` (regression-check a
//! benchmark JSON against a baseline).

use std::sync::Arc;

use crate::coordinator::{run_distributed_hierarchical, DistributedOptions};
use crate::game::cost::Framework;
use crate::game::hierarchy::RackLayout;
use crate::graph::generators::{generate, GraphFamily};
use crate::partition::MachineConfig;
use crate::sim::dynamic::{CompareReport, DynamicDriver, DynamicOptions, WeightEstimator};
use crate::sim::engine::SimOptions;
use crate::sim::scenario::{ScenarioKind, MAX_SCHEDULE_THREADS};
use crate::util::bench::{parse_json, write_json_group, JsonVal};
use crate::util::cli::Args;
use crate::util::rng::Pcg32;

use super::CliResult;

pub(crate) fn cmd_churn_sweep(args: &Args) -> CliResult {
    let seed = args.opt_or::<u64>("seed", 2011)?;
    let nodes = args.opt_or::<usize>("nodes", 120)?;
    let k = args.opt_or::<usize>("k", 4)?;
    let threads = args.opt_or::<usize>("threads", 100)?;
    let horizon = args.opt_or::<u64>("horizon", 1_600)?;
    let epoch_ticks = args.opt_or::<u64>("epoch-ticks", 200)?;
    let framework: Framework = args.str_or("framework", "A").parse()?;
    let tick_value = args.opt_or::<f64>("tick-value", 1.0)?;
    let out = args.str_or("out", "results/BENCH_sim.json").to_string();
    if nodes == 0 || k == 0 || threads == 0 || horizon == 0 || epoch_ticks == 0 {
        return Err("--nodes, --k, --threads, --horizon, --epoch-ticks must be >= 1".into());
    }
    if threads as u64 > MAX_SCHEDULE_THREADS {
        return Err(format!("--threads must be <= {MAX_SCHEDULE_THREADS}").into());
    }
    if !(tick_value >= 0.0 && tick_value.is_finite()) {
        return Err("--tick-value must be finite and >= 0".into());
    }
    let charges: Vec<u64> =
        args.opt_list::<u64>("charges")?.unwrap_or_else(|| vec![0, 2, 8, 32]);
    if charges.is_empty() {
        return Err("--charges needs at least one level".into());
    }
    if charges.windows(2).any(|w| w[1] <= w[0]) {
        return Err("--charges must be strictly increasing".into());
    }
    let scenario_kinds: Vec<ScenarioKind> = args
        .str_or("scenarios", "hotspot,flash")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| s.parse::<ScenarioKind>())
        .collect::<Result<_, _>>()?;
    if scenario_kinds.is_empty() {
        return Err("--scenarios needs at least one scenario".into());
    }
    for (i, a) in scenario_kinds.iter().enumerate() {
        if scenario_kinds[..i].contains(a) {
            return Err(format!(
                "--scenarios lists {} twice (duplicate JSON keys in the report)",
                a.name()
            )
            .into());
        }
    }

    println!(
        "churn sweep: {} scenario(s), charges {:?} ticks/transfer (tick value {tick_value}), \
         {nodes} LPs, K={k}, {threads} floods over {horizon} ticks, epoch {epoch_ticks}, framework {framework}",
        scenario_kinds.len(),
        charges,
    );
    let mut group: Vec<(String, JsonVal)> = vec![
        ("smoke".into(), JsonVal::Bool(std::env::var("GTIP_BENCH_SMOKE").is_ok())),
        (
            "charges".into(),
            JsonVal::Arr(charges.iter().map(|&c| JsonVal::Int(c)).collect()),
        ),
    ];
    let mut strictly_decreasing_everywhere = 0usize;
    for kind in &scenario_kinds {
        let fixture = crate::util::testkit::ScenarioFixture::new(*kind, seed)
            .nodes(nodes)
            .machines(k)
            .threads(threads)
            .horizon(horizon)
            .build();
        println!("  {:<8} charge | transfers | migration_ticks | frozen | rebalanced | speedup", kind.name());
        // The frozen arm never refines, so it is charge-independent:
        // run it once per scenario and reuse it at every charge level.
        let frozen = DynamicDriver::new(
            &fixture.graph,
            fixture.machines.clone(),
            fixture.initial.clone(),
            fixture.scenario.injections.clone(),
            WeightEstimator::instantaneous(),
            DynamicOptions {
                sim: SimOptions { max_ticks: 2_000_000, ..Default::default() },
                epoch_ticks: 0,
                framework,
                ..Default::default()
            },
        )
        .run_owned();
        let mut rows: Vec<(String, JsonVal)> = Vec::new();
        let mut transfer_curve: Vec<u64> = Vec::new();
        for &charge in &charges {
            let options = DynamicOptions {
                sim: SimOptions { max_ticks: 2_000_000, ..Default::default() },
                epoch_ticks,
                framework,
                ..Default::default()
            }
            .charge_transfers(charge, tick_value);
            let rebalanced = DynamicDriver::new(
                &fixture.graph,
                fixture.machines.clone(),
                fixture.initial.clone(),
                fixture.scenario.injections.clone(),
                WeightEstimator::ewma(0.5),
                options,
            )
            .run_owned();
            let transfers = rebalanced.transfers as u64;
            let truncated = frozen.stats.truncated || rebalanced.stats.truncated;
            let speedup = CompareReport::speedup_of(frozen.total_time(), rebalanced.total_time());
            println!(
                "  {:<8} {:>6} | {:>9} | {:>15} | {:>6} | {:>10} | {:.3}x{}",
                kind.name(),
                charge,
                transfers,
                rebalanced.migration_ticks,
                frozen.total_time(),
                rebalanced.total_time(),
                speedup,
                if truncated { "  [TRUNCATED at the tick cap — numbers understate]" } else { "" },
            );
            transfer_curve.push(transfers);
            rows.push((
                format!("charge_{charge}"),
                JsonVal::Obj(vec![
                    ("transfers".into(), JsonVal::Int(transfers)),
                    ("migration_ticks".into(), JsonVal::Int(rebalanced.migration_ticks)),
                    ("frozen_ticks".into(), JsonVal::Int(frozen.total_time())),
                    ("rebalanced_ticks".into(), JsonVal::Int(rebalanced.total_time())),
                    ("speedup".into(), JsonVal::Num(speedup)),
                    ("truncated".into(), JsonVal::Bool(truncated)),
                ]),
            ));
        }
        // "Strictly decreasing" with two refinements: it needs at least
        // one real comparison (a single-level sweep can't vacuously
        // claim it), and a 0 -> 0 plateau at high charges counts — the
        // balancer is fully damped, which is the behavior the flag
        // exists to demonstrate, not a violation of it.
        let strictly_decreasing = transfer_curve.len() >= 2
            && transfer_curve.windows(2).all(|w| w[1] < w[0] || (w[0] == 0 && w[1] == 0));
        if strictly_decreasing {
            strictly_decreasing_everywhere += 1;
        }
        rows.push((
            "transfers_strictly_decreasing".into(),
            JsonVal::Bool(strictly_decreasing),
        ));
        group.push((kind.name().to_string(), JsonVal::Obj(rows)));
    }
    println!(
        "transfers strictly decreasing with the charge on {strictly_decreasing_everywhere}/{} scenario(s)",
        scenario_kinds.len()
    );
    let path = write_json_group(&out, "churn_tradeoff", &JsonVal::Obj(group))?;
    println!("(merged churn_tradeoff into {})", path.display());
    Ok(())
}

/// Measure the two-level hierarchy's coordination overhead (DESIGN.md
/// §12): run the in-process hierarchical refinement over several graph
/// sizes on a fixed fleet/rack layout and merge a `hierarchy` group
/// into the bench report. The table demonstrates the O(K_rack +
/// K_machine) claim: a cross-rack `RackUpdate` costs exactly `33 + 8R`
/// framed bytes — scaling with the rack count R, not the machine count
/// K, and independent of N — while the inner games' `RegularUpdate`s
/// stay at the flat `33 + 8K`.
pub(crate) fn cmd_hierarchy_bench(args: &Args) -> CliResult {
    let seed = args.opt_or::<u64>("seed", 2011)?;
    let k = args.opt_or::<usize>("k", 9)?;
    let mu = args.opt_or::<f64>("mu", 8.0)?;
    let framework: Framework = args.str_or("framework", "A").parse()?;
    let out = args.str_or("out", "results/BENCH_sim.json").to_string();
    let sizes: Vec<usize> =
        args.opt_list::<usize>("sizes")?.unwrap_or_else(|| vec![120, 240, 360]);
    if sizes.is_empty() || sizes.iter().any(|&n| n == 0) {
        return Err("--sizes needs at least one size, all >= 1".into());
    }
    if k == 0 {
        return Err("--k must be >= 1".into());
    }
    // Default: K=9 over R=3 equal racks. A 2-rack outer ring never
    // broadcasts a RackUpdate (a transfer notifies only its
    // counterpart, via ReceiveNode), so the measurable default keeps
    // R >= 3.
    let layout = match args.opt_str("racks") {
        Some(spec) => RackLayout::parse(spec, k)?,
        None => {
            let per = k.div_ceil(3);
            RackLayout::new((0..k).map(|m| m / per).collect())?
        }
    };
    let racks = layout.rack_count();
    println!(
        "hierarchy bench: K={k} machines over R={racks} racks, sizes {sizes:?}, \
         framework {framework}, mu={mu}"
    );

    let mut group: Vec<(String, JsonVal)> = vec![
        ("smoke".into(), JsonVal::Bool(std::env::var("GTIP_BENCH_SMOKE").is_ok())),
        ("machines".into(), JsonVal::Int(k as u64)),
        ("racks".into(), JsonVal::Int(racks as u64)),
    ];
    println!("       N | transfers | rack_update msgs | bytes/RackUpdate | bytes/RegularUpdate");
    let mut per_message: Vec<f64> = Vec::new();
    for &n in &sizes {
        let mut rng = Pcg32::new(seed);
        let graph = generate(GraphFamily::PreferentialAttachment, n, &mut rng);
        let machines = MachineConfig::homogeneous(k);
        // A uniform random start (not the balanced grower) so the
        // outer game has genuine cross-rack imbalance to descend —
        // otherwise zero RackUpdates flow and there is nothing to
        // measure.
        let assignment: Vec<usize> = (0..n).map(|_| rng.index(k)).collect();
        let initial =
            crate::partition::Partition::from_assignment(&graph, k, assignment);
        let report = run_distributed_hierarchical(
            Arc::new(graph),
            &machines,
            initial,
            &layout,
            &DistributedOptions { mu, framework, ..Default::default() },
        );
        let o = &report.overhead;
        println!(
            "  {n:>6} | {:>9} | {:>16} | {:>16.1} | {:>19.1}",
            report.transfers,
            o.rack_update.messages,
            o.bytes_per_rack_update(),
            o.bytes_per_regular_update(),
        );
        if o.rack_update.messages > 0 {
            per_message.push(o.bytes_per_rack_update());
        }
        group.push((
            format!("n_{n}"),
            JsonVal::Obj(vec![
                ("transfers".into(), JsonVal::Int(report.transfers as u64)),
                ("converged".into(), JsonVal::Bool(report.converged)),
                ("rack_update_messages".into(), JsonVal::Int(o.rack_update.messages)),
                ("rack_update_bytes".into(), JsonVal::Int(o.rack_update.bytes)),
                (
                    "rack_update_bytes_per_message".into(),
                    JsonVal::Num(o.bytes_per_rack_update()),
                ),
                (
                    "regular_update_bytes_per_message".into(),
                    JsonVal::Num(o.bytes_per_regular_update()),
                ),
                ("total_bytes".into(), JsonVal::Int(o.total_bytes())),
            ]),
        ));
    }
    // The headline check: every observed cross-rack aggregate frame is
    // exactly 33 + 8R bytes — flat across N (and across K at fixed R).
    let expected = (33 + 8 * racks) as f64;
    let flat = !per_message.is_empty() && per_message.iter().all(|&b| b == expected);
    println!(
        "cross-rack aggregate bytes/message: expected {expected} (33 + 8R), flat across N: {flat}"
    );
    group.push(("rack_update_bytes_expected".into(), JsonVal::Num(expected)));
    group.push(("rack_update_bytes_flat_across_n".into(), JsonVal::Bool(flat)));
    if !flat {
        return Err(format!(
            "hierarchy bench: cross-rack aggregate bytes not flat at 33+8R={expected}: {per_message:?}"
        )
        .into());
    }
    let path = write_json_group(&out, "hierarchy", &JsonVal::Obj(group))?;
    println!("(merged hierarchy into {})", path.display());
    Ok(())
}

/// Schema gate for the bench trajectory: every group/key present in
/// the committed baseline must appear in the measured report, so a
/// bench that silently stops emitting a metric fails CI instead of
/// shipping an empty trajectory.
pub(crate) fn cmd_bench_gate(args: &Args) -> CliResult {
    let baseline_path = args.str_or("baseline", "results/BENCH_baseline.json");
    let measured_path = args.str_or("measured", "results/BENCH_sim.json");
    let baseline = parse_json(&std::fs::read_to_string(baseline_path).map_err(|e| {
        format!("reading baseline {baseline_path}: {e}")
    })?)
    .map_err(|e| format!("parsing {baseline_path}: {e}"))?;
    let measured = parse_json(&std::fs::read_to_string(measured_path).map_err(|e| {
        format!("reading measured {measured_path}: {e}")
    })?)
    .map_err(|e| format!("parsing {measured_path}: {e}"))?;

    let mut missing = Vec::new();
    fn walk(baseline: &JsonVal, measured: &JsonVal, path: &str, missing: &mut Vec<String>) {
        if let JsonVal::Obj(kvs) = baseline {
            for (k, sub) in kvs {
                let child = if path.is_empty() { k.clone() } else { format!("{path}.{k}") };
                match measured.get(k) {
                    Some(m) => walk(sub, m, &child, missing),
                    None => missing.push(child),
                }
            }
        }
    }
    walk(&baseline, &measured, "", &mut missing);
    if missing.is_empty() {
        println!("bench gate OK: {measured_path} covers every key of {baseline_path}");
        Ok(())
    } else {
        for m in &missing {
            eprintln!("bench gate: {measured_path} is missing {m}");
        }
        Err(format!(
            "schema regression: {} key(s) present in {baseline_path} but absent from {measured_path}",
            missing.len()
        )
        .into())
    }
}

/// Adversarial scenario fuzzing (`sim::fuzz`): search the drift-schedule
/// genome space for worst-case workloads, shrink the winners, and

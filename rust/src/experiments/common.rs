//! Shared experiment plumbing: paper-default setups and a refinement
//! runner that tracks *both* global costs per step (needed for the
//! §5.1 discrepancy statistics).

use crate::game::cost::{CostModel, Framework};
use crate::game::refine::{RefineEngine, RefineOptions};
use crate::graph::generators::{table1_graph, WeightModel};
use crate::graph::Graph;
use crate::partition::initial::grow_partition;
use crate::partition::{global_cost, MachineConfig, Partition};
use crate::util::rng::Pcg32;

/// Paper §5.1 study setup: N=230 LPs, degrees 3–6, node/edge weights of
/// mean 5, K=5 machines with normalized speeds (.1,.2,.3,.3,.1), μ=8.
#[derive(Debug, Clone)]
pub struct StudySetup {
    pub nodes: usize,
    pub machines: MachineConfig,
    pub mu: f64,
}

impl Default for StudySetup {
    fn default() -> Self {
        StudySetup {
            nodes: 230,
            machines: MachineConfig::from_speeds(&[0.1, 0.2, 0.3, 0.3, 0.1]),
            mu: 8.0,
        }
    }
}

impl StudySetup {
    /// Generate the §5.1 random graph for this setup.
    pub fn graph(&self, rng: &mut Pcg32) -> Graph {
        table1_graph(self.nodes, 3, 6, WeightModel::default(), rng)
    }

    /// App. A initial partition (shared between framework arms so the
    /// comparison is from identical starts, as the paper requires).
    pub fn initial(&self, graph: &Graph, rng: &mut Pcg32) -> Partition {
        grow_partition(graph, &self.machines, rng)
    }
}

/// Result of one tracked refinement run.
#[derive(Debug, Clone)]
pub struct TrackedRun {
    pub framework: Framework,
    /// Node transfers to convergence ("iterations" in Table I).
    pub iterations: usize,
    /// Final C0 (framework A's global cost).
    pub c0: f64,
    /// Final C̃0 (framework B's global cost).
    pub c0_tilde: f64,
    /// Steps that *increased* C0 (only possible under framework B) —
    /// "C0-discrepancies" in §5.1.
    pub c0_discrepancies: usize,
    /// Steps that *increased* C̃0 (only possible under framework A) —
    /// "C̃0-discrepancies".
    pub c0_tilde_discrepancies: usize,
}

/// Run refinement to convergence under `framework`, tracking both global
/// costs exactly via the per-move identities (Thm 3.1 / Thm 5.1) — no
/// from-scratch recomputation per step.
pub fn run_tracked(
    graph: &Graph,
    machines: &MachineConfig,
    initial: Partition,
    mu: f64,
    framework: Framework,
) -> TrackedRun {
    let other = match framework {
        Framework::A => Framework::B,
        Framework::B => Framework::A,
    };
    let other_model = CostModel::new(graph, machines.clone(), mu, other);
    let mut engine = RefineEngine::new(graph, machines, initial, mu, framework);

    let k = machines.count();
    let mut c0_disc = 0;
    let mut c0t_disc = 0;
    let mut iterations = 0;
    let mut consecutive_forfeits = 0;
    let mut turn = 0usize;
    let epsilon = RefineOptions::default().epsilon;
    let cap = 100_000;

    while consecutive_forfeits < k && iterations < cap {
        let m = turn % k;
        turn += 1;
        match engine.most_dissatisfied(m, epsilon) {
            None => consecutive_forfeits += 1,
            Some((node, _j, target)) => {
                consecutive_forfeits = 0;
                // Exact delta of the *other* framework's global cost.
                let other_delta = other_model.potential_delta(engine.partition(), node, target);
                match framework {
                    Framework::A if other_delta > 1e-9 => c0t_disc += 1,
                    Framework::B if other_delta > 1e-9 => c0_disc += 1,
                    _ => {}
                }
                engine.apply_transfer(node, target);
                iterations += 1;
            }
        }
    }

    let c0 = global_cost::c0(graph, machines, engine.partition(), mu);
    let c0_tilde = global_cost::c0_tilde(graph, machines, engine.partition(), mu);
    TrackedRun {
        framework,
        iterations,
        c0,
        c0_tilde,
        c0_discrepancies: c0_disc,
        c0_tilde_discrepancies: c0t_disc,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracked_run_matches_engine_run() {
        let setup = StudySetup::default();
        let mut rng = Pcg32::new(1);
        let g = setup.graph(&mut rng);
        let initial = setup.initial(&g, &mut rng);

        let tracked = run_tracked(&g, &setup.machines, initial.clone(), setup.mu, Framework::A);
        let mut engine =
            RefineEngine::new(&g, &setup.machines, initial, setup.mu, Framework::A);
        let report = engine.run(&RefineOptions::default());
        assert_eq!(tracked.iterations, report.transfers);
        assert!((tracked.c0 - report.final_potential).abs() < 1e-6 * (1.0 + tracked.c0.abs()));
    }

    #[test]
    fn discrepancies_only_on_other_framework() {
        let setup = StudySetup::default();
        let mut rng = Pcg32::new(2);
        let g = setup.graph(&mut rng);
        let initial = setup.initial(&g, &mut rng);
        let a = run_tracked(&g, &setup.machines, initial.clone(), setup.mu, Framework::A);
        let b = run_tracked(&g, &setup.machines, initial, setup.mu, Framework::B);
        // Under A, C0 descends monotonically: no C0 discrepancies possible.
        assert_eq!(a.c0_discrepancies, 0);
        // Under B, C̃0 descends monotonically.
        assert_eq!(b.c0_tilde_discrepancies, 0);
    }
}

//! Figs. 9 and 10 (§6.1): per-machine load (mean event-list length per
//! resident LP) over wall-clock time, without refinement (Fig. 9) vs
//! with refinement every 500 ticks (Fig. 10). The with-refinement traces
//! should be visibly tighter; we also quantify it via the
//! time-averaged coefficient of variation across machines.

use crate::game::cost::Framework;
use crate::graph::generators::{generate, GraphFamily};
use crate::sim::driver::{run_dynamic, DriverOptions};
use crate::sim::engine::SimOptions;
use crate::sim::workload::{FloodWorkload, WorkloadOptions};
use crate::util::rng::Pcg32;
use crate::util::stats::{ascii_chart, coeff_of_variation, traces_to_csv, Trace};

/// Result of one arm (Fig. 9 or Fig. 10).
#[derive(Debug, Clone)]
pub struct LoadTraceReport {
    pub refine_every: u64,
    pub sim_time: u64,
    pub traces: Vec<Trace>,
    /// Mean across time of the cross-machine load CoV (0 = perfectly
    /// balanced at every sampled instant).
    pub mean_cov: f64,
}

/// Compute the time-averaged cross-machine coefficient of variation.
pub fn mean_cross_machine_cov(traces: &[Trace]) -> f64 {
    if traces.is_empty() {
        return 0.0;
    }
    let len = traces.iter().map(|t| t.points.len()).min().unwrap_or(0);
    if len == 0 {
        return 0.0;
    }
    let mut covs = Vec::with_capacity(len);
    for i in 0..len {
        let sample: Vec<f64> = traces.iter().map(|t| t.points[i].1).collect();
        // Skip all-idle instants (mean 0 has no meaningful imbalance).
        if sample.iter().sum::<f64>() > 1e-9 {
            covs.push(coeff_of_variation(&sample));
        }
    }
    if covs.is_empty() {
        0.0
    } else {
        covs.iter().sum::<f64>() / covs.len() as f64
    }
}

/// Run one arm with load tracing on.
pub fn run_arm(
    family: GraphFamily,
    nodes: usize,
    machines: usize,
    refine_every: u64,
    seed: u64,
    quick: bool,
) -> LoadTraceReport {
    let mut rng = Pcg32::new(seed);
    let graph = generate(family, nodes, &mut rng);
    let machine_cfg = crate::partition::MachineConfig::homogeneous(machines);
    let workload = FloodWorkload::generate(
        &graph,
        &WorkloadOptions {
            threads: if quick { 80 } else { 150 },
            horizon_ticks: if quick { 1500 } else { 4000 },
            hot_spot_period: 500,
            ..Default::default()
        },
        &mut rng,
    );
    let driver = DriverOptions {
        sim: SimOptions { trace_every: 50, max_ticks: 400_000, ..Default::default() },
        refine_every,
        framework: Framework::A,
        mu: 8.0,
        ticks_per_transfer: 0,
    };
    let report = run_dynamic(&graph, &machine_cfg, workload, &driver, &mut rng);
    let mean_cov = mean_cross_machine_cov(&report.load_traces);
    LoadTraceReport {
        refine_every,
        sim_time: report.total_time(),
        traces: report.load_traces,
        mean_cov,
    }
}

/// CLI entry: runs both arms from the same seed and prints both figures.
pub fn run_and_report(seed: u64, quick: bool) -> (LoadTraceReport, LoadTraceReport) {
    let nodes = if quick { 150 } else { 230 };
    let fig9 = run_arm(GraphFamily::PreferentialAttachment, nodes, 5, 0, seed, quick);
    let fig10 = run_arm(GraphFamily::PreferentialAttachment, nodes, 5, 500, seed, quick);

    println!("### Fig. 9 — machine loads, NO refinement (sim time {} ticks)", fig9.sim_time);
    println!("{}", ascii_chart(&fig9.traces, 60, 10));
    println!("### Fig. 10 — machine loads, refinement every 500 ticks (sim time {} ticks)", fig10.sim_time);
    println!("{}", ascii_chart(&fig10.traces, 60, 10));
    println!(
        "time-averaged cross-machine load CoV: no-refine {:.3} vs refine {:.3} (lower = more balanced)",
        fig9.mean_cov, fig10.mean_cov
    );

    let _ = std::fs::create_dir_all("results");
    let _ = std::fs::write("results/fig9_loads.csv", traces_to_csv(&fig9.traces));
    let _ = std::fs::write("results/fig10_loads.csv", traces_to_csv(&fig10.traces));
    println!("(wrote results/fig9_loads.csv, results/fig10_loads.csv)");
    (fig9, fig10)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn refinement_balances_loads() {
        let fig9 = run_arm(GraphFamily::PreferentialAttachment, 100, 4, 0, 11, true);
        let fig10 = run_arm(GraphFamily::PreferentialAttachment, 100, 4, 500, 11, true);
        assert!(!fig9.traces.is_empty() && !fig10.traces.is_empty());
        assert!(
            fig10.mean_cov < fig9.mean_cov,
            "refined run should be more balanced: {} vs {}",
            fig10.mean_cov,
            fig9.mean_cov
        );
    }

    #[test]
    fn traces_have_one_series_per_machine() {
        let r = run_arm(GraphFamily::PreferentialAttachment, 80, 3, 0, 13, true);
        assert_eq!(r.traces.len(), 3);
        for t in &r.traces {
            assert!(!t.is_empty());
        }
    }

    #[test]
    fn cov_of_identical_traces_is_zero() {
        let mut t1 = Trace::new("a");
        let mut t2 = Trace::new("b");
        for i in 0..10 {
            t1.push(i as f64, 5.0);
            t2.push(i as f64, 5.0);
        }
        assert!(mean_cross_machine_cov(&[t1, t2]) < 1e-12);
    }
}

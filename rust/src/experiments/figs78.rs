//! Figs. 7 and 8 (§6.1): total simulation execution time vs partition-
//! refinement frequency, on a preferential-attachment graph (Fig. 7) and
//! the specialized geometric graph (Fig. 8). Series: framework A,
//! framework B, and the no-refinement baseline; averaged over seeds.

use crate::game::cost::Framework;
use crate::graph::generators::{generate, GraphFamily};
use crate::sim::driver::{run_dynamic, DriverOptions};
use crate::sim::engine::SimOptions;
use crate::sim::workload::{FloodWorkload, WorkloadOptions};
use crate::util::rng::Pcg32;
use crate::util::stats::{ascii_chart, Trace};
use crate::util::table::Table;

/// Sweep parameters.
#[derive(Debug, Clone)]
pub struct SweepOptions {
    pub family: GraphFamily,
    pub nodes: usize,
    pub machines: usize,
    pub mu: f64,
    /// Refinement periods to sweep (0 is added automatically as the
    /// no-refinement baseline).
    pub periods: Vec<u64>,
    pub seeds: usize,
    pub workload: WorkloadOptions,
    pub sim: SimOptions,
}

impl SweepOptions {
    pub fn paper_default(family: GraphFamily) -> SweepOptions {
        SweepOptions {
            family,
            nodes: 230,
            machines: 5,
            mu: 8.0,
            periods: vec![2000, 1000, 500, 250],
            seeds: 3,
            workload: WorkloadOptions {
                threads: 150,
                horizon_ticks: 4000,
                hot_spot_period: 500,
                ..Default::default()
            },
            sim: SimOptions { max_ticks: 400_000, ..Default::default() },
        }
    }
}

/// One point of the figure.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// Refinement period in wall ticks (0 = never).
    pub period: u64,
    /// Refinements per 1000 ticks — the x-axis as a *frequency*, the way
    /// the paper plots it.
    pub frequency: f64,
    pub mean_time_a: f64,
    pub mean_time_b: f64,
    pub mean_time_none: f64,
    pub mean_rollbacks_a: f64,
    pub mean_rollbacks_none: f64,
}

#[derive(Debug, Clone)]
pub struct SweepReport {
    pub family: GraphFamily,
    pub points: Vec<SweepPoint>,
}

impl SweepReport {
    pub fn to_table(&self, title: &str) -> Table {
        let mut t = Table::new(
            title,
            &[
                "refine-period",
                "freq/1k-ticks",
                "sim-time A",
                "sim-time B",
                "sim-time none",
                "rollbacks A",
                "rollbacks none",
            ],
        );
        for p in &self.points {
            t.row(&[
                if p.period == 0 { "never".into() } else { p.period.to_string() },
                format!("{:.2}", p.frequency),
                format!("{:.0}", p.mean_time_a),
                format!("{:.0}", p.mean_time_b),
                format!("{:.0}", p.mean_time_none),
                format!("{:.0}", p.mean_rollbacks_a),
                format!("{:.0}", p.mean_rollbacks_none),
            ]);
        }
        t
    }

    /// Does simulation time with refinement beat the baseline at the
    /// highest swept frequency? (The headline claim of Figs. 7/8.)
    pub fn refinement_helps(&self) -> bool {
        self.points
            .iter()
            .filter(|p| p.period > 0)
            .all(|p| p.mean_time_a < p.mean_time_none * 1.02)
            && self
                .points
                .iter()
                .filter(|p| p.period > 0)
                .any(|p| p.mean_time_a < 0.9 * p.mean_time_none)
    }
}

fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len().max(1) as f64
}

/// Run the sweep.
pub fn run(options: &SweepOptions, seed: u64) -> SweepReport {
    let mut points = Vec::new();
    for &period in &options.periods {
        let mut times_a = Vec::new();
        let mut times_b = Vec::new();
        let mut times_none = Vec::new();
        let mut rb_a = Vec::new();
        let mut rb_none = Vec::new();
        for s in 0..options.seeds {
            let arm_seed = seed.wrapping_add(1000 * s as u64);
            // Same graph + workload + initial-partition seed per arm.
            for (arm, fw) in
                [(0, Some(Framework::A)), (1, Some(Framework::B)), (2, None)]
            {
                let mut rng = Pcg32::new(arm_seed);
                let graph = generate(options.family, options.nodes, &mut rng);
                let machines =
                    crate::partition::MachineConfig::homogeneous(options.machines);
                let workload = FloodWorkload::generate(&graph, &options.workload, &mut rng);
                let driver = DriverOptions {
                    sim: options.sim.clone(),
                    refine_every: if fw.is_some() { period } else { 0 },
                    framework: fw.unwrap_or(Framework::A),
                    mu: options.mu,
                    ticks_per_transfer: 0,
                };
                let report = run_dynamic(&graph, &machines, workload, &driver, &mut rng);
                let time = report.total_time() as f64;
                match arm {
                    0 => {
                        times_a.push(time);
                        rb_a.push(report.stats.rollbacks as f64);
                    }
                    1 => times_b.push(time),
                    _ => {
                        times_none.push(time);
                        rb_none.push(report.stats.rollbacks as f64);
                    }
                }
            }
        }
        points.push(SweepPoint {
            period,
            frequency: if period == 0 { 0.0 } else { 1000.0 / period as f64 },
            mean_time_a: mean(&times_a),
            mean_time_b: mean(&times_b),
            mean_time_none: mean(&times_none),
            mean_rollbacks_a: mean(&rb_a),
            mean_rollbacks_none: mean(&rb_none),
        });
    }
    points.sort_by(|a, b| a.frequency.partial_cmp(&b.frequency).expect("finite"));
    SweepReport { family: options.family, points }
}

/// CLI entry for Fig. 7 (preferential attachment) / Fig. 8 (geometric).
pub fn run_and_report(family: GraphFamily, seed: u64, quick: bool) -> SweepReport {
    let mut options = SweepOptions::paper_default(family);
    if quick {
        options.seeds = 1;
        options.nodes = 150;
        options.workload.threads = 80;
    }
    let (figure, csv) = match family {
        GraphFamily::PreferentialAttachment => {
            ("Fig. 7 — simulation time vs refinement frequency (preferential attachment)", "fig7")
        }
        GraphFamily::Geometric => {
            ("Fig. 8 — simulation time vs refinement frequency (specialized geometric)", "fig8")
        }
        _ => ("simulation time vs refinement frequency", "fig78_custom"),
    };
    let report = run(&options, seed);
    let table = report.to_table(figure);
    println!("{}", table.to_text());

    // ASCII rendition of the figure: one series per arm over frequency.
    let mut tr_a = Trace::new("frameworkA");
    let mut tr_b = Trace::new("frameworkB");
    let mut tr_n = Trace::new("no-refine");
    for p in &report.points {
        tr_a.push(p.frequency, p.mean_time_a);
        tr_b.push(p.frequency, p.mean_time_b);
        tr_n.push(p.frequency, p.mean_time_none);
    }
    println!("{}", ascii_chart(&[tr_a, tr_b, tr_n], 48, 12));
    println!(
        "refinement helps: {} (paper: simulation time decreases with refinement frequency)",
        report.refinement_helps()
    );
    if let Ok(path) = table.write_csv(csv) {
        println!("(wrote {})", path.display());
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_options(family: GraphFamily) -> SweepOptions {
        SweepOptions {
            family,
            nodes: 100,
            machines: 4,
            mu: 8.0,
            periods: vec![400],
            seeds: 1,
            workload: WorkloadOptions {
                threads: 60,
                horizon_ticks: 1500,
                hot_spot_period: 400,
                ..Default::default()
            },
            sim: SimOptions { max_ticks: 200_000, ..Default::default() },
        }
    }

    #[test]
    fn fig7_shape_refinement_beats_baseline() {
        let report = run(&quick_options(GraphFamily::PreferentialAttachment), 5);
        assert_eq!(report.points.len(), 1);
        let p = &report.points[0];
        assert!(
            p.mean_time_a < p.mean_time_none,
            "refinement must beat no-refinement: {} vs {}",
            p.mean_time_a,
            p.mean_time_none
        );
    }

    #[test]
    fn fig8_shape_refinement_beats_baseline() {
        let report = run(&quick_options(GraphFamily::Geometric), 6);
        let p = &report.points[0];
        assert!(
            p.mean_time_a < p.mean_time_none,
            "refinement must beat no-refinement: {} vs {}",
            p.mean_time_a,
            p.mean_time_none
        );
    }

    #[test]
    fn points_sorted_by_frequency() {
        let mut opts = quick_options(GraphFamily::PreferentialAttachment);
        opts.periods = vec![400, 800];
        let report = run(&opts, 7);
        assert!(report.points[0].frequency <= report.points[1].frequency);
    }
}

//! Experiment harnesses regenerating every table and figure of the
//! paper's evaluation (see DESIGN.md §4 for the experiment index):
//!
//! * [`table1`] — §5.1 Table I: the two cost frameworks head-to-head on
//!   5 random graphs (C0, C̃0, iterations at convergence).
//! * [`batch`] — §5.1 batch study: 50 realizations × 10 initial
//!   partitions; win counts and discrepancy statistics.
//! * [`figs78`] — Figs. 7/8: total simulation time vs refinement
//!   frequency on preferential-attachment / geometric graphs.
//! * [`fig9_10`] — Figs. 9/10: machine-load traces with and without
//!   refinement.
//!
//! Each harness prints the paper-shaped table/series, writes CSV into
//! `results/`, and returns a structured report for tests/benches.

pub mod ablation;
pub mod batch;
pub mod cli;
mod cmd;
pub mod common;
pub mod fig9_10;
pub mod figs78;
pub mod table1;

//! Table I (§5.1): five random graph realizations; for each, iterative
//! refinement under Framework A and Framework B from the *same* initial
//! partition and turn order; report `C0`, `C̃0` and iterations to
//! convergence at the equilibrium each framework reaches.

use crate::experiments::common::{run_tracked, StudySetup, TrackedRun};
use crate::game::cost::Framework;
use crate::util::rng::Pcg32;
use crate::util::table::Table;

/// One trial row.
#[derive(Debug, Clone)]
pub struct Trial {
    pub trial: usize,
    pub a: TrackedRun,
    pub b: TrackedRun,
}

/// Full experiment result.
#[derive(Debug, Clone)]
pub struct Table1Report {
    pub trials: Vec<Trial>,
}

impl Table1Report {
    /// How many trials framework A won on both global costs (the paper
    /// observes A winning on both in all 5 trials).
    pub fn a_wins_both(&self) -> usize {
        self.trials
            .iter()
            .filter(|t| t.a.c0 <= t.b.c0 && t.a.c0_tilde <= t.b.c0_tilde)
            .count()
    }

    pub fn to_table(&self) -> Table {
        let mut table = Table::new(
            "Table I — comparison of the two cost frameworks (C0 / C~0 at convergence)",
            &[
                "trial",
                "A: C0",
                "A: C~0",
                "A: iters",
                "B: C0",
                "B: C~0",
                "B: iters",
            ],
        );
        for t in &self.trials {
            table.row(&[
                t.trial.to_string(),
                format!("{:.0}", t.a.c0),
                format!("{:.0}", t.a.c0_tilde),
                t.a.iterations.to_string(),
                format!("{:.0}", t.b.c0),
                format!("{:.0}", t.b.c0_tilde),
                t.b.iterations.to_string(),
            ]);
        }
        table
    }
}

/// Run the experiment: `trials` realizations from `seed`.
pub fn run(setup: &StudySetup, trials: usize, seed: u64) -> Table1Report {
    let mut out = Vec::with_capacity(trials);
    for trial in 1..=trials {
        let mut rng = Pcg32::new(seed.wrapping_add(trial as u64));
        let graph = setup.graph(&mut rng);
        let initial = setup.initial(&graph, &mut rng);
        let a = run_tracked(&graph, &setup.machines, initial.clone(), setup.mu, Framework::A);
        let b = run_tracked(&graph, &setup.machines, initial, setup.mu, Framework::B);
        out.push(Trial { trial, a, b });
    }
    Table1Report { trials: out }
}

/// CLI entry: print + persist.
pub fn run_and_report(seed: u64) -> Table1Report {
    let setup = StudySetup::default();
    let report = run(&setup, 5, seed);
    let table = report.to_table();
    println!("{}", table.to_text());
    println!(
        "Framework A best on BOTH global costs in {}/{} trials (paper: 5/5)",
        report.a_wins_both(),
        report.trials.len()
    );
    if let Ok(path) = table.write_csv("table1") {
        println!("(wrote {})", path.display());
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_setup() -> StudySetup {
        // Smaller N for test speed; same structure.
        StudySetup { nodes: 120, ..Default::default() }
    }

    #[test]
    fn five_trials_produced() {
        let report = run(&small_setup(), 5, 42);
        assert_eq!(report.trials.len(), 5);
        for t in &report.trials {
            assert!(t.a.iterations > 0 || t.b.iterations > 0);
            assert!(t.a.c0 > 0.0 && t.b.c0 > 0.0);
        }
    }

    #[test]
    fn framework_a_usually_wins_both_costs() {
        // Paper: A wins on both costs in 5/5 Table-I trials (and 49/50 in
        // the batch study). Allow one upset on small graphs.
        let report = run(&small_setup(), 5, 7);
        assert!(
            report.a_wins_both() >= 3,
            "A won both costs only {}/5 times",
            report.a_wins_both()
        );
    }

    #[test]
    fn table_renders_with_all_columns() {
        let report = run(&small_setup(), 2, 1);
        let txt = report.to_table().to_text();
        assert!(txt.contains("A: C0"));
        assert_eq!(txt.lines().count(), 2 + 2 + 1); // title + header + sep + rows
    }
}

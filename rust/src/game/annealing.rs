//! Simulated-annealing meta-heuristic (paper §4.4).
//!
//! The refinement engine converges to a *local* optimum of the potential.
//! §4.4 points to (distributed) simulated annealing [Kirkpatrick et al.
//! 1983; Bertsimas & Tsitsiklis 1993] as a way to escape poor local
//! minima, citing ≈5 % cost improvements in the literature. This module
//! implements a standard geometric-cooling annealer over single-node
//! moves using the exact O(deg + K) potential deltas from
//! [`CostModel::potential_delta`], plus a convenience pipeline that
//! anneals and then re-runs best-response refinement to land on a Nash
//! equilibrium again.

use crate::game::cost::{CostModel, Framework};
use crate::game::refine::{RefineEngine, RefineOptions};
use crate::graph::Graph;
use crate::partition::{MachineConfig, Partition};
use crate::util::rng::Pcg32;

/// Annealing schedule parameters.
#[derive(Debug, Clone)]
pub struct AnnealOptions {
    /// Starting temperature as a fraction of the initial potential
    /// (scale-free: T0 = `initial_temp_frac · |potential|`).
    pub initial_temp_frac: f64,
    /// Geometric cooling factor per sweep.
    pub cooling: f64,
    /// Proposed moves per sweep (a "sweep" ≈ N proposals if set to N).
    pub moves_per_sweep: usize,
    /// Number of sweeps.
    pub sweeps: usize,
}

impl Default for AnnealOptions {
    fn default() -> Self {
        AnnealOptions { initial_temp_frac: 1e-3, cooling: 0.9, moves_per_sweep: 256, sweeps: 40 }
    }
}

/// Outcome of an annealing run.
#[derive(Debug, Clone)]
pub struct AnnealReport {
    pub proposed: usize,
    pub accepted: usize,
    pub uphill_accepted: usize,
    pub start_potential: f64,
    pub final_potential: f64,
}

/// Anneal `part` in place under the given framework's potential.
pub fn anneal(
    graph: &Graph,
    machines: &MachineConfig,
    part: &mut Partition,
    mu: f64,
    framework: Framework,
    options: &AnnealOptions,
    rng: &mut Pcg32,
) -> AnnealReport {
    let model = CostModel::new(graph, machines.clone(), mu, framework);
    let k = machines.count();
    let n = graph.node_count();
    let start_potential = model.potential(part);
    let mut potential = start_potential;
    let mut temp = options.initial_temp_frac * start_potential.abs().max(1.0);

    let mut proposed = 0;
    let mut accepted = 0;
    let mut uphill_accepted = 0;

    // Track the best assignment seen so we never return worse than start.
    let mut best_assignment = part.assignment().to_vec();
    let mut best_potential = potential;

    for _ in 0..options.sweeps {
        for _ in 0..options.moves_per_sweep {
            proposed += 1;
            let node = rng.index(n);
            let to = rng.index(k);
            if to == part.machine_of(node) {
                continue;
            }
            let delta = model.potential_delta(part, node, to);
            let accept = delta < 0.0 || {
                let p = (-delta / temp.max(f64::MIN_POSITIVE)).exp();
                rng.chance(p)
            };
            if accept {
                part.transfer(graph, node, to);
                potential += delta;
                accepted += 1;
                if delta > 0.0 {
                    uphill_accepted += 1;
                }
                if potential < best_potential {
                    best_potential = potential;
                    best_assignment.copy_from_slice(part.assignment());
                }
            }
        }
        temp *= options.cooling;
    }

    // Restore the best state seen.
    if best_potential < potential {
        let target = best_assignment;
        for i in 0..n {
            if part.machine_of(i) != target[i] {
                part.transfer(graph, i, target[i]);
            }
        }
        potential = best_potential;
    }

    AnnealReport {
        proposed,
        accepted,
        uphill_accepted,
        start_potential,
        final_potential: potential,
    }
}

/// Anneal, then run best-response refinement to convergence: the §4.4
/// "meta-heuristic on top of the game" pipeline. Returns the refined
/// partition and its final potential.
pub fn anneal_then_refine(
    graph: &Graph,
    machines: &MachineConfig,
    part: Partition,
    mu: f64,
    framework: Framework,
    options: &AnnealOptions,
    rng: &mut Pcg32,
) -> (Partition, f64) {
    let mut part = part;
    let _ = anneal(graph, machines, &mut part, mu, framework, options, rng);
    let mut engine = RefineEngine::new(graph, machines, part, mu, framework);
    let _ = engine.run(&RefineOptions::default());
    let p = engine.potential();
    (engine.into_partition(), p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{table1_graph, WeightModel};
    use crate::partition::global_cost;

    fn setup(seed: u64) -> (Graph, MachineConfig, Partition) {
        let mut rng = Pcg32::new(seed);
        let g = table1_graph(70, 3, 6, WeightModel::default(), &mut rng);
        let machines = MachineConfig::from_speeds(&[0.1, 0.2, 0.3, 0.3, 0.1]);
        let assignment: Vec<usize> = (0..70).map(|_| rng.index(5)).collect();
        let p = Partition::from_assignment(&g, 5, assignment);
        (g, machines, p)
    }

    #[test]
    fn anneal_never_worsens() {
        let (g, m, mut p) = setup(1);
        let mut rng = Pcg32::new(99);
        let report =
            anneal(&g, &m, &mut p, 8.0, Framework::A, &AnnealOptions::default(), &mut rng);
        assert!(report.final_potential <= report.start_potential + 1e-9);
        // Tracked potential must equal from-scratch recomputation.
        let scratch = global_cost::c0(&g, &m, &p, 8.0);
        assert!(
            (report.final_potential - scratch).abs() < 1e-6 * (1.0 + scratch.abs()),
            "{} vs {scratch}",
            report.final_potential
        );
        p.validate(&g).unwrap();
    }

    #[test]
    fn anneal_accepts_uphill_moves_at_high_temp() {
        let (g, m, mut p) = setup(2);
        let mut rng = Pcg32::new(5);
        let opts = AnnealOptions {
            initial_temp_frac: 10.0, // very hot: almost everything accepted
            cooling: 1.0,
            moves_per_sweep: 500,
            sweeps: 1,
        };
        let report = anneal(&g, &m, &mut p, 8.0, Framework::A, &opts, &mut rng);
        assert!(report.uphill_accepted > 0, "hot annealer must take uphill moves");
    }

    #[test]
    fn anneal_then_refine_reaches_equilibrium() {
        let (g, m, p) = setup(3);
        let mut rng = Pcg32::new(17);
        let (refined, potential) = anneal_then_refine(
            &g,
            &m,
            p,
            8.0,
            Framework::A,
            &AnnealOptions::default(),
            &mut rng,
        );
        let model = CostModel::new(&g, m.clone(), 8.0, Framework::A);
        for i in 0..refined.node_count() {
            let (j, _) = model.dissatisfaction(&refined, i);
            assert!(j <= 1e-6, "node {i} dissatisfied after refine: {j}");
        }
        let scratch = global_cost::c0(&g, &m, &refined, 8.0);
        assert!((potential - scratch).abs() < 1e-6 * (1.0 + scratch.abs()));
    }

    #[test]
    fn anneal_can_beat_plain_refinement_sometimes() {
        // Not a strict guarantee, but across a few seeds annealing should
        // find a solution at least as good as plain refinement.
        let (g, m, p) = setup(4);
        let mut best_plain = f64::INFINITY;
        let mut best_annealed = f64::INFINITY;
        for seed in 0..4 {
            let mut engine = RefineEngine::new(&g, &m, p.clone(), 8.0, Framework::A);
            let r = engine.run(&RefineOptions::default());
            best_plain = best_plain.min(r.final_potential);
            let mut rng = Pcg32::new(seed);
            let (_, pot) = anneal_then_refine(
                &g,
                &m,
                p.clone(),
                8.0,
                Framework::A,
                &AnnealOptions::default(),
                &mut rng,
            );
            best_annealed = best_annealed.min(pot);
        }
        assert!(
            best_annealed <= best_plain * 1.001,
            "annealed {best_annealed} worse than plain {best_plain}"
        );
    }
}

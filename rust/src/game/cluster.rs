//! Cluster (multi-node) transfers — the paper's §4.4/§7 extension.
//!
//! Single-node best-response play can stall in local minima where no one
//! node wants to move alone but a *connected group* would jointly lower
//! the potential (coordinated play). The paper proposes transferring
//! clusters of connected nodes and suggests a sparse-cut-style search to
//! keep the exponential joint space tractable. We implement a greedy
//! variant: seed at the most dissatisfied node, grow the cluster along
//! same-machine neighbors in decreasing gain order, and accept the whole
//! move only if the *exact* cumulative potential delta (computed via the
//! paper's per-move identities while moves are applied one by one) is
//! negative; otherwise roll the moves back.

use crate::game::cost::{CostModel, Framework};
use crate::graph::{Graph, NodeId};
use crate::partition::{MachineConfig, MachineId, Partition};

/// Options for cluster-transfer search.
#[derive(Debug, Clone)]
pub struct ClusterOptions {
    /// Maximum nodes moved jointly.
    pub max_cluster: usize,
    /// Maximum cluster attempts per call of [`cluster_escape`].
    pub max_attempts: usize,
}

impl Default for ClusterOptions {
    fn default() -> Self {
        ClusterOptions { max_cluster: 6, max_attempts: 32 }
    }
}

/// One attempted cluster move.
#[derive(Debug, Clone)]
pub struct ClusterMove {
    pub nodes: Vec<NodeId>,
    pub from: MachineId,
    pub to: MachineId,
    pub delta: f64,
    pub accepted: bool,
}

/// Try to escape a (single-node) Nash equilibrium by moving connected
/// clusters. Returns the accepted moves. `part` is expected to already be
/// a single-node equilibrium (but this is not required for correctness).
pub fn cluster_escape(
    graph: &Graph,
    machines: &MachineConfig,
    part: &mut Partition,
    mu: f64,
    framework: Framework,
    options: &ClusterOptions,
) -> Vec<ClusterMove> {
    let model = CostModel::new(graph, machines.clone(), mu, framework);
    let k = machines.count();
    let mut accepted_moves = Vec::new();

    // Rank seed candidates by how *close* they are to moving: smallest
    // positive margin C_i(best other) − C_i(current).
    let mut seeds: Vec<(f64, NodeId, MachineId)> = (0..graph.node_count())
        .map(|i| {
            let cur = model.current_cost(part, i);
            let mut best_other = f64::INFINITY;
            let mut best_k = part.machine_of(i);
            for m in 0..k {
                if m == part.machine_of(i) {
                    continue;
                }
                let c = model.node_cost(part, i, m);
                if c < best_other {
                    best_other = c;
                    best_k = m;
                }
            }
            (best_other - cur, i, best_k)
        })
        .collect();
    seeds.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite costs"));

    for &(_, seed, target) in seeds.iter().take(options.max_attempts) {
        let from = part.machine_of(seed);
        if from == target {
            continue;
        }
        // Grow a connected same-machine cluster around the seed.
        let mut cluster = vec![seed];
        let mut frontier = vec![seed];
        while cluster.len() < options.max_cluster {
            // Candidate = same-machine neighbor of the cluster not yet in it,
            // chosen to minimize its own cost increase of joining `target`.
            let mut best: Option<(f64, NodeId)> = None;
            for &u in &frontier {
                for &v in graph.neighbors(u) {
                    if part.machine_of(v) != from || cluster.contains(&v) {
                        continue;
                    }
                    let gain = model.node_cost(part, v, target) - model.current_cost(part, v);
                    if best.map(|(g, _)| gain < g).unwrap_or(true) {
                        best = Some((gain, v));
                    }
                }
            }
            match best {
                Some((_, v)) => {
                    cluster.push(v);
                    frontier.push(v);
                }
                None => break,
            }
        }

        // Apply the joint move, accumulating the exact potential delta.
        let mut delta = 0.0;
        for &u in &cluster {
            delta += model.potential_delta(part, u, target);
            part.transfer(graph, u, target);
        }
        if delta < -1e-9 {
            accepted_moves.push(ClusterMove {
                nodes: cluster,
                from,
                to: target,
                delta,
                accepted: true,
            });
        } else {
            // Roll back.
            for &u in cluster.iter().rev() {
                part.transfer(graph, u, from);
            }
        }
    }
    accepted_moves
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::game::refine::{RefineEngine, RefineOptions};
    use crate::graph::generators::{table1_graph, WeightModel};
    use crate::graph::GraphBuilder;
    use crate::partition::global_cost;
    use crate::util::rng::Pcg32;

    #[test]
    fn rollback_preserves_partition() {
        // A configuration engineered so no cluster move helps: verify
        // the partition is untouched after attempts that all roll back.
        let mut b = GraphBuilder::with_nodes(4);
        b.add_edge(0, 1, 1.0).add_edge(2, 3, 1.0).add_edge(1, 2, 0.01);
        let g = b.build();
        let machines = MachineConfig::homogeneous(2);
        let part0 = Partition::from_assignment(&g, 2, vec![0, 0, 1, 1]);
        let mut part = part0.clone();
        let _ = cluster_escape(
            &g,
            &machines,
            &mut part,
            1.0,
            Framework::A,
            &ClusterOptions::default(),
        );
        part.validate(&g).unwrap();
        // Either unchanged, or changed with a strictly better potential.
        let before = global_cost::c0(&g, &machines, &part0, 1.0);
        let after = global_cost::c0(&g, &machines, &part, 1.0);
        assert!(after <= before + 1e-9);
    }

    #[test]
    fn accepted_moves_strictly_descend() {
        let mut rng = Pcg32::new(11);
        let g = table1_graph(60, 3, 6, WeightModel::default(), &mut rng);
        let machines = MachineConfig::from_speeds(&[0.1, 0.2, 0.3, 0.3, 0.1]);
        let assignment: Vec<usize> = (0..60).map(|_| rng.index(5)).collect();

        // First reach a single-node equilibrium.
        let part = Partition::from_assignment(&g, 5, assignment);
        let mut engine = RefineEngine::new(&g, &machines, part, 8.0, Framework::A);
        let _ = engine.run(&RefineOptions::default());
        let mut part = engine.into_partition();

        let before = global_cost::c0(&g, &machines, &part, 8.0);
        let moves = cluster_escape(
            &g,
            &machines,
            &mut part,
            8.0,
            Framework::A,
            &ClusterOptions::default(),
        );
        let after = global_cost::c0(&g, &machines, &part, 8.0);
        let predicted: f64 = moves.iter().map(|m| m.delta).sum();
        assert!(
            ((after - before) - predicted).abs() < 1e-6 * (1.0 + before.abs()),
            "delta mismatch: actual {} predicted {predicted}",
            after - before
        );
        for m in &moves {
            assert!(m.delta < 0.0);
            assert!(m.accepted);
            assert!(m.nodes.len() <= ClusterOptions::default().max_cluster);
        }
        part.validate(&g).unwrap();
    }

    #[test]
    fn cluster_is_connected_and_single_source() {
        let mut rng = Pcg32::new(13);
        let g = table1_graph(60, 3, 6, WeightModel::default(), &mut rng);
        let machines = MachineConfig::homogeneous(4);
        let assignment: Vec<usize> = (0..60).map(|_| rng.index(4)).collect();
        let part = Partition::from_assignment(&g, 4, assignment);
        let mut engine = RefineEngine::new(&g, &machines, part, 8.0, Framework::A);
        let _ = engine.run(&RefineOptions::default());
        let mut part = engine.into_partition();
        let moves =
            cluster_escape(&g, &machines, &mut part, 8.0, Framework::A, &ClusterOptions::default());
        for mv in &moves {
            assert_ne!(mv.from, mv.to);
            // Connectivity: every non-seed node adjacent to an earlier one.
            for (idx, &u) in mv.nodes.iter().enumerate().skip(1) {
                let earlier = &mv.nodes[..idx];
                assert!(
                    earlier.iter().any(|&e| g.neighbors(u).contains(&e)),
                    "cluster node {u} not connected to earlier members"
                );
            }
        }
    }
}

//! Node-level cost frameworks.
//!
//! Framework **A** (paper eq. 1):
//! ```text
//! C_i(r_i, r_-i) = (b_i / w_{r_i}) · Σ_{j≠i: r_j = r_i} b_j
//!                + (μ/2) · Σ_{j: r_j ≠ r_i} c_ij
//! ```
//!
//! Framework **B** (paper eq. 6):
//! ```text
//! C̃_i(r_i, r_-i) = b_i²/w_{r_i}² + (2 b_i / w_{r_i}²) Σ_{j≠i: r_j=r_i} b_j
//!                 − (2 b_i / w_{r_i}) Σ_j b_j
//!                 + (μ/2) Σ_{j: r_j ≠ r_i} c_ij
//! ```
//!
//! Feasibility (§4.5): both evaluate for *any* candidate machine `k`
//! from (a) the node's own adjacency row and (b) the K machine-level
//! aggregates `L_k` — nothing about other machines' memberships is
//! needed, so the state machines must exchange is O(K), independent of N.
//!
//! **Augmented (migration-cost-aware) game** (DESIGN.md §9): with a
//! per-move surcharge `c_mig ≥ 0`, the cost a node sees on a candidate
//! machine `k ≠ r_i` is `Ĉ_i(k) = C_i(k) + c_mig` (its home machine is
//! never surcharged). This is a switching-cost congestion game (cf.
//! arXiv:1109.6925): a move is only accepted when its raw gain exceeds
//! the charge, the augmented potential `Φ' = Φ + c_mig·(#moves)` still
//! strictly descends per accepted transfer (for A, `ΔΦ = −2(𝔍'+c_mig)`
//! so `ΔΦ' = −2𝔍' − c_mig < 0`; for B, `ΔΦ = −(𝔍'+c_mig)` so
//! `ΔΦ' = −𝔍' < 0`), and pure Nash equilibria of the augmented game
//! exist by the same finite-potential argument as Thm 4.1. The charge
//! acts as a hysteresis band: churn whose benefit is below `c_mig`
//! is filtered out inside the game rather than post-hoc.

use crate::graph::{Graph, NodeId};
use crate::partition::{MachineConfig, MachineId, Partition};

/// Which local cost framework drives node decisions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Framework {
    /// Paper eq. (1); potential `C0` (Thm 3.1).
    A,
    /// Paper eq. (6); potential `C̃0` (eq. 8, Thm 5.1).
    B,
}

impl std::str::FromStr for Framework {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "A" | "a" | "1" | "ci" => Ok(Framework::A),
            "B" | "b" | "2" | "ci-tilde" => Ok(Framework::B),
            other => Err(format!("unknown framework {other:?} (want A or B)")),
        }
    }
}

impl std::fmt::Display for Framework {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Framework::A => write!(f, "A"),
            Framework::B => write!(f, "B"),
        }
    }
}

/// Evaluates node costs against a graph + machine pool. Stateless with
/// respect to the partition; callers pass aggregates explicitly so both
/// the sequential engine and the distributed machines can share it.
#[derive(Debug, Clone)]
pub struct CostModel<'g> {
    pub graph: &'g Graph,
    pub machines: MachineConfig,
    pub mu: f64,
    pub framework: Framework,
    /// Per-move migration surcharge `c_mig` added to every non-home
    /// candidate's cost (augmented game, DESIGN.md §9). 0 recovers the
    /// paper's charge-free game exactly.
    pub migration_charge: f64,
}

impl<'g> CostModel<'g> {
    pub fn new(graph: &'g Graph, machines: MachineConfig, mu: f64, framework: Framework) -> Self {
        assert!(mu >= 0.0, "mu must be non-negative");
        CostModel { graph, machines, mu, framework, migration_charge: 0.0 }
    }

    /// Builder: price every candidate move at `c_mig` cost units
    /// (`c_mig = ticks_per_transfer · tick_value` in the closed loop).
    pub fn with_migration_charge(mut self, c_mig: f64) -> Self {
        assert!(c_mig >= 0.0 && c_mig.is_finite(), "migration charge must be finite and >= 0");
        self.migration_charge = c_mig;
        self
    }

    /// Machine count `K`.
    pub fn k(&self) -> usize {
        self.machines.count()
    }

    /// Adjacency row of node `i`: `adj[k] = Σ_{j∈N(i): r_j=k} c_ij`,
    /// written into `buf` (length K). Returns `S_i = Σ_j c_ij`.
    pub fn adj_row(&self, part: &Partition, i: NodeId, buf: &mut [f64]) -> f64 {
        debug_assert_eq!(buf.len(), self.k());
        buf.iter_mut().for_each(|x| *x = 0.0);
        let mut s = 0.0;
        for (j, c) in self.graph.neighbors_weighted(i) {
            buf[part.machine_of(j)] += c;
            s += c;
        }
        s
    }

    /// Cost of node `i` if assigned to machine `k`, given the current
    /// partition. O(deg(i) + 1).
    pub fn node_cost(&self, part: &Partition, i: NodeId, k: MachineId) -> f64 {
        let mut adj = vec![0.0; self.k()];
        let s = self.adj_row(part, i, &mut adj);
        self.node_cost_with_adj(part, i, k, s, &adj)
    }

    /// Same as [`node_cost`] but with the adjacency row precomputed —
    /// the O(1)-per-candidate form used in hot loops. Includes the
    /// migration surcharge on every non-home candidate.
    #[inline]
    pub fn node_cost_with_adj(
        &self,
        part: &Partition,
        i: NodeId,
        k: MachineId,
        s_i: f64,
        adj: &[f64],
    ) -> f64 {
        let surcharge = if part.machine_of(i) == k { 0.0 } else { self.migration_charge };
        self.raw_node_cost_with_adj(part, i, k, s_i, adj) + surcharge
    }

    /// The paper's un-augmented node cost (eq. 1 / eq. 6) — no
    /// migration surcharge. The potential identities (Thm 3.1 / 5.1)
    /// are stated on this quantity.
    #[inline]
    fn raw_node_cost_with_adj(
        &self,
        part: &Partition,
        i: NodeId,
        k: MachineId,
        s_i: f64,
        adj: &[f64],
    ) -> f64 {
        let b = self.graph.node_weight(i);
        let w = self.machines.speed(k);
        // Σ_{j≠i: r_j=k} b_j: subtract own weight if already resident.
        let same_load = part.load(k) - if part.machine_of(i) == k { b } else { 0.0 };
        let cut = self.mu * 0.5 * (s_i - adj[k]);
        match self.framework {
            Framework::A => b / w * same_load + cut,
            Framework::B => {
                let b_total = self.graph.total_node_weight();
                b * b / (w * w) + 2.0 * b / (w * w) * same_load - 2.0 * b / w * b_total + cut
            }
        }
    }

    /// Current cost `C_i(r_i, r_-i)`.
    pub fn current_cost(&self, part: &Partition, i: NodeId) -> f64 {
        self.node_cost(part, i, part.machine_of(i))
    }

    /// Best response of node `i`: `(argmin_k C_i(k), min_k C_i(k))`.
    /// Ties break toward the current machine (no gratuitous moves), then
    /// toward the lowest machine id (determinism).
    pub fn best_response(&self, part: &Partition, i: NodeId) -> (MachineId, f64) {
        let mut adj = vec![0.0; self.k()];
        let s = self.adj_row(part, i, &mut adj);
        self.best_response_with_adj(part, i, s, &adj)
    }

    /// Best response with precomputed adjacency row.
    pub fn best_response_with_adj(
        &self,
        part: &Partition,
        i: NodeId,
        s_i: f64,
        adj: &[f64],
    ) -> (MachineId, f64) {
        let cur = part.machine_of(i);
        let mut best_k = cur;
        let mut best = self.node_cost_with_adj(part, i, cur, s_i, adj);
        for k in 0..self.k() {
            if k == cur {
                continue;
            }
            let c = self.node_cost_with_adj(part, i, k, s_i, adj);
            if c < best - 1e-12 * (1.0 + best.abs()) {
                best = c;
                best_k = k;
            }
        }
        (best_k, best)
    }

    /// Dissatisfaction `𝔍(i) = C_i(r_i) − min_k C_i(k)` (paper eq. 4);
    /// non-negative by construction. Returns `(𝔍, argmin machine)`.
    ///
    /// Framework A routes through the candidate-set fast path
    /// ([`dissat_fast_a`]) so every caller — the sequential engine and
    /// the distributed machine actors — picks identical nodes/targets.
    pub fn dissatisfaction(&self, part: &Partition, i: NodeId) -> (f64, MachineId) {
        let mut adj = vec![0.0; self.k()];
        let s = self.adj_row(part, i, &mut adj);
        if self.framework == Framework::A {
            let q1 = self.argmin_load_per_speed(part);
            self.dissat_fast_a(part, i, s, &adj, q1)
        } else {
            self.dissatisfaction_with_adj(part, i, s, &adj)
        }
    }

    /// `argmin_q L_q / w_q` — the per-turn precomputation of the
    /// framework-A fast path.
    pub fn argmin_load_per_speed(&self, part: &Partition) -> MachineId {
        let mut q1 = 0usize;
        let mut q1_low = f64::INFINITY;
        for q in 0..self.k() {
            let low = part.load(q) / self.machines.speed(q);
            if low < q1_low {
                q1_low = low;
                q1 = q;
            }
        }
        q1
    }

    /// Framework-A exact dissatisfaction via candidate evaluation (§Perf).
    ///
    /// For machines `q` with `adj_i[q] = 0` the cost
    /// `b_i·L_q/w_q + (μ/2)·S_i` is affine in the scalar `L_q/w_q`, and
    /// the exact cost at `q1 = argmin_q L_q/w_q` lower-bounds every
    /// zero-adjacency machine's cost, so the true argmin over all K
    /// machines lies in `{q1} ∪ {neighbor machines} ∪ {r_i}` — at most
    /// `deg_i + 2` exact evaluations instead of K.
    ///
    /// Arithmetic is association-identical to [`node_cost_with_adj`], and
    /// loads/adjacency sums are integer-valued in every workload this
    /// repo generates, so cached-incremental and fresh evaluations agree
    /// bit-for-bit.
    #[inline]
    pub fn dissat_fast_a(
        &self,
        part: &Partition,
        i: NodeId,
        s_i: f64,
        adj: &[f64],
        q1: MachineId,
    ) -> (f64, MachineId) {
        debug_assert_eq!(self.framework, Framework::A);
        debug_assert!(self.k() <= 64, "fast path assumes K <= 64; widen the seen mask");
        let b = self.graph.node_weight(i);
        let cur = part.machine_of(i);
        let mu = self.mu;
        let charge = self.migration_charge;
        let loads = part.loads();
        let speeds = self.machines.speeds();
        // The surcharge is the same constant on every non-home machine,
        // so the candidate-set lower-bound argument below is unchanged:
        // for zero-adjacency machines the augmented cost is (affine in
        // L_q/w_q) + c_mig, and q1 = argmin L_q/w_q still minimizes it.
        let eval = |q: usize| -> f64 {
            let same_load = loads[q] - if q == cur { b } else { 0.0 };
            let surcharge = if q == cur { 0.0 } else { charge };
            b / speeds[q] * same_load + mu * 0.5 * (s_i - adj[q]) + surcharge
        };
        let cost_cur = eval(cur);
        let mut best_k = q1;
        let mut best_cost = eval(q1);
        // Dedup candidate machines with a bitmask: hub nodes in scale-free
        // graphs have many neighbors but few distinct machines.
        let mut seen: u64 = (1 << q1) | (1 << cur);
        for &nb in self.graph.neighbors(i) {
            let q = part.machine_of(nb);
            if seen & (1 << q) != 0 {
                continue;
            }
            seen |= 1 << q;
            let c = eval(q);
            if c < best_cost {
                best_cost = c;
                best_k = q;
            }
        }
        if cost_cur <= best_cost {
            // Prefer staying put on ties (no gratuitous moves).
            best_cost = cost_cur;
            best_k = cur;
        }
        ((cost_cur - best_cost).max(0.0), best_k)
    }

    /// Dissatisfaction with precomputed adjacency row.
    #[inline]
    pub fn dissatisfaction_with_adj(
        &self,
        part: &Partition,
        i: NodeId,
        s_i: f64,
        adj: &[f64],
    ) -> (f64, MachineId) {
        let cur_cost = self.node_cost_with_adj(part, i, part.machine_of(i), s_i, adj);
        let (best_k, best) = self.best_response_with_adj(part, i, s_i, adj);
        ((cur_cost - best).max(0.0), best_k)
    }

    /// Dissatisfaction restricted to a candidate-machine `scope` (the
    /// inner game of the two-level hierarchy, DESIGN.md §12): the argmin
    /// ranges over `scope ∪ {r_i}` instead of all K machines, so a
    /// rack-scoped player can never propose a cross-rack move. Same
    /// strict-improvement tolerance as [`best_response_with_adj`] and
    /// identical cost arithmetic ([`node_cost_with_adj`]), so a scope
    /// covering all machines reproduces [`dissatisfaction_with_adj`]
    /// bit-for-bit.
    pub fn dissatisfaction_scoped_with_adj(
        &self,
        part: &Partition,
        i: NodeId,
        s_i: f64,
        adj: &[f64],
        scope: &[MachineId],
    ) -> (f64, MachineId) {
        let cur = part.machine_of(i);
        let cur_cost = self.node_cost_with_adj(part, i, cur, s_i, adj);
        let mut best_k = cur;
        let mut best = cur_cost;
        for &q in scope {
            if q == cur {
                continue;
            }
            let c = self.node_cost_with_adj(part, i, q, s_i, adj);
            if c < best - 1e-12 * (1.0 + best.abs()) {
                best = c;
                best_k = q;
            }
        }
        ((cur_cost - best).max(0.0), best_k)
    }

    /// The framework's global potential, from scratch. For A this is
    /// `C0`, for B it is `C̃0` — refinement descends exactly this value.
    pub fn potential(&self, part: &Partition) -> f64 {
        match self.framework {
            Framework::A => {
                crate::partition::global_cost::c0(self.graph, &self.machines, part, self.mu)
            }
            Framework::B => {
                crate::partition::global_cost::c0_tilde(self.graph, &self.machines, part, self.mu)
            }
        }
    }

    /// Exact *raw* potential change if node `l` moved from its current
    /// machine to `to`, per the paper's identities: `ΔC0 = 2·ΔC_l`
    /// (Thm 3.1) and `ΔC̃0 = ΔC̃_l` (Thm 5.1). O(deg(l) + K). The
    /// migration surcharge deliberately does not appear here — it prices
    /// *decisions*, while the potential tracks the raw objective; the
    /// augmented potential adds `c_mig` per executed move on top (see
    /// [`crate::partition::global_cost::augmented`]).
    pub fn potential_delta(&self, part: &Partition, l: NodeId, to: MachineId) -> f64 {
        let from = part.machine_of(l);
        if from == to {
            return 0.0;
        }
        let mut adj = vec![0.0; self.k()];
        let s = self.adj_row(part, l, &mut adj);
        let cur = self.raw_node_cost_with_adj(part, l, from, s, &adj);
        let new = self.raw_node_cost_with_adj(part, l, to, s, &adj);
        match self.framework {
            Framework::A => 2.0 * (new - cur),
            Framework::B => new - cur,
        }
    }
}

/// Dense cost matrices for all `(i, k)` pairs — the native mirror of the
/// L1 Pallas kernel, used as the PJRT cross-check oracle and by the dense
/// rebuild at refinement-epoch start.
///
/// Returns `(costs_a, costs_b, dissat_a, dissat_b)` with the matrices in
/// row-major `N×K` layout.
pub fn dense_cost_matrices(
    graph: &Graph,
    machines: &MachineConfig,
    part: &Partition,
    mu: f64,
) -> DenseCosts {
    let n = graph.node_count();
    let k = machines.count();
    let b_total = graph.total_node_weight();
    let mut costs_a = vec![0.0f64; n * k];
    let mut costs_b = vec![0.0f64; n * k];
    let mut adj = vec![0.0f64; k];
    for i in 0..n {
        adj.iter_mut().for_each(|x| *x = 0.0);
        let mut s_i = 0.0;
        for (j, c) in graph.neighbors_weighted(i) {
            adj[part.machine_of(j)] += c;
            s_i += c;
        }
        let b = graph.node_weight(i);
        let ri = part.machine_of(i);
        for m in 0..k {
            let w = machines.speed(m);
            let same_load = part.load(m) - if ri == m { b } else { 0.0 };
            let cut = mu * 0.5 * (s_i - adj[m]);
            costs_a[i * k + m] = b / w * same_load + cut;
            costs_b[i * k + m] =
                b * b / (w * w) + 2.0 * b / (w * w) * same_load - 2.0 * b / w * b_total + cut;
        }
    }
    let dissat = |costs: &[f64]| -> Vec<f64> {
        (0..n)
            .map(|i| {
                let row = &costs[i * k..(i + 1) * k];
                let cur = row[part.machine_of(i)];
                let min = row.iter().copied().fold(f64::INFINITY, f64::min);
                (cur - min).max(0.0)
            })
            .collect()
    };
    let dissat_a = dissat(&costs_a);
    let dissat_b = dissat(&costs_b);
    DenseCosts { n, k, costs_a, costs_b, dissat_a, dissat_b }
}

/// Output bundle of [`dense_cost_matrices`].
#[derive(Debug, Clone)]
pub struct DenseCosts {
    pub n: usize,
    pub k: usize,
    /// Framework A costs, row-major N×K.
    pub costs_a: Vec<f64>,
    /// Framework B costs, row-major N×K.
    pub costs_b: Vec<f64>,
    pub dissat_a: Vec<f64>,
    pub dissat_b: Vec<f64>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{table1_graph, WeightModel};
    use crate::partition::global_cost;
    use crate::util::rng::Pcg32;

    fn setup(seed: u64, fw: Framework) -> (Graph, CostModel<'static>, Partition) {
        let mut rng = Pcg32::new(seed);
        let g = table1_graph(50, 3, 6, WeightModel::default(), &mut rng);
        let g: &'static Graph = Box::leak(Box::new(g));
        let machines = MachineConfig::from_speeds(&[0.1, 0.2, 0.3, 0.3, 0.1]);
        let assignment: Vec<usize> = (0..50).map(|_| rng.index(5)).collect();
        let p = Partition::from_assignment(g, 5, assignment);
        let model = CostModel::new(g, machines, 8.0, fw);
        (g.clone(), model, p)
    }

    #[test]
    fn sum_of_node_costs_equals_c0() {
        let (_, model, p) = setup(1, Framework::A);
        let sum: f64 = (0..p.node_count()).map(|i| model.current_cost(&p, i)).sum();
        let c0 = global_cost::c0(model.graph, &model.machines, &p, model.mu);
        assert!((sum - c0).abs() < 1e-6 * (1.0 + c0.abs()), "{sum} vs {c0}");
    }

    #[test]
    fn dissatisfaction_nonnegative() {
        for fw in [Framework::A, Framework::B] {
            let (_, model, p) = setup(2, fw);
            for i in 0..p.node_count() {
                let (j, _) = model.dissatisfaction(&p, i);
                assert!(j >= 0.0, "node {i} fw {fw}: 𝔍={j}");
            }
        }
    }

    /// Thm 3.1 identity: moving any node changes C0 by exactly 2·ΔC_l.
    #[test]
    fn potential_identity_framework_a() {
        let (g, model, p) = setup(3, Framework::A);
        for l in [0usize, 7, 23, 49] {
            for to in 0..5 {
                let before = global_cost::c0(&g, &model.machines, &p, model.mu);
                let predicted = model.potential_delta(&p, l, to);
                let mut p2 = p.clone();
                p2.transfer(&g, l, to);
                let after = global_cost::c0(&g, &model.machines, &p2, model.mu);
                assert!(
                    ((after - before) - predicted).abs() < 1e-6 * (1.0 + before.abs()),
                    "node {l} → {to}: actual Δ {} vs predicted {}",
                    after - before,
                    predicted
                );
            }
        }
    }

    /// Thm 5.1 identity: moving any node changes C̃0 by exactly ΔC̃_l.
    #[test]
    fn potential_identity_framework_b() {
        let (g, model, p) = setup(4, Framework::B);
        for l in [1usize, 13, 31, 44] {
            for to in 0..5 {
                let before = global_cost::c0_tilde(&g, &model.machines, &p, model.mu);
                let predicted = model.potential_delta(&p, l, to);
                let mut p2 = p.clone();
                p2.transfer(&g, l, to);
                let after = global_cost::c0_tilde(&g, &model.machines, &p2, model.mu);
                assert!(
                    ((after - before) - predicted).abs() < 1e-6 * (1.0 + before.abs()),
                    "node {l} → {to}: actual Δ {} vs predicted {}",
                    after - before,
                    predicted
                );
            }
        }
    }

    #[test]
    fn best_response_is_minimum() {
        for fw in [Framework::A, Framework::B] {
            let (_, model, p) = setup(5, fw);
            for i in 0..p.node_count() {
                let (bk, bc) = model.best_response(&p, i);
                for k in 0..5 {
                    let c = model.node_cost(&p, i, k);
                    assert!(
                        bc <= c + 1e-9 * (1.0 + c.abs()),
                        "fw {fw} node {i}: best {bc}@{bk} > cost {c}@{k}"
                    );
                }
            }
        }
    }

    #[test]
    fn dense_matches_scalar() {
        let (g, model_a, p) = setup(6, Framework::A);
        let model_b =
            CostModel::new(model_a.graph, model_a.machines.clone(), model_a.mu, Framework::B);
        let dense = dense_cost_matrices(&g, &model_a.machines, &p, model_a.mu);
        for i in 0..dense.n {
            for k in 0..dense.k {
                let a = model_a.node_cost(&p, i, k);
                let b = model_b.node_cost(&p, i, k);
                assert!((dense.costs_a[i * dense.k + k] - a).abs() < 1e-9 * (1.0 + a.abs()));
                assert!((dense.costs_b[i * dense.k + k] - b).abs() < 1e-9 * (1.0 + b.abs()));
            }
            let (ja, _) = model_a.dissatisfaction(&p, i);
            let (jb, _) = model_b.dissatisfaction(&p, i);
            assert!((dense.dissat_a[i] - ja).abs() < 1e-9 * (1.0 + ja.abs()));
            assert!((dense.dissat_b[i] - jb).abs() < 1e-9 * (1.0 + jb.abs()));
        }
    }

    #[test]
    fn mu_zero_reduces_to_load_balancing_incentive() {
        // Paper eq. (2): with μ=0 a node prefers the machine with lower
        // normalized existing load.
        let (_, mut model, p) = setup(7, Framework::A);
        model.mu = 0.0;
        for i in 0..p.node_count() {
            let (bk, _) = model.best_response(&p, i);
            let b = model.graph.node_weight(i);
            let norm = |k: usize| {
                (p.load(k) - if p.machine_of(i) == k { b } else { 0.0 }) / model.machines.speed(k)
            };
            for k in 0..5 {
                assert!(norm(bk) <= norm(k) + 1e-9, "node {i}: {bk} vs {k}");
            }
        }
    }

    /// The augmented game prices every non-home candidate at +c_mig:
    /// dissatisfaction shrinks by exactly the charge (clamped at 0)
    /// whenever the best response is a genuine move, and a large enough
    /// charge silences every node (no move's raw gain can beat it).
    #[test]
    fn migration_charge_damps_dissatisfaction() {
        for fw in [Framework::A, Framework::B] {
            let (_, base, p) = setup(8, fw);
            let charged = base.clone().with_migration_charge(3.0);
            for i in 0..p.node_count() {
                let (j0, k0) = base.dissatisfaction(&p, i);
                let (j1, k1) = charged.dissatisfaction(&p, i);
                assert!(
                    j1 <= j0 + 1e-9,
                    "fw {fw} node {i}: charge increased dissatisfaction {j0} -> {j1}"
                );
                if k1 != p.machine_of(i) {
                    // A priced move: the augmented gain is the raw gain
                    // to the same-or-better raw target minus the charge.
                    let raw_gain_to_k1 =
                        base.node_cost(&p, i, p.machine_of(i)) - base.node_cost(&p, i, k1);
                    assert!(
                        (j1 - (raw_gain_to_k1 - 3.0)).abs() < 1e-9 * (1.0 + j1.abs()),
                        "fw {fw} node {i}: augmented 𝔍 {j1} != raw gain {raw_gain_to_k1} - charge"
                    );
                }
                let _ = k0;
            }
            let huge = base.clone().with_migration_charge(1e12);
            for i in 0..p.node_count() {
                let (j, k) = huge.dissatisfaction(&p, i);
                assert_eq!(k, p.machine_of(i), "fw {fw}: node {i} still wants to move");
                assert_eq!(j, 0.0);
            }
        }
    }

    /// The framework-A candidate-set fast path and the evaluate-all-K
    /// path agree under a nonzero charge (the surcharge is constant
    /// across non-home machines, so the lower-bound argument holds).
    #[test]
    fn fast_path_matches_full_scan_under_charge() {
        let (_, model, p) = setup(9, Framework::A);
        let model = model.with_migration_charge(2.5);
        let mut adj = vec![0.0; model.k()];
        for i in 0..p.node_count() {
            let s = model.adj_row(&p, i, &mut adj);
            let q1 = model.argmin_load_per_speed(&p);
            let (jf, kf) = model.dissat_fast_a(&p, i, s, &adj, q1);
            let (jg, kg) = model.dissatisfaction_with_adj(&p, i, s, &adj);
            assert!((jf - jg).abs() < 1e-9 * (1.0 + jg.abs()), "node {i}: {jf} vs {jg}");
            if jg > 1e-9 {
                let cf = model.node_cost(&p, i, kf);
                let cg = model.node_cost(&p, i, kg);
                assert!(
                    (cf - cg).abs() < 1e-9 * (1.0 + cg.abs()),
                    "node {i}: fast path picked a worse target ({cf} vs {cg})"
                );
            }
        }
    }

    /// `potential_delta` tracks the RAW potential regardless of the
    /// charge — the Thm 3.1 / 5.1 identities are about the un-augmented
    /// objective.
    #[test]
    fn potential_delta_is_charge_invariant() {
        for fw in [Framework::A, Framework::B] {
            let (_, base, p) = setup(10, fw);
            let charged = base.clone().with_migration_charge(7.0);
            for l in [0usize, 11, 29, 47] {
                for to in 0..5 {
                    assert_eq!(
                        base.potential_delta(&p, l, to),
                        charged.potential_delta(&p, l, to),
                        "fw {fw} node {l} -> {to}"
                    );
                }
            }
        }
    }

    #[test]
    fn framework_parse() {
        assert_eq!("A".parse::<Framework>().unwrap(), Framework::A);
        assert_eq!("ci-tilde".parse::<Framework>().unwrap(), Framework::B);
        assert!("zzz".parse::<Framework>().is_err());
    }
}

//! The two-level (rack / super-machine) partitioning game — DESIGN.md
//! §12.
//!
//! Flat refinement exchanges O(K) aggregates per move and dials O(K²)
//! sockets; past a few dozen machines the coordinator itself becomes
//! the bottleneck. The hierarchy splits the game in two:
//!
//! * **Outer game** — each rack is a *super-machine* whose speed is the
//!   sum of its members' normalized speeds and whose load is the sum of
//!   member loads. The outer game is literally the flat machinery run
//!   on the rack quotient: same graph, a [`RackLayout::quotient_config`]
//!   machine pool of R racks, and the node→rack assignment. Every
//!   theorem about the flat game (potential descent, Nash termination,
//!   the augmented-charge bound) therefore holds verbatim at rack
//!   granularity, and only rack-boundary LPs move between racks.
//! * **Inner game** — the flat engine scoped to one rack's member
//!   machines ([`crate::game::refine::RefineEngine::run_scoped`]).
//!   Scoped turns only move nodes between machines of the same rack, so
//!   every other machine's load and every node's adjacency column
//!   outside the rack are invariant — rack subgames are exactly
//!   independent, and chaining them sequentially on one shared engine
//!   is bit-identical to playing them concurrently per rack.
//!
//! The outer result is mapped back to machines by
//! [`RackLayout::map_back`] (nodes that stayed in their rack keep their
//! machine; migrants go to the target rack's least-loaded machine) and
//! accepted only if the *flat* potential did not increase
//! ([`guarded_map_back`]) — so the composed two-level pass descends the
//! same global potential the flat game does, and on singleton racks it
//! reproduces the flat game bit-for-bit (the quotient *is* the flat
//! instance and the map-back is the identity).

use crate::game::cost::{CostModel, Framework};
use crate::game::refine::{RefineEngine, RefineOptions, RefineReport};
use crate::graph::Graph;
use crate::partition::{MachineConfig, MachineId, Partition};

/// Static rack membership: a dense map `machine → rack` over `0..R`.
///
/// Every rack is nonempty; members are kept ascending and the *rack
/// leader* is the member with the smallest machine id (the leader plays
/// the outer game on the rack's behalf in the distributed protocol).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RackLayout {
    rack_of: Vec<usize>,
    members: Vec<Vec<MachineId>>,
}

impl RackLayout {
    /// Build from a `machine → rack` map. Rack ids must be dense
    /// (`0..R`, every id used); anything else is a configuration error
    /// the caller should surface, not a panic.
    pub fn new(rack_of: Vec<usize>) -> Result<Self, String> {
        if rack_of.is_empty() {
            return Err("rack layout needs at least one machine".into());
        }
        let racks = rack_of.iter().copied().max().expect("nonempty") + 1;
        let mut members: Vec<Vec<MachineId>> = vec![Vec::new(); racks];
        for (m, &r) in rack_of.iter().enumerate() {
            members[r].push(m);
        }
        if let Some(empty) = members.iter().position(|ms| ms.is_empty()) {
            return Err(format!("rack ids must be dense: rack {empty} has no machines"));
        }
        Ok(RackLayout { rack_of, members })
    }

    /// Parse a `--racks "0,0,1,1"` CLI string for a K-machine fleet.
    pub fn parse(spec: &str, k: usize) -> Result<Self, String> {
        let rack_of: Vec<usize> = spec
            .split(',')
            .map(|t| t.trim().parse::<usize>().map_err(|e| format!("bad rack id {t:?}: {e}")))
            .collect::<Result<_, _>>()?;
        if rack_of.len() != k {
            return Err(format!("rack map names {} machines, fleet has {k}", rack_of.len()));
        }
        RackLayout::new(rack_of)
    }

    /// One machine per rack — the layout under which the hierarchy is
    /// bit-identical to the flat game.
    pub fn singletons(k: usize) -> Self {
        RackLayout::new((0..k).collect()).expect("identity map is dense")
    }

    /// Number of machines K.
    pub fn machine_count(&self) -> usize {
        self.rack_of.len()
    }

    /// Number of racks R.
    pub fn rack_count(&self) -> usize {
        self.members.len()
    }

    /// Rack of machine `m`.
    #[inline]
    pub fn rack_of(&self, m: MachineId) -> usize {
        self.rack_of[m]
    }

    /// The whole `machine → rack` map.
    pub fn rack_of_slice(&self) -> &[usize] {
        &self.rack_of
    }

    /// Machines of rack `r`, ascending.
    pub fn members(&self, r: usize) -> &[MachineId] {
        &self.members[r]
    }

    /// Rack `r`'s leader: its smallest member machine id.
    pub fn leader(&self, r: usize) -> MachineId {
        self.members[r][0]
    }

    /// True if machine `m` leads its rack.
    pub fn is_leader(&self, m: MachineId) -> bool {
        self.leader(self.rack_of[m]) == m
    }

    /// All rack leaders, in rack order.
    pub fn leaders(&self) -> Vec<MachineId> {
        (0..self.rack_count()).map(|r| self.leader(r)).collect()
    }

    /// True when every rack holds exactly one machine.
    pub fn is_singleton(&self) -> bool {
        self.members.iter().all(|ms| ms.len() == 1)
    }

    /// The layout after the given machines left the fleet: dead
    /// machines are dropped, survivors renumber compactly (the same
    /// renumbering `TcpEndpoint::compact` / `rehome_assignment` use),
    /// racks left empty are dropped, and rack ids renumber preserving
    /// order — fully deterministic, so every survivor derives the same
    /// layout from the same survivor list.
    pub fn without_machines(&self, dead: &[MachineId]) -> Result<Self, String> {
        let survivors: Vec<usize> = (0..self.machine_count())
            .filter(|m| !dead.contains(m))
            .map(|m| self.rack_of[m])
            .collect();
        if survivors.is_empty() {
            return Err("cannot drop every machine from the rack layout".into());
        }
        // Renumber rack ids compactly, preserving first-appearance order
        // of the *original* ids (ascending, since new() made them dense).
        let mut alive: Vec<usize> = survivors.clone();
        alive.sort_unstable();
        alive.dedup();
        let rack_of =
            survivors.iter().map(|r| alive.binary_search(r).expect("alive rack")).collect();
        RackLayout::new(rack_of)
    }

    /// Rack a joining machine should be assigned when the operator did
    /// not name one: the rack with the fewest members, ties to the
    /// lowest rack id — deterministic, so leader and workers agree.
    pub fn join_rack(&self) -> usize {
        (0..self.rack_count())
            .min_by_key(|&r| (self.members[r].len(), r))
            .expect("at least one rack")
    }

    /// The layout after a machine is inserted at logical position `pos`
    /// (machines at and above `pos` shift up by one) into rack `rack`.
    /// `rack == rack_count()` opens a new rack.
    pub fn with_inserted(&self, pos: usize, rack: usize) -> Result<Self, String> {
        if pos > self.machine_count() {
            return Err(format!("insert position {pos} past fleet size {}", self.machine_count()));
        }
        if rack > self.rack_count() {
            return Err(format!("rack {rack} would leave a gap (R = {})", self.rack_count()));
        }
        let mut rack_of = self.rack_of.clone();
        rack_of.insert(pos, rack);
        RackLayout::new(rack_of)
    }

    /// The outer game's machine pool: one super-machine per rack whose
    /// normalized speed is the sum of its members'. Sums of normalized
    /// speeds are already normalized, so the quotient adopts them
    /// verbatim ([`MachineConfig::from_normalized`]) — for singleton
    /// racks the "sum" is a single term and the quotient speeds are
    /// bit-identical to the flat speeds.
    pub fn quotient_config(&self, machines: &MachineConfig) -> MachineConfig {
        assert_eq!(machines.count(), self.machine_count());
        let speeds = self
            .members
            .iter()
            .map(|ms| ms.iter().map(|&m| machines.speed(m)).sum())
            .collect();
        MachineConfig::from_normalized(speeds)
    }

    /// Project a node→machine assignment to the node→rack quotient the
    /// outer game plays on.
    pub fn quotient_assignment(&self, assignment: &[MachineId]) -> Vec<MachineId> {
        assignment.iter().map(|&m| self.rack_of[m]).collect()
    }

    /// Turn an outer-game node→rack result back into a node→machine
    /// assignment. Nodes whose rack did not change keep their machine;
    /// cross-rack migrants are placed (ascending node order) on the
    /// target rack's machine with the lowest normalized load
    /// `L_q / w_q` at that moment, ties to the lowest machine id —
    /// the `rehome_assignment` placement rule, fully deterministic.
    /// On singleton racks the map-back is the identity composed with
    /// "the unique member", i.e. exactly the outer assignment.
    pub fn map_back(
        &self,
        graph: &Graph,
        machines: &MachineConfig,
        before: &Partition,
        outer: &[MachineId],
    ) -> Vec<MachineId> {
        let k = self.machine_count();
        assert_eq!(machines.count(), k);
        assert_eq!(before.node_count(), outer.len());
        const UNPLACED: usize = usize::MAX;
        let mut assignment: Vec<MachineId> = Vec::with_capacity(outer.len());
        let mut loads = vec![0.0f64; k];
        for (i, &r) in outer.iter().enumerate() {
            assert!(r < self.rack_count(), "node {i} on invalid rack {r}");
            let m = before.machine_of(i);
            if self.rack_of[m] == r {
                assignment.push(m);
                loads[m] += graph.node_weight(i);
            } else {
                assignment.push(UNPLACED);
            }
        }
        for (i, &r) in outer.iter().enumerate() {
            if assignment[i] != UNPLACED {
                continue;
            }
            let mut best = self.members[r][0];
            let mut best_load = loads[best] / machines.speed(best);
            for &m in &self.members[r][1..] {
                let v = loads[m] / machines.speed(m);
                if v < best_load {
                    best_load = v;
                    best = m;
                }
            }
            assignment[i] = best;
            loads[best] += graph.node_weight(i);
        }
        assignment
    }
}

/// Result of the guarded outer→machine map-back.
#[derive(Debug, Clone)]
pub struct OuterMapBack {
    /// The accepted node→machine assignment: the map-back if it kept
    /// the flat potential from rising, otherwise `before` unchanged.
    pub assignment: Vec<MachineId>,
    /// False when the outer moves were discarded.
    pub accepted: bool,
    /// Fresh flat potential of `before`.
    pub flat_before: f64,
    /// Fresh flat potential of the mapped-back assignment.
    pub flat_mapped: f64,
}

/// Map an outer-game result back to machines and accept it only if the
/// *flat* potential did not increase (same tolerance the dynamic-loop
/// descent check uses). The sequential runner, the in-process
/// distributed orchestrator, and the TCP leader all route through this
/// one function, so every deployment applies the identical guard.
///
/// The guard exists because the map-back places migrants by load, not
/// by cut: a placement can in principle trade the outer game's gain
/// away. Rejection is safe — the inner game still descends from
/// `before` — and on singleton racks the map-back *is* the outer
/// engine's own final partition, whose potential descended move by
/// move (the augmented game descends the raw potential too, DESIGN.md
/// §9), so the guard always accepts and bit-equality with the flat
/// game is preserved.
pub fn guarded_map_back(
    graph: &Graph,
    machines: &MachineConfig,
    layout: &RackLayout,
    before: &[MachineId],
    outer: &[MachineId],
    mu: f64,
    framework: Framework,
) -> OuterMapBack {
    let model = CostModel::new(graph, machines.clone(), mu, framework);
    let before_part = Partition::from_assignment(graph, machines.count(), before.to_vec());
    let mapped = layout.map_back(graph, machines, &before_part, outer);
    let mapped_part = Partition::from_assignment(graph, machines.count(), mapped.clone());
    let flat_before = model.potential(&before_part);
    let flat_mapped = model.potential(&mapped_part);
    let accepted = flat_mapped <= flat_before + 1e-9 * (1.0 + flat_before.abs());
    OuterMapBack {
        assignment: if accepted { mapped } else { before.to_vec() },
        accepted,
        flat_before,
        flat_mapped,
    }
}

/// Outcome of one two-level refinement pass.
#[derive(Debug, Clone)]
pub struct HierarchicalReport {
    /// The outer (rack-quotient) game's report. Its `final_potential`
    /// is the *quotient* potential the outer engine descended.
    pub outer: RefineReport,
    /// One inner report per rack, in rack order. `final_potential`
    /// values are the global flat potential as each subgame finished.
    pub inner: Vec<RefineReport>,
    /// Outer transfers actually applied (0 if discarded) plus all inner
    /// transfers.
    pub transfers: usize,
    /// True when the outer game and every inner subgame reached Nash.
    pub converged: bool,
    /// Fresh flat potential before the pass.
    pub potential_before: f64,
    /// Fresh flat potential after the pass.
    pub potential_after: f64,
    /// True when the outer result failed the [`guarded_map_back`] check
    /// and the inner game started from the original partition.
    pub outer_discarded: bool,
}

/// One sequential two-level refinement pass: outer quotient game →
/// guarded map-back → inner rack subgames chained on one shared engine
/// (exactly equivalent to per-rack concurrent play — see the module
/// docs). Returns the refined partition and the per-level reports.
#[allow(clippy::too_many_arguments)]
pub fn refine_hierarchical(
    graph: &Graph,
    machines: &MachineConfig,
    part: Partition,
    mu: f64,
    framework: Framework,
    migration_charge: f64,
    layout: &RackLayout,
    options: &RefineOptions,
) -> (Partition, HierarchicalReport) {
    assert_eq!(machines.count(), layout.machine_count());
    assert_eq!(part.machine_count(), layout.machine_count());

    // Outer game: the flat engine on the rack quotient.
    let qconfig = layout.quotient_config(machines);
    let qassign = layout.quotient_assignment(part.assignment());
    let qpart = Partition::from_assignment(graph, layout.rack_count(), qassign);
    let mut outer_engine = RefineEngine::new(graph, &qconfig, qpart, mu, framework)
        .with_migration_charge(migration_charge);
    let outer = outer_engine.run(options);
    let outer_part = outer_engine.into_partition();

    // Guarded map-back to machines.
    let mapped = guarded_map_back(
        graph,
        machines,
        layout,
        part.assignment(),
        outer_part.assignment(),
        mu,
        framework,
    );
    let outer_transfers = if mapped.accepted { outer.transfers } else { 0 };
    let start = Partition::from_assignment(graph, layout.machine_count(), mapped.assignment);

    // Inner game: rack subgames chained on one shared engine.
    let mut engine = RefineEngine::new(graph, machines, start, mu, framework)
        .with_migration_charge(migration_charge);
    let inner: Vec<RefineReport> =
        (0..layout.rack_count()).map(|r| engine.run_scoped(options, layout.members(r))).collect();

    let model = CostModel::new(graph, machines.clone(), mu, framework);
    let potential_after = model.potential(engine.partition());
    let report = HierarchicalReport {
        transfers: outer_transfers + inner.iter().map(|r| r.transfers).sum::<usize>(),
        converged: outer.converged && inner.iter().all(|r| r.converged),
        potential_before: mapped.flat_before,
        potential_after,
        outer_discarded: !mapped.accepted,
        outer,
        inner,
    };
    (engine.into_partition(), report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{table1_graph, WeightModel};
    use crate::util::rng::Pcg32;

    fn fixture(seed: u64) -> (Graph, MachineConfig, Vec<MachineId>) {
        let mut rng = Pcg32::new(seed);
        let g = table1_graph(80, 3, 6, WeightModel::default(), &mut rng);
        let machines = MachineConfig::from_speeds(&[1.0, 2.0, 3.0, 3.0, 1.0]);
        let assignment: Vec<MachineId> = (0..80).map(|i| i % 5).collect();
        (g, machines, assignment)
    }

    #[test]
    fn layout_validates_density_and_parse() {
        assert!(RackLayout::new(vec![0, 0, 2]).is_err(), "rack 1 missing");
        assert!(RackLayout::new(vec![]).is_err());
        let l = RackLayout::parse("0, 0, 1, 1", 4).unwrap();
        assert_eq!(l.rack_count(), 2);
        assert_eq!(l.members(0), &[0, 1]);
        assert_eq!(l.members(1), &[2, 3]);
        assert_eq!(l.leaders(), vec![0, 2]);
        assert!(l.is_leader(0) && !l.is_leader(1) && l.is_leader(2));
        assert!(RackLayout::parse("0,0,1", 4).is_err(), "length mismatch");
        assert!(RackLayout::parse("0,x,1,1", 4).is_err(), "non-numeric");
        assert!(RackLayout::singletons(3).is_singleton());
        assert!(!l.is_singleton());
    }

    #[test]
    fn without_machines_renumbers_and_drops_empty_racks() {
        let l = RackLayout::new(vec![0, 0, 1, 1, 2]).unwrap();
        // Drop machine 4 (the whole of rack 2) and machine 1.
        let survivors = l.without_machines(&[1, 4]).unwrap();
        assert_eq!(survivors.rack_of_slice(), &[0, 1, 1]);
        assert_eq!(survivors.rack_count(), 2);
        // Dropping everything is an error, not a panic.
        assert!(l.without_machines(&[0, 1, 2, 3, 4]).is_err());
        // Determinism: same input, same layout.
        assert_eq!(survivors, l.without_machines(&[1, 4]).unwrap());
    }

    #[test]
    fn join_rack_prefers_smallest_rack_then_lowest_id() {
        let l = RackLayout::new(vec![0, 0, 1]).unwrap();
        assert_eq!(l.join_rack(), 1);
        let tie = RackLayout::new(vec![0, 0, 1, 1]).unwrap();
        assert_eq!(tie.join_rack(), 0);
        let grown = tie.with_inserted(4, 1).unwrap();
        assert_eq!(grown.rack_of_slice(), &[0, 0, 1, 1, 1]);
        let new_rack = tie.with_inserted(0, 2).unwrap();
        assert_eq!(new_rack.rack_count(), 3);
        assert_eq!(new_rack.rack_of(0), 2);
        assert!(tie.with_inserted(0, 3).is_err(), "gap rack id");
    }

    #[test]
    fn singleton_quotient_config_is_bit_identical() {
        let machines = MachineConfig::from_speeds(&[1.0, 2.0, 3.0, 3.0, 1.0]);
        let q = RackLayout::singletons(5).quotient_config(&machines);
        for m in 0..5 {
            assert_eq!(q.speed(m).to_bits(), machines.speed(m).to_bits());
        }
    }

    #[test]
    fn singleton_racks_reproduce_the_flat_game_bit_for_bit() {
        // Frameworks A and B, charged and uncharged: with one machine
        // per rack the outer game IS the flat game and the inner
        // subgames are no-ops, so assignments, transfer counts, and the
        // outer engine's incremental potential must match exactly.
        for &fw in &[Framework::A, Framework::B] {
            for &charge in &[0.0, 25.0] {
                let (g, machines, assignment) = fixture(11);
                let layout = RackLayout::singletons(5);
                let options = RefineOptions::default();

                let flat_start = Partition::from_assignment(&g, 5, assignment.clone());
                let mut flat = RefineEngine::new(&g, &machines, flat_start, 8.0, fw)
                    .with_migration_charge(charge);
                let flat_report = flat.run(&options);

                let start = Partition::from_assignment(&g, 5, assignment);
                let (part, report) = refine_hierarchical(
                    &g,
                    &machines,
                    start,
                    8.0,
                    fw,
                    charge,
                    &layout,
                    &options,
                );
                assert_eq!(part.assignment(), flat.partition().assignment(), "{fw:?}/{charge}");
                assert_eq!(report.transfers, flat_report.transfers, "{fw:?}/{charge}");
                assert_eq!(
                    report.outer.final_potential.to_bits(),
                    flat_report.final_potential.to_bits(),
                    "{fw:?}/{charge}"
                );
                assert_eq!(report.converged, flat_report.converged);
                assert!(!report.outer_discarded, "guard must accept a descending flat run");
                assert_eq!(report.inner.iter().map(|r| r.transfers).sum::<usize>(), 0);
            }
        }
    }

    #[test]
    fn per_level_descent_on_real_racks() {
        // Property: with 2 racks of mixed size, every recorded step of
        // the outer trace and each inner trace is non-increasing, and
        // the composed pass descends the flat potential.
        for seed in [3u64, 7, 19] {
            for &fw in &[Framework::A, Framework::B] {
                let (g, machines, assignment) = fixture(seed);
                let layout = RackLayout::new(vec![0, 0, 0, 1, 1]).unwrap();
                let options = RefineOptions { track_potential: true, ..Default::default() };
                let start = Partition::from_assignment(&g, 5, assignment);
                let (part, report) =
                    refine_hierarchical(&g, &machines, start, 8.0, fw, 0.0, &layout, &options);
                part.validate(&g).unwrap();
                for w in report.outer.potential_trace.windows(2) {
                    assert!(w[1] <= w[0] + 1e-9 * (1.0 + w[0].abs()), "outer ascent {w:?}");
                }
                for inner in &report.inner {
                    for w in inner.potential_trace.windows(2) {
                        assert!(w[1] <= w[0] + 1e-9 * (1.0 + w[0].abs()), "inner ascent {w:?}");
                    }
                }
                assert!(
                    report.potential_after
                        <= report.potential_before + 1e-9 * (1.0 + report.potential_before.abs()),
                    "seed {seed} {fw:?}: flat potential rose {} -> {}",
                    report.potential_before,
                    report.potential_after
                );
                assert!(report.converged, "both levels should reach Nash");
            }
        }
    }

    #[test]
    fn map_back_keeps_stayers_and_places_migrants_in_rack() {
        let (g, machines, assignment) = fixture(5);
        let layout = RackLayout::new(vec![0, 0, 0, 1, 1]).unwrap();
        let before = Partition::from_assignment(&g, 5, assignment.clone());
        // Push every node of rack 0 to rack 1 and vice versa.
        let outer: Vec<MachineId> =
            assignment.iter().map(|&m| 1 - layout.rack_of(m)).collect();
        let mapped = layout.map_back(&g, &machines, &before, &outer);
        for (i, &m) in mapped.iter().enumerate() {
            assert_eq!(layout.rack_of(m), outer[i], "node {i} landed outside its rack");
        }
        // Stayers keep machines: identity outer assignment is a no-op.
        let stay: Vec<MachineId> = assignment.iter().map(|&m| layout.rack_of(m)).collect();
        assert_eq!(layout.map_back(&g, &machines, &before, &stay), assignment);
        // Deterministic.
        assert_eq!(mapped, layout.map_back(&g, &machines, &before, &outer));
    }

    #[test]
    fn guard_rejects_an_ascending_map_back() {
        // Hand the guard an "outer result" that lumps everything onto
        // rack 0 — the flat potential rises, so it must refuse and hand
        // back the original assignment.
        let (g, machines, assignment) = fixture(2);
        let layout = RackLayout::new(vec![0, 0, 0, 1, 1]).unwrap();
        let lumped = vec![0usize; 80];
        let out = guarded_map_back(
            &g,
            &machines,
            &layout,
            &assignment,
            &lumped,
            8.0,
            Framework::A,
        );
        assert!(!out.accepted);
        assert!(out.flat_mapped > out.flat_before);
        assert_eq!(out.assignment, assignment);
    }

    #[test]
    fn scoped_subgames_chain_like_independent_racks() {
        // The inner phase must not let rack 1's subgame disturb rack
        // 0's result: running rack 0 alone on a fresh engine matches
        // rack 0's slice of the chained run.
        let (g, machines, assignment) = fixture(13);
        let layout = RackLayout::new(vec![0, 0, 0, 1, 1]).unwrap();
        let options = RefineOptions::default();

        let mut chained = RefineEngine::new(
            &g,
            &machines,
            Partition::from_assignment(&g, 5, assignment.clone()),
            8.0,
            Framework::A,
        );
        let r0 = chained.run_scoped(&options, layout.members(0));
        let r1 = chained.run_scoped(&options, layout.members(1));
        assert!(r0.converged && r1.converged);

        let mut solo = RefineEngine::new(
            &g,
            &machines,
            Partition::from_assignment(&g, 5, assignment),
            8.0,
            Framework::A,
        );
        let solo0 = solo.run_scoped(&options, layout.members(0));
        assert_eq!(solo0.transfers, r0.transfers);
        assert_eq!(solo0.final_potential.to_bits(), r0.final_potential.to_bits());
        for (i, (&a, &b)) in
            solo.partition().assignment().iter().zip(chained.partition().assignment()).enumerate()
        {
            if layout.rack_of(a) == 0 || layout.rack_of(b) == 0 {
                assert_eq!(a, b, "rack-0 node {i} diverged");
            }
        }
    }
}

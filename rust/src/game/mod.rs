//! The partitioning game (paper §3–§5): node-level cost frameworks, the
//! dissatisfaction criterion, and the iterative partition-refinement
//! engine, plus the meta-heuristic extensions (§4.4 simulated annealing,
//! §7 cluster transfers).

pub mod annealing;
pub mod cluster;
pub mod cost;
pub mod hierarchy;
pub mod refine;

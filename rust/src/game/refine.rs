//! Iterative partition refinement (paper §4.2, Figs. 1–2).
//!
//! Machines take turns in round-robin order. On its turn a machine finds
//! the **most dissatisfied** node it owns (eq. 4) and transfers it to
//! that node's best-response machine; if no owned node is dissatisfied
//! the machine forfeits its turn. When all K machines forfeit
//! consecutively the partition is a pure-strategy Nash equilibrium of
//! the chosen framework's game and the algorithm has converged (Thm 4.1:
//! each transfer strictly descends the potential, which is bounded
//! below, so convergence is guaranteed).
//!
//! The engine maintains the §4.5 incremental state: per-node adjacency-
//! to-machine rows (updated in O(deg(l)) per transfer) and the O(K)
//! machine load aggregates, so one machine turn costs O(N_m · K) and a
//! node transfer costs O(deg(l) + K).

use crate::game::cost::{CostModel, Framework};
use crate::graph::{Graph, NodeId};
use crate::partition::{MachineConfig, MachineId, Partition};

/// Options controlling a refinement run.
#[derive(Debug, Clone)]
pub struct RefineOptions {
    /// Hard cap on node transfers (safety valve; the algorithm converges
    /// on its own).
    pub max_transfers: usize,
    /// Record the potential after every transfer.
    pub track_potential: bool,
    /// Minimum dissatisfaction treated as non-zero (floating-point
    /// hygiene; exact 0 in theory).
    pub epsilon: f64,
}

impl Default for RefineOptions {
    fn default() -> Self {
        RefineOptions { max_transfers: 1_000_000, track_potential: false, epsilon: 1e-9 }
    }
}

/// Outcome of a refinement run.
#[derive(Debug, Clone)]
pub struct RefineReport {
    /// Number of node transfers executed ("iterations" in Table I).
    pub transfers: usize,
    /// Number of machine turns consumed (including forfeits).
    pub turns: usize,
    /// True if a Nash equilibrium was reached (all machines forfeited).
    pub converged: bool,
    /// Potential value at convergence (C0 for A, C̃0 for B).
    pub final_potential: f64,
    /// Potential after each transfer, if tracked.
    pub potential_trace: Vec<f64>,
}

/// A single executed transfer (also used by the distributed coordinator
/// to broadcast `ReceiveNodeTrigger` payloads).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transfer {
    pub node: NodeId,
    pub from: MachineId,
    pub to: MachineId,
    /// Dissatisfaction of the node at the moment of transfer.
    pub dissatisfaction: f64,
}

/// Sequential refinement engine.
pub struct RefineEngine<'g> {
    model: CostModel<'g>,
    part: Partition,
    /// Per-machine membership lists with O(1) removal.
    members: Vec<Vec<NodeId>>,
    /// `position[i]` = index of node `i` inside `members[machine_of(i)]`.
    position: Vec<usize>,
    /// Flattened N×K adjacency-to-machine table `adj[i*K + k]`.
    adj: Vec<f64>,
    /// `s[i] = Σ_j c_ij` (incident weight of node `i`).
    s: Vec<f64>,
    /// Incrementally tracked potential.
    potential: f64,
    /// Machine whose turn is next.
    next_turn: MachineId,
    transfers_done: usize,
    turns_done: usize,
}

impl<'g> RefineEngine<'g> {
    /// Build the engine for a graph + machine pool + starting partition.
    pub fn new(
        graph: &'g Graph,
        machines: &MachineConfig,
        part: Partition,
        mu: f64,
        framework: Framework,
    ) -> Self {
        let model = CostModel::new(graph, machines.clone(), mu, framework);
        let k = machines.count();
        let n = graph.node_count();
        assert_eq!(part.machine_count(), k);
        assert_eq!(part.node_count(), n);

        let mut members: Vec<Vec<NodeId>> = vec![Vec::new(); k];
        let mut position = vec![0usize; n];
        for i in 0..n {
            let m = part.machine_of(i);
            position[i] = members[m].len();
            members[m].push(i);
        }
        let mut adj = vec![0.0f64; n * k];
        let mut s = vec![0.0f64; n];
        for i in 0..n {
            let row = &mut adj[i * k..(i + 1) * k];
            for (j, c) in graph.neighbors_weighted(i) {
                row[part.machine_of(j)] += c;
                s[i] += c;
            }
        }
        let potential = model.potential(&part);
        RefineEngine {
            model,
            part,
            members,
            position,
            adj,
            s,
            potential,
            next_turn: 0,
            transfers_done: 0,
            turns_done: 0,
        }
    }

    /// Builder: price every transfer at `c_mig` cost units inside the
    /// game (augmented dissatisfaction, DESIGN.md §9). A move is only
    /// accepted when its raw gain exceeds the charge, which damps
    /// migration churn at the source instead of post-hoc; the augmented
    /// potential `Φ + c_mig·transfers` still strictly descends.
    pub fn with_migration_charge(mut self, c_mig: f64) -> Self {
        assert!(c_mig >= 0.0 && c_mig.is_finite(), "migration charge must be finite and >= 0");
        self.model.migration_charge = c_mig;
        self
    }

    /// The graph being partitioned.
    pub fn graph(&self) -> &Graph {
        self.model.graph
    }

    /// Current partition (read-only).
    pub fn partition(&self) -> &Partition {
        &self.part
    }

    /// Consume the engine and return the partition.
    pub fn into_partition(self) -> Partition {
        self.part
    }

    /// Current *raw* potential (C0 for framework A, C̃0 for B).
    pub fn potential(&self) -> f64 {
        self.potential
    }

    /// The per-move migration surcharge priced into the game.
    pub fn migration_charge(&self) -> f64 {
        self.model.migration_charge
    }

    /// Augmented potential `Φ' = Φ + c_mig·(#transfers executed)` —
    /// strictly descends on every accepted transfer (DESIGN.md §9).
    pub fn augmented_potential(&self) -> f64 {
        crate::partition::global_cost::augmented(
            self.potential,
            self.model.migration_charge,
            self.transfers_done,
        )
    }

    /// The cost model in use.
    pub fn model(&self) -> &CostModel<'g> {
        &self.model
    }

    /// Number of transfers executed so far.
    pub fn transfers_done(&self) -> usize {
        self.transfers_done
    }

    /// Find machine `m`'s most dissatisfied node: `(node, 𝔍, best_k)`,
    /// or `None` if every owned node has `𝔍 ≤ epsilon`.
    ///
    /// Framework A uses a candidate-set fast path (see
    /// [`most_dissatisfied_fast_a`]); framework B evaluates all K
    /// candidates (its load term is not reducible to a single per-machine
    /// scalar, and K is small).
    pub fn most_dissatisfied(
        &self,
        m: MachineId,
        epsilon: f64,
    ) -> Option<(NodeId, f64, MachineId)> {
        if self.model.framework == Framework::A {
            return self.most_dissatisfied_fast_a(m, epsilon);
        }
        let k = self.model.k();
        let mut best: Option<(NodeId, f64, MachineId)> = None;
        for &i in &self.members[m] {
            let row = &self.adj[i * k..(i + 1) * k];
            let (j, target) = self.model.dissatisfaction_with_adj(&self.part, i, self.s[i], row);
            if j > epsilon {
                match best {
                    Some((_, bj, _)) if bj >= j => {}
                    _ => best = Some((i, j, target)),
                }
            }
        }
        best
    }

    /// Framework-A specialized scan (§Perf): delegates the per-node work
    /// to [`CostModel::dissat_fast_a`] (≤ deg_i + 2 exact evaluations per
    /// node instead of K), computing the `argmin L_q/w_q` precondition
    /// once per turn instead of once per node.
    fn most_dissatisfied_fast_a(
        &self,
        m: MachineId,
        epsilon: f64,
    ) -> Option<(NodeId, f64, MachineId)> {
        let k = self.model.k();
        let q1 = self.model.argmin_load_per_speed(&self.part);
        let mut best: Option<(NodeId, f64, MachineId)> = None;
        for &i in &self.members[m] {
            let row = &self.adj[i * k..(i + 1) * k];
            let (j, target) = self.model.dissat_fast_a(&self.part, i, self.s[i], row, q1);
            if j > epsilon {
                match best {
                    Some((_, bj, _)) if bj >= j => {}
                    _ => best = Some((i, j, target)),
                }
            }
        }
        best
    }

    /// Execute a transfer, maintaining all incremental state. Returns
    /// the potential delta (negative for best-response moves).
    pub fn apply_transfer(&mut self, node: NodeId, to: MachineId) -> f64 {
        let delta = self.model.potential_delta(&self.part, node, to);
        self.apply_transfer_with_delta(node, to, delta);
        delta
    }

    /// Transfer with a pre-computed potential delta (§Perf: `take_turn`
    /// already knows `Δ = −2𝔍` for A / `−𝔍` for B from the scan, so the
    /// O(deg + K) delta recomputation is skipped on the hot path).
    fn apply_transfer_with_delta(&mut self, node: NodeId, to: MachineId, delta: f64) {
        let from = self.part.machine_of(node);
        assert_ne!(from, to, "transfer to same machine");

        // Membership lists: swap-remove from `from`, push to `to`.
        let pos = self.position[node];
        let last = *self.members[from].last().expect("member list nonempty");
        self.members[from].swap_remove(pos);
        if last != node {
            self.position[last] = pos;
        }
        self.position[node] = self.members[to].len();
        self.members[to].push(node);

        // Partition aggregates.
        self.part.transfer(self.model.graph, node, to);

        // Neighbors' adjacency rows: c_{j,node} moves from column `from`
        // to column `to`.
        let k = self.model.k();
        for (j, c) in self.model.graph.neighbors_weighted(node) {
            let row = &mut self.adj[j * k..(j + 1) * k];
            row[from] -= c;
            row[to] += c;
        }

        self.potential += delta;
        self.transfers_done += 1;
    }

    /// One machine turn (paper Fig. 2 `TakeMyTurnTrigger` body). Returns
    /// the executed transfer, or `None` if the machine forfeited.
    pub fn take_turn(&mut self, m: MachineId, epsilon: f64) -> Option<Transfer> {
        self.turns_done += 1;
        let (node, dissat, target) = self.most_dissatisfied(m, epsilon)?;
        let from = self.part.machine_of(node);
        // ΔC0 = 2·ΔC_l = −2𝔍 (Thm 3.1); ΔC̃0 = ΔC̃_l = −𝔍 (Thm 5.1).
        // Under the augmented game 𝔍 is the *augmented* dissatisfaction
        // (raw gain minus c_mig, and 𝔍 > ε ⇒ target ≠ from), so the raw
        // node-cost change is −(𝔍 + c_mig) and the raw potential drops
        // by at least the charge on every accepted transfer.
        let raw_gain = dissat + self.model.migration_charge;
        let delta = match self.model.framework {
            Framework::A => -2.0 * raw_gain,
            Framework::B => -raw_gain,
        };
        self.apply_transfer_with_delta(node, target, delta);
        Some(Transfer { node, from, to: target, dissatisfaction: dissat })
    }

    /// Run round-robin turns until convergence (all K machines forfeit
    /// consecutively) or the transfer cap is hit.
    pub fn run(&mut self, options: &RefineOptions) -> RefineReport {
        let k = self.model.k();
        let mut trace = Vec::new();
        if options.track_potential {
            trace.push(self.potential);
        }
        let mut consecutive_forfeits = 0;
        let mut transfers = 0;
        while consecutive_forfeits < k && transfers < options.max_transfers {
            let m = self.next_turn;
            self.next_turn = (self.next_turn + 1) % k;
            match self.take_turn(m, options.epsilon) {
                Some(_) => {
                    consecutive_forfeits = 0;
                    transfers += 1;
                    if options.track_potential {
                        trace.push(self.potential);
                    }
                }
                None => consecutive_forfeits += 1,
            }
        }
        RefineReport {
            transfers,
            turns: self.turns_done,
            converged: consecutive_forfeits >= k,
            final_potential: self.potential,
            potential_trace: trace,
        }
    }

    /// Machine `m`'s most dissatisfied node when candidate targets are
    /// restricted to `scope` (the inner rack subgame, DESIGN.md §12):
    /// `(node, 𝔍, best_k)` with the argmin over `scope ∪ {r_i}`, or
    /// `None` if every owned node has scoped `𝔍 ≤ epsilon`. Both
    /// frameworks use the generic scan — the framework-A candidate-set
    /// fast path assumes the global `argmin L_q/w_q` is a candidate,
    /// which a scope does not contain in general.
    pub fn most_dissatisfied_scoped(
        &self,
        m: MachineId,
        epsilon: f64,
        scope: &[MachineId],
    ) -> Option<(NodeId, f64, MachineId)> {
        let k = self.model.k();
        let mut best: Option<(NodeId, f64, MachineId)> = None;
        for &i in &self.members[m] {
            let row = &self.adj[i * k..(i + 1) * k];
            let (j, target) =
                self.model.dissatisfaction_scoped_with_adj(&self.part, i, self.s[i], row, scope);
            if j > epsilon {
                match best {
                    Some((_, bj, _)) if bj >= j => {}
                    _ => best = Some((i, j, target)),
                }
            }
        }
        best
    }

    /// One scope-restricted machine turn. The ΔΦ identities of
    /// [`take_turn`] hold verbatim: a scoped best response is still a
    /// best response among the candidates it considered, so the raw
    /// potential drops by `2·(𝔍 + c_mig)` (A) / `𝔍 + c_mig` (B) on
    /// every accepted transfer — the inner game descends the *global*
    /// flat potential, not merely a per-rack objective.
    pub fn take_turn_scoped(
        &mut self,
        m: MachineId,
        epsilon: f64,
        scope: &[MachineId],
    ) -> Option<Transfer> {
        self.turns_done += 1;
        let (node, dissat, target) = self.most_dissatisfied_scoped(m, epsilon, scope)?;
        let from = self.part.machine_of(node);
        let raw_gain = dissat + self.model.migration_charge;
        let delta = match self.model.framework {
            Framework::A => -2.0 * raw_gain,
            Framework::B => -raw_gain,
        };
        self.apply_transfer_with_delta(node, target, delta);
        Some(Transfer { node, from, to: target, dissatisfaction: dissat })
    }

    /// Run a round-robin subgame over `scope` only (ascending machine
    /// ids; turn order starts at `scope[0]`), until all `scope.len()`
    /// members forfeit consecutively or the transfer cap is hit. The
    /// engine's global ring position (`next_turn`) is untouched, so
    /// scoped subgames can be chained rack-by-rack on one shared engine
    /// — and because scoped turns only move nodes between machines of
    /// `scope`, the loads and adjacency columns of every other machine
    /// are invariant, which makes rack subgames exactly independent
    /// (DESIGN.md §12). A singleton scope forfeits immediately (the
    /// argmin over one machine is the current machine).
    ///
    /// `turns` and `final_potential` mirror [`run`]: the cumulative
    /// engine turn counter and the global flat potential.
    pub fn run_scoped(&mut self, options: &RefineOptions, scope: &[MachineId]) -> RefineReport {
        assert!(!scope.is_empty(), "scope must name at least one machine");
        debug_assert!(
            scope.windows(2).all(|w| w[0] < w[1]) && *scope.last().unwrap() < self.model.k(),
            "scope must be ascending machine ids in range"
        );
        let k = scope.len();
        let mut trace = Vec::new();
        if options.track_potential {
            trace.push(self.potential);
        }
        let mut pos = 0usize;
        let mut consecutive_forfeits = 0;
        let mut transfers = 0;
        while consecutive_forfeits < k && transfers < options.max_transfers {
            let m = scope[pos];
            pos = (pos + 1) % k;
            match self.take_turn_scoped(m, options.epsilon, scope) {
                Some(_) => {
                    consecutive_forfeits = 0;
                    transfers += 1;
                    if options.track_potential {
                        trace.push(self.potential);
                    }
                }
                None => consecutive_forfeits += 1,
            }
        }
        RefineReport {
            transfers,
            turns: self.turns_done,
            converged: consecutive_forfeits >= k,
            final_potential: self.potential,
            potential_trace: trace,
        }
    }

    /// Re-sync all incremental state after the graph's node/edge weights
    /// changed (dynamic re-weighting between refinement epochs, §6.1).
    /// O(N·K + |E|).
    pub fn resync_weights(&mut self) {
        let k = self.model.k();
        let n = self.model.graph.node_count();
        self.part.rebuild_aggregates(self.model.graph);
        self.adj.iter_mut().for_each(|x| *x = 0.0);
        self.s.iter_mut().for_each(|x| *x = 0.0);
        for i in 0..n {
            let row = &mut self.adj[i * k..(i + 1) * k];
            for (j, c) in self.model.graph.neighbors_weighted(i) {
                row[self.part.machine_of(j)] += c;
                self.s[i] += c;
            }
        }
        self.potential = self.model.potential(&self.part);
    }

    /// Debug validation: incremental state equals from-scratch state.
    pub fn validate(&self) -> Result<(), String> {
        self.part.validate(self.model.graph)?;
        let k = self.model.k();
        for i in 0..self.model.graph.node_count() {
            let mut row = vec![0.0; k];
            let s = self.model.adj_row(&self.part, i, &mut row);
            if (s - self.s[i]).abs() > 1e-6 * (1.0 + s.abs()) {
                return Err(format!("s[{i}] drift: {} vs {}", self.s[i], s));
            }
            for m in 0..k {
                let cached = self.adj[i * k + m];
                if (cached - row[m]).abs() > 1e-6 * (1.0 + row[m].abs()) {
                    return Err(format!("adj[{i},{m}] drift: {cached} vs {}", row[m]));
                }
            }
            if self.position[i] >= self.members[self.part.machine_of(i)].len()
                || self.members[self.part.machine_of(i)][self.position[i]] != i
            {
                return Err(format!("membership index broken for node {i}"));
            }
        }
        let fresh = self.model.potential(&self.part);
        if (fresh - self.potential).abs() > 1e-6 * (1.0 + fresh.abs()) {
            return Err(format!("potential drift: {} vs {}", self.potential, fresh));
        }
        Ok(())
    }
}

/// Re-home an assignment after machines left the fleet — the
/// elastic-membership step of checkpoint recovery (DESIGN.md §10).
///
/// Surviving machines are renumbered compactly (old id `m` maps to
/// `m − #{d ∈ dead : d < m}`), keeping their nodes. Each orphaned node
/// (owned by a dead machine) goes to the survivor with the lowest
/// normalized load `L_q / w_q` at that moment, processed in ascending
/// node order with ties broken toward the lowest machine index — fully
/// deterministic, so every replica derives the same partition. Returns
/// the new assignment (over `machines.count()` machines) and the
/// number of re-homed nodes.
///
/// This is only a *feasible* starting point, not an equilibrium: the
/// caller runs one refinement pass from it, which Thm 4.1 guarantees
/// descends the potential from any start. A machine *joining* needs no
/// re-homing at all — the old assignment is already feasible over K+1
/// machines (the newcomer starts empty) and refinement pulls nodes
/// toward it.
pub fn rehome_assignment(
    assignment: &[MachineId],
    dead: &[MachineId],
    graph: &Graph,
    machines: &MachineConfig,
) -> (Vec<MachineId>, usize) {
    let k_after = machines.count();
    let k_before = k_after + dead.len();
    assert_eq!(graph.node_count(), assignment.len(), "assignment/graph size mismatch");
    let mut map = vec![usize::MAX; k_before];
    let mut next = 0;
    for (m, slot) in map.iter_mut().enumerate() {
        if !dead.contains(&m) {
            *slot = next;
            next += 1;
        }
    }
    assert_eq!(next, k_after, "dead set does not match the shrunken fleet");

    let mut loads = vec![0.0f64; k_after];
    let mut rehomed = 0usize;
    let mut out = Vec::with_capacity(assignment.len());
    for (i, &m) in assignment.iter().enumerate() {
        assert!(m < k_before, "assignment references machine {m} outside the old fleet");
        let target = map[m];
        if target != usize::MAX {
            loads[target] += graph.node_weight(i);
        }
        out.push(target);
    }
    for (i, slot) in out.iter_mut().enumerate() {
        if *slot == usize::MAX {
            let mut best = 0;
            let mut best_score = f64::INFINITY;
            for (q, &load) in loads.iter().enumerate() {
                let score = load / machines.speed(q);
                if score < best_score {
                    best = q;
                    best_score = score;
                }
            }
            loads[best] += graph.node_weight(i);
            *slot = best;
            rehomed += 1;
        }
    }
    (out, rehomed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{table1_graph, WeightModel};
    use crate::partition::global_cost;
    use crate::util::rng::Pcg32;

    fn random_partition(n: usize, k: usize, rng: &mut Pcg32) -> Vec<usize> {
        (0..n).map(|_| rng.index(k)).collect()
    }

    fn engine(seed: u64, fw: Framework) -> RefineEngine<'static> {
        let mut rng = Pcg32::new(seed);
        let g = table1_graph(80, 3, 6, WeightModel::default(), &mut rng);
        let g: &'static Graph = Box::leak(Box::new(g));
        let machines = MachineConfig::from_speeds(&[0.1, 0.2, 0.3, 0.3, 0.1]);
        let assignment = random_partition(80, 5, &mut rng);
        let part = Partition::from_assignment(g, 5, assignment);
        RefineEngine::new(g, &machines, part, 8.0, fw)
    }

    #[test]
    fn converges_and_descends_framework_a() {
        let mut e = engine(1, Framework::A);
        let start = e.potential();
        let report = e.run(&RefineOptions { track_potential: true, ..Default::default() });
        assert!(report.converged);
        assert!(report.final_potential <= start);
        // strict descent at every step
        for w in report.potential_trace.windows(2) {
            assert!(w[1] < w[0] + 1e-9, "non-descent step: {} -> {}", w[0], w[1]);
        }
        e.validate().unwrap();
    }

    #[test]
    fn converges_and_descends_framework_b() {
        let mut e = engine(2, Framework::B);
        let start = e.potential();
        let report = e.run(&RefineOptions { track_potential: true, ..Default::default() });
        assert!(report.converged);
        assert!(report.final_potential <= start);
        for w in report.potential_trace.windows(2) {
            assert!(w[1] < w[0] + 1e-9);
        }
        e.validate().unwrap();
    }

    #[test]
    fn converged_state_is_nash_equilibrium() {
        for fw in [Framework::A, Framework::B] {
            let mut e = engine(3, fw);
            let report = e.run(&RefineOptions::default());
            assert!(report.converged);
            // No node can improve by unilateral deviation.
            for i in 0..e.partition().node_count() {
                let (j, _) = e.model().dissatisfaction(e.partition(), i);
                assert!(j <= 1e-6, "fw {fw}: node {i} still dissatisfied by {j}");
            }
        }
    }

    #[test]
    fn incremental_potential_matches_scratch() {
        let mut e = engine(4, Framework::A);
        let _ = e.run(&RefineOptions::default());
        let scratch =
            global_cost::c0(e.model().graph, &e.model().machines, e.partition(), e.model().mu);
        assert!((e.potential() - scratch).abs() < 1e-6 * (1.0 + scratch.abs()));
    }

    #[test]
    fn transfer_cap_respected() {
        let mut e = engine(5, Framework::A);
        let report = e.run(&RefineOptions { max_transfers: 3, ..Default::default() });
        assert!(report.transfers <= 3);
    }

    #[test]
    fn apply_transfer_keeps_state_valid() {
        let mut e = engine(6, Framework::A);
        // Move several arbitrary nodes irrespective of dissatisfaction.
        for (node, to) in [(0usize, 1usize), (5, 2), (10, 0), (0, 4)] {
            if e.partition().machine_of(node) != to {
                e.apply_transfer(node, to);
            }
            e.validate().unwrap();
        }
    }

    #[test]
    fn resync_is_idempotent_and_reweighting_reconverges() {
        let mut rng = Pcg32::new(7);
        let mut g = table1_graph(60, 3, 6, WeightModel::default(), &mut rng);
        let machines = MachineConfig::homogeneous(4);
        let assignment = random_partition(60, 4, &mut rng);

        // Epoch 1: refine, resync (no weight change) must be a no-op.
        let part = Partition::from_assignment(&g, 4, assignment);
        let converged = {
            let mut e = RefineEngine::new(&g, &machines, part, 4.0, Framework::A);
            let _ = e.run(&RefineOptions::default());
            let before = e.potential();
            e.resync_weights();
            assert!((e.potential() - before).abs() < 1e-6 * (1.0 + before.abs()));
            e.validate().unwrap();
            e.into_partition()
        };

        // Dynamic load change (paper §6.1): new node weights, then a new
        // refinement epoch starting from the previous assignment.
        let w: Vec<f64> = (0..60).map(|i| 1.0 + (i % 7) as f64).collect();
        g.set_node_weights(&w);
        let mut part2 = converged;
        part2.rebuild_aggregates(&g);
        let mut e2 = RefineEngine::new(&g, &machines, part2, 4.0, Framework::A);
        let report = e2.run(&RefineOptions::default());
        assert!(report.converged);
        e2.validate().unwrap();
    }

    #[test]
    fn equilibrium_forfeits_all_turns() {
        let mut e = engine(8, Framework::A);
        let _ = e.run(&RefineOptions::default());
        for m in 0..5 {
            assert!(e.most_dissatisfied(m, 1e-9).is_none());
        }
    }

    fn engine_with_charge(seed: u64, fw: Framework, c_mig: f64) -> RefineEngine<'static> {
        engine(seed, fw).with_migration_charge(c_mig)
    }

    /// Augmented game: converges to an augmented Nash equilibrium, the
    /// raw potential drops by at least the charge per transfer, and the
    /// augmented potential Φ + c·t strictly descends.
    #[test]
    fn augmented_game_converges_and_descends() {
        for fw in [Framework::A, Framework::B] {
            let charge = 2.0;
            let mut e = engine_with_charge(20, fw, charge);
            let start_aug = e.augmented_potential();
            let report = e.run(&RefineOptions { track_potential: true, ..Default::default() });
            assert!(report.converged, "fw {fw}: no convergence under charge");
            // Raw trace: each step drops by at least (charge for B,
            // 2*charge for A).
            let min_drop = match fw {
                Framework::A => 2.0 * charge,
                Framework::B => charge,
            };
            for w in report.potential_trace.windows(2) {
                assert!(
                    w[1] <= w[0] - min_drop + 1e-9 * (1.0 + w[0].abs()),
                    "fw {fw}: step dropped less than the charge: {} -> {}",
                    w[0],
                    w[1]
                );
            }
            // Augmented potential strictly descends end to end.
            assert!(
                e.augmented_potential() < start_aug || report.transfers == 0,
                "fw {fw}: augmented potential did not descend"
            );
            // Augmented equilibrium: no node's raw gain exceeds the charge.
            for i in 0..e.partition().node_count() {
                let (j, _) = e.model().dissatisfaction(e.partition(), i);
                assert!(j <= 1e-6, "fw {fw}: node {i} still (augmented-)dissatisfied by {j}");
            }
            e.validate().unwrap();
        }
    }

    /// Zero charge is exactly the paper's game: identical transfer
    /// sequence and final assignment.
    #[test]
    fn zero_charge_is_the_unaugmented_game() {
        let mut plain = engine(21, Framework::A);
        let mut zero = engine_with_charge(21, Framework::A, 0.0);
        let rp = plain.run(&RefineOptions::default());
        let rz = zero.run(&RefineOptions::default());
        assert_eq!(rp.transfers, rz.transfers);
        assert_eq!(plain.partition().assignment(), zero.partition().assignment());
        assert_eq!(rp.final_potential.to_bits(), rz.final_potential.to_bits());
    }

    /// Churn damping, theorem-backed: every positive charge level
    /// satisfies the churn bound `T ≤ (Φ_start − Φ_end) / min_drop`
    /// (each accepted move drops the raw potential by ≥ c for B, ≥ 2c
    /// for A), and a prohibitive charge — far above any raw gain these
    /// fixtures can produce — freezes the partition entirely. (The
    /// rung-to-rung monotonicity of a fixed fixture is pinned in
    /// `prop_invariants::churn_monotone_in_migration_charge_on_fixed_fixture`;
    /// it is an empirical property, not a theorem.)
    #[test]
    fn charge_ladder_damps_churn() {
        for fw in [Framework::A, Framework::B] {
            for &charge in &[4.0, 32.0, 256.0] {
                let mut e = engine_with_charge(22, fw, charge);
                let start = e.potential();
                let report = e.run(&RefineOptions::default());
                assert!(report.converged);
                let min_drop = match fw {
                    Framework::A => 2.0 * charge,
                    Framework::B => charge,
                };
                let bound = (start - e.potential()) / min_drop;
                assert!(
                    report.transfers as f64 <= bound * (1.0 + 1e-9) + 1e-9,
                    "fw {fw} charge {charge}: {} transfers > churn bound {bound}",
                    report.transfers
                );
            }
            let mut frozen = engine_with_charge(22, fw, 1e9);
            let report = frozen.run(&RefineOptions::default());
            assert!(report.converged);
            assert_eq!(report.transfers, 0, "fw {fw}: a 1e9 charge should freeze everything");
        }
    }

    /// `rehome_assignment` mechanics: survivors renumber compactly and
    /// keep their nodes; orphans land on the least-loaded survivor in
    /// a deterministic order.
    #[test]
    fn rehome_renumbers_survivors_and_spreads_orphans() {
        let mut rng = Pcg32::new(30);
        let g = table1_graph(40, 3, 6, WeightModel::default(), &mut rng);
        let machines_before = MachineConfig::from_speeds(&[0.1, 0.2, 0.3, 0.3, 0.1]);
        let assignment = random_partition(40, 5, &mut rng);
        let orphans = assignment.iter().filter(|&&m| m == 2).count();
        assert!(orphans > 0, "fixture must put nodes on the dying machine");

        // Kill machine 2: survivors {0,1,3,4} renumber to {0,1,2,3}.
        let speeds: Vec<f64> = [0.1, 0.2, 0.3, 0.1].iter().map(|s| s / 0.7).collect();
        let machines_after = MachineConfig::from_normalized(speeds);
        let (rehomed, count) = rehome_assignment(&assignment, &[2], &g, &machines_after);
        assert_eq!(count, orphans);
        assert_eq!(rehomed.len(), 40);
        for (i, (&old, &new)) in assignment.iter().zip(&rehomed).enumerate() {
            assert!(new < 4, "node {i} assigned outside the shrunken fleet");
            match old {
                0 | 1 => assert_eq!(new, old, "survivor node {i} must stay put"),
                3 | 4 => assert_eq!(new, old - 1, "survivor node {i} must renumber down"),
                _ => {} // orphan: anywhere in the new fleet
            }
        }
        // Determinism: same inputs, same output.
        let again = rehome_assignment(&assignment, &[2], &g, &machines_after);
        assert_eq!(again.0, rehomed);
        assert_eq!(again.1, count);

        // The result is a feasible Partition over the new fleet.
        let part = Partition::from_assignment(&g, 4, rehomed);
        part.validate(&g).unwrap();
    }

    /// Elastic shrink: refine to equilibrium at K, lose a machine,
    /// re-home, and refine at K−1 on a *new* engine — Thm 4.1 descent
    /// holds from the re-homed start, reaching a K−1 Nash equilibrium.
    #[test]
    fn refinement_descends_after_machine_loss() {
        for fw in [Framework::A, Framework::B] {
            let mut rng = Pcg32::new(31);
            let g = table1_graph(80, 3, 6, WeightModel::default(), &mut rng);
            let machines = MachineConfig::from_speeds(&[0.1, 0.2, 0.3, 0.3, 0.1]);
            let assignment = random_partition(80, 5, &mut rng);
            let part = Partition::from_assignment(&g, 5, assignment);
            let mut e = RefineEngine::new(&g, &machines, part, 8.0, fw);
            let report = e.run(&RefineOptions::default());
            assert!(report.converged);

            // The most-loaded machine dies (guaranteed non-empty);
            // survivors keep their relative speeds.
            let dead = (0..5).max_by_key(|&m| e.partition().count(m)).unwrap();
            let survivor_total: f64 = machines
                .speeds()
                .iter()
                .enumerate()
                .filter(|&(m, _)| m != dead)
                .map(|(_, &s)| s)
                .sum();
            let speeds: Vec<f64> = machines
                .speeds()
                .iter()
                .enumerate()
                .filter(|&(m, _)| m != dead)
                .map(|(_, &s)| s / survivor_total)
                .collect();
            let machines_after = MachineConfig::from_normalized(speeds);
            let (rehomed, count) =
                rehome_assignment(e.partition().assignment(), &[dead], &g, &machines_after);
            assert!(count > 0, "fw {fw}: the most-loaded machine cannot be empty");
            let part_after = Partition::from_assignment(&g, 4, rehomed);
            let mut e2 = RefineEngine::new(&g, &machines_after, part_after, 8.0, fw);
            let start = e2.potential();
            let report2 = e2.run(&RefineOptions { track_potential: true, ..Default::default() });
            assert!(report2.converged, "fw {fw}: no K-1 convergence");
            assert!(report2.final_potential <= start + 1e-9 * (1.0 + start.abs()));
            for w in report2.potential_trace.windows(2) {
                assert!(w[1] < w[0] + 1e-9, "fw {fw}: non-descent step after shrink");
            }
            e2.validate().unwrap();
        }
    }

    /// Elastic grow: a joining machine needs no re-homing — the old
    /// assignment is feasible over K+1 (the newcomer starts empty) and
    /// refinement descends toward it, pulling work onto the new
    /// machine.
    #[test]
    fn refinement_descends_after_machine_join() {
        let mut rng = Pcg32::new(32);
        let g = table1_graph(80, 3, 6, WeightModel::default(), &mut rng);
        let machines = MachineConfig::from_speeds(&[0.25, 0.25, 0.25, 0.25]);
        let assignment = random_partition(80, 4, &mut rng);
        let part = Partition::from_assignment(&g, 4, assignment);
        let mut e = RefineEngine::new(&g, &machines, part, 8.0, Framework::A);
        let _ = e.run(&RefineOptions::default());

        // A fifth machine joins with equal raw speed.
        let machines_after = MachineConfig::from_speeds(&[0.25, 0.25, 0.25, 0.25, 0.25]);
        let joined = Partition::from_assignment(&g, 5, e.partition().assignment().to_vec());
        assert_eq!(joined.count(4), 0, "the newcomer must start empty");
        let mut e2 = RefineEngine::new(&g, &machines_after, joined, 8.0, Framework::A);
        let start = e2.potential();
        let report = e2.run(&RefineOptions { track_potential: true, ..Default::default() });
        assert!(report.converged);
        assert!(report.final_potential <= start + 1e-9 * (1.0 + start.abs()));
        for w in report.potential_trace.windows(2) {
            assert!(w[1] < w[0] + 1e-9, "non-descent step after join");
        }
        assert!(
            e2.partition().count(4) > 0,
            "refinement should pull work onto the joined machine"
        );
        e2.validate().unwrap();
    }

    #[test]
    fn refinement_improves_over_random_start() {
        // Sanity on the headline effect: refinement should substantially
        // reduce the potential of a random partition.
        let mut e = engine(9, Framework::A);
        let start = e.potential();
        let report = e.run(&RefineOptions::default());
        assert!(
            report.final_potential < 0.99 * start,
            "expected >1% improvement: {start} -> {}",
            report.final_potential
        );
    }
}

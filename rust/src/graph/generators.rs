//! Random graph generators used by the paper's evaluation.
//!
//! * [`table1_graph`] — §5.1 numerical study: N nodes, per-node degree
//!   drawn uniformly from `[3, 6]`, random node/edge weights with a given
//!   mean (the paper uses mean 5).
//! * [`preferential_attachment`] — §6.1 / Fig. 7: scale-free graph in the
//!   style of Bu–Towsley / Barabási–Albert, modeling AS-level Internet
//!   topology.
//! * [`specialized_geometric`] — §6.1 / Fig. 8: nodes with 2-D coordinates
//!   where each node links to nodes chosen among its 15 nearest.
//! * [`erdos_renyi`] — App. A Thm A.1 substrate (initial-partitioning
//!   growth-law validation).
//!
//! All generators guarantee a **connected** graph (the paper assumes
//! connectivity; §3 notes disconnected graphs can be patched with
//! zero-weight edges, which is exactly what [`connect_components`] does).

use crate::graph::{metrics, Graph, GraphBuilder, NodeId};
use crate::util::rng::Pcg32;

/// Parameters for random node/edge weights: uniform integer-valued
/// weights in `[1, 2*mean - 1]`, matching "randomly generated node and
/// edge weights each with mean 5" (§5.1) while keeping weights positive.
#[derive(Debug, Clone, Copy)]
pub struct WeightModel {
    pub node_mean: f64,
    pub edge_mean: f64,
}

impl Default for WeightModel {
    fn default() -> Self {
        WeightModel { node_mean: 5.0, edge_mean: 5.0 }
    }
}

fn uniform_mean(rng: &mut Pcg32, mean: f64) -> f64 {
    // Uniform integers in [1, 2*mean-1] have mean `mean` for integer mean.
    let hi = (2.0 * mean - 1.0).max(1.0) as u64;
    rng.gen_range(1, hi) as f64
}

/// Assign random node and edge weights in place.
pub fn randomize_weights(g: &mut Graph, model: WeightModel, rng: &mut Pcg32) {
    let n = g.node_count();
    for u in 0..n {
        let w = uniform_mean(rng, model.node_mean);
        g.set_node_weight(u, w);
    }
    let edges: Vec<(NodeId, NodeId)> = g.edges().map(|(u, v, _)| (u, v)).collect();
    for (u, v) in edges {
        let w = uniform_mean(rng, model.edge_mean);
        g.set_edge_weight(u, v, w);
    }
}

/// Add zero-weight edges to stitch disconnected components together
/// (paper §3: "convert a disconnected graph into a connected one by
/// adding edges of weight zero").
pub fn connect_components(builder: &mut GraphBuilder) {
    let snapshot = builder.clone().build();
    let comps = metrics::connected_components(&snapshot);
    if comps.component_count <= 1 {
        return;
    }
    // Link the first node of each component to the first node of comp 0.
    let mut rep: Vec<Option<NodeId>> = vec![None; comps.component_count];
    for u in 0..snapshot.node_count() {
        let c = comps.labels[u];
        if rep[c].is_none() {
            rep[c] = Some(u);
        }
    }
    let root = rep[0].expect("component 0 nonempty");
    for c in 1..comps.component_count {
        let u = rep[c].expect("component nonempty");
        builder.add_edge(root, u, 0.0);
    }
}

/// §5.1 graph: each node's target degree drawn uniformly in
/// `[deg_lo, deg_hi]` (paper: 3..6); edges wired by random matching of
/// degree stubs, rejecting duplicates/self-loops; then connected.
pub fn table1_graph(
    n: usize,
    deg_lo: usize,
    deg_hi: usize,
    weights: WeightModel,
    rng: &mut Pcg32,
) -> Graph {
    assert!(n >= 2 && deg_lo >= 1 && deg_hi >= deg_lo && deg_hi < n);
    let mut builder = GraphBuilder::with_nodes(n);
    let targets: Vec<usize> =
        (0..n).map(|_| rng.gen_range(deg_lo as u64, deg_hi as u64) as usize).collect();
    let mut degree = vec![0usize; n];
    // Stub list: node u appears targets[u] times.
    let mut stubs: Vec<NodeId> = Vec::new();
    for (u, &t) in targets.iter().enumerate() {
        stubs.extend(std::iter::repeat(u).take(t));
    }
    rng.shuffle(&mut stubs);
    let mut i = 0;
    while i + 1 < stubs.len() {
        let (u, v) = (stubs[i], stubs[i + 1]);
        i += 2;
        if u == v || builder.has_edge(u, v) {
            continue;
        }
        // Cap degrees at targets to keep the [3,6]-ish profile.
        if degree[u] >= targets[u] || degree[v] >= targets[v] {
            continue;
        }
        builder.add_edge(u, v, 1.0);
        degree[u] += 1;
        degree[v] += 1;
    }
    // Patch isolated / underfull nodes minimally so min degree >= 1.
    for u in 0..n {
        if degree[u] == 0 {
            let mut v = rng.index(n);
            while v == u {
                v = rng.index(n);
            }
            if !builder.has_edge(u, v) {
                builder.add_edge(u, v, 1.0);
                degree[u] += 1;
                degree[v] += 1;
            }
        }
    }
    connect_components(&mut builder);
    let mut g = builder.build();
    randomize_weights(&mut g, weights, rng);
    g
}

/// Scale-free preferential-attachment graph (§6.1, Fig. 7): start from a
/// small clique of `m0 = m + 1` nodes; each arriving node attaches `m`
/// edges to existing nodes with probability proportional to degree.
pub fn preferential_attachment(n: usize, m: usize, rng: &mut Pcg32) -> Graph {
    assert!(m >= 1 && n > m + 1);
    let mut builder = GraphBuilder::with_nodes(n);
    let m0 = m + 1;
    for u in 0..m0 {
        for v in (u + 1)..m0 {
            builder.add_edge(u, v, 1.0);
        }
    }
    // Repeated-endpoint list: each half-edge endpoint appears once, so
    // sampling uniformly from it is degree-proportional sampling.
    let mut endpoints: Vec<NodeId> = Vec::with_capacity(2 * m * n);
    for u in 0..m0 {
        for v in (u + 1)..m0 {
            endpoints.push(u);
            endpoints.push(v);
        }
    }
    for u in m0..n {
        let mut chosen: Vec<NodeId> = Vec::with_capacity(m);
        let mut guard = 0;
        while chosen.len() < m && guard < 50 * m {
            guard += 1;
            let v = endpoints[rng.index(endpoints.len())];
            if v != u && !chosen.contains(&v) {
                chosen.push(v);
            }
        }
        for &v in &chosen {
            builder.add_edge(u, v, 1.0);
            endpoints.push(u);
            endpoints.push(v);
        }
    }
    connect_components(&mut builder);
    builder.build()
}

/// Specialized geometric graph (§6.1, Fig. 8): nodes get uniform 2-D
/// coordinates; each node forms `links_per_node` links, each to a node
/// chosen uniformly among its `k_nearest` (paper: 15) nearest neighbors.
///
/// Small instances (`n <= 2048`) keep the original O(n²) all-pairs scan
/// (bit-identical output, so seeded fixtures are stable); larger
/// instances — e.g. the 1e5-LP engine-scaling bench graph — switch to a
/// grid-bucketed *exact* k-nearest-neighbor query plus a hashed
/// duplicate-edge check, bringing generation down to roughly
/// O(n·k log k).
pub fn specialized_geometric(
    n: usize,
    k_nearest: usize,
    links_per_node: usize,
    rng: &mut Pcg32,
) -> Graph {
    assert!(n > k_nearest && k_nearest >= links_per_node && links_per_node >= 1);
    let coords: Vec<(f64, f64)> =
        (0..n).map(|_| (rng.next_f64(), rng.next_f64())).collect();
    let mut builder = GraphBuilder::with_nodes(n);
    builder.set_coords(coords.clone());

    if n <= 2048 {
        // O(n^2) nearest-neighbor scan, kept verbatim for seed
        // stability at the paper's experiment sizes.
        let mut dist_buf: Vec<(f64, NodeId)> = Vec::with_capacity(n - 1);
        for u in 0..n {
            dist_buf.clear();
            let (ux, uy) = coords[u];
            for v in 0..n {
                if v == u {
                    continue;
                }
                let (vx, vy) = coords[v];
                let d2 = (ux - vx) * (ux - vx) + (uy - vy) * (uy - vy);
                dist_buf.push((d2, v));
            }
            dist_buf.sort_unstable_by(|a, b| a.0.partial_cmp(&b.0).expect("finite"));
            let nearest: Vec<NodeId> = dist_buf[..k_nearest].iter().map(|&(_, v)| v).collect();
            let mut made = 0;
            let mut guard = 0;
            while made < links_per_node && guard < 20 * links_per_node {
                guard += 1;
                let v = nearest[rng.index(k_nearest)];
                if !builder.has_edge(u, v) {
                    builder.add_edge(u, v, 1.0);
                    made += 1;
                }
            }
        }
    } else {
        // Grid-bucketed exact k-NN: ~k_nearest points per cell expected.
        let cells = ((n / k_nearest.max(1)) as f64).sqrt().floor().max(1.0) as usize;
        let side = 1.0 / cells as f64;
        let cell_of = |x: f64, y: f64| -> (usize, usize) {
            (
                ((x / side) as usize).min(cells - 1),
                ((y / side) as usize).min(cells - 1),
            )
        };
        let mut buckets: Vec<Vec<NodeId>> = vec![Vec::new(); cells * cells];
        for (u, &(x, y)) in coords.iter().enumerate() {
            let (cx, cy) = cell_of(x, y);
            buckets[cy * cells + cx].push(u);
        }
        let mut edge_set: std::collections::HashSet<(NodeId, NodeId)> =
            std::collections::HashSet::with_capacity(n * links_per_node);
        let mut cand: Vec<(f64, NodeId)> = Vec::new();
        for u in 0..n {
            let (ux, uy) = coords[u];
            let (cx, cy) = cell_of(ux, uy);
            cand.clear();
            let mut r = 0usize;
            loop {
                // Add the ring of cells at Chebyshev distance r.
                let x_lo = cx.saturating_sub(r);
                let x_hi = (cx + r).min(cells - 1);
                let y_lo = cy.saturating_sub(r);
                let y_hi = (cy + r).min(cells - 1);
                for gy in y_lo..=y_hi {
                    for gx in x_lo..=x_hi {
                        // Ring membership: exactly Chebyshev distance r
                        // from (cx, cy); inner cells were collected in
                        // earlier rings.
                        if gx.abs_diff(cx).max(gy.abs_diff(cy)) != r {
                            continue;
                        }
                        for &v in &buckets[gy * cells + gx] {
                            if v == u {
                                continue;
                            }
                            let (vx, vy) = coords[v];
                            let d2 = (ux - vx) * (ux - vx) + (uy - vy) * (uy - vy);
                            cand.push((d2, v));
                        }
                    }
                }
                // Any point outside rings 0..=r is farther than r·side
                // in some axis, so once the k-th nearest candidate is
                // within that bound the answer is exact. A select (not
                // a full sort) suffices per ring; only the final
                // k-prefix is sorted, once.
                let by_dist = |a: &(f64, NodeId), b: &(f64, NodeId)| {
                    a.0.partial_cmp(&b.0).expect("finite").then(a.1.cmp(&b.1))
                };
                let whole_grid =
                    x_lo == 0 && y_lo == 0 && x_hi == cells - 1 && y_hi == cells - 1;
                if cand.len() >= k_nearest {
                    cand.select_nth_unstable_by(k_nearest - 1, by_dist);
                    let guaranteed = (r as f64) * side;
                    if whole_grid || cand[k_nearest - 1].0.sqrt() <= guaranteed {
                        cand[..k_nearest].sort_unstable_by(by_dist);
                        break;
                    }
                }
                debug_assert!(
                    !(whole_grid && cand.len() < k_nearest),
                    "grid exhausted below k (n > k_nearest is asserted)"
                );
                r += 1;
            }
            let nearest: Vec<NodeId> =
                cand[..k_nearest].iter().map(|&(_, v)| v).collect();
            let mut made = 0;
            let mut guard = 0;
            while made < links_per_node && guard < 20 * links_per_node {
                guard += 1;
                let v = nearest[rng.index(k_nearest)];
                let key = (u.min(v), u.max(v));
                if edge_set.insert(key) {
                    builder.add_edge(u, v, 1.0);
                    made += 1;
                }
            }
        }
    }
    connect_components(&mut builder);
    builder.build()
}

/// Erdős–Rényi G(n, p) (App. A substrate).
pub fn erdos_renyi(n: usize, p: f64, rng: &mut Pcg32) -> Graph {
    assert!(n >= 2 && (0.0..=1.0).contains(&p));
    let mut builder = GraphBuilder::with_nodes(n);
    for u in 0..n {
        for v in (u + 1)..n {
            if rng.chance(p) {
                builder.add_edge(u, v, 1.0);
            }
        }
    }
    connect_components(&mut builder);
    builder.build()
}

/// Named graph family selector used by the CLI and experiment configs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphFamily {
    Table1,
    PreferentialAttachment,
    Geometric,
    ErdosRenyi,
}

impl std::str::FromStr for GraphFamily {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "table1" | "degree36" => Ok(GraphFamily::Table1),
            "pa" | "preferential-attachment" | "scale-free" => {
                Ok(GraphFamily::PreferentialAttachment)
            }
            "geo" | "geometric" => Ok(GraphFamily::Geometric),
            "er" | "erdos-renyi" => Ok(GraphFamily::ErdosRenyi),
            other => Err(format!("unknown graph family {other:?}")),
        }
    }
}

/// Generate a graph of the given family with family-appropriate default
/// shape parameters (paper values).
pub fn generate(family: GraphFamily, n: usize, rng: &mut Pcg32) -> Graph {
    match family {
        GraphFamily::Table1 => table1_graph(n, 3, 6, WeightModel::default(), rng),
        GraphFamily::PreferentialAttachment => preferential_attachment(n, 2, rng),
        GraphFamily::Geometric => specialized_geometric(n, 15, 3, rng),
        GraphFamily::ErdosRenyi => {
            // keep expected degree ~ 6
            let p = (6.0 / (n as f64 - 1.0)).min(1.0);
            erdos_renyi(n, p, rng)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::metrics::connected_components;

    #[test]
    fn table1_graph_profile() {
        let mut rng = Pcg32::new(1);
        let g = table1_graph(230, 3, 6, WeightModel::default(), &mut rng);
        assert_eq!(g.node_count(), 230);
        assert_eq!(connected_components(&g).component_count, 1);
        // Mean degree should land in [2.5, 6]: stub matching under-fills a bit.
        let mean_deg =
            (0..230).map(|u| g.degree(u) as f64).sum::<f64>() / 230.0;
        assert!(
            (2.5..=6.0).contains(&mean_deg),
            "mean degree {mean_deg} out of expected band"
        );
        // Node weights should average near 5.
        let mean_w = g.total_node_weight() / 230.0;
        assert!((mean_w - 5.0).abs() < 1.0, "mean node weight {mean_w}");
    }

    #[test]
    fn table1_weights_positive() {
        let mut rng = Pcg32::new(2);
        let g = table1_graph(100, 3, 6, WeightModel::default(), &mut rng);
        assert!(g.node_weights().iter().all(|&w| w >= 1.0));
        assert!(g.edges().all(|(_, _, w)| w >= 0.0));
    }

    #[test]
    fn preferential_attachment_scale_free_ish() {
        let mut rng = Pcg32::new(3);
        let g = preferential_attachment(500, 2, &mut rng);
        assert_eq!(g.node_count(), 500);
        assert_eq!(connected_components(&g).component_count, 1);
        let max_deg = (0..500).map(|u| g.degree(u)).max().unwrap();
        let mean_deg = (0..500).map(|u| g.degree(u) as f64).sum::<f64>() / 500.0;
        // A hub should greatly exceed the mean in a scale-free graph.
        assert!(
            max_deg as f64 > 4.0 * mean_deg,
            "max {max_deg} vs mean {mean_deg} — not heavy-tailed"
        );
    }

    #[test]
    fn geometric_links_are_local() {
        let mut rng = Pcg32::new(4);
        let g = specialized_geometric(300, 15, 3, &mut rng);
        assert_eq!(connected_components(&g).component_count, 1);
        let coords = g.coords().expect("geometric graph has coords");
        // Average edge length must be far below the ~0.52 random-pair mean.
        let mut total = 0.0;
        let mut cnt = 0usize;
        for (u, v, _) in g.edges() {
            let (ux, uy) = coords[u];
            let (vx, vy) = coords[v];
            total += ((ux - vx).powi(2) + (uy - vy).powi(2)).sqrt();
            cnt += 1;
        }
        let mean_len = total / cnt as f64;
        assert!(mean_len < 0.25, "edges not local: mean length {mean_len}");
    }

    #[test]
    fn geometric_large_n_grid_path_is_exact_and_local() {
        // n > 2048 exercises the grid-bucketed k-NN path.
        let mut rng = Pcg32::new(6);
        let n = 2500;
        let k_nearest = 15;
        let g = specialized_geometric(n, k_nearest, 3, &mut rng);
        assert_eq!(g.node_count(), n);
        assert_eq!(connected_components(&g).component_count, 1);
        let coords = g.coords().expect("geometric graph has coords");
        // Every non-stitch edge must land inside the node's brute-force
        // k-nearest set — the grid query is exact, not approximate.
        let brute_knn = |u: usize| -> Vec<usize> {
            let (ux, uy) = coords[u];
            let mut d: Vec<(f64, usize)> = (0..n)
                .filter(|&v| v != u)
                .map(|v| {
                    let (vx, vy) = coords[v];
                    ((ux - vx).powi(2) + (uy - vy).powi(2), v)
                })
                .collect();
            d.sort_unstable_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
            d[..k_nearest].iter().map(|&(_, v)| v).collect()
        };
        let mut checked = 0;
        for (u, v, w) in g.edges() {
            if w == 0.0 {
                continue; // connect_components stitch edge
            }
            if u % 97 != 0 {
                continue; // sample to keep the O(n) brute scans cheap
            }
            let knn_u = brute_knn(u);
            let knn_v = brute_knn(v);
            assert!(
                knn_u.contains(&v) || knn_v.contains(&u),
                "edge ({u},{v}) joins no k-nearest set"
            );
            checked += 1;
        }
        assert!(checked > 10, "sample too small: {checked}");
    }

    #[test]
    fn erdos_renyi_edge_density() {
        let mut rng = Pcg32::new(5);
        let n = 200;
        let p = 0.05;
        let g = erdos_renyi(n, p, &mut rng);
        let expected = p * (n * (n - 1) / 2) as f64;
        let m = g.edge_count() as f64;
        assert!((m - expected).abs() < 0.25 * expected, "m={m} expected~{expected}");
        assert_eq!(connected_components(&g).component_count, 1);
    }

    #[test]
    fn generators_deterministic_under_seed() {
        let g1 = {
            let mut rng = Pcg32::new(77);
            preferential_attachment(100, 2, &mut rng)
        };
        let g2 = {
            let mut rng = Pcg32::new(77);
            preferential_attachment(100, 2, &mut rng)
        };
        assert_eq!(g1.edge_count(), g2.edge_count());
        let e1: Vec<_> = g1.edges().collect();
        let e2: Vec<_> = g2.edges().collect();
        assert_eq!(e1, e2);
    }

    #[test]
    fn family_parsing() {
        assert_eq!("pa".parse::<GraphFamily>().unwrap(), GraphFamily::PreferentialAttachment);
        assert_eq!("geo".parse::<GraphFamily>().unwrap(), GraphFamily::Geometric);
        assert!("bogus".parse::<GraphFamily>().is_err());
    }

    #[test]
    fn generate_dispatch() {
        let mut rng = Pcg32::new(6);
        for fam in [
            GraphFamily::Table1,
            GraphFamily::PreferentialAttachment,
            GraphFamily::Geometric,
            GraphFamily::ErdosRenyi,
        ] {
            let g = generate(fam, 60, &mut rng);
            assert_eq!(g.node_count(), 60);
            assert_eq!(connected_components(&g).component_count, 1, "{fam:?}");
        }
    }
}

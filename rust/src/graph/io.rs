//! Graph serialization: a small self-describing text format so users can
//! bring their own LP graphs to the partitioner and experiments can be
//! re-run from recorded inputs.
//!
//! Format (line-oriented, `#` comments):
//! ```text
//! gtip-graph v1
//! nodes <n>
//! node <id> <weight> [<x> <y>]
//! edge <u> <v> <weight>
//! ```

use std::io::{BufRead, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::graph::{Graph, GraphBuilder};

/// Serialize a graph to the text format.
pub fn write_graph<W: Write>(g: &Graph, mut w: W) -> Result<()> {
    writeln!(w, "gtip-graph v1")?;
    writeln!(w, "nodes {}", g.node_count())?;
    let coords = g.coords();
    for u in 0..g.node_count() {
        match coords {
            Some(c) => writeln!(w, "node {} {} {} {}", u, g.node_weight(u), c[u].0, c[u].1)?,
            None => writeln!(w, "node {} {}", u, g.node_weight(u))?,
        }
    }
    for (u, v, wt) in g.edges() {
        writeln!(w, "edge {u} {v} {wt}")?;
    }
    Ok(())
}

/// Save to a file path.
pub fn save_graph(g: &Graph, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path)?;
    write_graph(g, std::io::BufWriter::new(f))
}

/// Parse a graph from the text format.
pub fn read_graph<R: BufRead>(r: R) -> Result<Graph> {
    let mut lines = r.lines();
    let header = lines
        .next()
        .ok_or_else(|| Error::Graph("empty graph file".into()))??;
    if header.trim() != "gtip-graph v1" {
        return Err(Error::Graph(format!("bad header {header:?}")));
    }
    let mut builder: Option<GraphBuilder> = None;
    let mut coords: Vec<(f64, f64)> = Vec::new();
    let mut saw_coords = false;
    for (lineno, line) in lines.enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut fields = line.split_whitespace();
        let kind = fields.next().expect("non-empty");
        let rest: Vec<&str> = fields.collect();
        let mut rest_iter = rest.into_iter();
        let mut next_field = |what: &str| -> Result<String> {
            rest_iter
                .next()
                .map(str::to_string)
                .ok_or_else(|| Error::Graph(format!("line {}: missing {what}", lineno + 2)))
        };
        let parse_f = |s: String| -> Result<f64> {
            s.parse::<f64>().map_err(|e| Error::Graph(format!("bad number {s:?}: {e}")))
        };
        let parse_u = |s: String| -> Result<usize> {
            s.parse::<usize>().map_err(|e| Error::Graph(format!("bad id {s:?}: {e}")))
        };
        match kind {
            "nodes" => {
                let n = parse_u(next_field("count")?)?;
                builder = Some(GraphBuilder::with_nodes(n));
                coords = vec![(0.0, 0.0); n];
            }
            "node" => {
                let b = builder
                    .as_mut()
                    .ok_or_else(|| Error::Graph("'node' before 'nodes'".into()))?;
                let id = parse_u(next_field("id")?)?;
                let w = parse_f(next_field("weight")?)?;
                if id >= b.node_count() {
                    return Err(Error::Graph(format!("node id {id} out of range")));
                }
                b.set_node_weight(id, w);
                if let Ok(x) = next_field("x") {
                    let x = parse_f(x)?;
                    let y = parse_f(next_field("y")?)?;
                    coords[id] = (x, y);
                    saw_coords = true;
                }
            }
            "edge" => {
                let b = builder
                    .as_mut()
                    .ok_or_else(|| Error::Graph("'edge' before 'nodes'".into()))?;
                let u = parse_u(next_field("u")?)?;
                let v = parse_u(next_field("v")?)?;
                let w = parse_f(next_field("weight")?)?;
                if u >= b.node_count() || v >= b.node_count() {
                    return Err(Error::Graph(format!("edge ({u},{v}) out of range")));
                }
                b.add_edge(u, v, w);
            }
            other => return Err(Error::Graph(format!("unknown record {other:?}"))),
        }
    }
    let mut builder = builder.ok_or_else(|| Error::Graph("no 'nodes' record".into()))?;
    if saw_coords {
        builder.set_coords(coords);
    }
    Ok(builder.build())
}

/// Load from a file path.
pub fn load_graph(path: impl AsRef<Path>) -> Result<Graph> {
    let f = std::fs::File::open(path)?;
    read_graph(std::io::BufReader::new(f))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{specialized_geometric, table1_graph, WeightModel};
    use crate::util::rng::Pcg32;

    #[test]
    fn round_trip_weights_and_edges() {
        let mut rng = Pcg32::new(42);
        let g = table1_graph(50, 3, 6, WeightModel::default(), &mut rng);
        let mut buf = Vec::new();
        write_graph(&g, &mut buf).unwrap();
        let g2 = read_graph(std::io::Cursor::new(buf)).unwrap();
        assert_eq!(g.node_count(), g2.node_count());
        assert_eq!(g.edge_count(), g2.edge_count());
        for u in 0..g.node_count() {
            assert_eq!(g.node_weight(u), g2.node_weight(u));
            assert_eq!(g.neighbors(u), g2.neighbors(u));
        }
        for (u, v, w) in g.edges() {
            assert_eq!(g2.edge_weight(u, v), Some(w));
        }
    }

    #[test]
    fn round_trip_coords() {
        let mut rng = Pcg32::new(43);
        let g = specialized_geometric(40, 15, 2, &mut rng);
        let mut buf = Vec::new();
        write_graph(&g, &mut buf).unwrap();
        let g2 = read_graph(std::io::Cursor::new(buf)).unwrap();
        let c1 = g.coords().unwrap();
        let c2 = g2.coords().unwrap();
        for (a, b) in c1.iter().zip(c2.iter()) {
            assert!((a.0 - b.0).abs() < 1e-12 && (a.1 - b.1).abs() < 1e-12);
        }
    }

    #[test]
    fn rejects_bad_header() {
        let r = read_graph(std::io::Cursor::new(b"not-a-graph\n".to_vec()));
        assert!(r.is_err());
    }

    #[test]
    fn rejects_out_of_range_edge() {
        let text = "gtip-graph v1\nnodes 2\nedge 0 5 1.0\n";
        assert!(read_graph(std::io::Cursor::new(text.as_bytes().to_vec())).is_err());
    }

    #[test]
    fn comments_and_blank_lines_ok() {
        let text = "gtip-graph v1\n# comment\nnodes 2\n\nnode 0 3.0\nnode 1 4.0\nedge 0 1 2.0\n";
        let g = read_graph(std::io::Cursor::new(text.as_bytes().to_vec())).unwrap();
        assert_eq!(g.node_weight(0), 3.0);
        assert_eq!(g.edge_weight(0, 1), Some(2.0));
    }
}

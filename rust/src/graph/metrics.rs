//! Graph metrics: BFS geodesics, connected components, cut weights and
//! degree statistics. Used by initial partitioning (App. A focal-node
//! search needs geodesic distances) and by the experiment harnesses.

use crate::graph::{Graph, NodeId};

/// Result of a connected-components labeling.
#[derive(Debug, Clone)]
pub struct Components {
    /// `labels[u]` = component index of node `u` (dense, 0-based).
    pub labels: Vec<usize>,
    pub component_count: usize,
}

/// Label connected components with iterative BFS.
pub fn connected_components(g: &Graph) -> Components {
    let n = g.node_count();
    let mut labels = vec![usize::MAX; n];
    let mut count = 0;
    let mut queue = std::collections::VecDeque::new();
    for start in 0..n {
        if labels[start] != usize::MAX {
            continue;
        }
        labels[start] = count;
        queue.push_back(start);
        while let Some(u) = queue.pop_front() {
            for &v in g.neighbors(u) {
                if labels[v] == usize::MAX {
                    labels[v] = count;
                    queue.push_back(v);
                }
            }
        }
        count += 1;
    }
    Components { labels, component_count: count }
}

/// Unweighted geodesic (hop) distances from `source` to all nodes.
/// Unreachable nodes get `usize::MAX`.
pub fn bfs_distances(g: &Graph, source: NodeId) -> Vec<usize> {
    let n = g.node_count();
    let mut dist = vec![usize::MAX; n];
    dist[source] = 0;
    let mut queue = std::collections::VecDeque::new();
    queue.push_back(source);
    while let Some(u) = queue.pop_front() {
        let du = dist[u];
        for &v in g.neighbors(u) {
            if dist[v] == usize::MAX {
                dist[v] = du + 1;
                queue.push_back(v);
            }
        }
    }
    dist
}

/// BFS distances from `source`, stopping once `targets` are all resolved
/// (small optimization for the focal-node heuristic's repeated queries).
pub fn bfs_distances_to(g: &Graph, source: NodeId, targets: &[NodeId]) -> Vec<usize> {
    let n = g.node_count();
    let mut dist = vec![usize::MAX; n];
    dist[source] = 0;
    let mut remaining: usize =
        targets.iter().filter(|&&t| t != source).count();
    if remaining == 0 {
        return dist;
    }
    let is_target = {
        let mut mask = vec![false; n];
        for &t in targets {
            mask[t] = true;
        }
        mask
    };
    let mut queue = std::collections::VecDeque::new();
    queue.push_back(source);
    while let Some(u) = queue.pop_front() {
        let du = dist[u];
        for &v in g.neighbors(u) {
            if dist[v] == usize::MAX {
                dist[v] = du + 1;
                if is_target[v] {
                    remaining -= 1;
                    if remaining == 0 {
                        return dist;
                    }
                }
                queue.push_back(v);
            }
        }
    }
    dist
}

/// Total weight of edges crossing the given assignment
/// (`assignment[u]` = machine of node `u`); each undirected edge counted
/// once. This is the classical partitioning objective's cut term.
pub fn cut_weight(g: &Graph, assignment: &[usize]) -> f64 {
    assert_eq!(assignment.len(), g.node_count());
    g.edges()
        .filter(|&(u, v, _)| assignment[u] != assignment[v])
        .map(|(_, _, w)| w)
        .sum()
}

/// Number of edges crossing the assignment.
pub fn cut_edges(g: &Graph, assignment: &[usize]) -> usize {
    g.edges().filter(|&(u, v, _)| assignment[u] != assignment[v]).count()
}

/// Degree distribution summary.
#[derive(Debug, Clone)]
pub struct DegreeStats {
    pub min: usize,
    pub max: usize,
    pub mean: f64,
}

pub fn degree_stats(g: &Graph) -> DegreeStats {
    let n = g.node_count();
    let mut min = usize::MAX;
    let mut max = 0;
    let mut sum = 0usize;
    for u in 0..n {
        let d = g.degree(u);
        min = min.min(d);
        max = max.max(d);
        sum += d;
    }
    DegreeStats { min, max, mean: sum as f64 / n as f64 }
}

/// Approximate graph diameter: max BFS eccentricity over `samples`
/// random-ish starting nodes (deterministic stride sampling).
pub fn approx_diameter(g: &Graph, samples: usize) -> usize {
    let n = g.node_count();
    let step = (n / samples.max(1)).max(1);
    let mut best = 0;
    for s in (0..n).step_by(step) {
        let d = bfs_distances(g, s);
        let ecc = d.iter().filter(|&&x| x != usize::MAX).max().copied().unwrap_or(0);
        best = best.max(ecc);
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    /// 0-1-2-3 path plus isolated pair 4-5.
    fn two_components() -> Graph {
        let mut b = GraphBuilder::with_nodes(6);
        b.add_edge(0, 1, 1.0).add_edge(1, 2, 1.0).add_edge(2, 3, 1.0).add_edge(4, 5, 1.0);
        b.build()
    }

    #[test]
    fn components_counted() {
        let g = two_components();
        let c = connected_components(&g);
        assert_eq!(c.component_count, 2);
        assert_eq!(c.labels[0], c.labels[3]);
        assert_ne!(c.labels[0], c.labels[4]);
    }

    #[test]
    fn bfs_path_distances() {
        let g = two_components();
        let d = bfs_distances(&g, 0);
        assert_eq!(&d[..4], &[0, 1, 2, 3]);
        assert_eq!(d[4], usize::MAX);
    }

    #[test]
    fn bfs_targets_early_exit_matches_full() {
        let g = two_components();
        let full = bfs_distances(&g, 0);
        let partial = bfs_distances_to(&g, 0, &[2]);
        assert_eq!(partial[2], full[2]);
    }

    #[test]
    fn cut_weight_counts_each_edge_once() {
        let mut b = GraphBuilder::with_nodes(4);
        b.add_edge(0, 1, 2.0).add_edge(1, 2, 3.0).add_edge(2, 3, 4.0);
        let g = b.build();
        // Split {0,1} | {2,3}: only edge (1,2) crosses.
        let cut = cut_weight(&g, &[0, 0, 1, 1]);
        assert!((cut - 3.0).abs() < 1e-12);
        assert_eq!(cut_edges(&g, &[0, 0, 1, 1]), 1);
        // All same machine: no cut.
        assert_eq!(cut_weight(&g, &[0, 0, 0, 0]), 0.0);
    }

    #[test]
    fn degree_stats_path() {
        let g = two_components();
        let s = degree_stats(&g);
        assert_eq!(s.min, 1);
        assert_eq!(s.max, 2);
    }

    #[test]
    fn diameter_of_path() {
        let mut b = GraphBuilder::with_nodes(5);
        for i in 0..4 {
            b.add_edge(i, i + 1, 1.0);
        }
        let g = b.build();
        assert_eq!(approx_diameter(&g, 5), 4);
    }
}

//! Weighted undirected graphs of logical processes (LPs).
//!
//! The network model under simulation is represented as an undirected
//! graph `G = (V, E)` with node weights `b_i` (computational load of LP
//! `i`) and edge weights `c_ij` (traffic / potential rollback-delay cost
//! between LPs `i` and `j`) — paper §3. Storage is CSR (compressed sparse
//! rows) with both directions of every undirected edge materialized, so
//! `neighbors(i)` is a contiguous slice: the refinement hot loop iterates
//! it with no hashing or pointer chasing.

pub mod generators;
pub mod io;
pub mod metrics;

/// Node identifier (dense `0..n`).
pub type NodeId = usize;

/// A weighted undirected graph in CSR form.
#[derive(Debug, Clone)]
pub struct Graph {
    /// CSR row offsets, length `n + 1`.
    offsets: Vec<usize>,
    /// Flattened adjacency: target node of each half-edge.
    targets: Vec<NodeId>,
    /// Edge weight `c_ij` aligned with `targets`.
    edge_weights: Vec<f64>,
    /// Node weights `b_i`.
    node_weights: Vec<f64>,
    /// Optional 2-D coordinates (geometric generators populate these).
    coords: Option<Vec<(f64, f64)>>,
}

/// Builder that accumulates undirected edges, then freezes into CSR.
#[derive(Debug, Default, Clone)]
pub struct GraphBuilder {
    n: usize,
    edges: Vec<(NodeId, NodeId, f64)>,
    node_weights: Vec<f64>,
    coords: Option<Vec<(f64, f64)>>,
}

impl GraphBuilder {
    pub fn with_nodes(n: usize) -> Self {
        GraphBuilder { n, edges: Vec::new(), node_weights: vec![1.0; n], coords: None }
    }

    /// Add an undirected edge `{u, v}` with weight `w`. Self-loops are
    /// rejected; duplicate edges are merged (weights summed) at freeze.
    pub fn add_edge(&mut self, u: NodeId, v: NodeId, w: f64) -> &mut Self {
        assert!(u != v, "self-loop {u}");
        assert!(u < self.n && v < self.n, "edge ({u},{v}) out of range n={}", self.n);
        assert!(w >= 0.0, "negative edge weight");
        self.edges.push((u.min(v), u.max(v), w));
        self
    }

    pub fn set_node_weight(&mut self, u: NodeId, w: f64) -> &mut Self {
        assert!(w >= 0.0, "negative node weight");
        self.node_weights[u] = w;
        self
    }

    pub fn set_coords(&mut self, coords: Vec<(f64, f64)>) -> &mut Self {
        assert_eq!(coords.len(), self.n);
        self.coords = Some(coords);
        self
    }

    /// Whether the edge `{u, v}` was already added (linear scan — only
    /// used by generators on small candidate sets).
    pub fn has_edge(&self, u: NodeId, v: NodeId) -> bool {
        let (a, b) = (u.min(v), u.max(v));
        self.edges.iter().any(|&(x, y, _)| x == a && y == b)
    }

    pub fn node_count(&self) -> usize {
        self.n
    }

    pub fn edge_count(&self) -> usize {
        self.edges.len()
    }

    /// Freeze into CSR, merging duplicate edges by summing weights.
    pub fn build(mut self) -> Graph {
        // Merge duplicates.
        self.edges.sort_unstable_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        self.edges.dedup_by(|next, prev| {
            if next.0 == prev.0 && next.1 == prev.1 {
                prev.2 += next.2;
                true
            } else {
                false
            }
        });

        let n = self.n;
        let mut degree = vec![0usize; n];
        for &(u, v, _) in &self.edges {
            degree[u] += 1;
            degree[v] += 1;
        }
        let mut offsets = vec![0usize; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + degree[i];
        }
        let m2 = offsets[n];
        let mut targets = vec![0usize; m2];
        let mut edge_weights = vec![0.0f64; m2];
        let mut cursor = offsets.clone();
        for &(u, v, w) in &self.edges {
            targets[cursor[u]] = v;
            edge_weights[cursor[u]] = w;
            cursor[u] += 1;
            targets[cursor[v]] = u;
            edge_weights[cursor[v]] = w;
            cursor[v] += 1;
        }
        // Sort each row by target for deterministic iteration + binary search.
        for i in 0..n {
            let (s, e) = (offsets[i], offsets[i + 1]);
            let mut row: Vec<(usize, f64)> =
                targets[s..e].iter().copied().zip(edge_weights[s..e].iter().copied()).collect();
            row.sort_unstable_by_key(|&(t, _)| t);
            for (k, (t, w)) in row.into_iter().enumerate() {
                targets[s + k] = t;
                edge_weights[s + k] = w;
            }
        }
        Graph {
            offsets,
            targets,
            edge_weights,
            node_weights: self.node_weights,
            coords: self.coords,
        }
    }
}

impl Graph {
    /// Number of nodes.
    pub fn node_count(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of undirected edges.
    pub fn edge_count(&self) -> usize {
        self.targets.len() / 2
    }

    /// Degree of node `u`.
    pub fn degree(&self, u: NodeId) -> usize {
        self.offsets[u + 1] - self.offsets[u]
    }

    /// Neighbor node ids of `u` (sorted ascending).
    #[inline]
    pub fn neighbors(&self, u: NodeId) -> &[NodeId] {
        &self.targets[self.offsets[u]..self.offsets[u + 1]]
    }

    /// `(neighbor, c_uv)` pairs for `u`.
    #[inline]
    pub fn neighbors_weighted(&self, u: NodeId) -> impl Iterator<Item = (NodeId, f64)> + '_ {
        let s = self.offsets[u];
        let e = self.offsets[u + 1];
        self.targets[s..e].iter().copied().zip(self.edge_weights[s..e].iter().copied())
    }

    /// Number of stored half-edges (`2·edge_count`): the length of any
    /// per-half-edge side table aligned with the CSR slots.
    pub fn half_edge_count(&self) -> usize {
        self.targets.len()
    }

    /// First CSR slot of `u`'s adjacency row; slot `row_offset(u) + k`
    /// holds `u`'s `k`-th neighbor as returned by [`Self::neighbors`].
    #[inline]
    pub fn row_offset(&self, u: NodeId) -> usize {
        self.offsets[u]
    }

    /// CSR slot of the directed half-edge `u -> v`, or `None` if `{u,v}`
    /// is not an edge.
    pub fn half_edge_index(&self, u: NodeId, v: NodeId) -> Option<usize> {
        let s = self.offsets[u];
        let e = self.offsets[u + 1];
        self.targets[s..e].binary_search(&v).ok().map(|k| s + k)
    }

    /// Edge weight `c_uv`, or `None` if `{u,v}` is not an edge.
    pub fn edge_weight(&self, u: NodeId, v: NodeId) -> Option<f64> {
        let s = self.offsets[u];
        let e = self.offsets[u + 1];
        let row = &self.targets[s..e];
        row.binary_search(&v).ok().map(|k| self.edge_weights[s + k])
    }

    /// Node weight `b_u`.
    #[inline]
    pub fn node_weight(&self, u: NodeId) -> f64 {
        self.node_weights[u]
    }

    /// All node weights.
    pub fn node_weights(&self) -> &[f64] {
        &self.node_weights
    }

    /// Sum of all node weights `B = Σ_i b_i`.
    pub fn total_node_weight(&self) -> f64 {
        self.node_weights.iter().sum()
    }

    /// Sum of incident edge weights `S_u = Σ_j c_uj`.
    pub fn incident_weight(&self, u: NodeId) -> f64 {
        let s = self.offsets[u];
        let e = self.offsets[u + 1];
        self.edge_weights[s..e].iter().sum()
    }

    /// Replace all node weights (dynamic re-weighting between refinement
    /// epochs, §6.1).
    pub fn set_node_weights(&mut self, w: &[f64]) {
        assert_eq!(w.len(), self.node_count());
        assert!(w.iter().all(|x| *x >= 0.0), "negative node weight");
        self.node_weights.copy_from_slice(w);
    }

    /// Set node weight of a single node.
    pub fn set_node_weight(&mut self, u: NodeId, w: f64) {
        assert!(w >= 0.0);
        self.node_weights[u] = w;
    }

    /// Replace the weight of edge `{u,v}` (both directions). Returns
    /// `false` if the edge does not exist.
    pub fn set_edge_weight(&mut self, u: NodeId, v: NodeId, w: f64) -> bool {
        assert!(w >= 0.0);
        let mut found = false;
        for (a, b) in [(u, v), (v, u)] {
            let s = self.offsets[a];
            let e = self.offsets[a + 1];
            if let Ok(k) = self.targets[s..e].binary_search(&b) {
                self.edge_weights[s + k] = w;
                found = true;
            }
        }
        found
    }

    /// Coordinates if the generator attached them.
    pub fn coords(&self) -> Option<&[(f64, f64)]> {
        self.coords.as_deref()
    }

    /// Iterate undirected edges `(u, v, w)` with `u < v`.
    pub fn edges(&self) -> impl Iterator<Item = (NodeId, NodeId, f64)> + '_ {
        (0..self.node_count()).flat_map(move |u| {
            self.neighbors_weighted(u)
                .filter(move |&(v, _)| u < v)
                .map(move |(v, w)| (u, v, w))
        })
    }

    /// Dense adjacency matrix (row-major `n*n`), used to feed the PJRT
    /// cost-evaluation artifact and the pure-Rust dense oracle.
    pub fn dense_adjacency(&self) -> Vec<f64> {
        let n = self.node_count();
        let mut a = vec![0.0f64; n * n];
        for (u, v, w) in self.edges() {
            a[u * n + v] = w;
            a[v * n + u] = w;
        }
        a
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> Graph {
        let mut b = GraphBuilder::with_nodes(3);
        b.add_edge(0, 1, 1.0).add_edge(1, 2, 2.0).add_edge(0, 2, 3.0);
        b.set_node_weight(0, 5.0);
        b.build()
    }

    #[test]
    fn csr_shape() {
        let g = triangle();
        assert_eq!(g.node_count(), 3);
        assert_eq!(g.edge_count(), 3);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(1), &[0, 2]);
    }

    #[test]
    fn edge_weights_symmetric() {
        let g = triangle();
        assert_eq!(g.edge_weight(0, 1), Some(1.0));
        assert_eq!(g.edge_weight(1, 0), Some(1.0));
        assert_eq!(g.edge_weight(2, 0), Some(3.0));
        assert_eq!(g.edge_weight(0, 0), None);
    }

    #[test]
    fn node_weights() {
        let g = triangle();
        assert_eq!(g.node_weight(0), 5.0);
        assert_eq!(g.node_weight(1), 1.0);
        assert!((g.total_node_weight() - 7.0).abs() < 1e-12);
    }

    #[test]
    fn incident_weight_sums() {
        let g = triangle();
        assert!((g.incident_weight(0) - 4.0).abs() < 1e-12);
        assert!((g.incident_weight(1) - 3.0).abs() < 1e-12);
        assert!((g.incident_weight(2) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn half_edge_slots_align_with_neighbors() {
        let g = triangle();
        assert_eq!(g.half_edge_count(), 6);
        for u in 0..3 {
            for (k, &v) in g.neighbors(u).iter().enumerate() {
                assert_eq!(g.half_edge_index(u, v), Some(g.row_offset(u) + k));
            }
        }
        assert_eq!(g.half_edge_index(0, 0), None);
    }

    #[test]
    fn duplicate_edges_merge() {
        let mut b = GraphBuilder::with_nodes(2);
        b.add_edge(0, 1, 1.0).add_edge(1, 0, 2.5);
        let g = b.build();
        assert_eq!(g.edge_count(), 1);
        assert_eq!(g.edge_weight(0, 1), Some(3.5));
    }

    #[test]
    fn set_edge_weight_both_directions() {
        let mut g = triangle();
        assert!(g.set_edge_weight(1, 2, 9.0));
        assert_eq!(g.edge_weight(2, 1), Some(9.0));
        assert!(!g.set_edge_weight(0, 0, 1.0) || true); // self lookup is a no-edge
    }

    #[test]
    fn edges_iterator_each_once() {
        let g = triangle();
        let es: Vec<_> = g.edges().collect();
        assert_eq!(es.len(), 3);
        assert!(es.iter().all(|&(u, v, _)| u < v));
    }

    #[test]
    fn dense_adjacency_round_trip() {
        let g = triangle();
        let a = g.dense_adjacency();
        assert_eq!(a.len(), 9);
        assert_eq!(a[0 * 3 + 1], 1.0);
        assert_eq!(a[1 * 3 + 0], 1.0);
        assert_eq!(a[0 * 3 + 0], 0.0);
        assert_eq!(a[2 * 3 + 0], 3.0);
    }

    #[test]
    #[should_panic]
    fn self_loop_rejected() {
        let mut b = GraphBuilder::with_nodes(2);
        b.add_edge(1, 1, 1.0);
    }

    #[test]
    fn dynamic_reweighting() {
        let mut g = triangle();
        g.set_node_weights(&[1.0, 2.0, 3.0]);
        assert_eq!(g.node_weight(2), 3.0);
        g.set_node_weight(0, 7.0);
        assert_eq!(g.node_weight(0), 7.0);
    }
}

//! # GTIP — Game Theoretic Iterative Partitioning
//!
//! A reproduction of Kurve, Griffin, Miller & Kesidis, *"Game Theoretic
//! Iterative Partitioning for Dynamic Load Balancing in Distributed
//! Network Simulation"* (ACM TOMACS, 2011), built as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the coordination contribution: the
//!   node-as-player partitioning game ([`game`]), the distributed
//!   machine-actor refinement protocol ([`coordinator`]), the optimistic
//!   PDES archetype it load-balances ([`sim`]), graph substrates
//!   ([`graph`]) and the experiment harnesses ([`experiments`]).
//! * **Layer 2/1 (python/compile)** — a JAX + Pallas dense cost-matrix
//!   evaluator, AOT-lowered to HLO text and executed from Rust through
//!   PJRT ([`runtime`]). Python never runs at partitioning time.
//!
//! See `DESIGN.md` for the full system inventory and `EXPERIMENTS.md`
//! for the paper-vs-measured record.

pub mod config;
pub mod coordinator;
pub mod error;
pub mod experiments;
pub mod game;
pub mod graph;
pub mod partition;
pub mod runtime;
pub mod sim;
pub mod util;

pub use error::{Error, Result};

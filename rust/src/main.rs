//! `gtip` CLI — leader entrypoint.
//!
//! Subcommands:
//! * `partition`  — generate/load a graph, run initial partitioning +
//!   iterative refinement, report global costs.
//! * `simulate`   — run the optimistic PDES archetype with dynamic
//!   refinement and report simulation time + machine load traces.
//! * `dynamic`    — the closed-loop §6.1 title scenario: scripted
//!   drifting workloads, epoch-windowed load measurement, estimator-
//!   smoothed re-weighting, warm-started refinement, live migration,
//!   per-epoch reports (`--compare` adds the frozen baseline;
//!   `--transport tcp --peers ...` leads a multi-process TCP cluster).
//! * `serve`      — one worker machine of that TCP cluster: joins the
//!   mesh, replays refinement rounds until the leader says goodbye.
//! * `bench-gate` — fail if `results/BENCH_sim.json` is missing a
//!   group/key present in the committed baseline (schema regression).
//! * `experiment` — regenerate a paper table/figure
//!   (`table1 | batch | fig7 | fig8 | fig9 | fig10 | all`).
//! * `artifacts`  — verify the PJRT artifacts load and agree with the
//!   native evaluator.

fn main() {
    let code = gtip::experiments::cli::main();
    std::process::exit(code);
}

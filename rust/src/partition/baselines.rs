//! Baseline partitioners the paper's method is compared against.
//!
//! * [`random_partition`] — uniform random assignment.
//! * [`round_robin`] — node `i` to machine `i mod K`.
//! * [`greedy_load`] — classical longest-processing-time greedy load
//!   balancing (ignores edges entirely): nodes in decreasing weight order
//!   to the machine with least normalized load. The "load-only" end of
//!   the spectrum.
//! * [`cut_only_gain`] — a Nandy–Loucks-style iterative refinement whose
//!   node gain minimizes **only the cut** (no computational-load term),
//!   with each node allowed to migrate at most once (their "forced
//!   convergence"). The paper (§2) singles this out as the closest prior
//!   work; it is the "cut-only" end of the spectrum.

use crate::graph::{Graph, NodeId};
use crate::partition::{MachineConfig, MachineId, Partition};
use crate::util::rng::Pcg32;

/// Uniform random assignment.
pub fn random_partition(g: &Graph, k: usize, rng: &mut Pcg32) -> Partition {
    let assignment: Vec<MachineId> = (0..g.node_count()).map(|_| rng.index(k)).collect();
    Partition::from_assignment(g, k, assignment)
}

/// Round-robin assignment.
pub fn round_robin(g: &Graph, k: usize) -> Partition {
    let assignment: Vec<MachineId> = (0..g.node_count()).map(|i| i % k).collect();
    Partition::from_assignment(g, k, assignment)
}

/// Greedy LPT load balancing on node weights, speed-aware, edge-blind.
pub fn greedy_load(g: &Graph, machines: &MachineConfig) -> Partition {
    let k = machines.count();
    let mut order: Vec<NodeId> = (0..g.node_count()).collect();
    order.sort_by(|&a, &b| {
        g.node_weight(b).partial_cmp(&g.node_weight(a)).expect("finite weights")
    });
    let mut loads = vec![0.0f64; k];
    let mut assignment = vec![0usize; g.node_count()];
    for i in order {
        // Machine minimizing post-assignment normalized load.
        let m = (0..k)
            .min_by(|&a, &b| {
                let la = (loads[a] + g.node_weight(i)) / machines.speed(a);
                let lb = (loads[b] + g.node_weight(i)) / machines.speed(b);
                la.partial_cmp(&lb).expect("finite")
            })
            .expect("k >= 1");
        assignment[i] = m;
        loads[m] += g.node_weight(i);
    }
    Partition::from_assignment(g, k, assignment)
}

/// Result of the cut-only refinement baseline.
#[derive(Debug, Clone)]
pub struct CutOnlyReport {
    pub moves: usize,
    pub initial_cut: f64,
    pub final_cut: f64,
}

/// Nandy–Loucks-style cut-only iterative improvement: repeatedly move the
/// node with the largest positive cut gain (external − internal edge
/// weight toward its best machine), each node at most once ("forced
/// convergence"). Ignores node weights / machine loads entirely.
pub fn cut_only_gain(g: &Graph, part: &mut Partition) -> CutOnlyReport {
    let k = part.machine_count();
    let initial_cut = crate::graph::metrics::cut_weight(g, part.assignment());
    let n = g.node_count();
    let mut migrated = vec![false; n];
    let mut moves = 0;

    loop {
        // Find the node with the best (largest) positive gain.
        let mut best: Option<(f64, NodeId, MachineId)> = None;
        for i in 0..n {
            if migrated[i] {
                continue;
            }
            let cur = part.machine_of(i);
            // adj[k] = weight of i's edges into machine k.
            let mut adj = vec![0.0f64; k];
            for (j, c) in g.neighbors_weighted(i) {
                adj[part.machine_of(j)] += c;
            }
            for m in 0..k {
                if m == cur {
                    continue;
                }
                // Gain = reduction in cut if i moves to m.
                let gain = adj[m] - adj[cur];
                if gain > 1e-12 && best.map(|(bg, _, _)| gain > bg).unwrap_or(true) {
                    best = Some((gain, i, m));
                }
            }
        }
        match best {
            Some((_, i, m)) => {
                part.transfer(g, i, m);
                migrated[i] = true;
                moves += 1;
            }
            None => break,
        }
    }
    let final_cut = crate::graph::metrics::cut_weight(g, part.assignment());
    CutOnlyReport { moves, initial_cut, final_cut }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{table1_graph, WeightModel};
    use crate::graph::metrics::cut_weight;

    fn graph(seed: u64) -> Graph {
        let mut rng = Pcg32::new(seed);
        table1_graph(80, 3, 6, WeightModel::default(), &mut rng)
    }

    #[test]
    fn random_partition_valid() {
        let g = graph(1);
        let mut rng = Pcg32::new(2);
        let p = random_partition(&g, 5, &mut rng);
        p.validate(&g).unwrap();
    }

    #[test]
    fn round_robin_counts_even() {
        let g = graph(2);
        let p = round_robin(&g, 4);
        p.validate(&g).unwrap();
        let counts = p.counts();
        assert!(counts.iter().max().unwrap() - counts.iter().min().unwrap() <= 1);
    }

    #[test]
    fn greedy_load_balances_normalized_loads() {
        let g = graph(3);
        let machines = MachineConfig::from_speeds(&[1.0, 2.0, 3.0, 3.0, 1.0]);
        let p = greedy_load(&g, &machines);
        p.validate(&g).unwrap();
        // Normalized loads should be within ~2 max node weights of each other.
        let max_b = (0..80).map(|i| g.node_weight(i)).fold(0.0f64, f64::max);
        let norm: Vec<f64> = (0..5).map(|k| p.load(k) / machines.speed(k)).collect();
        let spread = norm.iter().fold(f64::NEG_INFINITY, |a, &b| a.max(b))
            - norm.iter().fold(f64::INFINITY, |a, &b| a.min(b));
        assert!(
            spread <= 2.5 * max_b / machines.speed(0),
            "spread {spread} too large (max_b {max_b}; norm {norm:?})"
        );
    }

    #[test]
    fn cut_only_reduces_cut_monotonically() {
        let g = graph(4);
        let mut rng = Pcg32::new(5);
        let mut p = random_partition(&g, 4, &mut rng);
        let before = cut_weight(&g, p.assignment());
        let report = cut_only_gain(&g, &mut p);
        let after = cut_weight(&g, p.assignment());
        assert!((report.initial_cut - before).abs() < 1e-9);
        assert!((report.final_cut - after).abs() < 1e-9);
        assert!(after <= before);
        p.validate(&g).unwrap();
    }

    #[test]
    fn cut_only_each_node_moves_at_most_once() {
        let g = graph(6);
        let mut rng = Pcg32::new(7);
        let mut p = random_partition(&g, 4, &mut rng);
        let report = cut_only_gain(&g, &mut p);
        assert!(report.moves <= g.node_count());
    }

    #[test]
    fn cut_only_ignores_load_balance() {
        // A clique collapses onto one machine under cut-only refinement —
        // demonstrating exactly the deficiency the paper calls out (§2).
        let mut b = crate::graph::GraphBuilder::with_nodes(8);
        for u in 0..8 {
            for v in (u + 1)..8 {
                b.add_edge(u, v, 1.0);
            }
        }
        let g = b.build();
        let mut p = Partition::from_assignment(&g, 2, vec![0, 1, 0, 1, 0, 1, 0, 1]);
        let _ = cut_only_gain(&g, &mut p);
        let counts = p.counts();
        assert!(
            counts.contains(&0) || counts.iter().any(|&c| c >= 7),
            "clique should collapse to one side: {counts:?}"
        );
    }
}

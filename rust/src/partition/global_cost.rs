//! Global (social-welfare / potential) cost functions.
//!
//! * `C0` — the potential of Framework A (Thm 3.1): the sum of all node
//!   costs `C_0(r) = Σ_i C_i(r_i, r_{-i})`. Closed form per machine:
//!   `Σ_k (L_k² − Σ_{i∈k} b_i²) / w_k + μ · cut`, since each cut edge is
//!   paid `μ/2` by *both* endpoints.
//! * `C̃0` — the centralized Lagrangian of Framework B (eq. 8): squared
//!   speed-normalized load deviation plus a `(μ/2)·cut` term — reading
//!   eq. 8's pair sum over unordered pairs, the reading under which
//!   Thm 5.1's exact identity `ΔC̃0 = C̃_i(n) − C̃_i(m)` holds
//!   (verified by unit + property tests). I.e. the cut term is:
//!   `Σ_k (L_k / w_k − B)² + μ · cut`.
//!
//! Both are evaluated from scratch here (O(N + |E|)); the refinement
//! engine tracks them incrementally and unit tests assert agreement.

use crate::graph::{metrics, Graph};
use crate::partition::{MachineConfig, Partition};

/// Framework A's potential `C_0(r)` (Thm 3.1).
pub fn c0(graph: &Graph, machines: &MachineConfig, part: &Partition, mu: f64) -> f64 {
    let k = part.machine_count();
    assert_eq!(machines.count(), k);
    // Σ_{i∈k} b_i² per machine.
    let mut sq = vec![0.0f64; k];
    for i in 0..graph.node_count() {
        let b = graph.node_weight(i);
        sq[part.machine_of(i)] += b * b;
    }
    let mut comp = 0.0;
    for m in 0..k {
        let l = part.load(m);
        comp += (l * l - sq[m]) / machines.speed(m);
    }
    comp + mu * metrics::cut_weight(graph, part.assignment())
}

/// Framework B's centralized cost `C̃_0(X)` (eq. 8).
pub fn c0_tilde(graph: &Graph, machines: &MachineConfig, part: &Partition, mu: f64) -> f64 {
    let k = part.machine_count();
    assert_eq!(machines.count(), k);
    let b_total = graph.total_node_weight();
    let mut dev = 0.0;
    for m in 0..k {
        let d = part.load(m) / machines.speed(m) - b_total;
        dev += d * d;
    }
    dev + mu * 0.5 * metrics::cut_weight(graph, part.assignment())
}

/// Both global costs at once (the experiment harnesses report both for
/// each framework, as Table I does).
pub fn both(graph: &Graph, machines: &MachineConfig, part: &Partition, mu: f64) -> (f64, f64) {
    (c0(graph, machines, part, mu), c0_tilde(graph, machines, part, mu))
}

/// The augmented global objective of the migration-cost-aware game
/// (DESIGN.md §9): `Φ' = Φ + c_mig · (#transfers executed)`. Every
/// accepted transfer of the augmented refinement strictly decreases
/// this quantity (for A, `ΔΦ = −2(𝔍'+c_mig)` so `ΔΦ' = −2𝔍' − c_mig`;
/// for B, `ΔΦ = −(𝔍'+c_mig)` so `ΔΦ' = −𝔍'`), which is what bounds the
/// churn: total transfers ≤ (Φ_start − Φ_min) / c_mig for any positive
/// charge. Reports pair it with the raw potential so the migration
/// spend is visible in the same units as the objective.
pub fn augmented(raw_potential: f64, migration_charge: f64, transfers: usize) -> f64 {
    debug_assert!(migration_charge >= 0.0);
    raw_potential + migration_charge * transfers as f64
}

/// A global potential decomposed along a rack partition of the machine
/// pool (the two-level hierarchy of DESIGN.md §12): one subtotal per
/// rack (that rack's member machine terms plus the intra-rack share of
/// the cut term) and a single cross-rack cut weight. The identity
/// `total = Σ_r per_rack[r] + cut_coeff · cross_cut` recovers the flat
/// potential — bit-for-bit when every rack is a singleton (the
/// accumulation order is then literally the flat loop), and to 1e-9
/// relative accuracy for any grouping (addition is re-associated).
#[derive(Debug, Clone, PartialEq)]
pub struct RackPotential {
    /// Per-rack subtotal: member machine terms + the intra-rack cut
    /// share (already scaled by `μ` / `μ/2`).
    pub per_rack: Vec<f64>,
    /// Total weight of edges whose endpoints live on machines of
    /// *different* racks — the only coupling between rack subgames.
    pub cross_cut: f64,
    /// `Σ_r per_rack[r] + cut_coeff · cross_cut`.
    pub total: f64,
}

/// Shared scan behind [`c0_by_rack`] and [`c0_tilde_by_rack`]:
/// `machine_term(m)` is the per-machine summand, `cut_coeff` the factor
/// on cut weight (`μ` for A, `μ/2` for B). `rack_of[m]` maps machine →
/// rack id (dense `0..R`).
fn potential_by_rack(
    graph: &Graph,
    part: &Partition,
    rack_of: &[usize],
    cut_coeff: f64,
    machine_term: impl Fn(usize) -> f64,
) -> RackPotential {
    let k = part.machine_count();
    assert_eq!(rack_of.len(), k, "rack_of must map every machine");
    let racks = rack_of.iter().copied().max().map_or(0, |r| r + 1);
    assert!(rack_of.iter().all(|&r| r < racks));
    let mut member_terms = vec![0.0f64; racks];
    for m in 0..k {
        member_terms[rack_of[m]] += machine_term(m);
    }
    let mut intra = vec![0.0f64; racks];
    let mut cross_cut = 0.0f64;
    for (u, v, w) in graph.edges() {
        let (mu_, mv) = (part.machine_of(u), part.machine_of(v));
        if mu_ == mv {
            continue;
        }
        let (ru, rv) = (rack_of[mu_], rack_of[mv]);
        if ru == rv {
            intra[ru] += w;
        } else {
            cross_cut += w;
        }
    }
    let per_rack: Vec<f64> =
        (0..racks).map(|r| member_terms[r] + cut_coeff * intra[r]).collect();
    let total = per_rack.iter().sum::<f64>() + cut_coeff * cross_cut;
    RackPotential { per_rack, cross_cut, total }
}

/// Framework A's potential decomposed by rack:
/// `C_0 = Σ_r [Σ_{m∈r} (L_m² − Σ b²)/w_m + μ·cut_intra(r)] + μ·cut_cross`.
pub fn c0_by_rack(
    graph: &Graph,
    machines: &MachineConfig,
    part: &Partition,
    mu: f64,
    rack_of: &[usize],
) -> RackPotential {
    let k = part.machine_count();
    assert_eq!(machines.count(), k);
    let mut sq = vec![0.0f64; k];
    for i in 0..graph.node_count() {
        let b = graph.node_weight(i);
        sq[part.machine_of(i)] += b * b;
    }
    potential_by_rack(graph, part, rack_of, mu, |m| {
        let l = part.load(m);
        (l * l - sq[m]) / machines.speed(m)
    })
}

/// Framework B's centralized cost decomposed by rack:
/// `C̃_0 = Σ_r [Σ_{m∈r} (L_m/w_m − B)² + (μ/2)·cut_intra(r)] + (μ/2)·cut_cross`.
pub fn c0_tilde_by_rack(
    graph: &Graph,
    machines: &MachineConfig,
    part: &Partition,
    mu: f64,
    rack_of: &[usize],
) -> RackPotential {
    let k = part.machine_count();
    assert_eq!(machines.count(), k);
    let b_total = graph.total_node_weight();
    potential_by_rack(graph, part, rack_of, mu * 0.5, |m| {
        let d = part.load(m) / machines.speed(m) - b_total;
        d * d
    })
}

/// Naive O(N²)-style `C_0` computed literally from the definition
/// `Σ_i C_i` — the test oracle for the closed form above.
pub fn c0_naive(graph: &Graph, machines: &MachineConfig, part: &Partition, mu: f64) -> f64 {
    let n = graph.node_count();
    let mut total = 0.0;
    for i in 0..n {
        let ri = part.machine_of(i);
        let bi = graph.node_weight(i);
        // Σ_{j≠i, r_j=r_i} b_j = L_{r_i} − b_i
        let same_load = part.load(ri) - bi;
        let mut cut = 0.0;
        for (j, c) in graph.neighbors_weighted(i) {
            if part.machine_of(j) != ri {
                cut += c;
            }
        }
        total += bi / machines.speed(ri) * same_load + mu / 2.0 * cut;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{table1_graph, WeightModel};
    use crate::util::rng::Pcg32;

    fn setup(seed: u64) -> (Graph, MachineConfig, Partition) {
        let mut rng = Pcg32::new(seed);
        let g = table1_graph(60, 3, 6, WeightModel::default(), &mut rng);
        let machines = MachineConfig::from_speeds(&[0.1, 0.2, 0.3, 0.3, 0.1]);
        let assignment: Vec<usize> = (0..60).map(|_| rng.index(5)).collect();
        let p = Partition::from_assignment(&g, 5, assignment);
        (g, machines, p)
    }

    #[test]
    fn closed_form_matches_naive() {
        for seed in 0..5 {
            let (g, m, p) = setup(seed);
            let fast = c0(&g, &m, &p, 8.0);
            let slow = c0_naive(&g, &m, &p, 8.0);
            assert!(
                (fast - slow).abs() < 1e-6 * (1.0 + fast.abs()),
                "seed {seed}: {fast} vs {slow}"
            );
        }
    }

    #[test]
    fn zero_mu_drops_cut_term() {
        let (g, m, p) = setup(1);
        let with = c0(&g, &m, &p, 8.0);
        let without = c0(&g, &m, &p, 0.0);
        let cut = crate::graph::metrics::cut_weight(&g, p.assignment());
        assert!((with - without - 8.0 * cut).abs() < 1e-9);
    }

    #[test]
    fn c0_tilde_zero_at_perfect_balance_no_cut() {
        // Two disconnected-ish nodes (zero-weight bridging edge), equal
        // machines, one node each: deviation and weighted cut both 0.
        let mut b = crate::graph::GraphBuilder::with_nodes(2);
        b.add_edge(0, 1, 0.0);
        b.set_node_weight(0, 5.0);
        b.set_node_weight(1, 5.0);
        let g = b.build();
        let m = MachineConfig::homogeneous(2);
        let p = Partition::from_assignment(&g, 2, vec![0, 1]);
        // L_k / w_k = 5 / 0.5 = 10 = B for both machines.
        assert!(c0_tilde(&g, &m, &p, 8.0).abs() < 1e-9);
    }

    #[test]
    fn c0_tilde_penalizes_imbalance() {
        let (g, m, _) = setup(2);
        let balancedish = Partition::from_assignment(&g, 5, (0..60).map(|i| i % 5).collect());
        let lumped = Partition::all_on_machine(&g, 5, 0);
        assert!(
            c0_tilde(&g, &m, &lumped, 0.0) > c0_tilde(&g, &m, &balancedish, 0.0),
            "lumping everything on one machine must cost more"
        );
    }

    #[test]
    fn augmented_adds_charge_per_transfer() {
        assert_eq!(augmented(100.0, 0.0, 50), 100.0);
        assert_eq!(augmented(100.0, 2.5, 4), 110.0);
        assert_eq!(augmented(-7.0, 3.0, 0), -7.0);
    }

    #[test]
    fn both_returns_consistent_pair() {
        let (g, m, p) = setup(3);
        let (a, b) = both(&g, &m, &p, 8.0);
        assert_eq!(a, c0(&g, &m, &p, 8.0));
        assert_eq!(b, c0_tilde(&g, &m, &p, 8.0));
    }

    #[test]
    fn rack_decomposition_is_exact_on_singleton_racks() {
        // One machine per rack: the decomposed accumulation order is
        // literally the flat loop, so totals must agree bit-for-bit.
        for seed in 0..5 {
            let (g, m, p) = setup(seed);
            let singles: Vec<usize> = (0..5).collect();
            let a = c0_by_rack(&g, &m, &p, 8.0, &singles);
            let b = c0_tilde_by_rack(&g, &m, &p, 8.0, &singles);
            assert_eq!(a.total.to_bits(), c0(&g, &m, &p, 8.0).to_bits(), "seed {seed} (A)");
            assert_eq!(b.total.to_bits(), c0_tilde(&g, &m, &p, 8.0).to_bits(), "seed {seed} (B)");
            assert_eq!(a.per_rack.len(), 5);
        }
    }

    #[test]
    fn rack_decomposition_matches_flat_on_random_groupings() {
        // Property: for any rack grouping, Σ_r per_rack + coeff·cross
        // re-associates the flat sum — equal to 1e-9 relative.
        let mut rng = Pcg32::new(77);
        for seed in 0..20 {
            let (g, m, p) = setup(seed);
            // Random dense rack map over 1..=3 racks covering 5 machines.
            let racks = 1 + rng.index(3);
            let mut rack_of: Vec<usize> = (0..5).map(|_| rng.index(racks)).collect();
            // Densify: make sure every rack id below the max appears.
            for r in 0..racks {
                rack_of[r % 5] = r.min(racks - 1);
            }
            let max = rack_of.iter().copied().max().unwrap();
            for r in rack_of.iter_mut() {
                *r = (*r).min(max);
            }
            let a = c0_by_rack(&g, &m, &p, 8.0, &rack_of);
            let flat_a = c0(&g, &m, &p, 8.0);
            assert!(
                (a.total - flat_a).abs() <= 1e-9 * (1.0 + flat_a.abs()),
                "seed {seed}: {} vs {flat_a}",
                a.total
            );
            let b = c0_tilde_by_rack(&g, &m, &p, 8.0, &rack_of);
            let flat_b = c0_tilde(&g, &m, &p, 8.0);
            assert!(
                (b.total - flat_b).abs() <= 1e-9 * (1.0 + flat_b.abs()),
                "seed {seed}: {} vs {flat_b}",
                b.total
            );
            // The cross-rack cut plus intra shares re-compose the cut.
            let cut = crate::graph::metrics::cut_weight(&g, p.assignment());
            let intra_sum: f64 = a
                .per_rack
                .iter()
                .enumerate()
                .map(|(r, &v)| {
                    let member: f64 = (0..5)
                        .filter(|&mch| rack_of[mch] == r)
                        .map(|mch| {
                            let l = p.load(mch);
                            let sq: f64 = (0..g.node_count())
                                .filter(|&i| p.machine_of(i) == mch)
                                .map(|i| g.node_weight(i) * g.node_weight(i))
                                .sum();
                            (l * l - sq) / m.speed(mch)
                        })
                        .sum();
                    (v - member) / 8.0
                })
                .sum();
            assert!(
                (intra_sum + a.cross_cut - cut).abs() <= 1e-6 * (1.0 + cut.abs()),
                "seed {seed}: intra {intra_sum} + cross {} vs cut {cut}",
                a.cross_cut
            );
        }
    }
}

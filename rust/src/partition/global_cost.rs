//! Global (social-welfare / potential) cost functions.
//!
//! * `C0` — the potential of Framework A (Thm 3.1): the sum of all node
//!   costs `C_0(r) = Σ_i C_i(r_i, r_{-i})`. Closed form per machine:
//!   `Σ_k (L_k² − Σ_{i∈k} b_i²) / w_k + μ · cut`, since each cut edge is
//!   paid `μ/2` by *both* endpoints.
//! * `C̃0` — the centralized Lagrangian of Framework B (eq. 8): squared
//!   speed-normalized load deviation plus a `(μ/2)·cut` term — reading
//!   eq. 8's pair sum over unordered pairs, the reading under which
//!   Thm 5.1's exact identity `ΔC̃0 = C̃_i(n) − C̃_i(m)` holds
//!   (verified by unit + property tests). I.e. the cut term is:
//!   `Σ_k (L_k / w_k − B)² + μ · cut`.
//!
//! Both are evaluated from scratch here (O(N + |E|)); the refinement
//! engine tracks them incrementally and unit tests assert agreement.

use crate::graph::{metrics, Graph};
use crate::partition::{MachineConfig, Partition};

/// Framework A's potential `C_0(r)` (Thm 3.1).
pub fn c0(graph: &Graph, machines: &MachineConfig, part: &Partition, mu: f64) -> f64 {
    let k = part.machine_count();
    assert_eq!(machines.count(), k);
    // Σ_{i∈k} b_i² per machine.
    let mut sq = vec![0.0f64; k];
    for i in 0..graph.node_count() {
        let b = graph.node_weight(i);
        sq[part.machine_of(i)] += b * b;
    }
    let mut comp = 0.0;
    for m in 0..k {
        let l = part.load(m);
        comp += (l * l - sq[m]) / machines.speed(m);
    }
    comp + mu * metrics::cut_weight(graph, part.assignment())
}

/// Framework B's centralized cost `C̃_0(X)` (eq. 8).
pub fn c0_tilde(graph: &Graph, machines: &MachineConfig, part: &Partition, mu: f64) -> f64 {
    let k = part.machine_count();
    assert_eq!(machines.count(), k);
    let b_total = graph.total_node_weight();
    let mut dev = 0.0;
    for m in 0..k {
        let d = part.load(m) / machines.speed(m) - b_total;
        dev += d * d;
    }
    dev + mu * 0.5 * metrics::cut_weight(graph, part.assignment())
}

/// Both global costs at once (the experiment harnesses report both for
/// each framework, as Table I does).
pub fn both(graph: &Graph, machines: &MachineConfig, part: &Partition, mu: f64) -> (f64, f64) {
    (c0(graph, machines, part, mu), c0_tilde(graph, machines, part, mu))
}

/// The augmented global objective of the migration-cost-aware game
/// (DESIGN.md §9): `Φ' = Φ + c_mig · (#transfers executed)`. Every
/// accepted transfer of the augmented refinement strictly decreases
/// this quantity (for A, `ΔΦ = −2(𝔍'+c_mig)` so `ΔΦ' = −2𝔍' − c_mig`;
/// for B, `ΔΦ = −(𝔍'+c_mig)` so `ΔΦ' = −𝔍'`), which is what bounds the
/// churn: total transfers ≤ (Φ_start − Φ_min) / c_mig for any positive
/// charge. Reports pair it with the raw potential so the migration
/// spend is visible in the same units as the objective.
pub fn augmented(raw_potential: f64, migration_charge: f64, transfers: usize) -> f64 {
    debug_assert!(migration_charge >= 0.0);
    raw_potential + migration_charge * transfers as f64
}

/// Naive O(N²)-style `C_0` computed literally from the definition
/// `Σ_i C_i` — the test oracle for the closed form above.
pub fn c0_naive(graph: &Graph, machines: &MachineConfig, part: &Partition, mu: f64) -> f64 {
    let n = graph.node_count();
    let mut total = 0.0;
    for i in 0..n {
        let ri = part.machine_of(i);
        let bi = graph.node_weight(i);
        // Σ_{j≠i, r_j=r_i} b_j = L_{r_i} − b_i
        let same_load = part.load(ri) - bi;
        let mut cut = 0.0;
        for (j, c) in graph.neighbors_weighted(i) {
            if part.machine_of(j) != ri {
                cut += c;
            }
        }
        total += bi / machines.speed(ri) * same_load + mu / 2.0 * cut;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{table1_graph, WeightModel};
    use crate::util::rng::Pcg32;

    fn setup(seed: u64) -> (Graph, MachineConfig, Partition) {
        let mut rng = Pcg32::new(seed);
        let g = table1_graph(60, 3, 6, WeightModel::default(), &mut rng);
        let machines = MachineConfig::from_speeds(&[0.1, 0.2, 0.3, 0.3, 0.1]);
        let assignment: Vec<usize> = (0..60).map(|_| rng.index(5)).collect();
        let p = Partition::from_assignment(&g, 5, assignment);
        (g, machines, p)
    }

    #[test]
    fn closed_form_matches_naive() {
        for seed in 0..5 {
            let (g, m, p) = setup(seed);
            let fast = c0(&g, &m, &p, 8.0);
            let slow = c0_naive(&g, &m, &p, 8.0);
            assert!(
                (fast - slow).abs() < 1e-6 * (1.0 + fast.abs()),
                "seed {seed}: {fast} vs {slow}"
            );
        }
    }

    #[test]
    fn zero_mu_drops_cut_term() {
        let (g, m, p) = setup(1);
        let with = c0(&g, &m, &p, 8.0);
        let without = c0(&g, &m, &p, 0.0);
        let cut = crate::graph::metrics::cut_weight(&g, p.assignment());
        assert!((with - without - 8.0 * cut).abs() < 1e-9);
    }

    #[test]
    fn c0_tilde_zero_at_perfect_balance_no_cut() {
        // Two disconnected-ish nodes (zero-weight bridging edge), equal
        // machines, one node each: deviation and weighted cut both 0.
        let mut b = crate::graph::GraphBuilder::with_nodes(2);
        b.add_edge(0, 1, 0.0);
        b.set_node_weight(0, 5.0);
        b.set_node_weight(1, 5.0);
        let g = b.build();
        let m = MachineConfig::homogeneous(2);
        let p = Partition::from_assignment(&g, 2, vec![0, 1]);
        // L_k / w_k = 5 / 0.5 = 10 = B for both machines.
        assert!(c0_tilde(&g, &m, &p, 8.0).abs() < 1e-9);
    }

    #[test]
    fn c0_tilde_penalizes_imbalance() {
        let (g, m, _) = setup(2);
        let balancedish = Partition::from_assignment(&g, 5, (0..60).map(|i| i % 5).collect());
        let lumped = Partition::all_on_machine(&g, 5, 0);
        assert!(
            c0_tilde(&g, &m, &lumped, 0.0) > c0_tilde(&g, &m, &balancedish, 0.0),
            "lumping everything on one machine must cost more"
        );
    }

    #[test]
    fn augmented_adds_charge_per_transfer() {
        assert_eq!(augmented(100.0, 0.0, 50), 100.0);
        assert_eq!(augmented(100.0, 2.5, 4), 110.0);
        assert_eq!(augmented(-7.0, 3.0, 0), -7.0);
    }

    #[test]
    fn both_returns_consistent_pair() {
        let (g, m, p) = setup(3);
        let (a, b) = both(&g, &m, &p, 8.0);
        assert_eq!(a, c0(&g, &m, &p, 8.0));
        assert_eq!(b, c0_tilde(&g, &m, &p, 8.0));
    }
}

//! Initial partitioning (paper Appendix A).
//!
//! Because node/edge weights are unknown and dynamic before the
//! simulation starts, the paper seeds the iterative game with a simple
//! structural partition: choose K **focal nodes** far apart in geodesic
//! distance (eq. 11, via an iterated local-improvement heuristic over
//! multiple restarts), then let machines expand hop-by-hop from their
//! focal nodes, claiming unowned nodes — with random waits + a semaphore
//! arbitrating contention in the real distributed setting (modeled here
//! by randomized round-robin claim order). Unit node/edge weights are
//! assumed during this phase, exactly as §4.1 specifies.
//!
//! Also implements **Theorem A.1**: the expected BFS-cluster growth law
//! on Erdős–Rényi graphs used to size focal-node separation.

use crate::graph::{metrics, Graph, NodeId};
use crate::partition::{MachineConfig, MachineId, Partition};
use crate::util::rng::Pcg32;

/// Options for focal-node selection.
#[derive(Debug, Clone)]
pub struct FocalOptions {
    /// Independent restarts of the local-improvement heuristic; the best
    /// focal set (by max-min geodesic separation) wins.
    pub restarts: usize,
    /// Cap on improvement passes per restart.
    pub max_passes: usize,
}

impl Default for FocalOptions {
    fn default() -> Self {
        FocalOptions { restarts: 4, max_passes: 16 }
    }
}

/// Minimum pairwise geodesic distance of a candidate focal set.
fn min_pairwise_distance(g: &Graph, focals: &[NodeId]) -> usize {
    let mut best = usize::MAX;
    for (idx, &f) in focals.iter().enumerate() {
        let others: Vec<NodeId> = focals[idx + 1..].to_vec();
        if others.is_empty() {
            continue;
        }
        let d = metrics::bfs_distances_to(g, f, &others);
        for &o in &others {
            best = best.min(d[o]);
        }
    }
    best
}

/// Choose K focal nodes approximately maximizing the minimum pairwise
/// geodesic distance (paper eq. 11): random init, then round-robin local
/// improvement where each machine moves its focal node to a neighbor if
/// that increases its own min-distance to the other focal nodes;
/// iterated to a fixed point, over several restarts.
pub fn choose_focal_nodes(
    g: &Graph,
    k: usize,
    options: &FocalOptions,
    rng: &mut Pcg32,
) -> Vec<NodeId> {
    let n = g.node_count();
    assert!(k >= 1 && k <= n, "need 1 <= K <= N");
    if k == 1 {
        return vec![rng.index(n)];
    }
    let mut best_set: Vec<NodeId> = Vec::new();
    let mut best_score = 0usize;

    for _ in 0..options.restarts.max(1) {
        let mut focals = rng.sample_indices(n, k);
        let mut improved = true;
        let mut passes = 0;
        while improved && passes < options.max_passes {
            improved = false;
            passes += 1;
            for idx in 0..k {
                let others: Vec<NodeId> =
                    focals.iter().enumerate().filter(|&(j, _)| j != idx).map(|(_, &f)| f).collect();
                // Current min distance from focal idx to the others.
                let d_cur = metrics::bfs_distances_to(g, focals[idx], &others);
                let cur_min = others.iter().map(|&o| d_cur[o]).min().unwrap_or(usize::MAX);
                // Try neighbors of the current focal node.
                let mut best_move: Option<(usize, NodeId)> = None;
                for &cand in g.neighbors(focals[idx]) {
                    if focals.contains(&cand) {
                        continue;
                    }
                    let d = metrics::bfs_distances_to(g, cand, &others);
                    let cand_min = others.iter().map(|&o| d[o]).min().unwrap_or(usize::MAX);
                    if cand_min > cur_min
                        && best_move.map(|(m, _)| cand_min > m).unwrap_or(true)
                    {
                        best_move = Some((cand_min, cand));
                    }
                }
                if let Some((_, cand)) = best_move {
                    focals[idx] = cand;
                    improved = true;
                }
            }
        }
        let score = min_pairwise_distance(g, &focals);
        if score > best_score || best_set.is_empty() {
            best_score = score;
            best_set = focals;
        }
    }
    best_set
}

/// Hop-by-hop expansion from focal nodes (App. A phase 2): each machine
/// claims the unowned neighbors of its current frontier; machines take
/// hops in a randomly shuffled order per round, which models the random
/// wait + semaphore contention arbitration of the distributed original.
/// Any node left unreached (disconnected corner case) is assigned to the
/// least-loaded machine. Unit weights are used, per §4.1.
pub fn expand_from_focals(
    g: &Graph,
    k: usize,
    focals: &[NodeId],
    rng: &mut Pcg32,
) -> Vec<MachineId> {
    assert_eq!(focals.len(), k);
    let n = g.node_count();
    let mut owner: Vec<Option<MachineId>> = vec![None; n];
    let mut frontier: Vec<Vec<NodeId>> = vec![Vec::new(); k];
    for (m, &f) in focals.iter().enumerate() {
        if owner[f].is_none() {
            owner[f] = Some(m);
            frontier[m].push(f);
        }
    }
    let mut order: Vec<MachineId> = (0..k).collect();
    let owned = owner.iter().filter(|o| o.is_some()).count();
    let mut remaining = n - owned;

    while remaining > 0 {
        let mut any_claimed = false;
        rng.shuffle(&mut order); // random wait ≈ random machine order
        for &m in &order {
            let mut next_frontier = Vec::new();
            for &u in &frontier[m] {
                for &v in g.neighbors(u) {
                    if owner[v].is_none() {
                        owner[v] = Some(m); // semaphore: first claim wins
                        next_frontier.push(v);
                        remaining -= 1;
                        any_claimed = true;
                    }
                }
            }
            frontier[m] = next_frontier;
        }
        if !any_claimed {
            break; // disconnected remainder
        }
    }
    // Disconnected remainder → least-populated machine.
    let mut counts = vec![0usize; k];
    for o in owner.iter().flatten() {
        counts[*o] += 1;
    }
    owner
        .into_iter()
        .map(|o| {
            o.unwrap_or_else(|| {
                let m = counts
                    .iter()
                    .enumerate()
                    .min_by_key(|&(_, c)| *c)
                    .map(|(m, _)| m)
                    .expect("k >= 1");
                counts[m] += 1;
                m
            })
        })
        .collect()
}

/// Full initial partitioning: focal selection + expansion (App. A).
pub fn grow_partition(g: &Graph, machines: &MachineConfig, rng: &mut Pcg32) -> Partition {
    let k = machines.count();
    let focals = choose_focal_nodes(g, k, &FocalOptions::default(), rng);
    let assignment = expand_from_focals(g, k, &focals, rng);
    Partition::from_assignment(g, k, assignment)
}

/// Theorem A.1: expected BFS-cluster sizes on an Erdős–Rényi G(|V|, p)
/// graph. Returns `N_0, N_1, ..., N_hops` where
/// `N_{k+1} = N_k + (|V| − N_k)(1 − (1−p)^{N_k − N_{k−1}})`, `N_1 = 1`.
pub fn er_cluster_growth(v: usize, p: f64, hops: usize) -> Vec<f64> {
    assert!((0.0..=1.0).contains(&p));
    let v = v as f64;
    let mut out = Vec::with_capacity(hops + 1);
    // N_0 = 0 (nothing before the seed), N_1 = 1 (the seed itself).
    out.push(0.0);
    if hops == 0 {
        return out;
    }
    out.push(1.0);
    for k in 1..hops {
        let nk = out[k];
        let nk1 = out[k - 1];
        let newly = nk - nk1;
        let next = nk + (v - nk) * (1.0 - (1.0 - p).powf(newly));
        out.push(next.min(v));
    }
    out
}

/// Mean number of hops for an ER BFS cluster to cover `target` nodes,
/// per the Thm A.1 recursion (used to size focal separation `2·N_{|V|/K}`).
pub fn er_hops_to_cover(v: usize, p: f64, target: f64) -> usize {
    let growth = er_cluster_growth(v, p, 4 * (v.max(2)).ilog2() as usize + 8);
    for (hop, &n) in growth.iter().enumerate() {
        if n >= target {
            return hop;
        }
    }
    growth.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{erdos_renyi, preferential_attachment, table1_graph, WeightModel};
    use crate::graph::GraphBuilder;

    #[test]
    fn focal_nodes_distinct_and_spread_on_path() {
        // Path graph: optimal 2 focal nodes are the endpoints.
        let mut b = GraphBuilder::with_nodes(20);
        for i in 0..19 {
            b.add_edge(i, i + 1, 1.0);
        }
        let g = b.build();
        let mut rng = Pcg32::new(1);
        let focals =
            choose_focal_nodes(&g, 2, &FocalOptions { restarts: 8, max_passes: 64 }, &mut rng);
        assert_eq!(focals.len(), 2);
        assert_ne!(focals[0], focals[1]);
        let d = metrics::bfs_distances(&g, focals[0]);
        assert!(
            d[focals[1]] >= 12,
            "focal nodes too close on path: dist {}",
            d[focals[1]]
        );
    }

    #[test]
    fn expansion_covers_all_nodes() {
        let mut rng = Pcg32::new(2);
        let g = table1_graph(120, 3, 6, WeightModel::default(), &mut rng);
        let machines = MachineConfig::from_speeds(&[0.1, 0.2, 0.3, 0.3, 0.1]);
        let p = grow_partition(&g, &machines, &mut rng);
        p.validate(&g).unwrap();
        // Every machine got at least one node on a connected 120-node graph.
        for k in 0..5 {
            assert!(p.count(k) > 0, "machine {k} got no nodes");
        }
    }

    #[test]
    fn expansion_roughly_balances_counts() {
        let mut rng = Pcg32::new(3);
        let g = preferential_attachment(400, 2, &mut rng);
        let machines = MachineConfig::homogeneous(4);
        let p = grow_partition(&g, &machines, &mut rng);
        let counts = p.counts();
        let max = *counts.iter().max().unwrap() as f64;
        let min = *counts.iter().min().unwrap() as f64;
        // Hop-growth is not perfectly equitable, but should be same-order.
        assert!(max / min.max(1.0) < 20.0, "counts wildly unbalanced: {counts:?}");
    }

    #[test]
    fn expansion_produces_connected_regions_on_grid() {
        // 6x6 grid, 4 machines: claimed regions should each be connected.
        let n = 36;
        let mut b = GraphBuilder::with_nodes(n);
        for r in 0..6 {
            for c in 0..6 {
                let u = r * 6 + c;
                if c + 1 < 6 {
                    b.add_edge(u, u + 1, 1.0);
                }
                if r + 1 < 6 {
                    b.add_edge(u, u + 6, 1.0);
                }
            }
        }
        let g = b.build();
        let mut rng = Pcg32::new(4);
        let focals = choose_focal_nodes(&g, 4, &FocalOptions::default(), &mut rng);
        let assign = expand_from_focals(&g, 4, &focals, &mut rng);
        // Check per-machine connectivity via BFS within the machine.
        for m in 0..4 {
            let members: Vec<usize> = (0..n).filter(|&u| assign[u] == m).collect();
            if members.is_empty() {
                continue;
            }
            let mut seen = vec![false; n];
            let mut queue = std::collections::VecDeque::new();
            seen[members[0]] = true;
            queue.push_back(members[0]);
            let mut reached = 1;
            while let Some(u) = queue.pop_front() {
                for &v in g.neighbors(u) {
                    if assign[v] == m && !seen[v] {
                        seen[v] = true;
                        reached += 1;
                        queue.push_back(v);
                    }
                }
            }
            assert_eq!(reached, members.len(), "machine {m} region disconnected");
        }
    }

    #[test]
    fn thm_a1_growth_monotone_and_bounded() {
        let growth = er_cluster_growth(1000, 0.01, 20);
        for w in growth.windows(2) {
            assert!(w[1] >= w[0] - 1e-9, "growth not monotone: {w:?}");
        }
        assert!(growth.iter().all(|&n| n <= 1000.0 + 1e-9));
        // With p=0.01, mean degree ~10: growth should be fast but the
        // first hop adds about |V|·p ≈ 10 nodes.
        assert!((growth[2] - 1.0 - 999.0 * (1.0 - 0.99f64)).abs() < 1.0);
    }

    #[test]
    fn thm_a1_matches_er_simulation() {
        // Empirical check of the recursion against actual BFS layers.
        let v = 600;
        let p = 0.008;
        let predicted = er_cluster_growth(v, p, 6);
        let mut rng = Pcg32::new(5);
        let trials = 40;
        let mut measured = vec![0.0f64; predicted.len()];
        for _ in 0..trials {
            let g = erdos_renyi(v, p, &mut rng);
            let d = metrics::bfs_distances(&g, rng.index(v));
            for hop in 0..predicted.len() {
                // Cluster size by hop `hop` = # nodes with distance < hop.
                let cnt = d.iter().filter(|&&x| x != usize::MAX && x < hop).count();
                measured[hop] += cnt as f64 / trials as f64;
            }
        }
        // Compare at hop 2 and 3 (before saturation effects dominate).
        for hop in [2usize, 3] {
            let rel = (measured[hop] - predicted[hop]).abs() / predicted[hop].max(1.0);
            assert!(
                rel < 0.35,
                "hop {hop}: measured {} vs predicted {} (rel {rel})",
                measured[hop],
                predicted[hop]
            );
        }
    }

    #[test]
    fn hops_to_cover_sane() {
        let h = er_hops_to_cover(1000, 0.01, 200.0);
        assert!(h >= 2 && h <= 10, "h={h}");
    }
}

//! Partition state: the LP-to-machine assignment vector plus the
//! machine-level aggregates (`L_k = Σ_{j: r_j = k} b_j`) that make the
//! game's cost functions evaluable with O(K) shared state — the paper's
//! feasibility argument (§4.5): synchronization overhead is independent
//! of the number of simulated nodes.

pub mod baselines;
pub mod global_cost;
pub mod initial;

use crate::graph::{Graph, NodeId};

/// Machine (partition) identifier, `0..K`.
pub type MachineId = usize;

/// Static description of the machine pool: normalized speeds
/// `w_k = s_k / Σ_j s_j` (§3).
#[derive(Debug, Clone, PartialEq)]
pub struct MachineConfig {
    speeds: Vec<f64>,
}

impl MachineConfig {
    /// Build from raw (unnormalized) speeds.
    pub fn from_speeds(raw: &[f64]) -> Self {
        assert!(!raw.is_empty(), "need at least one machine");
        assert!(raw.iter().all(|&s| s > 0.0), "speeds must be positive");
        let total: f64 = raw.iter().sum();
        MachineConfig { speeds: raw.iter().map(|s| s / total).collect() }
    }

    /// `k` machines of equal speed.
    pub fn homogeneous(k: usize) -> Self {
        assert!(k >= 1);
        MachineConfig { speeds: vec![1.0 / k as f64; k] }
    }

    /// Adopt already-normalized speeds verbatim, without dividing by
    /// the sum again. Used to reconstruct a `MachineConfig` from
    /// speeds that were produced by [`MachineConfig::speeds`] on
    /// another machine: renormalizing can shift each weight by an ulp
    /// (e.g. five 0.2s sum to 1.0000000000000002), which would break
    /// the bit-identical replica guarantee of the TCP coordinator.
    pub fn from_normalized(speeds: Vec<f64>) -> Self {
        assert!(!speeds.is_empty(), "need at least one machine");
        assert!(speeds.iter().all(|&s| s > 0.0), "speeds must be positive");
        let total: f64 = speeds.iter().sum();
        assert!((total - 1.0).abs() < 1e-6, "speeds are not normalized (sum {total})");
        MachineConfig { speeds }
    }

    /// Number of machines `K`.
    pub fn count(&self) -> usize {
        self.speeds.len()
    }

    /// Normalized speed `w_k`.
    #[inline]
    pub fn speed(&self, k: MachineId) -> f64 {
        self.speeds[k]
    }

    /// All normalized speeds.
    pub fn speeds(&self) -> &[f64] {
        &self.speeds
    }
}

/// The assignment vector `r` plus incrementally-maintained per-machine
/// load aggregates.
#[derive(Debug, Clone)]
pub struct Partition {
    /// `assignment[i] = r_i` — machine owning node `i`.
    assignment: Vec<MachineId>,
    /// `loads[k] = L_k = Σ_{j: r_j = k} b_j`.
    loads: Vec<f64>,
    /// `counts[k]` = number of nodes on machine `k`.
    counts: Vec<usize>,
    k: usize,
}

impl Partition {
    /// Build from an explicit assignment vector.
    pub fn from_assignment(graph: &Graph, k: usize, assignment: Vec<MachineId>) -> Self {
        assert_eq!(assignment.len(), graph.node_count());
        assert!(assignment.iter().all(|&r| r < k), "machine id out of range");
        let mut loads = vec![0.0; k];
        let mut counts = vec![0usize; k];
        for (i, &r) in assignment.iter().enumerate() {
            loads[r] += graph.node_weight(i);
            counts[r] += 1;
        }
        Partition { assignment, loads, counts, k }
    }

    /// All nodes on machine 0 (degenerate start).
    pub fn all_on_machine(graph: &Graph, k: usize, machine: MachineId) -> Self {
        assert!(machine < k);
        Partition::from_assignment(graph, k, vec![machine; graph.node_count()])
    }

    /// Number of machines `K`.
    pub fn machine_count(&self) -> usize {
        self.k
    }

    /// Number of nodes `N`.
    pub fn node_count(&self) -> usize {
        self.assignment.len()
    }

    /// Machine of node `i`.
    #[inline]
    pub fn machine_of(&self, i: NodeId) -> MachineId {
        self.assignment[i]
    }

    /// The whole assignment vector.
    pub fn assignment(&self) -> &[MachineId] {
        &self.assignment
    }

    /// Aggregate load `L_k`.
    #[inline]
    pub fn load(&self, k: MachineId) -> f64 {
        self.loads[k]
    }

    /// All aggregate loads — this O(K) vector is the *only* global state
    /// machines exchange during refinement (§4.5).
    pub fn loads(&self) -> &[f64] {
        &self.loads
    }

    /// Node count on machine `k`.
    pub fn count(&self, k: MachineId) -> usize {
        self.counts[k]
    }

    /// All node counts.
    pub fn counts(&self) -> &[usize] {
        &self.counts
    }

    /// Nodes currently assigned to machine `k` (O(N) scan; the hot path
    /// keeps its own per-machine membership lists — see `game::refine`).
    pub fn members(&self, k: MachineId) -> Vec<NodeId> {
        self.assignment
            .iter()
            .enumerate()
            .filter(|&(_, &r)| r == k)
            .map(|(i, _)| i)
            .collect()
    }

    /// Move node `i` to machine `to`, maintaining aggregates. Returns the
    /// previous machine.
    pub fn transfer(&mut self, graph: &Graph, i: NodeId, to: MachineId) -> MachineId {
        assert!(to < self.k);
        let from = self.assignment[i];
        if from == to {
            return from;
        }
        let b = graph.node_weight(i);
        self.loads[from] -= b;
        self.loads[to] += b;
        self.counts[from] -= 1;
        self.counts[to] += 1;
        self.assignment[i] = to;
        from
    }

    /// Recompute aggregates from scratch (used after the graph's node
    /// weights change between refinement epochs, and by validity checks).
    pub fn rebuild_aggregates(&mut self, graph: &Graph) {
        self.loads.iter_mut().for_each(|l| *l = 0.0);
        self.counts.iter_mut().for_each(|c| *c = 0);
        for (i, &r) in self.assignment.iter().enumerate() {
            self.loads[r] += graph.node_weight(i);
            self.counts[r] += 1;
        }
    }

    /// Check internal consistency against the graph: every node assigned
    /// to a valid machine and aggregates equal from-scratch recomputation.
    pub fn validate(&self, graph: &Graph) -> Result<(), String> {
        if self.assignment.len() != graph.node_count() {
            return Err(format!(
                "assignment len {} != node count {}",
                self.assignment.len(),
                graph.node_count()
            ));
        }
        let mut loads = vec![0.0; self.k];
        let mut counts = vec![0usize; self.k];
        for (i, &r) in self.assignment.iter().enumerate() {
            if r >= self.k {
                return Err(format!("node {i} on invalid machine {r}"));
            }
            loads[r] += graph.node_weight(i);
            counts[r] += 1;
        }
        for k in 0..self.k {
            if (loads[k] - self.loads[k]).abs() > 1e-6 * (1.0 + loads[k].abs()) {
                return Err(format!("load[{k}] drift: {} vs {}", self.loads[k], loads[k]));
            }
            if counts[k] != self.counts[k] {
                return Err(format!("count[{k}] drift: {} vs {}", self.counts[k], counts[k]));
            }
        }
        Ok(())
    }

    /// Load imbalance: max_k (L_k / w_k) / (Σ L / 1) − 1, i.e. how far the
    /// worst machine is above the speed-weighted ideal. 0 = perfect.
    pub fn imbalance(&self, machines: &MachineConfig) -> f64 {
        let total: f64 = self.loads.iter().sum();
        if total == 0.0 {
            return 0.0;
        }
        let worst = (0..self.k)
            .map(|k| self.loads[k] / machines.speed(k))
            .fold(f64::NEG_INFINITY, f64::max);
        worst / total - 1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{table1_graph, WeightModel};
    use crate::util::rng::Pcg32;

    fn setup() -> (Graph, Partition) {
        let mut rng = Pcg32::new(1);
        let g = table1_graph(40, 3, 6, WeightModel::default(), &mut rng);
        let assignment: Vec<usize> = (0..40).map(|i| i % 4).collect();
        let p = Partition::from_assignment(&g, 4, assignment);
        (g, p)
    }

    #[test]
    fn machine_config_normalizes() {
        let m = MachineConfig::from_speeds(&[1.0, 2.0, 3.0, 3.0, 1.0]);
        assert_eq!(m.count(), 5);
        assert!((m.speeds().iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((m.speed(2) - 0.3).abs() < 1e-12);
    }

    #[test]
    fn homogeneous_speeds() {
        let m = MachineConfig::homogeneous(4);
        assert!((m.speed(0) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn aggregates_match_scan() {
        let (g, p) = setup();
        p.validate(&g).unwrap();
        let total: f64 = p.loads().iter().sum();
        assert!((total - g.total_node_weight()).abs() < 1e-9);
    }

    #[test]
    fn transfer_maintains_aggregates() {
        let (g, mut p) = setup();
        let before_load = p.load(0) + p.load(1);
        let from = p.transfer(&g, 0, 1);
        assert_eq!(from, 0);
        assert_eq!(p.machine_of(0), 1);
        p.validate(&g).unwrap();
        assert!((p.load(0) + p.load(1) - before_load).abs() < 1e-9);
    }

    #[test]
    fn transfer_same_machine_noop() {
        let (g, mut p) = setup();
        let l0 = p.load(0);
        p.transfer(&g, 0, 0);
        assert_eq!(p.load(0), l0);
        p.validate(&g).unwrap();
    }

    #[test]
    fn members_partition_nodes() {
        let (_, p) = setup();
        let mut all: Vec<usize> = (0..4).flat_map(|k| p.members(k)).collect();
        all.sort_unstable();
        assert_eq!(all, (0..40).collect::<Vec<_>>());
    }

    #[test]
    fn rebuild_after_reweighting() {
        let (mut g, mut p) = setup();
        let w: Vec<f64> = (0..40).map(|i| (i + 1) as f64).collect();
        g.set_node_weights(&w);
        p.rebuild_aggregates(&g);
        p.validate(&g).unwrap();
    }

    #[test]
    fn imbalance_zero_when_proportional() {
        let mut rng = Pcg32::new(9);
        let g = table1_graph(30, 3, 6, WeightModel { node_mean: 1.0, edge_mean: 1.0 }, &mut rng);
        // all nodes weight 1 after this
        let mut g = g;
        g.set_node_weights(&vec![1.0; 30]);
        let machines = MachineConfig::homogeneous(3);
        let assignment: Vec<usize> = (0..30).map(|i| i % 3).collect();
        let p = Partition::from_assignment(&g, 3, assignment);
        assert!(p.imbalance(&machines).abs() < 1e-9);
    }

    #[test]
    fn validate_detects_drift() {
        let (g, mut p) = setup();
        p.loads[0] += 100.0;
        assert!(p.validate(&g).is_err());
    }
}

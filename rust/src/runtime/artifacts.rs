//! Artifact manifest: discovery of the AOT-compiled HLO programs.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.txt`:
//! ```text
//! gtip-artifacts v1
//! artifact refine_step_n256_k8 n=256 k=8 file=refine_step_n256_k8.hlo.txt
//! ```

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// One compiled shape.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactSpec {
    pub name: String,
    /// Padded node count.
    pub n: usize,
    /// Padded machine count.
    pub k: usize,
    /// HLO text path (absolute or relative to the manifest).
    pub path: PathBuf,
}

/// Parsed manifest: the available padded-shape ladder.
#[derive(Debug, Clone, Default)]
pub struct ArtifactManifest {
    pub specs: Vec<ArtifactSpec>,
}

impl ArtifactManifest {
    /// Default on-disk location, overridable with `GTIP_ARTIFACTS_DIR`.
    pub fn default_dir() -> PathBuf {
        std::env::var("GTIP_ARTIFACTS_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Load `manifest.txt` from a directory.
    pub fn load_dir(dir: impl AsRef<Path>) -> Result<ArtifactManifest> {
        let dir = dir.as_ref();
        let text = std::fs::read_to_string(dir.join("manifest.txt"))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text; `base` resolves relative artifact files.
    pub fn parse(text: &str, base: &Path) -> Result<ArtifactManifest> {
        let mut lines = text.lines();
        let header = lines.next().unwrap_or("");
        if header.trim() != "gtip-artifacts v1" {
            return Err(Error::Runtime(format!("bad manifest header {header:?}")));
        }
        let mut specs = Vec::new();
        for line in lines {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            match parts.next() {
                Some("artifact") => {}
                other => return Err(Error::Runtime(format!("unknown record {other:?}"))),
            }
            let name = parts
                .next()
                .ok_or_else(|| Error::Runtime("artifact missing name".into()))?
                .to_string();
            let mut n = None;
            let mut k = None;
            let mut file = None;
            for kv in parts {
                let (key, value) = kv
                    .split_once('=')
                    .ok_or_else(|| Error::Runtime(format!("bad field {kv:?}")))?;
                match key {
                    "n" => n = Some(value.parse::<usize>().map_err(|e| Error::Runtime(e.to_string()))?),
                    "k" => k = Some(value.parse::<usize>().map_err(|e| Error::Runtime(e.to_string()))?),
                    "file" => file = Some(base.join(value)),
                    other => return Err(Error::Runtime(format!("unknown field {other:?}"))),
                }
            }
            specs.push(ArtifactSpec {
                name,
                n: n.ok_or_else(|| Error::Runtime("missing n".into()))?,
                k: k.ok_or_else(|| Error::Runtime("missing k".into()))?,
                path: file.ok_or_else(|| Error::Runtime("missing file".into()))?,
            });
        }
        if specs.is_empty() {
            return Err(Error::Runtime("manifest lists no artifacts".into()));
        }
        specs.sort_by_key(|s| (s.k, s.n));
        Ok(ArtifactManifest { specs })
    }

    /// Smallest artifact that fits an `n`-node, `k`-machine problem.
    pub fn best_fit(&self, n: usize, k: usize) -> Option<&ArtifactSpec> {
        self.specs.iter().find(|s| s.n >= n && s.k >= k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "gtip-artifacts v1\n\
        artifact refine_step_n256_k8 n=256 k=8 file=refine_step_n256_k8.hlo.txt\n\
        artifact refine_step_n512_k8 n=512 k=8 file=refine_step_n512_k8.hlo.txt\n";

    #[test]
    fn parses_sample() {
        let m = ArtifactManifest::parse(SAMPLE, Path::new("/a")).unwrap();
        assert_eq!(m.specs.len(), 2);
        assert_eq!(m.specs[0].n, 256);
        assert_eq!(m.specs[0].path, PathBuf::from("/a/refine_step_n256_k8.hlo.txt"));
    }

    #[test]
    fn best_fit_picks_smallest_adequate() {
        let m = ArtifactManifest::parse(SAMPLE, Path::new(".")).unwrap();
        assert_eq!(m.best_fit(230, 5).unwrap().n, 256);
        assert_eq!(m.best_fit(257, 5).unwrap().n, 512);
        assert_eq!(m.best_fit(256, 8).unwrap().n, 256);
        assert!(m.best_fit(600, 5).is_none());
        assert!(m.best_fit(100, 9).is_none());
    }

    #[test]
    fn rejects_bad_header() {
        assert!(ArtifactManifest::parse("nope\n", Path::new(".")).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        let r = ArtifactManifest::parse("gtip-artifacts v1\nartifact x n=2 k=2\n", Path::new("."));
        assert!(r.is_err());
    }
}
